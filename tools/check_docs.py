"""Execute every fenced ``python`` code block in README.md and docs/*.md.

Documentation snippets rot silently; this is the CI docs job (and a tier-1
test via tests/test_docs.py).  Rules:

* blocks fenced as ```python run headlessly, each in a fresh namespace,
  with src/ on sys.path (so snippets read exactly as a user would run
  them after ``pip install -e .``);
* blocks fenced as ```python no-run are syntax-checked only (for
  illustrative fragments that need external state);
* any other fence language (```bash, ```text, ...) is ignored.

Usage:  python tools/check_docs.py [file.md ...]
"""
from __future__ import annotations

import re
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
FENCE = re.compile(r"^```python([^\n]*)\n(.*?)^```\s*$",
                   re.MULTILINE | re.DOTALL)


def doc_files() -> list:
    return [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))


def iter_blocks(path: Path):
    text = path.read_text()
    for m in FENCE.finditer(text):
        info, code = m.group(1).strip(), m.group(2)
        line = text[:m.start()].count("\n") + 2  # first line of the code
        yield line, info, code


def check_file(path: Path) -> list:
    failures = []
    for line, info, code in iter_blocks(path):
        where = f"{path.relative_to(ROOT)}:{line}"
        t0 = time.time()
        try:
            if "no-run" in info:
                compile(code, where, "exec")
                verdict = "SYNTAX-OK"
            else:
                exec(compile(code, where, "exec"), {"__name__": "__docs__"})
                verdict = "OK"
        except Exception as e:  # noqa: BLE001 — report and keep going
            failures.append((where, e))
            print(f"FAIL      {where}  {type(e).__name__}: {e}", flush=True)
            continue
        print(f"{verdict:9s} {where}  ({time.time() - t0:.1f}s)", flush=True)
    return failures


def main(argv=None) -> int:
    sys.path.insert(0, str(ROOT / "src"))
    paths = ([Path(a).resolve() for a in argv] if argv else doc_files())
    failures, n_files = [], 0
    for p in paths:
        if not p.exists():
            print(f"missing doc file: {p}", flush=True)
            failures.append((str(p), FileNotFoundError(p)))
            continue
        n_files += 1
        failures += check_file(p)
    print(f"\n{n_files} doc files checked; {len(failures)} failing blocks",
          flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

"""Trace report CLI: summarize a serving trace into the critical-path
breakdown table, validate trace files, and run the self-contained
trace smoke (the CI ``trace-smoke`` step).

A trace is the Chrome trace-event / Perfetto JSON a traced session
exports (``SessionConfig(trace=True)`` + ``MonitorSession.export_trace``,
or ``bench_serving --trace`` / ``launch.serve --trace`` /
``launch.server --trace-file``).  This tool reads one back and answers
the ROADMAP's question — where does the wire RTT actually go? — as a
table over the four stages that tile each request (serialize / socket /
queue / compute), plus every edge-side span group.

Usage::

    python tools/trace_report.py results/trace_wire_b64.json
    python tools/trace_report.py --validate results/trace_wire_b64.json
    python tools/trace_report.py --smoke [--out /tmp/trace.json]

``--validate`` only runs the schema gate (exit nonzero on violation).
``--smoke`` needs no input file: it spawns a correction-server
subprocess, runs a traced batch-8 wire session against it (threshold
pinned low so every step triggers), exports the trace, validates it,
and prints the breakdown — the whole observability path in one command.
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def report(path: str) -> None:
    from repro.observability import breakdown_table, load_trace
    obj = load_trace(path)
    events = obj["traceEvents"]
    other = obj.get("otherData", {})
    print(f"{path}: {len(events)} events, trace_id="
          f"{other.get('trace_id', '?')}, dropped={other.get('dropped', 0)}")
    for line in breakdown_table(events):
        print(line)


def validate(path: str) -> None:
    from repro.observability import load_trace
    n = len(load_trace(path)["traceEvents"])
    print(f"{path}: OK ({n} events)")


def smoke(out: str, *, batch: int = 8, steps: int = 24,
          transport: str = "wire",
          max_socket_p50_ms: float = None) -> None:
    """Traced end-to-end session against a spawned server process.

    ``transport="shm"`` starts the server with ``--transport shm`` and
    attaches through the shared-memory ring pair: the smoke then
    requires the ``shm.ring`` span group (payload frames must actually
    ride the rings, not silently fall back to the socket).
    ``max_socket_p50_ms`` optionally bounds the socket-stage p50 — the
    CI shm-smoke passes the measured wire baseline here, so a shm run
    that stops collapsing the transport stage fails loudly."""
    import numpy as np

    from repro.configs.paper_synthetic import SERVING
    from repro.core import decomposition as deco
    from repro.launch.server import spawn_subprocess
    from repro.observability import breakdown, breakdown_table, load_trace
    from repro.serving import MonitorSession, SessionConfig, TransportSpec

    import jax

    cfg = SERVING
    params = deco.init_collab_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    stream = rng.integers(0, cfg.vocab_size, (batch, steps)).astype(np.int32)

    extra = ["--idle-exit-s", "30"]
    if transport == "shm":
        extra += ["--transport", "shm"]
    tmp = tempfile.mkdtemp(prefix="trace-smoke-")
    uds = os.path.join(tmp, "corr.sock")
    proc = spawn_subprocess("paper-synthetic-serving", uds=uds,
                            slots=batch, max_len=steps + 8,
                            ready_file=os.path.join(tmp, "ready"),
                            extra_args=tuple(extra))
    try:
        # pin the operating point so EVERY step triggers: the smoke must
        # exercise dispatch / wire / server spans, not depend on the data
        config = SessionConfig(mode="async", max_staleness=4, trace=True,
                               threshold=-1e9, trigger_margin=0.0,
                               transport=TransportSpec(transport,
                                                       address=uds))
        session = MonitorSession.open(params, cfg, batch=batch,
                                      max_len=steps + 8, config=config)
        session.run(stream)
        n = session.export_trace(out)
        obj = load_trace(out)  # the schema gate
        names = {e["name"] for e in obj["traceEvents"] if e.get("ph") == "X"}
        required = {"edge.decode", "edge.trigger", "wire.encode",
                    "wire.request", "server.queue", "server.catchup"}
        if transport == "shm":
            # frames must ride the rings: a silent wire fallback would
            # still pass every other gate
            required |= {"shm.ring"}
        missing = required - names
        if missing:
            raise SystemExit(f"trace-smoke: missing span groups {missing}")
        print(f"trace-smoke OK ({transport}): {n} spans -> {out}")
        for line in breakdown_table(obj["traceEvents"]):
            print(line)
        if max_socket_p50_ms is not None:
            sock = breakdown(obj["traceEvents"]).get("socket")
            if sock is None:
                raise SystemExit("trace-smoke: no socket-stage spans")
            p50_ms = sock["p50_s"] * 1e3
            if p50_ms >= max_socket_p50_ms:
                raise SystemExit(
                    f"trace-smoke: socket-stage p50 {p50_ms:.3f}ms >= "
                    f"bound {max_socket_p50_ms:.3f}ms")
            print(f"socket-stage p50 {p50_ms:.3f}ms < "
                  f"{max_socket_p50_ms:.3f}ms bound")
    finally:
        proc.terminate()
        proc.wait(timeout=30)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", nargs="?", default=None,
                    help="trace JSON to summarize")
    ap.add_argument("--validate", action="store_true",
                    help="schema-validate only (no table)")
    ap.add_argument("--smoke", action="store_true",
                    help="spawn a server, run a traced session, "
                         "validate + summarize (the CI trace-smoke and "
                         "shm-smoke steps)")
    ap.add_argument("--transport", choices=("wire", "shm"), default="wire",
                    help="--smoke: transport to drive (shm additionally "
                         "requires the shm.ring span group)")
    ap.add_argument("--max-socket-p50-ms", type=float, default=None,
                    help="--smoke: fail if the socket-stage p50 exceeds "
                         "this bound (CI shm-smoke passes the measured "
                         "wire baseline)")
    ap.add_argument("--out", default=None,
                    help="--smoke: where to write the trace "
                         "(default: results/trace_smoke.json)")
    args = ap.parse_args(argv)
    if args.smoke:
        if args.trace is not None:
            ap.error("--smoke generates its own trace (drop the argument)")
        smoke(args.out or "results/trace_smoke.json",
              transport=args.transport,
              max_socket_p50_ms=args.max_socket_p50_ms)
        return
    if args.trace is None:
        ap.error("need a trace file (or --smoke)")
    if args.validate:
        validate(args.trace)
    else:
        report(args.trace)


if __name__ == "__main__":
    try:
        main()
    except BrokenPipeError:  # e.g. piped into head
        sys.exit(0)

"""Trace report CLI: summarize a serving trace into the critical-path
breakdown table, validate trace files, and run the self-contained
trace smoke (the CI ``trace-smoke`` step).

A trace is the Chrome trace-event / Perfetto JSON a traced session
exports (``SessionConfig(trace=True)`` + ``MonitorSession.export_trace``,
or ``bench_serving --trace`` / ``launch.serve --trace`` /
``launch.server --trace-file``).  This tool reads one back and answers
the ROADMAP's question — where does the wire RTT actually go? — as a
table over the four stages that tile each request (serialize / socket /
queue / compute), plus every edge-side span group.

Usage::

    python tools/trace_report.py results/trace_wire_b64.json
    python tools/trace_report.py --validate results/trace_wire_b64.json
    python tools/trace_report.py --smoke [--out /tmp/trace.json]

``--validate`` only runs the schema gate (exit nonzero on violation).
``--smoke`` needs no input file: it spawns a correction-server
subprocess, runs a traced batch-8 wire session against it (threshold
pinned low so every step triggers), exports the trace, validates it,
and prints the breakdown — the whole observability path in one command.
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def report(path: str) -> None:
    from repro.observability import breakdown_table, load_trace
    obj = load_trace(path)
    events = obj["traceEvents"]
    other = obj.get("otherData", {})
    print(f"{path}: {len(events)} events, trace_id="
          f"{other.get('trace_id', '?')}, dropped={other.get('dropped', 0)}")
    for line in breakdown_table(events):
        print(line)


def validate(path: str) -> None:
    from repro.observability import load_trace
    n = len(load_trace(path)["traceEvents"])
    print(f"{path}: OK ({n} events)")


def smoke(out: str, *, batch: int = 8, steps: int = 24) -> None:
    """Traced end-to-end wire session against a spawned server process."""
    import numpy as np

    from repro.configs.paper_synthetic import SERVING
    from repro.core import decomposition as deco
    from repro.launch.server import spawn_subprocess
    from repro.observability import breakdown_table, load_trace
    from repro.serving import MonitorSession, SessionConfig, TransportSpec

    import jax

    cfg = SERVING
    params = deco.init_collab_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    stream = rng.integers(0, cfg.vocab_size, (batch, steps)).astype(np.int32)

    tmp = tempfile.mkdtemp(prefix="trace-smoke-")
    uds = os.path.join(tmp, "corr.sock")
    proc = spawn_subprocess("paper-synthetic-serving", uds=uds,
                            slots=batch, max_len=steps + 8,
                            ready_file=os.path.join(tmp, "ready"),
                            extra_args=("--idle-exit-s", "30"))
    try:
        # pin the operating point so EVERY step triggers: the smoke must
        # exercise dispatch / wire / server spans, not depend on the data
        config = SessionConfig(mode="async", max_staleness=4, trace=True,
                               threshold=-1e9, trigger_margin=0.0,
                               transport=TransportSpec("wire", address=uds))
        session = MonitorSession.open(params, cfg, batch=batch,
                                      max_len=steps + 8, config=config)
        session.run(stream)
        n = session.export_trace(out)
        obj = load_trace(out)  # the schema gate
        names = {e["name"] for e in obj["traceEvents"] if e.get("ph") == "X"}
        required = {"edge.decode", "edge.trigger", "wire.encode",
                    "wire.request", "server.queue", "server.catchup"}
        missing = required - names
        if missing:
            raise SystemExit(f"trace-smoke: missing span groups {missing}")
        print(f"trace-smoke OK: {n} spans -> {out}")
        for line in breakdown_table(obj["traceEvents"]):
            print(line)
    finally:
        proc.terminate()
        proc.wait(timeout=30)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", nargs="?", default=None,
                    help="trace JSON to summarize")
    ap.add_argument("--validate", action="store_true",
                    help="schema-validate only (no table)")
    ap.add_argument("--smoke", action="store_true",
                    help="spawn a server, run a traced wire session, "
                         "validate + summarize (the CI trace-smoke step)")
    ap.add_argument("--out", default=None,
                    help="--smoke: where to write the trace "
                         "(default: results/trace_smoke.json)")
    args = ap.parse_args(argv)
    if args.smoke:
        if args.trace is not None:
            ap.error("--smoke generates its own trace (drop the argument)")
        smoke(args.out or "results/trace_smoke.json")
        return
    if args.trace is None:
        ap.error("need a trace file (or --smoke)")
    if args.validate:
        validate(args.trace)
    else:
        report(args.trace)


if __name__ == "__main__":
    try:
        main()
    except BrokenPipeError:  # e.g. piped into head
        sys.exit(0)

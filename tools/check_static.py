"""Compile-time invariant verifier for the whole serving stack.

Runs every ``analysis`` rule against every registered arch and prints a
report table (CI's ``static-analysis`` job):

* ``sign-safety`` — jaxpr interval/sign certificates: ``corr >= 0`` and
  ``fhat <= u`` on the training forward AND the serving catch-up, per
  arch x sigma kind (counterexample primitive chain on failure);
* ``collective-free`` / ``no-host-transfer`` / ``no-dynamic-shapes`` —
  parsed per-op HLO rules over each arch's compiled monitor path;
* ``recompile-once`` — a guarded churn episode on the paper serving
  config (each jitted path compiles exactly once after warmup);
* the mutation self-test — seeds one violation per rule (sign flip,
  injected psum, host callback, dynamic dim, forced retrace) and
  asserts the rule fires.

Usage::

    python tools/check_static.py [--strict] [--arch NAME ...]
                                 [--no-selftest] [--no-recompile]
                                 [--verbose]

``--strict`` exits nonzero on any failed rule or non-firing mutation.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _parse_args(argv):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--strict", action="store_true",
                    help="exit nonzero on any failure")
    ap.add_argument("--arch", nargs="*", default=None,
                    help="restrict to these registry archs")
    ap.add_argument("--no-selftest", action="store_true",
                    help="skip the mutation self-test")
    ap.add_argument("--no-recompile", action="store_true",
                    help="skip the churn recompile guard")
    ap.add_argument("--verbose", action="store_true",
                    help="print rule details even on pass")
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = _parse_args(argv)
    # the psum mutation needs >=2 devices; pin the virtual device count
    # BEFORE jax imports (no-op when the user already set XLA_FLAGS)
    if "jax" not in sys.modules and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"

    from repro.analysis import rules

    t0 = time.time()
    results = []
    results += rules.run_sign_rules(args.arch)
    results += rules.run_hlo_rules(args.arch)
    if not args.no_recompile:
        results += rules.run_recompile_rule()
    if not args.no_selftest:
        selftest = rules.mutation_selftest()
        for r in selftest:
            r.rule = "selftest/" + r.rule
        results += selftest

    print(rules.format_report(results, verbose=args.verbose))
    print(f"({time.time() - t0:.1f}s)")
    n_fail = sum(not r.ok for r in results)
    if n_fail and args.strict:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Deterministic fallback for ``hypothesis`` when it is not installed.

The real dependency is declared in pyproject's ``test`` extra
(``pip install -e .[test]``); this stub keeps the suite collecting and
running in hermetic environments where it is absent.  It implements the
tiny subset the tests use — ``given`` with positional/keyword strategies,
``settings(max_examples=..., deadline=...)``, and the ``floats`` /
``integers`` / ``booleans`` / ``sampled_from`` / ``lists`` strategies —
drawing a fixed number of deterministic pseudo-random examples per test
(seeded from the test's qualified name, so runs are reproducible).  No
shrinking; on failure the falsifying example is attached to the error.

``tests/conftest.py`` registers this module as ``sys.modules["hypothesis"]``
only when the real package is missing.
"""
from __future__ import annotations

import functools
import inspect
import random
import zlib

DEFAULT_MAX_EXAMPLES = 20


class SearchStrategy:
    def __init__(self, draw):
        self._draw = draw

    def example_from(self, rng: random.Random):
        return self._draw(rng)


class strategies:  # mirrors `from hypothesis import strategies as st`
    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_kw) -> SearchStrategy:
        lo, hi = float(min_value), float(max_value)
        return SearchStrategy(lambda rng: rng.uniform(lo, hi))

    @staticmethod
    def integers(min_value=0, max_value=2**31 - 1) -> SearchStrategy:
        lo, hi = int(min_value), int(max_value)
        return SearchStrategy(lambda rng: rng.randint(lo, hi))

    @staticmethod
    def booleans() -> SearchStrategy:
        return SearchStrategy(lambda rng: bool(rng.getrandbits(1)))

    @staticmethod
    def sampled_from(elements) -> SearchStrategy:
        elements = list(elements)
        return SearchStrategy(lambda rng: rng.choice(elements))

    @staticmethod
    def lists(elements: SearchStrategy, min_size=0, max_size=10,
              **_kw) -> SearchStrategy:
        def draw(rng):
            n = rng.randint(int(min_size), int(max_size))
            return [elements.example_from(rng) for _ in range(n)]
        return SearchStrategy(draw)


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None,
             **_kw):
    """Works above or below @given: sets the example budget on whatever
    callable it decorates (the raw test or the given-wrapper)."""
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*gargs, **gkwargs):
    def deco(fn):
        sig_names = [p.name for p in inspect.signature(fn).parameters.values()]
        # hypothesis semantics: positional strategies fill the RIGHTMOST
        # parameters (so methods' `self` is left to the caller)
        pos_names = sig_names[len(sig_names) - len(gargs):] if gargs else []

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            max_ex = getattr(wrapper, "_stub_max_examples",
                             getattr(fn, "_stub_max_examples",
                                     DEFAULT_MAX_EXAMPLES))
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(max_ex):
                draw = {name: strat.example_from(rng)
                        for name, strat in zip(pos_names, gargs)}
                draw.update({name: strat.example_from(rng)
                             for name, strat in gkwargs.items()})
                try:
                    fn(*args, **draw, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (hypothesis stub): {draw}") from e

        # pytest introspects the signature to resolve fixtures: expose one
        # WITHOUT the strategy-filled parameters (mirrors real hypothesis)
        filled = set(pos_names) | set(gkwargs)
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(
            parameters=[p for p in sig.parameters.values()
                        if p.name not in filled])
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__  # stop pytest unwrapping to fn
        wrapper.hypothesis_stub = True
        return wrapper
    return deco

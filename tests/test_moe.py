"""MoE dispatch invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.moe import expert_capacity, init_moe, moe_apply

KEY = jax.random.PRNGKey(3)


def dense_moe_reference(p, x, n_experts, top_k):
    """Dense (no-capacity) reference: every token reaches its top-k experts."""
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)
    logits = xf @ p["router"]["w"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    y = jnp.zeros((T, d), jnp.float32)
    for e in range(n_experts):
        g = jax.nn.silu(xf @ p["w_gate"][e]) * (xf @ p["w_up"][e])
        eo = g @ p["w_down"][e]
        w = jnp.sum(jnp.where(top_i == e, top_p, 0.0), axis=-1)
        y = y + eo * w[:, None]
    if "shared" in p:
        sp = p["shared"]
        y = y + (jax.nn.silu(xf @ sp["w_gate"]) * (xf @ sp["w_up"])) @ sp["w_down"]
    return y.reshape(B, S, d)


class TestDispatch:
    @pytest.mark.parametrize("n_experts,top_k,n_shared", [(4, 2, 0), (8, 2, 1),
                                                          (4, 1, 0)])
    def test_matches_dense_reference_at_high_capacity(self, n_experts, top_k,
                                                      n_shared):
        d, dff = 64, 96
        p = init_moe(KEY, d, dff, n_experts, n_shared=n_shared)
        x = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 32, d))
        # capacity_factor big enough that nothing drops
        y, aux = moe_apply(p, x, n_experts=n_experts, top_k=top_k,
                           capacity_factor=float(n_experts),
                           compute_dtype=jnp.float32)
        ref = dense_moe_reference(p, x, n_experts, top_k)
        np.testing.assert_allclose(y, ref, atol=1e-4, rtol=1e-4)

    def test_dropping_is_graceful(self):
        """Tiny capacity: output stays finite; dropped tokens contribute 0."""
        d, dff, E = 32, 48, 4
        p = init_moe(KEY, d, dff, E)
        x = jax.random.normal(KEY, (1, 64, d))
        y, _ = moe_apply(p, x, n_experts=E, top_k=2, capacity_factor=0.05,
                         compute_dtype=jnp.float32)
        assert bool(jnp.all(jnp.isfinite(y)))
        # with capacity ~0 almost everything drops -> y ~ 0 for most tokens
        frac_zero = float(jnp.mean(jnp.all(jnp.abs(y) < 1e-9, axis=-1)))
        assert frac_zero > 0.5

    def test_aux_loss_uniform_router_is_one(self):
        """Balanced routing gives aux ~ 1 (Switch normalisation)."""
        d, dff, E = 32, 48, 8
        p = init_moe(KEY, d, dff, E)
        p["router"]["w"] = jnp.zeros_like(p["router"]["w"])  # uniform probs
        x = jax.random.normal(KEY, (2, 128, d))
        _, aux = moe_apply(p, x, n_experts=E, top_k=2, compute_dtype=jnp.float32)
        assert float(aux) == pytest.approx(1.0, rel=0.05)

    def test_capacity_rounding(self):
        assert expert_capacity(1024, 8, 2, 1.25) % 8 == 0
        assert expert_capacity(1024, 8, 2, 1.25) >= 1024 * 2 // 8

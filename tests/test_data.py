"""Data generators."""
import numpy as np

from repro.configs import registry
from repro.data import tokens as tok
from repro.data.synthetic import (financial_series, financial_xy,
                                  monitoring_target, paper_synthetic,
                                  synthetic_residual)


class TestPaperSynthetic:
    def test_matches_formula(self):
        x, f = paper_synthetic(0, 128, rho=0.9, n_modes=100)
        i = np.arange(1, 101)
        f_ref = np.cos(x * i[None, :]) @ (0.9 ** (i - 1))
        np.testing.assert_allclose(f, f_ref, rtol=1e-5)
        assert x.min() >= -3 and x.max() <= 3

    def test_residual_consistency(self):
        """f = truncated(n) + residual(n) exactly."""
        x, f = paper_synthetic(1, 64)
        n = 17
        i = np.arange(1, n + 1)
        trunc = np.cos(x * i[None, :]) @ (0.9 ** (i - 1))
        np.testing.assert_allclose(trunc + synthetic_residual(x, n), f,
                                   rtol=1e-4, atol=1e-5)


class TestFinancial:
    def test_panel_statistics(self):
        panel = financial_series(0)
        assert panel.shape == (2520, 30)
        assert panel.min() >= 0.0 and panel.max() <= 1.0
        x, f = financial_xy(panel)
        assert x.shape == (2520, 29) and f.shape == (2520,)
        # correlated market: average pairwise correlation is substantial
        c = np.corrcoef(panel.T)
        off = c[~np.eye(30, dtype=bool)]
        assert off.mean() > 0.2

    def test_deterministic(self):
        np.testing.assert_array_equal(financial_series(7), financial_series(7))


class TestMonitoringTarget:
    def test_deterministic_given_tokens(self):
        t = np.random.default_rng(0).integers(0, 512, (2, 64))
        np.testing.assert_array_equal(monitoring_target(t, 512),
                                      monitoring_target(t, 512))

    def test_adverse_events_sparse_but_present(self):
        t = np.random.default_rng(1).integers(0, 512, (8, 2048))
        f = monitoring_target(t, 512)
        frac = (f > 0).mean()
        assert 0.005 < frac < 0.6


class TestLMBatches:
    def test_batch_contract(self):
        cfg = registry.get_smoke("granite-8b")
        b = next(tok.lm_batches(0, cfg, 4, 32))
        assert b["tokens"].shape == (4, 32) and b["labels"].shape == (4, 32)
        assert b["tokens"].max() < cfg.vocab_size
        assert b["monitor_target"].shape == (4, 32)
        # labels are the shifted stream
        b2 = next(tok.lm_batches(0, cfg, 4, 32))
        np.testing.assert_array_equal(b["tokens"], b2["tokens"])

    def test_vlm_batch_has_image_embeds(self):
        cfg = registry.get_smoke("llama-3.2-vision-11b")
        b = next(tok.lm_batches(0, cfg, 2, 16))
        assert b["image_embeds"].shape == (2, cfg.n_image_tokens, cfg.d_model)

    def test_audio_batch_has_codebooks(self):
        cfg = registry.get_smoke("musicgen-large")
        b = next(tok.lm_batches(0, cfg, 2, 16))
        assert b["tokens"].shape == (2, 16, cfg.n_codebooks)

"""Trigger gating + communication accounting."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.gating import (CommsMeter, compact_correction,
                               masked_correction, trigger_mask)

KEY = jax.random.PRNGKey(11)


class TestMaskedCorrection:
    @given(thr=st.floats(-1, 1), margin=st.floats(0, 1), seed=st.integers(0, 99))
    @settings(max_examples=30, deadline=None)
    def test_untriggered_rows_pass_through(self, thr, margin, seed):
        k = jax.random.PRNGKey(seed)
        u = jax.random.normal(k, (256,))
        corr = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(k, 1), (256,)))
        fhat, mask = masked_correction(u, corr, thr, margin)
        quiet = np.asarray(mask) == 0
        np.testing.assert_allclose(np.asarray(fhat)[quiet], np.asarray(u)[quiet])
        fired = ~quiet
        np.testing.assert_allclose(np.asarray(fhat)[fired],
                                   np.asarray(u - corr)[fired], atol=1e-6)


class TestCompactCorrection:
    def test_matches_masked_when_capacity_suffices(self):
        u = jax.random.normal(KEY, (128,))
        xs = jax.random.normal(jax.random.fold_in(KEY, 1), (128, 4))
        corrector = lambda b: jax.nn.sigmoid(b[:, 0])
        fhat_c, mask_c, n = compact_correction(u, xs, corrector, 0.0, 0.25, 128)
        corr_full = corrector(xs)
        fhat_m, mask_m = masked_correction(u, corr_full, 0.0, 0.25)
        np.testing.assert_allclose(fhat_c, fhat_m, atol=1e-6)
        np.testing.assert_allclose(mask_c, mask_m)
        assert int(n) == int(mask_m.sum())

    def test_capacity_overflow_serves_most_urgent(self):
        u = jnp.arange(32, dtype=jnp.float32)  # all triggered, 31 most urgent
        xs = jnp.ones((32, 2))
        fhat, mask, n = compact_correction(u, xs, lambda b: jnp.ones((b.shape[0],)),
                                           0.0, 0.5, capacity=8)
        served = np.where(np.asarray(mask) > 0)[0]
        assert set(served) == set(range(24, 32)), "top-capacity by urgency"
        assert int(n) == 32  # all triggered even if only 8 served

    def test_untriggered_never_served(self):
        u = jnp.array([-5.0, -4.0, 3.0, -6.0])
        fhat, mask, n = compact_correction(
            u, jnp.ones((4, 1)), lambda b: jnp.ones((b.shape[0],)), 0.0, 0.0, 4)
        np.testing.assert_allclose(mask, [0, 0, 1, 0])
        assert int(n) == 1


class TestCommsMeter:
    def test_reduction_math(self):
        m = CommsMeter(bytes_per_request=8)
        for _ in range(90):
            m.update(0, 10)
        for _ in range(10):
            m.update(10, 10)
        assert m.trigger_rate == 0.1
        assert m.reduction == 10.0
        rep = m.report()
        assert rep["bytes_baseline"] == 1000 * 8
        assert rep["bytes_sent"] == 100 * 8

    def test_windowed_rate_tracks_step_cumulative_washes_out(self):
        """The gauge the threshold controllers consume: after a
        trigger-rate step (quiet regime -> loud regime),
        ``recent_trigger_rate`` converges to the NEW rate within one
        window while the cumulative ``trigger_rate`` stays diluted by
        the old regime — the two must diverge."""
        m = CommsMeter(bytes_per_request=8, n_streams=2, rate_window=16)
        quiet = np.asarray([0, 0], np.int64)
        loud = np.asarray([1, 0], np.int64)  # stream 0 goes loud, 1 stays
        seen = np.asarray([1, 1], np.int64)
        for _ in range(200):
            m.update_per_stream(quiet, seen)
        assert m.recent_trigger_rate()[0] == 0.0
        for _ in range(16):  # one full window of the new regime
            m.update_per_stream(loud, seen)
        recent = m.recent_trigger_rate()
        assert recent[0] == 1.0          # gauge fully on the new rate
        assert recent[1] == 0.0          # per-stream: neighbor unaffected
        assert m.trigger_rate < 0.05     # cumulative still near the old one
        # the gauge also forgets: back to quiet, one window later it's 0
        for _ in range(16):
            m.update_per_stream(quiet, seen)
        assert m.recent_trigger_rate()[0] == 0.0

    def test_windowed_rate_ignores_legacy_aggregate_updates(self):
        """Only per-stream updates feed the ring: the legacy aggregate
        ``update()`` has no per-stream attribution to push."""
        m = CommsMeter(bytes_per_request=8, n_streams=1, rate_window=8)
        for _ in range(20):
            m.update(1, 1)
        assert m.recent_trigger_rate()[0] == 0.0
        assert m.trigger_rate == 1.0

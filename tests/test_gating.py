"""Trigger gating + communication accounting."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.gating import (CommsMeter, compact_correction,
                               masked_correction, trigger_mask)

KEY = jax.random.PRNGKey(11)


class TestMaskedCorrection:
    @given(thr=st.floats(-1, 1), margin=st.floats(0, 1), seed=st.integers(0, 99))
    @settings(max_examples=30, deadline=None)
    def test_untriggered_rows_pass_through(self, thr, margin, seed):
        k = jax.random.PRNGKey(seed)
        u = jax.random.normal(k, (256,))
        corr = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(k, 1), (256,)))
        fhat, mask = masked_correction(u, corr, thr, margin)
        quiet = np.asarray(mask) == 0
        np.testing.assert_allclose(np.asarray(fhat)[quiet], np.asarray(u)[quiet])
        fired = ~quiet
        np.testing.assert_allclose(np.asarray(fhat)[fired],
                                   np.asarray(u - corr)[fired], atol=1e-6)


class TestCompactCorrection:
    def test_matches_masked_when_capacity_suffices(self):
        u = jax.random.normal(KEY, (128,))
        xs = jax.random.normal(jax.random.fold_in(KEY, 1), (128, 4))
        corrector = lambda b: jax.nn.sigmoid(b[:, 0])
        fhat_c, mask_c, n = compact_correction(u, xs, corrector, 0.0, 0.25, 128)
        corr_full = corrector(xs)
        fhat_m, mask_m = masked_correction(u, corr_full, 0.0, 0.25)
        np.testing.assert_allclose(fhat_c, fhat_m, atol=1e-6)
        np.testing.assert_allclose(mask_c, mask_m)
        assert int(n) == int(mask_m.sum())

    def test_capacity_overflow_serves_most_urgent(self):
        u = jnp.arange(32, dtype=jnp.float32)  # all triggered, 31 most urgent
        xs = jnp.ones((32, 2))
        fhat, mask, n = compact_correction(u, xs, lambda b: jnp.ones((b.shape[0],)),
                                           0.0, 0.5, capacity=8)
        served = np.where(np.asarray(mask) > 0)[0]
        assert set(served) == set(range(24, 32)), "top-capacity by urgency"
        assert int(n) == 32  # all triggered even if only 8 served

    def test_untriggered_never_served(self):
        u = jnp.array([-5.0, -4.0, 3.0, -6.0])
        fhat, mask, n = compact_correction(
            u, jnp.ones((4, 1)), lambda b: jnp.ones((b.shape[0],)), 0.0, 0.0, 4)
        np.testing.assert_allclose(mask, [0, 0, 1, 0])
        assert int(n) == 1


class TestCommsMeter:
    def test_reduction_math(self):
        m = CommsMeter(bytes_per_request=8)
        for _ in range(90):
            m.update(0, 10)
        for _ in range(10):
            m.update(10, 10)
        assert m.trigger_rate == 0.1
        assert m.reduction == 10.0
        rep = m.report()
        assert rep["bytes_baseline"] == 1000 * 8
        assert rep["bytes_sent"] == 100 * 8

"""Slot-pool churn: streams attach/detach mid-session (MonitorSession),
locally and over the wire.

Invariants under churn (the acceptance set):

  * streams present for the whole run are BIT-IDENTICAL (u/trigger, and
    fhat in sync mode) to a fixed-batch run — admission and departure of
    neighbours never perturbs a co-resident stream;
  * a detached slot stops accruing communication charges;
  * a reused slot starts from a cold backlog: the new tenant's traces
    match a fresh fixed-batch engine's bit-for-bit, and its server
    catch-up starts at position 0;
  * over the wire, ATTACH/DETACH frames re-lease single super-batch rows
    without disturbing co-resident clients of the same server process.
"""
import os
import subprocess
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.paper_synthetic import SERVING
from repro.core import decomposition as deco
from repro.data import tokens as tok
from repro.serving import SessionConfig, TransportSpec
from repro.serving.collaborative import CollaborativeEngine

KEY = jax.random.PRNGKey(0)


def _setup(cfg_base=None, threshold=0.1, batch=3, length=16, seed=0):
    cfg = cfg_base if cfg_base is not None else registry.get_smoke("granite-8b")
    cfg = cfg.replace(monitor=cfg.monitor.__class__(
        **{**cfg.monitor.__dict__, "threshold": threshold,
           "trigger_margin": 0.0}))
    params = deco.init_collab_lm(KEY, cfg)
    stream = next(tok.lm_batches(seed, cfg, batch, length))["tokens"]
    return cfg, params, stream


def _trace(outs, sid, k):
    return np.asarray([o[k] for o in outs[sid]])


class TestLocalChurn:
    def test_churn_smoke(self):
        """CI churn smoke: one attach + one detach mid-session on the
        sync path; survivors bit-identical to a fixed-batch run, the
        joiner bit-cold."""
        self._check_mode(SessionConfig(mode="sync"))

    @pytest.mark.parametrize("config", [
        SessionConfig(mode="async", transport="inproc", max_staleness=2),
        SessionConfig(mode="async",
                      transport=TransportSpec("stream", latency_s=0.003),
                      max_staleness=3),
    ], ids=["async-inproc", "async-stream"])
    def test_churn_async(self, config):
        self._check_mode(config)

    def _check_mode(self, config, make_session=None):
        S, detach_at, attach_at = 16, 6, 9
        cfg, params, stream = _setup(length=S)
        fresh = next(tok.lm_batches(7, cfg, 1, S))["tokens"][0]

        # fixed-batch references (no churn): the original trio, and the
        # joiner "d" occupying slot 1 of a fresh engine from its step 0
        ref = CollaborativeEngine(params, cfg, batch=3,
                                  max_len=32).session().run(stream)
        joined = np.stack([stream[0], fresh, stream[2]])
        ref_d = CollaborativeEngine(params, cfg, batch=3,
                                    max_len=32).session().run(joined)

        eng = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        if make_session is None:
            session = eng.session(config, streams=["a", "b", "c"])
        else:
            session = make_session(eng)
        with session:
            seen_at_detach = None
            outs = {sid: [] for sid in "abcd"}
            for t in range(S):
                if t == detach_at:
                    session.detach("b")
                    seen_at_detach = int(eng.comms.tokens_seen[1])
                if t == attach_at:
                    assert session.attach("d") == 1
                toks = {sid: stream[{"a": 0, "b": 1, "c": 2}[sid], t]
                        for sid in session.streams if sid != "d"}
                if "d" in session.streams:
                    toks["d"] = fresh[t - attach_at]
                r = session.step(toks)
                for i, sid in enumerate(r["streams"]):
                    outs[sid].append((r["u"][i], r["fhat"][i],
                                      r["triggered"][i]))
            # snapshot the accounting the assertions below check BEFORE
            # the guarded epilogue perturbs it
            seen_final = int(eng.comms.tokens_seen[1])
            server_pos_d = int(eng.server_pos[1])
            # recompile guard (analysis.recompile): the episode above is
            # the warmup — every exercised jitted path must now be
            # compiled; further churn may not retrace ANY of them
            guard = session.arm_recompile_guard(track_global=False,
                                                warm_only=True)
            session.detach("a")
            assert session.attach("e") == 0
            for t2 in range(4):
                session.step({sid: stream[0, t2] for sid in session.streams})
        guard.assert_stable()  # zero retraces across the guarded churn

        # streams present the whole run: bit-identical to the fixed batch
        for sid, row in (("a", 0), ("c", 2)):
            np.testing.assert_array_equal(_trace(outs, sid, 0),
                                          ref["u"][row])
            np.testing.assert_array_equal(_trace(outs, sid, 2),
                                          ref["triggered"][row])
            if config.mode == "sync":
                np.testing.assert_array_equal(_trace(outs, sid, 1),
                                              ref["fhat"][row])
            else:  # async merges are late; safety still holds
                assert bool(np.all(_trace(outs, sid, 1)
                                   <= _trace(outs, sid, 0) + 1e-6))
        # the departed stream matched the reference while it was attached
        np.testing.assert_array_equal(_trace(outs, "b", 0),
                                      ref["u"][1][:detach_at])

        # detached slot stops accruing comms: steps detach_at..attach_at-1
        # charge nothing to slot 1
        assert seen_at_detach == detach_at
        assert seen_final == seen_at_detach + (S - attach_at), \
            "detached slot accrued charges while empty"

        # reused slot is bit-cold: the joiner matches a fresh fixed-batch
        # engine, and its server catch-up restarted from position 0
        np.testing.assert_array_equal(_trace(outs, "d", 0),
                                      ref_d["u"][1][:S - attach_at])
        np.testing.assert_array_equal(_trace(outs, "d", 2),
                                      ref_d["triggered"][1][:S - attach_at])
        assert 0 <= server_pos_d <= S - attach_at

    def test_recompile_exactly_once_per_signature(self):
        """The churn guard's strong form: with the threshold forced low
        (every step triggers the catch-up), a full churn episode leaves
        the catch-up with EXACTLY its two legitimate compiled signatures
        — scalar-t (uniform pool) and vector-t (ragged pool) — and every
        monitor-path jit with exactly one."""
        cfg, params, stream = _setup(threshold=-1e9, length=12)
        eng = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        with eng.session(streams=["a", "b", "c"]) as s:
            for t in range(4):                      # uniform: scalar-t
                s.step({sid: stream[i, t] for i, sid in enumerate("abc")})
            s.detach("b")
            for t in range(4, 6):                   # ragged: vector-t
                s.step({"a": stream[0, t], "c": stream[2, t]})
            assert s.attach("d") == 1
            guard = s.arm_recompile_guard(track_global=False)
            for t in range(6, 12):                  # churn under guard
                s.step({"a": stream[0, t], "c": stream[2, t],
                        "d": stream[1, t - 6]})
            guard.assert_stable()
        sizes = {n: int(f._cache_size())
                 for n, f in eng.jitted_paths().items()}
        assert sizes["catchup"] == 2, sizes         # scalar-t + vector-t
        assert sizes["edge.step_masked"] == 1, sizes
        assert sizes["u_head"] == 1, sizes
        assert sizes["record_at"] == 1, sizes

    def test_detached_slots_ship_nothing_even_when_loud(self):
        """A detached slot must not trigger or ship even with a monitor
        that would always page."""
        cfg, params, stream = _setup(threshold=-1e9, length=10)
        eng = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        s = eng.session(streams=["a", "b", "c"])
        for t in range(4):
            s.step({sid: stream[i, t]
                    for i, sid in enumerate(("a", "b", "c"))})
        s.detach("b")
        sent_before = eng.comms.tokens_sent.copy()
        for t in range(4, 10):
            s.step({"a": stream[0, t], "c": stream[2, t]})
        assert eng.comms.tokens_sent[1] == sent_before[1]
        assert eng.comms.tokens_sent[0] > sent_before[0]
        assert eng.server_pos[1] == 4, "detached slot's server state frozen"

    def test_pool_full_and_duplicate_ids(self):
        cfg, params, stream = _setup()
        eng = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        s = eng.session(streams=["a", "b", "c"])
        with pytest.raises(RuntimeError, match="full"):
            s.attach("x")
        s.detach("b")
        with pytest.raises(ValueError, match="already attached"):
            s.attach("a")
        with pytest.raises(KeyError):
            s.detach("nope")
        assert s.attach("x") == 1
        assert s.streams == ("a", "x", "c")


class TestWireChurn:
    """Acceptance: two CONCURRENT clients attach/detach against ONE
    correction-server subprocess; each client's surviving streams stay
    bit-identical to local fixed-batch runs and the server re-leases
    single rows without disturbing the co-resident client."""

    def test_two_clients_churn_against_one_server(self):
        S, detach_at, attach_at = 14, 5, 8
        cfg, params, _ = _setup(cfg_base=SERVING, length=S)
        stream_a = next(tok.lm_batches(1, cfg, 3, S))["tokens"]
        stream_b = next(tok.lm_batches(2, cfg, 3, S))["tokens"]
        fresh_a = next(tok.lm_batches(3, cfg, 1, S))["tokens"][0]
        fresh_b = next(tok.lm_batches(4, cfg, 1, S))["tokens"][0]

        # local fixed-batch references
        refs = {}
        for tag, stream, fresh in (("A", stream_a, fresh_a),
                                   ("B", stream_b, fresh_b)):
            refs[tag] = CollaborativeEngine(
                params, cfg, batch=3, max_len=32).session().run(stream)
            joined = np.stack([stream[0], fresh, stream[2]])
            refs[tag + "d"] = CollaborativeEngine(
                params, cfg, batch=3, max_len=32).session().run(joined)

        tmp = tempfile.mkdtemp(prefix="wire_churn_")
        uds = os.path.join(tmp, "s.sock")
        from conftest import SPAWN_DEADLINE_S
        from repro.launch.server import spawn_subprocess
        proc = spawn_subprocess("paper-synthetic-serving", uds=uds,
                                slots=8, max_len=32,
                                ready_file=os.path.join(tmp, "ready"),
                                timeout_s=SPAWN_DEADLINE_S)
        try:
            wcfg = SessionConfig(
                mode="async", max_staleness=2,
                transport=TransportSpec("wire", address=uds))
            ea = CollaborativeEngine(params, cfg, batch=3, max_len=32)
            eb = CollaborativeEngine(params, cfg, batch=3, max_len=32)
            sa = ea.session(wcfg, streams=["a", "b", "c"]).__enter__()
            sb = eb.session(wcfg, streams=["a", "b", "c"]).__enter__()
            outs = {"A": {sid: [] for sid in "abcd"},
                    "B": {sid: [] for sid in "abcd"}}
            # interleave the two clients' steps; both churn mid-flight
            # (B one step after A, so the server sees staggered
            # ATTACH/DETACH across coalesced request queues)
            for t in range(S):
                for tag, sess, stream, fresh, off in (
                        ("A", sa, stream_a, fresh_a, 0),
                        ("B", sb, stream_b, fresh_b, 1)):
                    if t == detach_at + off:
                        sess.detach("b")
                    if t == attach_at + off:
                        assert sess.attach("d") == 1
                    toks = {sid: stream[{"a": 0, "b": 1, "c": 2}[sid], t]
                            for sid in sess.streams if sid != "d"}
                    if "d" in sess.streams:
                        toks["d"] = fresh[t - (attach_at + off)]
                    r = sess.step(toks)
                    for i, sid in enumerate(r["streams"]):
                        outs[tag][sid].append(
                            (r["u"][i], r["fhat"][i], r["triggered"][i]))
            sa.close()
            sb.close()

            for tag, off in (("A", 0), ("B", 1)):
                o = outs[tag]
                # survivors bit-identical to the local fixed-batch run
                for sid, row in (("a", 0), ("c", 2)):
                    np.testing.assert_array_equal(
                        _trace(o, sid, 0), refs[tag]["u"][row])
                    np.testing.assert_array_equal(
                        _trace(o, sid, 2), refs[tag]["triggered"][row])
                    assert bool(np.all(_trace(o, sid, 1)
                                       <= _trace(o, sid, 0) + 1e-6))
                # the joiner is bit-cold on its re-leased server row
                n_d = S - (attach_at + off)
                np.testing.assert_array_equal(
                    _trace(o, "d", 0), refs[tag + "d"]["u"][1][:n_d])
                np.testing.assert_array_equal(
                    _trace(o, "d", 2),
                    refs[tag + "d"]["triggered"][1][:n_d])
            # both engines measured real wire traffic
            for eng in (ea, eb):
                w = eng.comms.report()["wire"]
                assert w["tx_bytes"] > 0 and w["replies"] > 0
        finally:
            proc.terminate()
            try:
                proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                proc.kill()


class TestPolicyChurn:
    def test_reattached_slot_gets_cold_controller(self):
        """Slot-pool churn under an adaptive policy: the controller is
        per-tenant state.  Detach a stream whose controller has warmed
        (tau above the floor, a full evidence window), attach a new
        tenant into the same slot — the slot's tau must be back at the
        calibrated floor with zero evidence, while co-resident streams
        keep their warmed thresholds."""
        from repro.serving import QuantilePolicy
        S = 16
        cfg, params, stream = _setup(threshold=-0.5, length=S)
        fresh = next(tok.lm_batches(7, cfg, 1, S))["tokens"][0]
        eng = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        pol = QuantilePolicy(0.3, window=6, min_samples=3)
        session = eng.session(SessionConfig(mode="sync", policy=pol),
                              streams=["a", "b", "c"])
        with session:
            for t in range(8):
                session.step({sid: stream[i, t]
                              for i, sid in enumerate("abc")})
            warmed = pol.state()
            # the controller actually warmed: every stream's window is
            # full and slot 1 left the floor (threshold -0.5 puts the
            # 0.7-quantile of u above it)
            assert (warmed["n_observed"] >= 8).all()
            assert warmed["tau"][1] > np.float32(warmed["tau0"])

            session.detach("b")
            session.step({"a": stream[0, 8], "c": stream[2, 8]})
            tau_a_before = pol.state()["tau"][0]  # a's tau keeps evolving
            assert session.attach("d") == 1  # same slot re-leased

            cold = pol.state()
            # cold controller for the new tenant: floor + no evidence...
            assert cold["tau"][1] == np.float32(cold["tau0"])
            assert cold["n_observed"][1] == 0
            # ...and the engine's effective threshold for the slot is
            # back at the calibrated floor too
            assert eng._thr_eff[1] == np.float32(cold["tau0"])
            # no leakage ONTO neighbors: stream a kept its warmed tau
            assert cold["tau"][0] == tau_a_before
            assert cold["n_observed"][0] >= 9

            # the new tenant re-warms from ITS OWN stream only
            for t2 in range(6):
                session.step({"a": stream[0, 9 + t2], "c": stream[2, 9 + t2],
                              "d": fresh[t2]})
            assert pol.state()["n_observed"][1] == 6

"""The static verifier itself: interval domain, jaxpr sign certificates,
HLO rule engine (op-level, metadata-immune), and the recompile guard.

tier-1 coverage of ``src/repro/analysis`` WITHOUT the full registry
sweep (that is ``tools/check_static.py --strict``, CI's static-analysis
job).  Includes the runtime complement to the static proof: a property
test that ``fhat <= u`` survives float32/bfloat16 rounding at +-1e4
logit tails — the regression class the sign domain abstracts away.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.analysis import hlo as ahlo
from repro.analysis import recompile as arc
from repro.analysis import signs
from repro.analysis.signs import Interval
from repro.configs import registry
from repro.core import decomposition as deco
from repro.kernels.ref import monitor_combine_ref
from repro.serving import mesh as mesh_mod

INF = math.inf


# ---------------------------------------------------------------------------
# Interval domain
# ---------------------------------------------------------------------------


class TestInterval:
    def test_arithmetic(self):
        a, b = Interval(-1.0, 2.0), Interval(3.0, 4.0)
        assert signs.iadd(a, b) == Interval(2.0, 6.0)
        assert signs.isub(b, a) == Interval(1.0, 5.0)
        assert signs.imul(a, b) == Interval(-4.0, 8.0)

    def test_mul_zero_times_inf_is_sound(self):
        # the 0 * inf := 0 convention: [0, inf] * [0, 1] stays [0, inf]
        assert signs.imul(Interval(0.0, INF), Interval(0.0, 1.0)) \
            == Interval(0.0, INF)
        assert signs.imul(Interval(0.0, INF), Interval(-1.0, 0.0)) \
            == Interval(-INF, 0.0)

    def test_nan_widens_to_top(self):
        assert Interval(float("nan"), 1.0) == signs.TOP

    def test_div_excluding_zero(self):
        assert signs.idiv(Interval(1.0, 2.0), Interval(2.0, 4.0)) \
            == Interval(0.25, 1.0)
        assert signs.idiv(Interval(1.0, 2.0), Interval(-1.0, 1.0)) \
            == signs.TOP


class TestInterpreter:
    def _out(self, fn, *avals, in_intervals=None):
        closed = jax.make_jaxpr(fn)(*avals)
        return signs.analyze_jaxpr(closed, in_intervals).out_nodes

    def test_sigmoid_bounded(self):
        (node,) = self._out(jax.nn.sigmoid,
                            jax.ShapeDtypeStruct((4,), jnp.float32))
        assert node.ival.lo >= 0.0 and node.ival.hi <= 1.0

    def test_scaled_sigmoid_nonneg(self):
        (node,) = self._out(lambda x: 0.2 * jax.nn.sigmoid(x),
                            jax.ShapeDtypeStruct((4,), jnp.float32))
        ok, _ = signs.prove_nonneg(node)
        assert ok

    def test_negative_scale_refuted_with_chain(self):
        (node,) = self._out(lambda x: -0.2 * jax.nn.sigmoid(x),
                            jax.ShapeDtypeStruct((4,), jnp.float32))
        ok, chain = signs.prove_nonneg(node)
        assert not ok
        assert any("mul" in c for c in chain)

    def test_where_upper_bound_through_pjit(self):
        # jnp.where lowers to a nested pjit; the structural prover must
        # see the outer u inside it
        def f(u, v, trig):
            return jnp.where(trig, u - 0.2 * jax.nn.sigmoid(v), u), u
        fhat, u = self._out(
            f, jax.ShapeDtypeStruct((4,), jnp.float32),
            jax.ShapeDtypeStruct((4,), jnp.float32),
            jax.ShapeDtypeStruct((4,), jnp.bool_))
        ok, _ = signs.prove_le(fhat, u)
        assert ok

    def test_add_positive_refutes_upper_bound(self):
        def f(u, v):
            return u + jax.nn.sigmoid(v), u
        fhat, u = self._out(f, jax.ShapeDtypeStruct((4,), jnp.float32),
                            jax.ShapeDtypeStruct((4,), jnp.float32))
        ok, _ = signs.prove_le(fhat, u)
        assert not ok

    def test_loop_carry_is_top_but_sound(self):
        def f(x):
            return jax.lax.fori_loop(
                0, 3, lambda i, c: jax.nn.sigmoid(c), x)
        (node,) = self._out(f, jax.ShapeDtypeStruct((), jnp.float32))
        # carry join includes the [0,1] body output and the TOP init
        assert node.ival == signs.TOP or node.ival.lo <= 0.0

    def test_input_refinement(self):
        (node,) = self._out(lambda x: x * 2.0,
                            jax.ShapeDtypeStruct((4,), jnp.float32),
                            in_intervals=[Interval(0.0, 1.0)])
        assert node.ival == Interval(0.0, 2.0)


# ---------------------------------------------------------------------------
# Sign certificates (single arch here; the sweep is check_static)
# ---------------------------------------------------------------------------


class TestCertificates:
    def test_forward_proves_both_sigmas(self):
        cfg = registry.get_smoke("granite-8b")
        for kind in signs.SIGMA_KINDS:
            cert = signs.verify_forward(cfg, arch="granite-8b", sigma=kind)
            assert cert.ok, cert.detail
            assert cert.corr_interval.nonneg

    def test_catchup_proves(self):
        cfg = registry.get_smoke("granite-8b")
        cert = signs.verify_catchup(cfg, arch="granite-8b")
        assert cert.ok, cert.detail

    def test_flipped_sign_refuted_with_counterexample(self):
        cfg = registry.get_smoke("granite-8b")
        cert = signs.verify_forward(cfg, arch="granite-8b", s=-0.2)
        assert not cert.ok
        assert "mul" in cert.detail  # the chain names the offending prim
        assert cert.corr_interval.lo < 0.0


# ---------------------------------------------------------------------------
# HLO rule engine
# ---------------------------------------------------------------------------


class TestHloRules:
    def test_parser_reads_opcodes_and_shapes(self):
        txt = ("ENTRY %main {\n"
               "  %p0 = f32[4,8]{1,0} parameter(0)\n"
               "  ROOT %s = (f32[4]{0}, s32[]) custom-call(%p0), "
               'custom_call_target="TopK"\n}\n')
        instrs = ahlo.parse_hlo(txt)
        assert [i.opcode for i in instrs] == ["parameter", "custom-call"]
        assert instrs[1].custom_call_target == "TopK"

    def test_benign_metadata_name_is_not_a_collective(self):
        """Regression (the old substring scan's false positive): an op
        whose METADATA carries a collective-sounding scope name must not
        trip the collective-free rule."""
        def f(x):
            with jax.named_scope("all_gather_like"):
                return x + 1.0
        txt = jax.jit(f).lower(
            jax.ShapeDtypeStruct((4,), jnp.float32)).compile().as_text()
        assert "all_gather_like" in txt  # the bait really is in the text
        assert ahlo.collective_instructions(txt) == []
        ahlo.assert_collective_free(txt, "benign metadata")  # no raise
        # and via the serving surface that migrated onto the engine
        assert mesh_mod.collective_ops(txt) == ()
        mesh_mod.assert_collective_free(txt, "benign metadata")

    def test_real_collective_still_raises(self):
        # layout-free shapes (the self-probe line test_mesh also uses)
        txt = "%ar = f32[8] all-reduce(f32[1] %x)"
        assert len(ahlo.collective_instructions(txt)) == 1
        with pytest.raises(AssertionError, match="collective"):
            ahlo.assert_collective_free(txt, "probe")
        with pytest.raises(AssertionError, match="collective"):
            mesh_mod.assert_collective_free(txt, "probe")

    def test_async_collective_halves_flagged(self):
        txt = ("%s = f32[8]{0} all-reduce-start(f32[8]{0} %x)\n"
               "%d = f32[8]{0} all-reduce-done(f32[8]{0} %s)\n")
        assert len(ahlo.collective_instructions(txt)) == 2

    def test_host_callback_flagged_topk_allowed(self):
        def f(x):
            return jax.pure_callback(
                lambda a: np.asarray(a) * 2.0,
                jax.ShapeDtypeStruct((4,), jnp.float32), x)
        txt = jax.jit(f).lower(
            jax.ShapeDtypeStruct((4,), jnp.float32)).compile().as_text()
        hits = ahlo.host_transfer_instructions(txt)
        assert hits and all(i.opcode == "custom-call" for i in hits)
        with pytest.raises(AssertionError, match="host"):
            ahlo.assert_no_host_transfer(txt, "callback probe")

        def g(x):
            return jax.lax.top_k(x, 2)
        txt2 = jax.jit(g).lower(
            jax.ShapeDtypeStruct((8,), jnp.float32)).compile().as_text()
        assert ahlo.host_transfer_instructions(txt2) == []

    def test_dynamic_shape_rule(self):
        txt = "%x = f32[<=8]{0} parameter(0)"
        assert len(ahlo.dynamic_shape_instructions(txt)) == 1
        assert ahlo.dynamic_shape_instructions("%x = f32[8]{0} parameter(0)") \
            == []

    def test_unsharded_monitor_path_passes_all_rules(self):
        from repro.analysis.rules import _engine_for
        eng = _engine_for(registry.get_smoke("granite-8b"))
        results = ahlo.check_monitor_path(eng)
        kernels = {k for k, _, _ in results}
        assert {"decode_masked", "u_head", "record_at",
                "catchup"} <= kernels
        for kernel, rule, hits in results:
            assert not hits, (kernel, rule,
                              [h.brief() for h in hits])


# ---------------------------------------------------------------------------
# Recompile guard
# ---------------------------------------------------------------------------


class TestRecompileGuard:
    def test_stable_and_violation(self):
        f = jax.jit(lambda x: x * 2.0)
        f(jnp.zeros((2,)))
        guard = arc.RecompileGuard({"f": f}, track_global=False).arm()
        f(jnp.ones((2,)))          # same signature: cache hit
        assert guard.violations() == []
        guard.assert_stable()
        f(jnp.zeros((3,)))         # new shape: retrace
        assert guard.violations()
        with pytest.raises(arc.RecompileError, match="f: 1 -> 2"):
            guard.assert_stable()

    def test_context_manager_raises_on_exit(self):
        f = jax.jit(lambda x: x + 1.0)
        f(jnp.zeros((2,)))
        with pytest.raises(arc.RecompileError):
            with arc.RecompileGuard({"f": f}, track_global=False):
                f(jnp.zeros((5,)))

    def test_unarmed_guard_refuses(self):
        g = arc.RecompileGuard({}, track_global=False)
        with pytest.raises(RuntimeError, match="not armed"):
            g.violations()

    def test_global_counter_sees_fresh_compiles(self):
        g = arc.RecompileGuard({}, track_global=True).arm()
        jax.jit(lambda x: x * 3.0 + 1.0)(jnp.zeros((7,)))  # fresh jit
        assert g.global_compiles() >= 1

    def test_engine_jitted_paths_enumeration(self):
        from repro.analysis.rules import _engine_for
        eng = _engine_for(registry.get_smoke("granite-8b"))
        paths = eng.jitted_paths()
        for expected in ("catchup", "u_head", "edge.step_masked",
                         "server.step_masked", "edge.prefill"):
            assert expected in paths, sorted(paths)


# ---------------------------------------------------------------------------
# Mutation self-test plumbing (cheap subset; full set is check_static)
# ---------------------------------------------------------------------------


class TestMutationSelftest:
    def test_all_rules_fire(self):
        from repro.analysis import rules
        for r in rules.mutation_selftest():
            assert r.ok, f"{r.rule} did not fire: {r.target} {r.detail}"

    def test_report_formatting(self):
        from repro.analysis.rules import RuleResult, format_report
        rep = format_report([RuleResult("r", "t", True),
                             RuleResult("r", "t2", False, "boom")])
        assert "FAIL" in rep and "boom" in rep and "1 failed" in rep


# ---------------------------------------------------------------------------
# Runtime complement: fhat <= u survives rounding at the tails
# ---------------------------------------------------------------------------


class TestSafetyAtTails:
    @settings(max_examples=60, deadline=None)
    @given(u=st.floats(min_value=-1e4, max_value=1e4),
           v=st.floats(min_value=-1e4, max_value=1e4),
           s=st.floats(min_value=0.0, max_value=4.0),
           dtype=st.sampled_from(["float32", "bfloat16"]),
           kind=st.sampled_from(["sigmoid", "tanh01"]))
    def test_fhat_le_u_under_rounding(self, u, v, s, dtype, kind):
        """The static proof works in exact reals; this pins down that
        float32/bfloat16 rounding cannot push fhat above u even at
        +-1e4 logits (saturated sigma, catastrophic cancellation
        territory)."""
        dt = jnp.dtype(dtype)
        uj = jnp.asarray(u, dt)
        vj = jnp.asarray(v, dt)
        corr = (jnp.asarray(s, dt) * deco.sigma(vj, kind)).astype(dt)
        fhat = (uj - corr).astype(dt)
        assert bool(fhat <= uj), (
            f"fhat={fhat} > u={uj} at v={v} s={s} {dtype}/{kind}")

    @settings(max_examples=40, deadline=None)
    @given(u=st.floats(min_value=-1e4, max_value=1e4),
           v=st.floats(min_value=-1e4, max_value=1e4))
    def test_monitor_combine_ref_respects_bound(self, u, v):
        """The fused serving combine (the op the catch-up actually
        calls) honours the same inequality at the tails."""
        uj = jnp.asarray([u], jnp.float32)
        vj = jnp.asarray([v], jnp.float32)
        fhat, _, _ = monitor_combine_ref(uj, vj, uj, s=0.2, threshold=0.1,
                                         margin=0.0)
        assert bool(fhat[0] <= uj[0])

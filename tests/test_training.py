"""Optimizer / schedule / checkpoint."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import checkpoint as ckpt
from repro.training.optimizer import SGD, AdamW
from repro.training.schedule import constant, inverse_sqrt, warmup_cosine

KEY = jax.random.PRNGKey(0)


class TestAdamW:
    def test_quadratic_convergence(self):
        params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array(5.0)}
        opt = AdamW(lr=0.1, clip_norm=0.0)
        st = opt.init(params)
        loss = lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2
        for _ in range(300):
            g = jax.grad(loss)(params)
            params, st, _ = opt.update(g, st, params)
        assert float(loss(params)) < 1e-4

    def test_first_step_matches_reference(self):
        """Adam step 1 = -lr * sign-ish update (bias-corrected)."""
        p = {"w": jnp.array([1.0])}
        g = {"w": jnp.array([0.5])}
        opt = AdamW(lr=0.1, clip_norm=0.0)
        st = opt.init(p)
        p2, _, _ = opt.update(g, st, p)
        # m_hat = g, v_hat = g^2 -> step = g/(|g|+eps) ~ 1
        assert float(p2["w"][0]) == pytest.approx(1.0 - 0.1, abs=1e-5)

    def test_clip_norm(self):
        p = {"w": jnp.array([0.0])}
        g = {"w": jnp.array([1000.0])}
        opt = AdamW(lr=0.1, clip_norm=1.0)
        _, _, gnorm = opt.update(g, opt.init(p), p)
        assert float(gnorm) == pytest.approx(1000.0, rel=1e-5)

    def test_weight_decay_pulls_to_zero(self):
        p = {"w": jnp.array([1.0])}
        opt = AdamW(lr=0.1, weight_decay=0.1, clip_norm=0.0)
        st = opt.init(p)
        for _ in range(500):  # decoupled decay: (1 - lr*wd)^500 ~ 0.0066
            p, st, _ = opt.update({"w": jnp.array([0.0])}, st, p)
        assert abs(float(p["w"][0])) < 0.05

    def test_sgd_momentum(self):
        p = {"w": jnp.array([4.0])}
        opt = SGD(lr=0.05, momentum=0.9)
        st = opt.init(p)
        for _ in range(200):
            g = {"w": 2 * p["w"]}
            p, st, _ = opt.update(g, st, p)
        assert abs(float(p["w"][0])) < 1e-3


class TestSchedules:
    def test_warmup_cosine(self):
        f = warmup_cosine(peak=1.0, warmup=100, total=1000, floor=0.1)
        assert float(f(jnp.asarray(0))) == 0.0
        assert float(f(jnp.asarray(100))) == pytest.approx(1.0, rel=1e-3)
        assert float(f(jnp.asarray(1000))) == pytest.approx(0.1, rel=1e-2)
        assert float(f(jnp.asarray(50))) == pytest.approx(0.5, rel=1e-2)

    def test_inverse_sqrt(self):
        f = inverse_sqrt(peak=1.0, warmup=100)
        assert float(f(jnp.asarray(100))) == pytest.approx(1.0, rel=1e-3)
        assert float(f(jnp.asarray(400))) == pytest.approx(0.5, rel=1e-3)

    def test_constant(self):
        assert float(constant(3e-4)(jnp.asarray(17))) == pytest.approx(3e-4)


class TestCheckpoint:
    def test_roundtrip_with_opt_state(self, tmp_path):
        from repro.configs import registry
        from repro.core import decomposition as deco
        cfg = registry.get_smoke("xlstm-350m")
        params = deco.init_collab_lm(KEY, cfg)
        opt = AdamW(lr=1e-3)
        st = opt.init(params)
        path = os.path.join(tmp_path, "ck")
        ckpt.save(path, 42, params, st, meta={"arch": cfg.name})
        step, p2, st2 = ckpt.load(path, params, st)
        assert step == 42
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(a, b)
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(st2)):
            np.testing.assert_array_equal(a, b)

    def test_shape_mismatch_rejected(self, tmp_path):
        params = {"w": jnp.zeros((3,))}
        path = os.path.join(tmp_path, "ck2")
        ckpt.save(path, 0, params)
        with pytest.raises(AssertionError):
            ckpt.load(path, {"w": jnp.zeros((4,))})

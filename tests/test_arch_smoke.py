"""Per-architecture smoke tests (brief requirement): a REDUCED variant of
each assigned family runs one forward AND one train step on CPU, asserting
output shapes and no NaNs; plus one decode step against a fresh cache."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.configs.base import ShapeConfig
from repro.core import decomposition as deco
from repro.core.losses import collab_lm_loss
from repro.data import tokens as tok
from repro.models import api as model_api
from repro.training.optimizer import AdamW

ARCHS = registry.names()
KEY = jax.random.PRNGKey(0)
SHAPE = ShapeConfig("smoke_train", seq_len=32, global_batch=2, kind="train")
DEC = ShapeConfig("smoke_dec", seq_len=32, global_batch=2, kind="decode")


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_shapes_no_nan(self, arch):
        cfg = registry.get_smoke(arch)
        params = model_api.init_model(KEY, cfg)
        batch = model_api.sample_batch(KEY, cfg, SHAPE)
        out = model_api.forward(params, cfg, batch)
        B, S = 2, 32
        if cfg.family == "audio":
            assert out["logits"].shape == (B, S, cfg.n_codebooks, cfg.vocab_size)
        else:
            assert out["logits"].shape == (B, S, cfg.vocab_size)
        assert out["hidden"].shape == (B, S, cfg.d_model)
        assert bool(jnp.all(jnp.isfinite(out["logits"])))

    def test_one_train_step(self, arch):
        cfg = registry.get_smoke(arch)
        params = deco.init_collab_lm(KEY, cfg)
        batch = {k: jnp.asarray(v) for k, v in
                 next(tok.lm_batches(0, cfg, 2, 32)).items()}

        def loss_fn(p):
            out = deco.collab_forward(p, cfg, batch)
            return collab_lm_loss(out, batch)["total"]

        loss, grads = jax.value_and_grad(loss_fn)(params)
        assert bool(jnp.isfinite(loss))
        gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
        assert gnorm > 0 and jnp.isfinite(gnorm)
        opt = AdamW(lr=1e-3)
        p2, _, _ = opt.update(grads, opt.init(params), params)
        l2 = loss_fn(p2)
        assert bool(jnp.isfinite(l2))

    def test_decode_step(self, arch):
        cfg = registry.get_smoke(arch)
        params = model_api.init_model(KEY, cfg)
        db = model_api.sample_batch(KEY, cfg, DEC)
        logits, hidden, cache = model_api.decode_step(
            params, cfg, db["cache"], db["tokens"], db["pos"])
        if cfg.family == "audio":
            assert logits.shape == (2, cfg.n_codebooks, cfg.vocab_size)
        else:
            assert logits.shape == (2, cfg.vocab_size)
        assert hidden.shape == (2, cfg.d_model)
        assert bool(jnp.all(jnp.isfinite(logits)))
        # cache structure round-trips
        assert jax.tree.structure(cache) == jax.tree.structure(db["cache"])


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyper-parameters."""
    spec = {
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "deepseek-v3-671b": (61, 7168, 128, 128, 18432, 129280),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "mixtral-8x22b": (56, 6144, 48, 8, 0, 32768),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
    }[arch]
    cfg = registry.get_full(arch)
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab_size) == spec
    assert cfg.citation
    # smoke variants respect the reduction contract
    sm = registry.get_smoke(arch)
    assert sm.d_model <= 512 and (sm.n_experts <= 4)
    assert sm.n_layers <= 5

"""Numeric validation of the paper's Propositions 1-4 and §3.4 rules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import decomposition as deco
from repro.core import safety, theory
from repro.data.synthetic import paper_synthetic, synthetic_residual


def _target(x, rho=0.9, n_modes=100):
    i = np.arange(1, n_modes + 1)
    return (np.cos(x[:, None] * i) @ (rho ** (i - 1))).astype(np.float32)


class TestProp2:
    """u_{n,t(n)} >= f and FN == 0 when t(n) = ||residual||_inf, s >= 2t."""

    @pytest.mark.parametrize("n", [5, 10, 20, 40])
    def test_safety_offset_guarantees_upper_bound(self, n):
        rho, n_modes = 0.9, 100
        xs = np.linspace(-3, 3, 4001).astype(np.float32)
        f = _target(xs, rho, n_modes)
        # truncated series + exact-on-sample t(n)
        i = np.arange(1, n + 1)
        u_trunc = (np.cos(xs[:, None] * i) @ (rho ** (i - 1))).astype(np.float32)
        resid = synthetic_residual(xs, n, rho=rho, n_modes=n_modes)
        t = float(np.max(np.abs(resid)))
        u = u_trunc + t
        assert np.all(u >= f - 1e-5), "Prop 2: u_{n,t(n)} must dominate f"
        assert float(safety.fn_rate(jnp.asarray(f), jnp.asarray(u))) == 0.0

    def test_practical_t_upper_bounds_exact_t(self):
        # paper's surrogate sum|a_i| >= sampled sup |residual|
        rho, n_modes = 0.9, 100
        xs = np.linspace(-3, 3, 2001).astype(np.float32)
        for n in (3, 10, 30):
            t_sur = theory.t_of_n(theory.exp_coeffs(rho, n_modes), n)
            t_exact = theory.t_of_n_sampled(
                lambda z: synthetic_residual(z, n, rho=rho, n_modes=n_modes), xs)
            assert t_sur >= t_exact - 1e-6

    def test_t_of_n_decreases(self):
        c = theory.exp_coeffs(0.9, 100)
        ts = [theory.t_of_n(c, n) for n in range(0, 90, 10)]
        assert all(a > b for a, b in zip(ts, ts[1:]))


class TestProp3:
    """mu_FP <= (delta + s) vol / (2 eps) — checked empirically."""

    @given(s=st.floats(0.05, 2.0), eps=st.floats(0.05, 0.5),
           seed=st.integers(0, 10_000))
    @settings(max_examples=50, deadline=None)
    def test_fp_bound_holds(self, s, eps, seed):
        rng = np.random.default_rng(seed)
        f = rng.uniform(-1, 1, size=4096).astype(np.float32)
        v = rng.normal(size=4096).astype(np.float32)
        delta = 0.05
        # construct fhat within delta of f, u = fhat + s*sigma(v)
        fhat = f + rng.uniform(-delta, delta, size=4096).astype(np.float32)
        u = fhat + s / (1 + np.exp(-v))
        mu_fp = float(safety.fp_rate(jnp.asarray(f), jnp.asarray(u), eps))
        bound = theory.prop3_fp_bound(delta, s, eps, vol=1.0)
        assert mu_fp <= bound + 1e-6

    def test_fp_grows_with_s_on_average(self):
        rng = np.random.default_rng(0)
        f = rng.uniform(-1, 1, size=8192).astype(np.float32)
        v = rng.normal(size=8192).astype(np.float32)
        rates = []
        for s in (0.1, 0.5, 1.0, 2.0):
            u = f + s / (1 + np.exp(-v))  # fhat == f exactly
            rates.append(float(safety.fp_rate(jnp.asarray(f), jnp.asarray(u), 0.05)))
        assert rates == sorted(rates), "FP rate must be monotone in s"


class TestProp4:
    @given(n=st.integers(5, 60), eps=st.floats(0.02, 0.3),
           tf=st.floats(0.1, 0.9))
    @settings(max_examples=40, deadline=None)
    def test_fn_chebyshev_bound(self, n, eps, tf):
        """Undersized t ⇒ FN mass bounded by ||residual||_2^2/(2eps+t)^2."""
        rho, n_modes = 0.9, 100
        xs = np.linspace(-3, 3, 4001).astype(np.float32)
        f = _target(xs, rho, n_modes)
        i = np.arange(1, n + 1)
        resid = synthetic_residual(xs, n, rho=rho, n_modes=n_modes)
        t = tf * float(np.max(np.abs(resid)))  # deliberately undersized
        u = (np.cos(xs[:, None] * i) @ (rho ** (i - 1))).astype(np.float32) + t
        # FN measure over Omega = [-3,3] (vol normalised to 1 by mean)
        mu_fn = float(safety.fn_rate(jnp.asarray(f), jnp.asarray(u), eps))
        resid_l2_sq = float(np.mean(resid ** 2))
        bound = theory.prop4_fn_bound(resid_l2_sq, eps, t)
        assert mu_fn <= bound + 1e-6


class TestSelectionRules:
    def test_exp_decay_matches_t_of_n(self):
        rho = 0.9
        for n in (5, 20, 50):
            # t(n) = sum_{i>n} rho^{i-1} = rho^n/(1-rho)
            assert theory.t_of_n(theory.exp_coeffs(rho, 10_000), n) == pytest.approx(
                theory.exp_decay_s(rho, n), rel=1e-6)

    def test_s_rule_is_twice_t(self):
        assert theory.s_rule(0.37) == pytest.approx(0.74)

    def test_power_law_residual_l2(self):
        # ||sum_{i>n} i^-a phi_i||_2^2 = sum i^{-2a} ~ n^{1-2a}/(2a-1) (orthonormal)
        alpha, n = 1.0, 50
        tail = sum((1 / i) ** (2 * alpha) for i in range(n + 1, 200_000))
        assert tail == pytest.approx(n ** (1 - 2 * alpha) / (2 * alpha - 1), rel=0.05)


class TestProp1:
    def test_decomposition_matches_complex_model_accuracy(self):
        """Trained f_hat = u - s sigma(v) reaches the accuracy of V alone
        (inequality (5)), on the paper's synthetic dataset."""
        from repro.configs.paper_synthetic import SMOKE as CFG
        from repro.training.loop import train_paper
        x, f = paper_synthetic(0, 2048, rho=CFG.rho, n_modes=24)
        key = jax.random.PRNGKey(0)
        # baseline: V alone (s tiny => fhat ~ u is ignored; train v head only)
        _, base = train_paper(key, CFG, x, f, u_mode="independent",
                              u_dims=(1, 24, 1), s=1e-6, steps=800, lr=3e-3)
        _, dec = train_paper(key, CFG, x, f, u_mode="cosine", n_modes=24,
                             steps=800, lr=3e-3)
        l2_base = float(jnp.mean((base["out"]["fhat"] - f) ** 2))
        l2_dec = float(jnp.mean((dec["out"]["fhat"] - f) ** 2))
        # decomposed model must be in the same accuracy class (Prop 1)
        assert l2_dec <= max(4 * l2_base, 0.05)

"""Expert-parallel (shard_map) MoE vs the dense-dispatch oracle.

Runs in a subprocess with 8 placeholder devices (mesh 2x4) so the session's
single-device tests are unaffected (same pattern as test_dryrun_subprocess).
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.nn.moe import init_moe, moe_apply, moe_apply_ep, ep_applicable

E, K, D, F = 8, 2, 64, 128
B, S = 4, 32
key = jax.random.PRNGKey(0)
p = init_moe(key, D, F, E, n_shared=1)
x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, D), jnp.float32)

y_ref, aux_ref = moe_apply(p, x, n_experts=E, top_k=K, compute_dtype=jnp.float32)

try:
    set_mesh = jax.sharding.set_mesh      # jax >= 0.5 public API
except AttributeError:
    set_mesh = lambda m: m                # legacy: Mesh is a context manager

mesh = jax.make_mesh((2, 4), ("data", "model"))
with set_mesh(mesh):
    assert ep_applicable(E), "ep must be applicable on 2x4 mesh with E=8"
    xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
    y_ep, aux_ep = jax.jit(lambda p, x: moe_apply_ep(
        p, x, n_experts=E, top_k=K, compute_dtype=jnp.float32))(p, xs)

# Same capacity semantics only when no tokens are dropped in either scheme;
# with cf=1.25 a few drops can differ (global vs per-shard ranking), so
# compare with a tolerance on the overwhelming majority of positions.
y_ref, y_ep = np.asarray(y_ref), np.asarray(y_ep)
close = np.isclose(y_ref, y_ep, rtol=2e-4, atol=2e-4)
frac = close.mean()
assert frac > 0.97, f"only {frac:.4f} of outputs match"
assert abs(float(aux_ref) - float(aux_ep)) < 5e-2, (aux_ref, aux_ep)

# gradient flows through the ep path
def loss(p, x):
    y, aux = moe_apply_ep(p, x, n_experts=E, top_k=K, compute_dtype=jnp.float32)
    return jnp.sum(y ** 2) + aux
with set_mesh(mesh):
    g = jax.jit(jax.grad(loss))(p, xs)
for leaf in jax.tree.leaves(g):
    assert np.isfinite(np.asarray(leaf)).all()
print("EP_OK", frac)

# --- TP-ff variant: E=6 not divisible by model=4 -> ff tensor-sharded ------
E2 = 6
p2 = init_moe(jax.random.fold_in(key, 7), D, F, E2)
y2_ref, aux2_ref = moe_apply(p2, x, n_experts=E2, top_k=K,
                             compute_dtype=jnp.float32)
with set_mesh(mesh):
    y2_ep, aux2_ep = jax.jit(lambda p, x: moe_apply_ep(
        p, x, n_experts=E2, top_k=K, compute_dtype=jnp.float32))(p2, xs)
y2_ref, y2_ep = np.asarray(y2_ref), np.asarray(y2_ep)
frac2 = np.isclose(y2_ref, y2_ep, rtol=2e-4, atol=2e-4).mean()
assert frac2 > 0.97, f"tp-ff: only {frac2:.4f} match"
print("TP_OK", frac2)
"""


@pytest.mark.slow
def test_moe_ep_matches_dense():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "EP_OK" in r.stdout

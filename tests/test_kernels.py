"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles in
kernels/ref.py, interpret=True on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref as R
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.monitor_combine import monitor_combine
from repro.kernels.ssm_scan import ssd_scan
from repro.nn.attention import chunked_attention

KEY = jax.random.PRNGKey(0)


def rand(shape, dtype, k):
    return jax.random.normal(jax.random.fold_in(KEY, k), shape, jnp.float32).astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,S,Hq,Hkv,D,bq,bk,window", [
        (1, 128, 4, 4, 64, 64, 64, 0),       # MHA
        (2, 256, 8, 2, 64, 128, 64, 0),      # GQA
        (1, 256, 4, 1, 128, 64, 128, 0),     # MQA, wide head
        (2, 256, 4, 2, 32, 64, 64, 96),      # sliding window
        (1, 512, 2, 2, 64, 128, 128, 128),   # SWA block-aligned
    ])
    def test_vs_oracle(self, dtype, B, S, Hq, Hkv, D, bq, bk, window):
        q = rand((B, S, Hq, D), dtype, 1)
        k = rand((B, S, Hkv, D), dtype, 2)
        v = rand((B, S, Hkv, D), dtype, 3)
        out = flash_attention(q, k, v, causal=True, window=window, bq=bq, bk=bk)
        ref = R.attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=TOL[dtype], rtol=TOL[dtype])

    def test_chunked_xla_path_matches_oracle(self):
        q, k, v = (rand((2, 256, 8, 64), jnp.float32, i) for i in (1, 2, 3))
        out = chunked_attention(q, k, v, q_block=64, causal=True, window=100)
        ref = R.attention_ref(q, k, v, causal=True, window=100)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


class TestDecodeAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,Hq,Hkv,D,C,bk,pos,window", [
        (2, 8, 2, 64, 512, 128, 100, 0),
        (1, 4, 4, 128, 256, 256, 255, 0),
        (2, 8, 1, 64, 512, 64, 700, 512),   # ring buffer fully wrapped
        (1, 16, 2, 64, 1024, 256, 0, 0),    # first token
    ])
    def test_vs_oracle(self, dtype, B, Hq, Hkv, D, C, bk, pos, window):
        q = rand((B, Hq, D), dtype, 1)
        kc = rand((B, C, Hkv, D), dtype, 2)
        vc = rand((B, C, Hkv, D), dtype, 3)
        out = decode_attention(q, kc, vc, pos, window=window, bk=bk)
        ref = R.decode_attention_ref(q, kc, vc, pos, window=window)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=TOL[dtype], rtol=TOL[dtype])


class TestSSDScan:
    @pytest.mark.parametrize("B,S,H,P,N,chunk", [
        (2, 256, 4, 32, 16, 64),
        (1, 128, 2, 64, 64, 128),   # single chunk
        (2, 512, 8, 16, 32, 32),    # many chunks
    ])
    def test_vs_sequential_oracle(self, B, S, H, P, N, chunk):
        x = 0.3 * rand((B, S, H, P), jnp.float32, 1)
        dt = jax.nn.softplus(rand((B, S, H), jnp.float32, 2))
        A = -jnp.exp(jnp.linspace(0.0, 1.0, H))
        Bm = 0.5 * rand((B, S, N), jnp.float32, 3)
        Cm = 0.5 * rand((B, S, N), jnp.float32, 4)
        xdt = x * dt[..., None]
        la = dt * A[None, None, :]
        out = ssd_scan(xdt, la, Bm, Cm, chunk=chunk)
        ref = R.ssd_ref(xdt, la, Bm, Cm)
        np.testing.assert_allclose(out, ref, atol=5e-5, rtol=5e-4)


class TestMonitorCombine:
    @given(n_blocks=st.integers(1, 4), s=st.floats(0.05, 2.0),
           thr=st.floats(-0.5, 0.5), seed=st.integers(0, 1000))
    @settings(max_examples=25, deadline=None)
    def test_vs_oracle(self, n_blocks, s, thr, seed):
        n = 256 * n_blocks
        k = jax.random.PRNGKey(seed)
        u, v, f = (jax.random.normal(jax.random.fold_in(k, i), (n,))
                   for i in range(3))
        fh, m, c = monitor_combine(u, v, f, s=s, threshold=thr, block=256)
        fr, mr, cr = R.monitor_combine_ref(u, v, f, s=s, threshold=thr)
        np.testing.assert_allclose(fh, fr, atol=1e-6)
        np.testing.assert_allclose(m, mr)
        np.testing.assert_allclose(c, cr)


class TestOpsDispatch:
    def test_xla_and_pallas_agree(self):
        from repro.kernels import ops
        q, k, v = (rand((1, 128, 4, 64), jnp.float32, i) for i in (1, 2, 3))
        ops.set_impl("xla")
        a = ops.flash_attention(q, k, v)
        ops.set_impl("pallas_interpret")
        b = ops.flash_attention(q, k, v)
        ops.set_impl("xla")
        np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("arch", ["granite-8b", "zamba2-7b",
                                      "mixtral-8x22b"])
    def test_model_forward_same_under_kernel_impl(self, arch):
        """Whole-model forward: Pallas kernel path == XLA path."""
        from repro.configs import registry
        from repro.configs.base import ShapeConfig
        from repro.kernels import ops
        from repro.models import api as model_api
        cfg = registry.get_smoke(arch)
        params = model_api.init_model(KEY, cfg)
        batch = model_api.sample_batch(KEY, cfg,
                                       ShapeConfig("t", 32, 2, "train"))
        try:
            ops.set_impl("xla")
            a = model_api.forward(params, cfg, batch)["logits"]
            ops.set_impl("pallas_interpret")
            b = model_api.forward(params, cfg, batch)["logits"]
        finally:
            ops.set_impl("xla")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-4)

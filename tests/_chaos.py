"""Fault-injection harness for the fleet tests: a ``ChaosProxy`` that
sits on the UDS path between a ``SocketWorker`` and a correction server
and, on command, injects the failures the failover machinery must
survive:

  * ``drop_mid_frame()``  — forward only HALF of the next server->client
    frame, then hard-close both directions (a crash mid-write: the
    client sees a torn frame then EOF);
  * ``delay_next_reply(s)`` — hold the server->client stream for ``s``
    seconds before forwarding the next REPLY (a stall; ordering is
    preserved — the whole stream waits, frames are never reordered);
  * ``dup_next_reply()``  — forward the next REPLY twice (a retransmit
    bug: the duplicate must be dropped by the worker's head-of-flights
    check, never surfaced to the Dispatcher);
  * ``cut_all()``         — sever every live link at once.

SIGKILLing a server subprocess needs no proxy — ``FleetSupervisor``
handles (``SubprocessServer.kill`` / ``ThreadServer.kill``) are the
kill primitive; the proxy covers the byte-level faults a kill cannot
express deterministically.

Determinism: the proxy injects NOTHING unless armed, and each command
fires exactly once on the next matching frame — a test arms a command
at a chosen step, so every schedule is reproducible.  ``seed`` only
seeds the mid-frame cut point jitter.

Wiring: pass ``proxy.wrap`` as ``FleetSupervisor(address_wrapper=...)``
— every REDIRECT then advertises a proxied address, so new connections
transparently route through the chaos path.
"""
from __future__ import annotations

import os
import random
import socket
import tempfile
import threading
from typing import Dict, List, Optional, Tuple

from repro.serving import wire

_REPLY = wire.MSG_REPLY


class _Link:
    """One proxied client connection: two pump threads, two sockets."""

    def __init__(self, proxy: "ChaosProxy", client: socket.socket,
                 upstream_addr: str):
        self.proxy = proxy
        self.client = client
        family, target = wire.parse_address(upstream_addr)
        self.upstream = socket.socket(family, socket.SOCK_STREAM)
        self.upstream.connect(target)
        self.dead = False
        t1 = threading.Thread(target=self._pump_c2s, daemon=True)
        t2 = threading.Thread(target=self._pump_s2c, daemon=True)
        t1.start()
        t2.start()

    def kill(self) -> None:
        self.dead = True
        for s in (self.client, self.upstream):
            try:
                s.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                s.close()
            except OSError:
                pass

    def _pump_c2s(self) -> None:
        # client->server: raw passthrough (faults are injected on the
        # reply path where the protocol state machine lives)
        try:
            while not self.dead:
                data = self.client.recv(1 << 16)
                if not data:
                    break
                self.upstream.sendall(data)
        except OSError:
            pass
        self.kill()

    def _pump_s2c(self) -> None:
        # server->client: re-framed so commands act on whole frames;
        # wire.frame() re-emits byte-identical framing
        reader = wire.FrameReader()
        try:
            while not self.dead:
                data = self.upstream.recv(1 << 16)
                if not data:
                    break
                for payload in reader.feed(data):
                    if not self.proxy._forward(self, payload):
                        return
        except (OSError, wire.WireError):
            pass
        self.kill()


def torn_ring_write(writer, payload: bytes,
                    rng: Optional[random.Random] = None) -> int:
    """The shm-plane mirror of ``drop_mid_frame``: publish only a PREFIX
    of the framed ``payload`` into ``writer`` (a ``wire.RingWriter``), as
    a producer that died mid-stream would — at least the length prefix,
    never the whole frame.  Returns the number of bytes published.

    The consumer's ``FrameReader`` must hold the torn frame forever
    without yielding or corrupting (rings carry stream semantics: a torn
    write is indistinguishable from a stream cut); peer death is then
    detected out-of-band on the control socket, exactly like the socket
    torn-frame case."""
    buf = wire.frame(payload)
    rng = rng or random.Random(0)
    n = max(1, min(len(buf) - 1, rng.randint(1, len(buf) - 1)))
    done = 0
    while done < n:
        w = writer.write(buf[done:n])
        assert w > 0, "ring full while tearing a write (size the test ring)"
        done += w
    return n


class ChaosProxy:
    """Frame-aware fault-injecting proxy; see module docstring."""

    def __init__(self, seed: int = 0, root: Optional[str] = None):
        self.rng = random.Random(seed)
        self.root = root or tempfile.mkdtemp(prefix="chaos-")
        self._lock = threading.Lock()
        self._cmd: Dict[str, object] = {}   # armed one-shot commands
        self._links: List[_Link] = []
        self._listeners: List[socket.socket] = []
        self._wrapped: Dict[str, str] = {}  # upstream -> proxy address
        self._closed = False
        self.stats = {"frames": 0, "dropped_mid_frame": 0, "duplicated": 0,
                      "delayed": 0}

    # -- wiring --------------------------------------------------------------
    def wrap(self, upstream: str) -> str:
        """Return a proxy address piping to ``upstream`` (creating the
        listener on first use) — the ``FleetSupervisor`` address_wrapper
        hook."""
        with self._lock:
            if upstream in self._wrapped:
                return self._wrapped[upstream]
            path = os.path.join(self.root, f"p{len(self._wrapped)}.sock")
            lst = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            lst.bind(path)
            lst.listen(16)
            self._listeners.append(lst)
            self._wrapped[upstream] = path
        threading.Thread(target=self._accept_loop,
                         args=(lst, upstream), daemon=True).start()
        return path

    def _accept_loop(self, lst: socket.socket, upstream: str) -> None:
        while not self._closed:
            try:
                conn, _ = lst.accept()
            except OSError:
                return
            try:
                link = _Link(self, conn, upstream)
            except OSError:
                conn.close()   # upstream is gone: refuse like a dead server
                continue
            with self._lock:
                self._links.append(link)

    # -- commands (one-shot, armed by the test at a chosen step) -------------
    def drop_mid_frame(self) -> None:
        with self._lock:
            self._cmd["drop_mid_frame"] = True

    def delay_next_reply(self, seconds: float) -> None:
        with self._lock:
            self._cmd["delay"] = float(seconds)

    def dup_next_reply(self) -> None:
        with self._lock:
            self._cmd["dup"] = True

    def cut_all(self) -> None:
        with self._lock:
            links, self._links = self._links, []
        for ln in links:
            ln.kill()

    # -- the injection point -------------------------------------------------
    def _take(self, key: str) -> Optional[object]:
        with self._lock:
            return self._cmd.pop(key, None)

    def _forward(self, link: _Link, payload: bytes) -> bool:
        """Forward one server->client frame, applying at most one armed
        command.  Returns False when the link was severed."""
        self.stats["frames"] += 1
        buf = wire.frame(payload)
        is_reply = len(payload) >= 4 and payload[3] == _REPLY
        if self._take("drop_mid_frame") is not None:
            # a torn frame then EOF — at least the length prefix, never
            # the whole frame
            n = max(1, min(len(buf) - 1,
                           self.rng.randint(1, max(1, len(buf) - 1))))
            self.stats["dropped_mid_frame"] += 1
            try:
                link.client.sendall(buf[:n])
            except OSError:
                pass
            link.kill()
            return False
        if is_reply:
            d = self._take("delay")
            if d is not None:
                self.stats["delayed"] += 1
                threading.Event().wait(float(d))  # holds the whole stream
            if self._take("dup") is not None:
                self.stats["duplicated"] += 1
                try:
                    link.client.sendall(buf)
                except OSError:
                    link.kill()
                    return False
        try:
            link.client.sendall(buf)
        except OSError:
            link.kill()
            return False
        return True

    def close(self) -> None:
        self._closed = True
        self.cut_all()
        for lst in self._listeners:
            try:
                lst.close()
            except OSError:
                pass
        for path in self._wrapped.values():
            try:
                os.unlink(path)
            except OSError:
                pass

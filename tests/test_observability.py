"""Observability subsystem: span tracer ring/export/schema, the unified
metrics registry, histogram percentile edge cases, the protocol-v4 REPLY
timing payload (v3 compatibility both ways), and the load-bearing
contract that tracing is FREE when off and INVISIBLE when on — traced
sessions produce bitwise-identical protocol outputs on every execution
path (sync / scan / async / wire)."""
import json
import os
import struct
import tempfile
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_synthetic import SERVING
from repro.core import decomposition as deco
from repro.data import tokens as tok
from repro.observability import (MetricsRegistry, Tracer, breakdown,
                                 breakdown_table, flatten, load_trace,
                                 validate_chrome_trace)
from repro.serving import SessionConfig, TransportSpec, wire
from repro.serving.collaborative import CollaborativeEngine
from repro.serving.tracker import Histogram, InMemoryTracker

KEY = jax.random.PRNGKey(0)


def _cfg(threshold=0.1):
    return SERVING.replace(monitor=SERVING.monitor.__class__(
        **{**SERVING.monitor.__dict__, "threshold": threshold,
           "trigger_margin": 0.0}))


def _uds_path(tag):
    return os.path.join(tempfile.mkdtemp(prefix=f"obs_{tag}_"), "s.sock")


# -- tracer ------------------------------------------------------------------

class TestTracer:
    def test_spans_record_and_clamp(self):
        tr = Tracer()
        t0 = tr.clock()
        tr.done("edge.decode", "edge", t0, track="edge", step=3)
        tr.add("server.queue", "server", 10.0, -0.5, track="server")
        spans = tr.spans()
        assert [s.name for s in spans] == ["edge.decode", "server.queue"]
        assert spans[0].dur >= 0 and spans[0].args["step"] == 3
        assert spans[1].dur == 0.0, "negative durations clamp to zero"

    def test_ring_bound_and_dropped(self):
        tr = Tracer(capacity=4)
        for i in range(10):
            tr.add(f"s{i}", "edge", float(i), 0.1, track="edge")
        assert len(tr) == 4
        assert tr.dropped == 6
        assert [s.name for s in tr.spans()] == ["s6", "s7", "s8", "s9"]
        st = tr.stats()
        assert st["spans"] == 4 and st["dropped"] == 6

    def test_export_validate_round_trip(self, tmp_path):
        tr = Tracer()
        tr.add("wire.request", "wire", 1.0, 0.25, track="wire", req_id=7)
        tr.add("edge.decode", "edge", 1.0, 0.01, track="edge")
        path = str(tmp_path / "trace.json")
        assert tr.export(path) == 2
        obj = load_trace(path)  # validates on load
        xs = [e for e in obj["traceEvents"] if e["ph"] == "X"]
        assert len(xs) == 2
        req = next(e for e in xs if e["name"] == "wire.request")
        assert req["dur"] == pytest.approx(0.25e6)  # microseconds
        assert req["args"]["req_id"] == 7
        # thread-name metadata makes Perfetto label the tracks
        metas = [e for e in obj["traceEvents"] if e["ph"] == "M"]
        names = {e["args"]["name"] for e in metas}
        assert {"edge", "wire", "server"} <= names

    def test_validate_rejects_malformed(self, tmp_path):
        with pytest.raises(ValueError):
            validate_chrome_trace({"no": "events"})
        with pytest.raises(ValueError):
            validate_chrome_trace({"traceEvents": []})  # no X events
        with pytest.raises(ValueError):
            validate_chrome_trace({"traceEvents": [
                {"ph": "X", "pid": 1, "tid": 0, "name": "a",
                 "ts": -1.0, "dur": 0.0}]})
        p = str(tmp_path / "garbage.json")
        with open(p, "w") as fh:
            json.dump({"traceEvents": [{"ph": "X"}]}, fh)
        with pytest.raises(ValueError):
            load_trace(p)

    def test_breakdown_over_spans_and_events(self, tmp_path):
        tr = Tracer()
        tr.add("wire.request", "wire", 0.0, 0.010, track="wire")
        tr.add("wire.encode", "wire", 0.0, 0.001, track="wire")
        tr.add("server.queue", "server", 0.0, 0.002, track="server")
        tr.add("server.catchup", "server", 0.0, 0.004, track="server")
        tr.add("wire.socket", "wire", 0.0, 0.003, track="wire")
        stats = breakdown(tr.spans())
        assert stats["rtt"]["p50_s"] == pytest.approx(0.010)
        assert stats["serialize"]["n"] == 1
        assert stats["compute"]["mean_s"] == pytest.approx(0.004)
        # identical numbers when computed from the exported JSON events
        path = str(tmp_path / "t.json")
        tr.export(path)
        stats2 = breakdown(load_trace(path)["traceEvents"])
        assert stats2["rtt"]["p50_s"] == pytest.approx(0.010)
        lines = breakdown_table(tr.spans())
        assert lines[1].split()[0] == "rtt", "RTT leads the table"


# -- metrics registry --------------------------------------------------------

class TestMetricsRegistry:
    def test_get_or_create_and_snapshot(self):
        reg = MetricsRegistry()
        assert reg.counter("requests") is reg.counter("requests")
        reg.inc("requests", 3)
        reg.gauge("load", fn=lambda: 0.5)
        reg.observe("lat_s", 0.2, lo=1e-4, hi=10.0)
        snap = reg.snapshot()
        assert snap["requests"] == 3
        assert snap["load"] == 0.5
        assert snap["lat_s_n"] == 1
        # single observation: percentiles are exactly the observation
        assert snap["lat_s_p50"] == snap["lat_s_p99"] == 0.2
        empty = MetricsRegistry()
        empty.histogram("h")
        s = empty.snapshot()
        assert s["h_n"] == 0 and s["h_p50"] is None and s["h_p99"] is None

    def test_flatten_nested(self):
        nested = {"a": 1, "wire": {"rtt_mean_s": 0.5, "deep": {"x": 2}},
                  "per_stream": [1, 2]}
        flat = flatten(nested, "comms")
        assert flat == {"comms/a": 1, "comms/wire/rtt_mean_s": 0.5,
                        "comms/wire/deep/x": 2, "comms/per_stream": [1, 2]}


# -- histogram percentile edge cases (satellite) -----------------------------

class TestHistogramEdgeCases:
    def test_empty_percentiles_are_none(self):
        s = Histogram(1e-4, 10.0).summary()
        assert s == {"n": 0, "mean": 0.0, "max": 0.0, "p50": None,
                     "p99": None}

    def test_single_observation_is_its_own_percentile(self):
        h = Histogram(1e-4, 10.0)
        h.observe(0.037)  # far from any bucket midpoint
        s = h.summary()
        assert s["p50"] == s["p99"] == 0.037
        assert s["n"] == 1 and s["max"] == 0.037

    def test_quantiles_clamped_to_observed_range(self):
        h = Histogram(1e-4, 10.0)
        for x in (0.02, 0.021, 0.022):
            h.observe(x)
        s = h.summary()
        assert 0.02 <= s["p50"] <= 0.022
        assert 0.02 <= s["p99"] <= 0.022


class TestInMemoryTrackerBound:
    def test_ring_evicts_oldest(self):
        t = InMemoryTracker(max_records=4)
        for i in range(10):
            t.log({"i": i})
        recs = t.records
        assert len(recs) == 4
        assert [r["i"] for r in recs] == [6, 7, 8, 9]
        assert t.latest == {"i": 9}

    def test_unbounded_keeps_everything(self):
        t = InMemoryTracker(max_records=None)
        for i in range(10):
            t.log({"i": i})
        assert len(t.records) == 10


# -- protocol v4 timing payload ----------------------------------------------

def _reply(queue_s):
    return wire.WireReply(
        req_id=9, t=5, triggered=np.array([True, False, True]),
        v=np.array([0.1, 0.0, 0.2], np.float32),
        fhat=np.array([0.5, 0.6, 0.7], np.float32),
        server_time_s=0.004, coalesced=2, queue_s=queue_s)


def _payload(buf):
    payloads = wire.FrameReader().feed(buf)
    assert len(payloads) == 1
    return payloads[0]


class TestWireV4Timing:
    def test_queue_s_round_trips(self):
        msg = wire.decode(_payload(wire.encode_reply(_reply(0.0025))))
        assert msg.queue_s == pytest.approx(0.0025)
        np.testing.assert_array_equal(msg.triggered, [True, False, True])
        assert msg.server_time_s == pytest.approx(0.004)

    def test_absent_payload_decodes_as_minus_one(self):
        short = _payload(wire.encode_reply(_reply(-1.0)))
        full = _payload(wire.encode_reply(_reply(0.0)))
        assert len(short) == len(full) - 8, "payload is exactly one <d"
        assert wire.decode(short).queue_s == -1.0

    def test_v3_frame_decodes_without_timing(self):
        # a v3 peer's REPLY: same body, no timing payload, version byte 3
        payload = bytearray(_payload(wire.encode_reply(_reply(-1.0))))
        assert payload[2] == wire.VERSION
        payload[2] = 3
        msg = wire.decode(bytes(payload))
        assert msg.queue_s == -1.0
        np.testing.assert_array_equal(msg.fhat, _reply(-1.0).fhat)

    def test_versions_outside_window_rejected(self):
        payload = bytearray(_payload(wire.encode_reply(_reply(0.5))))
        for bad in (wire.MIN_VERSION - 1, wire.VERSION + 1):
            payload[2] = bad
            with pytest.raises(wire.WireError, match="version"):
                wire.decode(bytes(payload))


# -- tracing is invisible: bitwise identity on every path --------------------

@pytest.fixture(scope="module")
def proto():
    cfg = _cfg()
    params = deco.init_collab_lm(KEY, cfg)
    stream = next(tok.lm_batches(0, cfg, 3, 14))["tokens"]
    return cfg, params, stream


def _run(cfg, params, stream, session_cfg):
    eng = CollaborativeEngine(params, cfg, batch=3, max_len=32)
    sess = eng.session(session_cfg)
    r = sess.run(stream)
    return r, sess


def _assert_bitwise(r_plain, r_traced):
    np.testing.assert_array_equal(r_plain["u"], r_traced["u"])
    np.testing.assert_array_equal(r_plain["triggered"], r_traced["triggered"])
    np.testing.assert_array_equal(r_plain["fhat"], r_traced["fhat"])


class TestTracedIdentity:
    def test_sync_bitwise(self, proto, tmp_path):
        cfg, params, stream = proto
        r0, _ = _run(cfg, params, stream, SessionConfig())
        r1, sess = _run(cfg, params, stream, SessionConfig(trace=True))
        _assert_bitwise(r0, r1)
        assert 0.0 < r1["triggered"].mean() < 1.0, "need mixed triggers"
        spans = sess.tracer.spans()
        names = {s.name for s in spans}
        assert {"edge.decode", "edge.trigger"} <= names
        assert "edge.catchup" in names, "triggered steps catch up in sync"
        path = str(tmp_path / "sync.json")
        assert sess.export_trace(path) == len(spans)
        load_trace(path)

    def test_scan_bitwise(self, proto):
        cfg, params, stream = proto
        r0, _ = _run(cfg, params, stream, SessionConfig(mode="scan"))
        r1, sess = _run(cfg, params, stream,
                        SessionConfig(mode="scan", trace=True))
        _assert_bitwise(r0, r1)
        assert {s.name for s in sess.tracer.spans()} == {"scan.run"}

    def test_async_bitwise(self, proto):
        cfg, params, stream = proto
        sc = SessionConfig(mode="async", max_staleness=2,
                           transport=TransportSpec("stream"))
        r0, _ = _run(cfg, params, stream, sc)
        r1, sess = _run(cfg, params, stream,
                        SessionConfig(mode="async", max_staleness=2,
                                      transport=TransportSpec("stream"),
                                      trace=True))
        _assert_bitwise(r0, r1)
        names = {s.name for s in sess.tracer.spans()}
        assert "edge.dispatch" in names and "edge.merge" in names

    def test_metrics_snapshot_shape(self, proto):
        cfg, params, stream = proto
        _, sess = _run(cfg, params, stream, SessionConfig(trace=True))
        snap = sess.metrics()
        assert snap["comms/trigger_rate"] > 0
        assert snap["trace/spans"] == len(sess.tracer.spans())
        # untraced sessions still get the registry + comms panes
        _, plain = _run(cfg, params, stream, SessionConfig())
        snap2 = plain.metrics()
        assert "comms/trigger_rate" in snap2
        assert not any(k.startswith("trace/") for k in snap2)

    def test_trace_ring_bound_respected_in_session(self, proto):
        cfg, params, stream = proto
        r1, sess = _run(cfg, params, stream,
                        SessionConfig(trace=True, trace_capacity=8))
        assert len(sess.tracer) == 8
        assert sess.tracer.dropped > 0
        r0, _ = _run(cfg, params, stream, SessionConfig())
        _assert_bitwise(r0, r1)  # dropping spans can't change the protocol


@pytest.fixture(scope="module")
def obs_wire_server(proto):
    """One in-thread CorrectionServer with its OWN tracer, shared by the
    wire identity tests."""
    from repro.serving.server import CorrectionServer
    cfg, params, _ = proto
    uds = _uds_path("srv")
    srv = CorrectionServer(cfg, params, slots=8, max_len=32, uds=uds,
                           tracer=Tracer())
    stop = threading.Event()
    th = threading.Thread(target=srv.serve_forever,
                          kwargs=dict(stop=stop), daemon=True)
    th.start()
    yield uds, srv
    stop.set()
    th.join(timeout=10)
    srv.close()


class TestTracedWire:
    def test_strict_sync_over_wire_bitwise(self, proto, obs_wire_server):
        """max_staleness=0 over the real socket: the fully deterministic
        boundary, so traced == untraced is bitwise INCLUDING fhat."""
        cfg, params, stream = proto
        uds, _ = obs_wire_server
        sc = dict(mode="sync", transport=TransportSpec("wire", address=uds))
        r0, _ = _run(cfg, params, stream, SessionConfig(**sc))
        r1, sess = _run(cfg, params, stream,
                        SessionConfig(**sc, trace=True))
        _assert_bitwise(r0, r1)
        names = {s.name for s in sess.tracer.spans()}
        assert {"wire.encode", "wire.request", "wire.socket",
                "server.queue", "server.catchup"} <= names

    def test_pipelined_over_wire_monitor_path_bitwise(self, proto,
                                                      obs_wire_server):
        """Pipelined over a real socket: merge timing is inherently
        nondeterministic (a reply lands at t+1 or t+2 run to run), so
        the contract is the monitor path — u and the trigger trace —
        bitwise, with corrections only ever lowering fhat."""
        cfg, params, stream = proto
        uds, srv = obs_wire_server
        sc = dict(mode="async", max_staleness=3,
                  transport=TransportSpec("wire", address=uds))
        r0, _ = _run(cfg, params, stream, SessionConfig(**sc))
        r1, sess = _run(cfg, params, stream,
                        SessionConfig(**sc, trace=True))
        np.testing.assert_array_equal(r0["u"], r1["u"])
        np.testing.assert_array_equal(r0["triggered"], r1["triggered"])
        assert np.all(r1["fhat"] <= r1["u"] + 1e-6)
        # the measured RTT breakdown reached the session registry
        snap = sess.metrics()
        assert snap["rtt_s_n"] > 0
        assert snap["rtt_queue_s_n"] > 0, "v4 timing payload present"
        assert snap["rtt_compute_s_p50"] is not None
        # and the server recorded its own half on its own tracer
        srv_names = {s.name for s in srv.tracer.spans()}
        assert {"server.queue", "server.replay"} <= srv_names
        assert srv.stats_snapshot()["queue_wait_s_n"] > 0


# -- the disabled path is actually disabled ----------------------------------

class TestDisabledPath:
    def test_untraced_session_never_touches_tracer(self, proto, monkeypatch):
        """No Tracer may be constructed or used when trace=False — the
        overhead guard behind the 'free when off' acceptance bullet."""
        def boom(*a, **k):
            raise AssertionError("tracer touched on the disabled path")
        monkeypatch.setattr(Tracer, "__init__", boom)
        monkeypatch.setattr(Tracer, "done", boom)
        monkeypatch.setattr(Tracer, "add", boom)
        cfg, params, stream = proto
        r, sess = _run(cfg, params, stream, SessionConfig())
        assert sess.tracer is None
        assert r["triggered"].any()

    def test_export_trace_refuses_when_off(self, proto):
        cfg, params, stream = proto
        _, sess = _run(cfg, params, stream, SessionConfig())
        with pytest.raises(RuntimeError, match="trace=True"):
            sess.export_trace("/tmp/never.json")

    def test_reused_engine_drops_stale_tracer(self, proto):
        """A traced session followed by an untraced one on the SAME
        engine must not inherit the old tracer."""
        cfg, params, stream = proto
        eng = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        s1 = eng.session(SessionConfig(trace=True))
        s1.run(stream)
        assert eng._tracer is not None
        s2 = eng.session(SessionConfig())
        s2._ensure_open()
        assert eng._tracer is None

"""Wire transport subsystem: codec round trips, the standalone correction
server (loopback bit-identity, multi-client isolation, request
coalescing), the transport registry's failure modes, and idempotent
teardown of workers/dispatchers."""
import os
import socket
import subprocess
import sys
import tempfile
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import SPAWN_DEADLINE_S
from repro.configs.paper_synthetic import SERVING
from repro.core import decomposition as deco
from repro.data import tokens as tok
from repro.serving import SessionConfig, TransportSpec, async_rpc, wire
from repro.serving.collaborative import CollaborativeEngine

KEY = jax.random.PRNGKey(0)


def run_scan(eng, stream):
    return eng.session(SessionConfig(mode="scan")).run(stream)


def run_sync(eng, stream):
    return eng.session().run(stream)


def run_wire(eng, stream, *, address, max_staleness):
    cfg = SessionConfig(mode="async", max_staleness=max_staleness,
                        transport=TransportSpec("wire", address=address))
    with eng.session(cfg) as s:
        return s.run(stream)


def _cfg(threshold=0.1):
    return SERVING.replace(monitor=SERVING.monitor.__class__(
        **{**SERVING.monitor.__dict__, "threshold": threshold,
           "trigger_margin": 0.0}))


def _uds_path(tag):
    # mktemp-style: bind() creates the file, so the path must not exist
    return os.path.join(tempfile.mkdtemp(prefix=f"wire_{tag}_"), "s.sock")


# -- codec -------------------------------------------------------------------

class TestCodec:
    @settings(max_examples=20, deadline=None)
    @given(batch=st.integers(min_value=1, max_value=9),
           max_len=st.integers(min_value=2, max_value=33),
           t_frac=st.floats(min_value=0.0, max_value=1.0),
           k=st.sampled_from([0, 2]),
           seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_request_round_trip(self, batch, max_len, t_frac, k, seed):
        """Arbitrary batch/length/codebook-tail geometry survives the
        frame: vectors bitwise, and the token payload is EXACTLY the
        concatenated triggered backlogs (never the full history)."""
        rng = np.random.default_rng(seed)
        t = int(round(t_frac * (max_len - 1)))
        triggered = rng.random(batch) < 0.5
        server_pos = rng.integers(0, t + 1, batch).astype(np.int32)
        u = rng.standard_normal(batch).astype(np.float32)
        tail = (k,) if k else ()
        history = rng.integers(0, 255, (batch, max_len) + tail,
                               dtype=np.int64).astype(np.int32)
        buf = wire.encode_request(7, t, triggered, server_pos, u, history)
        payloads = wire.FrameReader().feed(buf)
        assert len(payloads) == 1
        msg = wire.decode(payloads[0])
        assert isinstance(msg, wire.WireRequest)
        assert msg.req_id == 7 and msg.t == t
        np.testing.assert_array_equal(msg.triggered, triggered)
        np.testing.assert_array_equal(msg.server_pos, server_pos)
        np.testing.assert_array_equal(msg.u, u)
        rows = np.flatnonzero(triggered)
        if len(rows):
            want = np.concatenate(
                [history[i, server_pos[i]:t + 1] for i in rows], axis=0)
        else:
            want = np.zeros((0,) + tail, np.int32)
        np.testing.assert_array_equal(msg.tokens, want)
        np.testing.assert_array_equal(
            msg.backlog_lengths(),
            np.where(triggered, t + 1 - server_pos, 0))
        # backlog-proportional frames: payload ≈ tokens + per-stream
        # vectors, nowhere near the full (batch, max_len) history
        assert len(buf) < want.size * 4 + batch * 16 + 128

    @settings(max_examples=10, deadline=None)
    @given(batch=st.integers(min_value=1, max_value=17),
           seed=st.integers(min_value=0, max_value=2**31 - 1),
           coalesced=st.integers(min_value=1, max_value=64))
    def test_reply_round_trip(self, batch, seed, coalesced):
        rng = np.random.default_rng(seed)
        r = wire.WireReply(
            req_id=rng.integers(0, 2**63), t=int(rng.integers(0, 1000)),
            triggered=rng.random(batch) < 0.5,
            v=rng.standard_normal(batch).astype(np.float32),
            fhat=rng.standard_normal(batch).astype(np.float32),
            server_time_s=float(rng.random()), coalesced=coalesced)
        buf = wire.encode_reply(r)
        (payload,) = wire.FrameReader().feed(buf)
        got = wire.decode(payload)
        assert isinstance(got, wire.WireReply)
        assert got.req_id == r.req_id and got.t == r.t
        assert got.coalesced == coalesced
        assert got.server_time_s == pytest.approx(r.server_time_s)
        np.testing.assert_array_equal(got.triggered, r.triggered)
        np.testing.assert_array_equal(got.v, r.v)
        np.testing.assert_array_equal(got.fhat, r.fhat)

    def test_control_messages_round_trip(self):
        h = wire.Hello(batch=4, max_len=32, tok_tail=(8,), coalesce=False,
                       client="edge-7")
        (p,) = wire.FrameReader().feed(wire.encode_hello(h))
        assert wire.decode(p) == h
        a = wire.HelloAck(session_id=3, slot_lo=12, server_max_len=128)
        (p,) = wire.FrameReader().feed(wire.encode_hello_ack(a))
        assert wire.decode(p) == a
        (p,) = wire.FrameReader().feed(wire.encode_bye())
        assert isinstance(wire.decode(p), wire.Bye)
        (p,) = wire.FrameReader().feed(wire.encode_attach(3))
        assert wire.decode(p) == wire.Attach(3)
        (p,) = wire.FrameReader().feed(wire.encode_detach(7))
        assert wire.decode(p) == wire.Detach(7)
        (p,) = wire.FrameReader().feed(wire.encode_error("boom"))
        assert wire.decode(p) == wire.Error("boom")
        (p,) = wire.FrameReader().feed(wire.encode_redirect("/tmp/x.sock"))
        assert wire.decode(p) == wire.Redirect("/tmp/x.sock")
        (p,) = wire.FrameReader().feed(wire.encode_goaway())
        assert wire.decode(p) == wire.GoAway("draining")
        (p,) = wire.FrameReader().feed(wire.encode_goaway("rebalance"))
        assert wire.decode(p) == wire.GoAway("rebalance")

    def test_old_protocol_version_rejected_loudly(self):
        """Versions outside the ``[MIN_VERSION, VERSION]`` accept window
        must be rejected with an error NAMING both the version and the
        window — never silent misinterpretation of the old layout.  (v4
        and v5 are frame-compatible with v3 — the optional REPLY timing
        payload and the HELLO/HELLO_ACK shm tails are detected by
        presence — so v3 itself DECODES; see test_observability.py for
        that direction.)"""
        assert wire.VERSION == 5 and wire.MIN_VERSION == 3
        good = wire.FrameReader().feed(wire.encode_bye())[0]
        v1 = good[:2] + b"\x01" + good[3:]
        with pytest.raises(wire.WireError,
                           match=r"version 1.*supported \[3, 5\]"):
            wire.decode(v1)
        v6 = good[:2] + b"\x06" + good[3:]
        with pytest.raises(wire.WireError, match="version 6"):
            wire.decode(v6)

    def test_frame_reader_reassembles_any_fragmentation(self):
        frames = [wire.encode_bye(), wire.encode_error("x" * 300),
                  wire.encode_hello(wire.Hello(2, 8))]
        stream = b"".join(frames)
        rd = wire.FrameReader()
        got = []
        for i in range(len(stream)):           # worst case: 1 byte per read
            got.extend(rd.feed(stream[i:i + 1]))
        assert len(got) == 3
        assert isinstance(wire.decode(got[0]), wire.Bye)
        assert wire.decode(got[1]) == wire.Error("x" * 300)
        assert wire.decode(got[2]) == wire.Hello(2, 8)

    def test_malformed_frames_raise_wire_error(self):
        good = wire.FrameReader().feed(wire.encode_bye())[0]
        with pytest.raises(wire.WireError, match="magic"):
            wire.decode(b"\x00\x00" + good[2:])
        with pytest.raises(wire.WireError, match="version"):
            wire.decode(good[:2] + b"\x63" + good[3:])
        with pytest.raises(wire.WireError):
            wire.decode(good[:3])              # short frame
        req = wire.FrameReader().feed(wire.encode_request(
            1, 3, np.array([True]), np.array([0], np.int32),
            np.zeros(1, np.float32), np.zeros((1, 8), np.int32)))[0]
        with pytest.raises(wire.WireError):
            wire.decode(req[:-5])              # truncated array body
        with pytest.raises(wire.WireError, match="cap"):
            wire.FrameReader().feed(b"\xff\xff\xff\xff")
        # non-UTF8 string bytes must surface as WireError, nothing else
        err = wire.FrameReader().feed(wire.encode_error("ok"))[0]
        with pytest.raises(wire.WireError, match="string"):
            wire.decode(err[:-2] + b"\xff\xfe")


# -- transport registry / teardown satellites --------------------------------

def _dummy_worker_args():
    def fn(params, cache, history, server_pos, t, triggered, u):
        return cache, jnp.zeros_like(u), u
    return fn, None, jnp.zeros((2, 4))


class TestTransportRegistry:
    def test_unknown_transport_lists_valid_ones(self):
        fn, params, cache = _dummy_worker_args()
        with pytest.raises(ValueError) as ei:
            async_rpc.make_worker("carrier-pigeon", fn, params, cache)
        msg = str(ei.value)
        assert "carrier-pigeon" in msg
        for t in async_rpc.TRANSPORTS:
            assert repr(t) in msg, f"{t} missing from: {msg}"

    def test_wire_requires_address_and_rejects_latency(self):
        fn, params, cache = _dummy_worker_args()
        with pytest.raises(ValueError, match="address"):
            async_rpc.make_worker("wire", fn, params, cache)
        with pytest.raises(ValueError, match="measured"):
            async_rpc.make_worker("wire", fn, params, cache,
                                  latency_s=0.01,
                                  wire_opts={"address": "/nowhere"})

    @pytest.mark.parametrize("transport",
                             ["inproc", "stream", "thread", "mock_remote"])
    def test_close_is_idempotent(self, transport):
        fn, params, cache = _dummy_worker_args()
        w = async_rpc.make_worker(transport, fn, params, cache)
        w.close()
        w.close()  # must be a no-op, not a deadlock/error

    def test_finish_async_then_close_and_drain_reentrant(self):
        cfg = _cfg()
        params = deco.init_collab_lm(KEY, cfg)
        stream = next(tok.lm_batches(0, cfg, 2, 6))["tokens"]
        eng = CollaborativeEngine(params, cfg, batch=2, max_len=16)
        sess = eng.session(SessionConfig(mode="async", transport="inproc",
                                         max_staleness=2)).__enter__()
        disp, worker = eng._dispatcher, eng._worker
        for t in range(6):
            sess.step(jnp.asarray(stream[:, t]))
        sess.close()
        worker.close()            # second close (session close already did)
        worker.close()
        assert disp.drain() == [] # re-entrant after close
        assert disp.drain() == []


# -- the standalone correction server ----------------------------------------

@pytest.fixture(scope="module")
def wire_server():
    """One in-thread CorrectionServer shared by the loopback tests."""
    from repro.serving.server import CorrectionServer
    cfg = _cfg()
    params = deco.init_collab_lm(KEY, cfg)
    uds = _uds_path("srv")
    srv = CorrectionServer(cfg, params, slots=8, max_len=32, uds=uds)
    stop = threading.Event()
    th = threading.Thread(target=srv.serve_forever,
                          kwargs=dict(stop=stop), daemon=True)
    th.start()
    yield cfg, params, uds, srv
    stop.set()
    th.join(timeout=10)
    srv.close()


class TestWireLoopback:
    def test_sync_over_wire_matches_scan_and_run(self, wire_server):
        """Acceptance: the REAL boundary with max_staleness=0 reproduces
        the protocol — u/trigger bit-identical to run_scan, fhat and
        server positions matching the in-process sync engine, with RTT
        and bytes measured on the socket."""
        cfg, params, uds, srv = wire_server
        stream = next(tok.lm_batches(0, cfg, 3, 16))["tokens"]
        scan = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        rs = run_scan(scan, stream)
        sync = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        r1 = run_sync(sync, stream)
        a = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        r0 = run_wire(a, stream, address=uds, max_staleness=0)
        assert 0.0 < r0["triggered"].mean() < 1.0, "need mixed triggers"
        np.testing.assert_array_equal(r0["u"], rs["u"])
        np.testing.assert_array_equal(r0["triggered"], rs["triggered"])
        np.testing.assert_allclose(r0["fhat"], r1["fhat"], atol=1e-6)
        np.testing.assert_array_equal(a.server_pos, sync.server_pos)
        rep = r0["comms"]
        assert rep["bytes_sent"] == r1["comms"]["bytes_sent"]
        w = rep["wire"]
        assert w["replies"] == rep["async"]["requests"] > 0
        assert w["tx_bytes"] > 0 and w["rx_bytes"] > 0
        assert w["rtt_mean_s"] > 0.0

    def test_pipelined_over_wire_bytes_invariant_under_coalescing(
            self, wire_server):
        """Deep pipeline on the real boundary: the monitor path stays
        bit-identical, corrections only lower fhat, and the modeled byte
        accounting (each token ships once) survives server-side
        coalescing — bytes_sent is staleness- and coalescing-independent
        and <= baseline."""
        cfg, params, uds, srv = wire_server
        stream = next(tok.lm_batches(0, cfg, 3, 16))["tokens"]
        scan = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        rs = run_scan(scan, stream)
        sync = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        r1 = run_sync(sync, stream)
        a = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        ra = run_wire(a, stream, address=uds, max_staleness=4)
        np.testing.assert_array_equal(ra["u"], rs["u"])
        np.testing.assert_array_equal(ra["triggered"], rs["triggered"])
        assert bool(np.all(ra["fhat"] <= ra["u"] + 1e-6))
        rep = ra["comms"]
        assert rep["bytes_sent"] == r1["comms"]["bytes_sent"]
        assert rep["bytes_sent"] <= rep["bytes_baseline"]
        per = rep["per_stream"]
        assert (per["bytes_sent"] <= per["bytes_baseline"]).all()
        np.testing.assert_array_equal(a.server_pos, sync.server_pos)
        assert rep["async"]["inflight_now"] == 0

    def test_multi_client_session_isolation(self, wire_server):
        """Two engines on ONE server, stepped interleaved: the chatty
        client's triggers must not perturb the quiet client's traces,
        comms account, or server-side rows."""
        cfg, params, uds, srv = wire_server
        loud_cfg = _cfg(threshold=-1e9)   # every step triggers
        stream_a = next(tok.lm_batches(1, cfg, 2, 12))["tokens"]
        stream_b = next(tok.lm_batches(2, cfg, 2, 12))["tokens"]

        # local references, no wire
        ref_b = CollaborativeEngine(params, cfg, batch=2, max_len=32)
        rb_ref = run_sync(ref_b, stream_b)

        a = CollaborativeEngine(params, loud_cfg, batch=2, max_len=32)
        b = CollaborativeEngine(params, cfg, batch=2, max_len=32)
        wcfg = SessionConfig(mode="async", max_staleness=2,
                             transport=TransportSpec("wire", address=uds))
        sa = a.session(wcfg).__enter__()
        sb = b.session(wcfg).__enter__()
        outs_a, outs_b = [], []
        for t in range(12):
            outs_a.append(sa.step(jnp.asarray(stream_a[:, t])))
            outs_b.append(sb.step(jnp.asarray(stream_b[:, t])))
        sa.close()
        sb.close()
        res_b = {k: np.stack([o[k] for o in outs_b], 1)
                 for k in ("u", "fhat", "triggered")}
        res_a_trig = np.stack([o["triggered"] for o in outs_a], 1)
        assert res_a_trig.all(), "loud client must trigger every step"
        # B's protocol is exactly what it would be alone
        np.testing.assert_array_equal(res_b["u"], rb_ref["u"])
        np.testing.assert_array_equal(res_b["triggered"],
                                      rb_ref["triggered"])
        np.testing.assert_array_equal(b.server_pos, ref_b.server_pos)
        # and B's comms account only B's traffic
        assert (b.comms.report()["bytes_sent"]
                == rb_ref["comms"]["bytes_sent"])
        assert srv.stats["sessions"] >= 2

    def test_session_errors(self, wire_server):
        cfg, params, uds, srv = wire_server
        # more slots than the server owns -> Error frame, no crash
        sock = wire.connect(uds, timeout=10)
        try:
            sock.sendall(wire.encode_hello(wire.Hello(batch=999, max_len=16)))
            sock.settimeout(10.0)
            rd = wire.FrameReader()
            msgs = []
            while not msgs:
                data = sock.recv(1 << 16)
                assert data, "server closed without replying"
                msgs = [wire.decode(p) for p in rd.feed(data)]
            assert isinstance(msgs[0], wire.Error)
            assert "server full" in msgs[0].message
        finally:
            sock.close()
        # the client transport surfaces the refusal as a WireError
        with pytest.raises(wire.WireError, match="server full"):
            async_rpc.SocketWorker(cache=None, address=uds, batch=999,
                                   max_len=16)
        # an oversized max_len is refused before any slots are leased
        with pytest.raises(wire.WireError, match="max_len"):
            async_rpc.SocketWorker(cache=None, address=uds, batch=1,
                                   max_len=10_000)
        # a request whose vectors don't match the leased batch is refused
        # AND the session dropped — it can never reach foreign rows
        sock = wire.connect(uds, timeout=10)
        try:
            sock.settimeout(10.0)
            sock.sendall(wire.encode_hello(wire.Hello(batch=2, max_len=16)))
            rd = wire.FrameReader()
            msgs = []
            while not msgs:
                msgs = [wire.decode(p) for p in rd.feed(sock.recv(1 << 16))]
            assert isinstance(msgs[0], wire.HelloAck)
            bad = wire.WireRequest(
                req_id=0, t=3, triggered=np.ones(3, bool),
                server_pos=np.zeros(3, np.int32), u=np.zeros(3, np.float32),
                tokens=np.zeros(12, np.int32))
            sock.sendall(wire.encode_request_arrays(bad))
            msgs = []
            while not msgs:
                msgs = [wire.decode(p) for p in rd.feed(sock.recv(1 << 16))]
            assert isinstance(msgs[0], wire.Error)
            assert "session batch" in msgs[0].message
            assert sock.recv(1 << 16) == b"", "server must drop the session"
        finally:
            sock.close()
        # a v1 peer is rejected LOUDLY: the server answers an ERROR frame
        # naming both versions, then drops the connection
        sock = wire.connect(uds, timeout=10)
        try:
            sock.settimeout(10.0)
            hello = wire.encode_hello(wire.Hello(batch=1, max_len=16))
            v1 = hello[:6] + b"\x01" + hello[7:]  # patch the version byte
            sock.sendall(v1)
            rd = wire.FrameReader()
            msgs = []
            while not msgs:
                data = sock.recv(1 << 16)
                assert data, "server closed without replying"
                msgs = [wire.decode(p) for p in rd.feed(data)]
            assert isinstance(msgs[0], wire.Error)
            assert "version 1" in msgs[0].message
            assert "3" in msgs[0].message
        finally:
            sock.close()
        # churn frames are validated against the lease like requests
        sock = wire.connect(uds, timeout=10)
        try:
            sock.settimeout(10.0)
            sock.sendall(wire.encode_hello(wire.Hello(batch=2, max_len=16)))
            rd = wire.FrameReader()
            msgs = []
            while not msgs:
                msgs = [wire.decode(p) for p in rd.feed(sock.recv(1 << 16))]
            assert isinstance(msgs[0], wire.HelloAck)
            sock.sendall(wire.encode_attach(99))  # outside the lease
            msgs = []
            while not msgs:
                msgs = [wire.decode(p) for p in rd.feed(sock.recv(1 << 16))]
            assert isinstance(msgs[0], wire.Error)
            assert "lease" in msgs[0].message
        finally:
            sock.close()

    def test_engine_detached_after_wire_session(self, wire_server):
        """With a real boundary the server-side state dies with the
        session; the engine must refuse silent cold-cache serving after."""
        cfg, params, uds, srv = wire_server
        stream = next(tok.lm_batches(4, cfg, 2, 8))["tokens"]
        a = CollaborativeEngine(params, cfg, batch=2, max_len=32)
        run_wire(a, stream, address=uds, max_staleness=2)
        with pytest.raises(RuntimeError, match="remote correction server"):
            a.session().step(jnp.asarray(stream[:, 0]))
        with pytest.raises(RuntimeError, match="remote correction server"):
            a.session(SessionConfig(mode="async",
                                    transport="inproc")).__enter__()


class TestCoalescing:
    """Deterministic coalescing semantics via a manually-ticked server."""

    def _open(self, srv, uds, batch, coalesce):
        sock = wire.connect(uds, timeout=5)
        sock.sendall(wire.encode_hello(
            wire.Hello(batch=batch, max_len=16, coalesce=coalesce)))
        ack = self._collect(srv, sock, 1)[0]
        assert isinstance(ack, wire.HelloAck), ack
        return sock, ack

    def _collect(self, srv, sock, n, reader=None):
        reader = reader or wire.FrameReader()
        sock.settimeout(0.0)
        msgs = []
        deadline = time.monotonic() + 30
        while len(msgs) < n:
            srv.serve_tick(0.001)
            try:
                data = sock.recv(1 << 16)
            except (BlockingIOError, socket.timeout):
                continue
            assert data, "server closed"
            msgs.extend(wire.decode(p) for p in reader.feed(data))
            assert time.monotonic() < deadline
        return msgs

    def test_merged_replay_equals_per_request_replay(self):
        """Two queued requests (a deep pipeline: r2 re-triggers r1's row)
        merge into ONE replay — union of masks, min of positions, per-row
        latest t — and the replies match a per-request session replaying
        the same backlogs one by one."""
        from repro.serving.server import CorrectionServer
        cfg = _cfg()
        params = deco.init_collab_lm(KEY, cfg)
        srv = CorrectionServer(cfg, params, slots=2, max_len=16,
                               uds=_uds_path("coal"))
        try:
            rng = np.random.default_rng(0)
            hist = rng.integers(0, 255, (2, 16)).astype(np.int32)
            u1 = np.asarray([0.7, 0.0], np.float32)
            u2 = np.asarray([0.9, 0.4], np.float32)
            def reqs():
                # r1: row 0 triggers at t=2 (backlog 0..2)
                r1 = wire.encode_request(0, 2, np.array([True, False]),
                                         np.array([0, 0], np.int32), u1, hist)
                # r2: rows 0+1 trigger at t=5 (row0 backlog 3..5, row1 0..5)
                r2 = wire.encode_request(1, 5, np.array([True, True]),
                                         np.array([3, 0], np.int32), u2, hist)
                return r1, r2

            # coalescing session: both requests queued before one tick
            sock, _ = self._open(srv, srv.uds, 2, coalesce=True)
            r1, r2 = reqs()
            sock.sendall(r1 + r2)
            rep1, rep2 = self._collect(srv, sock, 2)
            assert rep1.req_id == 0 and rep2.req_id == 1, "FIFO per session"
            assert rep1.coalesced == 2 and rep2.coalesced == 2
            assert srv.stats["replays"] == 1 and srv.stats["coalesced"] == 1
            # merged semantics: row 0 replayed through t=5 once, so BOTH
            # replies carry the fresher corrector for row 0
            np.testing.assert_array_equal(rep1.v[0], rep2.v[0])
            sock.sendall(wire.encode_bye())
            sock.close()
            for _ in range(10):
                srv.serve_tick(0.001)
            assert not srv._sessions, "BYE must free the session"

            # per-request session (coalesce=False) on the SAME rows
            sock, ack = self._open(srv, srv.uds, 2, coalesce=False)
            assert ack.slot_lo == 0, "freed rows must be reused (and reset)"
            r1, r2 = reqs()
            sock.sendall(r1 + r2)
            p1, p2 = self._collect(srv, sock, 2)
            assert p1.coalesced == 1 and p2.coalesced == 1
            assert srv.stats["replays"] == 3, "per-request arm: one each"
            # after its full backlog both paths end at the same replay
            # state: r2's corrections agree bitwise
            np.testing.assert_array_equal(rep2.v, p2.v)
            np.testing.assert_array_equal(rep2.fhat, p2.fhat)
            # r1's reply in the per-request arm is the STALER t=2 v
            assert not np.array_equal(rep1.v[0], p1.v[0])
            sock.close()
        finally:
            srv.close()


class TestConnectHello:
    """Regression: the connect/handshake retry loop used to treat a
    refused handshake and a mid-handshake EOF identically — now a
    deliberate ERROR answer ("server full", "draining") surfaces as
    ``HandshakeRefused`` IMMEDIATELY (the fleet client tries a sibling),
    while a dead peer (refused connect, EOF mid-handshake) is retried
    until the deadline and then surfaces as ``PeerGone`` (the supervisor
    marks the server unhealthy)."""

    def test_deliberate_refusal_raises_immediately(self, wire_server):
        cfg, params, uds, srv = wire_server
        t0 = time.monotonic()
        with pytest.raises(wire.HandshakeRefused) as ei:
            # way over the 8-slot pool: the server answers ERROR
            wire.connect_hello(uds, wire.Hello(batch=100, max_len=32),
                               timeout=30.0)
        # refused != dead: no retry-until-deadline, and the server's
        # reason survives verbatim on .message
        assert time.monotonic() - t0 < 10.0
        assert "server full" in ei.value.message
        assert isinstance(ei.value, wire.WireError)

    def test_no_listener_is_peer_gone_after_retries(self):
        path = _uds_path("gone")  # directory exists, socket never bound
        t0 = time.monotonic()
        with pytest.raises(wire.PeerGone):
            wire.connect_hello(path, wire.Hello(batch=1, max_len=8),
                               timeout=0.6, retry_interval=0.05)
        assert time.monotonic() - t0 >= 0.5, "must retry until deadline"

    def test_mid_handshake_eof_is_peer_gone_not_refused(self):
        # a listener that accepts and instantly closes: the client sees
        # EOF before any ERROR frame — that is a dead peer, not a refusal
        path = _uds_path("eof")
        lst = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        lst.bind(path)
        lst.listen(8)
        accepts = []

        def slam():
            while True:
                try:
                    c, _ = lst.accept()
                except OSError:
                    return
                accepts.append(1)
                c.close()

        th = threading.Thread(target=slam, daemon=True)
        th.start()
        try:
            with pytest.raises(wire.PeerGone, match="handshake"):
                wire.connect_hello(path, wire.Hello(batch=1, max_len=8),
                                   timeout=0.6, retry_interval=0.05)
            assert len(accepts) >= 2, "EOF mid-handshake must be retried"
        finally:
            lst.close()

    def test_redirect_hop_is_followed(self, wire_server):
        cfg, params, uds, srv = wire_server
        # a fake router: answers any HELLO with REDIRECT to the real server
        path = _uds_path("rtr")
        lst = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        lst.bind(path)
        lst.listen(8)

        def router():
            try:
                c, _ = lst.accept()
            except OSError:
                return
            rd = wire.FrameReader()
            while not rd.feed(c.recv(1 << 16)):
                pass
            c.sendall(wire.encode_redirect(uds))
            c.close()

        th = threading.Thread(target=router, daemon=True)
        th.start()
        try:
            sock, ack, reader, tx, rx = wire.connect_hello(
                path, wire.Hello(batch=2, max_len=32), timeout=20.0)
            try:
                assert isinstance(ack, wire.HelloAck)
                assert tx > 0 and rx > 0
            finally:
                sock.sendall(wire.encode_bye())
                sock.close()
        finally:
            lst.close()


class TestTwoProcessSmoke:
    """CI tier-1: a real server SUBPROCESS + one engine over a UDS."""

    def test_two_process_loopback(self):
        cfg = _cfg()
        params = deco.init_collab_lm(KEY, cfg)
        stream = next(tok.lm_batches(0, cfg, 2, 10))["tokens"]
        tmp = tempfile.mkdtemp(prefix="wire_proc_")
        uds, ready = os.path.join(tmp, "s.sock"), os.path.join(tmp, "ready")
        src = os.path.join(os.path.dirname(__file__), "..", "src")
        env = dict(os.environ)
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.launch.server",
             "--arch", "paper-synthetic-serving", "--uds", uds,
             "--slots", "2", "--max-len", "24", "--ready-file", ready],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
            text=True)
        try:
            deadline = time.monotonic() + SPAWN_DEADLINE_S
            while not os.path.exists(ready):
                assert proc.poll() is None, proc.stderr.read()[-3000:]
                assert time.monotonic() < deadline, "server startup timeout"
                time.sleep(0.05)
            eng = CollaborativeEngine(params, cfg, batch=2, max_len=24)
            res = run_wire(eng, stream, address=uds, max_staleness=2)
            scan = CollaborativeEngine(params, cfg, batch=2, max_len=24)
            rs = run_scan(scan, stream)
            np.testing.assert_array_equal(res["u"], rs["u"])
            np.testing.assert_array_equal(res["triggered"], rs["triggered"])
            assert bool(np.all(res["fhat"] <= res["u"] + 1e-6))
            w = res["comms"]["wire"]
            assert w["tx_bytes"] > 0 and w["rx_bytes"] > 0
            assert w["rtt_mean_s"] > 0.0, "RTT must be measured, not modeled"
        finally:
            proc.terminate()
            try:
                proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                proc.kill()

"""Partition-rule engine invariants (AbstractMesh — no devices needed)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.core import decomposition as deco
from repro.distributed import sharding as shd
from repro.nn.module import iter_paths, map_with_path

MESH = shd.abstract_mesh((16, 16), ("data", "model"))
MESH3 = shd.abstract_mesh((2, 16, 16), ("pod", "data", "model"))

KEY = jax.random.PRNGKey(0)


def _specs_with_shapes(arch):
    cfg = registry.get_full(arch)
    shapes = jax.eval_shape(lambda: deco.init_collab_lm(KEY, cfg))
    return cfg, shapes, shd.param_specs(shapes, MESH)


@pytest.mark.parametrize("arch", registry.names())
def test_all_specs_divide(arch):
    """Every assigned spec axis must divide the corresponding dim — this IS
    the 'sharding coherence' property the dry-run compiles prove at scale."""
    _, shapes, specs = _specs_with_shapes(arch)
    flat_shapes = dict(iter_paths(shapes))
    flat_specs = dict(iter_paths(specs))
    checked = 0
    for path, spec in flat_specs.items():
        leaf = flat_shapes[path]
        if leaf is None or isinstance(spec, type(None)):
            continue
        assert len(spec) <= len(leaf.shape), path
        padded = (None,) * (len(leaf.shape) - len(spec)) + tuple(spec)
        for dim, ax in zip(leaf.shape, padded):
            if ax is not None:
                assert dim % MESH.shape[ax] == 0, (path, leaf.shape, spec)
                checked += 1
    assert checked > 0, "at least some leaves must be sharded"


@pytest.mark.parametrize("arch", ["granite-8b", "deepseek-v3-671b",
                                  "zamba2-7b", "xlstm-350m"])
def test_monitor_tower_replicated(arch):
    _, shapes, specs = _specs_with_shapes(arch)
    for path, spec in iter_paths(specs):
        if path.startswith(("edge/", "u_head/", "v_head/")):
            assert all(a is None for a in tuple(spec)), (
                f"monitor leaf {path} must replicate, got {spec}")


def test_moe_expert_parallel_vs_tp_fallback():
    """deepseek (256 experts) -> expert-parallel; mixtral (8) -> ff TP.
    Compare the trailing (E, d, ff) axes (leaves may be layer-stacked)."""
    _, ds_shapes, ds_specs = _specs_with_shapes("deepseek-v3-671b")
    got = dict(iter_paths(ds_specs))
    ds_gate = [v for k, v in got.items() if k.endswith("moe/w_gate")]
    assert ds_gate and all(tuple(s)[-3:] == ("model", None, None)
                           for s in ds_gate)

    _, mx_shapes, mx_specs = _specs_with_shapes("mixtral-8x22b")
    got = dict(iter_paths(mx_specs))
    mx_gate = [v for k, v in got.items() if k.endswith("moe/w_gate")]
    assert mx_gate and all(tuple(s)[-3:] == (None, None, "model")
                           for s in mx_gate if len(s) >= 3)


def test_batch_spec_handles_batch_one():
    assert shd.batch_spec(MESH, (1, 524288), 1) == P()
    assert shd.batch_spec(MESH, (256, 4096), 256) == P("data", None)
    assert shd.batch_spec(MESH3, (256, 4096), 256) == P(("pod", "data"), None)


def test_cache_specs_shard_batch_and_trailing():
    from repro.models import api as model_api
    cfg = registry.get_full("granite-8b")
    cache = jax.eval_shape(lambda: model_api.init_cache(cfg, 128, 32768))
    specs = shd.cache_specs(cache, MESH, 128)
    k_spec = specs["blocks"].k
    assert k_spec[1] == "data"          # batch axis
    assert "model" in tuple(k_spec)     # head_dim (128 % 16 == 0)
    assert k_spec[2] is None            # cache-time axis never sharded
    # edge variant: no model axis anywhere
    especs = shd.cache_specs(cache, MESH, 128, use_model=False)
    assert "model" not in tuple(especs["blocks"].k)


def test_cache_specs_time_mode():
    """§Perf B1: mode='time' shards the cache seq axis, not head_dim."""
    from repro.models import api as model_api
    cfg = registry.get_full("granite-8b")
    cache = jax.eval_shape(lambda: model_api.init_cache(cfg, 128, 32768))
    specs = shd.cache_specs(cache, MESH, 128, mode="time")
    k_spec = specs["blocks"].k  # (L, B, C, kv, hd)
    assert k_spec[1] == "data"
    assert k_spec[2] == "model"          # time axis sharded
    assert all(ax is None for ax in tuple(k_spec)[3:])


def test_opt_specs_zero1_widens_over_data():
    """§Perf A3: ZeRO-1 moments pick up a 'data' axis where divisible."""
    cfg = registry.get_full("deepseek-v3-671b")
    shapes = jax.eval_shape(lambda: deco.init_collab_lm(KEY, cfg))
    base = shd.opt_specs(shapes, MESH, zero1=False)
    z1 = shd.opt_specs(shapes, MESH, zero1=True)
    # expert weights: (E, d, ff) P('model', None, None) -> P('model','data',None)
    def find(tree, frag):
        return [(p, s) for p, s in iter_paths(tree)
                if frag in p and isinstance(s, P)]
    b = dict(find(base, "moe/w_gate"))
    z = dict(find(z1, "moe/w_gate"))
    assert b, "no moe/w_gate specs found"
    for path in b:
        zspec = z[path]
        assert any(ax == "data" or (isinstance(ax, tuple) and "data" in ax)
                   for ax in tuple(zspec)), (path, zspec)
    # every widened spec still divides the shape
    flat_p = dict(iter_paths(shapes))
    for path, spec in iter_paths(z1):
        if not isinstance(spec, P):
            continue
        leaf = flat_p.get(path)
        if leaf is None or not hasattr(leaf, "shape"):
            continue
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 9):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= MESH.shape[a]
            assert dim % n == 0, (path, spec, leaf.shape)

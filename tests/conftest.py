import os
import sys

# NOTE: deliberately no xla_force_host_platform_device_count here — smoke
# tests and benches must see the real (single) device; only the dry-run
# subprocess pins a placeholder device count.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Gate the optional `hypothesis` test dependency (pyproject `test` extra):
# hermetic environments without it fall back to the deterministic stub so
# the property-test modules still collect and run.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub
    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

# Centralized subprocess-startup deadline for every test that spawns a
# correction server (test_wire, test_churn, test_mesh, test_fleet).  The
# old per-test hardcoded 180 s flaked on the 2-core CI container, where a
# cold jax import under load can exceed it; one env-overridable knob
# beats four copies.  (launch.server.spawn_subprocess reads the same env
# var when no explicit timeout is passed.)
SPAWN_DEADLINE_S = float(os.environ.get("REPRO_SPAWN_DEADLINE_S", "240"))

"""Property tests (hypothesis) for the compact MoE dispatch core (§Perf A2).

Invariants checked over random routings:
  * with generous capacity (no drops) the sort-based dispatch equals a
    per-token dense reference computed straight from top-k;
  * slot bookkeeping: every non-sentinel slot_tok is a valid token id and
    each (token, expert) assignment lands at most once;
  * the load-balance aux loss is >= 1 at the optimum and finite.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.nn.moe import (_route, _slot_table, expert_capacity, init_moe,
                          moe_apply)

KEY = jax.random.PRNGKey(0)


def _dense_reference(p, x, n_experts, top_k):
    """Per-token loop over top-k experts (no capacity): the semantic spec."""
    B, S, d = x.shape
    xf = np.asarray(x.reshape(B * S, d), np.float32)
    logits = xf @ np.asarray(p["router"]["w"], np.float32)
    if "b" in p["router"]:
        logits = logits + np.asarray(p["router"]["b"], np.float32)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    order = np.argsort(-probs, axis=-1)[:, :top_k]
    wg = np.asarray(p["w_gate"], np.float32)
    wu = np.asarray(p["w_up"], np.float32)
    wd = np.asarray(p["w_down"], np.float32)
    y = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        ws = probs[t, order[t]]
        ws = ws / ws.sum()
        for j, e in enumerate(order[t]):
            g = xf[t] @ wg[e]
            u = xf[t] @ wu[e]
            silu = g / (1.0 + np.exp(-g))
            y[t] += ws[j] * ((silu * u) @ wd[e])
    return y.reshape(B, S, d)


@settings(max_examples=8, deadline=None)
@given(st.integers(2, 8), st.integers(1, 3), st.integers(2, 6),
       st.integers(0, 10_000))
def test_no_drop_dispatch_matches_dense_reference(E, K, T, seed):
    K = min(K, E)
    d, f = 8, 16
    key = jax.random.fold_in(KEY, seed)
    p = init_moe(key, d, f, E)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, T, d), jnp.float32)
    # capacity_factor large enough that nothing can drop
    y, aux = moe_apply(p, x, n_experts=E, top_k=K,
                       capacity_factor=float(E), compute_dtype=jnp.float32)
    ref = _dense_reference(p, x, E, K)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-4, atol=2e-4)
    assert np.isfinite(float(aux))


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 16), st.integers(1, 4), st.integers(1, 64),
       st.floats(0.25, 2.0), st.integers(0, 10_000))
def test_slot_table_invariants(E, K, T, cf, seed):
    K = min(K, E)
    key = jax.random.fold_in(KEY, seed)
    top_p = jax.nn.softmax(
        jax.random.normal(key, (T, K), jnp.float32), axis=-1)
    # top-k without replacement per token (real routers never duplicate)
    noise = jax.random.normal(jax.random.fold_in(key, 1), (T, E))
    top_i = jnp.argsort(-noise, axis=-1)[:, :K]
    C = expert_capacity(T, E, K, cf)
    slot_tok, w_slot = _slot_table(top_i, top_p, n_experts=E, top_k=K, C=C)
    slot_tok = np.asarray(slot_tok)
    w_slot = np.asarray(w_slot)
    assert slot_tok.shape == (E * C,)
    # sentinel or valid token id
    assert ((slot_tok == T) | ((slot_tok >= 0) & (slot_tok < T))).all()
    # empty slots carry zero weight
    assert (w_slot[slot_tok == T] == 0).all()
    # each (expert, token) assignment appears at most once
    pairs = [(s // C, t) for s, t in enumerate(slot_tok) if t < T]
    assert len(pairs) == len(set(pairs))
    # no expert exceeds capacity (structural: slots are per-expert rows)
    for e in range(E):
        assert (slot_tok[e * C:(e + 1) * C] < T).sum() <= C


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(2, 32), st.integers(0, 10_000))
def test_router_aux_loss_floor(K, E, seed):
    """Switch aux loss is ~1 for a perfectly uniform router, > 1 skewed."""
    K = min(K, E)
    key = jax.random.fold_in(KEY, seed)
    T, d = 256, 8
    xf = jax.random.normal(key, (T, d), jnp.float32)
    p = {"router": {"w": jnp.zeros((d, E), jnp.float32)}}  # uniform router
    _, _, aux = _route(p, xf, E, K)
    assert float(aux) == pytest.approx(1.0, rel=0.1)

"""Serving engine correctness + collaborative protocol accounting."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import registry
from repro.core import decomposition as deco
from repro.data import tokens as tok
from repro.serving import SessionConfig, TransportSpec
from repro.serving.collaborative import CollaborativeEngine
from repro.serving.engine import ServeEngine

KEY = jax.random.PRNGKey(0)


# every caller goes through the public MonitorSession API; the deprecated
# engine shims get their own dedicated test (tests/test_api.py)
def run_sync(eng, stream):
    return eng.session().run(stream)


def run_scan(eng, stream):
    return eng.session(SessionConfig(mode="scan")).run(stream)


def run_async(eng, stream, *, transport="stream", max_staleness=1,
              latency_s=None, address=None):
    spec = TransportSpec(transport, address=address, latency_s=latency_s)
    with eng.session(SessionConfig(mode="async", transport=spec,
                                   max_staleness=max_staleness)) as s:
        return s.run(stream)


class TestServeEngine:
    def test_prefill_matches_forward(self):
        """Cache-building prefill must reproduce the batched forward logits."""
        from repro.models import api as model_api
        cfg = registry.get_smoke("granite-8b")
        params = model_api.init_model(KEY, cfg)
        toks = next(tok.lm_batches(0, cfg, 2, 16))["tokens"]
        fwd = model_api.forward(params, cfg, {"tokens": jnp.asarray(toks)})
        eng = ServeEngine(params, cfg, batch=2, max_len=32)
        logits_last = eng.prefill(jnp.asarray(toks))
        np.testing.assert_allclose(np.asarray(logits_last),
                                   np.asarray(fwd["logits"][:, -1]),
                                   atol=2e-3, rtol=2e-3)

    def test_generate_is_deterministic_greedy(self):
        cfg = registry.get_smoke("xlstm-350m")
        from repro.models import api as model_api
        params = model_api.init_model(KEY, cfg)
        toks = jnp.asarray(next(tok.lm_batches(1, cfg, 2, 8))["tokens"])
        g1 = ServeEngine(params, cfg, 2, 32).generate(toks, 6)
        g2 = ServeEngine(params, cfg, 2, 32).generate(toks, 6)
        np.testing.assert_array_equal(g1, g2)

    def test_decode_at_per_element_positions_and_masking(self):
        """The per-element decode primitive: uniform vector positions match
        the batched decode; inactive elements' cache rows stay bit-frozen;
        heterogeneous positions decode each element at its own depth."""
        from repro.models import api as model_api
        cfg = registry.get_smoke("granite-8b")
        params = model_api.init_model(KEY, cfg)
        stream = jnp.asarray(next(tok.lm_batches(0, cfg, 3, 8))["tokens"])

        # uniform positions, all active == plain batched decode
        ref = ServeEngine(params, cfg, batch=3, max_len=16)
        per = ServeEngine(params, cfg, batch=3, max_len=16)
        for t in range(4):
            _, h_ref = ref.decode(stream[:, t])
            _, h_per = per.decode_at(stream[:, t], jnp.full((3,), t, jnp.int32),
                                     jnp.ones((3,), bool))
            np.testing.assert_allclose(np.asarray(h_per), np.asarray(h_ref),
                                       atol=2e-3, rtol=2e-3)

        # masking: inactive element's cache row is bit-untouched
        eng = ServeEngine(params, cfg, batch=3, max_len=16)
        before = np.asarray(eng.cache["blocks"].k).copy()
        eng.decode_at(stream[:, 0], jnp.zeros((3,), jnp.int32),
                      jnp.asarray([True, False, True]))
        after = np.asarray(eng.cache["blocks"].k)
        assert not np.array_equal(before[:, 0], after[:, 0])
        np.testing.assert_array_equal(before[:, 1], after[:, 1])

        # heterogeneous positions: element 1 held at pos 0 while element 0
        # advances; its eventual first decode matches a fresh engine's
        het = ServeEngine(params, cfg, batch=2, max_len=16)
        for t in range(3):
            het.decode_at(stream[:2, t], jnp.full((2,), t, jnp.int32),
                          jnp.asarray([True, False]))
        _, h = het.decode_at(jnp.stack([stream[0, 3], stream[1, 0]]),
                             jnp.asarray([3, 0], jnp.int32),
                             jnp.ones((2,), bool))
        fresh = ServeEngine(params, cfg, batch=2, max_len=16)
        _, h0 = fresh.decode(jnp.stack([stream[0, 0], stream[1, 0]]))
        np.testing.assert_allclose(np.asarray(h)[1], np.asarray(h0)[1],
                                   atol=2e-3, rtol=2e-3)


class TestCollaborativeEngine:
    def _engine(self, threshold):
        cfg = registry.get_smoke("granite-8b")
        cfg = cfg.replace(monitor=cfg.monitor.__class__(
            **{**cfg.monitor.__dict__, "threshold": threshold,
               "trigger_margin": 0.0}))
        params = deco.init_collab_lm(KEY, cfg)
        return cfg, params

    def test_no_trigger_means_no_server_traffic(self):
        cfg, params = self._engine(threshold=1e9)  # unreachable
        eng = CollaborativeEngine(params, cfg, batch=2, max_len=64)
        stream = next(tok.lm_batches(0, cfg, 2, 12))["tokens"]
        res = run_sync(eng, stream)
        assert res["triggered"].sum() == 0
        assert res["comms"]["bytes_sent"] == 0
        assert eng.server.pos == 0, "server cache must stay cold"
        np.testing.assert_allclose(res["fhat"], res["u"])

    def test_always_trigger_matches_joint_model(self):
        """With threshold=-inf the engine must reproduce u - s*sigma(v) with
        the server fully caught up each step."""
        cfg, params = self._engine(threshold=-1e9)
        eng = CollaborativeEngine(params, cfg, batch=2, max_len=64)
        stream = next(tok.lm_batches(0, cfg, 2, 10))["tokens"]
        res = run_sync(eng, stream)
        assert res["triggered"].all()
        assert eng.server.pos == 10
        assert res["comms"]["reduction_x"] <= 1.0 + 1e-6
        assert bool(np.all(res["fhat"] <= res["u"] + 1e-6))

    def test_comms_reduction_under_selective_trigger(self):
        """Per-stream accounting: a quiet stream buys the full reduction —
        its tokens are NEVER shipped, regardless of what other streams do."""
        cfg, params = self._engine(threshold=0.5)
        eng = CollaborativeEngine(params, cfg, batch=2, max_len=128)
        # deterministic per-stream stub: stream 0 always pages, stream 1 never
        eng._u_head = jax.jit(
            lambda p, h: jnp.where(jnp.arange(h.shape[0]) == 0, 1.0, -1.0))
        stream = next(tok.lm_batches(3, cfg, 2, 40))["tokens"]
        res = run_sync(eng, stream)
        trig_rate = res["triggered"].mean()
        assert 0.0 < trig_rate < 1.0, "stub must produce mixed triggering"
        assert res["comms"]["bytes_sent"] < res["comms"]["bytes_baseline"]
        assert res["comms"]["reduction_x"] > 1.0
        per = res["comms"]["per_stream"]
        assert per["bytes_sent"][1] == 0, "quiet stream must ship nothing"
        assert per["bytes_sent"][0] == per["bytes_baseline"][0]

    def test_bytes_invariant_under_mixed_trigger(self):
        """Each token ships at most once => bytes_sent <= bytes_baseline,
        per stream and in aggregate (the seed charged
        triggered.sum() * backlog_len, which violates this)."""
        cfg, params = self._engine(threshold=0.5)
        eng = CollaborativeEngine(params, cfg, batch=2, max_len=128)
        eng._u_head = jax.jit(lambda p, h: jnp.tanh(10.0 * h[..., 0]))
        stream = next(tok.lm_batches(3, cfg, 2, 40))["tokens"]
        res = run_sync(eng, stream)
        assert 0.0 < res["triggered"].mean() < 1.0
        assert res["comms"]["bytes_sent"] <= res["comms"]["bytes_baseline"]
        per = res["comms"]["per_stream"]
        assert (per["bytes_sent"] <= per["bytes_baseline"]).all()
        # and the meter agrees with the raw trigger trace: shipped tokens on
        # stream i = index of its last trigger + 1
        for i in range(2):
            idx = np.where(res["triggered"][i])[0]
            want = (idx[-1] + 1) if len(idx) else 0
            assert per["bytes_sent"][i] == want * 8


class TestBatchedScanPath:
    def _setup(self, threshold=0.1, batch=3, length=20):
        cfg = registry.get_smoke("granite-8b")
        cfg = cfg.replace(monitor=cfg.monitor.__class__(
            **{**cfg.monitor.__dict__, "threshold": threshold,
               "trigger_margin": 0.0}))
        params = deco.init_collab_lm(KEY, cfg)
        stream = next(tok.lm_batches(0, cfg, batch, length))["tokens"]
        return cfg, params, stream

    def test_scan_bit_identical_to_per_step_reference(self):
        """The lax.scan fast path is pure machinery: identical ops to a
        per-step loop => bit-identical u/fhat/triggered traces."""
        from repro.core.gating import compact_correction
        from repro.models import api as model_api
        cfg, params, stream = self._setup()
        B, S = stream.shape[:2]
        eng = CollaborativeEngine(params, cfg, batch=B, max_len=32)
        rs = run_scan(eng, stream)

        m, ecfg = cfg.monitor, deco.edge_arch(cfg)
        ecache = model_api.init_cache(ecfg, B, eng.max_len)
        scache = model_api.init_cache(cfg, B, eng.max_len)

        @jax.jit
        def ref_step(ecache, scache, tok_t, pos):
            _, eh, ecache = model_api.decode_step(
                params["edge"], ecfg, ecache, tok_t, pos)
            u = eng._u_head(params, eh)
            _, sh, scache = model_api.decode_step(
                params["server"], cfg, scache, tok_t, pos)

            def corrector(buf):
                return m.s * deco.sigma(eng._v_head(params, buf), m.sigma)

            fhat, _, _ = compact_correction(
                u, sh.astype(jnp.float32), corrector, m.threshold,
                m.trigger_margin, B)
            return ecache, scache, u, fhat, u > m.threshold - m.trigger_margin

        us, fhats, trigs = [], [], []
        for t in range(S):
            ecache, scache, u, fhat, trig = ref_step(
                ecache, scache, jnp.asarray(stream[:, t]),
                jnp.asarray(t, jnp.int32))
            us.append(np.asarray(u)); fhats.append(np.asarray(fhat))
            trigs.append(np.asarray(trig))
        np.testing.assert_array_equal(rs["u"], np.stack(us, 1))
        np.testing.assert_array_equal(rs["fhat"], np.stack(fhats, 1))
        np.testing.assert_array_equal(rs["triggered"], np.stack(trigs, 1))

    def test_scan_matches_lazy_online_engine(self):
        """Protocol equivalence: the lazily-catching-up online engine and
        the eager offline scan produce the same traces (u/trigger exact;
        fhat to vmap-vs-batch matmul rounding) and the SAME per-stream
        communication accounting."""
        cfg, params, stream = self._setup()
        B = stream.shape[0]
        lazy = CollaborativeEngine(params, cfg, batch=B, max_len=32)
        r1 = run_sync(lazy, stream)
        scan = CollaborativeEngine(params, cfg, batch=B, max_len=32)
        r2 = run_scan(scan, stream)
        assert 0.0 < r1["triggered"].mean() < 1.0, "need mixed triggers"
        np.testing.assert_array_equal(r1["u"], r2["u"])
        np.testing.assert_array_equal(r1["triggered"], r2["triggered"])
        np.testing.assert_allclose(r1["fhat"], r2["fhat"], atol=1e-6)
        np.testing.assert_array_equal(r1["comms"]["per_stream"]["bytes_sent"],
                                      r2["comms"]["per_stream"]["bytes_sent"])
        assert r1["comms"]["bytes_sent"] == r2["comms"]["bytes_sent"]
        assert r1["comms"]["trigger_rate"] == r2["comms"]["trigger_rate"]

    def test_per_element_backlog_isolation(self):
        """A trigger on stream 0 must not flush stream 1's backlog, advance
        its server position, or charge its comms account."""
        cfg, params, stream = self._setup(batch=2, length=12)
        eng = CollaborativeEngine(params, cfg, batch=2, max_len=32)
        eng._u_head = jax.jit(
            lambda p, h: jnp.where(jnp.arange(h.shape[0]) == 0, 1.0, -1.0))
        server_k_before = np.asarray(eng.server.cache["blocks"].k).copy()
        res = run_sync(eng, stream)
        assert res["triggered"][0].all() and not res["triggered"][1].any()
        # stream 0 caught up to the end; stream 1's server state untouched
        assert eng.server_pos[0] == 12 and eng.server_pos[1] == 0
        server_k = np.asarray(eng.server.cache["blocks"].k)
        assert not np.array_equal(server_k[:, 0], server_k_before[:, 0])
        np.testing.assert_array_equal(server_k[:, 1], server_k_before[:, 1])
        per = eng.comms.per_stream_report()
        assert per["bytes_sent"][0] > 0 and per["bytes_sent"][1] == 0
        # quiet stream's report is pure pass-through: fhat == u
        np.testing.assert_array_equal(res["fhat"][1], res["u"][1])

    def test_u_head_applies_truncation_mask(self):
        """Serving u must equal training u (monitor_score's Eq. 8
        truncation), not the full-basis head the seed served."""
        from repro.models import api as model_api
        cfg, params, stream = self._setup(batch=2, length=8)
        eng = CollaborativeEngine(params, cfg, batch=2, max_len=16,
                                  monitor_n=cfg.monitor.n_features // 2)
        res = run_sync(eng, stream)
        # training-side reference with the same truncation
        m = cfg.monitor
        from repro.nn.module import linear
        eout = model_api.forward(params["edge"], deco.edge_arch(cfg),
                                 {"tokens": jnp.asarray(stream)})
        feats = jnp.tanh(linear(params["u_head"]["w_feat"],
                                eout["hidden"].astype(jnp.float32)))
        mask = (jnp.arange(feats.shape[-1]) < m.n_features // 2).astype(jnp.float32)
        t = jax.nn.softplus(params["u_head"]["raw_t"])
        u_train = feats @ (params["u_head"]["a"] * mask) + t
        np.testing.assert_allclose(res["u"], np.asarray(u_train),
                                   atol=2e-3, rtol=2e-3)
        # and with a truncated n the serving scores differ from full-basis
        eng_full = CollaborativeEngine(params, cfg, batch=2, max_len=16)
        res_full = run_sync(eng_full, stream)
        assert not np.allclose(res["u"], res_full["u"])


class TestAsyncPipelinedEngine:
    """The pipelined online path (serving/async_rpc.py): strict-sync
    fallback bit-identity, staleness-independent monitor path, one-step-late
    merge semantics, and comms/server state consistency."""

    def _setup(self, threshold=0.1, batch=3, length=16):
        cfg = registry.get_smoke("granite-8b")
        cfg = cfg.replace(monitor=cfg.monitor.__class__(
            **{**cfg.monitor.__dict__, "threshold": threshold,
               "trigger_margin": 0.0}))
        params = deco.init_collab_lm(KEY, cfg)
        stream = next(tok.lm_batches(0, cfg, batch, length))["tokens"]
        return cfg, params, stream

    def test_sync_fallback_bit_identical_to_run(self):
        """max_staleness=0 is the strict synchronous engine: same traces,
        same comms, same server cache — bit for bit."""
        cfg, params, stream = self._setup()
        B = stream.shape[0]
        sync = CollaborativeEngine(params, cfg, batch=B, max_len=32)
        r1 = run_sync(sync, stream)
        a = CollaborativeEngine(params, cfg, batch=B, max_len=32)
        r0 = run_async(a, stream, transport="inproc", max_staleness=0)
        assert 0.0 < r1["triggered"].mean() < 1.0, "need mixed triggers"
        np.testing.assert_array_equal(r0["u"], r1["u"])
        np.testing.assert_array_equal(r0["fhat"], r1["fhat"])
        np.testing.assert_array_equal(r0["triggered"], r1["triggered"])
        assert r0["comms"]["bytes_sent"] == r1["comms"]["bytes_sent"]
        assert r0["comms"]["trigger_rate"] == r1["comms"]["trigger_rate"]
        np.testing.assert_array_equal(
            r0["comms"]["per_stream"]["bytes_sent"],
            r1["comms"]["per_stream"]["bytes_sent"])
        np.testing.assert_array_equal(a.server_pos, sync.server_pos)
        for x, y in zip(jax.tree.leaves(a.server.cache),
                        jax.tree.leaves(sync.server.cache)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_sync_fallback_matches_run_scan(self):
        """Acceptance: max_staleness=0 vs the offline scan — u/trigger
        bit-identical; fhat to vmap-vs-batch matmul rounding (the same
        tolerance the sync engine itself is held to vs the scan)."""
        cfg, params, stream = self._setup()
        B = stream.shape[0]
        scan = CollaborativeEngine(params, cfg, batch=B, max_len=32)
        rs = run_scan(scan, stream)
        a = CollaborativeEngine(params, cfg, batch=B, max_len=32)
        r0 = run_async(a, stream, transport="inproc", max_staleness=0)
        np.testing.assert_array_equal(r0["u"], rs["u"])
        np.testing.assert_array_equal(r0["triggered"], rs["triggered"])
        np.testing.assert_allclose(r0["fhat"], rs["fhat"], atol=1e-6)

    @settings(max_examples=5, deadline=None)
    @given(staleness=st.integers(min_value=0, max_value=3),
           threshold=st.floats(min_value=-0.3, max_value=0.3))
    def test_monitor_path_staleness_independent(self, staleness, threshold):
        """Property (safety): u and the trigger trace NEVER depend on the
        staleness window — the monitor path does not wait on the server —
        and corrections only ever lower fhat below u."""
        cfg, params, stream = self._setup(threshold=threshold, batch=2,
                                          length=8)
        scan = CollaborativeEngine(params, cfg, batch=2, max_len=16)
        rs = run_scan(scan, stream)
        a = CollaborativeEngine(params, cfg, batch=2, max_len=16)
        ra = run_async(a, stream, transport="inproc", max_staleness=staleness)
        np.testing.assert_array_equal(ra["u"], rs["u"])
        np.testing.assert_array_equal(ra["triggered"], rs["triggered"])
        assert bool(np.all(ra["fhat"] <= ra["u"] + 1e-6))

    def test_corrections_merge_one_step_late(self):
        """Pipelined semantics: with an always-triggering monitor the
        correction computed for step t lands in fhat at step t+1 (applied
        to step t+1's u); step 0 reports the uncorrected u."""
        cfg, params, stream = self._setup(threshold=0.5, batch=2, length=10)
        stub = jax.jit(lambda p, h: jnp.ones(h.shape[0], jnp.float32))
        sync = CollaborativeEngine(params, cfg, batch=2, max_len=16)
        sync._u_head = stub
        r1 = run_sync(sync, stream)
        assert r1["triggered"].all()
        corr_sync = r1["u"] - r1["fhat"]  # s*sigma(v_t) per step
        assert (corr_sync > 0).any(), "corrector must actually fire"

        a = CollaborativeEngine(params, cfg, batch=2, max_len=16)
        a._u_head = stub
        ra = run_async(a, stream, transport="inproc", max_staleness=2)
        assert ra["triggered"].all()
        # step 0: no reply merged yet -> monitor-only report
        np.testing.assert_array_equal(ra["fhat"][:, 0], ra["u"][:, 0])
        # step t>=1: yesterday's corrector applied to today's u
        np.testing.assert_allclose(
            ra["fhat"][:, 1:], ra["u"][:, 1:] - corr_sync[:, :-1], atol=1e-6)

    def test_async_transports_agree_and_comms_invariants(self):
        """stream/thread/mock_remote transports under simulated latency:
        identical monitor traces, identical shipped bytes (charged at
        dispatch, so staleness-independent), bytes invariant, clean
        in-flight teardown, and the final server cache matches the
        synchronous engine's."""
        cfg, params, stream = self._setup()
        B = stream.shape[0]
        sync = CollaborativeEngine(params, cfg, batch=B, max_len=32)
        r1 = run_sync(sync, stream)
        for transport, latency in (("stream", 0.003), ("thread", 0.003),
                                   ("mock_remote", 0.003)):
            a = CollaborativeEngine(params, cfg, batch=B, max_len=32)
            ra = run_async(a, stream, transport=transport, latency_s=latency,
                           max_staleness=4)
            np.testing.assert_array_equal(ra["u"], r1["u"])
            np.testing.assert_array_equal(ra["triggered"], r1["triggered"])
            assert bool(np.all(ra["fhat"] <= ra["u"] + 1e-6))
            rep = ra["comms"]
            assert rep["bytes_sent"] == r1["comms"]["bytes_sent"]
            assert rep["bytes_sent"] <= rep["bytes_baseline"]
            per = rep["per_stream"]
            assert (per["bytes_sent"] <= per["bytes_baseline"]).all()
            assert rep["async"]["requests"] > 0
            assert rep["async"]["inflight_now"] == 0
            np.testing.assert_array_equal(a.server_pos, sync.server_pos)
            for x, y in zip(jax.tree.leaves(a.server.cache),
                            jax.tree.leaves(sync.server.cache)):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_staleness_bound_is_enforced(self):
        """No reply merges later than max_staleness steps after its
        trigger, and in pipelined mode none merges in-step (ages 1..k)."""
        cfg, params, stream = self._setup(batch=2, length=12)
        for k in (1, 3):
            a = CollaborativeEngine(params, cfg, batch=2, max_len=16)
            ages = []
            orig = a.comms.record_merge
            a.comms.record_merge = lambda m, age: (ages.append(age),
                                                   orig(m, age))
            run_async(a, stream, transport="inproc", max_staleness=k)
            assert ages, "must have merged something"
            assert all(1 <= g <= k for g in ages)

    def test_no_trigger_means_no_async_traffic(self):
        cfg, params, stream = self._setup(threshold=1e9)
        B = stream.shape[0]
        a = CollaborativeEngine(params, cfg, batch=B, max_len=32)
        ra = run_async(a, stream, transport="stream", max_staleness=4)
        assert ra["triggered"].sum() == 0
        assert ra["comms"]["bytes_sent"] == 0
        assert "async" not in ra["comms"], "no requests -> no async section"
        assert a.server.pos == 0, "server cache must stay cold"
        np.testing.assert_allclose(ra["fhat"], ra["u"])

"""Serving engine correctness + collaborative protocol accounting."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core import decomposition as deco
from repro.data import tokens as tok
from repro.serving.collaborative import CollaborativeEngine
from repro.serving.engine import ServeEngine

KEY = jax.random.PRNGKey(0)


class TestServeEngine:
    def test_prefill_matches_forward(self):
        """Cache-building prefill must reproduce the batched forward logits."""
        from repro.models import api as model_api
        cfg = registry.get_smoke("granite-8b")
        params = model_api.init_model(KEY, cfg)
        toks = next(tok.lm_batches(0, cfg, 2, 16))["tokens"]
        fwd = model_api.forward(params, cfg, {"tokens": jnp.asarray(toks)})
        eng = ServeEngine(params, cfg, batch=2, max_len=32)
        logits_last = eng.prefill(jnp.asarray(toks))
        np.testing.assert_allclose(np.asarray(logits_last),
                                   np.asarray(fwd["logits"][:, -1]),
                                   atol=2e-3, rtol=2e-3)

    def test_generate_is_deterministic_greedy(self):
        cfg = registry.get_smoke("xlstm-350m")
        from repro.models import api as model_api
        params = model_api.init_model(KEY, cfg)
        toks = jnp.asarray(next(tok.lm_batches(1, cfg, 2, 8))["tokens"])
        g1 = ServeEngine(params, cfg, 2, 32).generate(toks, 6)
        g2 = ServeEngine(params, cfg, 2, 32).generate(toks, 6)
        np.testing.assert_array_equal(g1, g2)


class TestCollaborativeEngine:
    def _engine(self, threshold):
        cfg = registry.get_smoke("granite-8b")
        cfg = cfg.replace(monitor=cfg.monitor.__class__(
            **{**cfg.monitor.__dict__, "threshold": threshold,
               "trigger_margin": 0.0}))
        params = deco.init_collab_lm(KEY, cfg)
        return cfg, params

    def test_no_trigger_means_no_server_traffic(self):
        cfg, params = self._engine(threshold=1e9)  # unreachable
        eng = CollaborativeEngine(params, cfg, batch=2, max_len=64)
        stream = next(tok.lm_batches(0, cfg, 2, 12))["tokens"]
        res = eng.run(stream)
        assert res["triggered"].sum() == 0
        assert res["comms"]["bytes_sent"] == 0
        assert eng.server.pos == 0, "server cache must stay cold"
        np.testing.assert_allclose(res["fhat"], res["u"])

    def test_always_trigger_matches_joint_model(self):
        """With threshold=-inf the engine must reproduce u - s*sigma(v) with
        the server fully caught up each step."""
        cfg, params = self._engine(threshold=-1e9)
        eng = CollaborativeEngine(params, cfg, batch=2, max_len=64)
        stream = next(tok.lm_batches(0, cfg, 2, 10))["tokens"]
        res = eng.run(stream)
        assert res["triggered"].all()
        assert eng.server.pos == 10
        assert res["comms"]["reduction_x"] <= 1.0 + 1e-6
        assert bool(np.all(res["fhat"] <= res["u"] + 1e-6))

    def test_comms_reduction_under_selective_trigger(self):
        cfg, params = self._engine(threshold=0.5)
        eng = CollaborativeEngine(params, cfg, batch=2, max_len=128)
        # deterministic mixed-trigger monitor head: u = tanh(10 * h[0])
        eng._u_head = jax.jit(lambda p, h: jnp.tanh(10.0 * h[..., 0]))
        stream = next(tok.lm_batches(3, cfg, 2, 40))["tokens"]
        res = eng.run(stream)
        trig_rate = res["triggered"].mean()
        assert 0.0 < trig_rate < 1.0, "stub must produce mixed triggering"
        assert res["comms"]["bytes_sent"] < res["comms"]["bytes_baseline"]
        assert res["comms"]["reduction_x"] > 1.0

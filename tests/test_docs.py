"""Documentation snippets must execute (the CI docs job, run in tier-1
too so a broken README never lands).  tools/check_docs.py executes every
fenced ```python block in README.md and docs/*.md headlessly."""
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))

import check_docs  # noqa: E402


def test_doc_files_exist():
    assert (ROOT / "README.md").exists()
    assert (ROOT / "docs" / "protocol.md").exists()


def test_docs_have_runnable_snippets():
    """The docs surface must contain executable examples, not just prose."""
    n_runnable = 0
    for path in check_docs.doc_files():
        for _, info, _ in check_docs.iter_blocks(path):
            if "no-run" not in info:
                n_runnable += 1
    assert n_runnable >= 2, "README + protocol.md must keep live snippets"


@pytest.mark.slow
def test_doc_snippets_execute():
    """Run the checker exactly as CI does (subprocess: fresh interpreter,
    no state leaking from the test session)."""
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_docs.py")],
        capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, \
        f"doc snippets failed:\n{proc.stdout}\n{proc.stderr}"

"""End-to-end behaviour: train the collaborative system on the paper's data
and verify the paper's three headline claims at small scale:
  1. FN = 0 with the Prop-2 calibrated offset,
  2. accuracy ~ complex model (Prop 1),
  3. communication reduced by selective triggering.
Also: a short LM-scale training run decreases all loss parts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.paper_synthetic import SMOKE as SYN
from repro.core import decomposition as deco, safety, theory
from repro.data import tokens as tok
from repro.data.synthetic import paper_synthetic, synthetic_residual
from repro.training.loop import train_collab_lm, train_paper

KEY = jax.random.PRNGKey(0)


class TestPaperPipelineEndToEnd:
    def test_calibrated_monitor_is_safe_and_accurate(self):
        rho, n_modes, n = SYN.rho, 24, 8
        x, f = paper_synthetic(0, 4096, rho=rho, n_modes=n_modes)
        # Prop-2 calibration: t = ||residual||_inf (sampled), s = 2t
        t = theory.t_of_n_sampled(
            lambda z: synthetic_residual(z, n, rho=rho, n_modes=n_modes), x)
        s = theory.s_rule(t)
        # small safety hinge: Prop-2 pins t, the hinge keeps the trained
        # a_i from drifting below f near events (FN -> 0 at 1500 steps)
        params, res = train_paper(KEY, SYN, x, f, u_mode="cosine",
                                  n_modes=n_modes, monitor_n=n, s=s,
                                  freeze_t=t, steps=1500, lr=5e-3,
                                  safety_weight=0.1)
        out = res["out"]
        fj = jnp.asarray(f)
        # claim 1: safety — FN rate 0 at eps=0.05 (paper Fig 2b)
        fn = float(safety.fn_rate(fj, out["u"], eps=0.05))
        assert fn < 0.005, f"FN rate {fn} must be ~0 under Prop-2 calibration"
        # claim 2: approximation error small (paper Fig 2a)
        l2 = float(safety.approx_error(fj, out["fhat"], 2.0))
        assert l2 < 0.35, f"combined model must approximate f, got L2={l2}"
        # u is a genuine upper envelope in the safety-relevant sense: the
        # trained coefficients drift from the true basis so pointwise
        # domination can fail off-threshold, but never near an event
        # (that's exactly what FN measures); violations stay minority+small
        viol, vmax = safety.safety_violation(fj, out["u"])
        assert float(viol) < 0.2
        assert float(vmax) < 2 * t

    def test_trigger_rate_matches_event_rate_order(self):
        """Monitoring only triggers around adverse regions -> comms savings."""
        rho, n_modes, n = SYN.rho, 24, 8
        x, f = paper_synthetic(1, 4096, rho=rho, n_modes=n_modes)
        t = theory.t_of_n_sampled(
            lambda z: synthetic_residual(z, n, rho=rho, n_modes=n_modes), x)
        params, res = train_paper(KEY, SYN, x, f, u_mode="cosine",
                                  n_modes=n_modes, monitor_n=n,
                                  s=theory.s_rule(t), freeze_t=t, steps=1200,
                                  lr=5e-3)
        u = np.asarray(res["out"]["u"])
        thr = np.quantile(f, 0.9)  # top-decile events
        trig = (u > thr).mean()
        event = (f > thr).mean()
        assert trig < 0.5, "monitor must not page the server for most inputs"
        assert trig >= event - 0.01, "every true event must trigger"


class TestLMTrainingEndToEnd:
    @pytest.mark.parametrize("arch", ["granite-8b", "zamba2-7b"])
    def test_losses_decrease(self, arch):
        cfg = registry.get_smoke(arch)
        batches = tok.lm_batches(0, cfg, batch=4, seq=32)
        _, hist = train_collab_lm(KEY, cfg, batches, steps=30, lr=1e-3,
                                  log_every=1, log_fn=lambda *_: None)
        first = np.mean([h["total"] for h in hist[:5]])
        last = np.mean([h["total"] for h in hist[-5:]])
        assert last < first, f"{arch}: loss must decrease ({first}->{last})"
        assert np.isfinite(last)
        # safety hinge specifically must be driven down
        s_first = np.mean([h["safety"] for h in hist[:5]])
        s_last = np.mean([h["safety"] for h in hist[-5:]])
        assert s_last <= s_first * 1.1

"""Dry-run machinery validated in a SUBPROCESS with 8 placeholder devices
(the main pytest process must keep the real single-device view).

Covers: lowering+compiling the collaborative train/serve steps of a smoke
config on a small (4 data x 2 model) mesh, and the paper's device-locality
guarantee — the monitor-only step's HLO contains NO model-axis collectives.
"""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.configs.base import ShapeConfig
from repro.core import decomposition as deco
from repro.distributed import sharding as shd
from repro.launch.dryrun import build_shardings
from repro.launch.steps import step_and_specs, make_monitor_step, EDGE_CACHE_LEN
from repro.models import api as model_api

mesh = jax.make_mesh((4, 2), ("data", "model"))
out = {}
for arch in ("granite-8b", "deepseek-v3-671b", "zamba2-7b"):
    cfg = registry.get_smoke(arch)
    for kind, shape in (("train", ShapeConfig("t", 64, 8, "train")),
                        ("decode", ShapeConfig("d", 64, 8, "decode"))):
        step, args = step_and_specs(cfg, shape)
        shards = build_shardings(args, cfg, shape, mesh)
        with mesh:
            c = jax.jit(step, in_shardings=shards).lower(*args).compile()
        out[f"{arch}/{kind}"] = "ok"

# monitor-step locality: lowered HLO must not touch the model axis
cfg = registry.get_smoke("granite-8b")
ecfg = deco.edge_arch(cfg)
params = jax.eval_shape(lambda: deco.init_collab_lm(jax.random.PRNGKey(0), cfg))
edge_cache = jax.eval_shape(lambda: model_api.init_cache(ecfg, 8, 64))
import jax.numpy as jnp
tokens = jax.ShapeDtypeStruct((8,), jnp.int32)
pos = jax.ShapeDtypeStruct((), jnp.int32)
mstep = make_monitor_step(cfg)
shards = (shd.param_shardings(params, mesh),
          shd.cache_shardings(edge_cache, mesh, 8, use_model=False),
          NamedSharding(mesh, P("data")), NamedSharding(mesh, P()))
with mesh:
    txt = jax.jit(mstep, in_shardings=shards).lower(
        params, edge_cache, tokens, pos).compile().as_text()
bad = []
for line in txt.splitlines():
    for op in ("all-reduce(", "all-gather(", "reduce-scatter(", "all-to-all("):
        if op in line and "replica_groups" in line:
            # model-axis groups have non-contiguous or stride-2 membership;
            # conservative: any collective at all is flagged except scalar
            # loss-style reductions over the data axis (size-4 groups of
            # stride 2 == data axis on this 4x2 mesh -> {0,2,4,6})
            bad.append(line.strip()[:160])
out["monitor_collectives"] = bad
print(json.dumps(out))
"""


@pytest.mark.slow
def test_dryrun_small_mesh(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stderr[-4000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    for k, v in out.items():
        if k != "monitor_collectives":
            assert v == "ok", (k, v)
    # the paper's locality requirement: the edge path runs without ANY
    # cross-device collective (its params and cache are replicated/batch-only)
    model_collectives = [l for l in out["monitor_collectives"]
                         if "{0,1}" in l or "{2,3}" in l or "{4,5}" in l]
    assert not model_collectives, model_collectives

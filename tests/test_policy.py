"""Adaptive triggering (serving/policy.py): per-stream online threshold
policies + the three-rung cascade.

The load-bearing guarantees:

* ``FixedPolicy`` is the regression anchor — bitwise-identical
  (u/fhat/trigger/comms) to a policy-free session on all four session
  paths, and bitwise vs ``run_scan``.
* ``fhat <= u`` survives ANY policy trajectory, adversarial included
  (hypothesis property) — thresholds only select when the server is
  consulted, never the corrector's sign.
* Threshold motion is DATA, not structure: zero new retraces under the
  recompile guard while a live policy moves every stream's tau.
* Controller state is per-tenant: attach gives a cold controller.
* ``SessionConfig`` refuses threshold+policy loudly (bugfix regression).
* The cascade runs over real wire transports with per-tier comms
  buckets and ``fhat <= u`` at every rung.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import SPAWN_DEADLINE_S  # noqa: F401  (parity with test_wire)
from repro.configs import registry
from repro.core import decomposition as deco
from repro.core.gating import CommsMeter
from repro.data import tokens as tok
from repro.serving import (BudgetPolicy, CascadeSession, FixedPolicy,
                           MonitorSession, QuantilePolicy, SessionConfig,
                           TransportSpec, TriggerPolicy)
from repro.serving.collaborative import CollaborativeEngine

KEY = jax.random.PRNGKey(0)


def _setup(threshold=0.1, batch=3, length=16):
    cfg = registry.get_smoke("granite-8b")
    cfg = cfg.replace(monitor=cfg.monitor.__class__(
        **{**cfg.monitor.__dict__, "threshold": threshold,
           "trigger_margin": 0.0}))
    params = deco.init_collab_lm(KEY, cfg)
    stream = next(tok.lm_batches(0, cfg, batch, length))["tokens"]
    return cfg, params, stream


_CACHE = {}


def _cached_setup():
    if "s" not in _CACHE:
        _CACHE["s"] = _setup()
    return _CACHE["s"]


def _engine(cfg, params, batch, max_len):
    return CollaborativeEngine(params, cfg, batch=batch, max_len=max_len)


def _comms_key(rep):
    return (rep["trigger_rate"], rep["bytes_sent"], rep["bytes_baseline"])


# -- config validation (bugfix regression) -----------------------------------

class TestConfigValidation:
    def test_threshold_plus_policy_refused(self):
        """The silent-ignore bug: combining an operating-point override
        with a policy must be a loud error naming BOTH fields."""
        with pytest.raises(ValueError) as ei:
            SessionConfig(policy=FixedPolicy(), threshold=0.25)
        msg = str(ei.value)
        assert "SessionConfig.threshold" in msg
        assert "SessionConfig.policy" in msg

    def test_margin_override_alone_still_works_with_policy(self):
        # trigger_margin is part of the calibrated floor the policy
        # binds to, not a competing trigger point — not refused
        SessionConfig(policy=FixedPolicy(), trigger_margin=None)

    def test_non_policy_object_refused(self):
        with pytest.raises(ValueError, match="TriggerPolicy"):
            SessionConfig(policy=object())


# -- FixedPolicy: the bitwise regression anchor ------------------------------

class TestFixedPolicyBitwise:
    def test_sync_scan_async_thread_identical(self):
        """All four session paths: a FixedPolicy session is bitwise
        (u/fhat/trigger/comms) vs the policy-free session, and sync
        stays bitwise vs run_scan on u/trigger."""
        cfg, params, stream = _cached_setup()
        B, S = stream.shape[:2]

        def run(mk_config):
            eng = _engine(cfg, params, B, S)
            r = eng.session(mk_config).run(stream)
            return {k: np.asarray(r[k]) for k in ("u", "fhat", "triggered")}, \
                _comms_key(eng.comms.report())

        paths = [
            ("sync", lambda p: SessionConfig(mode="sync", policy=p)),
            ("scan", lambda p: SessionConfig(mode="scan", policy=p)),
            ("async", lambda p: SessionConfig(
                mode="async", transport="stream", max_staleness=2, policy=p)),
            ("sync_thread", lambda p: SessionConfig(
                mode="sync", transport="thread", policy=p)),
        ]
        results = {}
        for name, mk in paths:
            base, comms_base = run(mk(None))
            fixed, comms_fixed = run(mk(FixedPolicy()))
            for k in ("u", "fhat", "triggered"):
                assert np.array_equal(base[k], fixed[k]), (name, k)
            if name != "scan":  # scan derives comms from the trace
                assert comms_base == comms_fixed, name
            results[name] = fixed
        # and across paths: u/trigger identical everywhere (fhat matches
        # exactly between the online paths; scan is allclose — the
        # compacted corrector sums in a different order)
        for name in ("scan", "async", "sync_thread"):
            assert np.array_equal(results["sync"]["u"], results[name]["u"])
            assert np.array_equal(results["sync"]["triggered"],
                                  results[name]["triggered"])
        np.testing.assert_allclose(results["sync"]["fhat"],
                                   results["scan"]["fhat"], atol=1e-6)
        assert np.array_equal(results["sync"]["fhat"],
                              results["sync_thread"]["fhat"])

    def test_zero_retraces_under_moving_policy(self):
        """Thresholds are data: a QuantilePolicy moving every stream's
        tau causes zero recompiles after warmup."""
        cfg, params, stream = _cached_setup()
        B, S = stream.shape[:2]
        eng = _engine(cfg, params, B, S)
        pol = QuantilePolicy(0.3, window=4, min_samples=2)
        with eng.session(SessionConfig(mode="sync", policy=pol)) as sess:
            for t in range(4):
                sess.step(stream[:, t])
            guard = sess.arm_recompile_guard()
            for t in range(4, S):
                sess.step(stream[:, t])
            guard.assert_stable()
        # the policy did actually move thresholds (the guard guarded
        # something real)
        assert (pol.state()["tau"] != pol.state()["tau0"]).any()


# -- safety property: fhat <= u under ANY trajectory -------------------------

class _AdversarialPolicy(TriggerPolicy):
    """Sets arbitrary per-stream thresholds each step from a seeded RNG
    — including below the floor (the base class clamps) and wild swings
    — to model a runaway controller."""

    name = "adversarial"

    def __init__(self, seed, lo=-2.0, hi=2.0):
        self._rng = np.random.default_rng(seed)
        self._lo, self._hi = lo, hi

    def _update(self, u, fhat, triggered, active, meter):
        self._tau[:] = self._rng.uniform(
            self._lo, self._hi, self._batch).astype(np.float32)


class TestSafetyProperty:
    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           kind=st.sampled_from(["adversarial", "quantile", "budget"]))
    def test_fhat_bounded_by_u_any_trajectory(self, seed, kind):
        """Random margin streams x {adversarial, quantile, budget}
        trajectories: fhat <= u at every step (sign-constrained
        corrections are threshold-independent)."""
        cfg, params, _ = _cached_setup()
        rng = np.random.default_rng(seed)
        B, S = 3, 10
        stream = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
        pol = {"adversarial": lambda: _AdversarialPolicy(seed),
               "quantile": lambda: QuantilePolicy(0.5, window=3,
                                                  min_samples=1),
               "budget": lambda: BudgetPolicy(0.2, fn_budget=0.3, window=4,
                                              min_evidence=1)}[kind]()
        eng = _engine(cfg, params, B, S)
        with eng.session(SessionConfig(mode="sync", policy=pol)) as sess:
            for t in range(S):
                r = sess.step(stream[:, t])
                assert (r["fhat"] <= r["u"]).all(), (kind, t)

    def test_floor_is_enforced(self):
        """Policies may only RAISE above the calibrated floor: even an
        adversarial subclass writing tau below tau0 is clamped."""
        pol = _AdversarialPolicy(0, lo=-100.0, hi=-50.0)
        pol.bind(threshold=0.1, margin=0.0, batch=4)
        pol.update(np.zeros(4), np.zeros(4), np.zeros(4, bool),
                   np.ones(4, bool))
        assert (pol.step_thresholds() >= np.float32(0.1)).all()


# -- controllers -------------------------------------------------------------

class TestQuantilePolicy:
    def test_tracks_per_stream_quantile(self):
        pol = QuantilePolicy(0.25, window=8, min_samples=4)
        pol.bind(threshold=0.0, margin=0.0, batch=2)
        rng = np.random.default_rng(0)
        u0 = rng.normal(2.0, 0.1, 16)   # stream 0: hot
        u1 = rng.normal(-1.0, 0.1, 16)  # stream 1: cold (below floor)
        for a, b in zip(u0, u1):
            pol.update(np.asarray([a, b], np.float32),
                       np.asarray([a, b], np.float32),
                       np.zeros(2, bool), np.ones(2, bool))
        tau = pol.step_thresholds()
        assert abs(tau[0] - np.quantile(u0[-8:].astype(np.float32), 0.75)) < 0.2
        assert tau[1] == np.float32(0.0)  # floored at tau0

    def test_cold_stream_sits_at_floor(self):
        pol = QuantilePolicy(0.25, window=8, min_samples=6)
        pol.bind(threshold=0.5, margin=0.1, batch=1)
        for _ in range(5):  # below min_samples
            pol.update(np.asarray([3.0]), np.asarray([3.0]),
                       np.zeros(1, bool), np.ones(1, bool))
        assert pol.step_thresholds()[0] == np.float32(0.5 - 0.1)


class TestBudgetPolicy:
    def _drive(self, pol, n, *, u=2.0, trig=True, fhat=None):
        """n identical steps on a 1-stream policy with a live meter."""
        meter = CommsMeter(bytes_per_request=8, n_streams=1, rate_window=8)
        for _ in range(n):
            t = np.asarray([trig])
            meter.update_per_stream(t.astype(np.int64), np.ones(1, np.int64))
            pol.update(np.asarray([u], np.float32),
                       np.asarray([fhat if fhat is not None else u - 1.0],
                                  np.float32), t, np.ones(1, bool), meter)
        return pol.step_thresholds()[0]

    def test_raises_when_over_rate_with_healthy_margins(self):
        pol = BudgetPolicy(0.1, fn_budget=0.9, window=8, min_evidence=2)
        pol.bind(threshold=0.0, margin=0.0, batch=1)
        # every step triggers (rate 1.0 > 0.1) and comes back with a
        # healthy margin (fhat = 1.0 < ... gamma=0 -> margin -1? no:
        # gamma - fhat = 0 - (-1) = 1 with fhat=-1)
        tau = self._drive(pol, 12, u=2.0, trig=True, fhat=-1.0)
        assert tau > np.float32(0.0)

    def test_thin_evidence_decays_to_floor(self):
        pol = BudgetPolicy(0.1, fn_budget=0.9, window=8, min_evidence=4)
        pol.bind(threshold=0.0, margin=0.0, batch=1)
        # raise first with margins in the window
        self._drive(pol, 12, u=2.0, trig=True, fhat=-1.0)
        # then a fresh tenant: reset wipes the evidence -> tau pinned
        # at the floor no matter what u does untriggered
        pol.reset_stream(0)
        tau = self._drive(pol, 12, u=2.0, trig=False)
        assert tau == np.float32(0.0)

    def test_blown_skip_budget_decays(self):
        pol = BudgetPolicy(0.1, fn_budget=0.2, window=8, min_evidence=2,
                           step=1.0)
        pol.bind(threshold=0.0, margin=0.0, batch=1)
        self._drive(pol, 8, u=2.0, trig=True, fhat=-1.0)
        raised = pol.step_thresholds()[0]
        assert raised > np.float32(0.0)
        # now every candidate is skipped: windowed skip rate -> 1.0 >
        # fn_budget -> multiplicative decay toward the floor
        tau = self._drive(pol, 8, u=2.0, trig=False)
        assert tau < raised

    def test_conservative_motion_is_monotone_decay(self):
        pol = BudgetPolicy(0.1, fn_budget=0.2, window=8, min_evidence=2,
                           decay=0.5, step=1.0)
        pol.bind(threshold=0.0, margin=0.0, batch=1)
        self._drive(pol, 8, u=2.0, trig=True, fhat=-1.0)
        taus = [pol.step_thresholds()[0]]
        for _ in range(6):
            self._drive(pol, 1, u=2.0, trig=False)
            taus.append(pol.step_thresholds()[0])
        diffs = np.diff(np.asarray(taus, np.float64))
        assert (diffs <= 0).all()          # only toward the floor
        assert (np.asarray(taus) >= 0).all()  # never below it


# -- cascade -----------------------------------------------------------------

def _uds_path(tag):
    import os
    import tempfile
    return os.path.join(tempfile.mkdtemp(prefix=f"policy_{tag}_"), "s.sock")


@pytest.fixture(scope="module")
def two_wire_servers():
    """TWO in-thread correction servers on their own Unix sockets: the
    regional (tier-1) and central (tier-2) rungs of the cascade."""
    from repro.serving.server import CorrectionServer
    cfg, params, _ = _cached_setup()
    servers, stops, threads, addrs = [], [], [], []
    for tag in ("regional", "central"):
        uds = _uds_path(tag)
        srv = CorrectionServer(cfg, params, slots=8, max_len=32, uds=uds)
        stop = threading.Event()
        th = threading.Thread(target=srv.serve_forever,
                              kwargs=dict(stop=stop), daemon=True)
        th.start()
        servers.append(srv); stops.append(stop)
        threads.append(th); addrs.append(uds)
    yield cfg, params, addrs
    for stop in stops:
        stop.set()
    for th, srv in zip(threads, servers):
        th.join(timeout=10)
        srv.close()


class TestCascade:
    def _mk(self, cfg, params, stream, *, esc=0.05, escalation=None,
            transports=(None, None)):
        B, S = stream.shape[:2]

        def tier(transport):
            eng = _engine(cfg, params, B, S)
            if transport is None:
                return eng.session(SessionConfig(mode="sync"))
            return eng.session(SessionConfig(
                mode="sync",
                transport=TransportSpec("wire", address=transport)))
        return CascadeSession(tier(transports[0]), tier(transports[1]),
                              escalate_above=esc, escalation=escalation)

    def test_three_rungs_inproc(self):
        """Edge -> regional -> central: escalated rows take the tighter
        corrected score, per-tier buckets account separately, fhat <= u
        at every rung (asserted inside step; re-checked on the stack)."""
        cfg, params, stream = _cached_setup()
        casc = self._mk(cfg, params, stream)
        out = casc.run(stream)
        assert (out["fhat"] <= out["u"]).all()
        assert (out["fhat_tier1"] <= out["u"]).all()
        assert (out["fhat_tier2"] <= out["u"]).all()
        # escalated rows carry the min of the two corrected scores
        esc = out["escalated"]
        assert esc.any()
        merged = np.where(esc, np.minimum(out["fhat_tier1"],
                                          out["fhat_tier2"]),
                          out["fhat_tier1"])
        assert np.array_equal(out["fhat"], merged)
        rep = out["comms"]
        assert rep["tier1"]["bytes_sent"] > 0
        assert rep["escalated_steps"] == int(esc.sum())
        # hop 2 re-ships from the client-held history: real charges in
        # the tier2 bucket, distinct from tier1's
        assert rep["tier2"]["bytes_sent"] > 0

    def test_no_escalation_when_residual_clears(self):
        """An escalation threshold above every residual: tier 2 is never
        consulted and its bucket stays empty."""
        cfg, params, stream = _cached_setup()
        casc = self._mk(cfg, params, stream, esc=1e9)
        out = casc.run(stream)
        assert not out["escalated"].any()
        assert out["comms"]["tier2"]["bytes_sent"] == 0
        assert np.array_equal(out["fhat"], out["fhat_tier1"])

    def test_membership_is_fixed(self):
        cfg, params, stream = _cached_setup()
        casc = self._mk(cfg, params, stream)
        with pytest.raises(RuntimeError, match="fixed"):
            casc.attach("x")
        casc.close()

    def test_tier2_policy_refused(self):
        cfg, params, stream = _cached_setup()
        B, S = stream.shape[:2]
        t1 = _engine(cfg, params, B, S).session(SessionConfig(mode="sync"))
        t2 = _engine(cfg, params, B, S).session(
            SessionConfig(mode="sync", policy=FixedPolicy()))
        with pytest.raises(ValueError, match="cascade drives"):
            CascadeSession(t1, t2, escalate_above=0.0)

    def test_cascade_over_wire_subprocess_boundary(self, two_wire_servers):
        """Acceptance: the three-rung cascade end-to-end over REAL wire
        transports — both hops cross sockets to their own correction
        server, each metered in its own tier bucket, fhat <= u at every
        rung.  The escalation policy here is a QuantilePolicy on the
        tier-1 residual (the regional tier's margin drives its own
        escalation budget)."""
        cfg, params, addrs = two_wire_servers
        _, _, stream = _cached_setup()
        esc_pol = QuantilePolicy(0.5, window=6, min_samples=3)
        casc = self._mk(cfg, params, stream, esc=0.05, escalation=esc_pol,
                        transports=tuple(addrs))
        out = casc.run(stream)
        assert (out["fhat"] <= out["u"]).all()
        assert (out["fhat_tier1"] <= out["u"]).all()
        assert (out["fhat_tier2"] <= out["u"]).all()
        assert out["escalated"].any()
        rep = out["comms"]
        # both hops really crossed their own socket
        assert rep["tier1"]["wire"]["tx_bytes"] > 0
        assert rep["tier2"]["wire"]["tx_bytes"] > 0
        assert rep["tier1"]["bytes_sent"] > 0
        assert rep["tier2"]["bytes_sent"] > 0

"""Same-host shared-memory transport (``serving/shm.py``): ring codec
properties (wrap straddling, backpressure, torn writes), the arena
handshake + lifecycle (unlink-after-mmap crash safety), end-to-end
bitwise identity over ``TransportSpec("shm", ...)``, the server's
gathered reply flush (wire micro-batching), and failover-by-replay out
of a dead shm session onto a plain-wire fleet sibling."""
import dataclasses
import os
import socket
import struct
import tempfile
import threading
import time

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from _chaos import torn_ring_write
from repro.configs.paper_synthetic import SERVING
from repro.core import decomposition as deco
from repro.data import tokens as tok
from repro.serving import SessionConfig, TransportSpec, shm, wire
from repro.serving.collaborative import CollaborativeEngine
from repro.serving.server import CorrectionServer

KEY = jax.random.PRNGKey(0)


def _cfg(threshold=0.1):
    return SERVING.replace(monitor=SERVING.monitor.__class__(
        **{**SERVING.monitor.__dict__, "threshold": threshold,
           "trigger_margin": 0.0}))


def _uds_path(tag):
    return os.path.join(tempfile.mkdtemp(prefix=f"shm_{tag}_"), "s.sock")


def _ring_pair(size):
    """A writer/reader pair over one in-memory ring (no mmap needed:
    the ring layer only asks for a writable buffer)."""
    buf = bytearray(wire.RING_HDR + size)
    return wire.RingWriter(buf, 0, size), wire.RingReader(buf, 0, size)


# -- the byte rings ----------------------------------------------------------

class TestRings:
    @settings(max_examples=40, deadline=None)
    @given(size=st.integers(min_value=32, max_value=257),
           sizes=st.lists(st.integers(min_value=0, max_value=300),
                          min_size=1, max_size=12),
           seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_framed_round_trip_with_wrap(self, size, sizes, seed):
        """Any schedule of frames — including frames bigger than the
        ring and frames straddling the wrap point — survives a
        write-what-fits / drain loop bit-exactly, because the rings
        carry stream semantics and ``FrameReader`` owns reassembly."""
        rng = np.random.default_rng(seed)
        w, r = _ring_pair(size)
        frames = [rng.integers(0, 256, n, dtype=np.uint8).tobytes()
                  for n in sizes]
        got = []
        for payload in frames:
            buf = wire.frame(payload)
            done = 0
            while done < len(buf):
                n = w.write(buf[done:])
                if n == 0:
                    got.extend(r.frames())   # full: drain, then resume
                    assert w.free() > 0, "drain must free space"
                done += n
        got.extend(r.frames())
        assert got == frames
        assert r.available() == 0 and w.free() == size

    def test_ring_full_returns_zero_never_corrupts(self):
        w, r = _ring_pair(64)
        payload = bytes(range(60))
        assert w.write(payload) == 60
        assert w.write(b"x" * 10) == 4      # partial: only what fits
        assert w.write(b"y") == 0           # full: refused, not clobbered
        assert r.read(60) == payload
        assert r.read() == b"x" * 4

    def test_torn_ring_write_yields_nothing_and_never_raises(self):
        """The shm mirror of the torn-frame chaos case: a producer that
        died after publishing part of a frame leaves the consumer
        holding a partial frame forever — no yield, no corruption, no
        exception.  Death is detected on the control socket, not here."""
        w, r = _ring_pair(1 << 12)
        n = torn_ring_write(w, b"z" * 600)
        assert 0 < n < 604                  # 4-byte length prefix + body
        assert r.frames() == []
        assert r.frames() == []             # idempotent on a cut stream
        assert r.available() == 0           # all torn bytes consumed...
        # ...and a resumed stream (same producer back up mid-write is
        # impossible, but the READER must not have lost sync state)
        assert r.reader.feed(b"") == []

    def test_oversize_frame_rejected_by_reader(self):
        w, r = _ring_pair(64)
        bad = struct.pack("<I", wire.MAX_FRAME_BYTES + 1) + b"\x00" * 10
        w.write(bad)
        with pytest.raises(wire.WireError, match="frame"):
            r.frames()


class TestDoorbellBackpressure:
    def test_blocked_writer_resumes_on_consumer_progress(self):
        """A real arena + doorbells: the producer blocks when the ring
        fills and resumes as the consumer frees space — every byte
        arrives intact, nothing is dropped or reordered."""
        arena = shm.ServerArena.create(1 << 10)
        fds = [os.dup(fd) for fd in arena.fds()]
        client = shm.attach(fds, 1 << 10, arena.db_kind)
        arena.sent()                        # fd closed + path unlinked
        server = arena.peer
        total = 64 * 1024
        rng = np.random.default_rng(0)
        blob = rng.integers(0, 256, total, dtype=np.uint8).tobytes()
        got = bytearray()

        def produce():
            mv = memoryview(blob)
            off = 0
            while off < len(mv):
                off += client.send_all(mv[off:off + 4096], timeout=30.0)

        t = threading.Thread(target=produce, daemon=True)
        t.start()
        deadline = time.monotonic() + 30
        while len(got) < total:
            assert time.monotonic() < deadline, "consumer starved"
            data = server.reader.read()
            if data:
                server.db_peer.ring()       # space freed: wake producer
                got.extend(data)
            else:
                server.db_own.drain()
                if not server.reader.available():
                    time.sleep(0.001)
        t.join(timeout=10)
        assert not t.is_alive()
        assert bytes(got) == blob
        client.close()
        arena.close()
        assert shm.stray_arenas() == []


# -- handshake codec (protocol v5 tails) -------------------------------------

class TestHandshakeCodec:
    def test_hello_shm_flag_presence_detected(self):
        for flag in (False, True):
            buf = wire.encode_hello(wire.Hello(4, 16, shm=flag))
            (p,) = wire.FrameReader().feed(buf)
            msg = wire.decode(p)
            assert msg.shm is flag
        # a v3/v4-shaped HELLO (no tail byte) decodes as shm=False
        assert wire.decode(
            wire.FrameReader().feed(wire.encode_hello(
                wire.Hello(4, 16)))[0]).shm is False

    def test_hello_ack_shm_tail_round_trip(self):
        ack = wire.HelloAck(7, 3, 64, shm_path="/dev/shm/repro-shm-x",
                            ring_bytes=1 << 20, db_kind=shm.DB_PIPE)
        (p,) = wire.FrameReader().feed(wire.encode_hello_ack(ack))
        got = wire.decode(p)
        assert got == ack
        plain = wire.HelloAck(7, 3, 64)
        (p,) = wire.FrameReader().feed(wire.encode_hello_ack(plain))
        got = wire.decode(p)
        assert got.ring_bytes == 0 and got.shm_path == ""

    def test_shm_open_round_trip(self):
        for ok in (False, True):
            (p,) = wire.FrameReader().feed(wire.encode_shm_open(ok))
            msg = wire.decode(p)
            assert isinstance(msg, wire.ShmOpen) and msg.ok is ok

    def test_shm_address_prefix_parses(self):
        fam, target = wire.parse_address("shm:/tmp/x.sock")
        assert fam == socket.AF_UNIX and target == "/tmp/x.sock"


# -- end-to-end over an in-thread shm server ---------------------------------

@pytest.fixture(scope="module")
def shm_server():
    cfg = _cfg()
    params = deco.init_collab_lm(KEY, cfg)
    uds = _uds_path("srv")
    srv = CorrectionServer(cfg, params, slots=8, max_len=32, uds=uds,
                           shm=True)
    stop = threading.Event()
    th = threading.Thread(target=srv.serve_forever,
                          kwargs=dict(stop=stop), daemon=True)
    th.start()
    yield cfg, params, uds, srv
    stop.set()
    th.join(timeout=10)
    srv.close()


def _run(eng, stream, *, address, max_staleness, kind="shm"):
    cfg = SessionConfig(mode="async", max_staleness=max_staleness,
                        transport=TransportSpec(kind, address=address))
    with eng.session(cfg) as s:
        return s.run(stream)


class TestShmLoopback:
    def test_strict_sync_bitwise_and_bytes_in_shm_bucket(self, shm_server):
        """Acceptance: max_staleness=0 over the rings reproduces the
        protocol — u/trigger bit-identical to run_scan, fhat matching
        the in-process sync engine — with the data plane's bytes and
        RTTs in ``comms["shm"]`` and only handshake/control on the
        socket."""
        cfg, params, uds, srv = shm_server
        stream = next(tok.lm_batches(0, cfg, 3, 16))["tokens"]
        scan = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        rs = scan.session(SessionConfig(mode="scan")).run(stream)
        sync = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        r1 = sync.session(SessionConfig()).run(stream)
        a = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        r0 = _run(a, stream, address=uds, max_staleness=0)
        assert 0.0 < r0["triggered"].mean() < 1.0, "need mixed triggers"
        np.testing.assert_array_equal(r0["u"], rs["u"])
        np.testing.assert_array_equal(r0["triggered"], rs["triggered"])
        np.testing.assert_allclose(r0["fhat"], r1["fhat"], atol=1e-6)
        np.testing.assert_array_equal(a.server_pos, sync.server_pos)
        rep = r0["comms"]
        assert rep["bytes_sent"] == r1["comms"]["bytes_sent"]
        s = rep["shm"]
        assert s["replies"] == rep["async"]["requests"] > 0
        assert s["tx_bytes"] > 0 and s["rx_bytes"] > 0
        assert s["rtt_mean_s"] > 0.0
        # control plane: a handful of handshake bytes, zero replies
        w = rep.get("wire")
        if w is not None:
            assert w["replies"] == 0
            assert w["tx_bytes"] < s["tx_bytes"]

    def test_pipelined_fhat_safe_and_no_stray_arenas(self, shm_server):
        cfg, params, uds, srv = shm_server
        stream = next(tok.lm_batches(0, cfg, 3, 16))["tokens"]
        scan = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        rs = scan.session(SessionConfig(mode="scan")).run(stream)
        a = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        ra = _run(a, stream, address=uds, max_staleness=4)
        np.testing.assert_array_equal(ra["u"], rs["u"])
        np.testing.assert_array_equal(ra["triggered"], rs["triggered"])
        assert bool(np.all(ra["fhat"] <= ra["u"] + 1e-6))
        assert ra["comms"]["shm"]["replies"] > 0
        assert shm.stray_arenas() == [], \
            "arena files must be unlinked as soon as both sides mmap"

    def test_wire_client_against_shm_server_stays_plain(self, shm_server):
        """A v5 wire client that doesn't ask for shm gets a plain
        session from an shm-enabled server (the offer is HELLO-gated)."""
        cfg, params, uds, srv = shm_server
        stream = next(tok.lm_batches(0, cfg, 3, 16))["tokens"]
        a = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        r = _run(a, stream, address=uds, max_staleness=0, kind="wire")
        assert "shm" not in r["comms"]
        assert r["comms"]["wire"]["replies"] > 0

    def test_shm_client_against_wire_server_falls_back(self):
        """A plain server offers no arena: the shm transport degrades to
        pure wire with a recorded reason — never an error."""
        cfg = _cfg()
        params = deco.init_collab_lm(KEY, cfg)
        uds = _uds_path("fallback")
        srv = CorrectionServer(cfg, params, slots=4, max_len=32, uds=uds)
        stop = threading.Event()
        th = threading.Thread(target=srv.serve_forever,
                              kwargs=dict(stop=stop), daemon=True)
        th.start()
        try:
            from repro.serving import async_rpc
            stream = next(tok.lm_batches(0, cfg, 3, 16))["tokens"]
            eng = CollaborativeEngine(params, cfg, batch=3, max_len=32)
            scfg = SessionConfig(mode="async", max_staleness=0,
                                 transport=TransportSpec("shm", address=uds))
            with eng.session(scfg) as s:
                out = [s.step(stream[:, i]) for i in range(4)]
                worker = eng._worker
                assert isinstance(worker, async_rpc.ShmWorker)
                assert worker._peer is None
                assert "no shm arena" in worker.fallback_reason
                rep = s.report()
            assert len(out) == 4
            assert "shm" not in rep and rep["wire"]["replies"] > 0
        finally:
            stop.set()
            th.join(timeout=10)
            srv.close()

    def test_declined_shm_open_keeps_session_on_wire(self, shm_server):
        """A client that cannot attach answers SHM_OPEN(ok=0): the
        server tears the arena down and serves the session pure-wire."""
        cfg, params, uds, srv = shm_server
        base_sessions = srv.stats["shm_sessions"]
        sock = wire.connect(uds, timeout=10)
        try:
            sock.settimeout(10.0)
            sock.sendall(wire.encode_hello(
                wire.Hello(batch=1, max_len=16, shm=True)))
            fds = []
            reader = wire.FrameReader()
            payloads = []
            while not payloads:
                data, new_fds, flags, _ = socket.recv_fds(sock, 1 << 16, 8)
                assert data, "server closed during handshake"
                fds.extend(new_fds)
                payloads = reader.feed(data)
            ack = wire.decode(payloads[0])
            assert isinstance(ack, wire.HelloAck)
            assert ack.ring_bytes > 0 and len(fds) >= 2
            for fd in fds:
                os.close(fd)                # simulate a failed attach
            sock.sendall(wire.encode_shm_open(False))
            # the session must still answer a plain wire request
            hist = np.zeros((1, 16), np.int32)
            sock.sendall(wire.encode_request(
                1, 0, np.array([True]), np.zeros(1, np.int32),
                np.zeros(1, np.float32), hist))
            msgs = []
            while not msgs:
                data = sock.recv(1 << 16)
                assert data, "server dropped a declined-shm session"
                msgs = [wire.decode(p) for p in reader.feed(data)]
            assert isinstance(msgs[0], wire.WireReply)
            assert srv.stats["shm_sessions"] == base_sessions
        finally:
            sock.close()
        deadline = time.monotonic() + 10
        while shm.stray_arenas() and time.monotonic() < deadline:
            time.sleep(0.01)
        assert shm.stray_arenas() == []


# -- the gathered reply flush (wire micro-batching) --------------------------

class TestReplyFlushBatching:
    def test_multi_reply_tick_is_one_sendmsg(self):
        """Regression for the per-reply ``send()`` flush: three queued
        requests answered in one tick must leave in ONE gathered
        sendmsg — ``tx_flushes`` (a syscall counter) grows by exactly 1
        while three REPLY frames arrive."""
        cfg = _cfg()
        params = deco.init_collab_lm(KEY, cfg)
        srv = CorrectionServer(cfg, params, slots=2, max_len=16,
                               uds=_uds_path("flush"))
        try:
            sock = wire.connect(srv.address, timeout=5)
            sock.sendall(wire.encode_hello(wire.Hello(batch=2, max_len=16)))
            reader = wire.FrameReader()
            msgs = self._collect(srv, sock, 1, reader)
            assert isinstance(msgs[0], wire.HelloAck)
            rng = np.random.default_rng(0)
            hist = rng.integers(0, 255, (2, 16)).astype(np.int32)
            trig = np.array([True, False])
            u = np.zeros(2, np.float32)
            # three requests land BEFORE the server ticks: they join one
            # replay group and their replies queue in the same tick
            for rid, t in ((1, 0), (2, 1), (3, 2)):
                sock.sendall(wire.encode_request(
                    rid, t, trig, np.zeros(2, np.int32), u, hist))
            flushes0 = srv.stats["tx_flushes"]
            msgs = self._collect(srv, sock, 3, reader)
            assert [m.req_id for m in msgs] == [1, 2, 3]
            assert all(isinstance(m, wire.WireReply) for m in msgs)
            assert srv.stats["tx_flushes"] == flushes0 + 1, \
                "3 same-tick replies must leave in one gathered sendmsg"
            sock.close()
        finally:
            srv.close()

    @staticmethod
    def _collect(srv, sock, n, reader):
        sock.settimeout(0.0)
        msgs = []
        deadline = time.monotonic() + 30
        while len(msgs) < n:
            srv.serve_tick(0.001)
            try:
                data = sock.recv(1 << 16)
            except (BlockingIOError, socket.timeout):
                continue
            assert data, "server closed"
            msgs.extend(wire.decode(p) for p in reader.feed(data))
            assert time.monotonic() < deadline
        return msgs


# -- lifecycle: kill an shm session mid-flight -------------------------------

class TestArenaLifecycle:
    def test_kill_mid_flight_leaves_no_arena_and_raises_peer_gone(self):
        """SIGKILL emulation on a live shm session: sever the sockets
        without ceremony.  A direct (non-fleet) client must surface a
        WireError, and no arena file may survive — the unlink-after-mmap
        discipline means there is nothing to leak."""
        cfg = _cfg()
        params = deco.init_collab_lm(KEY, cfg)
        uds = _uds_path("kill")
        srv = CorrectionServer(cfg, params, slots=4, max_len=32, uds=uds,
                               shm=True)
        stop = threading.Event()
        th = threading.Thread(target=srv.serve_forever,
                              kwargs=dict(stop=stop), daemon=True)
        th.start()
        stream = next(tok.lm_batches(0, cfg, 3, 16))["tokens"]
        eng = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        scfg = SessionConfig(mode="async", max_staleness=4,
                             transport=TransportSpec("shm", address=uds))
        try:
            with pytest.raises(wire.WireError):
                with eng.session(scfg) as s:
                    for i in range(stream.shape[1]):
                        s.step(stream[:, i])
                        if i == 6:
                            # crash: no BYE, no GOAWAY, no flush
                            stop.set()
                            th.join(timeout=10)
                            for sess in list(srv._sessions.values()):
                                try:
                                    sess.conn.shutdown(socket.SHUT_RDWR)
                                except OSError:
                                    pass
                            srv.close()
        finally:
            stop.set()
            th.join(timeout=10)
            srv.close()
        assert shm.stray_arenas() == [], \
            "a SIGKILLed shm session must not leak arena files"

    def test_fleet_failover_from_shm_onto_wire_sibling(self):
        """Failover-by-replay OUT of an shm session: kill the shm
        server mid-flight; the worker re-HELLOs through the router onto
        a sibling that offers no arena and finishes the trace pure-wire
        — bitwise identical to an uninterrupted scan, with the recovery
        audited in the failover bucket and no arena files left."""
        from test_fleet import fleet, run_session, victim_of, wait_live

        cfg = _cfg()
        params = deco.init_collab_lm(KEY, cfg)
        stream = next(tok.lm_batches(0, cfg, 4, 24))["tokens"]
        with fleet(cfg, params, n=2, shm=True) as sup:
            wait_live(sup, 2)
            ref, ref_rep, _ = run_session(sup, params, cfg, stream,
                                          staleness=4, kind="shm")
            assert ref_rep["shm"]["replies"] > 0
            # heterogeneous failover target: the sibling goes wire-only
            survivors_made_plain = threading.Event()

            def arm(sup_, eng, s):
                victim = victim_of(sup_, eng)
                for h in sup_.servers.values():
                    if h is not victim:
                        h.srv.shm = False   # sibling stops offering shm
                survivors_made_plain.set()
                victim.kill()

            res, rep, eng = run_session(
                sup, params, cfg, stream, staleness=4,
                kind="shm", at={10: arm})
            assert survivors_made_plain.is_set()
        np.testing.assert_array_equal(res["u"], ref["u"])
        np.testing.assert_array_equal(res["triggered"], ref["triggered"])
        assert bool(np.all(res["fhat"] <= res["u"] + 1e-6))
        assert rep["failover"]["failovers"] >= 1
        assert rep["shm"]["replies"] > 0, "pre-kill traffic rode the rings"
        # post-failover traffic rode the sibling's plain wire: the wire
        # bucket carried real replies this run
        assert rep["wire"]["replies"] > 0
        assert shm.stray_arenas() == []

"""Core decomposition invariants (paper Eq. 1) at both scales."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import registry
from repro.configs.base import ShapeConfig
from repro.configs.paper_synthetic import SMOKE as SYN
from repro.core import decomposition as deco
from repro.models import api as model_api

KEY = jax.random.PRNGKey(0)


class TestSigma:
    @given(st.lists(st.floats(-6, 6), min_size=1, max_size=64))
    @settings(max_examples=30, deadline=None)
    def test_range_and_inverse(self, xs):
        x = jnp.asarray(xs, jnp.float32)
        for kind in ("sigmoid", "tanh01"):
            y = deco.sigma(x, kind)
            assert bool(jnp.all((y > 0) & (y < 1)))
            x2 = deco.sigma_inv(y, kind)
            np.testing.assert_allclose(x2, x, atol=1e-2)

    def test_extreme_inputs_stay_in_closed_unit_interval(self):
        x = jnp.asarray([-1e4, -30.0, 30.0, 1e4], jnp.float32)
        for kind in ("sigmoid", "tanh01"):
            y = deco.sigma(x, kind)
            # f32 rounds the open interval shut at the extremes; the
            # corrector stays bounded either way (s * y <= s)
            assert bool(jnp.all((y >= 0) & (y <= 1)))
            assert bool(jnp.all(jnp.isfinite(deco.sigma_inv(y, kind))))


class TestStructuralSafety:
    """u >= fhat ALWAYS (corr > 0 by construction), any params, any mode."""

    @pytest.mark.parametrize("u_mode,kw", [("cosine", {"n_modes": 24}),
                                           ("truncated", {}),
                                           ("independent", {})])
    def test_u_dominates_fhat(self, u_mode, kw):
        p = deco.init_paper_decomposition(KEY, SYN, u_mode=u_mode, **kw)
        x = jax.random.uniform(KEY, (512, 1), minval=-3.0, maxval=3.0)
        out = deco.paper_forward(p, x, SYN, u_mode=u_mode)
        assert bool(jnp.all(out["u"] >= out["fhat"]))
        assert bool(jnp.all(out["corr"] > 0))
        assert bool(jnp.all(out["corr"] < SYN.s))

    def test_t_is_positive(self):
        p = deco.init_paper_decomposition(KEY, SYN, u_mode="truncated")
        x = jnp.zeros((4, 1))
        out = deco.paper_forward(p, x, SYN)
        assert float(out["t"]) > 0

    def test_truncation_masks_basis(self):
        """Features beyond n must not affect u (they never ship to device)."""
        p = deco.init_paper_decomposition(KEY, SYN, u_mode="cosine", n_modes=24)
        x = jax.random.uniform(KEY, (64, 1), minval=-3.0, maxval=3.0)
        u1 = deco.paper_forward(p, x, SYN, u_mode="cosine", monitor_n=8)["u"]
        p2 = dict(p)
        p2["a"] = p["a"].at[8:].set(123.0)  # poison truncated coefficients
        u2 = deco.paper_forward(p2, x, SYN, u_mode="cosine", monitor_n=8)["u"]
        np.testing.assert_allclose(u1, u2, atol=1e-6)


class TestCollabLM:
    def test_structural_safety_at_lm_scale(self):
        cfg = registry.get_smoke("granite-8b")
        params = deco.init_collab_lm(KEY, cfg)
        batch = model_api.sample_batch(KEY, cfg, ShapeConfig("t", 32, 2, "train"))
        out = deco.collab_forward(params, cfg, batch)
        assert bool(jnp.all(out["u"] >= out["fhat"]))
        assert out["u"].shape == batch["tokens"].shape

    def test_edge_tower_is_independent_of_server(self):
        """Monitor score must not read server params (device autonomy)."""
        cfg = registry.get_smoke("granite-8b")
        params = deco.init_collab_lm(KEY, cfg)
        batch = model_api.sample_batch(KEY, cfg, ShapeConfig("t", 32, 2, "train"))
        u1 = deco.monitor_score(params, cfg, batch)
        poisoned = dict(params)
        poisoned["server"] = jax.tree.map(lambda l: l * 0 + 7.0, params["server"])
        poisoned["v_head"] = jax.tree.map(lambda l: l * 0 + 7.0, params["v_head"])
        u2 = deco.monitor_score(poisoned, cfg, batch)
        np.testing.assert_allclose(u1, u2)

    def test_edge_param_count_is_small(self):
        from repro.nn.module import param_count
        cfg = registry.get_smoke("qwen2.5-32b")
        params = deco.init_collab_lm(KEY, cfg)
        edge = param_count(params["edge"]) + param_count(params["u_head"])
        server = param_count(params["server"])
        assert edge < server / 2, "edge tower must be much smaller than server"

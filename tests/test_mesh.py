"""Mesh-sharded serving (serving/mesh.py): spec parsing, per-row bitwise
identity of sharded sessions to the unsharded engine, the collective-free
monitor path (HLO-asserted), sharding-preserving row resets, and the
correction server's lease defrag.

The sharded tests need an 8-device mesh.  A CPU host exposes ONE device,
so they are skipped in the main pytest process and exercised two ways:

  * ``test_sharded_suite_subprocess`` (tier-1): re-runs this file in a
    subprocess under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``;
  * the CI ``shard-smoke`` step runs the same selection directly with
    the flag exported.
"""
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_synthetic import SERVING
from repro.core import decomposition as deco
from repro.data import tokens as tok
from repro.serving import SessionConfig, TransportSpec
from repro.serving import mesh as mesh_mod
from repro.serving.collaborative import CollaborativeEngine
from repro.serving.engine import zero_cache_rows

KEY = jax.random.PRNGKey(0)
NDEV = jax.device_count()
needs_mesh = pytest.mark.skipif(
    NDEV < 8, reason="needs 8 (virtual) devices: run under "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8 "
    "(tier-1 covers this via test_sharded_suite_subprocess)")

# the -k selection the subprocess runner and the CI shard-smoke step share
SHARDED_K = "sharded or hlo or preserves or defrag"


def _setup(threshold=0.1, batch=16, length=10, seed=0):
    import dataclasses
    cfg = SERVING.replace(monitor=dataclasses.replace(
        SERVING.monitor, threshold=threshold, trigger_margin=0.0))
    params = deco.init_collab_lm(KEY, cfg)
    stream = next(tok.lm_batches(seed, cfg, batch, length))["tokens"]
    return cfg, params, stream


class TestMeshSpec:
    """Parse/validation round-trips for the mesh field — no devices
    needed (``SessionConfig``/``TransportSpec`` are construction-time
    surfaces; ``MeshSpec.build`` is the only device-touching call)."""

    def test_parse_roundtrip(self):
        for text in ("data:8", "data:1", "pod:2,data:4"):
            spec = mesh_mod.MeshSpec.parse(text)
            assert str(spec) == text
            assert mesh_mod.MeshSpec.parse(str(spec)) == spec
        assert mesh_mod.MeshSpec.parse("data:8").n_devices == 8
        assert mesh_mod.MeshSpec.parse("pod:2,data:4").data_size == 8
        spec = mesh_mod.MeshSpec.parse("data:4")
        assert mesh_mod.MeshSpec.parse(spec) is spec  # passthrough

    @pytest.mark.parametrize("bad", [
        "", "data", "8", "data:0", "data:-1", "data:x", "data:2,data:4",
        "model:8", "da ta:2"])
    def test_parse_rejects(self, bad):
        with pytest.raises(ValueError):
            mesh_mod.MeshSpec.parse(bad)

    def test_session_config_mesh_field_roundtrip(self):
        cfg = SessionConfig(mesh="data:8")
        assert isinstance(cfg.mesh, mesh_mod.MeshSpec)
        assert str(cfg.mesh) == "data:8"
        # a parsed spec passes through; None stays None (unsharded)
        assert SessionConfig(mesh=cfg.mesh).mesh == cfg.mesh
        assert SessionConfig().mesh is None
        # the field composes with every mode, including offline scan
        assert SessionConfig(mode="scan", mesh="data:2").mesh.n_devices == 2
        with pytest.raises(ValueError):
            SessionConfig(mesh="data:zero")

    def test_transport_spec_roundtrip_with_mesh_config(self):
        """The transport parse round-trip is unchanged by the mesh field
        (mesh describes the LOCAL placement; the transport describes the
        server boundary — a sharded session composes with any kind)."""
        spec = TransportSpec.parse("wire:/tmp/corr.sock")
        assert (spec.kind, spec.address) == ("wire", "/tmp/corr.sock")
        assert TransportSpec.parse(spec) is spec
        cfg = SessionConfig(mode="async", transport=spec, mesh="data:8")
        assert cfg.transport == spec and str(cfg.mesh) == "data:8"

    def test_build_refuses_too_few_devices(self):
        spec = mesh_mod.MeshSpec.parse(f"data:{NDEV * 16}")
        with pytest.raises(ValueError, match="XLA_FLAGS"):
            spec.build()

    def test_engine_batch_must_divide(self):
        if NDEV < 2:
            pytest.skip("needs >= 2 devices to build a data:2 mesh")
        cfg, params, _ = _setup(batch=3)
        with pytest.raises(ValueError, match="divisible"):
            CollaborativeEngine(params, cfg, batch=3, max_len=16,
                                mesh="data:2")


@needs_mesh
class TestShardedBitIdentity:
    """Sharding is a placement change, not a numerics change: every
    serving path of an engine sharded over an 8-virtual-device mesh is
    per-row BITWISE identical to the unsharded engine."""

    MESH = "data:8"

    def _ref_and_sharded(self, cfg, params, batch, max_len):
        ref = CollaborativeEngine(params, cfg, batch=batch, max_len=max_len)
        shd = CollaborativeEngine(params, cfg, batch=batch, max_len=max_len,
                                  mesh=self.MESH)
        return ref, shd

    def test_sharded_sync_bit_identity(self):
        cfg, params, stream = _setup()
        ref_eng, shd_eng = self._ref_and_sharded(cfg, params, 16, 16)
        ref = ref_eng.session().run(stream)
        res = shd_eng.session(SessionConfig(mesh=self.MESH)).run(stream)
        assert ref["triggered"].any() and not ref["triggered"].all()
        for k in ("u", "fhat", "triggered"):
            np.testing.assert_array_equal(res[k], ref[k])
        np.testing.assert_array_equal(shd_eng.server_pos, ref_eng.server_pos)
        for a, b in zip(jax.tree.leaves(shd_eng.server.cache),
                        jax.tree.leaves(ref_eng.server.cache)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # comms accounting identical too
        ra, rb = shd_eng.comms.report(), ref_eng.comms.report()
        assert ra["bytes_sent"] == rb["bytes_sent"]
        np.testing.assert_array_equal(ra["per_stream"]["bytes_sent"],
                                      rb["per_stream"]["bytes_sent"])
        # and the super-batch state actually shrank per device
        full = sum(l.nbytes for l in jax.tree.leaves(shd_eng.server.cache))
        per_dev = mesh_mod.bytes_per_device(shd_eng.server.cache)
        assert full == 8 * per_dev

    def test_sharded_scan_bit_identity(self):
        cfg, params, stream = _setup()
        ref_eng, shd_eng = self._ref_and_sharded(cfg, params, 16, 16)
        ref = ref_eng.session(SessionConfig(mode="scan")).run(stream)
        res = shd_eng.session(
            SessionConfig(mode="scan", mesh=self.MESH)).run(stream)
        for k in ("u", "fhat", "triggered", "served"):
            np.testing.assert_array_equal(res[k], ref[k])

    def test_sharded_scan_ragged_capacity(self):
        """Regression: the scan path applies the corrector head to a
        (capacity, d) compacted buffer whose leading dim need not divide
        the mesh — a sharded scan at capacity=5 over 8 devices must run
        and stay bitwise identical."""
        cfg, params, stream = _setup()
        ref_eng = CollaborativeEngine(params, cfg, batch=16, max_len=16,
                                      capacity=5)
        shd_eng = CollaborativeEngine(params, cfg, batch=16, max_len=16,
                                      capacity=5, mesh=self.MESH)
        ref = ref_eng.session(
            SessionConfig(mode="scan", capacity=5)).run(stream)
        res = shd_eng.session(
            SessionConfig(mode="scan", capacity=5, mesh=self.MESH)).run(stream)
        for k in ("u", "fhat", "triggered", "served"):
            np.testing.assert_array_equal(res[k], ref[k])

    @pytest.mark.parametrize("transport", [
        TransportSpec("inproc"),
        TransportSpec("stream", latency_s=0.002)])
    def test_sharded_async_bit_identity(self, transport):
        cfg, params, stream = _setup()
        ref_eng, shd_eng = self._ref_and_sharded(cfg, params, 16, 16)

        def run(eng, mesh):
            config = SessionConfig(mode="async", max_staleness=2,
                                   transport=transport, mesh=mesh)
            with eng.session(config) as s:
                return s.run(stream)

        ref = run(ref_eng, None)
        res = run(shd_eng, self.MESH)
        # fhat is only compared on the deterministic transport: with a
        # real latency a reply may merge at age 1 or 2 depending on
        # wall-clock readiness, so the fhat TRACE is timing-dependent in
        # async mode (sharded and unsharded alike) — the monitor path
        # and the drained protocol state are the invariants
        keys = (("u", "fhat", "triggered") if transport.kind == "inproc"
                else ("u", "triggered"))
        for k in keys:
            np.testing.assert_array_equal(res[k], ref[k])
        np.testing.assert_array_equal(shd_eng.server_pos, ref_eng.server_pos)

    def test_sharded_churn_bit_identity(self):
        """Attach/detach/reuse: the slot-pool schedule produces the same
        bits sharded and unsharded, and row resets stay shard-local."""
        cfg, params, stream = _setup(length=12)
        fresh = next(tok.lm_batches(9, cfg, 2, 12))["tokens"]
        results = []
        for mesh in (None, self.MESH):
            eng = CollaborativeEngine(params, cfg, batch=16, max_len=16,
                                      mesh=mesh)
            sess = eng.session(SessionConfig(mesh=mesh))
            outs, born = [], {}
            for t in range(12):
                if t == 4:
                    sess.detach(1)
                    assert sess.attach("n1") == 1
                    born["n1"] = t
                if t == 7:
                    sess.detach(2)
                if t == 9:
                    assert sess.attach("n2") == 2  # reuse slot 2
                    born["n2"] = t
                toks = {}
                for sid in sess.streams:
                    if isinstance(sid, str):
                        toks[sid] = fresh[int(sid[1:]) - 1, t - born[sid]]
                    else:
                        toks[sid] = stream[sid, t]
                r = sess.step(toks)
                outs.append(r)
            results.append(outs)
        for ra, rb in zip(*results):
            assert ra["streams"] == rb["streams"]
            for k in ("u", "fhat", "triggered"):
                np.testing.assert_array_equal(ra[k], rb[k])

    @pytest.mark.slow
    def test_sharded_sync_bit_identity_b1024(self):
        """The acceptance operating point: batch 1024 over 8 virtual
        devices, per-row bitwise identical with ~8x per-device cache
        shrink and a collective-free monitor path."""
        cfg, params, _ = _setup(batch=1024, length=8)
        stream = next(tok.lm_batches(0, cfg, 1024, 8))["tokens"]
        ref_eng, shd_eng = self._ref_and_sharded(cfg, params, 1024, 12)
        ref = ref_eng.session().run(stream)
        res = shd_eng.session(SessionConfig(mesh=self.MESH)).run(stream)
        assert ref["triggered"].any()
        for k in ("u", "fhat", "triggered"):
            np.testing.assert_array_equal(res[k], ref[k])
        np.testing.assert_array_equal(shd_eng.server_pos, ref_eng.server_pos)
        full = sum(l.nbytes for l in jax.tree.leaves(shd_eng.server.cache))
        assert full == 8 * mesh_mod.bytes_per_device(shd_eng.server.cache)
        for name, txt in mesh_mod.edge_hlo(shd_eng).items():
            mesh_mod.assert_collective_free(txt, name)

    def test_sharded_wire_bit_identity(self):
        """The acceptance wire arm: a sharded client session against a
        sharded (``--mesh data:8``) correction-server subprocess is
        bitwise identical to the unsharded local sync engine."""
        cfg, params, stream = _setup(length=12)
        ref = CollaborativeEngine(params, cfg, batch=16,
                                  max_len=16).session().run(stream)
        tmp = tempfile.mkdtemp(prefix="mesh_wire_")
        uds = os.path.join(tmp, "s.sock")
        from conftest import SPAWN_DEADLINE_S
        from repro.launch.server import spawn_subprocess
        proc = spawn_subprocess(
            "paper-synthetic-serving", uds=uds, slots=16, max_len=16,
            ready_file=os.path.join(tmp, "ready"),
            extra_args=("--mesh", "data:8"), timeout_s=SPAWN_DEADLINE_S)
        try:
            eng = CollaborativeEngine(params, cfg, batch=16, max_len=16,
                                      mesh=self.MESH)
            config = SessionConfig(
                mode="sync", mesh=self.MESH,
                transport=TransportSpec("wire", address=uds))
            with eng.session(config) as sess:
                res = sess.run(stream)
            for k in ("u", "fhat", "triggered"):
                np.testing.assert_array_equal(res[k], ref[k])
            w = eng.comms.report()["wire"]
            assert w["tx_bytes"] > 0 and w["replies"] > 0
        finally:
            proc.terminate()
            try:
                proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                proc.kill()


@needs_mesh
class TestShardedInvariants:
    def test_edge_hlo_collective_free(self):
        """The paper's device-locality guarantee at batch scale: the
        compiled monitor path (masked edge decode + u head + history
        record) contains ZERO cross-device collective ops."""
        cfg, params, _ = _setup()
        eng = CollaborativeEngine(params, cfg, batch=16, max_len=16,
                                  mesh="data:8")
        hlos = mesh_mod.edge_hlo(eng)
        assert set(hlos) == {"decode_masked", "u_head", "record_at"}
        for name, txt in hlos.items():
            assert not mesh_mod.collective_ops(txt), name
            mesh_mod.assert_collective_free(txt, name)  # and the raiser
        # sanity: the checker does catch a collective when one exists
        with pytest.raises(AssertionError):
            mesh_mod.assert_collective_free(
                "%ar = f32[8] all-reduce(f32[1] %x)", "probe")

    def test_zero_cache_rows_preserves_sharding(self):
        """Regression (spec-aware row reset): zeroing slot rows of a
        sharded cache must keep every leaf's placement — no silent
        gather onto one device when a slot churns."""
        cfg, params, _ = _setup()
        eng = CollaborativeEngine(params, cfg, batch=16, max_len=16,
                                  mesh="data:8")
        want = eng.server._cache_shardings
        rows = np.zeros(16, bool)
        rows[5] = True
        # the spec-aware helper...
        out = zero_cache_rows(eng.server.cache, eng.server.axes,
                              jnp.asarray(rows), shardings=want)
        for leaf, sh in zip(jax.tree.leaves(out), jax.tree.leaves(want)):
            assert leaf.sharding.is_equivalent_to(sh, leaf.ndim), leaf.sharding
        # ...and the engine-level reset path slots use
        eng.server.zero_rows(rows)
        eng.edge.zero_rows(rows)
        for se in (eng.server, eng.edge):
            for leaf, sh in zip(jax.tree.leaves(se.cache),
                                jax.tree.leaves(se._cache_shardings)):
                assert leaf.sharding.is_equivalent_to(sh, leaf.ndim)

    def test_shard_engine_idempotent_and_guarded(self):
        cfg, params, _ = _setup()
        eng = CollaborativeEngine(params, cfg, batch=16, max_len=16,
                                  mesh="data:8")
        assert mesh_mod.shard_engine(eng, "data:8") is eng  # idempotent
        with pytest.raises(ValueError, match="already sharded"):
            mesh_mod.shard_engine(eng, "data:4")
        # a session config naming a different mesh is refused too
        with pytest.raises(ValueError, match="already sharded"):
            eng.session(SessionConfig(mesh="data:4")).step(
                np.zeros(16, np.int32))


class TestLeaseDefrag:
    """Server-side lease defrag: on BYE the freed row ranges compact so
    the free space stays one contiguous tail, live leases move with
    their cache/history rows, and the ``lease_fragmentation`` gauge
    reads 0 after compaction."""

    def _server(self, slots=8):
        from repro.serving.server import CorrectionServer
        cfg, params, _ = _setup()
        tmp = tempfile.mkdtemp(prefix="defrag_")
        return CorrectionServer(cfg, params, slots=slots,
                                uds=os.path.join(tmp, "s.sock"))

    def _lease(self, srv, n):
        import socket as socket_mod
        from repro.serving.server import Session
        a, b = socket_mod.socketpair()
        sess = Session(srv._next_sid, a)
        srv._next_sid += 1
        sess.lo, sess.batch, sess.max_len = srv._alloc(n), n, srv.max_len
        srv._sessions[a] = sess
        self._peers.append(b)
        return sess

    def setup_method(self, _):
        self._peers = []

    def teardown_method(self, _):
        for p in self._peers:
            p.close()

    def test_bye_defrag_compacts_and_moves_rows(self):
        srv = self._server(slots=8)
        try:
            s1 = self._lease(srv, 2)   # rows [0, 2)
            s2 = self._lease(srv, 3)   # rows [2, 5)
            s3 = self._lease(srv, 2)   # rows [5, 7)
            assert (s1.lo, s2.lo, s3.lo) == (0, 2, 5)
            # sentinel state: history row r carries value r; one cache
            # leaf's rows carry their index too
            srv._history[:] = np.arange(srv.slots)[:, None]
            srv._cache = jax.tree.map(
                lambda a, ax: jnp.moveaxis(
                    jnp.broadcast_to(
                        jnp.arange(srv.slots, dtype=a.dtype).reshape(
                            (srv.slots,) + (1,) * (a.ndim - 1)),
                        (srv.slots,) + tuple(np.delete(a.shape, ax))),
                    0, ax),
                srv._cache, srv._axes)
            srv._drop(s2)  # BYE the middle lease -> hole at [2, 5)
            assert srv.stats["defrags"] == 1
            assert srv.fragmentation() == 0.0
            assert (s1.lo, s3.lo) == (0, 2)      # s3 moved down
            assert srv._free == [(4, 8)]          # one contiguous tail
            # s3's rows (old 5,6) moved to 2,3 — history and cache alike
            np.testing.assert_array_equal(srv._history[2, 0], 5)
            np.testing.assert_array_equal(srv._history[3, 0], 6)
            leaf, ax = (jax.tree.leaves(srv._cache)[0],
                        jax.tree.leaves(srv._axes)[0])
            got = np.moveaxis(np.asarray(leaf), ax, 0)
            assert got.reshape(srv.slots, -1)[2].flat[0] == 5
            assert got.reshape(srv.slots, -1)[3].flat[0] == 6
            # s1 untouched bit-for-bit
            assert got.reshape(srv.slots, -1)[0].flat[0] == 0
            # a full-width HELLO now fits where it could not before
            assert srv._alloc(4) == 4
        finally:
            srv.close()

    def test_drop_defers_defrag_while_requests_pending(self):
        """Co-resident clients' queued replays must not stall behind a
        super-batch permutation: a FRAGMENTED drop (two free extents)
        defers compaction while requests are pending, and compacts on
        the next fragmented drop once the queue is empty."""
        srv = self._server(slots=8)
        try:
            s_a = self._lease(srv, 2)
            s_b = self._lease(srv, 2)
            s_c = self._lease(srv, 2)
            s_d = self._lease(srv, 2)          # fully leased
            srv._pending.append((s_b, None))   # a queued request
            srv._drop(s_a)                     # free [(0,2)] — one extent
            srv._drop(s_c)                     # free [(0,2),(4,6)] — two
            assert srv.stats["defrags"] == 0   # deferred: queue not empty
            assert srv.fragmentation() > 0
            assert (s_b.lo, s_d.lo) == (2, 6)  # nothing moved
            srv._pending.clear()
            srv._drop(s_d)                     # still fragmented, queue empty
            assert srv.stats["defrags"] == 1   # now it compacts
            assert s_b.lo == 0
            assert srv._free == [(2, 8)]
        finally:
            srv._pending.clear()
            srv.close()

    def test_fragmented_hello_defrags_then_leases(self):
        """A HELLO that fits in TOTAL free rows is never refused for
        holes: the lease map compacts lazily at allocation time."""
        import socket as socket_mod
        from repro.serving import wire
        from repro.serving.server import Session
        srv = self._server(slots=8)
        try:
            a = self._lease(srv, 3)
            b = self._lease(srv, 2)
            c = self._lease(srv, 3)
            srv._pending.append((b, None))   # suppress drop-time defrag
            srv._drop(a)
            srv._drop(c)                     # free [(0,3), (5,8)], b at [3,5)
            srv._pending.clear()
            assert srv.fragmentation() > 0
            x, y = socket_mod.socketpair()
            self._peers.extend([x, y])
            newcomer = Session(99, x)
            srv._sessions[x] = newcomer
            srv._handle(newcomer, wire.Hello(5, srv.max_len, srv.tok_tail,
                                             True, "t"))
            assert srv.stats["defrags"] == 1
            assert b.lo == 0                 # survivor compacted down
            assert (newcomer.lo, newcomer.batch) == (2, 5)
            assert y.recv(1 << 12)           # HELLO_ACK went out
        finally:
            srv._pending.clear()
            srv.close()

    def test_fragmentation_gauge(self):
        srv = self._server(slots=8)
        try:
            assert srv.fragmentation() == 0.0      # one free block
            srv._free = [(0, 1), (4, 7)]           # 4 free, largest 3
            assert srv.fragmentation() == pytest.approx(0.25)
            srv._free = []
            assert srv.fragmentation() == 0.0      # fully leased
        finally:
            srv.close()

    def test_double_drop_releases_lease_once(self):
        """Regression: ``_drop`` re-enters for one session when the BYE
        flush hits a peer that already closed (the flush drops, then the
        BYE handler drops again).  Double-releasing duplicated free
        ranges — the gauge read 0.333 on an empty server and a later
        HELLO could double-lease rows to two tenants."""
        srv = self._server(slots=8)
        try:
            s1 = self._lease(srv, 4)
            srv._drop(s1)
            srv._drop(s1)  # the BYE-after-failed-flush re-entry
            assert srv._free == [(0, 8)]
            assert srv.fragmentation() == 0.0
            # the full super-batch leases exactly once again
            assert srv._alloc(8) == 0 and srv._alloc(1) == -1
        finally:
            srv.close()

    def test_drop_without_fragmentation_skips_defrag(self):
        srv = self._server(slots=8)
        try:
            s1 = self._lease(srv, 2)
            s2 = self._lease(srv, 2)
            srv._drop(s2)  # frees the tail: already contiguous
            assert srv.stats["defrags"] == 0
            assert s1.lo == 0
        finally:
            srv.close()


@pytest.mark.slow
@pytest.mark.skipif(NDEV >= 8, reason="already on a multi-device host")
def test_sharded_suite_subprocess():
    """Tier-1 entry point for the sharded tests: re-run this file's
    device-gated selection under an 8-virtual-device host mesh."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-x", __file__,
         "-k", SHARDED_K],
        capture_output=True, text=True, env=env, timeout=1800)
    tail = (r.stdout + r.stderr)[-4000:]
    assert r.returncode == 0, tail
    assert "failed" not in r.stdout, tail

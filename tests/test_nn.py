"""Substrate invariants: prefill/decode consistency for every sequence-mixing
layer (the property that makes a serving cache correct), mask semantics,
RoPE shift-equivariance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn import attention as A
from repro.nn import ssm as S
from repro.nn import xlstm as X
from repro.nn.rotary import apply_rope

KEY = jax.random.PRNGKey(7)
B, SQ, D = 2, 48, 96


def x_seq(k=0, d=D):
    return jax.random.normal(jax.random.fold_in(KEY, k), (B, SQ, d))


class TestGQA:
    def test_decode_matches_prefill(self):
        p = A.init_gqa(KEY, D, 6, 2, 16)
        kw = dict(n_heads=6, n_kv=2, head_dim=16, compute_dtype=jnp.float32)
        x = x_seq()
        y_ref = A.gqa_prefill(p, x, **kw)
        cache = A.KVCache(jnp.zeros((B, SQ, 2, 16)), jnp.zeros((B, SQ, 2, 16)))
        outs = []
        for t in range(SQ):
            y, cache = A.gqa_decode(p, x[:, t], cache, t, **kw)
            outs.append(y)
        np.testing.assert_allclose(jnp.stack(outs, 1), y_ref, atol=1e-4)

    def test_ring_buffer_matches_window_prefill(self):
        win = 16
        p = A.init_gqa(KEY, D, 4, 4, 24)
        kw = dict(n_heads=4, n_kv=4, head_dim=24, compute_dtype=jnp.float32,
                  window=win)
        x = x_seq(1)
        y_ref = A.gqa_prefill(p, x, **kw)
        cache = A.KVCache(jnp.zeros((B, win, 4, 24)), jnp.zeros((B, win, 4, 24)))
        outs = []
        for t in range(SQ):
            y, cache = A.gqa_decode(p, x[:, t], cache, t, **kw)
            outs.append(y)
        np.testing.assert_allclose(jnp.stack(outs, 1), y_ref, atol=1e-4)

    def test_causality(self):
        """Future tokens must not influence past outputs."""
        p = A.init_gqa(KEY, D, 4, 2, 16)
        kw = dict(n_heads=4, n_kv=2, head_dim=16, compute_dtype=jnp.float32)
        x = x_seq(2)
        y1 = A.gqa_prefill(p, x, **kw)
        x2 = x.at[:, -1].set(99.0)
        y2 = A.gqa_prefill(p, x2, **kw)
        np.testing.assert_allclose(y1[:, :-1], y2[:, :-1], atol=1e-5)


class TestMLA:
    def test_decode_matches_prefill(self):
        p = A.init_mla(KEY, D, 4, q_lora=32, kv_lora=40, qk_nope=16,
                       qk_rope=8, v_dim=16)
        kw = dict(n_heads=4, qk_nope=16, qk_rope=8, v_dim=16,
                  compute_dtype=jnp.float32)
        x = x_seq(3)
        y_ref = A.mla_prefill(p, x, **kw)
        cache = A.MLACache(jnp.zeros((B, SQ, 40)), jnp.zeros((B, SQ, 8)))
        outs = []
        for t in range(SQ):
            y, cache = A.mla_decode(p, x[:, t], cache, t, kv_lora=40, **kw)
            outs.append(y)
        np.testing.assert_allclose(jnp.stack(outs, 1), y_ref, atol=1e-4)


class TestMamba2:
    def test_decode_matches_prefill(self):
        p = S.init_mamba2(KEY, D, expand=2, state=16, head_p=32)
        kw = dict(expand=2, state=16, conv_k=4, head_p=32,
                  compute_dtype=jnp.float32)
        x = x_seq(4)
        y_ref = S.mamba2_prefill(p, x, chunk=16, **kw)
        cache = S.init_ssm_cache(B, D, expand=2, state=16, conv_k=4, head_p=32)
        outs = []
        for t in range(SQ):
            y, cache = S.mamba2_decode(p, x[:, t], cache, **kw)
            outs.append(y)
        np.testing.assert_allclose(jnp.stack(outs, 1), y_ref, atol=1e-4)

    def test_chunk_size_invariance(self):
        p = S.init_mamba2(KEY, D, expand=2, state=16, head_p=32)
        kw = dict(expand=2, state=16, conv_k=4, head_p=32,
                  compute_dtype=jnp.float32)
        x = x_seq(5)
        y1 = S.mamba2_prefill(p, x, chunk=8, **kw)
        y2 = S.mamba2_prefill(p, x, chunk=48, **kw)
        np.testing.assert_allclose(y1, y2, atol=1e-4)


class TestXLSTM:
    def test_mlstm_recurrent_matches_parallel(self):
        p = X.init_mlstm(KEY, D, 4)
        x = x_seq(6)
        y_ref = X.mlstm_parallel(p, x, 4, compute_dtype=jnp.float32)
        st = X.init_mlstm_state(B, D, 4)
        outs = []
        for t in range(SQ):
            y, st = X.mlstm_decode(p, x[:, t], st, 4, compute_dtype=jnp.float32)
            outs.append(y)
        np.testing.assert_allclose(jnp.stack(outs, 1), y_ref, atol=1e-4)

    def test_slstm_scan_matches_step(self):
        p = X.init_slstm(KEY, D, 4)
        x = x_seq(7)
        y_scan, st_fin = X.slstm_scan(p, x, 4, compute_dtype=jnp.float32)
        st = X.init_slstm_state(B, D)
        hs = []
        for t in range(SQ):
            h, st = X.slstm_step(p, x[:, t], st, 4)
            hs.append(h)
        y_step = jnp.stack(hs, 1) * p["norm_scale"][None, None, :]
        np.testing.assert_allclose(y_scan, y_step, atol=1e-5)
        np.testing.assert_allclose(st_fin.h, st.h, atol=1e-5)


class TestRoPE:
    def test_relative_position_invariance(self):
        """<rope(q,i), rope(k,j)> depends only on i - j."""
        q = jax.random.normal(KEY, (1, 1, 1, 32))
        k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 1, 1, 32))
        def dot(i, j):
            qi = apply_rope(q, jnp.array([[i]]))
            kj = apply_rope(k, jnp.array([[j]]))
            return float(jnp.sum(qi * kj))
        assert dot(3, 1) == pytest.approx(dot(10, 8), abs=1e-4)
        assert dot(0, 0) == pytest.approx(dot(5, 5), abs=1e-4)

    def test_norm_preserved(self):
        x = jax.random.normal(KEY, (2, 4, 3, 64))
        y = apply_rope(x, jnp.arange(4)[None, :])
        np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                                   jnp.linalg.norm(x, axis=-1), rtol=1e-5)

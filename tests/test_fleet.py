"""Correction-server fleet: supervisor health/routing, and the failover
guarantee — a SIGKILL'd (or drained) server is survived by re-HELLO +
full-history replay with per-row u/trigger/fhat BITWISE equal to an
uninterrupted run, the replay traffic charged to ``comms["failover"]``.

Fault injection comes from two primitives:

  * handle kills — ``ThreadServer.kill`` severs every session socket
    with no BYE/GOAWAY (what a SIGKILL looks like from the wire);
    ``SubprocessServer.kill`` IS a SIGKILL (the batch-64 acceptance
    test, name contains "subprocess" so CI's fast chaos selection can
    deselect it with ``-k "not subprocess"``);
  * ``tests/_chaos.py``'s ChaosProxy — byte-level faults a kill cannot
    express deterministically: torn frame + EOF, duplicated REPLY,
    delayed REPLY.

Determinism notes (why each assertion is safe to make bitwise):
strict-sync (max_staleness=0) traces are bitwise end-to-end INCLUDING
across failover, because every step blocks on its reply — pipeline depth
never varies.  Pipelined traces keep u/triggered bitwise (trigger
decisions depend only on u, which is edge-local) while fhat merge timing
is scheduling-dependent — so pipelined tests assert u/trigger bitwise
plus the safety invariant ``fhat <= u`` instead.
"""
import os
import threading
import time
from contextlib import contextmanager
from io import StringIO

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from _chaos import ChaosProxy
from repro.configs.paper_synthetic import SERVING
from repro.core import decomposition as deco
from repro.data import tokens as tok
from repro.serving import (InMemoryTracker, CompositeTracker, Histogram,
                           JsonFileTracker, SessionConfig, TransportSpec,
                           wire)
from repro.serving.collaborative import CollaborativeEngine
from repro.serving.fleet import (PENDING_TTL_S, FleetSupervisor,
                                 ServerHandle, resolve_route)
from repro.serving.tracker import read_stats

KEY = jax.random.PRNGKey(0)
BATCH, STEPS, MAX_LEN = 4, 24, 32


def _cfg(threshold=0.1):
    return SERVING.replace(monitor=SERVING.monitor.__class__(
        **{**SERVING.monitor.__dict__, "threshold": threshold,
           "trigger_margin": 0.0}))


@pytest.fixture(scope="module")
def world():
    cfg = _cfg()
    params = deco.init_collab_lm(KEY, cfg)
    stream = next(tok.lm_batches(0, cfg, BATCH, STEPS))["tokens"]
    return cfg, params, stream


@contextmanager
def fleet(cfg, params, *, n=2, slots=8, respawn=False, wrapper=None,
          shm=False):
    """A thread-backend fleet with a daemon supervisor loop ticking it."""
    sup = FleetSupervisor(backend="thread", n_servers=n, slots=slots,
                          max_len=MAX_LEN, cfg=cfg, params=params,
                          respawn=respawn, address_wrapper=wrapper,
                          shm=shm)
    sup.start()
    stop = threading.Event()
    t = threading.Thread(target=sup.run_forever, args=(stop,), daemon=True)
    t.start()
    try:
        yield sup
    finally:
        stop.set()
        t.join(timeout=10)
        sup.close()


def run_session(sup, params, cfg, stream, *, staleness, at=None,
                kind="wire"):
    """Serve ``stream`` step-by-step through the fleet router, firing
    ``at[i](sup, eng, sess)`` after step i.  Returns (stacked traces,
    comms report, engine)."""
    batch = stream.shape[0]
    eng = CollaborativeEngine(params, cfg, batch=batch, max_len=MAX_LEN)
    scfg = SessionConfig(
        mode="async", max_staleness=staleness,
        transport=TransportSpec(kind,
                                address="fleet:" + sup.router_address))
    out = []
    with eng.session(scfg) as s:
        for i in range(stream.shape[1]):
            out.append(s.step(stream[:, i]))
            if at and i in at:
                at[i](sup, eng, s)
        rep = s.report()
    res = {k: np.stack([np.asarray(o[k]) for o in out])
           for k in ("u", "fhat", "triggered")}
    return res, rep, eng


def victim_of(sup, eng):
    """The handle currently serving ``eng``'s worker."""
    return next(h for h in sup.servers.values()
                if h.address == eng._worker.server_address)


def wait_live(sup, n, timeout=30.0):
    deadline = time.monotonic() + timeout
    while len(sup.live_servers()) < n:
        assert time.monotonic() < deadline, \
            f"fleet never reached {n} live: " \
            f"{[(h.name, h.state) for h in sup.servers.values()]}"
        time.sleep(0.02)


# -- trackers (the heartbeat/metrics surface) --------------------------------

class TestTracker:
    def test_histogram_summary_is_bounded_by_observations(self):
        h = Histogram(1e-4, 10.0)
        xs = [0.001, 0.01, 0.01, 0.5, 5.0]
        for x in xs:
            h.observe(x)
        s = h.summary()
        assert s["n"] == len(xs)
        assert s["max"] == max(xs)
        assert s["mean"] == pytest.approx(np.mean(xs))
        # approximate quantiles must stay inside the observed range
        assert 0 < s["p50"] <= s["max"]
        assert s["p50"] <= s["p99"] <= s["max"]
        # empty histogram: counts at zero, percentiles honestly absent
        # (None, not a fabricated 0.0 — see test_observability.py)
        assert Histogram(1e-4, 10.0).summary() == {
            "n": 0, "mean": 0.0, "max": 0.0, "p50": None, "p99": None}

    def test_json_file_tracker_heartbeat_round_trip(self, tmp_path):
        path = str(tmp_path / "hb" / "stats.json")
        t = JsonFileTracker(path)
        assert read_stats(path) is None, "no heartbeat before first log"
        t.log({"leased_rows": 3, "arr": np.arange(2)})
        rec = read_stats(path)
        assert rec["leased_rows"] == 3 and rec["arr"] == [0, 1]
        assert rec["ts"] > 0, "heartbeat must self-timestamp"
        t.log({"leased_rows": 5})
        assert read_stats(path)["leased_rows"] == 5, "log REPLACES the file"
        # a torn/garbage file is 'no heartbeat', never an exception
        with open(path, "w") as fh:
            fh.write('{"leased_rows": ')
        assert read_stats(path) is None
        t.finish()
        assert not os.path.exists(path), "finish() retires the heartbeat"

    def test_composite_tracker_fans_out(self):
        a, b = InMemoryTracker(), InMemoryTracker()
        buf = StringIO()
        from repro.serving.tracker import LogTracker
        c = CompositeTracker([a, LogTracker(buf, prefix="hb")])
        c.add(b)
        c.log({"x": 1}, step=7)
        c.log_summary({"done": True})
        assert a.records == b.records == [{"x": 1, "step": 7}]
        assert a.summary == {"done": True}
        assert buf.getvalue().startswith("hb[7] x=1")
        assert a.latest == {"x": 1, "step": 7}


# -- supervisor health state machine (no sockets, no jax) --------------------

class _FakeHandle(ServerHandle):
    def __init__(self, name="f", alive=True, rec=None):
        super().__init__(name)
        self._alive, self._rec = alive, rec

    def alive(self):
        return self._alive

    def scrape(self):
        return self._rec


class TestHealth:
    def test_starting_goes_live_on_first_heartbeat(self):
        h = _FakeHandle(rec=None)
        h.refresh(5.0)
        assert h.state == "starting", "no heartbeat yet: still starting"
        h._rec = {"ts": time.time(), "leased_rows": 2, "slots": 8,
                  "address": "/tmp/x.sock"}
        h.refresh(5.0)
        assert h.state == "live" and h.address == "/tmp/x.sock"
        assert h.load() == 2 and h.free_rows() == 6

    def test_stale_heartbeat_and_death_are_dead(self):
        h = _FakeHandle(rec={"ts": time.time(), "slots": 8})
        h.refresh(5.0)
        assert h.state == "live"
        h._rec = {"ts": time.time() - 60.0, "slots": 8}
        h.refresh(5.0)
        assert h.state == "dead", "stale heartbeat == hung server"
        h2 = _FakeHandle(rec={"ts": time.time(), "slots": 8})
        h2.refresh(5.0)
        h2._alive = False
        h2.refresh(5.0)
        assert h2.state == "dead"

    def test_draining_exit_is_a_clean_retire(self):
        h = _FakeHandle(rec={"ts": time.time(), "slots": 8})
        h.refresh(5.0)
        h._rec = {"ts": time.time(), "slots": 8, "draining": True}
        h.refresh(5.0)
        assert h.state == "draining"
        h._alive, h._rec = False, None
        h.refresh(5.0)
        assert h.state == "stopped", "drained exit is retire, not death"

    def test_pending_redirects_count_as_load_until_seen_or_expired(self):
        h = _FakeHandle(rec={"ts": time.time(), "leased_rows": 1, "slots": 8})
        h.refresh(5.0)
        h.pending.append((time.time(), 4))
        assert h.load() == 5, "an issued redirect is optimistic load"
        # a heartbeat NEWER than the redirect absorbs it (leased_rows now
        # reflects the session, or the client never came)
        h._rec = {"ts": time.time() + 0.001, "leased_rows": 5, "slots": 8}
        h.refresh(5.0)
        assert h.load() == 5
        h.pending.append((time.time() - 2 * PENDING_TTL_S, 4))
        assert h.load() == 5, "expired pending entries are dropped"


# -- routing -----------------------------------------------------------------

class TestRouting:
    def test_router_redirects_and_refuses(self, world):
        cfg, params, stream = world
        with fleet(cfg, params) as sup:
            wait_live(sup, 2)
            addrs = {h.address for h in sup.servers.values()}
            got = resolve_route(sup.router_address,
                                wire.Hello(batch=4, max_len=MAX_LEN))
            assert got in addrs
            # nothing fits 20 rows on 8-slot servers: ERROR, surfaced as
            # HandshakeRefused (try-elsewhere), not PeerGone (dead)
            with pytest.raises(wire.HandshakeRefused, match="no live"):
                resolve_route(sup.router_address,
                              wire.Hello(batch=20, max_len=MAX_LEN))
            assert sup.stats["routed"] >= 1
            assert sup.stats["refused"] >= 1

    def test_least_loaded_server_wins(self, world):
        cfg, params, _ = world
        stream5 = next(tok.lm_batches(1, cfg, 5, 4))["tokens"]
        with fleet(cfg, params) as sup:
            wait_live(sup, 2)
            eng = CollaborativeEngine(params, cfg, batch=5, max_len=MAX_LEN)
            scfg = SessionConfig(
                mode="async", max_staleness=0,
                transport=TransportSpec(
                    "wire", address="fleet:" + sup.router_address))
            with eng.session(scfg) as s:
                s.step(stream5[:, 0])
                busy = victim_of(sup, eng)
                # 5 of busy's 8 rows are leased: a 4-row session cannot
                # fit there, so the router MUST name the sibling
                got = resolve_route(sup.router_address,
                                    wire.Hello(batch=4, max_len=MAX_LEN))
                assert got != busy.address
            assert sup.stats["routed"] >= 2


# -- failover: kill / drain / retry-to-sibling (thread backend) --------------

class TestFailover:
    def test_kill_mid_flight_strict_sync_is_bitwise(self, world):
        """ISSUE acceptance (thread-scale): SIGKILL-equivalent mid-run,
        the client re-HELLOs, replays from position 0, and the whole
        per-row trace is bitwise identical to the uninterrupted run —
        with the replay charged to comms['failover'], not 'wire'."""
        cfg, params, stream = world
        with fleet(cfg, params) as sup:
            wait_live(sup, 2)
            ref, ref_rep, _ = run_session(sup, params, cfg, stream,
                                          staleness=0)
            kill = {10: lambda sup, eng, s: victim_of(sup, eng).kill()}
            got, rep, eng = run_session(sup, params, cfg, stream,
                                        staleness=0, at=kill)
            for k in ("u", "fhat", "triggered"):
                np.testing.assert_array_equal(got[k], ref[k], err_msg=k)
            assert ref_rep.get("failover") is None, \
                "no failover bucket without a failover"
            fo = rep["failover"]
            assert fo["failovers"] == 1
            assert fo["tx_bytes"] > 0 and fo["replayed_tokens"] > 0
            assert fo["replay_requests"] >= 1
            # trigger decisions replayed masked: replay tokens can only
            # come from positions the dead server had already acked
            assert fo["replayed_tokens"] <= BATCH * STEPS
            # the uninterrupted run's wire bytes are a lower bound: the
            # wire bucket must NOT absorb the replay traffic
            assert rep["wire"]["tx_bytes"] <= ref_rep["wire"]["tx_bytes"]

    def test_kill_during_pipelined_flight_recovers(self, world):
        """Kill while replies are in flight (max_staleness=2): survivors'
        u/trigger stay bitwise (trigger logic is edge-local) and the
        merged corrections never break fhat <= u."""
        cfg, params, stream = world
        with fleet(cfg, params) as sup:
            wait_live(sup, 2)
            ref, _, _ = run_session(sup, params, cfg, stream, staleness=2)
            kill = {12: lambda sup, eng, s: victim_of(sup, eng).kill()}
            got, rep, _ = run_session(sup, params, cfg, stream,
                                      staleness=2, at=kill)
            np.testing.assert_array_equal(got["u"], ref["u"])
            np.testing.assert_array_equal(got["triggered"], ref["triggered"])
            assert bool(np.all(got["fhat"] <= got["u"] + 1e-6))
            fo = rep["failover"]
            assert fo["failovers"] == 1
            # pipelined kill strands unanswered real flights: they are
            # re-sent VERBATIM after the synthetic replay
            assert fo["resent_requests"] >= 1

    def test_drain_drops_zero_streams_and_retires(self, world):
        """Drain mid-run: the victim GOAWAYs, the client migrates, every
        stream finishes bitwise (zero drops), and the drained server
        exits as 'stopped' — retired, never respawned."""
        cfg, params, stream = world
        with fleet(cfg, params, respawn=True) as sup:
            wait_live(sup, 2)
            ref, _, _ = run_session(sup, params, cfg, stream, staleness=0)
            names = {}

            def drain(sup, eng, s):
                names["victim"] = victim_of(sup, eng).name
                sup.drain(names["victim"])

            got, rep, _ = run_session(sup, params, cfg, stream,
                                      staleness=0, at={8: drain})
            assert got["u"].shape == (STEPS, BATCH), "zero dropped streams"
            for k in ("u", "fhat", "triggered"):
                np.testing.assert_array_equal(got[k], ref[k], err_msg=k)
            assert rep["failover"]["failovers"] == 1
            deadline = time.monotonic() + 20
            h = sup.servers[names["victim"]]
            while h.state != "stopped":
                assert time.monotonic() < deadline, \
                    f"drained server never retired (state={h.state})"
                time.sleep(0.02)
            assert sup.stats["retired"] >= 1
            assert sup.stats["respawns"] == 0, \
                "a drained server is retired, not replaced"

    def test_kill_during_hello_retries_to_sibling(self, world):
        """A redirect to a just-died server (the router's world-view is
        one heartbeat stale) must not strand the client: the dead-peer
        connect fails, the client re-asks the router, and lands on the
        sibling."""
        cfg, params, stream = world
        with fleet(cfg, params) as sup:
            wait_live(sup, 2)
            h0 = sup.servers["srv-0"]
            h0.kill()
            h0.state = "live"   # simulate the stale world-view window
            seen = {}
            spy = {0: lambda sup, eng, s:
                   seen.update(addr=eng._worker.server_address)}
            got, rep, eng = run_session(sup, params, cfg, stream,
                                        staleness=0, at=spy)
            assert seen["addr"] == sup.servers["srv-1"].address
            local = CollaborativeEngine(params, cfg, batch=BATCH,
                                        max_len=MAX_LEN)
            rs = local.session(SessionConfig(mode="scan")).run(stream)
            # scan traces are (batch, steps); stepped traces (steps, batch)
            np.testing.assert_array_equal(got["u"], np.asarray(rs["u"]).T)
            np.testing.assert_array_equal(got["triggered"],
                                          np.asarray(rs["triggered"]).T)
            # the bounce happened before any lease existed: nothing to
            # replay, so no failover is charged
            assert rep.get("failover") is None

    def test_dead_server_is_reaped_and_respawned(self, world):
        cfg, params, stream = world
        with fleet(cfg, params, respawn=True) as sup:
            wait_live(sup, 2)
            sup.kill("srv-0")
            wait_live(sup, 2)   # the replacement must come up live
            assert sup.servers["srv-0"].state == "dead"
            assert "srv-2" in sup.servers, "a fresh name, never reuse"
            assert sup.stats["reaped"] >= 1 and sup.stats["respawns"] >= 1
            got, rep, _ = run_session(sup, params, cfg, stream, staleness=0)
            assert rep.get("failover") is None, "post-respawn run is clean"


# -- byte-level chaos (proxy-injected) ---------------------------------------

class TestChaos:
    def test_duplicated_reply_is_dropped_not_merged(self, world):
        """A retransmitted REPLY must be discarded by the worker's
        head-of-flights check — merging it twice would corrupt acked
        positions and crash the Dispatcher's FIFO pairing."""
        cfg, params, stream = world
        proxy = ChaosProxy(seed=3)
        try:
            with fleet(cfg, params, wrapper=proxy.wrap) as sup:
                wait_live(sup, 2)
                ref, _, _ = run_session(sup, params, cfg, stream,
                                        staleness=0)
                arm = {5: lambda *_: proxy.dup_next_reply()}
                got, rep, _ = run_session(sup, params, cfg, stream,
                                          staleness=0, at=arm)
                assert proxy.stats["duplicated"] == 1
                for k in ("u", "fhat", "triggered"):
                    np.testing.assert_array_equal(got[k], ref[k], err_msg=k)
                assert rep.get("failover") is None, \
                    "a duplicate is dropped in place, no migration"
        finally:
            proxy.close()

    def test_torn_frame_then_eof_triggers_failover(self, world):
        """Connection dropped mid-frame (half a REPLY, then EOF): the
        worker must treat it as a dead server — re-HELLO + replay —
        and still land bitwise on the uninterrupted trace."""
        cfg, params, stream = world
        proxy = ChaosProxy(seed=3)
        try:
            with fleet(cfg, params, wrapper=proxy.wrap) as sup:
                wait_live(sup, 2)
                ref, _, _ = run_session(sup, params, cfg, stream,
                                        staleness=0)
                arm = {6: lambda *_: proxy.drop_mid_frame()}
                got, rep, _ = run_session(sup, params, cfg, stream,
                                          staleness=0, at=arm)
                assert proxy.stats["dropped_mid_frame"] == 1
                for k in ("u", "fhat", "triggered"):
                    np.testing.assert_array_equal(got[k], ref[k], err_msg=k)
                assert rep["failover"]["failovers"] >= 1
        finally:
            proxy.close()

    def test_delayed_reply_changes_nothing_but_time(self, world):
        cfg, params, stream = world
        proxy = ChaosProxy(seed=3)
        try:
            with fleet(cfg, params, wrapper=proxy.wrap) as sup:
                wait_live(sup, 2)
                ref, _, _ = run_session(sup, params, cfg, stream,
                                        staleness=0)
                arm = {4: lambda *_: proxy.delay_next_reply(0.4)}
                t0 = time.monotonic()
                got, rep, _ = run_session(sup, params, cfg, stream,
                                          staleness=0, at=arm)
                assert time.monotonic() - t0 >= 0.4
                assert proxy.stats["delayed"] == 1
                for k in ("u", "fhat", "triggered"):
                    np.testing.assert_array_equal(got[k], ref[k], err_msg=k)
        finally:
            proxy.close()


# -- property: random schedules preserve safety + byte accounting ------------

class TestFailoverProperty:
    @settings(max_examples=3, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_random_schedule_keeps_fhat_safe_and_bytes_bounded(self, seed):
        """Random (kill step, staleness, churn step, stream) schedules:
        after any failover replay the merged correction still satisfies
        fhat <= u at EVERY step, and the measured wire + failover bytes
        stay within the replay-adjusted bound implied by the meter's own
        request/token counts (no unmetered traffic, no double charge)."""
        rng = np.random.default_rng(seed)
        steps = 16
        cfg = _cfg()
        params = deco.init_collab_lm(KEY, cfg)
        stream = next(tok.lm_batches(int(rng.integers(0, 1000)), cfg,
                                     BATCH, steps))["tokens"]
        staleness = int(rng.choice([0, 1, 2]))
        kill_at = int(rng.integers(3, steps - 3))
        churn_at = int(rng.integers(2, steps - 2))

        def kill(sup, eng, s):
            victim_of(sup, eng).kill()

        def churn(sup, eng, s):
            sid = s.streams[int(rng.integers(0, BATCH))]
            s.detach(sid)
            s.attach(("fresh", sid))

        at = {kill_at: kill}
        if churn_at != kill_at:
            at[churn_at] = churn
        with fleet(cfg, params, respawn=True) as sup:
            wait_live(sup, 2)
            got, rep, eng = run_session(sup, params, cfg,
                                        stream[:, :steps],
                                        staleness=staleness, at=at)
        assert bool(np.all(got["fhat"] <= got["u"] + 1e-6)), \
            f"fhat>u after failover (seed={seed})"
        fo = rep["failover"]
        assert fo["failovers"] >= 1
        comms = eng.comms
        n_req = (comms.dispatched + fo["replay_requests"]
                 + fo["resent_requests"])
        n_tok = comms.tokens_shipped + fo["replayed_tokens"]
        # per-connection handshake/churn/BYE cap + per-request framing
        # cap + 4 bytes per int32 token actually shipped
        bound = ((fo["failovers"] + 1) * (160 + 16 * BATCH)
                 + n_req * (64 + 16 * BATCH) + 4 * n_tok)
        total = rep["wire"]["tx_bytes"] + fo["tx_bytes"]
        assert 0 < total <= bound, \
            f"tx {total} outside replay-adjusted bound {bound} (seed={seed})"


# -- the full-fat acceptance: subprocess fleet, SIGKILL at batch 64 ----------

class TestSubprocessFleet:
    def test_subprocess_sigkill_batch64_recovers_bitwise(self):
        """ISSUE acceptance: two launch.server SUBPROCESSES behind the
        router, a batch-64 strict-sync client, a real SIGKILL mid-flight
        — recovery via re-HELLO + replay, per-row u/trigger/fhat bitwise
        vs the uninterrupted single-server reference (the no-kill routed
        run, which lives entirely on one server)."""
        cfg = _cfg()
        params = deco.init_collab_lm(KEY, cfg)
        batch, steps, max_len = 64, 20, 24
        stream = next(tok.lm_batches(0, cfg, batch, steps))["tokens"]
        sup = FleetSupervisor("paper-synthetic-serving", n_servers=2,
                              slots=batch, max_len=max_len,
                              backend="subprocess", respawn=False)
        stop = threading.Event()
        t = threading.Thread(target=sup.run_forever, args=(stop,),
                             daemon=True)
        try:
            sup.start(wait=True)
            t.start()
            wait_live(sup, 2, timeout=60.0)

            def run(at=None):
                eng = CollaborativeEngine(params, cfg, batch=batch,
                                          max_len=max_len)
                scfg = SessionConfig(
                    mode="async", max_staleness=0,
                    transport=TransportSpec(
                        "wire", address="fleet:" + sup.router_address))
                out = []
                with eng.session(scfg) as s:
                    for i in range(steps):
                        out.append(s.step(stream[:, i]))
                        if at and i in at:
                            at[i](eng)
                    rep = s.report()
                return ({k: np.stack([np.asarray(o[k]) for o in out])
                         for k in ("u", "fhat", "triggered")}, rep)

            ref, ref_rep = run()
            sigkill = {9: lambda eng: victim_of(sup, eng).kill()}
            got, rep = run(at=sigkill)
            for k in ("u", "fhat", "triggered"):
                np.testing.assert_array_equal(got[k], ref[k], err_msg=k)
            assert 0.0 < got["triggered"].mean() < 1.0, "need mixed triggers"
            fo = rep["failover"]
            assert fo["failovers"] == 1 and fo["replayed_tokens"] > 0
            assert ref_rep.get("failover") is None
        finally:
            stop.set()
            t.join(timeout=10)
            sup.close()

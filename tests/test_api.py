"""The public serving API (serving/api.py): SessionConfig/TransportSpec
validation, the MonitorSession lifecycle, mode dispatch bit-identity
against the engine's three execution paths, and the deprecated engine
shims (run/run_scan/run_async) staying bit-identical to the session
path while warning."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import decomposition as deco
from repro.data import tokens as tok
from repro.serving import MonitorSession, SessionConfig, TransportSpec
from repro.serving.collaborative import CollaborativeEngine

KEY = jax.random.PRNGKey(0)


def _setup(threshold=0.1, batch=3, length=16):
    cfg = registry.get_smoke("granite-8b")
    cfg = cfg.replace(monitor=cfg.monitor.__class__(
        **{**cfg.monitor.__dict__, "threshold": threshold,
           "trigger_margin": 0.0}))
    params = deco.init_collab_lm(KEY, cfg)
    stream = next(tok.lm_batches(0, cfg, batch, length))["tokens"]
    return cfg, params, stream


class TestTransportSpec:
    def test_parse_forms(self):
        assert TransportSpec.parse("stream") == TransportSpec("stream")
        w = TransportSpec.parse("wire:/tmp/corr.sock")
        assert w.kind == "wire" and w.address == "/tmp/corr.sock"
        w = TransportSpec.parse("wire:127.0.0.1:7431")
        assert w.address == "127.0.0.1:7431"
        spec = TransportSpec("thread", latency_s=0.01)
        assert TransportSpec.parse(spec) is spec

    def test_validation(self):
        with pytest.raises(ValueError, match="carrier-pigeon"):
            TransportSpec("carrier-pigeon")
        with pytest.raises(ValueError, match="address"):
            TransportSpec("wire")  # wire needs an address
        with pytest.raises(ValueError, match="no address"):
            TransportSpec("stream", address="/tmp/x")
        with pytest.raises(ValueError, match="latency"):
            TransportSpec("inproc", latency_s=0.01)
        with pytest.raises(ValueError, match="measured"):
            TransportSpec("wire", address="/tmp/x", latency_s=0.01)


class TestSessionConfig:
    def test_mode_and_staleness_validation(self):
        with pytest.raises(ValueError, match="walk"):
            SessionConfig(mode="walk")
        with pytest.raises(ValueError, match="max_staleness"):
            SessionConfig(mode="async", max_staleness=-1)
        with pytest.raises(ValueError, match="offline"):
            SessionConfig(mode="scan", transport="stream")

    def test_transport_string_is_parsed(self):
        c = SessionConfig(mode="async", transport="mock_remote")
        assert c.transport == TransportSpec("mock_remote")

    def test_needs_worker_and_effective_staleness(self):
        assert not SessionConfig(mode="sync").needs_worker
        assert not SessionConfig(mode="scan").needs_worker
        assert SessionConfig(mode="async").needs_worker
        wire = SessionConfig(mode="sync", transport=TransportSpec(
            "wire", address="/tmp/x"), max_staleness=8)
        assert wire.needs_worker
        assert wire.effective_staleness == 0, "sync over a transport is strict"
        assert SessionConfig(mode="async",
                             max_staleness=8).effective_staleness == 8

    def test_operating_point_mismatch_refused(self):
        cfg, params, _ = _setup()
        eng = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        with pytest.raises(ValueError, match="MonitorSession.open"):
            eng.session(SessionConfig(threshold=0.9))
        # a matching override is fine
        eng.session(SessionConfig(threshold=cfg.monitor.threshold))

    def test_open_applies_operating_point(self):
        cfg, params, stream = _setup(threshold=0.1)
        hi = MonitorSession.open(params, cfg, batch=3, max_len=32,
                                 config=SessionConfig(threshold=1e9))
        r = hi.run(stream)
        assert r["triggered"].sum() == 0, "override must silence triggers"
        assert hi.engine.m.threshold == 1e9


class TestLifecycle:
    def test_state_machine_and_context_manager(self):
        cfg, params, stream = _setup()
        eng = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        s = eng.session(SessionConfig(mode="async", transport="inproc"))
        assert s.state == "new"
        with s:
            assert s.state == "open"
            s.step(jnp.asarray(stream[:, 0]))
        assert s.state == "closed"
        s.close()  # idempotent
        with pytest.raises(RuntimeError, match="closed"):
            s.step(jnp.asarray(stream[:, 1]))
        with pytest.raises(RuntimeError, match="closed"):
            s.attach("x")

    def test_run_closes_worker_backed_sessions(self):
        cfg, params, stream = _setup()
        eng = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        s = eng.session(SessionConfig(mode="async", transport="inproc"))
        s.run(stream)
        assert s.state == "closed"
        assert eng._dispatcher is None, "pipeline must be drained + closed"
        # plain sync sessions stay usable after run
        eng2 = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        s2 = eng2.session()
        s2.run(stream[:, :8])
        assert s2.state == "open"
        s2.step(jnp.asarray(stream[:, 8]))

    def test_scan_sessions_are_offline_and_fixed(self):
        cfg, params, stream = _setup()
        eng = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        s = eng.session(SessionConfig(mode="scan"))
        with pytest.raises(RuntimeError, match="offline"):
            s.step(jnp.asarray(stream[:, 0]))
        with pytest.raises(RuntimeError, match="fixed membership"):
            s.attach("x")
        r = s.run(stream)
        assert "served" in r

    def test_step_token_forms_and_stream_iter(self):
        """Array tokens, dict tokens, and the stream() iterator agree."""
        cfg, params, stream = _setup()
        e1 = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        s1 = e1.session()
        r_arr = [s1.step(jnp.asarray(stream[:, t])) for t in range(6)]
        e2 = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        s2 = e2.session(streams=["a", "b", "c"])
        r_dict = [s2.step({"a": stream[0, t], "b": stream[1, t],
                           "c": stream[2, t]}) for t in range(6)]
        e3 = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        r_iter = list(e3.session().stream(
            stream[:, t] for t in range(6)))
        for ra, rd, ri in zip(r_arr, r_dict, r_iter):
            np.testing.assert_array_equal(ra["u"], rd["u"])
            np.testing.assert_array_equal(ra["u"], ri["u"])
            np.testing.assert_array_equal(ra["fhat"], rd["fhat"])
        assert r_dict[0]["streams"] == ("a", "b", "c")
        with pytest.raises(ValueError, match="mismatch"):
            s2.step({"a": stream[0, 6], "b": stream[1, 6]})

    def test_explicit_streams_on_used_engine_start_cold(self):
        """A second session with EXPLICIT stream ids on a used engine
        must honour the bit-cold guarantee (no inherited tenant state);
        default membership resumes (shim continuation semantics)."""
        cfg, params, stream = _setup()
        eng = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        eng.session().run(stream[:, :8])
        assert eng.edge_pos.max() == 8
        s2 = eng.session(streams=["x", "y", "z"])
        assert (eng.edge_pos == 0).all() and (eng.server_pos == 0).all()
        r = [s2.step({"x": stream[0, t], "y": stream[1, t],
                      "z": stream[2, t]}) for t in range(8)]
        fresh = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        ref = fresh.session().run(stream[:, :8])
        np.testing.assert_array_equal(
            np.stack([o["u"] for o in r], 1), ref["u"])
        # default membership on a used engine resumes instead
        eng2 = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        eng2.session().run(stream[:, :8])
        eng2.session()
        assert eng2.edge_pos.max() == 8, "streams=None must not reset"

    def test_one_async_session_at_a_time(self):
        cfg, params, stream = _setup()
        eng = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        s1 = eng.session(SessionConfig(mode="async", transport="inproc"))
        s1.__enter__()
        s2 = eng.session(SessionConfig(mode="async", transport="inproc"))
        with pytest.raises(RuntimeError, match="already open"):
            s2.__enter__()
        s1.close()


class TestModeBitIdentity:
    """MonitorSession dispatches to the same jitted paths: sync vs scan
    vs strict-async traces stay bit-identical (u/trigger) across modes,
    exactly as the pre-session engine methods were held to."""

    def test_three_modes_agree(self):
        cfg, params, stream = _setup()
        sync = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        r_sync = sync.session().run(stream)
        assert 0.0 < r_sync["triggered"].mean() < 1.0, "need mixed triggers"
        scan = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        r_scan = scan.session(SessionConfig(mode="scan")).run(stream)
        a = CollaborativeEngine(params, cfg, batch=3, max_len=32)
        r_async = a.session(SessionConfig(mode="async", transport="inproc",
                                          max_staleness=0)).run(stream)
        for r in (r_scan, r_async):
            np.testing.assert_array_equal(r_sync["u"], r["u"])
            np.testing.assert_array_equal(r_sync["triggered"], r["triggered"])
        np.testing.assert_array_equal(r_sync["fhat"], r_async["fhat"])
        np.testing.assert_allclose(r_sync["fhat"], r_scan["fhat"], atol=1e-6)


class TestDeprecatedShims:
    """Satellite: run/run_scan/run_async survive as DeprecationWarning
    shims whose output is bit-identical (u/trigger/fhat/comms) to the
    session path."""

    def _engines(self, cfg, params, n=2):
        return [CollaborativeEngine(params, cfg, batch=3, max_len=32)
                for _ in range(n)]

    def test_run_shim_bit_identical_and_warns(self):
        cfg, params, stream = _setup()
        shim_eng, sess_eng = self._engines(cfg, params)
        with pytest.warns(DeprecationWarning, match="MonitorSession"):
            r_shim = shim_eng.run(stream)
        r_sess = sess_eng.session().run(stream)
        assert 0.0 < r_sess["triggered"].mean() < 1.0
        self._assert_identical(r_shim, r_sess)

    def test_run_scan_shim_bit_identical_and_warns(self):
        cfg, params, stream = _setup()
        shim_eng, sess_eng = self._engines(cfg, params)
        with pytest.warns(DeprecationWarning, match="MonitorSession"):
            r_shim = shim_eng.run_scan(stream)
        r_sess = sess_eng.session(SessionConfig(mode="scan")).run(stream)
        self._assert_identical(r_shim, r_sess)
        np.testing.assert_array_equal(r_shim["served"], r_sess["served"])

    def test_run_async_shim_bit_identical_and_warns(self):
        cfg, params, stream = _setup()
        shim_eng, sess_eng = self._engines(cfg, params)
        with pytest.warns(DeprecationWarning, match="MonitorSession"):
            r_shim = shim_eng.run_async(stream, transport="inproc",
                                        max_staleness=2)
        with sess_eng.session(SessionConfig(mode="async", transport="inproc",
                                            max_staleness=2)) as s:
            r_sess = s.run(stream)
        self._assert_identical(r_shim, r_sess)

    @staticmethod
    def _assert_identical(r_shim, r_sess):
        np.testing.assert_array_equal(r_shim["u"], r_sess["u"])
        np.testing.assert_array_equal(r_shim["triggered"], r_sess["triggered"])
        np.testing.assert_array_equal(r_shim["fhat"], r_sess["fhat"])
        cs, cr = r_shim["comms"], r_sess["comms"]
        assert cs["bytes_sent"] == cr["bytes_sent"]
        assert cs["bytes_baseline"] == cr["bytes_baseline"]
        assert cs["trigger_rate"] == cr["trigger_rate"]
        if "per_stream" in cs:
            np.testing.assert_array_equal(cs["per_stream"]["bytes_sent"],
                                          cr["per_stream"]["bytes_sent"])


class TestPublicSurface:
    def test_serving_exports_the_session_api(self):
        import repro.serving as serving
        assert serving.MonitorSession is MonitorSession
        assert serving.SessionConfig is SessionConfig
        assert serving.TransportSpec is TransportSpec
        assert serving.CollaborativeEngine is CollaborativeEngine

    def test_engine_step_methods_are_private(self):
        """The pre-redesign per-step entrypoints are gone from the public
        surface; only construction, session(), and the deprecated run*
        shims remain."""
        for name in ("step", "step_async", "start_async", "finish_async"):
            assert not hasattr(CollaborativeEngine, name), name
        for name in ("session", "run", "run_scan", "run_async"):
            assert hasattr(CollaborativeEngine, name), name

"""Linear layer-cost extrapolation for the dry-run roofline.

XLA's cost_analysis counts a while-loop body once, and fully unrolling an
81-layer model makes SPMD compilation take tens of minutes.  Layers inside
one scan group are IDENTICAL, so per-step cost is exactly linear in the
group's layer count:

    cost(counts) = glue + sum_g counts[g] * c_g

We compile small UNROLLED probes — the base (all groups = 1) plus one probe
per group (that group = 2) — solve for {glue, c_g}, and extrapolate to the
full counts.  tests/test_dryrun_subprocess.py + EXPERIMENTS.md §Methodology
validate the extrapolation against a directly-unrolled compile (<0.1%% off).

The full production (rolled) program is still compiled separately — THAT
compile proves the sharding is coherent at full depth and provides
memory_analysis; this module only reconstructs faithful cost totals.
"""
from __future__ import annotations

from typing import Dict, Tuple

from repro.configs.base import ArchConfig


def count_knobs(cfg: ArchConfig) -> Dict[str, int]:
    """Full per-group layer counts for each scan group of the architecture."""
    if cfg.family == "vlm" and cfg.cross_attn_every:
        n_super = cfg.n_layers // cfg.cross_attn_every
        tail = cfg.n_layers - n_super * cfg.cross_attn_every
        k = {"super": n_super}
        if tail:
            k["tail"] = tail
        return k
    if cfg.family == "hybrid" and cfg.shared_attn_every:
        n_super = cfg.n_layers // cfg.shared_attn_every
        tail = cfg.n_layers - n_super * cfg.shared_attn_every
        k = {"super": n_super}
        if tail:
            k["tail"] = tail
        return k
    if cfg.family == "ssm" and cfg.slstm_every:
        n_super = cfg.n_layers // cfg.slstm_every
        tail = cfg.n_layers - n_super * cfg.slstm_every
        k = {"super": n_super}
        if tail:
            k["tail"] = tail
        return k
    if cfg.is_moe:
        k = {}
        if cfg.first_dense_layers:
            k["dense"] = cfg.first_dense_layers
        k["moe"] = cfg.n_layers - cfg.first_dense_layers
        return k
    return {"blocks": cfg.n_layers}


def with_counts(cfg: ArchConfig, counts: Dict[str, int]) -> ArchConfig:
    """Rebuild the config with reduced per-group counts (same layer shapes)."""
    if cfg.family == "vlm" and cfg.cross_attn_every:
        n = cfg.cross_attn_every * counts["super"] + counts.get("tail", 0)
        return cfg.replace(n_layers=n)
    if cfg.family == "hybrid" and cfg.shared_attn_every:
        n = cfg.shared_attn_every * counts["super"] + counts.get("tail", 0)
        return cfg.replace(n_layers=n)
    if cfg.family == "ssm" and cfg.slstm_every:
        n = cfg.slstm_every * counts["super"] + counts.get("tail", 0)
        return cfg.replace(n_layers=n)
    if cfg.is_moe:
        fd = counts.get("dense", 0)
        return cfg.replace(first_dense_layers=fd, n_layers=fd + counts["moe"])
    return cfg.replace(n_layers=counts["blocks"])


def probe_plan(cfg: ArchConfig):
    """Returns (full_counts, [(name, counts) probe configs]).

    Probes: base = all groups 1; then one probe per group with that group=2.
    """
    full = count_knobs(cfg)
    base = {g: 1 for g in full}
    probes = [("base", dict(base))]
    for g in full:
        c = dict(base)
        c[g] = 2
        probes.append((g, c))
    return full, probes


def extrapolate(full: Dict[str, int], probe_costs: Dict[str, Dict[str, float]]
                ) -> Dict[str, float]:
    """probe_costs: {'base': {...}, '<group>': {...}} of cost dicts -> full
    cost dict.  cost(base)=glue+sum c_g; cost(g)=base+c_g."""
    base = probe_costs["base"]
    out = {}
    for key in base:
        c_g = {g: probe_costs[g][key] - base[key] for g in full}
        glue = base[key] - sum(c_g.values())
        out[key] = glue + sum(c_g[g] * full[g] for g in full)
        # numerical floor: costs cannot be negative
        out[key] = max(out[key], 0.0)
    return out

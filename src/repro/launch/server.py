"""Correction-server launcher: run the server half of the collaborative
protocol as its own process (``serving/server.py``), listening on a
Unix-domain or TCP socket for ``wire``-transport edge engines.

Client and server must agree on the model: both sides build the SAME
config and deterministic PRNGKey(0) init (or both restore the same
checkpoint via ``--ckpt-dir``) — parameters never cross the wire, only
protocol bytes (backlog tokens, scores) do.

Run:  PYTHONPATH=src python -m repro.launch.server --arch granite-8b \
          --uds /tmp/corr.sock --slots 16 --max-len 72
      PYTHONPATH=src python -m repro.launch.server \
          --arch paper-synthetic-serving --port 7431 --slots 128

then point clients at it:

      PYTHONPATH=src python -m repro.launch.serve --arch granite-8b \
          --engine collab --mode async --transport wire \
          --address /tmp/corr.sock
"""
from __future__ import annotations

import argparse
import os
import signal
import sys
import threading
import time
from typing import Optional, Tuple

import jax

from repro.configs import registry
from repro.core import decomposition as deco
from repro.training import checkpoint as ckpt


def resolve_config(name: str, smoke: bool = True):
    """Registry archs plus the paper-synthetic SERVING preset (the
    bench_serving workload, which lives outside the registry)."""
    if name == "paper-synthetic-serving":
        from repro.configs.paper_synthetic import SERVING
        return SERVING
    return registry.get_smoke(name) if smoke else registry.get_full(name)


def config_names():
    return registry.names() + ["paper-synthetic-serving"]


def spawn_subprocess(arch: str, *, uds: str, slots: int, max_len: int,
                     ready_file: str, ckpt_dir: Optional[str] = None,
                     extra_args: Tuple[str, ...] = (), quiet: bool = True,
                     timeout_s: Optional[float] = None,
                     wait: bool = True) -> "subprocess.Popen":
    """Start ``python -m repro.launch.server`` as a subprocess and block
    until it is listening (the ready file appears) or ``timeout_s``
    elapses.  Shared by the bench, the example demo, tests, and the
    fleet supervisor so the spawn/ready/teardown dance exists once.

    ``timeout_s=None`` uses the ``REPRO_SPAWN_DEADLINE_S`` env override
    (default 240 s — jax import on a loaded 2-core CI container can eat
    most of the old hardcoded 180 s).  ``wait=False`` returns the Popen
    immediately (the fleet supervisor ready-waits N servers in parallel
    with ``wait_ready``)."""
    import subprocess

    if timeout_s is None:
        timeout_s = float(os.environ.get("REPRO_SPAWN_DEADLINE_S", "240"))
    src = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "repro.launch.server", "--arch", arch,
           "--uds", uds, "--slots", str(slots), "--max-len", str(max_len),
           "--ready-file", ready_file]
    if ckpt_dir:
        cmd += ["--ckpt-dir", ckpt_dir]
    cmd += list(extra_args)
    pipe = subprocess.PIPE if quiet else None
    proc = subprocess.Popen(cmd, env=env, stdout=pipe, stderr=pipe,
                            text=quiet or None)
    if wait:
        wait_ready(proc, ready_file, timeout_s, quiet=quiet)
    return proc


def wait_ready(proc: "subprocess.Popen", ready_file: str,
               timeout_s: float, *, quiet: bool = True) -> None:
    """Block until ``ready_file`` exists or the process dies/times out."""
    deadline = time.monotonic() + timeout_s
    while not os.path.exists(ready_file):
        if proc.poll() is not None:
            err = proc.stderr.read()[-2000:] if quiet else ""
            raise RuntimeError(f"correction server died: {err}")
        if time.monotonic() > deadline:
            proc.terminate()
            raise RuntimeError("correction server startup timed out")
        time.sleep(0.05)


def _force_host_devices(mesh: str) -> None:
    """CPU convenience for ``--mesh data:N``: pin the placeholder host
    device count so a plain CPU host (which exposes ONE device) can
    build the mesh.  Must run before the first jax computation — the
    backend initialises lazily, so appending to XLA_FLAGS here works as
    long as nothing has touched devices yet.  A count already pinned in
    XLA_FLAGS wins; the flag only affects the host (CPU) platform."""
    from repro.serving.mesh import MeshSpec
    n = MeshSpec.parse(mesh).n_devices
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}").strip()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", required=True, choices=config_names())
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--uds", default=None, help="Unix-domain socket path")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=None,
                    help="TCP port (0 = ephemeral); default is UDS")
    ap.add_argument("--slots", type=int, default=16,
                    help="super-batch rows leased to client sessions")
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--no-coalesce", action="store_true",
                    help="disable request coalescing server-wide "
                         "(per-request replays; the bench baseline)")
    ap.add_argument("--transport", choices=("wire", "shm"), default="wire",
                    help="'shm' additionally offers same-host clients a "
                         "shared-memory ring arena on the HELLO handshake "
                         "(UDS only; wire clients are still served)")
    ap.add_argument("--mesh", default=None, metavar="SPEC",
                    help="shard the super-batch cache over a device mesh, "
                         "e.g. 'data:8' (slots must divide; on a CPU host "
                         "the placeholder device count is forced "
                         "automatically — see docs/sharding.md)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ready-file", default=None,
                    help="touch this path once listening (subprocess sync)")
    ap.add_argument("--idle-exit-s", type=float, default=None,
                    help="exit after all sessions have been gone this long")
    ap.add_argument("--stats-file", default=None,
                    help="heartbeat: atomically rewrite this JSON file "
                         "with a stats snapshot every --stats-interval-s "
                         "(the fleet supervisor's load/liveness channel)")
    ap.add_argument("--stats-interval-s", type=float, default=0.5)
    ap.add_argument("--trace-file", default=None,
                    help="record server-side spans (queue wait / replay) "
                         "and export Perfetto JSON here on shutdown")
    args = ap.parse_args(argv)

    if (args.uds is None) == (args.port is None):
        ap.error("exactly one of --uds / --port is required")

    if args.mesh is not None:
        # must precede the first jax computation: a CPU host exposes one
        # device unless the platform device count is forced.  jax was
        # only IMPORTED above (the backend initialises lazily at first
        # use), so setting XLA_FLAGS here still takes effect.
        _force_host_devices(args.mesh)

    cfg = resolve_config(args.arch, args.smoke)
    params = deco.init_collab_lm(jax.random.PRNGKey(0), cfg)
    if args.ckpt_dir:
        _, params, _ = ckpt.load(args.ckpt_dir, params)
        print(f"restored {args.ckpt_dir}", flush=True)

    from repro.serving.server import CorrectionServer
    from repro.serving.tracker import JsonFileTracker
    tracker = (JsonFileTracker(args.stats_file)
               if args.stats_file else None)
    tracer = None
    if args.trace_file:
        from repro.observability import Tracer
        tracer = Tracer()
    srv = CorrectionServer(cfg, params, slots=args.slots,
                           max_len=args.max_len, uds=args.uds,
                           host=args.host,
                           port=args.port if args.port is not None else 0,
                           coalesce=not args.no_coalesce, mesh=args.mesh,
                           tracker=tracker, tracer=tracer,
                           stats_interval_s=args.stats_interval_s,
                           shm=args.transport == "shm")
    print(f"correction server: arch={args.arch} slots={args.slots} "
          f"max_len={args.max_len} coalesce={not args.no_coalesce} "
          f"transport={args.transport} "
          f"mesh={srv.mesh_spec} listening on {srv.address}", flush=True)
    if args.ready_file:
        with open(args.ready_file, "w") as fh:
            fh.write(srv.address + "\n")

    stop = threading.Event()
    for sig in (signal.SIGTERM, signal.SIGINT):
        try:
            signal.signal(sig, lambda *_: stop.set())
        except ValueError:
            pass  # not the main thread
    try:
        # SIGUSR1 = drain: GOAWAY the sessions, refuse new HELLOs, exit
        # once empty — the fleet supervisor's graceful-retire signal
        signal.signal(signal.SIGUSR1, lambda *_: srv.request_drain())
    except (ValueError, AttributeError):
        pass  # not the main thread / platform without SIGUSR1
    try:
        srv.serve_forever(stop=stop, idle_exit_s=args.idle_exit_s)
    finally:
        st = srv.stats
        if tracker is not None:
            tracker.log_summary(srv.stats_snapshot())
        if tracer is not None:
            n = tracer.export(args.trace_file)
            print(f"trace: {n} spans -> {args.trace_file}", flush=True)
        print(f"served {st['sessions']} sessions, {st['requests']} requests "
              f"in {st['replays']} replays ({st['coalesced']} coalesced), "
              f"{st['attaches']} attaches / {st['detaches']} detaches, "
              f"{st['defrags']} lease defrags "
              f"(lease_fragmentation={srv.fragmentation():.3f}), "
              f"rx {st['bytes_rx']:,}B tx {st['bytes_tx']:,}B", flush=True)
        srv.close()


if __name__ == "__main__":
    main()

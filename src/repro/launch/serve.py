"""Serving launcher: batched collaborative monitoring over token streams.

The jitted serve step (server decode + corrector, edge decode + monitor,
gated combine) is the same function the dry-run lowers for decode_32k /
long_500k; here it runs on the host mesh with a reduced config.

Run:  PYTHONPATH=src python -m repro.launch.serve --arch zamba2-7b \
          --smoke --tokens 64 --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core import decomposition as deco
from repro.data import tokens as tok
from repro.launch.steps import EDGE_CACHE_LEN, make_serve_step
from repro.models import api as model_api
from repro.training import checkpoint as ckpt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.names())
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = registry.get_smoke(args.arch) if args.smoke else registry.get_full(args.arch)
    key = jax.random.PRNGKey(0)
    params = deco.init_collab_lm(key, cfg)
    if args.ckpt_dir:
        _, params, _ = ckpt.load(args.ckpt_dir, params)
        print(f"restored {args.ckpt_dir}")

    B, cap = args.batch, args.tokens + 8
    ecfg = deco.edge_arch(cfg)
    server_cache = model_api.init_cache(cfg, B, cap)
    edge_cache = model_api.init_cache(ecfg, B, min(cap, EDGE_CACHE_LEN))
    serve_step = jax.jit(make_serve_step(cfg))

    stream = next(tok.lm_batches(5, cfg, B, args.tokens))["tokens"]
    trig = np.zeros((B, args.tokens), bool)
    t0 = time.time()
    for t in range(args.tokens):
        out = serve_step(params, server_cache, edge_cache,
                         jnp.asarray(stream[:, t]), jnp.asarray(t, jnp.int32))
        server_cache, edge_cache = out["server_cache"], out["edge_cache"]
        trig[:, t] = np.asarray(out["mask"]) > 0
    dt = (time.time() - t0) / args.tokens
    print(f"{args.tokens} steps x batch {B}:  {dt*1e3:.1f} ms/step  "
          f"({B/dt:.1f} tok/s)")
    for b in range(B):
        print(f"  stream {b}: " + "".join("!" if x else "." for x in trig[b]))
    print(f"trigger rate {trig.mean():.3f}")


if __name__ == "__main__":
    main()

"""Serving launcher: batched collaborative monitoring over token streams.

Two engines:

  * the default jitted serve step (server decode + corrector, edge decode
    + monitor, gated combine) — the same function the dry-run lowers for
    decode_32k / long_500k; it runs on the host mesh with a reduced config.
  * ``--engine collab`` — the trigger-gated collaborative engine, served
    through the ``MonitorSession`` API: one ``SessionConfig`` describes
    the mode (sync / async), transport, staleness, and address
    (``--transport``, ``--max-staleness``, ``--latency-ms`` — see
    docs/api.md and docs/protocol.md).

Run:  PYTHONPATH=src python -m repro.launch.serve --arch zamba2-7b \
          --smoke --tokens 64 --batch 4
      PYTHONPATH=src python -m repro.launch.serve --arch granite-8b \
          --engine collab --mode async --latency-ms 20 --max-staleness 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core import decomposition as deco
from repro.data import tokens as tok
from repro.launch.steps import EDGE_CACHE_LEN, make_serve_step
from repro.models import api as model_api
from repro.training import checkpoint as ckpt


def run_collab(args, cfg, params) -> None:
    """Trigger-gated collaborative serving through the MonitorSession API
    (sync, or async-pipelined; any transport incl. the real wire)."""
    from repro.serving import SessionConfig, TransportSpec
    from repro.serving.collaborative import CollaborativeEngine

    B, S = args.batch, args.tokens
    stream = next(tok.lm_batches(5, cfg, B, S))["tokens"]
    eng = CollaborativeEngine(params, cfg, batch=B, max_len=S + 8)
    if args.transport in ("wire", "shm") and not args.address:
        raise SystemExit(f"--transport {args.transport} needs --address "
                         "(start: python -m repro.launch.server)")
    latency_s = (None if args.latency_ms is None or args.transport in
                 ("inproc", "wire", "shm") else args.latency_ms * 1e-3)
    # one config describes the whole session: mode="sync" over the wire is
    # the strict max_staleness=0 boundary (every trigger pays the measured
    # round trip); plain sync uses the blocking in-process path
    spec = (TransportSpec(args.transport, address=args.address,
                          latency_s=latency_s)
            if (args.mode == "async" or args.transport in ("wire", "shm"))
            else TransportSpec())
    config = SessionConfig(mode=args.mode, transport=spec,
                           max_staleness=args.max_staleness,
                           mesh=args.mesh, trace=args.trace is not None)
    t0 = time.time()
    with eng.session(config) as session:
        res = session.run(stream)
        if args.trace is not None:
            n = session.export_trace(args.trace)
            print(f"trace: {n} spans -> {args.trace} "
                  "(load in Perfetto / chrome://tracing)")
            from repro.observability.report import breakdown_table
            for line in breakdown_table(session.tracer.spans()):
                print(line)
    dt = (time.time() - t0) / S
    print(f"{args.mode} collab engine: {S} steps x batch {B}:  "
          f"{dt * 1e3:.1f} ms/step  ({B / dt:.1f} tok/s)")
    for b in range(B):
        print(f"  stream {b}: "
              + "".join("!" if x else "." for x in res["triggered"][b]))
    rep = res["comms"]
    print(f"trigger rate {rep['trigger_rate']:.3f}  |  "
          f"reduction {rep['reduction_x']:.1f}x")
    if "async" in rep:
        a = rep["async"]
        print(f"async: {a['requests']} requests, {a['merged_late']} merged "
              f"late, overlap {a['overlap_ratio']:.2f}, "
              f"stall {a['stall_s'] * 1e3:.0f} ms")
    if "wire" in rep:
        w = rep["wire"]
        print(f"wire (measured): {w['tx_bytes']:,}B tx / "
              f"{w['rx_bytes']:,}B rx, RTT mean "
              f"{w['rtt_mean_s'] * 1e3:.2f} ms / max "
              f"{w['rtt_max_s'] * 1e3:.2f} ms over {w['replies']} replies")
    if "shm" in rep:
        s = rep["shm"]
        print(f"shm rings (measured): {s['tx_bytes']:,}B tx / "
              f"{s['rx_bytes']:,}B rx, RTT mean "
              f"{s['rtt_mean_s'] * 1e3:.2f} ms / max "
              f"{s['rtt_max_s'] * 1e3:.2f} ms over {s['replies']} replies")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.names())
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--engine", choices=("step", "collab"), default="step")
    ap.add_argument("--mode", choices=("sync", "async"), default="sync")
    ap.add_argument("--transport", default="stream",
                    choices=("inproc", "stream", "thread", "mock_remote",
                             "wire", "shm"))
    ap.add_argument("--address", default=None,
                    help="wire/shm transport: correction server UDS path "
                         "or host:port (python -m repro.launch.server; "
                         "shm needs a UDS on the same host)")
    ap.add_argument("--max-staleness", type=int, default=8)
    ap.add_argument("--latency-ms", type=float, default=None,
                    help="simulated RTT; default keeps the transport's own")
    ap.add_argument("--mesh", default=None, metavar="SPEC",
                    help="collab engine only: mesh-shard per-stream state, "
                         "e.g. 'data:8' (batch must divide; see "
                         "docs/sharding.md)")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="collab engine only: trace the session "
                         "(SessionConfig(trace=True)) and export Perfetto "
                         "JSON to FILE, printing the critical-path "
                         "breakdown (docs/observability.md)")
    args = ap.parse_args()
    if args.trace is not None and args.engine != "collab":
        ap.error("--trace serves the collab engine (use --engine collab)")

    if args.mesh is not None:
        if args.engine != "collab":
            ap.error("--mesh serves the collab engine (use --engine collab)")
        from repro.launch.server import _force_host_devices
        _force_host_devices(args.mesh)  # before the first jax computation

    cfg = registry.get_smoke(args.arch) if args.smoke else registry.get_full(args.arch)
    key = jax.random.PRNGKey(0)
    params = deco.init_collab_lm(key, cfg)
    if args.ckpt_dir:
        _, params, _ = ckpt.load(args.ckpt_dir, params)
        print(f"restored {args.ckpt_dir}")

    if args.engine == "collab":
        run_collab(args, cfg, params)
        return

    B, cap = args.batch, args.tokens + 8
    ecfg = deco.edge_arch(cfg)
    server_cache = model_api.init_cache(cfg, B, cap)
    edge_cache = model_api.init_cache(ecfg, B, min(cap, EDGE_CACHE_LEN))
    serve_step = jax.jit(make_serve_step(cfg))

    stream = next(tok.lm_batches(5, cfg, B, args.tokens))["tokens"]
    trig = np.zeros((B, args.tokens), bool)
    t0 = time.time()
    for t in range(args.tokens):
        out = serve_step(params, server_cache, edge_cache,
                         jnp.asarray(stream[:, t]), jnp.asarray(t, jnp.int32))
        server_cache, edge_cache = out["server_cache"], out["edge_cache"]
        trig[:, t] = np.asarray(out["mask"]) > 0
    dt = (time.time() - t0) / args.tokens
    print(f"{args.tokens} steps x batch {B}:  {dt*1e3:.1f} ms/step  "
          f"({B/dt:.1f} tok/s)")
    for b in range(B):
        print(f"  stream {b}: " + "".join("!" if x else "." for x in trig[b]))
    print(f"trigger rate {trig.mean():.3f}")


if __name__ == "__main__":
    main()

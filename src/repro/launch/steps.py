"""Shape-complete step functions for the dry-run / launchers.

Three steps per architecture, matching the assigned input-shape kinds:

  train_step    (train_4k)    : collaborative fwd + loss + grads + Adam
  prefill_step  (prefill_32k) : collaborative fwd (monitor + corrector scores)
  serve_step    (decode_32k / long_500k): ONE new token against a seq_len
                KV/SSM cache — server decode + corrector, edge decode +
                monitor, fused combine, trigger mask.

``monitor_step`` is the edge-only path (no server tower): tests assert its
lowered HLO contains no model-axis collectives (paper locality requirement).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import decomposition as deco
from repro.core.gating import masked_correction
from repro.core.losses import collab_lm_loss
from repro.models import api as model_api
from repro.models.base import decode_capacity
from repro.nn.module import linear
from repro.training.optimizer import AdamW

EDGE_CACHE_LEN = 1024  # edge ring-buffer budget (device memory constraint)


def make_train_step(cfg: ArchConfig, opt: AdamW) -> Callable:
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            out = deco.collab_forward(p, cfg, batch)
            return collab_lm_loss(out, batch)["total"]

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params2, opt_state2, gnorm = opt.update(grads, opt_state, params)
        return params2, opt_state2, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_prefill_step(cfg: ArchConfig) -> Callable:
    def prefill_step(params, batch):
        out = deco.collab_forward(params, cfg, batch)
        m = cfg.monitor
        fhat, mask = masked_correction(out["u"], out["corr"], m.threshold,
                                       m.trigger_margin)
        return {"logits": out["logits"], "u": out["u"], "fhat": fhat,
                "trigger_rate": jnp.mean(mask)}

    return prefill_step


def _edge_u(params, cfg: ArchConfig, hidden_t):
    hd = params["u_head"]
    feats = jnp.tanh(linear(hd["w_feat"], hidden_t.astype(jnp.float32)))
    return feats @ hd["a"] + jax.nn.softplus(hd["raw_t"])


def make_serve_step(cfg: ArchConfig) -> Callable:
    ecfg = deco.edge_arch(cfg)
    m = cfg.monitor

    def serve_step(params, server_cache, edge_cache, tokens, pos):
        logits, h, new_sc = model_api.decode_step(params["server"], cfg,
                                                  server_cache, tokens, pos)
        v = linear(params["v_head"], h.astype(jnp.float32))[..., 0]
        etok = tokens[..., 0] if cfg.family == "audio" and ecfg.family != "audio" else tokens
        _, eh, new_ec = model_api.decode_step(params["edge"], ecfg,
                                              edge_cache, etok, pos)
        u = _edge_u(params, cfg, eh)
        corr = m.s * jax.nn.sigmoid(v)
        fhat, mask = masked_correction(u, corr, m.threshold, m.trigger_margin)
        return {"logits": logits, "u": u, "fhat": fhat, "mask": mask,
                "server_cache": new_sc, "edge_cache": new_ec}

    return serve_step


def make_monitor_step(cfg: ArchConfig) -> Callable:
    """Edge-only decode step (the device's always-on path)."""
    ecfg = deco.edge_arch(cfg)

    def monitor_step(params, edge_cache, tokens, pos):
        _, eh, new_ec = model_api.decode_step(params["edge"], ecfg,
                                              edge_cache, tokens, pos)
        u = _edge_u(params, cfg, eh)
        return {"u": u, "edge_cache": new_ec}

    return monitor_step


# ---------------------------------------------------------------------------
# Shape-only inputs for each step (dry-run)
# ---------------------------------------------------------------------------


def step_and_specs(cfg: ArchConfig, shape: ShapeConfig, key=None
                   ) -> Tuple[Callable, Tuple]:
    """Returns (step_fn, example ShapeDtypeStruct args)."""
    params = jax.eval_shape(
        lambda: deco.init_collab_lm(jax.random.PRNGKey(0), cfg))
    B, S = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        opt = AdamW(lr=3e-4)
        opt_state = jax.eval_shape(lambda: opt.init(params))
        batch = model_api.input_specs(cfg, shape)
        return make_train_step(cfg, opt), (params, opt_state, batch)

    if shape.kind == "prefill":
        batch = model_api.input_specs(cfg, shape)
        return make_prefill_step(cfg), (params, batch)

    # decode
    ecfg = deco.edge_arch(cfg)
    server_cache = jax.eval_shape(lambda: model_api.init_cache(cfg, B, S))
    edge_cache = jax.eval_shape(
        lambda: model_api.init_cache(ecfg, B, min(S, EDGE_CACHE_LEN)))
    if cfg.family == "audio":
        tokens = jax.ShapeDtypeStruct((B, cfg.n_codebooks), jnp.int32)
    else:
        tokens = jax.ShapeDtypeStruct((B,), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return make_serve_step(cfg), (params, server_cache, edge_cache, tokens, pos)

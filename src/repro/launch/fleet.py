"""Fleet launcher: run a supervised fleet of correction servers behind a
least-loaded router (``serving/fleet.py``).

The supervisor spawns N ``repro.launch.server`` subprocesses (each with
a JSON heartbeat file), opens the routing endpoint, and then loops:
route HELLOs to the least-loaded live server, scrape heartbeats, reap
dead servers (respawning unless ``--no-respawn``), retire drained ones.

Run:  PYTHONPATH=src python -m repro.launch.fleet \
          --arch paper-synthetic-serving --n-servers 2 --slots 64 \
          --max-len 64 --router-uds /tmp/fleet.sock

then point clients at the ROUTER with a ``fleet:`` address:

      TransportSpec.parse("fleet:/tmp/fleet.sock")

Signals: SIGTERM/SIGINT shut the fleet down (servers terminated, a
final aggregated summary printed); SIGUSR1 drains server 0 — handy for
poking failover by hand.
"""
from __future__ import annotations

import argparse
import json
import signal
import threading


def main(argv=None) -> None:
    from repro.launch.server import config_names
    from repro.serving.fleet import FleetSupervisor
    from repro.serving.tracker import LogTracker

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", required=True, choices=config_names())
    ap.add_argument("--n-servers", type=int, default=2)
    ap.add_argument("--slots", type=int, default=16,
                    help="super-batch rows per server")
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--root", default=None,
                    help="directory for per-server sockets/heartbeats "
                         "(default: a fresh tempdir)")
    ap.add_argument("--router-uds", default=None,
                    help="router listen path (default <root>/router.sock)")
    ap.add_argument("--router-port", type=int, default=None,
                    help="TCP router instead of UDS (0 = ephemeral)")
    ap.add_argument("--heartbeat-timeout-s", type=float, default=5.0)
    ap.add_argument("--no-respawn", action="store_true",
                    help="do not replace dead servers")
    ap.add_argument("--no-coalesce", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ready-file", default=None,
                    help="write the router address here once every "
                         "server is up (subprocess sync)")
    ap.add_argument("--log-interval-s", type=float, default=5.0,
                    help="aggregated fleet summary print interval")
    args = ap.parse_args(argv)

    sup = FleetSupervisor(
        args.arch, n_servers=args.n_servers, slots=args.slots,
        max_len=args.max_len, backend="subprocess", root=args.root,
        router_uds=args.router_uds, router_port=args.router_port,
        heartbeat_timeout_s=args.heartbeat_timeout_s,
        respawn=not args.no_respawn, ckpt_dir=args.ckpt_dir,
        coalesce=not args.no_coalesce)
    print(f"fleet: {args.n_servers} x {args.arch} (slots={args.slots}) "
          f"router on {sup.router_address} — waiting for servers",
          flush=True)
    sup.start(wait=True)
    print(f"fleet: all {args.n_servers} servers ready", flush=True)
    if args.ready_file:
        with open(args.ready_file, "w") as fh:
            fh.write(sup.router_address + "\n")

    stop = threading.Event()
    for sig in (signal.SIGTERM, signal.SIGINT):
        try:
            signal.signal(sig, lambda *_: stop.set())
        except ValueError:
            pass
    try:
        signal.signal(signal.SIGUSR1,
                      lambda *_: sup.drain(next(iter(sup.servers))))
    except (ValueError, AttributeError):
        pass

    log = LogTracker(prefix="fleet")
    import time
    last = 0.0
    try:
        while not stop.is_set():
            sup.tick(0.05)
            now = time.monotonic()
            if now - last >= args.log_interval_s:
                last = now
                agg = sup.aggregate()
                log.log({"n_live": agg["totals"].get("n_live"),
                         "routed": agg["totals"].get("routed"),
                         "leased_rows": agg["totals"].get("leased_rows", 0),
                         "respawns": agg["totals"].get("respawns"),
                         # fleet-wide worst-case latency percentiles
                         # (max over live servers; None until observed)
                         "replay_s_p99": agg["totals"].get("replay_s_p99"),
                         "queue_wait_s_p99":
                             agg["totals"].get("queue_wait_s_p99")})
    finally:
        agg = sup.aggregate()
        sup.close()
        print("fleet summary: " + json.dumps(agg["totals"], default=str),
              flush=True)


if __name__ == "__main__":
    main()

"""Roofline-term extraction from a compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

``cost_analysis`` (post-SPMD, per-device) supplies FLOPs/bytes; collective
bytes are parsed from the partitioned HLO text (result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute).
HLO shapes in the partitioned module are per-device, so all three terms are
per-chip quantities; the brief's global formulation (X / (chips * BW))
is identical.  Target: TPU v5e.
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass
from typing import Dict, Optional

# TPU v5e per-chip constants (brief-specified)
PEAK_FLOPS = 197e12        # bf16 FLOP/s
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|"
                       r"u64|u32|u16|u8|pred|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device result bytes of every collective op, by op kind.

    Matches both sync (``all-reduce(``) and async-start forms; ``-done`` ops
    are skipped (their bytes were counted at ``-start``).
    """
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        _, rhs = stripped.split("=", 1)
        rhs = rhs.strip()
        for kind in _COLLECTIVES:
            idx = rhs.find(kind + "(")
            if idx < 0:
                idx = rhs.find(kind + "-start(")
            if idx <= 0:  # idx==0 would mean no result type: not an op line
                continue
            for dt, dims in _SHAPE_RE.findall(rhs[:idx]):
                out[kind] += _shape_bytes(dt, dims)
            break
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collective_breakdown: Dict[str, int]
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float            # 6*N*D (or 2*N*D fwd-only), ACTIVE params
    useful_flops_ratio: float     # model_flops / (chips * flops_per_device)
    bytes_per_device_peak: Optional[float] = None  # memory_analysis if available

    def as_dict(self) -> Dict:
        return asdict(self)


def cost_dict(compiled) -> Dict[str, float]:
    """Flat cost record of one compiled artifact (per-device)."""
    ca = compiled.cost_analysis() or {}
    cb = collective_bytes(compiled.as_text())
    d = {"flops": float(ca.get("flops", 0.0)),
         "bytes": float(ca.get("bytes accessed", 0.0))}
    for k, v in cb.items():
        d["coll_" + k] = float(v)
    return d


def analyze(compiled, *, arch: str, shape: str, mesh_desc: str, chips: int,
            model_flops: float) -> Roofline:
    return analyze_costs(cost_dict(compiled), arch=arch, shape=shape,
                         mesh_desc=mesh_desc, chips=chips,
                         model_flops=model_flops)


def analyze_costs(costs: Dict[str, float], *, arch: str, shape: str,
                  mesh_desc: str, chips: int, model_flops: float) -> Roofline:
    flops = costs["flops"]
    byts = costs["bytes"]
    cb = {k[len("coll_"):]: v for k, v in costs.items()
          if k.startswith("coll_")}
    ctotal = float(sum(cb.values()))
    terms = {"compute": flops / PEAK_FLOPS, "memory": byts / HBM_BW,
             "collective": ctotal / ICI_BW}
    bottleneck = max(terms, key=terms.get)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_desc,
        flops_per_device=flops, bytes_per_device=byts,
        collective_bytes_per_device=ctotal, collective_breakdown=cb,
        compute_s=terms["compute"], memory_s=terms["memory"],
        collective_s=terms["collective"], bottleneck=bottleneck,
        model_flops=model_flops,
        useful_flops_ratio=(model_flops / (chips * flops)) if flops else 0.0,
    )


def fmt_row(r: Roofline) -> str:
    return (f"{r.arch:22s} {r.shape:12s} {r.mesh:10s} "
            f"compute {r.compute_s*1e3:9.3f}ms  memory {r.memory_s*1e3:9.3f}ms  "
            f"collective {r.collective_s*1e3:9.3f}ms  -> {r.bottleneck:10s} "
            f"useful {100*r.useful_flops_ratio:5.1f}%")

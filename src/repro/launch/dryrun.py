"""Multi-pod dry-run: lower + compile every (architecture x input-shape) on
the production mesh, with ShapeDtypeStruct inputs (no allocation), and
extract the roofline terms.

MUST set the placeholder device count before ANY other import — jax locks
the device count at first initialisation.
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.base import INPUT_SHAPES, SHAPES_BY_NAME  # noqa: E402
from repro.configs import registry  # noqa: E402
from repro.core import decomposition as deco  # noqa: E402
from repro.distributed import sharding as shd  # noqa: E402
from repro.launch import roofline as rf  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import step_and_specs  # noqa: E402
from repro.nn.module import iter_paths  # noqa: E402
from repro.training.optimizer import AdamState  # noqa: E402


def _active_params(params_shapes, cfg) -> float:
    """Exact ACTIVE server-param count from the eval_shape tree: routed
    expert weights are scaled by top_k/n_experts."""
    total = routed = 0
    for path, leaf in iter_paths(params_shapes["server"]):
        if leaf is None or not hasattr(leaf, "size"):
            continue
        total += int(leaf.size)
        if "/moe/w_" in ("/" + path) or path.split("/")[-2:-1] == ["moe"]:
            if "/shared/" not in "/" + path and "/router" not in "/" + path:
                routed += int(leaf.size)
    if cfg.is_moe and routed:
        active = total - routed + routed * cfg.top_k / cfg.n_experts
    else:
        active = total
    return float(active), float(total)


def _model_flops(cfg, shape, params_shapes) -> float:
    active, _ = _active_params(params_shapes, cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        return 2.0 * active * shape.global_batch * shape.seq_len
    return 2.0 * active * shape.global_batch  # decode: one token per stream


def build_shardings(step_args, cfg, shape, mesh):
    """Shardings matching step_and_specs arg order for each step kind."""
    params = step_args[0]
    pshard = shd.param_shardings(params, mesh)
    rep = shd.replicated(mesh)
    if shape.kind == "train":
        _, opt_state, batch = step_args
        oshard = shd.opt_shardings(params, mesh, zero1=cfg.zero1)
        opt_shard = AdamState(count=rep, m=oshard, v=oshard)
        return (pshard, opt_shard, shd.batch_shardings(batch, mesh))
    if shape.kind == "prefill":
        _, batch = step_args
        return (pshard, shd.batch_shardings(batch, mesh))
    _, server_cache, edge_cache, tokens, pos = step_args
    B = shape.global_batch
    return (pshard,
            shd.cache_shardings(server_cache, mesh, B,
                                mode=cfg.decode_cache_shard),
            shd.cache_shardings(edge_cache, mesh, B, use_model=False),
            shd.batch_shardings({"t": tokens}, mesh)["t"],
            rep)


def _compile(cfg, shape, mesh):
    step_fn, args = step_and_specs(cfg, shape)
    in_shardings = build_shardings(args, cfg, shape, mesh)
    # NOTE (§Perf B3, refuted): donating the KV caches (in-place update) is
    # the deployment-correct choice on TPU, but the CPU backend inserts
    # extra copies under donation+sharding and the cost model penalises it
    # (+12% memory term, +10 GiB args+temp) — so the dry-run measures the
    # undonated form.
    with mesh:
        return (jax.jit(step_fn, in_shardings=in_shardings)
                .lower(*args).compile(), args)


def run_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
             verbose: bool = True, overrides: Optional[Dict] = None,
             skip_probes: bool = False) -> Dict:
    """One (arch x shape x mesh) dry-run record.

    1) FULL production program (rolled scans) lowered+compiled on the mesh —
       proves sharding coherence and yields memory_analysis.
    2) Small UNROLLED probe compiles (launch/layer_costs.py) -> faithful
       per-device FLOPs / bytes / collective bytes, linear in layer counts.
    """
    from repro.launch import layer_costs as lc

    cfg = registry.get_full(arch).replace(**(overrides or {}))
    shape = SHAPES_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_desc = "x".join(str(s) for s in mesh.devices.shape)
    chips = mesh.devices.size

    t0 = time.time()
    compiled_full, args = _compile(cfg, shape, mesh)
    t_full = time.time() - t0
    mem = compiled_full.memory_analysis()
    params_shapes = args[0]

    if skip_probes:
        costs = rf.cost_dict(compiled_full)
    else:
        full_counts, probes = lc.probe_plan(cfg)
        probe_costs = {}
        for name, counts in probes:
            cfg_p = lc.with_counts(cfg, counts).replace(scan_unroll=True)
            compiled_p, _ = _compile(cfg_p, shape, mesh)
            probe_costs[name] = rf.cost_dict(compiled_p)
        costs = lc.extrapolate(full_counts, probe_costs)
    t_probes = time.time() - t0 - t_full

    mf = _model_flops(cfg, shape, params_shapes)
    roof = rf.analyze_costs(costs, arch=arch, shape=shape_name,
                            mesh_desc=mesh_desc, chips=chips, model_flops=mf)
    rec = roof.as_dict()
    rec.update({
        "chips": chips,
        "compile_full_s": round(t_full, 1), "compile_probes_s": round(t_probes, 1),
        "memory_analysis": {
            k: int(getattr(mem, k, 0)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
        } if mem is not None else None,
    })
    if verbose:
        print(rf.fmt_row(roof), flush=True)
        if mem is not None:
            gb = (rec["memory_analysis"]["argument_size_in_bytes"]
                  + rec["memory_analysis"]["temp_size_in_bytes"]) / 2**30
            print(f"    args+temp per device: {gb:.2f} GiB   "
                  f"full-compile {t_full:.0f}s probes {t_probes:.0f}s", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else registry.names()
    shapes = [args.shape] if args.shape else [s.name for s in INPUT_SHAPES]
    results = []
    for a in archs:
        for s in shapes:
            try:
                rec = run_pair(a, s, multi_pod=args.multi_pod)
                rec["status"] = "ok"
            except Exception as e:  # a failure here is a sharding bug
                traceback.print_exc()
                rec = {"arch": a, "shape": s, "status": "FAIL",
                       "error": repr(e)}
            results.append(rec)
            if args.out:
                with open(args.out, "a") as fh:
                    fh.write(json.dumps(rec) + "\n")
    n_ok = sum(r["status"] == "ok" for r in results)
    print(f"\n{n_ok}/{len(results)} pairs lowered+compiled OK")
    if n_ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run JSONL
records (results/dryrun_single_pod.jsonl, results/dryrun_multi_pod.jsonl).

Usage:  PYTHONPATH=src python -m repro.launch.report [--results results]

This module only FORMATS; all numbers come from the recorded
``lower().compile()`` artifacts (see launch/dryrun.py).
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(path: str) -> List[Dict]:
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    # keep the LAST record per (arch, shape) — reruns supersede
    dedup: Dict = {}
    for r in out:
        dedup[(r.get("arch"), r.get("shape"))] = r
    recs = list(dedup.values())
    recs.sort(key=lambda r: (r.get("arch", ""),
                             SHAPE_ORDER.index(r["shape"])
                             if r.get("shape") in SHAPE_ORDER else 99))
    return recs


def _ms(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def _gib(b: float) -> str:
    return f"{b/2**30:.2f}"


def roofline_table(recs: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | compute | memory | collective | "
           "bottleneck | useful FLOPs | HBM GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|")
    rows = [hdr]
    for r in recs:
        if r.get("status") == "FAIL":
            rows.append(f"| {r['arch']} | {r['shape']} | — | FAIL | | | "
                        f"`{r.get('error','')[:60]}` | | |")
            continue
        mem = r.get("memory_analysis") or {}
        hbm = mem.get("argument_size_in_bytes", 0) + mem.get(
            "temp_size_in_bytes", 0)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {_ms(r['compute_s'])} | {_ms(r['memory_s'])} "
            f"| {_ms(r['collective_s'])} | **{r['bottleneck']}** "
            f"| {100*r['useful_flops_ratio']:.1f}% | {_gib(hbm)} |")
    return "\n".join(rows)


def dryrun_table(recs: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | per-dev FLOPs | per-dev HBM bytes | "
           "per-dev collective bytes | AG/AR/RS/A2A/CP (GiB) | compile s |\n"
           "|---|---|---|---|---|---|---|---|")
    rows = [hdr]
    for r in recs:
        if r.get("status") == "FAIL":
            rows.append(f"| {r['arch']} | {r['shape']} | — | FAIL "
                        f"`{r.get('error','')[:60]}` | | | | |")
            continue
        cb = r.get("collective_breakdown", {})
        brk = "/".join(f"{cb.get(k,0)/2**30:.2f}" for k in (
            "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
            "collective-permute"))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['flops_per_device']:.3e} | {r['bytes_per_device']:.3e} "
            f"| {r['collective_bytes_per_device']:.3e} | {brk} "
            f"| {r.get('compile_full_s',0)}+{r.get('compile_probes_s',0)} |")
    return "\n".join(rows)


def summarize(recs: List[Dict]) -> str:
    ok = [r for r in recs if r.get("status") == "ok"]
    fails = [r for r in recs if r.get("status") != "ok"]
    lines = [f"{len(ok)}/{len(recs)} pairs lowered + compiled OK."]
    if fails:
        lines.append("FAILURES: " + ", ".join(
            f"{r['arch']}x{r['shape']}" for r in fails))
    by_bneck: Dict[str, int] = {}
    for r in ok:
        by_bneck[r["bottleneck"]] = by_bneck.get(r["bottleneck"], 0) + 1
    lines.append("Bottleneck mix: " + ", ".join(
        f"{k}={v}" for k, v in sorted(by_bneck.items())))
    return "\n".join(lines)


def pick_hillclimb(recs: List[Dict]) -> List[str]:
    """Worst useful-FLOPs ratio / most collective-bound / paper-central."""
    ok = [r for r in recs if r.get("status") == "ok"]
    if not ok:
        return []
    worst = min(ok, key=lambda r: r["useful_flops_ratio"] or 1.0)
    coll = max(ok, key=lambda r: (r["collective_s"] /
                                  max(r["compute_s"], r["memory_s"], 1e-12)))
    notes = [
        f"worst useful-FLOPs: {worst['arch']} x {worst['shape']} "
        f"({100*worst['useful_flops_ratio']:.1f}%)",
        f"most collective-bound: {coll['arch']} x {coll['shape']} "
        f"(coll/max(other)={coll['collective_s']/max(coll['compute_s'], coll['memory_s']):.2f})",
    ]
    return notes


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results")
    args = ap.parse_args()
    for mesh_kind in ("single_pod", "multi_pod"):
        recs = load(os.path.join(args.results, f"dryrun_{mesh_kind}.jsonl"))
        print(f"\n## {mesh_kind} ({len(recs)} records)\n")
        print(summarize(recs))
        print()
        print(roofline_table(recs))
        if mesh_kind == "single_pod":
            print("\nHillclimb candidates:")
            for n in pick_hillclimb(recs):
                print(" -", n)


if __name__ == "__main__":
    main()

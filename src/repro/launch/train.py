"""Distributed training launcher.

On a real TPU pod this runs the pjit'd collaborative train step on the
production mesh; on this CPU container it runs the same code path on a
host mesh (1 device) with a reduced config — the sharding rules, step
function and checkpointing are identical (the 512-chip program is proven
by launch/dryrun.py).

Run (CPU, reduced):
    PYTHONPATH=src python -m repro.launch.train --arch granite-8b \
        --smoke --steps 50
Run (pod):
    python -m repro.launch.train --arch qwen1.5-110b --mesh production
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core import decomposition as deco
from repro.data import tokens as tok
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_train_step
from repro.training import checkpoint as ckpt
from repro.training.optimizer import AdamState, AdamW
from repro.training.schedule import warmup_cosine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.names())
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU)")
    ap.add_argument("--mesh", default="host",
                    choices=["host", "production", "multipod"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=500)
    args = ap.parse_args()

    cfg = registry.get_smoke(args.arch) if args.smoke else registry.get_full(args.arch)
    mesh = {"host": make_host_mesh,
            "production": make_production_mesh,
            "multipod": lambda: make_production_mesh(multi_pod=True)}[args.mesh]()
    print(f"mesh {dict(mesh.shape)}  arch {cfg.name}  "
          f"batch {args.batch} x seq {args.seq}")

    opt = AdamW(lr=warmup_cosine(args.lr, 100, max(args.steps, 1000)))
    step_fn = make_train_step(cfg, opt)

    key = jax.random.PRNGKey(0)
    with mesh:
        params = deco.init_collab_lm(key, cfg)
        opt_state = opt.init(params)
        pshard = shd.param_shardings(params, mesh)
        oshard = AdamState(count=shd.replicated(mesh), m=pshard, v=pshard)
        params = jax.device_put(params, pshard)
        opt_state = jax.device_put(opt_state, oshard)
        jit_step = jax.jit(step_fn, in_shardings=(pshard, oshard, None),
                           donate_argnums=(0, 1))

        batches = tok.lm_batches(0, cfg, args.batch, args.seq)
        t0 = time.time()
        for i in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
            batch = jax.device_put(batch, shd.batch_shardings(batch, mesh))
            params, opt_state, metrics = jit_step(params, opt_state, batch)
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:5d}  loss {float(metrics['loss']):.4f}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}  "
                      f"({(time.time()-t0)/(i+1):.2f}s/step)", flush=True)
            if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
                ckpt.save(args.ckpt_dir, i + 1, params, opt_state,
                          meta={"arch": cfg.name})
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, params, opt_state,
                  meta={"arch": cfg.name})
        print(f"checkpoint -> {args.ckpt_dir}")


if __name__ == "__main__":
    main()

"""Production meshes (TPU v5e pods).

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (the dry-run pins the device count via XLA_FLAGS
before any jax initialisation).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(data=16, model=16) single pod (256 chips) or
    (pod=2, data=16, model=16) two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over however many local devices exist (tests/examples)."""
    n = jax.device_count()
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))

"""xLSTM-350m [arXiv:2405.04517] — sLSTM + mLSTM blocks (xLSTM[7:1]).

24 layers, d_model=1024, 4 heads, d_ff=0 (blocks carry internal projections),
vocab=50304.  One sLSTM block every 8 layers.  long_500k is native: O(1)
recurrent state, no KV cache.
"""
from repro.configs.base import ArchConfig, MonitorConfig

FULL = ArchConfig(
    name="xlstm-350m", family="ssm", citation="arXiv:2405.04517",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50304, slstm_every=8, tie_embeddings=True,
    monitor=MonitorConfig(n_layers=2, d_model=256, n_heads=4, d_ff=1024,
                          n_features=64),
)

SMOKE = FULL.replace(
    n_layers=2, d_model=128, n_heads=2, n_kv_heads=2, vocab_size=512,
    slstm_every=2, remat=False, dtype="float32",
    monitor=MonitorConfig(n_layers=1, d_model=64, n_heads=2, d_ff=128,
                          n_features=16),
)

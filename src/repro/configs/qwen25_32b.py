"""Qwen2.5-32B [hf:Qwen/Qwen2.5-0.5B family] — dense decoder, GQA + QKV bias.

64 layers, d_model=5120, 40 heads (GQA kv=8), d_ff=27648, vocab=152064.
long_500k = swa-variant.
"""
from repro.configs.base import ArchConfig, MonitorConfig

FULL = ArchConfig(
    name="qwen2.5-32b", family="dense", citation="hf:Qwen/Qwen2.5-0.5B",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=27648,
    vocab_size=152064, qkv_bias=True, rope_theta=1e6,
    prefill_kv_shard="time",  # §Perf D1: 6.2x on this arch's pathological prefill collective

    long_context_window=8192,
    monitor=MonitorConfig(n_layers=2, d_model=256, n_heads=4, d_ff=1024,
                          n_features=64),
)

SMOKE = FULL.replace(
    n_layers=2, d_model=320, n_heads=5, n_kv_heads=1, d_ff=768,
    vocab_size=512, remat=False, dtype="float32",
    monitor=MonitorConfig(n_layers=1, d_model=64, n_heads=2, d_ff=128,
                          n_features=16),
)

"""Granite-8B-Code [arXiv:2405.04324] — llama-arch dense decoder for code.

36 layers, d_model=4096, 32 heads (GQA kv=8), d_ff=14336, vocab=49152.
long_500k runs as the swa-variant (8k window ring cache, DESIGN.md §5).
"""
from repro.configs.base import ArchConfig, MonitorConfig

FULL = ArchConfig(
    name="granite-8b", family="dense", citation="arXiv:2405.04324",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=49152, rope_theta=1e4, tie_embeddings=True,
    long_context_window=8192,
    monitor=MonitorConfig(n_layers=2, d_model=256, n_heads=4, d_ff=1024,
                          n_features=64),
)

SMOKE = FULL.replace(
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
    vocab_size=512, remat=False, dtype="float32",
    monitor=MonitorConfig(n_layers=1, d_model=64, n_heads=2, d_ff=128,
                          n_features=16),
)

from repro.configs.base import (INPUT_SHAPES, SHAPES_BY_NAME, ArchConfig,
                                MonitorConfig, ShapeConfig)  # noqa: F401
from repro.configs import registry  # noqa: F401

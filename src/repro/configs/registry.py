"""Architecture registry: ``get(name)`` -> module with FULL / SMOKE configs.

Every config cites its source (paper / model card) per the assignment pool.
``--arch <id>`` in the launchers resolves through here.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ArchConfig

_ARCHS: Dict[str, str] = {
    "zamba2-7b": "repro.configs.zamba2_7b",
    "granite-8b": "repro.configs.granite_8b",
    "qwen1.5-110b": "repro.configs.qwen15_110b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "qwen2.5-32b": "repro.configs.qwen25_32b",
    "musicgen-large": "repro.configs.musicgen_large",
    "qwen1.5-32b": "repro.configs.qwen15_32b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "llama-3.2-vision-11b": "repro.configs.llama32_vision_11b",
    "xlstm-350m": "repro.configs.xlstm_350m",
    # the paper's own experiments (synthetic cosine / financial monitoring)
    "paper-synthetic": "repro.configs.paper_synthetic",
    "paper-financial": "repro.configs.paper_financial",
}


def names(include_paper: bool = False) -> List[str]:
    ns = [n for n in _ARCHS if not n.startswith("paper-")]
    return ns + [n for n in _ARCHS if n.startswith("paper-")] if include_paper else ns


def get_full(name: str) -> ArchConfig:
    return importlib.import_module(_ARCHS[name]).FULL


def get_smoke(name: str) -> ArchConfig:
    return importlib.import_module(_ARCHS[name]).SMOKE


def get_module(name: str):
    return importlib.import_module(_ARCHS[name])

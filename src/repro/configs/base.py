"""Configuration dataclasses for the repro framework.

``ArchConfig`` describes one backbone (the server-side class ``V`` of the
paper); ``MonitorConfig`` describes the small on-device tower (class ``U``)
plus the decomposition hyper-parameters (s, t, n, sigma, threshold) of
  f_hat = u - s * sigma(v)        (paper Eq. 1).

Every assigned architecture gets a module in this package exporting
``FULL`` (the exact assigned config) and ``SMOKE`` (a reduced variant of the
same family: <=2 layers, d_model<=512, <=4 experts) plus ``input_specs``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Input shapes assigned to this paper (public pool).
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in INPUT_SHAPES}


# ---------------------------------------------------------------------------
# Monitor / decomposition config (the paper's contribution).
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MonitorConfig:
    """Edge tower ``u`` and decomposition hyper-parameters.

    The edge tower is a reduced same-family model whose penultimate features
    feed the paper's truncated-basis head ``u_{n,t} = sum_{i<=n} a_i phi_i + t``
    (Eq. 8).  ``s`` scales the server-side negative corrector ``-s*sigma(v)``.
    """

    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    d_ff: int = 1024
    # Truncated feature basis size (paper's ``n``); <= d_model.
    n_features: int = 64
    # Safety offset t (paper's ``t``); trainable initialisation value.
    t_init: float = 0.1
    # Corrector scale s (paper's ``s``).  s = 2*t is the Prop-2/3 optimum.
    s: float = 0.2
    # Warning threshold gamma and trigger margin for gated correction.
    threshold: float = 0.0
    trigger_margin: float = 0.25
    # Fraction of the batch the serving compactor reserves for correction.
    correction_capacity: float = 0.25
    sigma: str = "sigmoid"  # sigmoid | tanh01


# ---------------------------------------------------------------------------
# Backbone config.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    citation: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # Attention variants -----------------------------------------------------
    sliding_window: int = 0          # 0 => full attention during prefill
    long_context_window: int = 0     # window used for the long_500k decode
                                     # swa-variant (0 => native cache layout)

    # MoE ---------------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25

    # MLA (DeepSeek-V3) -------------------------------------------------------
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    mtp_depth: int = 0               # multi-token-prediction extra heads

    # SSM (Mamba2 / xLSTM) ----------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_heads: int = 0
    ssm_expand: int = 2
    ssm_chunk: int = 128

    # hybrid (zamba2): one shared attention block applied every k ssm blocks
    shared_attn_every: int = 0

    # xLSTM: one sLSTM block every k mLSTM blocks (0 => pure mLSTM)
    slstm_every: int = 0

    # VLM ----------------------------------------------------------------------
    cross_attn_every: int = 0
    n_image_tokens: int = 0

    # audio ---------------------------------------------------------------------
    n_codebooks: int = 0

    # distribution knobs (perf hillclimb levers; see EXPERIMENTS.md §Perf) -----
    # "time" (default since §Perf B1: flash-decode — shard the cache seq axis
    # over model; attention is local per time-shard, softmax/output combine
    # via small cross-shard reductions) | "heads" (the recorded baseline:
    # trailing kv-heads/head_dim dim over model).
    decode_cache_shard: str = "time"
    # MoE dispatch impl: "dense" (jit-SPMD global sort dispatch, recorded
    # baseline), "ep" (expert-parallel shard_map, §Perf A1), "auto" (ep when
    # a mesh with model | n_experts is active, else dense).
    moe_impl: str = "dense"
    # ZeRO-1: shard Adam moments over the data axes as well (§Perf A3).
    zero1: bool = False
    # Sequence parallelism (§Perf C1): constrain the residual stream to
    # P(batch, 'model', None) in norm/elementwise regions; XLA turns the
    # megatron all-reduce into reduce-scatter + all-gather at equal volume
    # while the replicated elementwise/norm traffic divides by the model
    # axis size (Korthikanti et al., adapted to SPMD constraints).
    seq_parallel: bool = False
    # Prefill KV sharding: "none" (default) | "time" (§Perf D1 — fixes the
    # involuntary-remat pathology when kv_heads % model != 0 AND propagation
    # mishandles it; arch-dependent, measured per arch before enabling).
    prefill_kv_shard: str = "none"

    # dtypes / memory -----------------------------------------------------------
    dtype: str = "bfloat16"          # activation/compute dtype
    param_dtype: str = "float32"     # parameter storage dtype
    remat: bool = True               # activation checkpointing in layer scans
    # Dry-run accounting mode: XLA's cost_analysis counts a while-loop body
    # ONCE, so the dry-run unrolls layer/chunk scans to get faithful
    # FLOP/byte/collective totals (runtime configs keep scans rolled).
    scan_unroll: bool = False

    # monitoring head taps the mean-pooled (or last-token) hidden state
    monitor: MonitorConfig = field(default_factory=MonitorConfig)

    # ------------------------------------------------------------------ utils
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # Parameter count estimate (used for MODEL_FLOPS = 6*N*D roofline term).
    def param_count(self, active_only: bool = False) -> int:
        d, h = self.d_model, self.resolved_head_dim
        nq, nkv = self.n_heads, self.n_kv_heads
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "audio", "hybrid"):
            if self.use_mla:
                attn = (
                    d * self.q_lora_rank
                    + self.q_lora_rank * nq * (self.qk_nope_dim + self.qk_rope_dim)
                    + d * (self.kv_lora_rank + self.qk_rope_dim)
                    + self.kv_lora_rank * nq * (self.qk_nope_dim + self.v_head_dim)
                    + nq * self.v_head_dim * d
                )
            else:
                attn = d * h * (nq + 2 * nkv) + nq * h * d
        else:
            attn = 0
        if self.family == "ssm" or self.family == "hybrid":
            d_in = self.ssm_expand * d
            ssm = d * 2 * d_in + d_in * d + d_in * (2 * self.ssm_state + 2)
        else:
            ssm = 0
        mlp_dense = 3 * d * self.d_ff if self.d_ff else 0
        if self.is_moe:
            per_expert = 3 * d * self.moe_d_ff
            moe_total = per_expert * (self.n_experts + self.n_shared_experts)
            moe_active = per_expert * (self.top_k + self.n_shared_experts)
            router = d * self.n_experts
        else:
            moe_total = moe_active = router = 0

        total = 0
        active = 0
        for li in range(self.n_layers):
            if self.family == "ssm":
                total += ssm
                active += ssm
                continue
            if self.family == "hybrid":
                total += ssm + mlp_dense  # mamba block + its mlp? zamba2 blocks are mamba-only
                active += ssm + mlp_dense
                continue
            if self.is_moe and li >= self.first_dense_layers:
                total += attn + moe_total + router
                active += attn + moe_active + router
            else:
                total += attn + mlp_dense
                active += attn + mlp_dense
        if self.family == "hybrid" and self.shared_attn_every:
            shared = attn + 3 * d * self.d_ff
            total += shared
            active += shared * (self.n_layers // self.shared_attn_every)
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.family == "audio" and self.n_codebooks:
            emb = self.n_codebooks * self.vocab_size * d * 2
        total += emb
        active += emb
        return active if active_only else total

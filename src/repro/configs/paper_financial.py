"""The paper's financial experiment (§4.2): predict one ticker's normalised
price from the other 29 (DJIA).  V = FC(29,64,128,256,1); u truncates the
penultimate layer to 16 units; warning threshold 0.8; appendix variant uses
an independent FC(29,10,1) monitor.

Offline container: the DJIA CSV is re-synthesised with matched statistics by
data/synthetic.py::financial_series (correlated GBM, 30 tickers, normalised
to [0,1]); documented in DESIGN.md §9.
"""
from repro.configs.paper_synthetic import PaperMLPConfig

FULL = PaperMLPConfig(
    name="paper-financial", in_dim=29, hidden=(64, 128, 256), n_basis=256,
    monitor_n=16, s=0.1, t_init=0.02, threshold=0.8,
    citation="paper §4.2 (DJIA, FC(29,64,128,256,1), truncate-16, gamma=0.8)",
)

SMOKE = PaperMLPConfig(
    name="paper-financial-smoke", in_dim=29, hidden=(16, 32, 48), n_basis=48,
    monitor_n=8, s=0.1, t_init=0.05, threshold=0.8,
)

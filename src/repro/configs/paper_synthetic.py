"""The paper's own synthetic experiment (§4.1): f(x) = sum_i 0.9^{i-1} cos(ix),
x ~ U[-3,3], server net V = FC(1,16,32,64,100,1), on-device net U truncated
from V's penultimate layer (Eq. 8).
"""
from dataclasses import dataclass, field
from typing import Tuple

from repro.configs.base import ArchConfig, MonitorConfig


@dataclass(frozen=True)
class PaperMLPConfig:
    name: str
    in_dim: int
    hidden: Tuple[int, ...]          # server net V hidden widths
    n_basis: int                     # width of V's penultimate layer (the phi_i)
    monitor_n: int                   # truncation n for u_{n,t}
    s: float                         # corrector scale
    t_init: float
    threshold: float                 # warning threshold gamma
    rho: float = 0.0                 # exponential-decay rate of the target
    citation: str = "paper §4"
    monitor: MonitorConfig = field(default_factory=MonitorConfig)


FULL = PaperMLPConfig(
    name="paper-synthetic", in_dim=1, hidden=(16, 32, 64, 100), n_basis=100,
    monitor_n=20, s=0.2, t_init=0.1, threshold=0.0, rho=0.9,
    citation="paper §4.1 (exponential decay, rho=0.9, 100 cosine modes)",
)

SMOKE = PaperMLPConfig(
    name="paper-synthetic-smoke", in_dim=1, hidden=(8, 16, 24), n_basis=24,
    monitor_n=8, s=0.3, t_init=0.15, threshold=0.0, rho=0.8,
)

# LM analogue of the synthetic experiment at the paper's tiny scale (the
# paper's U/V are small FC nets): 1-layer d64 server tower + matching edge
# monitor.  This is the serving-bench workload for the trigger-gated
# collaborative engine (bench_serving, examples).
SERVING = ArchConfig(
    name="paper-synthetic-serving", family="dense",
    citation="paper §4.1 (LM-scale analogue of the synthetic experiment)",
    n_layers=1, d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
    vocab_size=256, tie_embeddings=True,
    monitor=MonitorConfig(n_layers=1, d_model=64, n_heads=2, d_ff=128,
                          n_features=16),
)

# Serving operating point for the async-overlap bench (bench_serving) and
# examples: per-stream trigger rate in the paper's Fig-4 operating region
# (the threshold is calibrated to this rate from a probe u-trace), the
# simulated server round trip, and the pipeline depth that hides it.
SERVING_TRIGGER_RATE = 0.15   # paper Fig 4: trigger rates ~0.05-0.3
SERVING_LATENCY_S = 0.05      # mock-remote RTT (cellular-class uplink)
SERVING_MAX_STALENESS = 16    # merge window: RTT / edge-step-time, rounded up
# wire-transport operating point (bench_serving --transport wire and the
# two-process demos): super-batch rows the correction server leases to
# client sessions — the multi-tenant capacity of one server process
SERVING_WIRE_SLOTS = 64

"""Zamba2-7B [arXiv:2411.15242] — Mamba2 backbone + shared attention block.

81 layers, d_model=3584, 32 heads (GQA kv=32), d_ff=14336, vocab=32000,
ssm_state=64.  The shared transformer block is applied every 6 Mamba2 blocks
(param-shared across invocations; per-invocation LoRA deltas omitted, see
DESIGN.md §5).  long_500k runs natively on the SSM state; the shared
attention block uses an 8k ring cache at long context.
"""
from repro.configs.base import ArchConfig, MonitorConfig

FULL = ArchConfig(
    name="zamba2-7b", family="hybrid", citation="arXiv:2411.15242",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336,
    vocab_size=32000, ssm_state=64, ssm_expand=2, ssm_conv=4,
    shared_attn_every=6, tie_embeddings=True,
    long_context_window=8192,
    monitor=MonitorConfig(n_layers=2, d_model=256, n_heads=4, d_ff=1024,
                          n_features=64),
)

SMOKE = FULL.replace(
    # 5 layers / period 2 exercises both the super-block scan AND the tail
    n_layers=5, d_model=256, n_heads=4, n_kv_heads=4, d_ff=512,
    vocab_size=512, ssm_state=16, shared_attn_every=2, remat=False,
    dtype="float32",
    monitor=MonitorConfig(n_layers=1, d_model=64, n_heads=2, d_ff=128,
                          n_features=16),
)

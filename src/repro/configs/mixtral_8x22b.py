"""Mixtral-8x22B [arXiv:2401.04088] — 8-expert top-2 MoE with sliding-window
attention.  56 layers, d_model=6144, 48 heads (GQA kv=8), expert d_ff=16384,
vocab=32768.  SWA (window 4096) makes long_500k native (ring cache).
bf16 params (141B total).
"""
from repro.configs.base import ArchConfig, MonitorConfig

FULL = ArchConfig(
    name="mixtral-8x22b", family="moe", citation="arXiv:2401.04088",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=0,
    vocab_size=32768,
    n_experts=8, top_k=2, moe_d_ff=16384, first_dense_layers=0,
    sliding_window=4096, capacity_factor=1.25,
    moe_impl="auto",  # shard_map local dispatch (EXPERIMENTS.md §Perf A); baseline: "dense"
    param_dtype="bfloat16", rope_theta=1e6,
    monitor=MonitorConfig(n_layers=2, d_model=256, n_heads=4, d_ff=1024,
                          n_features=64),
)

SMOKE = FULL.replace(
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=0,
    vocab_size=512, n_experts=4, top_k=2, moe_d_ff=256, sliding_window=32,
    remat=False, dtype="float32", param_dtype="float32",
    monitor=MonitorConfig(n_layers=1, d_model=64, n_heads=2, d_ff=128,
                          n_features=16),
)

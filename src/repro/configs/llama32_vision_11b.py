"""Llama-3.2-11B-Vision [hf:meta-llama/Llama-3.2-11B-Vision] — dense text
decoder with tanh-gated cross-attention image layers every 5th layer.

40 layers, d_model=4096, 32 heads (GQA kv=8), d_ff=14336, vocab=128256,
1601 image patch embeddings (560px/14 + CLS, single tile).  The ViT vision
encoder + projector is a STUB per the brief: input_specs provides projected
patch embeddings (B, 1601, d_model).  long_500k = swa-variant.
"""
from repro.configs.base import ArchConfig, MonitorConfig

FULL = ArchConfig(
    name="llama-3.2-vision-11b", family="vlm",
    citation="hf:meta-llama/Llama-3.2-11B-Vision",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=128256, cross_attn_every=5, n_image_tokens=1601,
    rope_theta=5e5, long_context_window=8192,
    monitor=MonitorConfig(n_layers=2, d_model=256, n_heads=4, d_ff=1024,
                          n_features=64),
)

SMOKE = FULL.replace(
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
    vocab_size=512, cross_attn_every=2, n_image_tokens=16,
    remat=False, dtype="float32",
    monitor=MonitorConfig(n_layers=1, d_model=64, n_heads=2, d_ff=128,
                          n_features=16),
)

"""DeepSeek-V3-671B [arXiv:2412.19437] — MLA + 1 shared / 256 routed top-8 MoE
with a depth-1 MTP head.

61 layers (first 3 dense, d_ff=18432 per the model card), d_model=7168,
128 heads, routed-expert d_ff=2048 (the assignment's d_ff), vocab=129280.
MLA: q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64, v_head=128 — the
latent KV cache (512+64 per token) is what makes decode_32k/long_500k viable.
bf16 params (671B).
"""
from repro.configs.base import ArchConfig, MonitorConfig

FULL = ArchConfig(
    name="deepseek-v3-671b", family="moe", citation="arXiv:2412.19437",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, d_ff=18432,
    vocab_size=129280,
    n_experts=256, top_k=8, n_shared_experts=1, moe_d_ff=2048,
    first_dense_layers=3, capacity_factor=1.25,
    moe_impl="auto",  # shard_map local dispatch (EXPERIMENTS.md §Perf A); baseline: "dense"
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128, mtp_depth=1,
    param_dtype="bfloat16", long_context_window=8192,
    monitor=MonitorConfig(n_layers=2, d_model=256, n_heads=4, d_ff=1024,
                          n_features=64),
)

SMOKE = FULL.replace(
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, d_ff=512,
    vocab_size=512, n_experts=4, top_k=2, n_shared_experts=1, moe_d_ff=128,
    first_dense_layers=1, use_mla=True, q_lora_rank=64, kv_lora_rank=64,
    qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32, mtp_depth=1,
    remat=False, dtype="float32", param_dtype="float32",
    monitor=MonitorConfig(n_layers=1, d_model=64, n_heads=2, d_ff=128,
                          n_features=16),
)

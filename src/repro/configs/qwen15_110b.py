"""Qwen1.5-110B [hf:Qwen/Qwen1.5-0.5B family] — dense decoder with QKV bias.

80 layers, d_model=8192, 64 heads (GQA kv=8), d_ff=49152, vocab=152064.
bf16 params (111B params: f32 storage would not fit 256 chips; see
EXPERIMENTS.md §Dry-run memory notes).  long_500k = swa-variant.
"""
from repro.configs.base import ArchConfig, MonitorConfig

FULL = ArchConfig(
    name="qwen1.5-110b", family="dense", citation="hf:Qwen/Qwen1.5-0.5B",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=49152,
    vocab_size=152064, qkv_bias=True, rope_theta=1e6,
    param_dtype="bfloat16", long_context_window=8192,
    monitor=MonitorConfig(n_layers=2, d_model=256, n_heads=4, d_ff=1024,
                          n_features=64),
)

SMOKE = FULL.replace(
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=768,
    vocab_size=512, remat=False, dtype="float32", param_dtype="float32",
    monitor=MonitorConfig(n_layers=1, d_model=64, n_heads=2, d_ff=128,
                          n_features=16),
)

"""MusicGen-large [arXiv:2306.05284] — decoder-only LM over EnCodec tokens.

48 layers, d_model=2048, 32 heads, d_ff=8192, vocab=2048 per codebook,
4 codebooks (delay interleaving handled by the data pipeline).  The EnCodec
frontend is a STUB per the brief: input_specs provides codebook token ids.
long_500k = swa-variant.
"""
from repro.configs.base import ArchConfig, MonitorConfig

FULL = ArchConfig(
    name="musicgen-large", family="audio", citation="arXiv:2306.05284",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=2048, n_codebooks=4, long_context_window=8192,
    monitor=MonitorConfig(n_layers=2, d_model=256, n_heads=4, d_ff=1024,
                          n_features=64),
)

SMOKE = FULL.replace(
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, d_ff=512,
    vocab_size=256, n_codebooks=2, remat=False, dtype="float32",
    monitor=MonitorConfig(n_layers=1, d_model=64, n_heads=2, d_ff=128,
                          n_features=16),
)

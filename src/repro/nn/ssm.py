"""Mamba2 (SSD) block: chunked-scan prefill/training + recurrent decode.

TPU adaptation (DESIGN.md §3): the GPU reference is a fused CUDA scan; here
the SSD *matrix form* maps the intra-chunk work onto dense einsums (MXU
friendly) and carries the inter-chunk state (B, H, P, N) through a
lax.scan — the Pallas kernel in kernels/ssm_scan.py tiles the same
computation into VMEM blocks.  All recurrence math is f32.

Projections are kept SEPARATE (w_z / w_x / w_B / w_C / w_dt and per-stream
convs) rather than one fused in_proj: the fused output dim
(2*d_in + 2N + H) is not divisible by the model mesh axis, while each
split stream shards cleanly (d_in and H are multiples of 16 for the
assigned configs) — tensor-parallel-friendly by construction.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.nn.module import Params, init_linear, linear, normal_init


class SSMCache(NamedTuple):
    h: jnp.ndarray        # (B, H, P, N) recurrent state
    conv_x: jnp.ndarray   # (B, conv_k - 1, d_in) conv tails per stream
    conv_B: jnp.ndarray   # (B, conv_k - 1, N)
    conv_C: jnp.ndarray   # (B, conv_k - 1, N)


def ssm_dims(d_model: int, expand: int, state: int, head_p: int = 64):
    d_in = expand * d_model
    n_heads = d_in // head_p
    return d_in, n_heads, head_p, state


def init_mamba2(key, d_model: int, *, expand: int = 2, state: int = 64,
                conv_k: int = 4, head_p: int = 64, dtype=jnp.float32) -> Params:
    d_in, H, P, N = ssm_dims(d_model, expand, state, head_p)
    ks = jax.random.split(key, 9)
    conv_sd = 1.0 / math.sqrt(conv_k)
    return {
        "w_z": init_linear(ks[0], d_model, d_in, dtype=dtype),
        "w_x": init_linear(ks[1], d_model, d_in, dtype=dtype),
        "w_B": init_linear(ks[2], d_model, N, dtype=dtype),
        "w_C": init_linear(ks[3], d_model, N, dtype=dtype),
        "w_dt": init_linear(ks[4], d_model, H, dtype=dtype),
        "conv_x": {"w": normal_init(ks[5], (conv_k, d_in), dtype, conv_sd),
                   "b": jnp.zeros((d_in,), dtype)},
        "conv_B": {"w": normal_init(ks[6], (conv_k, N), dtype, conv_sd),
                   "b": jnp.zeros((N,), dtype)},
        "conv_C": {"w": normal_init(ks[7], (conv_k, N), dtype, conv_sd),
                   "b": jnp.zeros((N,), dtype)},
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_scale": jnp.ones((d_in,), dtype),
        "out_proj": init_linear(ks[8], d_in, d_model, dtype=dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv. x:(B,S,C), w:(K,C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(xp[:, i: i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    return y + b[None, None, :]


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                Bm: jnp.ndarray, Cm: jnp.ndarray, *, chunk: int = 128,
                h0: jnp.ndarray | None = None,
                unroll: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD scan.  x:(B,S,H,P) dt:(B,S,H) A:(H,) Bm,Cm:(B,S,N) -> (y, h_final).

    y_t = C_t^T h_t,   h_t = exp(dt_t A_h) h_{t-1} + dt_t B_t x_t^T

    Canonical Mamba2 chunked form: ALL intra-chunk work (the matmuls) is
    batched over the chunk axis — MXU-parallel across chunks and counted
    exactly by cost_analysis — and only the tiny elementwise state
    combination h_c = decay_c * h_{c-1} + S_c runs in a lax.scan.
    ``unroll`` only unrolls that cheap state scan (dry-run accounting).
    """
    B_, S, H, P = x.shape
    N = Bm.shape[-1]
    L = chunk if S % chunk == 0 else S
    nch = S // L
    xf = x.astype(jnp.float32)
    la = (dt.astype(jnp.float32) * A[None, None, :])  # log decay (B,S,H), <= 0
    xdt = xf * dt.astype(jnp.float32)[..., None]

    xdtc = xdt.reshape(B_, nch, L, H, P)
    lac = la.reshape(B_, nch, L, H)
    Bc = Bm.astype(jnp.float32).reshape(B_, nch, L, N)
    Cc = Cm.astype(jnp.float32).reshape(B_, nch, L, N)

    cums = jnp.cumsum(lac, axis=2)  # (B,nch,L,H)
    tril = jnp.tril(jnp.ones((L, L), bool))

    # intra-chunk: W[t,s,h] = exp(cums_t - cums_s), s <= t, batched over chunks
    diff = cums[:, :, :, None, :] - cums[:, :, None, :, :]  # (B,nch,L,L,H)
    W = jnp.exp(jnp.where(tril[None, None, :, :, None], diff, -jnp.inf))
    CB = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)
    y_intra = jnp.einsum("bclmh,bcmhp->bclhp", CB[..., None] * W, xdtc)

    # per-chunk state contributions + decays (batched)
    dte = jnp.exp(cums[:, :, -1:, :] - cums)  # (B,nch,L,H)
    S_c = jnp.einsum("bclh,bcln,bclhp->bchpn", dte, Bc, xdtc)
    chunk_decay = jnp.exp(cums[:, :, -1, :])  # (B,nch,H)

    if h0 is None:
        h0 = jnp.zeros((B_, H, P, N), jnp.float32)

    def step(h, inp):
        d, s = inp  # (B,H), (B,H,P,N)
        return d[..., None, None] * h + s, h  # emit the INCOMING state

    h_fin, h_in = jax.lax.scan(
        step, h0, (chunk_decay.transpose(1, 0, 2), S_c.transpose(1, 0, 2, 3, 4)),
        unroll=unroll)
    h_in = h_in.transpose(1, 0, 2, 3, 4)  # (B,nch,H,P,N) state entering chunk

    # inter-chunk: carried state seen through C, batched over chunks
    y_inter = jnp.einsum("bcln,bchpn->bclhp", Cc, h_in) * jnp.exp(cums)[..., None]

    y = (y_intra + y_inter).reshape(B_, S, H, P)
    return y, h_fin


def mamba2_prefill(p: Params, x: jnp.ndarray, *, expand: int, state: int,
                   conv_k: int, chunk: int = 128, head_p: int = 64,
                   compute_dtype=jnp.bfloat16,
                   scan_fn=ssd_chunked) -> jnp.ndarray:
    B, S, d = x.shape
    d_in, H, P, N = ssm_dims(d, expand, state, head_p)
    # NOTE (§Perf C2a, refuted): fusing these five projections via an
    # apply-time weight concat COSTS more than the saved stream reads — the
    # materialised concat + its bwd gradient assembly, recomputed under
    # remat, outweigh 3 reads of h.  Kept separate.
    z = linear(p["w_z"], x, compute_dtype=compute_dtype)
    xs = linear(p["w_x"], x, compute_dtype=compute_dtype)
    Bs = linear(p["w_B"], x, compute_dtype=compute_dtype)
    Cs = linear(p["w_C"], x, compute_dtype=compute_dtype)
    dt = linear(p["w_dt"], x, compute_dtype=compute_dtype)

    conv = lambda v, c: jax.nn.silu(_causal_conv(
        v.astype(jnp.float32), c["w"].astype(jnp.float32),
        c["b"].astype(jnp.float32)))
    xi = conv(xs, p["conv_x"]).reshape(B, S, H, P)
    Bm = conv(Bs, p["conv_B"])
    Cm = conv(Cs, p["conv_C"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    y, _ = scan_fn(xi, dt, A, Bm, Cm, chunk=chunk)
    y = y + p["D"][None, None, :, None] * xi.astype(jnp.float32)
    y = y.reshape(B, S, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = y * p["norm_scale"].astype(jnp.float32)[None, None, :]
    return linear(p["out_proj"], y.astype(compute_dtype), compute_dtype=compute_dtype)


def mamba2_decode(p: Params, x: jnp.ndarray, cache: SSMCache, *, expand: int,
                  state: int, conv_k: int, head_p: int = 64,
                  compute_dtype=jnp.bfloat16) -> Tuple[jnp.ndarray, SSMCache]:
    """x: (B, d) one token; O(1) state update (this is the long_500k path)."""
    B, d = x.shape
    d_in, H, P, N = ssm_dims(d, expand, state, head_p)
    z = linear(p["w_z"], x, compute_dtype=compute_dtype)
    xs = linear(p["w_x"], x, compute_dtype=compute_dtype)
    Bs = linear(p["w_B"], x, compute_dtype=compute_dtype)
    Cs = linear(p["w_C"], x, compute_dtype=compute_dtype)
    dt = linear(p["w_dt"], x, compute_dtype=compute_dtype)

    def conv_step(tail, v_t, c):
        seq = jnp.concatenate([tail, v_t[:, None].astype(jnp.float32)], axis=1)
        y = jnp.einsum("bkc,kc->bc", seq, c["w"].astype(jnp.float32))
        return jax.nn.silu(y + c["b"].astype(jnp.float32)), seq[:, 1:]

    xi, ncx = conv_step(cache.conv_x, xs, p["conv_x"])
    Bm, ncB = conv_step(cache.conv_B, Bs, p["conv_B"])
    Cm, ncC = conv_step(cache.conv_C, Cs, p["conv_C"])
    xi = xi.reshape(B, H, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, :])
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A[None, :])  # (B,H)
    h = (cache.h * a[:, :, None, None]
         + jnp.einsum("bn,bhp->bhpn", Bm, xi * dt[..., None]))
    y = jnp.einsum("bn,bhpn->bhp", Cm, h) + p["D"][None, :, None] * xi
    y = y.reshape(B, d_in) * jax.nn.silu(z.astype(jnp.float32))
    y = y * p["norm_scale"].astype(jnp.float32)[None, :]
    out = linear(p["out_proj"], y.astype(compute_dtype), compute_dtype=compute_dtype)
    return out, SSMCache(h=h, conv_x=ncx, conv_B=ncB, conv_C=ncC)


def init_ssm_cache(batch: int, d_model: int, *, expand: int, state: int,
                   conv_k: int, head_p: int = 64) -> SSMCache:
    d_in, H, P, N = ssm_dims(d_model, expand, state, head_p)
    return SSMCache(
        h=jnp.zeros((batch, H, P, N), jnp.float32),
        conv_x=jnp.zeros((batch, conv_k - 1, d_in), jnp.float32),
        conv_B=jnp.zeros((batch, conv_k - 1, N), jnp.float32),
        conv_C=jnp.zeros((batch, conv_k - 1, N), jnp.float32),
    )

from repro.nn import attention, embedding, mlp, module, moe, norms, rotary, ssm, xlstm  # noqa: F401

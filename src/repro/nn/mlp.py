"""Feed-forward blocks: SwiGLU (llama/qwen family default) and GELU MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.module import Params, init_linear, linear


def init_swiglu(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": init_linear(ks[0], d_model, d_ff, dtype=dtype),
        "w_up": init_linear(ks[1], d_model, d_ff, dtype=dtype),
        "w_down": init_linear(ks[2], d_ff, d_model, dtype=dtype),
    }


def swiglu(p: Params, x: jnp.ndarray, compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    g = linear(p["w_gate"], x, compute_dtype=compute_dtype)
    u = linear(p["w_up"], x, compute_dtype=compute_dtype)
    return linear(p["w_down"], jax.nn.silu(g) * u, compute_dtype=compute_dtype)


def init_gelu_mlp(key, d_model: int, d_ff: int, *, bias: bool = True,
                  dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "w_in": init_linear(ks[0], d_model, d_ff, bias=bias, dtype=dtype),
        "w_out": init_linear(ks[1], d_ff, d_model, bias=bias, dtype=dtype),
    }


def gelu_mlp(p: Params, x: jnp.ndarray, compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    return linear(p["w_out"], jax.nn.gelu(linear(p["w_in"], x, compute_dtype=compute_dtype)),
                  compute_dtype=compute_dtype)

"""Mixture-of-Experts: top-k router + sort-based capacity dispatch.

TPU adaptation note (DESIGN.md §3/§4): the canonical GPU MoE uses ragged
grouped-GEMM; on TPU we use the static-capacity idiom — tokens are ranked
per expert, the first ``capacity`` survive, and expert compute is one
stacked einsum on the MXU.  Dropped tokens fall through on the residual
stream (standard Switch behaviour).  The same static-capacity trick is what
``core/gating.py`` reuses for the paper's trigger-gated corrector dispatch.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.nn.module import Params, init_linear, linear, normal_init


def init_moe(key, d_model: int, moe_d_ff: int, n_experts: int, *,
             n_shared: int = 0, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 5)
    sd_in = 1.0 / math.sqrt(d_model)
    sd_out = 1.0 / math.sqrt(moe_d_ff)
    p: Params = {
        "router": init_linear(ks[0], d_model, n_experts, dtype=jnp.float32),
        "w_gate": normal_init(ks[1], (n_experts, d_model, moe_d_ff), dtype, sd_in),
        "w_up": normal_init(ks[2], (n_experts, d_model, moe_d_ff), dtype, sd_in),
        "w_down": normal_init(ks[3], (n_experts, moe_d_ff, d_model), dtype, sd_out),
    }
    if n_shared:
        p["shared"] = {
            "w_gate": normal_init(ks[4], (d_model, n_shared * moe_d_ff), dtype, sd_in),
            "w_up": normal_init(jax.random.fold_in(ks[4], 1),
                                (d_model, n_shared * moe_d_ff), dtype, sd_in),
            "w_down": normal_init(jax.random.fold_in(ks[4], 2),
                                  (n_shared * moe_d_ff, d_model), dtype, sd_out),
        }
    return p


def expert_capacity(n_tokens: int, n_experts: int, top_k: int,
                    capacity_factor: float) -> int:
    c = int(math.ceil(n_tokens * top_k / n_experts * capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to multiple of 8


def _route(p: Params, xf: jnp.ndarray, n_experts: int, top_k: int):
    """Router in f32 -> (top_p, top_i, aux)."""
    T = xf.shape[0]
    logits = linear(p["router"], xf.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    top_p, top_i = jax.lax.top_k(probs, top_k)  # (T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    # Load-balance auxiliary loss (Switch/GShard form).
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((n_experts,), jnp.float32).at[top_i.reshape(-1)].add(
        1.0 / (T * top_k))
    aux = n_experts * jnp.sum(me * ce)
    return top_p, top_i, aux


def _slot_table(top_i, top_p, *, n_experts: int, top_k: int, C: int,
                e_lo: int = 0, e_sel: Optional[int] = None):
    """Compact slot table: (slot_tok (E_sel*C,), w_slot (E_sel*C,)).

    slot_tok[s] = token id filling slot s (sentinel T when empty/dropped);
    w_slot[s]   = routing weight of that assignment (0 when empty).
    All intermediates here are over index/weight VECTORS (never the d-wide
    activations) — §Perf A2: the activation gathers/scatters downstream run
    over E_sel*C kept slots, not T*k candidate slots.
    """
    T = top_i.shape[0]
    e_sel = n_experts if e_sel is None else e_sel
    flat_e = top_i.reshape(T * top_k)
    flat_w = top_p.reshape(T * top_k).astype(jnp.float32)
    order = jnp.argsort(flat_e)  # stable
    se = flat_e[order]
    stok = order // top_k
    sw = flat_w[order]
    counts = jnp.zeros((n_experts,), jnp.int32).at[se].add(1)
    offsets = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * top_k) - offsets[se]
    mine = (se >= e_lo) & (se < e_lo + e_sel)
    keep = (pos < C) & mine
    my_e = jnp.where(mine, se - e_lo, 0)
    slot = jnp.where(keep, my_e * C + jnp.minimum(pos, C - 1), e_sel * C)
    slot_tok = jnp.full((e_sel * C + 1,), T, jnp.int32).at[slot].set(
        jnp.where(keep, stok, T))
    w_slot = jnp.zeros((e_sel * C + 1,), jnp.float32).at[slot].set(sw * keep)
    return slot_tok[: e_sel * C], w_slot[: e_sel * C]


def _expert_ffn(xf, slot_tok, w_slot, wg, wu, wd, *, e_sel: int, C: int,
                compute_dtype):
    """Gather kept tokens -> stacked expert SwiGLU -> weighted scatter-add."""
    T, d = xf.shape
    xf_pad = jnp.concatenate(
        [xf.astype(compute_dtype), jnp.zeros((1, d), compute_dtype)], axis=0)
    h = xf_pad[slot_tok].reshape(e_sel, C, d)
    g = jnp.einsum("ecd,edf->ecf", h, wg.astype(compute_dtype))
    u = jnp.einsum("ecd,edf->ecf", h, wu.astype(compute_dtype))
    eo = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                    wd.astype(compute_dtype))
    contrib = eo.reshape(e_sel * C, d) * w_slot[:, None].astype(eo.dtype)
    y = jnp.zeros((T + 1, d), jnp.float32).at[slot_tok].add(
        contrib.astype(jnp.float32))
    return y[:T]


def _shared_ffn(p: Params, xf, compute_dtype):
    sp = p["shared"]
    gs = linear({"w": sp["w_gate"]}, xf, compute_dtype=compute_dtype)
    us = linear({"w": sp["w_up"]}, xf, compute_dtype=compute_dtype)
    return linear({"w": sp["w_down"]}, jax.nn.silu(gs) * us,
                  compute_dtype=compute_dtype).astype(jnp.float32)


def moe_apply(p: Params, x: jnp.ndarray, *, n_experts: int, top_k: int,
              capacity_factor: float = 1.25,
              compute_dtype=jnp.bfloat16) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss).  Sort-based static-capacity dispatch."""
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)
    top_p, top_i, aux = _route(p, xf, n_experts, top_k)
    C = expert_capacity(T, n_experts, top_k, capacity_factor)
    slot_tok, w_slot = _slot_table(top_i, top_p, n_experts=n_experts,
                                   top_k=top_k, C=C)
    y = _expert_ffn(xf, slot_tok, w_slot, p["w_gate"], p["w_up"], p["w_down"],
                    e_sel=n_experts, C=C, compute_dtype=compute_dtype)
    if "shared" in p:
        y = y + _shared_ffn(p, xf, compute_dtype)
    return y.reshape(B, S, d).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Expert-parallel dispatch (shard_map) — §Perf hillclimb A.
#
# Under plain jit-SPMD the sort/scatter dispatch above is GLOBAL: XLA must
# all-gather the token activations to run one argsort over B*S*k slots and
# materialise an (E, C_global, d) buffer — for deepseek-v3 train_4k that is a
# ~150 GB tensor and ~2000 s of ICI time per step.  Here each (pod,data) shard
# routes only its LOCAL tokens (activations are replicated over 'model'
# between blocks, megatron-style, so no token exchange is needed at all);
# each 'model' shard keeps its E/model_size experts, applies them at local
# capacity, and the partial outputs combine with ONE psum over 'model' per
# layer — the same collective class as the row-parallel matmul all-reduce
# that is already on the dense path.
# ---------------------------------------------------------------------------


def _current_mesh():
    try:  # jax >= 0.5 public API; 0.4.3x keeps it under jax._src.mesh
        get = jax.sharding.get_abstract_mesh
    except AttributeError:
        try:
            from jax._src.mesh import get_abstract_mesh as get
        except ImportError:
            get = lambda: None
    m = get()
    if m is not None and getattr(m, "axis_names", None):
        return m
    try:  # legacy `with mesh:` context
        from jax.interpreters import pxla
        pm = pxla.thread_resources.env.physical_mesh
        return pm if pm.axis_names else None
    except Exception:
        return None


def ep_applicable(n_experts: int) -> bool:
    """True when a mesh with a 'model' axis (>1) is active.  E % model == 0
    selects expert-parallel; otherwise the ff dim is tensor-sharded — both
    run the dispatch locally per data shard inside shard_map."""
    mesh = _current_mesh()
    return (mesh is not None and "model" in mesh.axis_names
            and mesh.shape["model"] > 1)


def moe_apply_ep(p: Params, x: jnp.ndarray, *, n_experts: int, top_k: int,
                 capacity_factor: float = 1.25,
                 compute_dtype=jnp.bfloat16) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Locally-dispatched moe_apply (shard_map): identical math to
    moe_apply, but routing/sort/scatter run per (pod,data) shard.

    - E % model == 0 (deepseek: 256 % 16): EXPERT-parallel — each model
      shard holds E/model experts and its partial outputs psum-combine.
    - else (mixtral: 8 on a 16-way axis): experts replicated, their ff dim
      TENSOR-sharded; the w_down contraction psum-combines.

    Either way there is exactly ONE psum over 'model' per layer and no
    global sort/gather.  Shared experts stay on the dense megatron path.
    """
    mesh = _current_mesh()
    B, S, d = x.shape
    ep = int(mesh.shape["model"])
    expert_parallel = n_experts % ep == 0
    e_sel = n_experts // ep if expert_parallel else n_experts
    daxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dtotal = 1
    for a in daxes:
        dtotal *= int(mesh.shape[a])
    if B % dtotal != 0:  # batch not shardable over data: fall back
        return moe_apply(p, x, n_experts=n_experts, top_k=top_k,
                         capacity_factor=capacity_factor,
                         compute_dtype=compute_dtype)
    t_loc = (B // dtotal) * S
    C = expert_capacity(t_loc, n_experts, top_k, capacity_factor)
    bspec = P(daxes if len(daxes) > 1 else daxes[0], None, None)
    wspec = (P("model", None, None) if expert_parallel
             else P(None, None, "model"))
    wdspec = (P("model", None, None) if expert_parallel
              else P(None, "model", None))

    def local(xl, rw, rb, wg, wu, wd):
        xf = xl.reshape(t_loc, d)
        logits = xf.astype(jnp.float32) @ rw + rb  # router in f32
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_i = jax.lax.top_k(probs, top_k)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
        me = jnp.mean(probs, axis=0)
        ce = jnp.zeros((n_experts,), jnp.float32).at[top_i.reshape(-1)].add(
            1.0 / (t_loc * top_k))
        aux = n_experts * jnp.sum(me * ce)
        if daxes:
            aux = jax.lax.pmean(aux, daxes)

        e_lo = (jax.lax.axis_index("model") * e_sel) if expert_parallel else 0
        slot_tok, w_slot = _slot_table(top_i, top_p, n_experts=n_experts,
                                       top_k=top_k, C=C, e_lo=e_lo,
                                       e_sel=e_sel)
        y = _expert_ffn(xf, slot_tok, w_slot, wg, wu, wd, e_sel=e_sel, C=C,
                        compute_dtype=compute_dtype)
        y = jax.lax.psum(y, "model")  # combine expert/ff-shard partials
        return y.reshape(xl.shape[0], S, d).astype(xl.dtype), aux

    rb = p["router"].get("b")
    if rb is None:
        rb = jnp.zeros((n_experts,), jnp.float32)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(bspec, P(), P(), wspec, wspec, wdspec),
        out_specs=(bspec, P()),
        check_rep=False)
    y, aux = fn(x, p["router"]["w"].astype(jnp.float32), rb,
                p["w_gate"], p["w_up"], p["w_down"])

    if "shared" in p:
        ys = _shared_ffn(p, x.reshape(B * S, d), compute_dtype)
        y = y + ys.reshape(B, S, d).astype(y.dtype)
    return y, aux


def moe_dispatch(p: Params, x: jnp.ndarray, *, n_experts: int, top_k: int,
                 capacity_factor: float = 1.25, compute_dtype=jnp.bfloat16,
                 impl: str = "auto") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """impl: 'dense' (jit-SPMD global dispatch, the recorded baseline),
    'ep' (locally-dispatched shard_map), 'auto' (ep when applicable)."""
    if impl == "ep" or (impl == "auto" and ep_applicable(n_experts)):
        return moe_apply_ep(p, x, n_experts=n_experts, top_k=top_k,
                            capacity_factor=capacity_factor,
                            compute_dtype=compute_dtype)
    return moe_apply(p, x, n_experts=n_experts, top_k=top_k,
                     capacity_factor=capacity_factor,
                     compute_dtype=compute_dtype)

"""Rotary position embeddings (RoPE), supporting offset positions for decode."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float = 1e4) -> jnp.ndarray:
    """Inverse frequencies, shape (head_dim//2,), float32."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 1e4) -> jnp.ndarray:
    """Rotate ``x`` of shape (..., S, H, D) by position-dependent angles.

    ``positions`` has shape broadcastable to (..., S). Uses the interleaved
    (GPT-NeoX "half-split") convention used by llama/qwen.
    """
    d = x.shape[-1]
    inv = rope_freqs(d, theta)  # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, d/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, d/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)

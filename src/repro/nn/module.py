"""Minimal functional module substrate.

No flax/haiku in the environment, so parameters are plain nested dicts of
``jnp.ndarray`` ("param trees").  Every layer exposes

    init_<layer>(key, ...) -> params          (pure, shape-only logic)
    <layer>(params, x, ...) -> y              (pure apply)

Path utilities flatten the tree into "/"-joined string paths; the sharding
rule engine (distributed/sharding.py) matches regexes against those paths.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Iterator, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def normal_init(key, shape, dtype, stddev: float = 0.02):
    return (stddev * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def lecun_init(key, shape, dtype, fan_in: int | None = None):
    fan = fan_in if fan_in is not None else shape[0]
    return normal_init(key, shape, dtype, stddev=1.0 / math.sqrt(max(fan, 1)))


def zeros_init(_key, shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype):
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# Linear
# ---------------------------------------------------------------------------


def init_linear(key, d_in: int, d_out: int, *, bias: bool = False,
                dtype=jnp.float32, stddev: float | None = None) -> Params:
    kw, _ = jax.random.split(key)
    sd = stddev if stddev is not None else 1.0 / math.sqrt(d_in)
    p: Params = {"w": normal_init(kw, (d_in, d_out), dtype, sd)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: Params, x: jnp.ndarray, *, compute_dtype=None) -> jnp.ndarray:
    w = p["w"]
    if compute_dtype is not None:
        w = w.astype(compute_dtype)
        x = x.astype(compute_dtype)
    y = x @ w
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Param tree utilities
# ---------------------------------------------------------------------------


def _is_container(tree) -> bool:
    """list/tuple nodes to descend into.  PartitionSpec subclasses tuple but
    is a LEAF (a spec per array), as is any NamedTuple-style cache record —
    descending into them mangles spec trees (e.g. 'embed/table/0')."""
    if not isinstance(tree, (list, tuple)):
        return False
    from jax.sharding import PartitionSpec
    return not (isinstance(tree, PartitionSpec) or hasattr(tree, "_fields"))


def iter_paths(tree: Params, prefix: str = "") -> Iterator[Tuple[str, jnp.ndarray]]:
    """Yield ("a/b/c", leaf) pairs in deterministic order."""
    if isinstance(tree, dict):
        for k in sorted(tree.keys()):
            yield from iter_paths(tree[k], f"{prefix}/{k}" if prefix else str(k))
    elif _is_container(tree):
        for i, v in enumerate(tree):
            yield from iter_paths(v, f"{prefix}/{i}" if prefix else str(i))
    else:
        yield prefix, tree


def map_with_path(fn: Callable[[str, Any], Any], tree: Params, prefix: str = ""):
    """Map ``fn(path, leaf)`` over the tree, preserving structure."""
    if isinstance(tree, dict):
        return {k: map_with_path(fn, v, f"{prefix}/{k}" if prefix else str(k))
                for k, v in tree.items()}
    if _is_container(tree):
        t = type(tree)
        return t(map_with_path(fn, v, f"{prefix}/{i}" if prefix else str(i))
                 for i, v in enumerate(tree))
    return fn(prefix, tree)


def param_count(tree: Params) -> int:
    return sum(int(l.size) for _, l in iter_paths(tree) if hasattr(l, "size"))


def param_bytes(tree: Params) -> int:
    return sum(int(l.size) * l.dtype.itemsize
               for _, l in iter_paths(tree) if hasattr(l, "size"))


def cast_tree(tree: Params, dtype) -> Params:
    return jax.tree.map(
        lambda l: l.astype(dtype) if jnp.issubdtype(l.dtype, jnp.floating) else l,
        tree)

"""Token embeddings and output heads (incl. multi-codebook audio variants)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.module import Params, normal_init


def init_embedding(key, vocab: int, d_model: int, dtype=jnp.float32) -> Params:
    return {"table": normal_init(key, (vocab, d_model), dtype, 0.02)}


def embed(p: Params, tokens: jnp.ndarray, compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    return p["table"].astype(compute_dtype)[tokens]


def unembed(p: Params, x: jnp.ndarray, compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    """Logits in f32 (softmax stability)."""
    return jnp.einsum("...d,vd->...v", x.astype(compute_dtype),
                      p["table"].astype(compute_dtype)).astype(jnp.float32)


def init_codebook_embedding(key, n_codebooks: int, vocab: int, d_model: int,
                            dtype=jnp.float32) -> Params:
    return {"table": normal_init(key, (n_codebooks, vocab, d_model), dtype, 0.02)}


def codebook_embed(p: Params, tokens: jnp.ndarray,
                   compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    """tokens: (B, S, K) -> sum over codebooks of per-book embeddings."""
    K = tokens.shape[-1]
    tab = p["table"].astype(compute_dtype)  # (K, V, d)
    outs = [tab[k][tokens[..., k]] for k in range(K)]
    return sum(outs)


def codebook_unembed(p: Params, x: jnp.ndarray,
                     compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    """-> (B, S, K, V) per-codebook logits."""
    tab = p["table"].astype(compute_dtype)  # (K, V, d)
    return jnp.einsum("...d,kvd->...kv", x.astype(compute_dtype), tab).astype(jnp.float32)

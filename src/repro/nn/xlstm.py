"""xLSTM cells and blocks (arXiv:2405.04517): mLSTM (matrix memory,
parallelisable) and sLSTM (scalar memory, hidden-to-hidden recurrence).

mLSTM has both a parallel (attention-like, training/prefill) and a
recurrent (decode) form; their equivalence is property-tested in
tests/test_xlstm.py.  sLSTM is inherently sequential -> lax.scan over time.
All gate/state math in f32 with the paper's max-stabiliser.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.nn.module import Params, init_linear, linear, normal_init
from repro.nn.norms import init_rmsnorm, rmsnorm


class MLSTMState(NamedTuple):
    C: jnp.ndarray  # (B, H, P, P) matrix memory
    n: jnp.ndarray  # (B, H, P) normaliser
    m: jnp.ndarray  # (B, H) stabiliser


class SLSTMState(NamedTuple):
    c: jnp.ndarray  # (B, d)
    n: jnp.ndarray  # (B, d)
    h: jnp.ndarray  # (B, d)
    m: jnp.ndarray  # (B, d)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, d_model: int, n_heads: int, *, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 7)
    d = d_model
    return {
        "wq": init_linear(ks[0], d, d, dtype=dtype),
        "wk": init_linear(ks[1], d, d, dtype=dtype),
        "wv": init_linear(ks[2], d, d, dtype=dtype),
        "w_i": init_linear(ks[3], d, n_heads, bias=True, dtype=jnp.float32),
        "w_f": init_linear(ks[4], d, n_heads, bias=True, dtype=jnp.float32),
        "w_o": init_linear(ks[5], d, d, bias=True, dtype=dtype),
        "w_out": init_linear(ks[6], d, d, dtype=dtype),
        "norm_scale": jnp.ones((d,), dtype),
    }


def mlstm_parallel(p: Params, x: jnp.ndarray, n_heads: int,
                   compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    """x: (B, S, d). Parallel (quadratic) form for training/prefill."""
    B, S, d = x.shape
    H, P = n_heads, d // n_heads
    q = linear(p["wq"], x, compute_dtype=compute_dtype).reshape(B, S, H, P).astype(jnp.float32)
    k = linear(p["wk"], x, compute_dtype=compute_dtype).reshape(B, S, H, P).astype(jnp.float32)
    v = linear(p["wv"], x, compute_dtype=compute_dtype).reshape(B, S, H, P).astype(jnp.float32)
    it = linear(p["w_i"], x.astype(jnp.float32))  # (B,S,H) pre-activation
    ft = linear(p["w_f"], x.astype(jnp.float32))
    logf = jax.nn.log_sigmoid(ft)
    F = jnp.cumsum(logf, axis=1)  # (B,S,H)
    # Dtil[b,t,s,h] = F_t - F_s + i_s  (s <= t)
    Dt = F[:, :, None, :] - F[:, None, :, :] + it[:, None, :, :]
    tril = jnp.tril(jnp.ones((S, S), bool))
    Dt = jnp.where(tril[None, :, :, None], Dt, -jnp.inf)
    m = jnp.max(Dt, axis=2)  # (B,S,H)
    Dm = jnp.exp(Dt - m[:, :, None, :])
    a = jnp.einsum("bthp,bshp->btsh", q, k) / math.sqrt(P)
    Sm = a * Dm
    num = jnp.einsum("btsh,bshp->bthp", Sm, v)
    den = jnp.maximum(jnp.abs(jnp.sum(Sm, axis=2)), jnp.exp(-m))  # (B,S,H)
    h = num / den[..., None]
    o = jax.nn.sigmoid(linear(p["w_o"], x.astype(jnp.float32)))
    y = (h.reshape(B, S, d) * o)
    y = y * p["norm_scale"].astype(jnp.float32)[None, None, :]
    return linear(p["w_out"], y.astype(compute_dtype), compute_dtype=compute_dtype)


def mlstm_decode(p: Params, x: jnp.ndarray, st: MLSTMState, n_heads: int,
                 compute_dtype=jnp.bfloat16) -> Tuple[jnp.ndarray, MLSTMState]:
    """x: (B, d) one token; recurrent matrix-memory update."""
    B, d = x.shape
    H, P = n_heads, d // n_heads
    q = linear(p["wq"], x, compute_dtype=compute_dtype).reshape(B, H, P).astype(jnp.float32)
    k = linear(p["wk"], x, compute_dtype=compute_dtype).reshape(B, H, P).astype(jnp.float32)
    v = linear(p["wv"], x, compute_dtype=compute_dtype).reshape(B, H, P).astype(jnp.float32)
    it = linear(p["w_i"], x.astype(jnp.float32))  # (B,H)
    ft = linear(p["w_f"], x.astype(jnp.float32))
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + st.m, it)
    fp = jnp.exp(logf + st.m - m_new)
    ip = jnp.exp(it - m_new)
    C = fp[..., None, None] * st.C + ip[..., None, None] * (
        v[..., :, None] * k[..., None, :])  # (B,H,P,P) v k^T
    n = fp[..., None] * st.n + ip[..., None] * k
    num = jnp.einsum("bhvp,bhp->bhv", C, q / math.sqrt(P))
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", n, q / math.sqrt(P))),
                      jnp.exp(-m_new))
    h = num / den[..., None]
    o = jax.nn.sigmoid(linear(p["w_o"], x.astype(jnp.float32)))
    y = h.reshape(B, d) * o
    y = y * p["norm_scale"].astype(jnp.float32)[None, :]
    out = linear(p["w_out"], y.astype(compute_dtype), compute_dtype=compute_dtype)
    return out, MLSTMState(C=C, n=n, m=m_new)


def init_mlstm_state(batch: int, d_model: int, n_heads: int) -> MLSTMState:
    P = d_model // n_heads
    return MLSTMState(
        C=jnp.zeros((batch, n_heads, P, P), jnp.float32),
        n=jnp.zeros((batch, n_heads, P), jnp.float32),
        m=jnp.full((batch, n_heads), -1e30, jnp.float32),
    )


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, d_model: int, n_heads: int, *, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 8)
    P = d_model // n_heads
    def rec(k):  # block-diagonal recurrent weights, (H, P, P)
        return normal_init(k, (n_heads, P, P), jnp.float32, 1.0 / math.sqrt(P))
    return {
        "w_z": init_linear(ks[0], d_model, d_model, bias=True, dtype=jnp.float32),
        "w_i": init_linear(ks[1], d_model, d_model, bias=True, dtype=jnp.float32),
        "w_f": init_linear(ks[2], d_model, d_model, bias=True, dtype=jnp.float32),
        "w_o": init_linear(ks[3], d_model, d_model, bias=True, dtype=jnp.float32),
        "r_z": rec(ks[4]), "r_i": rec(ks[5]), "r_f": rec(ks[6]), "r_o": rec(ks[7]),
        "norm_scale": jnp.ones((d_model,), dtype),
    }


def _rec_mm(r: jnp.ndarray, h: jnp.ndarray, H: int) -> jnp.ndarray:
    B, d = h.shape
    P = d // H
    return jnp.einsum("bhp,hpq->bhq", h.reshape(B, H, P), r).reshape(B, d)


def slstm_step(p: Params, x_t: jnp.ndarray, st: SLSTMState,
               n_heads: int) -> Tuple[jnp.ndarray, SLSTMState]:
    """One sLSTM step in f32. x_t: (B, d)."""
    H = n_heads
    xf = x_t.astype(jnp.float32)
    zt = jnp.tanh(linear(p["w_z"], xf) + _rec_mm(p["r_z"], st.h, H))
    it = linear(p["w_i"], xf) + _rec_mm(p["r_i"], st.h, H)
    ft = linear(p["w_f"], xf) + _rec_mm(p["r_f"], st.h, H)
    ot = jax.nn.sigmoid(linear(p["w_o"], xf) + _rec_mm(p["r_o"], st.h, H))
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + st.m, it)
    ip = jnp.exp(it - m_new)
    fp = jnp.exp(logf + st.m - m_new)
    c = fp * st.c + ip * zt
    n = jnp.maximum(fp * st.n + ip, 1e-6)
    h = ot * (c / n)
    return h, SLSTMState(c=c, n=n, h=h, m=m_new)


def slstm_scan(p: Params, x: jnp.ndarray, n_heads: int,
               compute_dtype=jnp.bfloat16,
               st: SLSTMState | None = None) -> Tuple[jnp.ndarray, SLSTMState]:
    """x: (B, S, d) -> (y, final_state); lax.scan over time.

    The input projections W_{z,i,f,o} x (the FLOPs majority) are hoisted out
    of the scan and computed as batched (B,S,d) matmuls; only the
    hidden-to-hidden recurrence R h_{t-1} (block-diagonal, d*P per step)
    stays sequential — both a real perf win and required for faithful
    dry-run cost accounting (a while-loop body is counted once).
    """
    B, S, d = x.shape
    H = n_heads
    if st is None:
        st = init_slstm_state(B, d)
    xf = x.astype(jnp.float32)
    zx = linear(p["w_z"], xf)
    ix = linear(p["w_i"], xf)
    fx = linear(p["w_f"], xf)
    ox = linear(p["w_o"], xf)

    def body(carry, gates_t):
        zt_, it_, ft_, ot_ = gates_t
        zt = jnp.tanh(zt_ + _rec_mm(p["r_z"], carry.h, H))
        it = it_ + _rec_mm(p["r_i"], carry.h, H)
        ft = ft_ + _rec_mm(p["r_f"], carry.h, H)
        ot = jax.nn.sigmoid(ot_ + _rec_mm(p["r_o"], carry.h, H))
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + carry.m, it)
        ip = jnp.exp(it - m_new)
        fp = jnp.exp(logf + carry.m - m_new)
        c = fp * carry.c + ip * zt
        n = jnp.maximum(fp * carry.n + ip, 1e-6)
        h = ot * (c / n)
        return SLSTMState(c=c, n=n, h=h, m=m_new), h

    gates = tuple(g.transpose(1, 0, 2) for g in (zx, ix, fx, ox))
    st_fin, hs = jax.lax.scan(body, st, gates)
    y = hs.transpose(1, 0, 2) * p["norm_scale"].astype(jnp.float32)[None, None, :]
    return y.astype(compute_dtype), st_fin


def init_slstm_state(batch: int, d_model: int) -> SLSTMState:
    z = jnp.zeros((batch, d_model), jnp.float32)
    return SLSTMState(c=z, n=z, h=z, m=jnp.full((batch, d_model), -1e30, jnp.float32))

"""Normalisation layers (RMSNorm is the default across all assigned archs)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.module import Params


def init_rmsnorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """Statistics and elementwise tail in f32, cast back at the end.
    NOTE (§Perf C2b, refuted): a bf16-elementwise variant (f32 statistics
    only) MEASURED +13% memory on zamba2 train — the extra boundary casts
    outweigh the halved chain under the CPU backend's fusion behaviour."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * (var + eps) ** -0.5
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dt)

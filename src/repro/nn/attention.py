"""Attention: GQA (llama/qwen/granite family), sliding-window, MLA (DeepSeek),
cross-attention (VLM), with separate prefill and single-token decode paths.

Prefill uses a query-block-chunked implementation (lax.scan over query
blocks) so the S x T score matrix is never materialised — this is the
XLA fallback matching the Pallas flash kernel in kernels/flash_attention.py
(dispatch happens in kernels/ops.py).

KV caches are fixed-capacity ring-free buffers: (B, S_max, n_kv, hd) with a
scalar fill pointer; decode writes at ``pos`` and masks entries >= pos+1.
Sliding-window decode uses a modular ring buffer of capacity ``window``.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.nn.module import Params, init_linear, linear
from repro.nn.rotary import apply_rope

NEG_INF = -1e30


import threading

_KV_SHARD = threading.local()  # prefill KV-sharding mode, set per block


class kv_shard_ctx:
    """Scope the prefill KV time-sharding mode ("none" | "time").

    §Perf D1 measured this lever as arch-dependent: it collapses
    qwen2.5-32b's pathological prefill collective 6.2× but REGRESSES archs
    whose propagation was already healthy (granite/llama-vision/mixtral:
    ~2× memory) — so it is opt-in per arch via cfg.prefill_kv_shard, and
    the paper's edge/monitor tower always runs "none" (device-local).
    """

    def __init__(self, mode: str):
        self.mode = mode

    def __enter__(self):
        self.prev = getattr(_KV_SHARD, "mode", "none")
        _KV_SHARD.mode = self.mode

    def __exit__(self, *a):
        _KV_SHARD.mode = self.prev


# backwards-compatible alias used by the monitor path
def kv_shard_optout():
    return kv_shard_ctx("none")


def _kv_time_shard(k: jnp.ndarray, v: jnp.ndarray):
    """§Perf D1: when kv-heads do NOT divide the 'model' axis, propagation
    shards K/V on head_dim and the score einsum contracts a sharded dim —
    SPMD then falls back to full rematerialisation (the same failure §Perf
    B1 fixed for decode).  Time-shard K/V instead: scores are local per
    time-shard; the softmax/output reductions are small.  No-op without an
    active mesh, with divisible kv-heads, or with an indivisible seq."""
    if getattr(_KV_SHARD, "mode", "none") != "time":
        return k, v
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
            from jax.interpreters import pxla
            mesh = pxla.thread_resources.env.physical_mesh
        m = mesh.shape.get("model", 1) if "model" in mesh.axis_names else 1
        if m <= 1 or k.shape[2] % m == 0 or k.shape[1] % m != 0:
            return k, v
        from jax.sharding import PartitionSpec as P
        daxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        b = (daxes if len(daxes) > 1 else daxes[0]) if daxes else None
        spec = P(b, "model", None, None)
        return (jax.lax.with_sharding_constraint(k, spec),
                jax.lax.with_sharding_constraint(v, spec))
    except Exception:
        return k, v


# ---------------------------------------------------------------------------
# Core chunked attention (shared by prefill paths)
# ---------------------------------------------------------------------------


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      q_block: int = 1024, causal: bool = True,
                      window: int = 0, q_offset: int = 0,
                      unroll: bool = False) -> jnp.ndarray:
    """Blockwise attention. q:(B,S,Hq,D) k,v:(B,T,Hkv,D) -> (B,S,Hq,D).

    Scans over query blocks; each block computes scores against the full
    K/V (masked), so peak memory is O(q_block * T) instead of O(S * T).
    GQA is handled by grouping query heads over KV heads.
    """
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    k, v = _kv_time_shard(k, v)

    nblk = S // q_block if S % q_block == 0 else -1
    if nblk <= 0:  # odd sizes (tests): single block
        q_block, nblk = S, 1

    qb = q.reshape(B, nblk, q_block, Hkv, G, D).transpose(1, 0, 2, 3, 4, 5)
    col = jnp.arange(T)

    def body(_, inp):
        bi, qblk = inp  # qblk: (B, q_block, Hkv, G, D)
        row = q_offset + bi * q_block + jnp.arange(q_block)
        # bf16 operands + f32 accumulation (MXU-native); avoids materialising
        # f32 copies of K/V every scan iteration (§Perf hillclimb B2).
        s = jnp.einsum("bqkgd,btkd->bqkgt", qblk, k,
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((q_block, T), bool)
        if causal:
            mask &= col[None, :] <= row[:, None]
        if window:
            mask &= col[None, :] > row[:, None] - window
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bqkgt,btkd->bqkgd", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        return None, o.astype(q.dtype)

    _, ob = jax.lax.scan(body, None, (jnp.arange(nblk), qb), unroll=unroll)
    return ob.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, Hq, Dv)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     pos: jnp.ndarray, *, window: int = 0) -> jnp.ndarray:
    """Single-query attention. q:(B,Hq,D), caches:(B,C,Hkv,D), pos scalar.

    Entries at index >= pos+1 (not yet written) are masked.  With a ring
    buffer (window > 0) every slot is valid once pos >= capacity.
    """
    B, Hq, D = q.shape
    C, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, Hkv, G, D)
    # bf16 cache reads + f32 accumulation: no f32 cache copies (§Perf B2).
    s = jnp.einsum("bkgd,btkd->bkgt", qg.astype(k_cache.dtype), k_cache,
                   preferred_element_type=jnp.float32) * scale
    idx = jnp.arange(C)
    if window:
        valid = idx < jnp.minimum(pos + 1, C)
    else:
        valid = idx <= pos
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA block (llama / qwen / granite / musicgen / zamba2-shared / mixtral)
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: jnp.ndarray  # (B, C, Hkv, D)
    v: jnp.ndarray


def init_gqa(key, d_model: int, n_heads: int, n_kv: int, head_dim: int, *,
             qkv_bias: bool = False, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], d_model, n_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "wk": init_linear(ks[1], d_model, n_kv * head_dim, bias=qkv_bias, dtype=dtype),
        "wv": init_linear(ks[2], d_model, n_kv * head_dim, bias=qkv_bias, dtype=dtype),
        "wo": init_linear(ks[3], n_heads * head_dim, d_model, bias=False, dtype=dtype),
    }


def gqa_prefill(p: Params, x: jnp.ndarray, *, n_heads: int, n_kv: int,
                head_dim: int, rope_theta: float = 1e4, window: int = 0,
                positions: Optional[jnp.ndarray] = None,
                compute_dtype=jnp.bfloat16, attn_fn=chunked_attention,
                return_kv: bool = False):
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q = linear(p["wq"], x, compute_dtype=compute_dtype).reshape(B, S, n_heads, head_dim)
    k = linear(p["wk"], x, compute_dtype=compute_dtype).reshape(B, S, n_kv, head_dim)
    v = linear(p["wv"], x, compute_dtype=compute_dtype).reshape(B, S, n_kv, head_dim)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    o = attn_fn(q, k, v, causal=True, window=window)
    y = linear(p["wo"], o.reshape(B, S, n_heads * head_dim), compute_dtype=compute_dtype)
    if return_kv:
        return y, KVCache(k, v)
    return y


def gqa_decode(p: Params, x: jnp.ndarray, cache: KVCache, pos: jnp.ndarray, *,
               n_heads: int, n_kv: int, head_dim: int, rope_theta: float = 1e4,
               window: int = 0, compute_dtype=jnp.bfloat16):
    """x: (B, d_model) one token. Returns (y, new_cache)."""
    B = x.shape[0]
    C = cache.k.shape[1]
    q = linear(p["wq"], x, compute_dtype=compute_dtype).reshape(B, n_heads, head_dim)
    k = linear(p["wk"], x, compute_dtype=compute_dtype).reshape(B, n_kv, head_dim)
    v = linear(p["wv"], x, compute_dtype=compute_dtype).reshape(B, n_kv, head_dim)
    posb = jnp.full((B, 1), pos)
    q = apply_rope(q[:, None], posb, rope_theta)[:, 0]
    k = apply_rope(k[:, None], posb, rope_theta)[:, 0]
    slot = pos % C if window else pos
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache.k, k[:, None].astype(cache.k.dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache.v, v[:, None].astype(cache.v.dtype), slot, axis=1)
    o = decode_attention(q, k_cache, v_cache, pos, window=window)
    y = linear(p["wo"], o.reshape(B, n_heads * head_dim), compute_dtype=compute_dtype)
    return y, KVCache(k_cache, v_cache)


# ---------------------------------------------------------------------------
# Cross-attention (llama-3.2-vision image layers); no causal mask, no rope on kv
# ---------------------------------------------------------------------------


def cross_attn(p: Params, x: jnp.ndarray, kv_feats: jnp.ndarray, *,
               n_heads: int, n_kv: int, head_dim: int,
               compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    B, S, _ = x.shape
    T = kv_feats.shape[1]
    q = linear(p["wq"], x, compute_dtype=compute_dtype).reshape(B, S, n_heads, head_dim)
    k = linear(p["wk"], kv_feats, compute_dtype=compute_dtype).reshape(B, T, n_kv, head_dim)
    v = linear(p["wv"], kv_feats, compute_dtype=compute_dtype).reshape(B, T, n_kv, head_dim)
    o = chunked_attention(q, k, v, causal=False)
    return linear(p["wo"], o.reshape(B, S, n_heads * head_dim), compute_dtype=compute_dtype)


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V3).  The KV cache stores the
# compressed latent c_kv (kv_lora_rank) + decoupled rope key (qk_rope_dim):
# 576 floats/token instead of n_kv*head_dim*2 = 32768 — the paper-assigned
# arch's own long-context enabler.
# ---------------------------------------------------------------------------


class MLACache(NamedTuple):
    ckv: jnp.ndarray   # (B, C, kv_lora_rank)
    krope: jnp.ndarray  # (B, C, qk_rope_dim)


def init_mla(key, d_model: int, n_heads: int, *, q_lora: int, kv_lora: int,
             qk_nope: int, qk_rope: int, v_dim: int, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 6)
    return {
        "wq_a": init_linear(ks[0], d_model, q_lora, dtype=dtype),
        "wq_b": init_linear(ks[1], q_lora, n_heads * (qk_nope + qk_rope), dtype=dtype),
        "wkv_a": init_linear(ks[2], d_model, kv_lora + qk_rope, dtype=dtype),
        "wkv_b": init_linear(ks[3], kv_lora, n_heads * (qk_nope + v_dim), dtype=dtype),
        "wo": init_linear(ks[4], n_heads * v_dim, d_model, dtype=dtype),
    }


def _mla_qkv(p, x, *, n_heads, qk_nope, qk_rope, v_dim, positions, rope_theta,
             compute_dtype):
    B, S, _ = x.shape
    q = linear(p["wq_b"], linear(p["wq_a"], x, compute_dtype=compute_dtype),
               compute_dtype=compute_dtype).reshape(B, S, n_heads, qk_nope + qk_rope)
    q_nope, q_rope = q[..., :qk_nope], q[..., qk_nope:]
    q_rope = apply_rope(q_rope, positions, rope_theta)
    kv_a = linear(p["wkv_a"], x, compute_dtype=compute_dtype)
    ckv, k_rope = kv_a[..., :-qk_rope], kv_a[..., -qk_rope:]
    k_rope = apply_rope(k_rope[:, :, None], positions, rope_theta)  # (B,S,1,r)
    kv = linear(p["wkv_b"], ckv, compute_dtype=compute_dtype).reshape(
        B, S, n_heads, qk_nope + v_dim)
    k_nope, v = kv[..., :qk_nope], kv[..., qk_nope:]
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, n_heads, qk_rope))], axis=-1)
    return q_full, k_full, v, ckv, k_rope[:, :, 0]


def mla_prefill(p: Params, x: jnp.ndarray, *, n_heads: int, qk_nope: int,
                qk_rope: int, v_dim: int, rope_theta: float = 1e4,
                positions: Optional[jnp.ndarray] = None, window: int = 0,
                compute_dtype=jnp.bfloat16, attn_fn=chunked_attention):
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v, _, _ = _mla_qkv(p, x, n_heads=n_heads, qk_nope=qk_nope,
                             qk_rope=qk_rope, v_dim=v_dim, positions=positions,
                             rope_theta=rope_theta, compute_dtype=compute_dtype)
    o = attn_fn(q, k, v, causal=True, window=window)
    return linear(p["wo"], o.reshape(B, S, n_heads * v_dim), compute_dtype=compute_dtype)


def mla_decode(p: Params, x: jnp.ndarray, cache: MLACache, pos: jnp.ndarray, *,
               n_heads: int, qk_nope: int, qk_rope: int, v_dim: int,
               kv_lora: int, rope_theta: float = 1e4,
               compute_dtype=jnp.bfloat16):
    """Latent-cache decode: attention runs in the compressed space.

    Uses the absorbed-matmul trick: q_nope is mapped through W^kv_b's key half
    so scores are computed directly against the cached latents.
    """
    B = x.shape[0]
    C = cache.ckv.shape[1]
    posb = jnp.full((B, 1), pos)
    q = linear(p["wq_b"], linear(p["wq_a"], x, compute_dtype=compute_dtype),
               compute_dtype=compute_dtype).reshape(B, n_heads, qk_nope + qk_rope)
    q_nope, q_rope = q[..., :qk_nope], q[..., qk_nope:]
    q_rope = apply_rope(q_rope[:, None], posb, rope_theta)[:, 0]
    kv_a = linear(p["wkv_a"], x, compute_dtype=compute_dtype)
    ckv_t, krope_t = kv_a[..., :-qk_rope], kv_a[..., -qk_rope:]
    krope_t = apply_rope(krope_t[:, None, None], posb, rope_theta)[:, 0, 0]
    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache.ckv, ckv_t[:, None].astype(cache.ckv.dtype), pos, axis=1)
    krope = jax.lax.dynamic_update_slice_in_dim(
        cache.krope, krope_t[:, None].astype(cache.krope.dtype), pos, axis=1)
    # Absorb: W^kv_b = [W_k (kv_lora -> H*qk_nope); W_v (kv_lora -> H*v_dim)]
    wkv = p["wkv_b"]["w"].astype(compute_dtype).reshape(kv_lora, n_heads, qk_nope + v_dim)
    wk, wv = wkv[..., :qk_nope], wkv[..., qk_nope:]
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope.astype(jnp.float32),
                       wk.astype(jnp.float32))  # (B,H,kv_lora)
    scale = 1.0 / math.sqrt(qk_nope + qk_rope)
    # bf16 latent-cache reads + f32 accumulation (§Perf B2): never
    # materialise an f32 copy of the (B, C, kv_lora) cache.
    s = (jnp.einsum("bhr,btr->bht", q_lat.astype(ckv.dtype), ckv,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhr,btr->bht", q_rope.astype(krope.dtype), krope,
                      preferred_element_type=jnp.float32)) * scale
    valid = jnp.arange(C) <= pos
    s = jnp.where(valid[None, None, :], s, NEG_INF)
    prob = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bht,btr->bhr", prob.astype(ckv.dtype), ckv,
                       preferred_element_type=jnp.float32)  # (B,H,r)
    o = jnp.einsum("bhr,rhv->bhv", o_lat, wv.astype(jnp.float32))
    y = linear(p["wo"], o.reshape(B, n_heads * v_dim).astype(compute_dtype),
               compute_dtype=compute_dtype)
    return y, MLACache(ckv, krope)

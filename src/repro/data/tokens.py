"""Synthetic LM token pipeline: Zipf-distributed token stream with Markov
bigram structure (so a real LM loss signal exists), batched + host-sharded.
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np

from repro.configs.base import ArchConfig
from repro.data.synthetic import monitoring_target


def zipf_tokens(rng: np.random.Generator, shape, vocab: int,
                a: float = 1.2) -> np.ndarray:
    """Zipf-ish token ids in [0, vocab) via inverse-CDF on a power law."""
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = ranks ** (-a)
    probs /= probs.sum()
    return rng.choice(vocab, size=shape, p=probs).astype(np.int32)


def markov_stream(rng: np.random.Generator, batch: int, seq: int, vocab: int,
                  order_mix: float = 0.5) -> np.ndarray:
    """Mix of Zipf draws and a deterministic bigram successor (t+1 = 7t+3 mod V)
    so next-token prediction is partially learnable."""
    base = zipf_tokens(rng, (batch, seq), vocab)
    succ = (7 * base[:, :-1] + 3) % vocab
    use_succ = rng.uniform(size=(batch, seq - 1)) < order_mix
    out = base.copy()
    out[:, 1:] = np.where(use_succ, succ, base[:, 1:])
    return out


def lm_batches(seed: int, cfg: ArchConfig, batch: int, seq: int,
               *, with_monitor: bool = True) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite iterator of {tokens, labels[, monitor_target, image_embeds]}."""
    rng = np.random.default_rng(seed)
    while True:
        if cfg.family == "audio":
            toks = zipf_tokens(rng, (batch, seq + 1, cfg.n_codebooks), cfg.vocab_size)
            b = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
            mon_src = toks[:, :-1, 0]
        else:
            toks = markov_stream(rng, batch, seq + 1, cfg.vocab_size)
            b = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
            mon_src = toks[:, :-1]
        if with_monitor:
            b["monitor_target"] = monitoring_target(mon_src, cfg.vocab_size)
        if cfg.family == "vlm":
            b["image_embeds"] = rng.standard_normal(
                (batch, cfg.n_image_tokens, cfg.d_model)).astype(np.float32)
        yield b

"""Data generators.

1. ``paper_synthetic`` — the paper's §4.1 dataset, exact: x ~ U[-3,3],
   f(x) = sum_{i=1}^{100} rho^{i-1} cos(ix) with rho = 0.9.
2. ``financial_series`` — §4.2 stand-in.  The DJIA CSV is not downloadable
   in this offline container, so we synthesise a 30-ticker correlated
   geometric-Brownian-motion panel with DJIA-like statistics (daily vol
   ~1.5%, pairwise correlation ~0.4, 10y span), normalised to [0,1] exactly
   as the paper does.  Ticker 0 plays 'AAPL' (target), tickers 1..29 are
   the predictors.  Documented in DESIGN.md §9.
3. ``monitoring_target`` — per-position scalar 'health index' for the LLM
   scale: a deterministic function of the token stream (EWMA of a token
   hazard + slow drift), so the monitor head has a learnable ground truth
   whose adverse events (f > gamma) are sparse.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


def paper_synthetic(seed: int, n: int, *, rho: float = 0.9,
                    n_modes: int = 100, x_range: Tuple[float, float] = (-3.0, 3.0)
                    ) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    x = rng.uniform(x_range[0], x_range[1], size=(n, 1)).astype(np.float32)
    i = np.arange(1, n_modes + 1, dtype=np.float64)
    a = rho ** (i - 1)
    f = (np.cos(x.astype(np.float64) * i[None, :]) @ a).astype(np.float32)
    return x, f


def synthetic_residual(x: np.ndarray, n: int, *, rho: float = 0.9,
                       n_modes: int = 100) -> np.ndarray:
    """sum_{i>n} a_i cos(ix) — used for exact t(n) calibration (Prop 2)."""
    i = np.arange(n + 1, n_modes + 1, dtype=np.float64)
    a = rho ** (i - 1)
    xs = x[..., 0] if x.ndim > 1 else x
    return (np.cos(xs.astype(np.float64)[:, None] * i[None, :]) @ a).astype(np.float32)


def financial_series(seed: int, n_days: int = 2520, n_tickers: int = 30,
                     *, daily_vol: float = 0.015, corr: float = 0.4,
                     drift: float = 0.0003) -> np.ndarray:
    """(n_days, n_tickers) normalised-to-[0,1] price panel (correlated GBM)."""
    rng = np.random.default_rng(seed)
    cov = np.full((n_tickers, n_tickers), corr)
    np.fill_diagonal(cov, 1.0)
    chol = np.linalg.cholesky(cov)
    shocks = rng.standard_normal((n_days, n_tickers)) @ chol.T
    logret = drift + daily_vol * shocks
    prices = 100.0 * np.exp(np.cumsum(logret, axis=0))
    lo, hi = prices.min(axis=0, keepdims=True), prices.max(axis=0, keepdims=True)
    return ((prices - lo) / (hi - lo + 1e-9)).astype(np.float32)


def financial_xy(panel: np.ndarray, target_col: int = 0
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """x = other 29 tickers, f = target ticker (paper: AAPL from the rest)."""
    f = panel[:, target_col]
    x = np.delete(panel, target_col, axis=1)
    return x.astype(np.float32), f.astype(np.float32)


# ---------------------------------------------------------------------------
# LLM-scale monitoring target
# ---------------------------------------------------------------------------


def monitoring_target(tokens: np.ndarray, vocab: int, *, hazard_frac: float = 0.03,
                      ewma: float = 0.95, drift_period: int = 512,
                      seed: int = 7) -> np.ndarray:
    """Deterministic per-position health index f in ~[-1, 1.5].

    A fixed pseudo-random subset (hazard_frac) of the vocabulary is
    'hazardous'; f is an EWMA of hazard occurrences plus a slow sinusoidal
    drift.  Adverse events (f > 0 after centering) are sparse and have
    temporal structure -> a sensible early-warning learning problem.
    tokens: (B, S) int -> (B, S) float32.
    """
    rng = np.random.default_rng(seed)
    hazard = (rng.uniform(size=vocab) < hazard_frac).astype(np.float32)
    h = hazard[tokens.reshape(-1)].reshape(tokens.shape)  # (B,S)
    B, S = tokens.shape
    f = np.zeros((B, S), np.float32)
    acc = np.zeros((B,), np.float32)
    for t in range(S):
        acc = ewma * acc + (1 - ewma) * h[:, t]
        f[:, t] = acc
    f = f / (hazard_frac + 1e-9)  # EWMA of Bernoulli(p) has mean p -> ~O(1)
    drift = 0.3 * np.sin(2 * np.pi * np.arange(S) / drift_period)
    return (f + drift[None, :] - 0.5).astype(np.float32)

"""Numeric forms of the paper's theory (Props 1-4 and §3.4 selection rules).

These are the *design rules* the framework applies when constructing a
monitor: given the coefficient decay of the target's basis expansion
(Assumption 1, Eq. 7), choose the truncation n, the safety offset t(n)
(Prop 2), and the corrector scale s (Props 2+3: s = 2 t(n) is the smallest
scale that preserves safety, and FP grows with s).
"""
from __future__ import annotations

import numpy as np


# -- Prop 2: t(n) = || sum_{i>n} a_i phi_i ||_inf ---------------------------

def t_of_n(coeffs: np.ndarray, n: int, phi_sup: float = 1.0) -> float:
    """Practical estimate t(n) ~= sum_{i>n} |a_i| * sup|phi| (paper §4.1 uses
    sum |a_i| as the inf-norm surrogate for the cosine basis)."""
    c = np.asarray(coeffs, dtype=np.float64)
    return float(np.sum(np.abs(c[n:])) * phi_sup)


def t_of_n_sampled(residual_fn, xs: np.ndarray) -> float:
    """Exact-on-sample t(n) = max_x |sum_{i>n} a_i phi_i(x)| (tight variant —
    closes the paper's noted gap between theoretical and practical optima)."""
    return float(np.max(np.abs(residual_fn(xs))))


def s_rule(t: float) -> float:
    """Props 2+3: s = 2 t(n) — smallest s that keeps FN = 0, minimising FP."""
    return 2.0 * t


# -- §3.4 closed forms -------------------------------------------------------

def exp_decay_s(rho: float, n: int) -> float:
    """a_i = rho^{i-1}: t(n) = rho^n/(1-rho); paper picks s ~ rho^n/(1-rho)."""
    return rho ** n / (1.0 - rho)


def power_law_s(alpha: float, n: int) -> float:
    """a_i = i^{-alpha}, orthonormal phi: ||residual||_2^2 <~ 1/n^{2a-1}."""
    return float(n ** (1.0 - 2.0 * alpha))


# -- Prop 3: FP upper bound --------------------------------------------------

def prop3_fp_bound(delta: float, s: float, eps: float, vol: float = 1.0) -> float:
    """mu_FP,eps <= (delta + s) * vol(Omega) / (2 eps)."""
    return (delta + s) * vol / (2.0 * eps)


# -- Prop 4: FN mass bound (Chebyshev) when t is under-sized -----------------

def prop4_fn_bound(residual_l2_sq: float, eps: float, t: float) -> float:
    """mu(Omega_FN,eps) <= ||sum_{i>n} a_i phi_i||_2^2 / (2 eps + t)^2."""
    return residual_l2_sq / (2.0 * eps + t) ** 2


def prop4_region_bound(residual_l2_sq: float, t: float, s: float) -> float:
    """mu(Omega^c_{-t,s-t}) <= (1/t^2 + 1/(s-t)^2) ||residual||_2^2."""
    return (1.0 / t ** 2 + 1.0 / (s - t) ** 2) * residual_l2_sq


# -- coefficient generators for the two §3.4 regimes -------------------------

def exp_coeffs(rho: float, n_modes: int) -> np.ndarray:
    return rho ** np.arange(n_modes, dtype=np.float64)


def power_coeffs(alpha: float, n_modes: int) -> np.ndarray:
    return (1.0 / np.arange(1, n_modes + 1, dtype=np.float64)) ** alpha

"""Performance metrics of paper §2.3: approximation error (Eq. 2), false
positive rate (Eq. 3), false negative rate (Eq. 4) — plus the corrected
(post-server) variants reported in Fig 2(d).

All metrics take the ground truth f, the on-device monitor u, and optionally
the combined prediction f_hat = u - s*sigma(v), as same-shaped arrays; the
threshold gamma defaults to 0 as in the paper ("for simplicity of
presentation we can set gamma to 0"), overridable for e.g. the financial
experiment's 0.8.
"""
from __future__ import annotations

import jax.numpy as jnp


def approx_error(f: jnp.ndarray, fhat: jnp.ndarray, p: float = 2.0) -> jnp.ndarray:
    """||f - fhat||_p, Monte-Carlo normalised (vol(Omega)=1 convention)."""
    d = jnp.abs(f.astype(jnp.float32) - fhat.astype(jnp.float32))
    if p == jnp.inf or p == float("inf"):
        return jnp.max(d)
    return jnp.mean(d ** p) ** (1.0 / p)


def fp_rate(f: jnp.ndarray, u: jnp.ndarray, eps: float = 0.0,
            threshold: float = 0.0) -> jnp.ndarray:
    """mu_FP,eps (Eq. 3): u raises the alarm while f is safely below."""
    return jnp.mean((f < threshold - eps) & (u > threshold + eps))


def fn_rate(f: jnp.ndarray, u: jnp.ndarray, eps: float = 0.0,
            threshold: float = 0.0) -> jnp.ndarray:
    """mu_FN,eps (Eq. 4): the safety-critical miss — f is adverse, u silent."""
    return jnp.mean((f > threshold + eps) & (u < threshold - eps))


def safety_violation(f: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """Mass and magnitude of u < f violations (u must upper-bound f)."""
    gap = f.astype(jnp.float32) - u.astype(jnp.float32)
    return jnp.mean(gap > 0), jnp.max(jnp.maximum(gap, 0.0))


def metrics_report(f, u, fhat, *, eps: float = 0.05, threshold: float = 0.0):
    """Full §2.3 metric set; 'corrected_*' replicate Fig 2(d) (server view)."""
    viol_rate, viol_max = safety_violation(f, u)
    return {
        "l1": approx_error(f, fhat, 1.0),
        "l2": approx_error(f, fhat, 2.0),
        "linf": approx_error(f, fhat, jnp.inf),
        "fp": fp_rate(f, u, eps, threshold),
        "fn": fn_rate(f, u, eps, threshold),
        "corrected_fp": fp_rate(f, fhat, eps, threshold),
        "corrected_fn": fn_rate(f, fhat, eps, threshold),
        "safety_violation_rate": viol_rate,
        "safety_violation_max": viol_max,
    }

"""Trigger-gated corrector dispatch + communication accounting.

The paper's serving protocol: the device evaluates u continuously; only
when u(x) > gamma - margin does it ship x to the server, which returns the
corrected f_hat = u - s*sigma(v).  Under SPMD two realisations exist
(DESIGN.md §3):

* ``masked_correction``   — dense compute, trigger applied as a mask.
  Shape-static, used inside jit'd training/eval steps and the dry-run.
* ``compact_correction``  — static-capacity compaction (the MoE trick):
  gather the triggered rows into a (capacity, ...) buffer, run the server
  on the small buffer only, scatter back.  This recovers the paper's
  compute/communication saving at serving time with fixed shapes.

``CommsMeter`` reproduces the paper's "communication reduced 10x" metric:
bytes actually shipped to the server vs. the ship-everything baseline.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def trigger_mask(u: jnp.ndarray, threshold: float, margin: float) -> jnp.ndarray:
    """1 where the device must consult the server (u near/above gamma)."""
    return (u > threshold - margin).astype(jnp.float32)


def masked_correction(u: jnp.ndarray, corr: jnp.ndarray, threshold: float,
                      margin: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """fhat = u - corr where triggered, u elsewhere.  Returns (fhat, mask)."""
    mask = trigger_mask(u, threshold, margin)
    return u - mask * corr, mask


def compact_correction(u: jnp.ndarray, xs: jnp.ndarray, corrector: Callable,
                       threshold: float, margin: float,
                       capacity: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Static-capacity gated correction over a flat batch (the MoE
    gather/scatter trick, applied to the paper's trigger).

    u: (N,) monitor scores; xs: (N, ...) server inputs; corrector maps a
    (capacity, ...) buffer to (capacity,) correction values (>= 0).
    Returns (fhat, mask, n_triggered).

    Contract (load-bearing for the serving scan path — see
    ``serving/collaborative.py`` scan path, ``SessionConfig(mode="scan")``):

    * **Static shapes.** ``capacity`` is a Python int, so the gather buffer
      ``xs[sel]`` has shape (capacity, ...) regardless of how many rows
      actually triggered — jit/scan-safe, recompilation-free.  Only
      ``capacity`` rows ever reach the (expensive) corrector; that is the
      paper's server-compute saving with fixed shapes.
    * **Selection.** Rows are ranked by trigger urgency
      ``u - (threshold - margin)``; non-triggered rows sort to +inf (the
      back).  ``jnp.argsort`` is stable, so ties and the untriggered tail
      resolve deterministically by row index — reruns are bit-identical.
    * **Overflow is conservative.** If more than ``capacity`` rows
      triggered, the LEAST urgent overflow rows pass through uncorrected as
      plain ``u``.  Because the sign constraint makes u an upper bound
      (fhat = u - s*sigma(v) <= u), dropping a correction can only keep a
      warning raised, never suppress one — overflow errs toward false
      positives, never false negatives.
    * **Scatter.** Untriggered rows gathered into the buffer as padding
      have their corrections zeroed by the ``valid`` mask before the
      scatter-add, so their fhat stays exactly ``u`` (bit-identical, not
      just approximately).
    """
    n = u.shape[0]
    urgency = u - (threshold - margin)  # > 0 == triggered
    triggered = urgency > 0
    # rank rows by urgency; non-triggered rows sort to the back
    order = jnp.argsort(jnp.where(triggered, -urgency, jnp.inf))
    sel = order[:capacity]
    buf = xs[sel]
    corr_buf = corrector(buf)  # (capacity,)
    valid = triggered[sel]
    fhat = u.at[sel].add(-(corr_buf * valid))
    mask = jnp.zeros((n,), jnp.float32).at[sel].set(valid.astype(jnp.float32))
    return fhat, mask, jnp.sum(triggered.astype(jnp.int32))


@dataclass
class CommsMeter:
    """Accounts device->server traffic (paper Fig 4: '10x reduction').

    Accounting is TOKEN-level: ``bytes_per_request`` is the payload of one
    shipped token (id + edge score), and the baseline assumes every observed
    token of every stream is shipped (pure on-server inference).

    Two granularities:

    * aggregate (``update``) — the legacy scalar API, still used by the
      paper-scale benches where the batch is one logical stream;
    * per-stream (``update_per_stream``) — each batch element is an
      independent monitored stream with its own shipped/observed counters,
      so the Fig-4 "reduction x" metric is measured per stream instead of
      smeared across the batch.  A trigger on stream i charges only
      stream i's backlog.

    Invariant (asserted in tests): each token is shipped at most once, so
    ``bytes_sent <= bytes_baseline`` always.  In async mode tokens are
    charged at DISPATCH (when they leave the device), not at merge — the
    wire is paid when the bytes move, so the invariant and the Fig-4
    reduction are staleness-independent.

    Async serving additionally meters the latency model (see
    ``serving/async_rpc.py``): per-stream in-flight request counts, the
    edge-loop stall time spent blocked on overdue replies, the server busy
    time, and the derived ``overlap_ratio`` — the fraction of total
    request wall time (compute + simulated network) hidden behind edge
    decode.  Synchronous fallback => overlap_ratio ~ 0; a deep enough
    pipeline => ~ 1.
    """

    bytes_per_request: int
    n_streams: int = 1
    rate_window: int = 64     # steps retained by the windowed rate gauge
    total_steps: int = 0
    triggered: int = 0        # trigger EVENTS (server consults)
    tokens_shipped: int = 0   # tokens actually sent (drives bytes_sent)
    tokens_sent: Optional[np.ndarray] = None   # (n_streams,) shipped tokens
    tokens_seen: Optional[np.ndarray] = None   # (n_streams,) observed tokens
    # -- async pipelining (filled by the Dispatcher) ------------------------
    requests_inflight: Optional[np.ndarray] = None  # (n_streams,) in flight now
    inflight_peak: int = 0     # max simultaneous in-flight requests
    dispatched: int = 0        # async requests dispatched
    merged_late: int = 0       # replies merged >= 1 step after their trigger
    stall_s: float = 0.0       # edge-loop time blocked on overdue replies
    server_busy_s: float = 0.0  # worker compute time
    request_wall_s: float = 0.0  # dispatch -> reply-visible (incl. latency)
    # -- wire transport (filled by SocketWorker): MEASURED, not modelled ----
    wire_tx_bytes: int = 0     # bytes actually written to the socket
    wire_rx_bytes: int = 0     # bytes actually read off the socket
    wire_rtt_s: float = 0.0    # sum of measured dispatch->reply round trips
    wire_rtt_max_s: float = 0.0
    wire_replies: int = 0
    # -- shm transport (filled by ShmWorker): ring-plane bytes/RTTs ---------
    shm_tx_bytes: int = 0      # frame bytes written into the c->s ring
    shm_rx_bytes: int = 0      # frame bytes drained from the s->c ring
    shm_rtt_s: float = 0.0     # sum of measured dispatch->reply round trips
    shm_rtt_max_s: float = 0.0
    shm_replies: int = 0
    # -- fleet failover (filled by SocketWorker when it migrates) -----------
    failovers: int = 0               # completed re-HELLO + replay migrations
    failover_tx_bytes: int = 0       # handshake + replay + resend tx bytes
    failover_rx_bytes: int = 0       # bytes read during recovery
    failover_replayed_tokens: int = 0  # tokens re-shipped (already paid once)
    failover_replay_requests: int = 0  # synthetic recovery requests sent
    failover_resent_requests: int = 0  # real in-flight requests re-sent

    def __post_init__(self) -> None:
        if self.tokens_sent is None:
            self.tokens_sent = np.zeros(self.n_streams, np.int64)
        if self.tokens_seen is None:
            self.tokens_seen = np.zeros(self.n_streams, np.int64)
        if self.requests_inflight is None:
            self.requests_inflight = np.zeros(self.n_streams, np.int64)
        # windowed per-stream trigger-rate gauge: one ring column per
        # update_per_stream call; cumulative trigger_rate washes out
        # regime changes, controllers need the recent rate
        self._ring_events = np.zeros((self.n_streams, self.rate_window), bool)
        self._ring_seen = np.zeros((self.n_streams, self.rate_window), bool)
        self._ring_pos = 0
        self._ring_len = 0
        self._per_stream_used = False
        self._async_used = False
        self._wire_used = False
        self._shm_used = False
        self._failover_used = False
        self._inflight_reqs = 0

    def update(self, n_triggered: int, n_total: int) -> None:
        """Aggregate accounting (legacy scalar path): n_triggered streams
        consulted the server this step, each shipping one token."""
        self.total_steps += int(n_total)
        self.triggered += int(n_triggered)
        self.tokens_shipped += int(n_triggered)

    def update_per_stream(self, sent, seen, events=None) -> None:
        """Per-stream accounting.  sent/seen: (n_streams,) token counts for
        this event (sent[i] = stream i's backlog shipped, 0 if untriggered;
        seen[i] = new tokens observed on stream i, usually 1 per step).
        ``events``: trigger-event count per stream for this update
        (defaults to sent > 0 — right for a single step; pass explicitly
        when folding a whole trace into one call)."""
        sent = np.asarray(sent, np.int64)
        seen = np.asarray(seen, np.int64)
        if events is None:
            events = (sent > 0).astype(np.int64)
        self._per_stream_used = True
        self.tokens_sent += sent
        self.tokens_seen += seen
        self.tokens_shipped += int(sent.sum())
        self.triggered += int(np.asarray(events).sum())
        self.total_steps += int(seen.sum())
        # push one ring column (this call ~ one step); the legacy
        # aggregate update() does not feed the gauge
        self._ring_events[:, self._ring_pos] = np.asarray(events) > 0
        self._ring_seen[:, self._ring_pos] = seen > 0
        self._ring_pos = (self._ring_pos + 1) % self.rate_window
        self._ring_len = min(self._ring_len + 1, self.rate_window)

    def recent_trigger_rate(self) -> np.ndarray:
        """(n_streams,) trigger rate over the last ``rate_window``
        per-stream updates, counting only steps where the stream actually
        observed a token (detached slots don't dilute their own rate).
        Unlike the cumulative ``trigger_rate``, this tracks regime
        changes — it is the comms feedback the threshold controllers in
        ``serving/policy.py`` consume.  All-cold streams report 0."""
        ev = self._ring_events.sum(axis=1, dtype=np.int64)
        seen = self._ring_seen.sum(axis=1, dtype=np.int64)
        return ev / np.maximum(seen, 1)

    # -- async pipelining ----------------------------------------------------
    def record_dispatch(self, mask) -> None:
        """A catch-up request left the edge; ``mask``: (n_streams,) bool of
        the streams it serves."""
        self._async_used = True
        self.requests_inflight += np.asarray(mask, bool)
        self.dispatched += 1
        self._inflight_reqs += 1
        self.inflight_peak = max(self.inflight_peak, self._inflight_reqs)

    def record_merge(self, mask, age: int) -> None:
        """The reply for ``mask`` merged ``age`` edge steps after its
        trigger (0 == synchronous fallback)."""
        self.requests_inflight -= np.asarray(mask, bool)
        self._inflight_reqs -= 1
        if age > 0:
            self.merged_late += 1

    def record_stall(self, dt: float) -> None:
        """Edge loop blocked ``dt`` seconds waiting for an overdue reply."""
        self.stall_s += float(dt)

    def record_server_busy(self, compute_s: float, wall_s: float) -> None:
        self.server_busy_s += float(compute_s)
        self.request_wall_s += float(wall_s)

    # -- wire transport (measured bytes/latency; serving/wire.py) -----------
    def record_wire_tx(self, nbytes: int) -> None:
        """``nbytes`` actually handed to the kernel (frames incl. headers
        and handshake) — the measured counterpart of ``bytes_sent``."""
        self._wire_used = True
        self.wire_tx_bytes += int(nbytes)

    def record_wire_rx(self, nbytes: int) -> None:
        self._wire_used = True
        self.wire_rx_bytes += int(nbytes)

    def record_wire_rtt(self, dt: float) -> None:
        """One measured dispatch->reply round trip over the real socket
        (serialization + kernel + server replay + deserialization)."""
        self._wire_used = True
        self.wire_replies += 1
        self.wire_rtt_s += float(dt)
        self.wire_rtt_max_s = max(self.wire_rtt_max_s, float(dt))

    # -- shm transport (same-host rings; serving/shm.py).  Ring traffic is
    # metered like socket traffic — zero-copy is not zero-cost, and the
    # byte-reduction story must stay honest when frames move via memcpy --
    def record_shm_tx(self, nbytes: int) -> None:
        """``nbytes`` of wire-codec frames written into the c->s ring."""
        self._shm_used = True
        self.shm_tx_bytes += int(nbytes)

    def record_shm_rx(self, nbytes: int) -> None:
        self._shm_used = True
        self.shm_rx_bytes += int(nbytes)

    def record_shm_rtt(self, dt: float) -> None:
        """One measured dispatch->reply round trip over the ring pair."""
        self._shm_used = True
        self.shm_replies += 1
        self.shm_rtt_s += float(dt)
        self.shm_rtt_max_s = max(self.shm_rtt_max_s, float(dt))

    # -- fleet failover (replay bytes audited separately from steady state) --
    def record_failover(self) -> None:
        """One completed migration: re-HELLO at a new server plus the cold
        catch-up replay that rebuilt the lease from the client's history."""
        self._failover_used = True
        self.failovers += 1

    def record_failover_tx(self, nbytes: int) -> None:
        """Bytes the recovery path wrote (handshake, replay requests,
        resent in-flight requests) — charged here, NOT to ``wire``, so the
        steady-state byte invariant stays auditable."""
        self._failover_used = True
        self.failover_tx_bytes += int(nbytes)

    def record_failover_rx(self, nbytes: int) -> None:
        self._failover_used = True
        self.failover_rx_bytes += int(nbytes)

    def record_failover_tokens(self, n_tokens: int, *,
                               resent: bool = False) -> None:
        """``n_tokens`` re-shipped during recovery (each was already paid
        for once in the wire bucket when first dispatched)."""
        self._failover_used = True
        self.failover_replayed_tokens += int(n_tokens)
        if resent:
            self.failover_resent_requests += 1
        else:
            self.failover_replay_requests += 1

    @property
    def overlap_ratio(self) -> float:
        """Fraction of request wall time (server compute + network) hidden
        behind edge decode; 1.0 when the pipeline never stalled."""
        if self.request_wall_s <= 0.0:
            return 1.0 if self.stall_s == 0.0 else 0.0
        return max(0.0, 1.0 - self.stall_s / self.request_wall_s)

    @property
    def trigger_rate(self) -> float:
        """Fraction of stream-steps that consulted the server (the paper's
        trigger frequency — NOT the shipped-token fraction; backlogs mean
        one consult can ship many tokens)."""
        return self.triggered / max(self.total_steps, 1)

    @property
    def bytes_sent(self) -> int:
        return self.tokens_shipped * self.bytes_per_request

    @property
    def bytes_baseline(self) -> int:
        """Ship-everything baseline (pure on-server inference)."""
        return self.total_steps * self.bytes_per_request

    @property
    def reduction(self) -> float:
        return self.bytes_baseline / max(self.bytes_sent, 1)

    def per_stream_report(self) -> Dict[str, np.ndarray]:
        sent_b = self.tokens_sent * self.bytes_per_request
        base_b = self.tokens_seen * self.bytes_per_request
        return {"bytes_sent": sent_b,
                "bytes_baseline": base_b,
                "reduction_x": base_b / np.maximum(sent_b, 1),
                "recent_trigger_rate": self.recent_trigger_rate()}

    def report(self) -> Dict[str, float]:
        rep = {"trigger_rate": self.trigger_rate,
               "bytes_sent": self.bytes_sent,
               "bytes_baseline": self.bytes_baseline,
               "reduction_x": self.reduction}
        if self._per_stream_used:  # only when per-stream accounting ran
            rep["per_stream"] = self.per_stream_report()
        if self._async_used:       # only when the pipelined path ran
            rep["async"] = {
                "requests": self.dispatched,
                "merged_late": self.merged_late,
                "inflight_now": int(self.requests_inflight.sum()),
                "inflight_peak": self.inflight_peak,
                "stall_s": self.stall_s,
                "server_busy_s": self.server_busy_s,
                "request_wall_s": self.request_wall_s,
                "overlap_ratio": self.overlap_ratio,
            }
        if self._wire_used:        # only when the wire transport ran
            rep["wire"] = {
                "tx_bytes": self.wire_tx_bytes,
                "rx_bytes": self.wire_rx_bytes,
                "replies": self.wire_replies,
                "rtt_mean_s": self.wire_rtt_s / max(self.wire_replies, 1),
                "rtt_max_s": self.wire_rtt_max_s,
            }
        if self._shm_used:         # only when the shm rings carried frames
            rep["shm"] = {
                "tx_bytes": self.shm_tx_bytes,
                "rx_bytes": self.shm_rx_bytes,
                "replies": self.shm_replies,
                "rtt_mean_s": self.shm_rtt_s / max(self.shm_replies, 1),
                "rtt_max_s": self.shm_rtt_max_s,
            }
        if self._failover_used:    # only when a fleet migration happened
            rep["failover"] = {
                "failovers": self.failovers,
                "tx_bytes": self.failover_tx_bytes,
                "rx_bytes": self.failover_rx_bytes,
                "replayed_tokens": self.failover_replayed_tokens,
                "replay_requests": self.failover_replay_requests,
                "resent_requests": self.failover_resent_requests,
            }
        return rep

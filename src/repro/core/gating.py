"""Trigger-gated corrector dispatch + communication accounting.

The paper's serving protocol: the device evaluates u continuously; only
when u(x) > gamma - margin does it ship x to the server, which returns the
corrected f_hat = u - s*sigma(v).  Under SPMD two realisations exist
(DESIGN.md §3):

* ``masked_correction``   — dense compute, trigger applied as a mask.
  Shape-static, used inside jit'd training/eval steps and the dry-run.
* ``compact_correction``  — static-capacity compaction (the MoE trick):
  gather the triggered rows into a (capacity, ...) buffer, run the server
  on the small buffer only, scatter back.  This recovers the paper's
  compute/communication saving at serving time with fixed shapes.

``CommsMeter`` reproduces the paper's "communication reduced 10x" metric:
bytes actually shipped to the server vs. the ship-everything baseline.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp


def trigger_mask(u: jnp.ndarray, threshold: float, margin: float) -> jnp.ndarray:
    """1 where the device must consult the server (u near/above gamma)."""
    return (u > threshold - margin).astype(jnp.float32)


def masked_correction(u: jnp.ndarray, corr: jnp.ndarray, threshold: float,
                      margin: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """fhat = u - corr where triggered, u elsewhere.  Returns (fhat, mask)."""
    mask = trigger_mask(u, threshold, margin)
    return u - mask * corr, mask


def compact_correction(u: jnp.ndarray, xs: jnp.ndarray, corrector: Callable,
                       threshold: float, margin: float,
                       capacity: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Static-capacity gated correction over a flat batch.

    u: (N,) monitor scores; xs: (N, ...) server inputs; corrector maps a
    (capacity, ...) buffer to (capacity,) correction values (>= 0).
    Rows are ranked by trigger urgency; the top-``capacity`` triggered rows
    are corrected, the rest pass through as u (exactly the device-side
    behaviour).  Returns (fhat, mask, n_triggered).
    """
    n = u.shape[0]
    urgency = u - (threshold - margin)  # > 0 == triggered
    triggered = urgency > 0
    # rank rows by urgency; non-triggered rows sort to the back
    order = jnp.argsort(jnp.where(triggered, -urgency, jnp.inf))
    sel = order[:capacity]
    buf = xs[sel]
    corr_buf = corrector(buf)  # (capacity,)
    valid = triggered[sel]
    fhat = u.at[sel].add(-(corr_buf * valid))
    mask = jnp.zeros((n,), jnp.float32).at[sel].set(valid.astype(jnp.float32))
    return fhat, mask, jnp.sum(triggered.astype(jnp.int32))


@dataclass
class CommsMeter:
    """Accounts device->server traffic (paper Fig 4: '10x reduction')."""

    bytes_per_request: int
    total_steps: int = 0
    triggered: int = 0

    def update(self, n_triggered: int, n_total: int) -> None:
        self.total_steps += int(n_total)
        self.triggered += int(n_triggered)

    @property
    def trigger_rate(self) -> float:
        return self.triggered / max(self.total_steps, 1)

    @property
    def bytes_sent(self) -> int:
        return self.triggered * self.bytes_per_request

    @property
    def bytes_baseline(self) -> int:
        """Ship-everything baseline (pure on-server inference)."""
        return self.total_steps * self.bytes_per_request

    @property
    def reduction(self) -> float:
        return self.bytes_baseline / max(self.bytes_sent, 1)

    def report(self) -> Dict[str, float]:
        return {"trigger_rate": self.trigger_rate,
                "bytes_sent": self.bytes_sent,
                "bytes_baseline": self.bytes_baseline,
                "reduction_x": self.reduction}

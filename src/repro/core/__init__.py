from repro.core import decomposition, gating, losses, safety, theory  # noqa: F401

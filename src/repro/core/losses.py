"""Training objectives.

Paper scale: pure end-to-end MSE on f_hat (the paper's §4 training), with an
optional safety hinge on (f - u) for the 'independent U' regime where t is
learned rather than sized by Prop 2.

LLM scale: the server tower trains as a language model (CE) while the
decomposition trains on the monitoring target; MoE load-balance aux and the
DeepSeek MTP loss fold in.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp


def paper_loss(out: Dict[str, jnp.ndarray], f: jnp.ndarray, *,
               safety_weight: float = 0.0, margin: float = 0.0) -> jnp.ndarray:
    """MSE(f_hat, f) + lambda * E[relu(f - u + margin)^2]."""
    loss = jnp.mean((out["fhat"] - f) ** 2)
    if safety_weight:
        viol = jax.nn.relu(f - out["u"] + margin)
        loss = loss + safety_weight * jnp.mean(viol ** 2)
    return loss


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean CE over all positions; supports (B,S,V) and audio (B,S,K,V)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def collab_lm_loss(out: Dict[str, jnp.ndarray], batch: Dict[str, jnp.ndarray], *,
                   monitor_weight: float = 1.0, safety_weight: float = 10.0,
                   aux_weight: float = 0.01, mtp_weight: float = 0.3,
                   margin: float = 0.0) -> Dict[str, jnp.ndarray]:
    """Joint objective for the collaborative LM system.

    lm       : next-token CE of the server tower
    monitor  : MSE(f_hat, monitor_target) — the paper's approximation term
    safety   : hinge on u < f (paper's safety requirement, learned form)
    aux      : MoE load-balance (+ MTP CE if the arch has an MTP head)
    """
    labels = batch["labels"]
    lm = cross_entropy(out["logits"], labels)
    f = batch["monitor_target"].astype(jnp.float32)
    monitor = jnp.mean((out["fhat"] - f) ** 2)
    safety = jnp.mean(jax.nn.relu(f - out["u"] + margin) ** 2)
    total = (lm + monitor_weight * monitor + safety_weight * safety
             + aux_weight * out["aux_loss"])
    parts = {"lm": lm, "monitor": monitor, "safety": safety,
             "aux": out["aux_loss"]}
    if out.get("mtp_logits") is not None:
        # depth-1 MTP: predict labels shifted one more step
        mtp_labels = jnp.roll(labels, -1, axis=1)
        mtp = cross_entropy(out["mtp_logits"][:, :-2], mtp_labels[:, :-2])
        total = total + mtp_weight * mtp
        parts["mtp"] = mtp
    parts["total"] = total
    return parts

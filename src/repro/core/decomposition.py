"""The paper's contribution: model decomposition  f_hat = u - s*sigma(v).

Two instantiations:

1. ``PaperDecomposition`` — the faithful paper-scale form.  V is an FC net;
   the monitor u is either
     * ``truncated``  : u = sum_{i<=n} a_i phi_i + t over V's penultimate
                        features (paper §4.2, Eq. 8),
     * ``cosine``     : u over the explicit cosine basis (paper §4.1, where
                        the ground-truth expansion is known), or
     * ``independent``: a separate small FC net (paper appendix, Fig 5).
   Safety is structural: the corrector -s*sigma(v) is strictly negative, so
   u >= f_hat always; u >= f holds when t is sized per Prop 2.  This is no
   longer just argued: ``repro.analysis.signs`` proves corr >= 0 (hence
   fhat <= u) on the traced jaxpr of ``collab_forward`` and the serving
   catch-up for every registry arch x sigma kind, and
   ``tools/check_static.py --strict`` gates CI on those certificates.

2. ``init_collab_lm`` / ``collab_*`` — the scaled form used with the 10
   assigned backbones: v = full backbone + scalar corrector head (server),
   u = small edge tower + truncated-basis head (device).  This is the
   Prop-1 regime (arbitrary U); the edge tower never shares weights or
   activations with the server tower, so the device can run standalone.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MonitorConfig
from repro.models import api as model_api
from repro.models.base import cdt
from repro.nn.module import Params, init_linear, linear

# ---------------------------------------------------------------------------
# sigma: fixed continuous invertible map into (0,1)
# ---------------------------------------------------------------------------


def sigma(x: jnp.ndarray, kind: str = "sigmoid") -> jnp.ndarray:
    if kind == "sigmoid":
        return jax.nn.sigmoid(x)
    if kind == "tanh01":
        return 0.5 * (jnp.tanh(x) + 1.0)
    raise ValueError(kind)


def sigma_inv(y: jnp.ndarray, kind: str = "sigmoid") -> jnp.ndarray:
    y = jnp.clip(y, 1e-7, 1 - 1e-7)
    if kind == "sigmoid":
        return jnp.log(y) - jnp.log1p(-y)
    if kind == "tanh01":
        return jnp.arctanh(2.0 * y - 1.0)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Paper-scale MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, dims) -> Params:
    ks = jax.random.split(key, len(dims) - 1)
    return {f"l{i}": init_linear(ks[i], dims[i], dims[i + 1], bias=True,
                                 stddev=1.0 / math.sqrt(dims[i]))
            for i in range(len(dims) - 1)}


def mlp_forward(p: Params, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (scalar_out (B,), penultimate features (B, n_basis))."""
    n = len(p)
    h = x
    for i in range(n - 1):
        h = jnp.tanh(linear(p[f"l{i}"], h))
    out = linear(p[f"l{n-1}"], h)
    return out[..., 0], h


def cosine_basis(x: jnp.ndarray, n_modes: int) -> jnp.ndarray:
    """phi_i(x) = cos(i x), i = 1..n_modes; x: (B,) or (B,1) -> (B, n_modes)."""
    xs = x if x.ndim == 1 else x[..., 0]
    i = jnp.arange(1, n_modes + 1, dtype=jnp.float32)
    return jnp.cos(xs[:, None] * i[None, :])


def init_paper_decomposition(key, cfg, *, u_mode: str = "truncated",
                             u_dims=None, n_modes: int = 0) -> Params:
    """cfg: PaperMLPConfig.  Builds {v, (a, raw_t) | u_net} params."""
    kv, ku, ka = jax.random.split(key, 3)
    dims = (cfg.in_dim,) + tuple(cfg.hidden) + (1,)
    p: Params = {"v": init_mlp(kv, dims)}
    if u_mode == "independent":
        p["u_net"] = init_mlp(ku, tuple(u_dims or (cfg.in_dim, 10, 1)))
        p["raw_t"] = jnp.asarray(_inv_softplus(cfg.t_init), jnp.float32)
    else:
        n_basis = n_modes if u_mode == "cosine" else cfg.n_basis
        p["a"] = 0.1 * jax.random.normal(ka, (n_basis,), jnp.float32)
        p["raw_t"] = jnp.asarray(_inv_softplus(cfg.t_init), jnp.float32)
    return p


def _inv_softplus(y: float) -> float:
    import numpy as np
    return float(np.log(np.expm1(y))) if y < 20 else float(y)


def paper_forward(p: Params, x: jnp.ndarray, cfg, *, u_mode: str = "truncated",
                  s: Optional[float] = None, monitor_n: Optional[int] = None,
                  sigma_kind: str = "sigmoid") -> Dict[str, jnp.ndarray]:
    """Full collaborative forward.  Returns u, v, fhat, t."""
    s = cfg.s if s is None else s
    n = cfg.monitor_n if monitor_n is None else monitor_n
    v_out, phi = mlp_forward(p["v"], x)
    t = jax.nn.softplus(p["raw_t"])
    if u_mode == "independent":
        u, _ = mlp_forward(p["u_net"], x)
        u = u + t
    else:
        basis = cosine_basis(x, p["a"].shape[0]) if u_mode == "cosine" else phi
        # truncation: only the first n basis functions reach the device
        mask = (jnp.arange(p["a"].shape[0]) < n).astype(jnp.float32)
        u = basis @ (p["a"] * mask) + t
    corr = s * sigma(v_out, sigma_kind)
    return {"u": u, "v": v_out, "corr": corr, "fhat": u - corr, "t": t}


# ---------------------------------------------------------------------------
# Scaled form: edge tower + server backbone (the 10 assigned archs)
# ---------------------------------------------------------------------------


def edge_arch(cfg: ArchConfig) -> ArchConfig:
    """Derive the edge tower's ArchConfig from MonitorConfig.

    The edge model is a small dense decoder (audio family keeps codebook
    embeddings so it can read the same token stream).  It is replicated on
    the device mesh axis — never sharded — mirroring 'all of u fits on the
    edge device'.
    """
    m = cfg.monitor
    fam = "audio" if cfg.family == "audio" else "dense"
    return ArchConfig(
        name=f"{cfg.name}-edge", family=fam, citation="edge tower (paper U)",
        n_layers=m.n_layers, d_model=m.d_model, n_heads=m.n_heads,
        n_kv_heads=m.n_heads, d_ff=m.d_ff, vocab_size=cfg.vocab_size,
        n_codebooks=cfg.n_codebooks, tie_embeddings=True,
        sliding_window=1024,  # edge memory budget: 1k-token ring cache
        dtype=cfg.dtype, param_dtype=cfg.param_dtype, remat=False,
        scan_unroll=cfg.scan_unroll, monitor=m,
    )


def init_collab_lm(key, cfg: ArchConfig) -> Params:
    """{server, v_head, edge, u_head(a, raw_t)} — the deployed system."""
    ks = jax.random.split(key, 4)
    m = cfg.monitor
    ecfg = edge_arch(cfg)
    return {
        "server": model_api.init_model(ks[0], cfg),
        "v_head": init_linear(ks[1], cfg.d_model, 1, bias=True),
        "edge": model_api.init_model(ks[2], ecfg),
        "u_head": {
            "w_feat": init_linear(ks[3], m.d_model, m.n_features),
            "a": 0.1 * jax.random.normal(jax.random.fold_in(ks[3], 1),
                                         (m.n_features,), jnp.float32),
            "raw_t": jnp.asarray(_inv_softplus(m.t_init), jnp.float32),
        },
    }


def monitor_score(params: Params, cfg: ArchConfig, batch: Dict) -> jnp.ndarray:
    """Edge-only path: u(x) per position.  MUST lower with no model-axis
    collectives (asserted in tests) — this is the paper's 'local' guarantee."""
    m = cfg.monitor
    from repro.nn.attention import kv_shard_optout
    with kv_shard_optout():  # edge tower stays device-local (paper req.)
        eout = model_api.forward(params["edge"], edge_arch(cfg), batch)
    feats = jnp.tanh(linear(params["u_head"]["w_feat"],
                            eout["hidden"].astype(jnp.float32)))
    n = m.n_features  # full n by default; truncation swept in benchmarks
    mask = (jnp.arange(feats.shape[-1]) < n).astype(jnp.float32)
    t = jax.nn.softplus(params["u_head"]["raw_t"])
    return feats @ (params["u_head"]["a"] * mask) + t


def corrector_score(params: Params, cfg: ArchConfig,
                    server_out: Dict) -> jnp.ndarray:
    """v(x) per position from the server backbone's hidden states."""
    return linear(params["v_head"],
                  server_out["hidden"].astype(jnp.float32))[..., 0]


def collab_forward(params: Params, cfg: ArchConfig, batch: Dict,
                   *, s: Optional[float] = None) -> Dict[str, jnp.ndarray]:
    """Training-time forward of the full collaborative system."""
    m = cfg.monitor
    s = m.s if s is None else s
    server_out = model_api.forward(params["server"], cfg, batch)
    u = monitor_score(params, cfg, batch)
    v = corrector_score(params, cfg, server_out)
    corr = s * sigma(v, m.sigma)
    return {"u": u, "v": v, "fhat": u - corr, "corr": corr,
            "logits": server_out["logits"], "aux_loss": server_out["aux_loss"],
            "mtp_logits": server_out.get("mtp_logits"),
            "t": jax.nn.softplus(params["u_head"]["raw_t"])}

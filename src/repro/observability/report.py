"""Critical-path breakdown over a span trace: group the per-request
spans by stage (serialize / socket / queue / compute) and summarize.

The stage mapping mirrors the span names the serving stack emits
(``trace.py`` module docstring): ``wire.encode`` is client serialization,
``wire.socket`` the derived socket time (RTT minus the server's reported
durations), ``server.queue`` the server-side queue wait and
``server.catchup`` the replay compute — together they tile one request's
measured RTT (``wire.request``).  Works on live ``Span`` objects
(``MonitorSession.tracer.spans()``) and on loaded Chrome trace events
(``load_trace(path)["traceEvents"]``) alike, so ``tools/trace_report.py``
and the launch CLIs share one implementation.

Percentiles here are EXACT (numpy over the raw durations) — unlike the
bucketed ``tracker.Histogram`` estimates, a trace keeps every sample.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List

import numpy as np

# span name -> breakdown stage, in critical-path order
STAGES = ("serialize", "socket", "queue", "compute")
SPAN_STAGE = {
    "wire.encode": "serialize",
    "wire.socket": "socket",
    "shm.ring": "socket",     # same stage, different plane (shm transport)
    "server.queue": "queue",
    "server.catchup": "compute",
}


def _name_dur_s(item: Any):
    """(name, duration seconds) from a Span or a Chrome trace event."""
    if isinstance(item, dict):
        if item.get("ph") != "X":
            return None
        return item["name"], float(item["dur"]) * 1e-6
    return item.name, float(item.dur)


def durations_by_stage(items: Iterable[Any]) -> Dict[str, List[float]]:
    """Stage -> raw durations (seconds), plus the measured ``rtt`` and
    every other span name verbatim (``edge.decode`` etc.)."""
    out: Dict[str, List[float]] = {}
    for item in items:
        nd = _name_dur_s(item)
        if nd is None:
            continue
        name, dur = nd
        key = SPAN_STAGE.get(name, "rtt" if name == "wire.request" else name)
        out.setdefault(key, []).append(dur)
    return out


def summarize(durs: List[float]) -> Dict[str, float]:
    a = np.asarray(durs, np.float64)
    return {"n": int(a.size), "total_s": float(a.sum()),
            "mean_s": float(a.mean()), "p50_s": float(np.percentile(a, 50)),
            "p99_s": float(np.percentile(a, 99)), "max_s": float(a.max())}


def breakdown(items: Iterable[Any]) -> Dict[str, Dict[str, float]]:
    """Stage/name -> summary stats, for every span group in the trace."""
    return {k: summarize(v) for k, v in durations_by_stage(items).items()}


def breakdown_table(items: Iterable[Any]) -> List[str]:
    """The human-readable critical-path table (one string per line):
    RTT first, then its four stages in path order, then every other span
    group alphabetically.  Milliseconds throughout."""
    stats = breakdown(items)
    order = [k for k in ("rtt",) + STAGES if k in stats]
    order += sorted(k for k in stats if k not in order)
    lines = [f"{'span':<14} {'n':>6} {'mean ms':>9} {'p50 ms':>9} "
             f"{'p99 ms':>9} {'total ms':>10}"]
    for k in order:
        s = stats[k]
        lines.append(f"{k:<14} {s['n']:>6} {s['mean_s'] * 1e3:>9.3f} "
                     f"{s['p50_s'] * 1e3:>9.3f} {s['p99_s'] * 1e3:>9.3f} "
                     f"{s['total_s'] * 1e3:>10.1f}")
    return lines

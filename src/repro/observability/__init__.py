"""End-to-end observability for the serving stack: span tracing +
unified metrics (see docs/observability.md).

  * ``Tracer`` / ``Span`` — bounded-ring span tracer with Chrome
    trace-event / Perfetto export; sessions enable it with
    ``SessionConfig(trace=True)`` and read it via
    ``MonitorSession.tracer`` / ``export_trace``.
  * ``MetricsRegistry`` / ``Counter`` / ``Gauge`` — the counter / gauge
    / histogram registry behind ``MonitorSession.metrics()`` and the
    correction server's heartbeat snapshot.
  * ``validate_chrome_trace`` / ``load_trace`` — the trace-event schema
    gate (CI trace-smoke, ``tools/trace_report.py``).
"""
from repro.observability.metrics import (Counter, Gauge, MetricsRegistry,
                                         flatten)
from repro.observability.report import breakdown, breakdown_table
from repro.observability.trace import (Span, Tracer, load_trace,
                                       validate_chrome_trace)

__all__ = ["Counter", "Gauge", "MetricsRegistry", "flatten",
           "Span", "Tracer", "breakdown", "breakdown_table",
           "load_trace", "validate_chrome_trace"]

"""Unified metrics registry: counters, gauges, and histograms behind one
snapshot, absorbing the serving stack's previously-fragmented telemetry.

Before this module the stack had three disjoint metric surfaces:

  * ``core.gating.CommsMeter`` — token-level modeled bytes plus measured
    wire/async/failover buckets, reported as a NESTED dict;
  * ``serving/tracker.py`` ``Histogram``s — server-side replay latency /
    coalesce width, summarized into the heartbeat by hand-built key
    loops in ``CorrectionServer.stats_snapshot``;
  * ad-hoc ``time.monotonic()`` stamps in ``async_rpc.py`` that never
    reached any report.

One ``MetricsRegistry`` now holds all three kinds.  The server backs its
counters and histograms with a registry (its heartbeat snapshot is
``registry.snapshot()`` plus identity fields — same keys as before, so
``FleetSupervisor``'s scrape and the fleet aggregation are unchanged
consumers).  The engine carries a registry too: the ``wire`` transport
feeds the measured RTT breakdown (serialize / socket / queue / compute,
from the protocol-v4 REPLY timing payload) into it, and
``MonitorSession.metrics()`` returns one flat snapshot that merges the
registry with the flattened ``CommsMeter`` report (``comms/...`` keys)
and the tracer's ring stats — the single pane the ROADMAP's autoscaling
item (p50/p99 admission latency) reads from.

Naming: flat snapshot keys.  Counters and gauges appear under their own
names; a histogram ``h`` contributes ``{h}_n/_mean/_max/_p50/_p99``
(percentiles are ``None`` while empty — see ``tracker.Histogram``).
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Any, Callable, Dict, Optional, Union

if TYPE_CHECKING:  # pragma: no cover
    from repro.serving.tracker import Histogram

# Histogram lives in serving/tracker.py (it predates this module and the
# heartbeat consumers import it from there); serving imports US for the
# registry, so pulling it in at module scope would be circular.  Resolved
# lazily at first histogram() call and cached here.
_Histogram = None


def _histogram_cls():
    global _Histogram
    if _Histogram is None:
        from repro.serving.tracker import Histogram
        _Histogram = Histogram
    return _Histogram


class Counter:
    """Monotonic counter (int or float increments)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: Union[int, float] = 0

    def inc(self, n: Union[int, float] = 1) -> None:
        self.value += n


class Gauge:
    """Point-in-time value: ``set()`` it, or construct with ``fn`` for a
    pull gauge evaluated at snapshot time (lease load, fragmentation)."""

    __slots__ = ("name", "_value", "_fn")

    def __init__(self, name: str, fn: Optional[Callable[[], Any]] = None):
        self.name = name
        self._value: Any = 0
        self._fn = fn

    def set(self, v: Any) -> None:
        self._value = v

    @property
    def value(self) -> Any:
        return self._fn() if self._fn is not None else self._value


class MetricsRegistry:
    """Name -> metric, with get-or-create accessors and one flat
    ``snapshot()``.  Not thread-safe by design: each owner (engine,
    server reactor) mutates its own registry from one thread, exactly
    like the structures it replaces."""

    def __init__(self) -> None:
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, "Histogram"] = {}

    # -- get-or-create -------------------------------------------------------
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str,
              fn: Optional[Callable[[], Any]] = None) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name, fn)
        return g

    def histogram(self, name: str, lo: float = 1e-6, hi: float = 60.0,
                  n_buckets: int = 24) -> "Histogram":
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = _histogram_cls()(lo, hi, n_buckets)
        return h

    # -- convenience mutators (hot-path friendly) ----------------------------
    def inc(self, name: str, n: Union[int, float] = 1) -> None:
        self.counter(name).inc(n)

    def observe(self, name: str, x: float, *, lo: float = 1e-6,
                hi: float = 60.0, n_buckets: int = 24) -> None:
        self.histogram(name, lo, hi, n_buckets).observe(x)

    # -- views ---------------------------------------------------------------
    def counters(self) -> Dict[str, Union[int, float]]:
        return {name: c.value for name, c in self._counters.items()}

    @property
    def hists(self) -> Dict[str, "Histogram"]:
        return self._hists

    def snapshot(self) -> Dict[str, Any]:
        """One flat dict: counters + gauges by name, histograms as
        ``{name}_{n,mean,max,p50,p99}`` — JSON-safe (the heartbeat
        format)."""
        snap: Dict[str, Any] = {}
        for name, c in self._counters.items():
            snap[name] = c.value
        for name, g in self._gauges.items():
            snap[name] = g.value
        for name, h in self._hists.items():
            for k, val in h.summary().items():
                snap[f"{name}_{k}"] = val
        return snap


def flatten(nested: Dict[str, Any], prefix: str = "",
            sep: str = "/") -> Dict[str, Any]:
    """Flatten a nested report dict (``CommsMeter.report()``) into
    ``prefix/key`` scalars; non-dict leaves (including per-stream lists)
    pass through unchanged."""
    out: Dict[str, Any] = {}
    for k, v in nested.items():
        key = f"{prefix}{sep}{k}" if prefix else str(k)
        if isinstance(v, dict):
            out.update(flatten(v, key, sep))
        else:
            out[key] = v
    return out

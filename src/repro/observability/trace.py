"""Span tracer for the serving stack: monotonic-clock spans, a bounded
ring buffer, Chrome trace-event / Perfetto JSON export.

The ROADMAP's zero-copy-transport item claims "most of the ~226 ms wire
RTT is serialization and socket hops, not compute" — this module is how
that claim gets a measurement.  One ``Tracer`` rides a serving session
(``SessionConfig(trace=True)``) and collects spans from every layer the
critical path crosses:

    edge track    edge.decode, edge.trigger, edge.dispatch, edge.merge,
                  edge.catchup (sync), edge.stall, scan.run
    wire track    wire.encode (serialize), wire.request (dispatch ->
                  reply), wire.socket (derived: RTT minus the server's
                  reported durations)
    server track  server.queue, server.catchup — SYNTHESIZED client-side
                  from the REPLY frame's duration-only timing payload
                  (protocol v4), so no clock sync between the processes
                  is ever needed; a ``CorrectionServer`` given its own
                  tracer additionally records server.replay spans locally

Correlation: every request-scoped span carries ``req_id`` in its args
(the Dispatcher's monotonically increasing id, echoed by the server), so
a reader can reassemble one request's serialize/socket/queue/compute
breakdown from the flat event list — ``tools/trace_report.py`` does
exactly that.

Cost discipline: the tracer is pay-for-what-you-use.  Sessions default
to ``trace=False`` and every instrumentation site in the engine /
dispatcher / worker is guarded by a single ``if tracer is not None``
flag check — the disabled path never allocates a span, never reads the
clock for tracing, and never touches this module (asserted by the
overhead guard in tests/test_observability.py).  Enabled, a span is one
``time.monotonic()`` pair plus an append into a bounded deque; nothing
here touches jax, so instrumentation can never introduce host transfers
or retraces (``tools/check_static.py --strict`` stays green).

Synthesized-span placement: the server reports DURATIONS only
(queue-wait and replay compute).  The client anchors them backwards from
reply arrival — compute ends at arrival, queue precedes compute — which
attributes both socket directions to the gap after dispatch.  Fine for
breakdown totals (durations are exact); only the left edges of the
server spans are approximate.
"""
from __future__ import annotations

import itertools
import json
import os
import time
from collections import deque
from typing import Any, Dict, List, Optional

# stable track -> Chrome tid mapping (one "process" per tracer)
TRACKS = ("edge", "wire", "server")
_TRACK_TID = {name: i for i, name in enumerate(TRACKS)}

_trace_seq = itertools.count(1)


class Span:
    """One completed span: name, category, start (monotonic seconds),
    duration, track, and a small args dict (req_id etc.)."""

    __slots__ = ("name", "cat", "ts", "dur", "track", "args")

    def __init__(self, name: str, cat: str, ts: float, dur: float,
                 track: str, args: Dict[str, Any]):
        self.name = name
        self.cat = cat
        self.ts = ts
        self.dur = dur
        self.track = track
        self.args = args

    def __repr__(self) -> str:  # debugging/test ergonomics
        return (f"Span({self.name!r}, ts={self.ts:.6f}, "
                f"dur={self.dur * 1e3:.3f}ms, track={self.track!r})")


class Tracer:
    """Bounded ring buffer of spans with trace-event export.

    ``capacity`` bounds memory: when full, the OLDEST spans are dropped
    (a long session keeps its tail, which is what a breakdown wants) and
    ``dropped`` counts them.  All methods are cheap enough for the
    reactor tick / per-step hot path when tracing is ON; when tracing is
    OFF the convention is that callers hold ``None`` instead of a
    disabled tracer — one flag check, zero calls into this class.
    """

    def __init__(self, capacity: int = 65536, *,
                 trace_id: Optional[str] = None):
        if capacity <= 0:
            raise ValueError("trace capacity must be >= 1")
        self.capacity = int(capacity)
        self.trace_id = (trace_id if trace_id is not None
                         else f"{os.getpid():x}-{next(_trace_seq):x}")
        self._spans: "deque[Span]" = deque(maxlen=self.capacity)
        self._appended = 0

    # -- recording -----------------------------------------------------------
    @staticmethod
    def clock() -> float:
        """The span clock (monotonic seconds) — callers stamp t0 with
        this so the disabled path can skip the read entirely."""
        return time.monotonic()

    def done(self, name: str, cat: str, t0: float, *, track: str = "edge",
             **args: Any) -> None:
        """Record a span that started at ``t0`` and ends NOW."""
        self.add(name, cat, t0, time.monotonic() - t0, track=track, **args)

    def add(self, name: str, cat: str, ts: float, dur: float, *,
            track: str = "edge", **args: Any) -> None:
        """Record a pre-measured span (synthesized server spans use this
        with durations carried by the REPLY timing payload)."""
        self._appended += 1
        self._spans.append(Span(name, cat, ts, max(float(dur), 0.0),
                                track, args))

    def instant(self, name: str, cat: str = "mark", *,
                track: str = "edge", **args: Any) -> None:
        self.add(name, cat, time.monotonic(), 0.0, track=track, **args)

    # -- inspection ----------------------------------------------------------
    def spans(self) -> List[Span]:
        return list(self._spans)

    def __len__(self) -> int:
        return len(self._spans)

    @property
    def dropped(self) -> int:
        """Spans evicted by the ring bound (0 unless the session outgrew
        ``capacity``)."""
        return max(0, self._appended - self.capacity)

    def stats(self) -> Dict[str, Any]:
        return {"trace_id": self.trace_id, "spans": len(self._spans),
                "dropped": self.dropped, "capacity": self.capacity}

    # -- export --------------------------------------------------------------
    def to_chrome(self) -> Dict[str, Any]:
        """The Chrome trace-event JSON object (Perfetto loads it as-is):
        ``{"traceEvents": [...], "displayTimeUnit": "ms"}`` with one
        complete ("X") event per span, ts/dur in microseconds, plus
        thread_name metadata naming the tracks."""
        pid = 1
        events: List[Dict[str, Any]] = [
            {"ph": "M", "pid": pid, "tid": tid, "name": "thread_name",
             "args": {"name": track}}
            for track, tid in _TRACK_TID.items()]
        for s in self._spans:
            events.append({
                "name": s.name, "cat": s.cat, "ph": "X",
                "ts": s.ts * 1e6, "dur": s.dur * 1e6,
                "pid": pid, "tid": _TRACK_TID.get(s.track, 0),
                "args": dict(s.args, trace_id=self.trace_id),
            })
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"trace_id": self.trace_id,
                              "dropped": self.dropped}}

    def export(self, path: str) -> int:
        """Write the Perfetto-loadable JSON; returns the span count."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as fh:
            json.dump(self.to_chrome(), fh)
        return len(self._spans)


def validate_chrome_trace(obj: Any) -> int:
    """Validate a loaded trace object against the trace-event schema we
    emit (the CI trace-smoke gate).  Returns the number of duration
    events; raises ``ValueError`` naming the first violation."""
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("not a trace-event object: missing 'traceEvents'")
    events = obj["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' is not a list")
    n_x = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object")
        for k in ("ph", "pid", "tid", "name"):
            if k not in ev:
                raise ValueError(f"event {i} missing required key {k!r}")
        if ev["ph"] == "X":
            n_x += 1
            for k in ("ts", "dur"):
                if not isinstance(ev.get(k), (int, float)):
                    raise ValueError(f"event {i}: {k!r} is not a number")
                if ev[k] < 0:
                    raise ValueError(f"event {i}: negative {k}")
    if n_x == 0:
        raise ValueError("trace has no duration ('X') events")
    return n_x


def load_trace(path: str) -> Dict[str, Any]:
    """Read + validate a trace file (``tools/trace_report.py``)."""
    with open(path, "r") as fh:
        obj = json.load(fh)
    validate_chrome_trace(obj)
    return obj

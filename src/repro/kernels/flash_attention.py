"""Pallas TPU flash attention (prefill): online-softmax over KV blocks with
grid-sequential accumulation.

Tiling: grid = (B, Hq, S/bq, T/bk); the last (kv) axis is sequential, with
running (m, l, acc) carried in VMEM scratch.  Block shapes are 128-aligned
on the MXU contraction dims.  GQA is handled in the K/V index maps
(h -> h // group).  Causal + sliding-window masks are applied with global
row/col iota; KV blocks strictly above the causal diagonal are skipped
entirely (``pl.when``), halving work for causal prefill.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, nk: int, scale: float, causal: bool,
                  window: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip blocks strictly above the causal diagonal / outside the window
    row0, col0 = iq * bq, ik * bk
    needed = jnp.asarray(True)
    if causal:
        needed = needed & (col0 <= row0 + bq - 1)
    if window:
        needed = needed & (col0 + bk - 1 > row0 - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)   # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)   # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)   # (bk, Dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        row = row0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        col = col0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = mask & (col <= row)
        if window:
            mask = mask & (col > row - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        # p must be explicitly re-masked: rows with no unmasked entry yet
        # have m_new == NEG_INF and exp(s - m_new) == 1 on masked entries.
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0, bq: int = 128,
                    bk: int = 128, interpret: bool = True) -> jnp.ndarray:
    """q: (B, S, Hq, D); k, v: (B, T, Hkv, D) -> (B, S, Hq, Dv)."""
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = Hq // Hkv
    bq, bk = min(bq, S), min(bk, T)
    assert S % bq == 0 and T % bk == 0, "seq lens must tile"
    nq, nk = S // bq, T // bk
    scale = 1.0 / math.sqrt(D)

    qt = q.transpose(0, 2, 1, 3)  # (B, Hq, S, D)
    kt = k.transpose(0, 2, 1, 3)  # (B, Hkv, T, D)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, nk=nk, scale=scale,
                               causal=causal, window=window)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, Dv), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, Dv), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, Dv), q.dtype),
        scratch_shapes=[_vmem((bq, Dv)), _vmem((bq,)), _vmem((bq,))],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)

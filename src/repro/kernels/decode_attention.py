"""Pallas TPU single-query (decode) attention over a KV cache.

This is the bandwidth-bound op of decode_32k / long_500k: every step streams
the whole (C, Hkv, D) cache from HBM through VMEM once.  Tiling: grid =
(B, Hkv, C/bk) with the cache axis sequential; all G query heads of a KV
group are processed together so the cache block is read once per group
(GQA's arithmetic-intensity advantage, made explicit).  Ring-buffer (SWA)
caches mask by slot validity instead of position order.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                   *, bk: int, nk: int, G: int, scale: float, window: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[0]
    q = q_ref[0, 0].astype(jnp.float32)       # (G, D)
    kb = k_ref[0, :, 0].astype(jnp.float32)   # (bk, D)
    vb = v_ref[0, :, 0].astype(jnp.float32)   # (bk, Dv)
    s = jax.lax.dot_general(q, kb, (((1,), (1,)), ((), ()))) * scale  # (G, bk)
    col = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    C = nk * bk
    if window:  # ring buffer: slots < min(pos+1, C) hold real entries
        valid = col < jnp.minimum(pos + 1, C)
    else:
        valid = col <= pos
    s = jnp.where(valid, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ vb
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     pos, *, window: int = 0, bk: int = 256,
                     interpret: bool = True) -> jnp.ndarray:
    """q: (B, Hq, D); caches: (B, C, Hkv, D); pos: scalar -> (B, Hq, Dv)."""
    B, Hq, D = q.shape
    C, Hkv = k_cache.shape[1], k_cache.shape[2]
    Dv = v_cache.shape[-1]
    G = Hq // Hkv
    bk = min(bk, C)
    assert C % bk == 0
    nk = C // bk
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, Hkv, G, D)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)

    kernel = functools.partial(_decode_kernel, bk=bk, nk=nk, G=G, scale=scale,
                               window=window)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ik: (0,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, D), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, ik: (b, ik, h, 0)),
            pl.BlockSpec((1, bk, 1, Dv), lambda b, h, ik: (b, ik, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dv), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dv), q.dtype),
        scratch_shapes=[pltpu.VMEM((G, Dv), jnp.float32),
                        pltpu.VMEM((G,), jnp.float32),
                        pltpu.VMEM((G,), jnp.float32)],
        interpret=interpret,
    )(pos_arr, qg, k_cache, v_cache)
    return out.reshape(B, Hq, Dv)

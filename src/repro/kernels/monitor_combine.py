"""Pallas fused monitor combine — the paper-central op, fused:

    fhat = u - s * sigmoid(v)
    mask = u > gamma - margin          (server-trigger mask)
    fp/fn indicator accumulators       (safety telemetry, Eq. 3/4)

On a (B, S) score grid during batched serving this is 3-4 elementwise HBM
round-trips if left to XLA fusion across jit boundaries; one VMEM pass here.
Outputs: fhat, mask (f32), and a (2,)-counter [n_triggered, n_violations]
accumulated across the grid (grid-sequential accumulation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _combine_kernel(u_ref, v_ref, f_ref, fhat_ref, mask_ref, count_ref, *,
                    s: float, threshold: float, margin: float, n_blocks: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        count_ref[...] = jnp.zeros_like(count_ref)

    u = u_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    f = f_ref[...].astype(jnp.float32)
    corr = s * jax.nn.sigmoid(v)
    fhat = u - corr
    trig = (u > threshold - margin).astype(jnp.float32)
    fhat_ref[...] = fhat
    mask_ref[...] = trig
    viol = (f > u).astype(jnp.float32)  # safety violations u < f
    count_ref[0] += jnp.sum(trig)
    count_ref[1] += jnp.sum(viol)


def monitor_combine(u: jnp.ndarray, v: jnp.ndarray, f: jnp.ndarray, *,
                    s: float, threshold: float = 0.0, margin: float = 0.25,
                    block: int = 1024, interpret: bool = True):
    """u, v, f: (N,) flat score vectors -> (fhat, mask, counts[2])."""
    N = u.shape[0]
    blk = min(block, N)
    assert N % blk == 0
    nb = N // blk
    kernel = functools.partial(_combine_kernel, s=s, threshold=threshold,
                               margin=margin, n_blocks=nb)
    fhat, mask, counts = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,)),
                  pl.BlockSpec((blk,), lambda i: (i,)),
                  pl.BlockSpec((blk,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((blk,), lambda i: (i,)),
                   pl.BlockSpec((blk,), lambda i: (i,)),
                   pl.BlockSpec((2,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((N,), jnp.float32),
                   jax.ShapeDtypeStruct((N,), jnp.float32),
                   jax.ShapeDtypeStruct((2,), jnp.float32)],
        interpret=interpret,
    )(u, v, f)
    return fhat, mask, counts

"""Pallas fused monitor combine — the paper-central op, fused:

    fhat = u - s * sigmoid(v)
    mask = u > gamma - margin          (server-trigger mask)
    fp/fn indicator accumulators       (safety telemetry, Eq. 3/4)

On a (B, S) score grid during batched serving this is 3-4 elementwise HBM
round-trips if left to XLA fusion across jit boundaries; one VMEM pass here.

TPU legality: flat (N,) score vectors are reshaped to 2D (rows, 128) tiles
(the VPU lane width; f32 tiles are (8, 128)), padded with "quiet" values
(u = gamma - margin, so the padding neither triggers nor counts as a safety
violation).  The [n_triggered, n_violations] counters accumulate across the
sequential TPU grid in SMEM.  ``interpret=None`` auto-selects the compiled
path on TPU and the interpreter everywhere else.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128      # VPU lane width: last dim of every block
SUBLANES = 8     # f32 min sublane tile


def _combine_kernel(u_ref, v_ref, f_ref, fhat_ref, mask_ref, count_ref, *,
                    s: float, threshold: float, margin: float):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        count_ref[0] = 0.0
        count_ref[1] = 0.0

    u = u_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    f = f_ref[...].astype(jnp.float32)
    corr = s * jax.nn.sigmoid(v)
    fhat = u - corr
    trig = (u > threshold - margin).astype(jnp.float32)
    fhat_ref[...] = fhat
    mask_ref[...] = trig
    viol = (f > u).astype(jnp.float32)  # safety violations u < f
    count_ref[0] += jnp.sum(trig)
    count_ref[1] += jnp.sum(viol)


def monitor_combine(u: jnp.ndarray, v: jnp.ndarray, f: jnp.ndarray, *,
                    s: float, threshold: float = 0.0, margin: float = 0.25,
                    block: int = 1024, interpret: bool | None = None):
    """u, v, f: (N,) flat score vectors -> (fhat, mask, counts[2]).

    ``block`` is the number of lanes processed per grid step (rounded to a
    TPU-legal (rows, 128) tile).  ``interpret=None`` compiles on TPU and
    falls back to the Pallas interpreter on CPU/GPU.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    N = u.shape[0]
    rows = max(block // LANES, 1)
    if not interpret:
        rows = max(rows, SUBLANES)  # compiled path: full f32 tile
    tile = rows * LANES
    n_pad = (-N) % tile
    quiet = jnp.float32(threshold - margin)  # no trigger, no violation
    uf = jnp.concatenate([u.astype(jnp.float32), jnp.full((n_pad,), quiet)]) \
        if n_pad else u.astype(jnp.float32)
    vf = jnp.concatenate([v.astype(jnp.float32), jnp.zeros((n_pad,))]) \
        if n_pad else v.astype(jnp.float32)
    ff = jnp.concatenate([f.astype(jnp.float32), jnp.full((n_pad,), quiet)]) \
        if n_pad else f.astype(jnp.float32)
    n_rows_total = (N + n_pad) // LANES
    u2, v2, f2 = (x.reshape(n_rows_total, LANES) for x in (uf, vf, ff))
    nb = n_rows_total // rows
    kernel = functools.partial(_combine_kernel, s=s, threshold=threshold,
                               margin=margin)
    blk2 = pl.BlockSpec((rows, LANES), lambda i: (i, 0))
    fhat, mask, counts = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[blk2, blk2, blk2],
        out_specs=[blk2, blk2,
                   pl.BlockSpec(memory_space=pltpu.SMEM)],
        out_shape=[jax.ShapeDtypeStruct((n_rows_total, LANES), jnp.float32),
                   jax.ShapeDtypeStruct((n_rows_total, LANES), jnp.float32),
                   jax.ShapeDtypeStruct((2,), jnp.float32)],
        interpret=interpret,
    )(u2, v2, f2)
    return fhat.reshape(-1)[:N], mask.reshape(-1)[:N], counts

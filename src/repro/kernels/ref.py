"""Pure-jnp oracles for every kernel (naive, O(S^2)/sequential forms —
independent of both the Pallas kernels AND the production chunked/blocked
implementations, so each is checked against ground truth, not itself).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=0):
    """Naive full-matrix attention. q:(B,S,Hq,D) k,v:(B,T,Hkv,D)."""
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, S, Hkv, G, D)
    s = jnp.einsum("bskgd,btkd->bskgt", qf, k.astype(jnp.float32)) / math.sqrt(D)
    row = jnp.arange(S)[:, None]
    col = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= col <= row
    if window:
        mask &= col > row - window
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bskgt,btkd->bskgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, Hq, v.shape[-1]).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, pos, *, window=0):
    """Naive single-query attention. q:(B,Hq,D), caches:(B,C,Hkv,D)."""
    B, Hq, D = q.shape
    C, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    qg = q.astype(jnp.float32).reshape(B, Hkv, G, D)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache.astype(jnp.float32)) / math.sqrt(D)
    idx = jnp.arange(C)
    valid = (idx < jnp.minimum(pos + 1, C)) if window else (idx <= pos)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, Hq, v_cache.shape[-1]).astype(q.dtype)


def ssd_ref(xdt, la, Bm, Cm):
    """Fully sequential SSD recurrence (the mathematical definition):
        h_t = exp(la_t) h_{t-1} + xdt_t B_t^T ;  y_t = C_t h_t^T
    xdt:(B,S,H,P) la:(B,S,H) Bm,Cm:(B,S,N) -> y:(B,S,H,P) f32."""
    B, S, H, P = xdt.shape
    N = Bm.shape[-1]

    def step(h, inp):
        x_t, la_t, b_t, c_t = inp  # (B,H,P),(B,H),(B,N),(B,N)
        h = (h * jnp.exp(la_t)[..., None, None]
             + jnp.einsum("bhp,bn->bhpn", x_t, b_t))
        y = jnp.einsum("bn,bhpn->bhp", c_t, h)
        return h, y

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    xs = (xdt.astype(jnp.float32).transpose(1, 0, 2, 3),
          la.astype(jnp.float32).transpose(1, 0, 2),
          Bm.astype(jnp.float32).transpose(1, 0, 2),
          Cm.astype(jnp.float32).transpose(1, 0, 2))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3)


def monitor_combine_ref(u, v, f, *, s, threshold=0.0, margin=0.25):
    uf = u.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    fhat = uf - s * jax.nn.sigmoid(vf)
    mask = (uf > threshold - margin).astype(jnp.float32)
    counts = jnp.stack([jnp.sum(mask),
                        jnp.sum((f.astype(jnp.float32) > uf).astype(jnp.float32))])
    return fhat, mask, counts

"""jit'd dispatch wrappers: one call site per kernel, selecting between the
Pallas TPU kernel (compiled on TPU, interpret=True on CPU tests) and the
production XLA fallback.  The model code takes these as its ``attn_fn`` /
``scan_fn`` injection points.

Global policy: ``set_impl("xla" | "pallas" | "pallas_interpret")``.  The
dry-run keeps "xla" (Pallas→HLO interpret lowering would pollute the
roofline); kernel tests force "pallas_interpret".
"""
from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dk
from repro.kernels import flash_attention as _fk
from repro.kernels import monitor_combine as _mk
from repro.kernels import ssm_scan as _sk
from repro.nn.attention import chunked_attention as _xla_attention
from repro.nn.attention import decode_attention as _xla_decode

_IMPL: str = "xla"


def set_impl(impl: Literal["xla", "pallas", "pallas_interpret"]) -> None:
    global _IMPL
    assert impl in ("xla", "pallas", "pallas_interpret")
    _IMPL = impl


def get_impl() -> str:
    return _IMPL


def _interp() -> bool:
    return _IMPL == "pallas_interpret" or jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0, **kw):
    if _IMPL == "xla":
        return _xla_attention(q, k, v, causal=causal, window=window,
                              q_offset=q_offset)
    return _fk.flash_attention(q, k, v, causal=causal, window=window,
                               interpret=_interp(), **kw)


def decode_attention(q, k_cache, v_cache, pos, *, window=0, **kw):
    if _IMPL == "xla":
        return _xla_decode(q, k_cache, v_cache, pos, window=window)
    return _dk.decode_attention(q, k_cache, v_cache, pos, window=window,
                                interpret=_interp(), **kw)


def ssd_scan(x, dt, A, Bm, Cm, *, chunk=128, h0=None):
    """Signature-compatible with nn.ssm.ssd_chunked (the XLA path)."""
    from repro.nn.ssm import ssd_chunked
    if _IMPL == "xla":
        return ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk, h0=h0)
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]
    la = dt.astype(jnp.float32) * A[None, None, :]
    y = _sk.ssd_scan(xdt, la, Bm, Cm, chunk=chunk, interpret=_interp())
    return y, None  # kernel path does not export the final state


def monitor_combine(u, v, f, *, s, threshold=0.0, margin=0.25):
    if _IMPL == "xla":
        from repro.kernels.ref import monitor_combine_ref
        return monitor_combine_ref(u, v, f, s=s, threshold=threshold,
                                   margin=margin)
    return _mk.monitor_combine(u, v, f, s=s, threshold=threshold,
                               margin=margin, interpret=_interp())

"""Pallas TPU Mamba2 SSD chunked scan.

Tiling: grid = (B, H, S/L) with the chunk axis sequential; the inter-chunk
state (P, N) lives in VMEM scratch, so the recurrence never round-trips
HBM.  Per chunk, the intra-chunk work is two (L,L)x(L,P)-class matmuls —
MXU-shaped when L = 128 — which is exactly the GPU algorithm's insight
(scan -> mostly-matmul) re-tiled for VMEM residency (DESIGN.md §3).

Inputs are pre-activated: xdt = x * dt (B,S,H,P), la = dt * A (B,S,H) the
per-step log-decay, and the shared B/C projections (B,S,N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(xdt_ref, la_ref, b_ref, c_ref, y_ref, h_ref, *, L: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    xdt = xdt_ref[0, :, 0].astype(jnp.float32)  # (L, P)
    la = la_ref[0, :, 0].astype(jnp.float32)    # (L,)
    Bb = b_ref[0].astype(jnp.float32)           # (L, N)
    Cb = c_ref[0].astype(jnp.float32)           # (L, N)
    h = h_ref[...]                              # (P, N)

    cums = jnp.cumsum(la)                       # (L,)
    # intra-chunk: W[t, s] = exp(cums_t - cums_s) for s <= t
    diff = cums[:, None] - cums[None, :]
    tril = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    W = jnp.where(tril, jnp.exp(diff), 0.0)
    CB = jax.lax.dot_general(Cb, Bb, (((1,), (1,)), ((), ())))  # (L, L)
    y_intra = (CB * W) @ xdt                                    # (L, P)
    y_inter = (Cb @ h.T) * jnp.exp(cums)[:, None]               # (L, P)
    y_ref[0, :, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h' = exp(cums_L) h + sum_s exp(cums_L - cums_s) xdt_s B_s^T
    dte = jnp.exp(cums[-1] - cums)              # (L,)
    h_ref[...] = (jnp.exp(cums[-1]) * h
                  + jax.lax.dot_general(xdt * dte[:, None], Bb,
                                        (((0,), (0,)), ((), ()))))  # (P, N)


def ssd_scan(xdt: jnp.ndarray, la: jnp.ndarray, Bm: jnp.ndarray,
             Cm: jnp.ndarray, *, chunk: int = 128,
             interpret: bool = True) -> jnp.ndarray:
    """xdt: (B,S,H,P) pre-multiplied x*dt; la: (B,S,H) log-decay dt*A;
    Bm, Cm: (B,S,N).  Returns y: (B,S,H,P) (f32 accumulation)."""
    B, S, H, P = xdt.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    assert S % L == 0
    nch = S // L

    kernel = functools.partial(_ssd_kernel, L=L)
    y = pl.pallas_call(
        kernel,
        grid=(B, H, nch),
        in_specs=[
            pl.BlockSpec((1, L, 1, P), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, L, 1), lambda b, h, ic: (b, ic, h)),
            pl.BlockSpec((1, L, N), lambda b, h, ic: (b, ic, 0)),
            pl.BlockSpec((1, L, N), lambda b, h, ic: (b, ic, 0)),
        ],
        out_specs=pl.BlockSpec((1, L, 1, P), lambda b, h, ic: (b, ic, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, P), jnp.float32),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xdt, la, Bm, Cm)
    return y

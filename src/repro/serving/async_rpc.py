"""Async pipelined server catch-up: the dispatch/merge layer between the
edge decode loop and the server corrector.

The paper's deployment story is an edge monitor ``u`` that runs on EVERY
token while the server corrector ``v`` is consulted only on trigger — so
server latency (catch-up compute + network round trip) must be hideable
behind edge decode.  This module provides the two halves of that overlap:

  * ``ServerWorker`` — owns the server-side protocol state (params + the
    batched KV/SSM cache) and applies ``CatchupRequest``s strictly in FIFO
    order, so the cache replay is identical to the synchronous engine's.
    Three transports:

      - ``inproc``      — computes at dispatch, on the caller's thread.
        Zero latency, fully deterministic; the functional transport used
        by equivalence tests (it exercises the one-step-late merge policy
        without real concurrency).
      - ``stream``      — the side-stream transport: exploits JAX's async
        dispatch.  The jitted catch-up is ENQUEUED from the caller's
        thread (returns in well under a millisecond) and XLA's runtime
        executes it concurrently with the edge loop's subsequent
        ``decode_step`` dispatches; readiness is observed via
        ``Array.is_ready()`` without blocking.  Successive requests chain
        through the worker's cache arrays, so XLA serializes the replay
        exactly like a real server while everything else overlaps.  This
        is the preferred overlap transport on shared hosts (it uses XLA's
        own scheduler — no OS-thread oversubscription) and the
        single-device analogue of dispatching onto a second device via
        ``jax.device_put`` (the worker exclusively owns its cache buffers,
        so they are also donation-safe).  ``latency_s`` adds a simulated
        wire delay on top of compute readiness.
      - ``thread``      — a single daemon worker thread runs the jitted
        catch-up.  The GIL is released during XLA execution, so the edge
        loop overlaps the server replay; prefer ``stream`` on hosts with
        few cores (two thread pools can thrash each other).
      - ``mock_remote`` — ``thread`` plus a simulated network round trip:
        a reply becomes visible ``latency_s`` after its compute finishes.
        Latency is modelled as a concurrent wire delay (replies overlap in
        flight); compute stays serialized like a real single server.
      - ``wire``        — the REAL boundary: a ``SocketWorker`` speaking
        the versioned binary protocol of ``serving/wire.py`` to a
        standalone correction-server process (``serving/server.py``,
        started via ``python -m repro.launch.server``) over a
        Unix-domain or TCP socket.  The server owns the cache; only
        backlog tokens + scores cross the wire; RTT and bytes are
        MEASURED (``CommsMeter.record_wire_*``), not modelled, and the
        server coalesces queued requests across clients and pipeline
        depth.  ``latency_s`` is rejected here — the wire has whatever
        latency it actually has.

  * ``Dispatcher`` — the edge-side bookkeeping: tracks in-flight requests,
    polls/blocks for replies, and enforces the staleness window.

STALENESS SEMANTICS (``max_staleness``):

  * ``max_staleness == 0`` — strict synchronous fallback: the reply for a
    trigger at step t is merged AT step t (the dispatcher blocks
    immediately).  Bit-identical to the engine's synchronous step path
    (what ``SessionConfig(mode="sync")`` over a transport means).
  * ``max_staleness == k >= 1`` — pipelined: a reply merges at the first
    step AFTER its trigger once it has arrived ("corrections merge one
    step late"), and no later than ``t + k`` — the dispatcher blocks the
    edge loop only when the oldest in-flight request reaches age k.
    The monitor path (u, trigger decision) NEVER waits on the server.

Replies deliberately do not carry the server cache: the worker owns it for
the duration of the async session and the engine re-adopts it once when
the ``MonitorSession`` closes (after a full drain), which keeps
cross-thread ownership trivial.  See ``docs/protocol.md`` for the full
timeline diagrams.

MESH-SHARDED SESSIONS (``SessionConfig(mesh=...)``, serving/mesh.py):
the session shards the engine BEFORE the worker is built, so every local
transport adopts the batch-sharded server cache and the re-jitted
catch-up (whose in/out shardings are compiled in) — requests chain
through sharded buffers exactly as through unsharded ones, and slot
churn's row resets on the worker-owned cache are spec-aware
(placement-preserving).  The ``wire`` transport is orthogonal: the
client's mesh shards its edge, while the server process shards its own
super-batch via ``CorrectionServer(mesh=...)``; only protocol bytes
cross the boundary either way.
"""
from __future__ import annotations

import queue
import socket
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

TRANSPORTS = ("inproc", "stream", "thread", "mock_remote", "wire", "shm")


@dataclass
class CatchupRequest:
    """One trigger-step's worth of server work.

    ``server_pos`` is the DISPATCH-time catch-up base: stream i's backlog is
    ``history[i, server_pos[i]:t+1]``.  ``history`` is the engine's on-device
    token history at dispatch; jnp arrays are immutable, so the snapshot is
    free and stable while later edge steps keep recording.
    """

    req_id: int
    t: int                      # trigger POSITION (inclusive end of backlog)
    triggered: np.ndarray       # (B,) bool — which streams this request serves
    server_pos: np.ndarray      # (B,) int — catch-up base per stream
    history: jax.Array          # (B, max_len[, K]) token history snapshot
    u: jax.Array                # (B,) monitor scores at the trigger step
    wall_dispatch: float = 0.0  # time.monotonic() at dispatch
    # the SESSION step index at dispatch: the staleness clock.  With a
    # uniform pool it equals ``t``; under slot-pool churn streams carry
    # their own positions, so ``t`` (a position) and the session clock
    # diverge — ages are measured on step_t, backlogs on t.
    step_t: int = -1


@dataclass
class CatchupReply:
    req_id: int
    t: int                      # the request's trigger step
    triggered: np.ndarray
    v: np.ndarray               # (B,) server scores (valid where triggered)
    fhat: np.ndarray            # (B,) fused fhat from the DISPATCH-time u
    server_time_s: float        # compute time inside the worker
    wall_ready: float = 0.0     # when the reply became visible (incl. latency)
    step_t: int = -1            # filled by the Dispatcher from the request


class ServerWorker:
    """Base transport: owns the server cache, applies requests in FIFO order.

    ``catchup_fn(params, cache, history, server_pos, t, triggered, u)``
    -> (cache, v, fhat) — the engine's jitted masked per-element catch-up.
    """

    kind = "inproc"

    def __init__(self, catchup_fn: Callable, params: Any, cache: Any):
        self._fn = catchup_fn
        self._params = params
        self.cache = cache
        self._ready: deque = deque()  # replies visible to poll(), FIFO
        self._closed = False

    # -- server side ---------------------------------------------------------
    def _compute(self, req: CatchupRequest) -> CatchupReply:
        t0 = time.monotonic()
        cache, v, fhat = self._fn(
            self._params, self.cache, req.history,
            jnp.asarray(req.server_pos, jnp.int32),
            jnp.asarray(req.t, jnp.int32),
            jnp.asarray(req.triggered), req.u)
        v, fhat = jax.block_until_ready((v, fhat))
        self.cache = cache
        done = time.monotonic()
        return CatchupReply(req.req_id, req.t, np.asarray(req.triggered),
                            np.asarray(v), np.asarray(fhat), done - t0,
                            wall_ready=done)

    # -- edge side -----------------------------------------------------------
    def dispatch(self, req: CatchupRequest) -> None:
        """inproc: compute now, on the caller's thread."""
        self._ready.append(self._compute(req))

    def poll(self) -> List[CatchupReply]:
        """All replies that are ready, in FIFO order.  Non-blocking."""
        out = list(self._ready)
        self._ready.clear()
        return out

    def wait(self, req_id: int) -> List[CatchupReply]:
        """Block until ``req_id`` is done; returns every reply up to and
        including it, in FIFO order.  inproc computes at dispatch, so the
        reply is already here."""
        taken: List[CatchupReply] = []
        while self._ready:
            r = self._ready.popleft()
            taken.append(r)
            if r.req_id == req_id:
                break
        return taken

    def close(self) -> None:
        """Idempotent on every transport: safe to call twice, and again
        after the ``MonitorSession`` closed (which closes the worker
        itself)."""
        self._closed = True


class StreamWorker(ServerWorker):
    """Side-stream transport: overlap via JAX async dispatch, no threads.

    ``dispatch`` enqueues the jitted catch-up and returns immediately with
    async result arrays; XLA executes it concurrently with whatever the
    edge loop dispatches next.  Requests chain through ``self.cache`` (an
    async array after the first dispatch), so the replay order is enforced
    by XLA's data dependencies — FIFO by construction.  ``poll`` observes
    readiness with ``Array.is_ready()``; conversion to numpy happens only
    at release, so nothing blocks early.

    ``latency_s`` simulates the network: a reply becomes visible
    ``latency_s`` after its compute is first OBSERVED ready (the edge loop
    polls every step, so the observation error is at most one step).
    """

    kind = "stream"

    def __init__(self, catchup_fn, params, cache, *, latency_s: float = 0.0):
        super().__init__(catchup_fn, params, cache)
        self.latency_s = float(latency_s)
        self._pending: deque = deque()  # [req, v, fhat, ready_at | None]

    def dispatch(self, req: CatchupRequest) -> None:
        cache, v, fhat = self._fn(
            self._params, self.cache, req.history,
            jnp.asarray(req.server_pos, jnp.int32),
            jnp.asarray(req.t, jnp.int32),
            jnp.asarray(req.triggered), req.u)
        self.cache = cache
        self._pending.append([req, v, fhat, None])

    def _release(self, item) -> CatchupReply:
        req, v, fhat, ready_at = item
        return CatchupReply(req.req_id, req.t, np.asarray(req.triggered),
                            np.asarray(v), np.asarray(fhat),
                            server_time_s=0.0,  # not observable without blocking
                            wall_ready=ready_at + self.latency_s)

    def _stamp_ready(self) -> None:
        # stamp readiness for EVERY pending request, not just the head —
        # the wire delays of distinct requests overlap (concurrent flights);
        # compute is FIFO (cache-chained), so stop at the first not-ready
        now = time.monotonic()
        for item in self._pending:
            if item[3] is None:
                if not item[1].is_ready():
                    break
                item[3] = now

    def poll(self) -> List[CatchupReply]:
        self._stamp_ready()
        out: List[CatchupReply] = []
        while self._pending:
            item = self._pending[0]
            if item[3] is None or item[3] + self.latency_s > time.monotonic():
                break
            self._pending.popleft()
            out.append(self._release(item))
        return out

    def wait(self, req_id: int) -> List[CatchupReply]:
        out: List[CatchupReply] = []
        while not out or out[-1].req_id < req_id:
            item = self._pending.popleft()
            if item[3] is None:
                jax.block_until_ready(item[1])
                item[3] = time.monotonic()
                # later requests may have finished compute while we
                # blocked: start their wire clocks NOW so their delays
                # overlap this item's sleep (concurrent flights — same
                # rule as poll)
                self._stamp_ready()
            dt = item[3] + self.latency_s - time.monotonic()
            if dt > 0:              # still on the simulated wire
                time.sleep(dt)
            out.append(self._release(item))
        return out

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        jax.block_until_ready(self.cache)


class ThreadWorker(ServerWorker):
    """Single worker thread; the edge loop overlaps the jitted catch-up.

    ``latency_s`` models the network round trip: a reply becomes visible
    ``latency_s`` after its compute finishes.  The delay is concurrent
    (multiple replies can be "on the wire" at once) while compute stays
    serialized — the realistic shape for a remote corrector, where RTT
    dominates and the server itself is fast.
    """

    kind = "thread"

    def __init__(self, catchup_fn, params, cache, *, latency_s: float = 0.0):
        super().__init__(catchup_fn, params, cache)
        self.latency_s = float(latency_s)
        self._q: "queue.Queue[Optional[CatchupRequest]]" = queue.Queue()
        self._cv = threading.Condition()
        self._done: deque = deque()  # (reply, visible_at) in FIFO order
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        while True:
            req = self._q.get()
            if req is None:
                return
            reply = self._compute(req)
            visible_at = reply.wall_ready + self.latency_s
            reply.wall_ready = visible_at
            with self._cv:
                self._done.append((reply, visible_at))
                self._cv.notify_all()

    def dispatch(self, req: CatchupRequest) -> None:
        self._q.put(req)

    def poll(self) -> List[CatchupReply]:
        now = time.monotonic()
        out: List[CatchupReply] = []
        with self._cv:
            while self._done and self._done[0][1] <= now:
                out.append(self._done.popleft()[0])
        return out

    def wait(self, req_id: int) -> List[CatchupReply]:
        out: List[CatchupReply] = []
        while not out or out[-1].req_id < req_id:
            with self._cv:
                while not self._done:
                    if not self._thread.is_alive():
                        raise RuntimeError(
                            "server worker thread died (catch-up raised)")
                    self._cv.wait(timeout=0.05)
                reply, visible_at = self._done.popleft()
            dt = visible_at - time.monotonic()
            if dt > 0:              # still on the simulated wire
                time.sleep(dt)
            out.append(reply)
        return out

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self._thread.is_alive():
            self._q.put(None)
            self._thread.join()


class MockRemoteWorker(ThreadWorker):
    """``thread`` + a nonzero simulated network round trip."""

    kind = "mock_remote"

    def __init__(self, catchup_fn, params, cache, *, latency_s: float = 0.02):
        super().__init__(catchup_fn, params, cache, latency_s=latency_s)


class _Flight:
    """One unanswered request on the socket, kept until its reply lands
    so a fleet failover can resend it verbatim.  ``internal`` flights are
    the recovery replay's own synthetic requests (their replies are
    consumed silently — the engine never sees them)."""

    __slots__ = ("req_id", "internal", "buf", "t", "triggered", "n_tokens")

    def __init__(self, req_id: int, internal: bool, buf: bytes, t: int,
                 triggered: np.ndarray, n_tokens: int):
        self.req_id = req_id
        self.internal = internal
        self.buf = buf
        self.t = t
        self.triggered = triggered
        self.n_tokens = n_tokens


class SocketWorker(ServerWorker):
    """The ``wire`` transport: catch-up requests cross a REAL socket to a
    standalone correction-server process (``serving/server.py``).

    The server owns the authoritative server cache (leased super-batch
    rows) and the replayed token history for the whole session; locally,
    ``self.cache`` keeps the engine's initial (cold) cache — with a real
    boundary there is nothing to re-adopt at session close, the
    protocol state that comes home is ``server_pos`` (carried by every
    reply).  Only the protocol bytes move: each dispatch serializes the
    trigger mask, per-stream catch-up bases, dispatch-time u scores and
    the BACKLOG token slices (never the full history snapshot); each
    reply carries (v, fhat) and the server's replay time.  Wire latency
    is whatever the kernel + scheduler + server actually take — the
    worker measures it per request (``CommsMeter.record_wire_rtt``) along
    with exact tx/rx byte counts, including the handshake.

    ``coalesce=False`` opts the session out of server-side request
    coalescing (per-request replays — the bench baseline).

    FLEET MODE (``address="fleet:<router>"``, serving/fleet.py): the
    worker HELLOs the router, follows its REDIRECT to the least-loaded
    live server, and treats the connection as expendable.  Because the
    client is the source of truth for its own token history, a dead or
    draining server costs a re-HELLO plus a cold replay — never state:

      * every request stays in ``self._flights`` until its reply lands
        (FIFO, mirroring the server's ordering contract), and
        ``self._acked_pos`` tracks the per-row position the server has
        CONFIRMED via replies;
      * on EOF/reset (or a GOAWAY once the pipeline is empty) the worker
        re-resolves through the router, re-HELLOs, replays each row's
        acked prefix ``history[i, :acked_pos[i]]`` from position 0 via
        synthetic internal requests, then resends the unanswered real
        requests verbatim — reconstructing the server state bit-exactly
        (the masked replay is position-deterministic), so survivors stay
        bitwise identical to an uninterrupted run;
      * every byte of that recovery (handshake, replay, resends) is
        charged to ``CommsMeter``'s ``failover`` bucket, keeping the
        steady-state ``wire`` byte invariants auditable.

    Duplicate or stale replies (a chaos proxy re-sending a REPLY, or a
    late frame racing a reconnect) are dropped by the head-of-flights
    req_id check — the Dispatcher's FIFO contract is enforced here.
    """

    kind = "wire"

    _FLEET_PREFIX = "fleet:"

    def __init__(self, cache, *, address: str, batch: int, max_len: int,
                 tok_tail: Tuple[int, ...] = (), coalesce: bool = True,
                 comms=None, metrics=None, tracer=None,
                 connect_timeout: float = 60.0,
                 client: str = "edge"):
        from repro.serving import wire  # local import: keep module light

        self._wire = wire
        self._fn = None          # the server process owns catchup + params
        self._params = None
        self.cache = cache       # stays cold locally (see class docstring)
        self._closed = False
        self._comms = comms
        # observability (both optional): ``metrics`` is the engine's
        # MetricsRegistry — the measured RTT breakdown (serialize / socket
        # / queue / compute, via the v4 REPLY timing payload) lands there;
        # ``tracer`` additionally records wire/server spans per request
        self._metrics = metrics
        self._tracer = tracer
        self._batch = int(batch)
        self._hello = wire.Hello(batch, max_len, tuple(tok_tail), coalesce,
                                 client)
        self._fleet = address.startswith(self._FLEET_PREFIX)
        self._target = address[len(self._FLEET_PREFIX):] if self._fleet \
            else address
        self._connect_timeout = connect_timeout
        self._replies: deque = deque()
        # req_id -> (dispatch wall time, serialize duration): the client
        # half of the per-request RTT breakdown
        self._dispatch_wall: Dict[int, Tuple[float, float]] = {}
        # -- failover state (fleet mode; harmless bookkeeping otherwise) -----
        self._flights: "deque[_Flight]" = deque()
        self._acked_pos = np.zeros(self._batch, np.int32)
        self._last_history: Optional[np.ndarray] = None
        self._must_move = False      # GOAWAY received: migrate when empty
        self._failing_over = False   # routes _tx/_rx to the failover bucket
        self._internal_next = 1 << 62  # clear of the Dispatcher's req_ids
        self.server_address: Optional[str] = None
        # cork/uncork: while corked, outgoing frames gather into one
        # buffer and leave in a single transmit at uncork — the engine
        # corks around a step's dispatch fan-out so N cohort requests
        # cost one syscall (the client half of wire micro-batching)
        self._corked: Optional[List[bytes]] = None
        self._sock, self._reader = None, wire.FrameReader()
        self._establish(self._connect_timeout)

    # -- metering ------------------------------------------------------------
    def _tx(self, n: int) -> None:
        if self._comms is not None:
            if self._failing_over:
                self._comms.record_failover_tx(n)
            else:
                self._comms.record_wire_tx(n)

    def _rx(self, n: int) -> None:
        if self._comms is not None:
            if self._failing_over:
                self._comms.record_failover_rx(n)
            else:
                self._comms.record_wire_rx(n)

    # -- connection management -----------------------------------------------
    def _establish(self, timeout: float) -> None:
        """Connect + HELLO (via the router in fleet mode, following its
        REDIRECT).  Fleet mode keeps retrying the router on a refused or
        dead target until ``timeout`` — a SIGKILLed server is replaced by
        a sibling on the next resolve; a direct address surfaces
        ``HandshakeRefused`` / ``PeerGone`` to the caller unchanged (the
        two failure modes the old ``connect()`` loop conflated)."""
        wire = self._wire
        deadline = time.monotonic() + timeout
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise wire.PeerGone(
                    f"no usable server via {self._target!r} "
                    f"within {timeout:.1f}s")
            # short per-attempt timeout in fleet mode: a dead redirect
            # target must bounce us back to the router, not eat the
            # whole deadline
            per = min(2.0, remaining) if self._fleet else remaining
            try:
                sock, ack, reader, tx, rx = self._handshake(per)
                break
            except (wire.HandshakeRefused, wire.PeerGone, OSError):
                if not self._fleet:
                    raise
                time.sleep(0.05)
        self._sock, self._reader = sock, reader
        self._tx(tx)
        self._rx(rx)
        self.session_id = ack.session_id
        self.slot_lo = ack.slot_lo
        try:
            peer = sock.getpeername()
            self.server_address = (peer if isinstance(peer, str)
                                   else f"{peer[0]}:{peer[1]}")
        except OSError:
            self.server_address = None
        self._must_move = False

    def _handshake(self, timeout: float):
        """One connect + HELLO attempt — the transport-specific half of
        ``_establish`` (the shm transport overrides this to negotiate an
        arena on the same handshake)."""
        return self._wire.connect_hello(self._target, self._hello,
                                        timeout=timeout)

    def _failover(self, why: str) -> None:
        """Migrate to another server: re-resolve, re-HELLO, replay each
        row's ACKED history prefix from position 0, resend unanswered
        requests verbatim.  Deterministic by construction: the server
        state after recovery is bitwise what the dead server had acked,
        so the resent requests see exactly the bases they were built on."""
        wire = self._wire
        if not self._fleet:
            raise wire.WireError(why)
        try:
            self._sock.close()
        except OSError:
            pass
        if self._comms is not None:
            self._comms.record_failover()
        real = [f for f in self._flights if not f.internal]
        self._failing_over = True
        deadline = time.monotonic() + self._connect_timeout
        try:
            while True:
                self._flights = deque()
                self._establish(max(0.1, deadline - time.monotonic()))
                try:
                    self._recover(real)
                    return
                except (wire.PeerGone, OSError):
                    # the NEW server died mid-recovery: route again
                    try:
                        self._sock.close()
                    except OSError:
                        pass
                    if time.monotonic() > deadline:
                        raise
        finally:
            self._failing_over = False

    def _recover(self, real: List[_Flight]) -> None:
        """On the fresh session: synthetic replay of the acked prefixes,
        then the unanswered real requests, all in FIFO order."""
        wire = self._wire
        acked = self._acked_pos
        if self._last_history is not None:
            zeros_pos = np.zeros(self._batch, np.int32)
            zeros_u = np.zeros(self._batch, np.float32)
            for p in sorted({int(x) for x in acked if x > 0}):
                trig = acked == p
                rid = self._internal_next
                self._internal_next += 1
                buf = wire.encode_request(rid, p - 1, trig, zeros_pos,
                                          zeros_u, self._last_history)
                self._flights.append(_Flight(rid, True, buf, p - 1,
                                             trig.copy(),
                                             int(trig.sum()) * p))
                self._send_frame(buf)
                if self._comms is not None:
                    self._comms.record_failover_tokens(int(trig.sum()) * p)
        for f in real:
            self._flights.append(f)
            self._send_frame(f.buf)
            if self._comms is not None:
                self._comms.record_failover_tokens(f.n_tokens, resent=True)

    def _move_now(self) -> None:
        """GOAWAY honored: pipeline is empty, leave politely and rebuild
        on a sibling (the replay machinery is identical to a crash — the
        only difference is the BYE)."""
        try:
            self._sock.settimeout(1.0)
            bye = self._wire.encode_bye()
            self._sock.sendall(bye)
            self._tx(len(bye))
        except OSError:
            pass
        self._failover("server draining")

    # -- socket pump ---------------------------------------------------------
    # the tracer span name for the transport-hop stage of the RTT: the
    # shm transport reports "shm.ring" (same stage key in the breakdown
    # table — docs/observability.md)
    _socket_span = "wire.socket"

    def _record_rtt(self, rtt: float) -> None:
        if self._comms is not None:
            self._comms.record_wire_rtt(rtt)

    def _to_reply(self, msg) -> CatchupReply:
        now = time.monotonic()
        disp, ser = self._dispatch_wall.pop(msg.req_id, (now, 0.0))
        rtt = now - disp
        self._record_rtt(rtt)
        if self._metrics is not None or self._tracer is not None:
            self._breakdown(msg, now, disp, ser, rtt)
        return CatchupReply(msg.req_id, msg.t, np.asarray(msg.triggered),
                            np.asarray(msg.v), np.asarray(msg.fhat),
                            msg.server_time_s, wall_ready=now)

    def _breakdown(self, msg, now: float, disp: float, ser: float,
                   rtt: float) -> None:
        """Split one measured RTT into serialize / socket / queue /
        compute using the REPLY's duration-only timing fields, observe
        the pieces into the registry, and (when tracing) synthesize the
        server-side spans — anchored BACKWARDS from reply arrival, since
        the server reported durations, not timestamps (no clock sync)."""
        compute = max(msg.server_time_s, 0.0)
        queue = msg.queue_s if msg.queue_s >= 0 else None   # None: v3 peer
        if self._metrics is not None:
            m = self._metrics
            m.observe("rtt_s", max(rtt, 1e-9))
            m.observe("rtt_serialize_s", max(ser, 1e-9))
            m.observe("rtt_compute_s", max(compute, 1e-9))
            if queue is not None:
                m.observe("rtt_queue_s", max(queue, 1e-9))
                m.observe("rtt_socket_s",
                          max(rtt - queue - compute, 1e-9))
        if self._tracer is not None:
            tr = self._tracer
            tr.add("wire.request", "wire", disp, rtt, track="wire",
                   req_id=msg.req_id, coalesced=msg.coalesced)
            # compute ends at arrival; queue precedes compute; the rest
            # of the gap after dispatch is both socket directions
            tr.add("server.catchup", "server", now - compute, compute,
                   track="server", req_id=msg.req_id,
                   coalesced=msg.coalesced)
            if queue is not None:
                tr.add("server.queue", "server", now - compute - queue,
                       queue, track="server", req_id=msg.req_id)
                tr.add(self._socket_span, "wire", disp,
                       max(rtt - queue - compute, 0.0), track="wire",
                       req_id=msg.req_id)

    def _accept_reply(self, msg) -> bool:
        """Match a REPLY against the head of the flight queue.  Anything
        else — a duplicated frame, a stale reply racing a reconnect — is
        dropped here so the Dispatcher's FIFO assert never fires.
        Returns True when a REAL (engine-visible) reply landed."""
        if not self._flights or self._flights[0].req_id != msg.req_id:
            return False
        f = self._flights.popleft()
        self._acked_pos = np.where(f.triggered, f.t + 1,
                                   self._acked_pos).astype(np.int32)
        if f.internal:
            return False
        self._replies.append(self._to_reply(msg))
        return True

    def _pump(self, block: bool) -> None:
        """Drain the socket into ``self._replies``.  Non-blocking drains
        whatever the kernel has; blocking returns once >= 1 reply landed.
        In fleet mode a dead connection triggers failover instead of
        raising, and a GOAWAY schedules a migration for when the
        pipeline is empty."""
        wire = self._wire
        got = False
        while True:
            if self._must_move and not self._flights:
                self._move_now()
            self._sock.settimeout(None if (block and not got) else 0.0)
            try:
                data = self._sock.recv(1 << 16)
            except (BlockingIOError, socket.timeout):
                return
            except InterruptedError:
                continue
            except OSError as e:
                self._failover(f"connection lost: {e}")
                continue
            if not data:
                self._failover("server closed connection")
                continue
            self._rx(len(data))
            got |= self._on_payloads(self._reader.feed(data))

    def _on_payloads(self, payloads: List[bytes]) -> bool:
        """Decode and act on frame payloads from either plane (socket or
        ring).  Returns True when a REAL reply landed."""
        wire = self._wire
        got = False
        for p in payloads:
            msg = wire.decode(p)
            if isinstance(msg, wire.Error):
                raise wire.WireError(f"server: {msg.message}")
            if isinstance(msg, wire.GoAway):
                self._must_move = True
            elif isinstance(msg, wire.WireReply):
                got |= self._accept_reply(msg)
        return got

    # -- ServerWorker API ----------------------------------------------------
    def dispatch(self, req: CatchupRequest) -> None:
        if self._must_move and not self._flights:
            self._move_now()
        hist = np.asarray(req.history)
        self._last_history = hist
        trig = np.asarray(req.triggered, bool)
        pos = np.asarray(req.server_pos, np.int32)
        n_tok = int(np.where(trig, int(req.t) + 1 - pos, 0).sum())
        t_enc = time.monotonic()
        buf = self._wire.encode_request(
            req.req_id, int(req.t), trig, pos,
            np.asarray(req.u, np.float32), hist)
        t_send = time.monotonic()
        self._dispatch_wall[req.req_id] = (t_send, t_send - t_enc)
        if self._tracer is not None:
            self._tracer.add("wire.encode", "wire", t_enc, t_send - t_enc,
                             track="wire", req_id=req.req_id,
                             bytes=len(buf), tokens=n_tok)
        self._flights.append(_Flight(req.req_id, False, buf, int(req.t),
                                     trig.copy(), n_tok))
        try:
            self._send_frame(buf)
        except OSError as e:
            # the flight is queued: failover re-establishes and resends
            self._failover(f"send failed: {e}")

    def poll(self) -> List[CatchupReply]:
        self._pump(block=False)
        out = list(self._replies)
        self._replies.clear()
        return out

    def wait(self, req_id: int) -> List[CatchupReply]:
        out: List[CatchupReply] = []
        while True:
            while self._replies:
                r = self._replies.popleft()
                out.append(r)
                if r.req_id == req_id:
                    return out
            self._pump(block=True)

    # -- frame egress --------------------------------------------------------
    def _send_frame(self, buf: bytes) -> None:
        if self._corked is not None:
            self._corked.append(buf)
            return
        self._transmit(buf)

    def _transmit(self, buf: bytes) -> None:
        """Hand one (possibly gathered) buffer to the transport — the
        only place client bytes actually leave."""
        self._sock.settimeout(None)
        self._sock.sendall(buf)
        self._tx(len(buf))

    def cork(self) -> None:
        """Start gathering outgoing frames (idempotent).  Frames queue
        locally until ``uncork`` sends them as ONE transmit — callers
        wrap a dispatch fan-out, never a wait."""
        if self._corked is None:
            self._corked = []

    def uncork(self) -> None:
        bufs, self._corked = self._corked, None
        if not bufs:
            return
        try:
            self._transmit(b"".join(bufs))
        except OSError as e:
            # every corked frame is already in _flights: failover
            # re-establishes and resends them verbatim
            self._failover(f"send failed: {e}")

    # -- slot-pool churn (MonitorSession.attach/detach over the wire) --------

    def attach_slot(self, slot: int) -> None:
        """Tell the server to zero and re-lease row ``slot`` of this
        session's lease (a new stream moved in).  Fire-and-forget: the
        socket is FIFO, so the reset lands before any later REQUEST that
        includes the slot.  The caller (engine) drains the pipeline
        first, so no earlier request is still in flight."""
        if self._must_move and not self._flights:
            self._move_now()
        self._acked_pos[slot] = 0  # the new tenant's history starts cold
        try:
            self._send_frame(self._wire.encode_attach(slot))
        except OSError as e:
            # a post-failover lease is freshly zeroed: the reset the
            # ATTACH asked for has already happened on the new server
            self._failover(f"send failed: {e}")

    def detach_slot(self, slot: int) -> None:
        """Tell the server the stream in row ``slot`` departed (the row
        is zeroed server-side as hygiene; ATTACH re-zeroes on reuse)."""
        self._acked_pos[slot] = 0
        try:
            self._send_frame(self._wire.encode_detach(slot))
        except OSError as e:
            self._failover(f"send failed: {e}")

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            self._sock.settimeout(1.0)
            bye = self._wire.encode_bye()
            self._sock.sendall(bye)
            self._tx(len(bye))
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass


class ShmWorker(SocketWorker):
    """The ``shm`` transport: ``SocketWorker`` with the DATA plane moved
    into a same-host shared-memory ring pair (``serving/shm.py``).

    The handshake negotiates an arena over the ordinary UDS control
    socket (HELLO asks, HELLO_ACK offers + ships the fds via
    SCM_RIGHTS, SHM_OPEN confirms); REQUEST frames then go out through
    the client->server ring and REPLY frames come back through the
    server->client ring — byte-identical wire-codec frames, so every
    protocol invariant (FIFO replies, head-of-flights dedup, replay
    failover) is inherited unchanged.  Control frames (BYE / ATTACH /
    DETACH / GOAWAY / ERROR) stay on the socket.

    FALLBACK (always to plain wire, with a logged reason): a TCP server
    address, a server that offers no arena (wire-only or pre-v5), or a
    failed arena attach all leave ``self._peer`` as None and this class
    behaves exactly like its parent.  Fleet failover composes the same
    way: on server death the usual re-HELLO runs through the router —
    if the surviving sibling doesn't offer shm, the session continues
    pure-wire (``tests/test_shm.py`` asserts bitwise identity through
    that migration).

    Metering: ring payload bytes and ring-transport RTTs land in
    ``comms["shm"]``, socket (handshake/control) bytes in
    ``comms["wire"]`` — shm traffic is measured, never silently free.
    """

    kind = "shm"

    def __init__(self, cache, **kw):
        self._peer = None
        self.fallback_reason = ""
        super().__init__(cache, **kw)

    # -- handshake -----------------------------------------------------------
    def _handshake(self, timeout: float):
        import dataclasses

        from repro.serving import shm, wire

        self._teardown_peer()
        family, _ = wire.parse_address(self._target)
        if family != socket.AF_UNIX:
            # SCM_RIGHTS and a shared arena need a shared host: don't
            # even ask, the session is pure wire
            self.fallback_reason = ("remote (TCP) server address: shm "
                                    "needs a shared host")
            shm.log.info("shm fallback to pure wire for %s: %s",
                         self._target, self.fallback_reason)
            self._socket_span = "wire.socket"
            return super()._handshake(timeout)
        hello = dataclasses.replace(self._hello, shm=True)
        sock, ack, reader, tx, rx, peer, reason = shm.connect_hello_shm(
            self._target, hello, timeout=timeout)
        self._peer = peer
        self.fallback_reason = reason
        # the transport-hop span in the traced RTT breakdown tracks the
        # plane actually carrying data frames
        self._socket_span = "shm.ring" if peer is not None else "wire.socket"
        return sock, ack, reader, tx, rx

    def _teardown_peer(self) -> None:
        if self._peer is not None:
            self._peer.close()
            self._peer = None

    # -- metering (ring plane -> comms["shm"]) -------------------------------
    def _tx_shm(self, n: int) -> None:
        if self._comms is not None:
            if self._failing_over:
                self._comms.record_failover_tx(n)
            else:
                self._comms.record_shm_tx(n)

    def _rx_shm(self, n: int) -> None:
        if self._comms is not None:
            if self._failing_over:
                self._comms.record_failover_rx(n)
            else:
                self._comms.record_shm_rx(n)

    def _record_rtt(self, rtt: float) -> None:
        if self._comms is None:
            return
        if self._peer is not None:
            self._comms.record_shm_rtt(rtt)
        else:
            self._comms.record_wire_rtt(rtt)

    # -- data plane ----------------------------------------------------------
    _SEND_DEADLINE_S = 60.0   # ring-full backpressure cap (server dead?)

    def _transmit(self, buf: bytes) -> None:
        peer = self._peer
        if peer is None:
            return super()._transmit(buf)
        mv = memoryview(buf)
        off = 0
        deadline = time.monotonic() + self._SEND_DEADLINE_S
        while off < len(mv):
            off += peer.send_all(mv[off:],
                                 timeout=deadline - time.monotonic(),
                                 wake_fds=(self._sock.fileno(),))
            if off >= len(mv):
                break
            # the ring is full AND the control socket has traffic (or
            # the deadline passed): service control frames — a dead
            # server surfaces here as OSError, which callers turn into
            # failover; backpressure with a live server just resumes
            self._drain_control()
            if time.monotonic() > deadline:
                raise OSError("shm ring backpressure timeout "
                              f"({self._SEND_DEADLINE_S:.0f}s)")
        self._tx_shm(len(buf))

    def _drain_control(self) -> None:
        """Non-blocking read of the control socket (raises OSError on a
        closed peer so the caller's failover path takes over)."""
        self._sock.settimeout(0.0)
        try:
            data = self._sock.recv(1 << 16)
        except (BlockingIOError, socket.timeout, InterruptedError):
            return
        if not data:
            raise OSError("server closed control socket")
        self._rx(len(data))
        self._on_payloads(self._reader.feed(data))

    def _pump(self, block: bool) -> None:
        if self._peer is None:
            return super()._pump(block)
        import select as _select
        got = False
        while True:
            if self._must_move and not self._flights:
                self._move_now()
                if self._peer is None:  # migrated onto a wire sibling
                    return super()._pump(block and not got)
            peer = self._peer
            # ring first (the data plane), then the control socket
            frames = peer.recv_frames()
            if frames:
                self._rx_shm(sum(len(p) + 4 for p in frames))
                got |= self._on_payloads(frames)
            try:
                self._drain_control()
            except OSError as e:
                self._failover(f"connection lost: {e}")
                if self._peer is None:
                    return super()._pump(block and not got)
                continue
            if got or not block:
                return
            # nothing yet: sleep on doorbell + socket.  Drain BEFORE the
            # ring re-check so a wakeup racing the select is never lost
            peer.db_own.drain()
            if peer.reader.available():
                continue
            _select.select([self._sock.fileno(), peer.fileno()],
                           [], [], 0.25)

    # -- lifecycle -----------------------------------------------------------
    def _failover(self, why: str) -> None:
        self._teardown_peer()
        super()._failover(why)

    def close(self) -> None:
        if self._closed:
            return
        super().close()
        self._teardown_peer()


def make_worker(transport: str, catchup_fn, params, cache, *,
                latency_s: Optional[float] = None,
                wire_opts: Optional[Dict[str, Any]] = None) -> ServerWorker:
    """``latency_s=None`` keeps each transport's own default (0 for
    stream/thread, 20 ms for mock_remote).  ``wire_opts`` configures the
    ``wire`` transport (at minimum ``address``; see ``SocketWorker``)."""
    if transport not in TRANSPORTS:
        raise ValueError(
            f"unknown transport {transport!r}: valid transports are "
            + ", ".join(repr(t) for t in TRANSPORTS))
    if transport == "inproc":
        if latency_s:
            raise ValueError("inproc transport has no latency model")
        return ServerWorker(catchup_fn, params, cache)
    if transport in ("wire", "shm"):
        if latency_s:
            raise ValueError(
                f"{transport} transport has no simulated latency: RTT is "
                "measured on the real socket (drop latency_s)")
        if not wire_opts or "address" not in wire_opts:
            raise ValueError(
                f"{transport} transport needs wire_opts={{'address': ...}} "
                "pointing at a running correction server (python -m "
                "repro.launch.server)")
        cls = SocketWorker if transport == "wire" else ShmWorker
        return cls(cache, **wire_opts)
    kw = {} if latency_s is None else {"latency_s": latency_s}
    if transport == "stream":
        return StreamWorker(catchup_fn, params, cache, **kw)
    if transport == "thread":
        return ThreadWorker(catchup_fn, params, cache, **kw)
    return MockRemoteWorker(catchup_fn, params, cache, **kw)


class Dispatcher:
    """Edge-side request tracking + the staleness merge policy.

    ``collect(now_t)`` is called once per edge step and returns the replies
    to merge at this step, already in FIFO (request) order:

      1. poll the worker (non-blocking) into a held buffer;
      2. while the oldest in-flight request has age >= max_staleness,
         BLOCK on it (this is the only place the edge loop ever waits, and
         it never gates the monitor/trigger path — the engine calls
         ``collect`` after u is computed);
      3. release held replies that satisfy the merge window: age >= 1 in
         pipelined mode (max_staleness >= 1), age >= 0 in strict sync mode.

    Stall time (step 2) and per-request wall/compute times feed the
    ``CommsMeter`` async accounting (overlap ratio, in-flight counts).
    """

    def __init__(self, worker: ServerWorker, *, max_staleness: int = 1,
                 comms=None, tracer=None):
        if max_staleness < 0:
            raise ValueError("max_staleness must be >= 0")
        self.worker = worker
        self.max_staleness = int(max_staleness)
        self.comms = comms
        self.tracer = tracer   # optional span tracer (edge.stall spans)
        self._inflight: deque = deque()   # CatchupRequest, FIFO
        self._held: deque = deque()       # arrived, not yet merge-eligible
        self._next_id = 0

    @property
    def n_inflight(self) -> int:
        return len(self._inflight) + len(self._held)

    def dispatch(self, *, t: int, triggered: np.ndarray,
                 server_pos: np.ndarray, history, u,
                 step_t: Optional[int] = None) -> CatchupRequest:
        req = CatchupRequest(self._next_id, int(t), np.asarray(triggered),
                             np.asarray(server_pos), history, u,
                             wall_dispatch=time.monotonic(),
                             step_t=int(t) if step_t is None else int(step_t))
        self._next_id += 1
        self._inflight.append(req)
        if self.comms is not None:
            self.comms.record_dispatch(req.triggered)
        self.worker.dispatch(req)
        return req

    def _arrived(self, replies: List[CatchupReply]) -> None:
        for r in replies:
            req = self._inflight.popleft()
            assert req.req_id == r.req_id, "worker must reply in FIFO order"
            r.step_t = req.step_t  # the staleness clock rides the request
            if self.comms is not None:
                self.comms.record_server_busy(
                    r.server_time_s, r.wall_ready - req.wall_dispatch)
            self._held.append(r)

    def collect(self, now_t: int) -> List[CatchupReply]:
        # ages are measured on the SESSION step clock (step_t), not the
        # request's trigger position t — the two coincide for a uniform
        # pool but diverge under slot-pool churn
        self._arrived(self.worker.poll())
        while (self._inflight
               and now_t - self._inflight[0].step_t >= self.max_staleness):
            t0 = time.monotonic()
            head = self._inflight[0].req_id
            replies = self.worker.wait(head)
            if self.comms is not None:
                self.comms.record_stall(time.monotonic() - t0)
            if self.tracer is not None:
                self.tracer.done("edge.stall", "edge", t0,
                                 req_id=head, step=now_t)
            self._arrived(replies)
        min_age = 1 if self.max_staleness > 0 else 0
        out: List[CatchupReply] = []
        while self._held and now_t - self._held[0].step_t >= min_age:
            r = self._held.popleft()
            if self.comms is not None:
                self.comms.record_merge(r.triggered, now_t - r.step_t)
            out.append(r)
        return out

    def drain(self) -> List[CatchupReply]:
        """Block for every outstanding reply (end of stream).  Tail replies
        have no edge step left to report into; the engine folds them into
        protocol state (server_pos) only.

        Re-entrant: once drained (or when nothing was ever dispatched) a
        further ``drain`` touches no worker state and returns ``[]`` —
        safe to call again after the session closed or on a closed
        worker.
        """
        if self._inflight:
            t0 = time.monotonic()
            self._arrived(self.worker.wait(self._inflight[-1].req_id))
            if self.comms is not None:
                self.comms.record_stall(time.monotonic() - t0)
            if self.tracer is not None:
                self.tracer.done("edge.stall", "edge", t0, drain=True)
        out = list(self._held)
        self._held.clear()
        if self.comms is not None:
            for r in out:
                self.comms.record_merge(r.triggered, self.max_staleness)
        return out

"""Async pipelined server catch-up: the dispatch/merge layer between the
edge decode loop and the server corrector.

The paper's deployment story is an edge monitor ``u`` that runs on EVERY
token while the server corrector ``v`` is consulted only on trigger — so
server latency (catch-up compute + network round trip) must be hideable
behind edge decode.  This module provides the two halves of that overlap:

  * ``ServerWorker`` — owns the server-side protocol state (params + the
    batched KV/SSM cache) and applies ``CatchupRequest``s strictly in FIFO
    order, so the cache replay is identical to the synchronous engine's.
    Three transports:

      - ``inproc``      — computes at dispatch, on the caller's thread.
        Zero latency, fully deterministic; the functional transport used
        by equivalence tests (it exercises the one-step-late merge policy
        without real concurrency).
      - ``stream``      — the side-stream transport: exploits JAX's async
        dispatch.  The jitted catch-up is ENQUEUED from the caller's
        thread (returns in well under a millisecond) and XLA's runtime
        executes it concurrently with the edge loop's subsequent
        ``decode_step`` dispatches; readiness is observed via
        ``Array.is_ready()`` without blocking.  Successive requests chain
        through the worker's cache arrays, so XLA serializes the replay
        exactly like a real server while everything else overlaps.  This
        is the preferred overlap transport on shared hosts (it uses XLA's
        own scheduler — no OS-thread oversubscription) and the
        single-device analogue of dispatching onto a second device via
        ``jax.device_put`` (the worker exclusively owns its cache buffers,
        so they are also donation-safe).  ``latency_s`` adds a simulated
        wire delay on top of compute readiness.
      - ``thread``      — a single daemon worker thread runs the jitted
        catch-up.  The GIL is released during XLA execution, so the edge
        loop overlaps the server replay; prefer ``stream`` on hosts with
        few cores (two thread pools can thrash each other).
      - ``mock_remote`` — ``thread`` plus a simulated network round trip:
        a reply becomes visible ``latency_s`` after its compute finishes.
        Latency is modelled as a concurrent wire delay (replies overlap in
        flight); compute stays serialized like a real single server.
      - ``wire``        — the REAL boundary: a ``SocketWorker`` speaking
        the versioned binary protocol of ``serving/wire.py`` to a
        standalone correction-server process (``serving/server.py``,
        started via ``python -m repro.launch.server``) over a
        Unix-domain or TCP socket.  The server owns the cache; only
        backlog tokens + scores cross the wire; RTT and bytes are
        MEASURED (``CommsMeter.record_wire_*``), not modelled, and the
        server coalesces queued requests across clients and pipeline
        depth.  ``latency_s`` is rejected here — the wire has whatever
        latency it actually has.

  * ``Dispatcher`` — the edge-side bookkeeping: tracks in-flight requests,
    polls/blocks for replies, and enforces the staleness window.

STALENESS SEMANTICS (``max_staleness``):

  * ``max_staleness == 0`` — strict synchronous fallback: the reply for a
    trigger at step t is merged AT step t (the dispatcher blocks
    immediately).  Bit-identical to the engine's synchronous step path
    (what ``SessionConfig(mode="sync")`` over a transport means).
  * ``max_staleness == k >= 1`` — pipelined: a reply merges at the first
    step AFTER its trigger once it has arrived ("corrections merge one
    step late"), and no later than ``t + k`` — the dispatcher blocks the
    edge loop only when the oldest in-flight request reaches age k.
    The monitor path (u, trigger decision) NEVER waits on the server.

Replies deliberately do not carry the server cache: the worker owns it for
the duration of the async session and the engine re-adopts it once when
the ``MonitorSession`` closes (after a full drain), which keeps
cross-thread ownership trivial.  See ``docs/protocol.md`` for the full
timeline diagrams.

MESH-SHARDED SESSIONS (``SessionConfig(mesh=...)``, serving/mesh.py):
the session shards the engine BEFORE the worker is built, so every local
transport adopts the batch-sharded server cache and the re-jitted
catch-up (whose in/out shardings are compiled in) — requests chain
through sharded buffers exactly as through unsharded ones, and slot
churn's row resets on the worker-owned cache are spec-aware
(placement-preserving).  The ``wire`` transport is orthogonal: the
client's mesh shards its edge, while the server process shards its own
super-batch via ``CorrectionServer(mesh=...)``; only protocol bytes
cross the boundary either way.
"""
from __future__ import annotations

import queue
import socket
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

TRANSPORTS = ("inproc", "stream", "thread", "mock_remote", "wire")


@dataclass
class CatchupRequest:
    """One trigger-step's worth of server work.

    ``server_pos`` is the DISPATCH-time catch-up base: stream i's backlog is
    ``history[i, server_pos[i]:t+1]``.  ``history`` is the engine's on-device
    token history at dispatch; jnp arrays are immutable, so the snapshot is
    free and stable while later edge steps keep recording.
    """

    req_id: int
    t: int                      # trigger POSITION (inclusive end of backlog)
    triggered: np.ndarray       # (B,) bool — which streams this request serves
    server_pos: np.ndarray      # (B,) int — catch-up base per stream
    history: jax.Array          # (B, max_len[, K]) token history snapshot
    u: jax.Array                # (B,) monitor scores at the trigger step
    wall_dispatch: float = 0.0  # time.monotonic() at dispatch
    # the SESSION step index at dispatch: the staleness clock.  With a
    # uniform pool it equals ``t``; under slot-pool churn streams carry
    # their own positions, so ``t`` (a position) and the session clock
    # diverge — ages are measured on step_t, backlogs on t.
    step_t: int = -1


@dataclass
class CatchupReply:
    req_id: int
    t: int                      # the request's trigger step
    triggered: np.ndarray
    v: np.ndarray               # (B,) server scores (valid where triggered)
    fhat: np.ndarray            # (B,) fused fhat from the DISPATCH-time u
    server_time_s: float        # compute time inside the worker
    wall_ready: float = 0.0     # when the reply became visible (incl. latency)
    step_t: int = -1            # filled by the Dispatcher from the request


class ServerWorker:
    """Base transport: owns the server cache, applies requests in FIFO order.

    ``catchup_fn(params, cache, history, server_pos, t, triggered, u)``
    -> (cache, v, fhat) — the engine's jitted masked per-element catch-up.
    """

    kind = "inproc"

    def __init__(self, catchup_fn: Callable, params: Any, cache: Any):
        self._fn = catchup_fn
        self._params = params
        self.cache = cache
        self._ready: deque = deque()  # replies visible to poll(), FIFO
        self._closed = False

    # -- server side ---------------------------------------------------------
    def _compute(self, req: CatchupRequest) -> CatchupReply:
        t0 = time.monotonic()
        cache, v, fhat = self._fn(
            self._params, self.cache, req.history,
            jnp.asarray(req.server_pos, jnp.int32),
            jnp.asarray(req.t, jnp.int32),
            jnp.asarray(req.triggered), req.u)
        v, fhat = jax.block_until_ready((v, fhat))
        self.cache = cache
        done = time.monotonic()
        return CatchupReply(req.req_id, req.t, np.asarray(req.triggered),
                            np.asarray(v), np.asarray(fhat), done - t0,
                            wall_ready=done)

    # -- edge side -----------------------------------------------------------
    def dispatch(self, req: CatchupRequest) -> None:
        """inproc: compute now, on the caller's thread."""
        self._ready.append(self._compute(req))

    def poll(self) -> List[CatchupReply]:
        """All replies that are ready, in FIFO order.  Non-blocking."""
        out = list(self._ready)
        self._ready.clear()
        return out

    def wait(self, req_id: int) -> List[CatchupReply]:
        """Block until ``req_id`` is done; returns every reply up to and
        including it, in FIFO order.  inproc computes at dispatch, so the
        reply is already here."""
        taken: List[CatchupReply] = []
        while self._ready:
            r = self._ready.popleft()
            taken.append(r)
            if r.req_id == req_id:
                break
        return taken

    def close(self) -> None:
        """Idempotent on every transport: safe to call twice, and again
        after the ``MonitorSession`` closed (which closes the worker
        itself)."""
        self._closed = True


class StreamWorker(ServerWorker):
    """Side-stream transport: overlap via JAX async dispatch, no threads.

    ``dispatch`` enqueues the jitted catch-up and returns immediately with
    async result arrays; XLA executes it concurrently with whatever the
    edge loop dispatches next.  Requests chain through ``self.cache`` (an
    async array after the first dispatch), so the replay order is enforced
    by XLA's data dependencies — FIFO by construction.  ``poll`` observes
    readiness with ``Array.is_ready()``; conversion to numpy happens only
    at release, so nothing blocks early.

    ``latency_s`` simulates the network: a reply becomes visible
    ``latency_s`` after its compute is first OBSERVED ready (the edge loop
    polls every step, so the observation error is at most one step).
    """

    kind = "stream"

    def __init__(self, catchup_fn, params, cache, *, latency_s: float = 0.0):
        super().__init__(catchup_fn, params, cache)
        self.latency_s = float(latency_s)
        self._pending: deque = deque()  # [req, v, fhat, ready_at | None]

    def dispatch(self, req: CatchupRequest) -> None:
        cache, v, fhat = self._fn(
            self._params, self.cache, req.history,
            jnp.asarray(req.server_pos, jnp.int32),
            jnp.asarray(req.t, jnp.int32),
            jnp.asarray(req.triggered), req.u)
        self.cache = cache
        self._pending.append([req, v, fhat, None])

    def _release(self, item) -> CatchupReply:
        req, v, fhat, ready_at = item
        return CatchupReply(req.req_id, req.t, np.asarray(req.triggered),
                            np.asarray(v), np.asarray(fhat),
                            server_time_s=0.0,  # not observable without blocking
                            wall_ready=ready_at + self.latency_s)

    def _stamp_ready(self) -> None:
        # stamp readiness for EVERY pending request, not just the head —
        # the wire delays of distinct requests overlap (concurrent flights);
        # compute is FIFO (cache-chained), so stop at the first not-ready
        now = time.monotonic()
        for item in self._pending:
            if item[3] is None:
                if not item[1].is_ready():
                    break
                item[3] = now

    def poll(self) -> List[CatchupReply]:
        self._stamp_ready()
        out: List[CatchupReply] = []
        while self._pending:
            item = self._pending[0]
            if item[3] is None or item[3] + self.latency_s > time.monotonic():
                break
            self._pending.popleft()
            out.append(self._release(item))
        return out

    def wait(self, req_id: int) -> List[CatchupReply]:
        out: List[CatchupReply] = []
        while not out or out[-1].req_id < req_id:
            item = self._pending.popleft()
            if item[3] is None:
                jax.block_until_ready(item[1])
                item[3] = time.monotonic()
                # later requests may have finished compute while we
                # blocked: start their wire clocks NOW so their delays
                # overlap this item's sleep (concurrent flights — same
                # rule as poll)
                self._stamp_ready()
            dt = item[3] + self.latency_s - time.monotonic()
            if dt > 0:              # still on the simulated wire
                time.sleep(dt)
            out.append(self._release(item))
        return out

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        jax.block_until_ready(self.cache)


class ThreadWorker(ServerWorker):
    """Single worker thread; the edge loop overlaps the jitted catch-up.

    ``latency_s`` models the network round trip: a reply becomes visible
    ``latency_s`` after its compute finishes.  The delay is concurrent
    (multiple replies can be "on the wire" at once) while compute stays
    serialized — the realistic shape for a remote corrector, where RTT
    dominates and the server itself is fast.
    """

    kind = "thread"

    def __init__(self, catchup_fn, params, cache, *, latency_s: float = 0.0):
        super().__init__(catchup_fn, params, cache)
        self.latency_s = float(latency_s)
        self._q: "queue.Queue[Optional[CatchupRequest]]" = queue.Queue()
        self._cv = threading.Condition()
        self._done: deque = deque()  # (reply, visible_at) in FIFO order
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        while True:
            req = self._q.get()
            if req is None:
                return
            reply = self._compute(req)
            visible_at = reply.wall_ready + self.latency_s
            reply.wall_ready = visible_at
            with self._cv:
                self._done.append((reply, visible_at))
                self._cv.notify_all()

    def dispatch(self, req: CatchupRequest) -> None:
        self._q.put(req)

    def poll(self) -> List[CatchupReply]:
        now = time.monotonic()
        out: List[CatchupReply] = []
        with self._cv:
            while self._done and self._done[0][1] <= now:
                out.append(self._done.popleft()[0])
        return out

    def wait(self, req_id: int) -> List[CatchupReply]:
        out: List[CatchupReply] = []
        while not out or out[-1].req_id < req_id:
            with self._cv:
                while not self._done:
                    if not self._thread.is_alive():
                        raise RuntimeError(
                            "server worker thread died (catch-up raised)")
                    self._cv.wait(timeout=0.05)
                reply, visible_at = self._done.popleft()
            dt = visible_at - time.monotonic()
            if dt > 0:              # still on the simulated wire
                time.sleep(dt)
            out.append(reply)
        return out

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self._thread.is_alive():
            self._q.put(None)
            self._thread.join()


class MockRemoteWorker(ThreadWorker):
    """``thread`` + a nonzero simulated network round trip."""

    kind = "mock_remote"

    def __init__(self, catchup_fn, params, cache, *, latency_s: float = 0.02):
        super().__init__(catchup_fn, params, cache, latency_s=latency_s)


class SocketWorker(ServerWorker):
    """The ``wire`` transport: catch-up requests cross a REAL socket to a
    standalone correction-server process (``serving/server.py``).

    The server owns the authoritative server cache (leased super-batch
    rows) and the replayed token history for the whole session; locally,
    ``self.cache`` keeps the engine's initial (cold) cache — with a real
    boundary there is nothing to re-adopt at session close, the
    protocol state that comes home is ``server_pos`` (carried by every
    reply).  Only the protocol bytes move: each dispatch serializes the
    trigger mask, per-stream catch-up bases, dispatch-time u scores and
    the BACKLOG token slices (never the full history snapshot); each
    reply carries (v, fhat) and the server's replay time.  Wire latency
    is whatever the kernel + scheduler + server actually take — the
    worker measures it per request (``CommsMeter.record_wire_rtt``) along
    with exact tx/rx byte counts, including the handshake.

    ``coalesce=False`` opts the session out of server-side request
    coalescing (per-request replays — the bench baseline).
    """

    kind = "wire"

    def __init__(self, cache, *, address: str, batch: int, max_len: int,
                 tok_tail: Tuple[int, ...] = (), coalesce: bool = True,
                 comms=None, connect_timeout: float = 60.0,
                 client: str = "edge"):
        from repro.serving import wire  # local import: keep module light

        self._wire = wire
        self._fn = None          # the server process owns catchup + params
        self._params = None
        self.cache = cache       # stays cold locally (see class docstring)
        self._closed = False
        self._comms = comms
        self._reader = wire.FrameReader()
        self._replies: deque = deque()
        self._dispatch_wall: Dict[int, float] = {}
        self._sock = wire.connect(address, timeout=connect_timeout)
        try:
            hello = wire.encode_hello(wire.Hello(
                batch, max_len, tuple(tok_tail), coalesce, client))
            self._sock.sendall(hello)
            self._tx(len(hello))
            ack = self._handshake()
        except BaseException:
            self._sock.close()  # a refused handshake must not leak the fd
            raise
        self.session_id = ack.session_id
        self.slot_lo = ack.slot_lo

    # -- metering ------------------------------------------------------------
    def _tx(self, n: int) -> None:
        if self._comms is not None:
            self._comms.record_wire_tx(n)

    def _rx(self, n: int) -> None:
        if self._comms is not None:
            self._comms.record_wire_rx(n)

    # -- socket pump ---------------------------------------------------------
    def _handshake(self):
        wire = self._wire
        self._sock.settimeout(None)
        while True:
            data = self._sock.recv(1 << 16)
            if not data:
                raise wire.WireError("server closed during handshake")
            self._rx(len(data))
            for p in self._reader.feed(data):
                msg = wire.decode(p)
                if isinstance(msg, wire.Error):
                    raise wire.WireError(f"server: {msg.message}")
                if isinstance(msg, wire.HelloAck):
                    return msg
                raise wire.WireError(f"unexpected handshake reply {msg}")

    def _to_reply(self, msg) -> CatchupReply:
        now = time.monotonic()
        disp = self._dispatch_wall.pop(msg.req_id, now)
        if self._comms is not None:
            self._comms.record_wire_rtt(now - disp)
        return CatchupReply(msg.req_id, msg.t, np.asarray(msg.triggered),
                            np.asarray(msg.v), np.asarray(msg.fhat),
                            msg.server_time_s, wall_ready=now)

    def _pump(self, block: bool) -> None:
        """Drain the socket into ``self._replies``.  Non-blocking drains
        whatever the kernel has; blocking returns once >= 1 reply landed."""
        wire = self._wire
        got = False
        while True:
            self._sock.settimeout(None if (block and not got) else 0.0)
            try:
                data = self._sock.recv(1 << 16)
            except (BlockingIOError, socket.timeout):
                return
            except InterruptedError:
                continue
            if not data:
                raise wire.WireError("server closed connection")
            self._rx(len(data))
            for p in self._reader.feed(data):
                msg = wire.decode(p)
                if isinstance(msg, wire.Error):
                    raise wire.WireError(f"server: {msg.message}")
                if isinstance(msg, wire.WireReply):
                    self._replies.append(self._to_reply(msg))
                    got = True

    # -- ServerWorker API ----------------------------------------------------
    def dispatch(self, req: CatchupRequest) -> None:
        buf = self._wire.encode_request(
            req.req_id, int(req.t), req.triggered, req.server_pos,
            np.asarray(req.u, np.float32), np.asarray(req.history))
        self._dispatch_wall[req.req_id] = time.monotonic()
        self._send_frame(buf)

    def poll(self) -> List[CatchupReply]:
        self._pump(block=False)
        out = list(self._replies)
        self._replies.clear()
        return out

    def wait(self, req_id: int) -> List[CatchupReply]:
        out: List[CatchupReply] = []
        while True:
            while self._replies:
                r = self._replies.popleft()
                out.append(r)
                if r.req_id == req_id:
                    return out
            self._pump(block=True)

    # -- slot-pool churn (MonitorSession.attach/detach over the wire) --------
    def _send_frame(self, buf: bytes) -> None:
        self._sock.settimeout(None)
        self._sock.sendall(buf)
        self._tx(len(buf))

    def attach_slot(self, slot: int) -> None:
        """Tell the server to zero and re-lease row ``slot`` of this
        session's lease (a new stream moved in).  Fire-and-forget: the
        socket is FIFO, so the reset lands before any later REQUEST that
        includes the slot.  The caller (engine) drains the pipeline
        first, so no earlier request is still in flight."""
        self._send_frame(self._wire.encode_attach(slot))

    def detach_slot(self, slot: int) -> None:
        """Tell the server the stream in row ``slot`` departed (the row
        is zeroed server-side as hygiene; ATTACH re-zeroes on reuse)."""
        self._send_frame(self._wire.encode_detach(slot))

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            self._sock.settimeout(1.0)
            bye = self._wire.encode_bye()
            self._sock.sendall(bye)
            self._tx(len(bye))
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass


def make_worker(transport: str, catchup_fn, params, cache, *,
                latency_s: Optional[float] = None,
                wire_opts: Optional[Dict[str, Any]] = None) -> ServerWorker:
    """``latency_s=None`` keeps each transport's own default (0 for
    stream/thread, 20 ms for mock_remote).  ``wire_opts`` configures the
    ``wire`` transport (at minimum ``address``; see ``SocketWorker``)."""
    if transport not in TRANSPORTS:
        raise ValueError(
            f"unknown transport {transport!r}: valid transports are "
            + ", ".join(repr(t) for t in TRANSPORTS))
    if transport == "inproc":
        if latency_s:
            raise ValueError("inproc transport has no latency model")
        return ServerWorker(catchup_fn, params, cache)
    if transport == "wire":
        if latency_s:
            raise ValueError(
                "wire transport has no simulated latency: RTT is measured "
                "on the real socket (drop latency_s)")
        if not wire_opts or "address" not in wire_opts:
            raise ValueError(
                "wire transport needs wire_opts={'address': ...} pointing "
                "at a running correction server (python -m "
                "repro.launch.server)")
        return SocketWorker(cache, **wire_opts)
    kw = {} if latency_s is None else {"latency_s": latency_s}
    if transport == "stream":
        return StreamWorker(catchup_fn, params, cache, **kw)
    if transport == "thread":
        return ThreadWorker(catchup_fn, params, cache, **kw)
    return MockRemoteWorker(catchup_fn, params, cache, **kw)


class Dispatcher:
    """Edge-side request tracking + the staleness merge policy.

    ``collect(now_t)`` is called once per edge step and returns the replies
    to merge at this step, already in FIFO (request) order:

      1. poll the worker (non-blocking) into a held buffer;
      2. while the oldest in-flight request has age >= max_staleness,
         BLOCK on it (this is the only place the edge loop ever waits, and
         it never gates the monitor/trigger path — the engine calls
         ``collect`` after u is computed);
      3. release held replies that satisfy the merge window: age >= 1 in
         pipelined mode (max_staleness >= 1), age >= 0 in strict sync mode.

    Stall time (step 2) and per-request wall/compute times feed the
    ``CommsMeter`` async accounting (overlap ratio, in-flight counts).
    """

    def __init__(self, worker: ServerWorker, *, max_staleness: int = 1,
                 comms=None):
        if max_staleness < 0:
            raise ValueError("max_staleness must be >= 0")
        self.worker = worker
        self.max_staleness = int(max_staleness)
        self.comms = comms
        self._inflight: deque = deque()   # CatchupRequest, FIFO
        self._held: deque = deque()       # arrived, not yet merge-eligible
        self._next_id = 0

    @property
    def n_inflight(self) -> int:
        return len(self._inflight) + len(self._held)

    def dispatch(self, *, t: int, triggered: np.ndarray,
                 server_pos: np.ndarray, history, u,
                 step_t: Optional[int] = None) -> CatchupRequest:
        req = CatchupRequest(self._next_id, int(t), np.asarray(triggered),
                             np.asarray(server_pos), history, u,
                             wall_dispatch=time.monotonic(),
                             step_t=int(t) if step_t is None else int(step_t))
        self._next_id += 1
        self._inflight.append(req)
        if self.comms is not None:
            self.comms.record_dispatch(req.triggered)
        self.worker.dispatch(req)
        return req

    def _arrived(self, replies: List[CatchupReply]) -> None:
        for r in replies:
            req = self._inflight.popleft()
            assert req.req_id == r.req_id, "worker must reply in FIFO order"
            r.step_t = req.step_t  # the staleness clock rides the request
            if self.comms is not None:
                self.comms.record_server_busy(
                    r.server_time_s, r.wall_ready - req.wall_dispatch)
            self._held.append(r)

    def collect(self, now_t: int) -> List[CatchupReply]:
        # ages are measured on the SESSION step clock (step_t), not the
        # request's trigger position t — the two coincide for a uniform
        # pool but diverge under slot-pool churn
        self._arrived(self.worker.poll())
        while (self._inflight
               and now_t - self._inflight[0].step_t >= self.max_staleness):
            t0 = time.monotonic()
            replies = self.worker.wait(self._inflight[0].req_id)
            if self.comms is not None:
                self.comms.record_stall(time.monotonic() - t0)
            self._arrived(replies)
        min_age = 1 if self.max_staleness > 0 else 0
        out: List[CatchupReply] = []
        while self._held and now_t - self._held[0].step_t >= min_age:
            r = self._held.popleft()
            if self.comms is not None:
                self.comms.record_merge(r.triggered, now_t - r.step_t)
            out.append(r)
        return out

    def drain(self) -> List[CatchupReply]:
        """Block for every outstanding reply (end of stream).  Tail replies
        have no edge step left to report into; the engine folds them into
        protocol state (server_pos) only.

        Re-entrant: once drained (or when nothing was ever dispatched) a
        further ``drain`` touches no worker state and returns ``[]`` —
        safe to call again after the session closed or on a closed
        worker.
        """
        if self._inflight:
            t0 = time.monotonic()
            self._arrived(self.worker.wait(self._inflight[-1].req_id))
            if self.comms is not None:
                self.comms.record_stall(time.monotonic() - t0)
        out = list(self._held)
        self._held.clear()
        if self.comms is not None:
            for r in out:
                self.comms.record_merge(r.triggered, self.max_staleness)
        return out

"""Mesh-sharded serving: data-parallel super-batch state + a
collective-free monitor path at batch 1k+.

The paper's deployment is a fleet of edge monitors behind ONE heavy
server-side corrector.  At production scale that corrector serves
thousands of concurrent streams, and the per-stream server state — the
KV/SSM catch-up cache, the token-history mirror — no longer fits one
device.  This module shards a ``CollaborativeEngine`` (and, through it,
the standalone ``CorrectionServer``) across a host/device mesh:

  * **params** — replicated.  Both towers are small relative to the
    super-batch state and every device decodes its own rows; replication
    keeps the per-row math bit-identical to the unsharded engine.
  * **per-stream state** — batch-axis sharded over the mesh ``data``
    axis: the edge + server caches (``distributed.sharding.cache_specs``
    finds each leaf's batch axis), the on-device token history, and
    every (B,) protocol vector crossing a jit boundary (positions,
    trigger masks, u/v scores).

The per-stream protocol is ELEMENTWISE across the batch: stream i's
decode, trigger decision, backlog replay, and cache rows never read
stream j's.  Sharding the batch axis therefore cannot introduce any
cross-device communication on the monitor path, and this module makes
that a checked guarantee rather than a hope: ``shard_engine`` compiles
the edge-path kernels (masked decode, u head, history record) with
explicit ``in_shardings``/``out_shardings`` and ASSERTS that the
resulting HLO contains **zero collective ops** (``edge_hlo`` /
``assert_collective_free``).  The server catch-up replay is re-jitted
with the same placements; its only cross-device traffic is the scalar
``n_rounds`` reduction that sizes the replay loop.

Per-row bitwise identity to the unsharded engine (u / trigger / fhat /
server cache / comms) is asserted in ``tests/test_mesh.py`` on an
8-virtual-device host mesh — sharding is a pure placement change, not a
numerics change.

Entry points
------------

* ``MeshSpec.parse("data:8")`` — the one mesh description every surface
  shares (``SessionConfig(mesh=...)``, ``CollaborativeEngine(mesh=...)``,
  ``CorrectionServer(mesh=...)``, ``--mesh`` on the launchers).
* ``shard_engine(engine, spec)`` — place + re-jit an engine in place
  (idempotent for the same spec; a ``MonitorSession`` whose config
  carries a mesh calls this transparently at open).
* ``edge_hlo(engine)`` / ``assert_collective_free(...)`` — the compiled
  monitor-path HLO and the zero-collectives check.
* ``bytes_per_device(tree)`` — per-device bytes of a sharded pytree
  (the bench's ``cache_bytes_per_device`` column).

Virtual-device runs (tests, CI ``shard-smoke``, the bench sweep) pin
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before importing
jax.  See docs/sharding.md for the placement table and the
collective-free argument.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Any, Dict, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shd

_AXIS_RE = re.compile(r"^[A-Za-z_][A-Za-z0-9_]*$")

# HLO op mnemonics that imply cross-device communication.  Kept for
# backward compatibility; the matching itself now lives in
# ``analysis.hlo`` and is OPCODE-level (parsed instructions), so a
# benign op whose metadata/fusion name mentions a collective no longer
# trips the check.
COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute", "collective-broadcast",
                  "ragged-all-to-all")


@dataclass(frozen=True)
class MeshSpec:
    """A parsed, validated mesh description — ``"data:8"`` style.

    ``axes`` is an ordered tuple of (name, size) pairs.  Serving shards
    per-stream state over the ``data`` axis (a ``pod`` axis, when
    present, widens it — same convention as
    ``distributed.sharding.data_axes``); any other axis is legal in the
    spec but idle on the serving path (params replicate).
    """

    axes: Tuple[Tuple[str, int], ...] = (("data", 1),)

    def __post_init__(self):
        if not self.axes:
            raise ValueError("empty mesh spec")
        names = [n for n, _ in self.axes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate mesh axis in {names}")
        for name, size in self.axes:
            if not _AXIS_RE.match(name):
                raise ValueError(f"bad mesh axis name {name!r}")
            if not isinstance(size, int) or size < 1:
                raise ValueError(
                    f"mesh axis {name!r} needs a positive integer size, "
                    f"got {size!r}")
        if "data" not in names:
            raise ValueError(
                "serving meshes shard per-stream state over a 'data' axis: "
                f"spec {self} has none (e.g. use 'data:8')")

    @classmethod
    def parse(cls, spec: Union[str, "MeshSpec"]) -> "MeshSpec":
        """``"data:8"`` / ``"pod:2,data:4"`` -> MeshSpec; a MeshSpec
        passes through unchanged.  Round-trips: ``MeshSpec.parse(str(s))
        == s``."""
        if isinstance(spec, cls):
            return spec
        axes = []
        for part in str(spec).split(","):
            name, sep, size = part.partition(":")
            if not sep:
                raise ValueError(
                    f"mesh axis {part!r} must be 'name:size' (e.g. 'data:8')")
            try:
                n = int(size)
            except ValueError:
                raise ValueError(f"mesh axis size {size!r} is not an integer")
            axes.append((name.strip(), n))
        return cls(tuple(axes))

    def __str__(self) -> str:
        return ",".join(f"{n}:{s}" for n, s in self.axes)

    @property
    def n_devices(self) -> int:
        n = 1
        for _, s in self.axes:
            n *= s
        return n

    @property
    def data_size(self) -> int:
        """Ways the batch axis splits (product of pod+data sizes)."""
        n = 1
        for name, s in self.axes:
            if name in ("pod", "data"):
                n *= s
        return n

    def build(self) -> Mesh:
        """Materialise the mesh over the first ``n_devices`` local
        devices.  Raises with an ``XLA_FLAGS`` hint when the host has
        too few (CPU hosts expose one device unless the platform device
        count is forced)."""
        have = jax.device_count()
        if have < self.n_devices:
            raise ValueError(
                f"mesh {self} needs {self.n_devices} devices, host has "
                f"{have}: set XLA_FLAGS=--xla_force_host_platform_"
                f"device_count={self.n_devices} before importing jax "
                "(virtual host mesh), or run on a multi-device platform")
        devs = np.asarray(jax.devices()[:self.n_devices]).reshape(
            tuple(s for _, s in self.axes))
        return Mesh(devs, tuple(n for n, _ in self.axes))


def collective_ops(hlo_text: str) -> Tuple[str, ...]:
    """The collective-op instruction lines in compiled HLO text —
    op-level matching via ``analysis.hlo`` (instructions are parsed, so
    collective names in metadata/fusion labels cannot false-positive)."""
    from repro.analysis import hlo as ahlo
    return tuple(i.brief() for i in ahlo.collective_instructions(hlo_text))


def assert_collective_free(hlo_text: str, what: str = "edge step") -> None:
    """The paper's device-locality guarantee, checked on compiled HLO:
    the monitor path must not communicate across devices."""
    from repro.analysis import hlo as ahlo
    ahlo.assert_collective_free(hlo_text, what)


def bytes_per_device(tree: Any) -> int:
    """Per-device bytes of a (possibly sharded) array pytree — each
    leaf's addressable shard size, via ``sharding.shard_shape``."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        shape = leaf.sharding.shard_shape(leaf.shape) \
            if hasattr(leaf, "sharding") else leaf.shape
        n = 1
        for d in shape:
            n *= d
        total += n * leaf.dtype.itemsize
    return total


# ---------------------------------------------------------------------------
# Engine sharding
# ---------------------------------------------------------------------------


def edge_hlo(engine) -> Dict[str, str]:
    """Compiled HLO of the three monitor-path kernels of a SHARDED
    engine: the dense masked edge decode, the u head, and the per-slot
    history record.  These are exactly the jits ``_monitor_prologue``
    drives every step — together they ARE the edge/monitor path.

    The lowering itself lives in ``analysis.hlo.monitor_path_hlo`` and
    also runs UNSHARDED (the edge rules apply to single-device engines
    too); this wrapper keeps the sharded-only contract for mesh users.
    """
    from repro.analysis import hlo as ahlo
    if getattr(engine, "mesh_spec", None) is None:
        raise ValueError("engine is not mesh-sharded (use shard_engine)")
    return ahlo.monitor_path_hlo(engine, include_catchup=False)


def shard_engine(engine, spec: Union[str, MeshSpec], *,
                 check_collectives: bool = True):
    """Shard a ``CollaborativeEngine`` over ``spec`` IN PLACE and return
    it: replicate params, split every per-stream buffer (edge + server
    cache, token history) over the mesh ``data`` axis, and re-jit the
    hot paths — masked edge decode, u/v heads, history record, masked
    catch-up replay, the offline scan — with explicit
    ``in_shardings``/``out_shardings`` so placements are compiled in,
    not re-derived per call.

    Values are untouched (``device_put`` only moves bytes): the sharded
    engine is per-row bit-identical to the unsharded one.  Idempotent
    for an equal spec; a different spec, or an engine with an open async
    session (its worker owns the server cache), is refused.

    ``check_collectives`` compiles the monitor-path kernels eagerly and
    asserts their HLO is collective-free (the paper's device-locality
    requirement, now enforced at shard time).
    """
    spec = MeshSpec.parse(spec)
    current = getattr(engine, "mesh_spec", None)
    if current == spec:
        return engine
    if current is not None:
        raise ValueError(
            f"engine is already sharded over {current}; re-sharding over "
            f"{spec} mid-life is not supported — build a fresh engine")
    if engine._dispatcher is not None:
        raise RuntimeError(
            "cannot shard an engine with an open async session (the "
            "worker owns the server cache); close the session first")
    if engine.batch % spec.data_size != 0:
        raise ValueError(
            f"batch {engine.batch} not divisible by the mesh data size "
            f"{spec.data_size} ({spec})")

    mesh = spec.build()
    daxes = shd.data_axes(mesh)
    dname = daxes if len(daxes) > 1 else daxes[0]
    repl = NamedSharding(mesh, P())
    d1 = NamedSharding(mesh, P(dname))  # batch-leading, rest unsharded

    # -- placement (pure data movement: values are untouched) ---------------
    engine.params = jax.device_put(engine.params, repl)
    engine.edge.params = engine.params["edge"]
    engine.server.params = engine.params["server"]
    for se in (engine.edge, engine.server):
        csh = shd.cache_shardings(se.cache, mesh, engine.batch,
                                  use_model=False)
        se.cache = jax.device_put(se.cache, csh)
        se._cache_shardings = csh
    engine._history = jax.device_put(engine._history, d1)
    engine._history_sharding = d1

    # -- re-jit the hot paths with compiled-in placements -------------------
    ecsh = engine.edge._cache_shardings
    scsh = engine.server._cache_shardings
    engine.edge._step_masked = jax.jit(
        engine.edge._step_masked_impl,
        in_shardings=(repl, ecsh, d1, repl, d1),
        out_shardings=(d1, d1, ecsh))
    engine.server._step_masked = jax.jit(
        engine.server._step_masked_impl,
        in_shardings=(repl, scsh, d1, repl, d1),
        out_shardings=(d1, d1, scsh))
    engine._record_at = jax.jit(
        engine._record_at_impl,
        in_shardings=(d1, d1, d1, d1), out_shardings=d1)
    engine._u_head = jax.jit(
        engine._u_head_impl, in_shardings=(repl, d1), out_shardings=d1)
    # _v_head is NOT constrained: besides the (B,) batch inside the
    # catch-up (where the outer jit's shardings govern the inlined
    # call), the scan path applies it to the (capacity, d) compacted
    # corrector buffer, whose leading dim need not divide the mesh.
    # Its row-local reduce form keeps per-row bits placement-independent
    # either way.
    # catch-up: t may be a scalar (uniform pool) or (B,) vector (ragged
    # pool / server coalescing) — P() replicates either rank, and the
    # round mask stays elementwise against the sharded positions
    engine._catchup = jax.jit(
        engine._catchup_impl,
        in_shardings=(repl, scsh, d1, d1, repl, d1, d1),
        out_shardings=(scsh, d1, d1))
    engine._scan = jax.jit(
        engine._scan_impl,
        in_shardings=(repl, d1, d1), out_shardings=(d1, d1, d1, d1))

    engine.mesh = mesh
    engine.mesh_spec = spec

    if check_collectives:
        for name, txt in edge_hlo(engine).items():
            assert_collective_free(txt, f"monitor path [{name}]")
    return engine


def ensure_sharded(engine, spec: Union[str, MeshSpec, None]):
    """Session-open hook: no-op for ``spec=None`` (whatever the engine
    already is), otherwise ``shard_engine`` (idempotent for an equal
    spec, loud on a mismatch)."""
    if spec is None:
        return engine
    return shard_engine(engine, spec)

"""Same-host zero-copy transport: a mmap-backed shared-memory arena
holding one SPSC byte-ring pair per session (client->server and
server->client), negotiated over the ordinary UDS control socket.

Why: the PR-8 span tracer showed the coalesced wire path's ~201 ms p50
RTT at batch 64 is ~184 ms socket/scheduling — serialization is 0.09 ms,
server queue 0.6 ms, replay compute 8.3 ms (results/bench.csv
``wire_traced``).  The hop itself is the cost, so where edge and server
share a host the data frames should move through shared memory and the
socket should carry only control traffic.

Division of labor (docs/transport.md has the full story):

* **Socket (control plane):** HELLO / HELLO_ACK negotiate the session
  AND the arena (geometry + doorbell kind in the ack tail, the fds via
  ``SCM_RIGHTS`` on the same ``sendmsg``); ATTACH / DETACH / BYE /
  GOAWAY / ERROR / REDIRECT stay here, so lease lifecycle and fleet
  semantics are byte-identical to a pure-wire session.
* **Rings (data plane):** REQUEST frames flow client->server through
  ring 0, REPLY frames server->client through ring 1, using the
  UNCHANGED length-prefixed wire codec — ``wire.RingWriter`` /
  ``wire.RingReader`` give the rings socket stream semantics, so
  ``FrameReader`` handles partial frames across the wrap point exactly
  as it handles a fragmenting kernel.
* **Doorbells:** one per side (eventfd when available, pipe fallback).
  A side rings its peer after PRODUCING into the peer's rx ring and
  after CONSUMING from the peer's tx ring (freeing space) — waiters
  always drain their doorbell first and then re-check ring state, so a
  wakeup can never be lost.  The server registers its doorbell fd with
  the reactor ``selectors`` — no busy-spinning; the client selects on
  ``[control socket, doorbell]``.

Crash safety: the server creates the arena under ``/dev/shm`` (tmpdir
fallback), maps it, ships the ARENA FD to the client, and unlinks the
path immediately — from then on the file lives only as long as some
process (or an in-flight SCM_RIGHTS message, which the kernel
reference-counts) holds it, so a SIGKILL on either side leaks nothing.

Arena layout (all offsets fixed by ``ring_bytes``)::

    [arena header: u32 magic 'SHM1' | u32 ring_bytes | pad to 64]
    [ring 0 (client->server): 128B header | ring_bytes data]
    [ring 1 (server->client): 128B header | ring_bytes data]

Fallback rules (the transport degrades, never fails): the client does
not request shm over TCP addresses; a server that does not offer shm
(older version, ``--transport wire``) yields a plain session; an attach
failure on the client answers ``SHM_OPEN(ok=False)`` so the server
tears the arena down and the session continues pure-wire.  Every
fallback logs its reason (``repro.serving.shm`` logger).
"""
from __future__ import annotations

import logging
import mmap
import os
import select
import socket
import struct
import tempfile
import time
from typing import List, Optional, Sequence, Tuple

from repro.serving import wire

log = logging.getLogger("repro.serving.shm")

ARENA_MAGIC = 0x53484D31          # "SHM1"
ARENA_HDR = 64
_ARENA_HEAD = struct.Struct("<II")  # magic, ring_bytes
DEFAULT_RING_BYTES = 1 << 20

DB_EVENTFD = 0
DB_PIPE = 1

ARENA_PREFIX = "repro-shm-"       # lifecycle tests glob for strays


class ShmError(wire.WireError):
    """Arena/ring setup or geometry violation (never a session crash:
    callers fall back to the pure-wire path)."""


def arena_size(ring_bytes: int) -> int:
    return ARENA_HDR + 2 * (wire.RING_HDR + int(ring_bytes))


# -- doorbells ---------------------------------------------------------------

class Doorbell:
    """Edge-triggered wakeup line between the two processes: ``ring()``
    makes the owner's ``fileno()`` readable, ``drain()`` re-arms it.
    Purely a wakeup — ring state is always re-checked after a drain, so
    coalesced or spurious rings are harmless."""

    def __init__(self, kind: int, rfd: int, wfd: int):
        self.kind = kind
        self._rfd = rfd
        self._wfd = wfd
        self._closed = False

    @classmethod
    def create(cls) -> "Doorbell":
        if hasattr(os, "eventfd"):
            try:
                fd = os.eventfd(0, os.EFD_NONBLOCK | os.EFD_CLOEXEC)
                return cls(DB_EVENTFD, fd, fd)
            except OSError:   # pragma: no cover - exotic kernels
                pass
        r, w = os.pipe()
        os.set_blocking(r, False)
        os.set_blocking(w, False)
        return cls(DB_PIPE, r, w)

    @classmethod
    def from_fds(cls, kind: int, fds: Sequence[int]) -> "Doorbell":
        """Adopt fds received over SCM_RIGHTS (1 for eventfd, 2 for
        pipe).  O_NONBLOCK travels with the open file description, but
        re-assert it — a blocking doorbell would deadlock the reactor."""
        fds = list(fds)
        if kind == DB_EVENTFD:
            if len(fds) != 1:
                raise ShmError(f"eventfd doorbell wants 1 fd, got {len(fds)}")
            os.set_blocking(fds[0], False)
            return cls(kind, fds[0], fds[0])
        if kind == DB_PIPE:
            if len(fds) != 2:
                raise ShmError(f"pipe doorbell wants 2 fds, got {len(fds)}")
            for fd in fds:
                os.set_blocking(fd, False)
            return cls(kind, fds[0], fds[1])
        raise ShmError(f"unknown doorbell kind {kind}")

    @property
    def n_fds(self) -> int:
        return 1 if self.kind == DB_EVENTFD else 2

    def fds(self) -> List[int]:
        """The fds to ship over SCM_RIGHTS (read end first)."""
        return [self._rfd] if self.kind == DB_EVENTFD else [self._rfd,
                                                            self._wfd]

    def fileno(self) -> int:
        return self._rfd

    def ring(self) -> None:
        if self._closed:
            return
        try:
            if self.kind == DB_EVENTFD:
                os.eventfd_write(self._wfd, 1)
            else:
                os.write(self._wfd, b"\0")
        except (BlockingIOError, OSError):
            pass  # counter saturated / peer gone: still (or never) wakeable

    def drain(self) -> None:
        try:
            if self.kind == DB_EVENTFD:
                os.eventfd_read(self._rfd)
            else:
                while os.read(self._rfd, 4096):
                    pass
        except (BlockingIOError, OSError):
            pass

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for fd in {self._rfd, self._wfd}:
            try:
                os.close(fd)
            except OSError:
                pass


# -- the peer object (one side's live view of a session arena) ---------------

class ShmPeer:
    """One side's handle on a session arena: tx/rx rings over the shared
    mapping plus the two doorbells.  ``db_own`` is the doorbell this
    side sleeps on; ``db_peer`` is rung to wake the other side."""

    def __init__(self, mm: mmap.mmap, ring_bytes: int, *, server: bool,
                 db_own: Doorbell, db_peer: Doorbell):
        c2s_off = ARENA_HDR
        s2c_off = ARENA_HDR + wire.RING_HDR + ring_bytes
        if server:
            self.writer = wire.RingWriter(mm, s2c_off, ring_bytes)
            self.reader = wire.RingReader(mm, c2s_off, ring_bytes)
        else:
            self.writer = wire.RingWriter(mm, c2s_off, ring_bytes)
            self.reader = wire.RingReader(mm, s2c_off, ring_bytes)
        self._mm = mm
        self.ring_bytes = ring_bytes
        self.db_own = db_own
        self.db_peer = db_peer
        self._closed = False

    def fileno(self) -> int:
        """The fd to select on for peer activity (data OR freed space)."""
        return self.db_own.fileno()

    def recv_frames(self) -> List[bytes]:
        """Drain the rx ring through the incremental frame parser,
        ringing the peer when space was freed (it may be blocked on a
        full ring)."""
        before = self.reader.available()
        frames = self.reader.frames()
        if before:
            self.db_peer.ring()
        return frames

    def send_all(self, data, *, timeout: Optional[float] = None,
                 wake_fds: Sequence[int] = ()) -> int:
        """Write all of ``data`` into the tx ring, ringing the peer
        after each chunk and sleeping on this side's doorbell when the
        ring is full (the peer rings back after consuming).  Returns the
        bytes written — short only when ``timeout`` elapses or one of
        ``wake_fds`` (e.g. the control socket) becomes readable, so the
        caller can service it and resume with ``data[n:]``.  Partial
        CHUNKS are fine (stream semantics); the ring is never corrupted.
        """
        mv = memoryview(data)
        off = 0
        deadline = None if timeout is None else time.monotonic() + timeout
        while off < len(mv):
            n = self.writer.write(mv[off:])
            if n:
                off += n
                self.db_peer.ring()
                continue
            # full: drain-then-recheck so a ring between our write
            # attempt and the select can't be lost
            self.db_own.drain()
            if self.writer.free():
                continue
            wait = None
            if deadline is not None:
                wait = deadline - time.monotonic()
                if wait <= 0:
                    break
            ready, _, _ = select.select(
                [self.db_own.fileno(), *wake_fds], [], [], wait)
            if any(fd in ready for fd in wake_fds):
                break
        return off

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        # wake the peer one last time so a blocked sender re-checks and
        # notices the session is gone instead of sleeping out a timeout
        self.db_peer.ring()
        for db in (self.db_own, self.db_peer):
            db.close()
        try:
            self._mm.close()
        except (BufferError, ValueError):  # pragma: no cover - exported views
            pass


# -- server side: arena creation ---------------------------------------------

def _arena_root() -> str:
    root = "/dev/shm"
    return root if os.path.isdir(root) else tempfile.gettempdir()


class ServerArena:
    """The server's end of one session arena, from creation to the
    SCM_RIGHTS handoff.  Usage::

        arena = ServerArena.create(ring_bytes)
        socket.send_fds(conn, [ack_frame], arena.fds())
        arena.sent()          # unlink + close the arena fd: crash-safe
        ... arena.peer ...    # rings + doorbells, reactor side
        arena.close()
    """

    def __init__(self, peer: ShmPeer, path: str, fd: int, ring_bytes: int,
                 db_client: Doorbell):
        self.peer = peer
        self.path = path
        self.ring_bytes = ring_bytes
        self.db_kind = peer.db_own.kind
        self._fd: Optional[int] = fd
        self._db_client = db_client

    @classmethod
    def create(cls, ring_bytes: int = DEFAULT_RING_BYTES,
               root: Optional[str] = None) -> "ServerArena":
        root = root or _arena_root()
        fd, path = tempfile.mkstemp(prefix=ARENA_PREFIX, suffix=".arena",
                                    dir=root)
        db_server = db_client = None
        try:
            os.ftruncate(fd, arena_size(ring_bytes))
            mm = mmap.mmap(fd, arena_size(ring_bytes))
            _ARENA_HEAD.pack_into(mm, 0, ARENA_MAGIC, ring_bytes)
            db_server = Doorbell.create()
            db_client = Doorbell.create()
            if db_server.kind != db_client.kind:  # pragma: no cover
                raise ShmError("mixed doorbell kinds")
            peer = ShmPeer(mm, ring_bytes, server=True,
                           db_own=db_server, db_peer=db_client)
            return cls(peer, path, fd, ring_bytes, db_client)
        except Exception:
            for db in (db_server, db_client):
                if db is not None:
                    db.close()
            os.close(fd)
            try:
                os.unlink(path)
            except OSError:
                pass
            raise

    def fds(self) -> List[int]:
        """[arena fd, server doorbell fds..., client doorbell fds...] —
        the SCM_RIGHTS payload accompanying the HELLO_ACK."""
        assert self._fd is not None, "arena already handed off"
        return [self._fd, *self.peer.db_own.fds(), *self._db_client.fds()]

    def sent(self) -> None:
        """The fds are in flight (kernel-referenced): unlink the path and
        drop our arena fd — from here a SIGKILL on either side leaks no
        file, and the mapping dies with the last process."""
        if self._fd is not None:
            os.close(self._fd)
            self._fd = None
        if self.path is not None:
            try:
                os.unlink(self.path)
            except OSError:
                pass
            self.path = None

    def close(self) -> None:
        self.sent()
        self.peer.close()


# -- client side: attach + handshake -----------------------------------------

def attach(fds: Sequence[int], ring_bytes: int, db_kind: int) -> ShmPeer:
    """Map the arena fd and adopt the doorbells the server shipped.
    Consumes (or closes) every fd in ``fds`` — on failure nothing leaks
    and the caller answers ``SHM_OPEN(ok=False)``."""
    fds = list(fds)
    want = 1 + 2 * (1 if db_kind == DB_EVENTFD else 2)
    try:
        if len(fds) != want:
            raise ShmError(f"expected {want} fds for doorbell kind "
                           f"{db_kind}, got {len(fds)}")
        if ring_bytes <= 0 or arena_size(ring_bytes) > (1 << 31):
            raise ShmError(f"implausible ring_bytes {ring_bytes}")
        mm = mmap.mmap(fds[0], arena_size(ring_bytes))
        magic, rb = _ARENA_HEAD.unpack_from(mm, 0)
        if magic != ARENA_MAGIC or rb != ring_bytes:
            mm.close()
            raise ShmError(f"arena header mismatch (magic=0x{magic:08x}, "
                           f"ring_bytes={rb} vs {ring_bytes})")
        os.close(fds[0])
        n = 1 if db_kind == DB_EVENTFD else 2
        db_server = Doorbell.from_fds(db_kind, fds[1:1 + n])
        db_client = Doorbell.from_fds(db_kind, fds[1 + n:1 + 2 * n])
        return ShmPeer(mm, ring_bytes, server=False,
                       db_own=db_client, db_peer=db_server)
    except Exception:
        close_fds(fds)
        raise


def close_fds(fds: Sequence[int]) -> None:
    for fd in fds:
        try:
            os.close(fd)
        except OSError:
            pass


def connect_hello_shm(address: str, hello: "wire.Hello", *,
                      timeout: Optional[float] = 20.0,
                      retry_interval: float = 0.05,
                      ) -> Tuple[socket.socket, "wire.HelloAck",
                                 "wire.FrameReader", int, int,
                                 Optional[ShmPeer], str]:
    """``wire.connect_hello`` with SCM_RIGHTS awareness: same retry /
    refusal / redirect semantics, but the ack is received with
    ``socket.recv_fds`` (a plain ``recv`` would silently drop the
    ancillary fds) and, when the server offered an arena, the mapping is
    attached and confirmed with ``SHM_OPEN`` before returning.

    Returns ``(sock, ack, reader, tx, rx, peer, reason)`` — ``peer`` is
    ``None`` when the session fell back to pure wire, with ``reason``
    saying why (also logged).  ``hello.shm`` should be True; if it is
    not, this degrades to the generic handshake with ``peer=None``.
    """
    payload = wire.encode_hello(hello)
    deadline = None if timeout is None else time.monotonic() + timeout
    while True:
        remaining = (None if deadline is None
                     else max(0.05, deadline - time.monotonic()))
        try:
            sock = wire.connect(address, timeout=remaining,
                                retry_interval=retry_interval)
        except OSError as e:
            raise wire.PeerGone(f"connect to {address!r} failed: {e}") from e
        tx = len(payload)
        reader = wire.FrameReader()
        fds: List[int] = []
        try:
            sock.sendall(payload)
            rx = 0
            msg = None
            while msg is None:
                chunk, new_fds, flags, _ = socket.recv_fds(sock, 65536, 8)
                fds.extend(new_fds)
                if flags & getattr(socket, "MSG_CTRUNC", 0):
                    raise ShmError("ancillary fd payload truncated")
                if not chunk:
                    raise wire.PeerGone("server closed during handshake")
                rx += len(chunk)
                frames = reader.feed(chunk)
                if frames:
                    msg = wire.decode(frames[0])
            if isinstance(msg, wire.Error):
                close_fds(fds)
                sock.close()
                raise wire.HandshakeRefused(msg.message)
            if isinstance(msg, wire.Redirect):
                close_fds(fds)
                sock.close()
                return connect_hello_shm(msg.address, hello,
                                         timeout=remaining,
                                         retry_interval=retry_interval)
            if not isinstance(msg, wire.HelloAck):
                close_fds(fds)
                sock.close()
                raise wire.WireError(f"unexpected handshake reply: {msg}")
            peer, reason = None, ""
            if msg.ring_bytes <= 0 or not fds:
                close_fds(fds)
                reason = ("server offered no shm arena (wire-only server "
                          "or pre-v5 peer)")
            else:
                try:
                    peer = attach(fds, msg.ring_bytes, msg.db_kind)
                    confirm = wire.encode_shm_open(True)
                    sock.sendall(confirm)
                    tx += len(confirm)
                except (ShmError, OSError, ValueError) as e:
                    reason = f"arena attach failed: {e}"
                    decline = wire.encode_shm_open(False)
                    sock.sendall(decline)
                    tx += len(decline)
            if reason:
                log.info("shm fallback to pure wire for %s: %s",
                         address, reason)
            return sock, msg, reader, tx, rx, peer, reason
        except (wire.PeerGone, OSError) as e:
            close_fds(fds)
            sock.close()
            if deadline is not None and time.monotonic() > deadline:
                if isinstance(e, wire.PeerGone):
                    raise
                raise wire.PeerGone(f"handshake with {address!r} failed: "
                                    f"{e}") from e
            time.sleep(retry_interval)
        except wire.WireError:
            close_fds(fds)
            sock.close()
            raise


def stray_arenas(root: Optional[str] = None) -> List[str]:
    """Arena files still on disk (should ALWAYS be empty outside the
    handshake window — the lifecycle tests assert on this)."""
    root = root or _arena_root()
    try:
        names = os.listdir(root)
    except OSError:
        return []
    return sorted(os.path.join(root, n) for n in names
                  if n.startswith(ARENA_PREFIX))

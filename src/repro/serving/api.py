"""The public serving API: ``MonitorSession`` — one session-oriented
entrypoint over the collaborative engine, with dynamic stream membership.

After PRs 1-3 the engine had grown seven overlapping entrypoints
(``step``/``run``/``run_scan``/``start_async``/``step_async``/
``finish_async``/``run_async``) with transport, address, staleness and
coalescing knobs split across the constructor, method kwargs, and two
CLIs — and batch membership frozen at construction.  This module folds
all of that into three small objects:

  * ``TransportSpec``  — WHERE the server half runs: one parsed spec
    unifying the five transports (``inproc`` / ``stream`` / ``thread`` /
    ``mock_remote`` / ``wire``) with their address / simulated-latency /
    coalescing knobs, parseable from a string
    (``"wire:/tmp/corr.sock"``).
  * ``SessionConfig``  — HOW a session serves: execution mode
    (``sync`` | ``scan`` | ``async``), the transport, the staleness
    merge window, and optional monitor-operating-point overrides
    (threshold / margin / scan capacity / truncation n).  Frozen: a
    config can be shared, logged, and compared.
  * ``MonitorSession`` — the session itself: a context manager that
    dispatches ``step`` / ``run`` / ``stream`` to the engine's private
    jitted sync, scan, and async paths, and manages the SLOT POOL —
    ``attach(stream_id)`` admits a monitored stream into a free slot of
    the engine's batch mid-flight, ``detach(stream_id)`` retires one;
    results are keyed by the caller's stream ids.

Slot-pool semantics (the paper's fleet-of-devices deployment — devices
arrive and depart; cf. the device-session framing of *Collaborative
Inference for AI-Empowered IoT Devices*):

  * every stream occupies one slot (batch row) of the engine; a freshly
    attached stream starts bit-cold (edge + server cache rows, token
    history, positions all zeroed — exactly a fresh engine's row) at its
    own position 0 while co-resident streams keep their clocks;
  * same-position cohorts decode in ONE dense masked call
    (``ServeEngine.decode_masked``), which is per-row bitwise identical
    to the plain batched decode — so streams present for a whole run
    produce bit-identical u/trigger traces to a fixed-batch run, churn
    or no churn (asserted in tests);
  * detached slots are masked out of decode, triggers, and the
    ``CommsMeter`` — they stop accruing communication charges;
  * in async mode a membership change first drains the pipeline (a
    reply must never land on a re-leased slot); over the ``wire``
    transport the change is mirrored to the correction server with
    ATTACH/DETACH frames so it zeroes and re-leases the single
    super-batch row without disturbing co-resident clients.

Typical use::

    from repro.serving import MonitorSession, SessionConfig, TransportSpec

    eng = CollaborativeEngine(params, cfg, batch=8, max_len=128)
    with eng.session(SessionConfig(mode="async", max_staleness=8)) as s:
        for out in s.stream(token_batches):   # dicts keyed by stream id
            ...
        s.detach(3)                           # device 3 went offline
        s.attach("device-9")                  # a new device joined

See docs/api.md for the full lifecycle state machine and the migration
table from the deprecated per-method API.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Hashable, Iterable, Iterator, Optional, Tuple, Union

import numpy as np

from repro.serving.async_rpc import TRANSPORTS

MODES = ("sync", "scan", "async")


@dataclass(frozen=True)
class TransportSpec:
    """Where (and over what) the server half of the protocol runs.

    kind      — one of ``inproc`` (compute at dispatch, deterministic),
                ``stream`` (JAX async dispatch overlap), ``thread``
                (worker thread), ``mock_remote`` (thread + simulated
                RTT), ``wire`` (real socket to a standalone correction
                server — ``python -m repro.launch.server``), ``shm``
                (wire protocol over same-host shared-memory rings —
                ``TransportSpec.parse("shm:/tmp/corr.sock")``; falls
                back to plain wire, with a logged reason, when the
                server is remote or offers no arena).
    address   — ``wire``/``shm``: UDS path or ``host:port`` of a server,
                or ``fleet:<router-address>`` to connect through a
                ``FleetSupervisor`` router (``python -m
                repro.launch.fleet``): the session HELLOs the router,
                follows its REDIRECT to the least-loaded live server,
                and transparently fails over — re-HELLO + replay — if
                that server dies or drains (serving/fleet.py,
                docs/fleet.md).  ``TransportSpec.parse("fleet:...")``
                is shorthand for ``wire`` with a fleet address.
    latency_s — simulated round trip (stream/thread/mock_remote only;
                the wire has whatever latency it actually has).
    coalesce  — ``wire`` only: opt out of server-side request
                coalescing when False (per-request replays).
    """

    kind: str = "inproc"
    address: Optional[str] = None
    latency_s: Optional[float] = None
    coalesce: bool = True

    def __post_init__(self):
        if self.kind not in TRANSPORTS:
            raise ValueError(
                f"unknown transport {self.kind!r}: valid transports are "
                + ", ".join(repr(t) for t in TRANSPORTS))
        if self.address is not None and self.kind not in ("wire", "shm"):
            raise ValueError(
                f"transport {self.kind!r} takes no address "
                "(only 'wire' and 'shm')")
        if self.kind in ("wire", "shm") and self.address is None:
            raise ValueError(
                f"{self.kind} transport needs an address (the correction "
                "server's UDS path or host:port — python -m "
                "repro.launch.server)")
        if self.latency_s is not None and self.kind in ("inproc", "wire",
                                                        "shm"):
            raise ValueError(
                f"transport {self.kind!r} has no latency model"
                + (": RTT is measured on the real socket"
                   if self.kind in ("wire", "shm") else ""))

    @classmethod
    def parse(cls, spec: Union[str, "TransportSpec"]) -> "TransportSpec":
        """``"stream"`` -> TransportSpec("stream");
        ``"wire:/tmp/corr.sock"`` / ``"wire:host:port"`` -> wire + address;
        ``"shm:/tmp/corr.sock"`` -> same-host shared-memory rings;
        ``"fleet:/tmp/router.sock"`` -> wire through a fleet router.
        A TransportSpec passes through unchanged."""
        if isinstance(spec, cls):
            return spec
        s = str(spec)
        if s.startswith("fleet:"):
            return cls("wire", address=s)
        kind, sep, rest = s.partition(":")
        return cls(kind, address=rest if sep else None)


@dataclass(frozen=True)
class SessionConfig:
    """How a ``MonitorSession`` serves.  Frozen and validated.

    mode           — ``sync`` (each trigger blocks on the server; with a
                     non-inproc transport this is the strict
                     ``max_staleness=0`` boundary), ``scan`` (offline
                     compiled trace evaluation, fixed membership), or
                     ``async`` (pipelined: corrections merge 1..
                     ``max_staleness`` steps late, the monitor path
                     never waits).
    transport      — a ``TransportSpec`` or parseable string.
    max_staleness  — async merge window (ignored for sync/scan).
    mesh           — mesh-sharded serving (``serving/mesh.py``): a
                     ``MeshSpec`` or ``"data:8"``-style string.  The
                     session shards the engine at open — params
                     replicated, per-stream state batch-sharded over
                     the mesh ``data`` axis, monitor path asserted
                     collective-free.  Per-row numerics are unchanged
                     (NOT an operating point: an engine already sharded
                     over the same mesh is accepted as-is).
    threshold / trigger_margin — monitor operating-point overrides,
                     applied at engine construction by
                     ``MonitorSession.open`` (an existing engine must
                     already match — ``engine.session`` refuses silent
                     mismatches).
    policy         — a ``repro.serving.policy.TriggerPolicy``: per-stream
                     online threshold control.  The session binds it to
                     the engine's calibrated operating point at open,
                     reads its (B,) thresholds before every step, and
                     feeds the step outcome (+ the CommsMeter's windowed
                     rate gauge) back.  Mutually exclusive with
                     ``threshold`` — a policy OWNS the trigger point, so
                     combining them is refused loudly rather than
                     silently ignoring one.  ``None`` (default): the
                     fixed calibrated threshold, bit-identical to
                     pre-policy behavior.  Controller state is
                     client-held: fleet failover replay preserves it;
                     ``attach`` cold-starts the slot's controller.
    capacity       — scan mode's static correction capacity.
    monitor_n      — Eq.-8 truncation override for the serving u head.
    trace          — span tracing (``docs/observability.md``): the
                     session installs a ``repro.observability.Tracer``
                     on the engine for its lifetime; read it via
                     ``MonitorSession.tracer`` / ``export_trace``.
                     Default OFF: the disabled path is a flag check per
                     instrumentation site, and traced sessions are
                     bitwise identical to untraced ones (tested).
    trace_capacity — span ring bound when tracing (oldest dropped).
    """

    mode: str = "sync"
    transport: TransportSpec = field(default_factory=TransportSpec)
    max_staleness: int = 1
    policy: Optional[Any] = None  # TriggerPolicy | None (fixed threshold)
    threshold: Optional[float] = None
    trigger_margin: Optional[float] = None
    capacity: Optional[int] = None
    monitor_n: Optional[int] = None
    mesh: Optional[Any] = None  # MeshSpec | "data:8" | None (unsharded)
    trace: bool = False
    trace_capacity: int = 65536

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"unknown mode {self.mode!r}: valid modes are "
                             + ", ".join(repr(m) for m in MODES))
        if not isinstance(self.transport, TransportSpec):
            object.__setattr__(self, "transport",
                               TransportSpec.parse(self.transport))
        if self.max_staleness < 0:
            raise ValueError("max_staleness must be >= 0")
        if self.trace_capacity <= 0:
            raise ValueError("trace_capacity must be >= 1")
        if self.mode == "scan" and self.transport != TransportSpec():
            raise ValueError("scan mode is offline: it takes no transport")
        if self.policy is not None:
            if self.threshold is not None:
                # refuse rather than silently ignore one of them: a
                # policy OWNS the trigger point (its floor is the
                # engine's calibrated threshold)
                raise ValueError(
                    f"SessionConfig.threshold={self.threshold} and "
                    f"SessionConfig.policy={type(self.policy).__name__} "
                    "are mutually exclusive: a policy owns the trigger "
                    "point (bound to the engine's calibrated operating "
                    "point at open) — set the operating point via "
                    "threshold= alone, or let the policy drive it")
            from repro.serving.policy import TriggerPolicy
            if not isinstance(self.policy, TriggerPolicy):
                raise ValueError(
                    f"SessionConfig.policy must be a TriggerPolicy, got "
                    f"{type(self.policy).__name__}")
        if self.mesh is not None:
            from repro.serving.mesh import MeshSpec
            object.__setattr__(self, "mesh", MeshSpec.parse(self.mesh))

    @property
    def needs_worker(self) -> bool:
        """Whether this session runs through the dispatch/merge layer
        (async mode, or sync over a real/simulated transport)."""
        return (self.mode == "async"
                or (self.mode == "sync" and self.transport.kind != "inproc"))

    @property
    def effective_staleness(self) -> int:
        """sync mode over a transport is the strict boundary."""
        return self.max_staleness if self.mode == "async" else 0


class MonitorSession:
    """A context-managed serving session over one ``CollaborativeEngine``
    — the single public serving entrypoint.

    Lifecycle: ``new`` -> (first step/run/enter) ``open`` -> ``closed``.
    ``run`` on a worker-backed session (async, or sync over a transport)
    drains the pipeline tail and closes the session when the stream
    ends; ``step``-driven sessions close at ``__exit__``/``close()``.
    The session assumes it owns the engine's protocol state for its
    lifetime; one engine serves one session at a time.

    Results (``step``/``stream`` dicts, ``run`` stacked traces) carry
    the attached streams' rows in slot order, with the ids under
    ``"streams"``.
    """

    def __init__(self, engine, config: Optional[SessionConfig] = None, *,
                 streams: Optional[Iterable[Hashable]] = None, worker=None):
        self._engine = engine
        self.config = config if config is not None else SessionConfig()
        self._check_engine_matches(engine, self.config)
        self._worker = worker
        self._state = "new"
        # bind the threshold policy to the engine's calibrated operating
        # point.  Controller state lives HERE (client side, like the
        # token history): fleet failover replays without touching it.
        self._policy = self.config.policy
        if self._policy is not None:
            self._policy.bind(threshold=engine.m.threshold,
                              margin=engine.m.trigger_margin,
                              batch=engine.batch)
        B = engine.batch
        ids = list(range(B)) if streams is None else list(streams)
        if len(ids) > B:
            raise ValueError(f"{len(ids)} initial streams > {B} slots")
        if len(set(ids)) != len(ids):
            raise ValueError("duplicate stream ids")
        # initial membership: ids occupy slots 0..n-1.  On a fresh engine
        # the rows have never been used, so no zeroing is needed.  When
        # EXPLICIT stream ids are given on a previously-stepped engine,
        # the bit-cold guarantee applies: every initial slot is reset
        # exactly like a mid-session attach.  Default membership
        # (streams=None) on a used engine instead RESUMES the engine's
        # protocol state — the continuation semantics the deprecated
        # run* shims rely on.
        self._slots: list = [None] * B
        for slot, sid in enumerate(ids):
            self._slots[slot] = sid
        engine.active = np.asarray([s is not None for s in self._slots])
        if streams is not None and engine.t > 0:
            for slot, sid in enumerate(self._slots):
                if sid is not None:
                    engine._attach_slot(slot)

    @staticmethod
    def _check_engine_matches(engine, config: SessionConfig) -> None:
        m = engine.m
        for name, want, have in (
                ("threshold", config.threshold, m.threshold),
                ("trigger_margin", config.trigger_margin, m.trigger_margin),
                ("capacity", config.capacity, engine.capacity),
                ("monitor_n", config.monitor_n, engine.monitor_n)):
            if want is not None and want != have:
                raise ValueError(
                    f"SessionConfig.{name}={want} != the engine's {have}: "
                    "operating-point overrides apply at engine construction "
                    "— build the session with MonitorSession.open(...)")

    @classmethod
    def open(cls, params, arch_cfg, *, batch: int, max_len: int,
             config: Optional[SessionConfig] = None,
             streams: Optional[Iterable[Hashable]] = None) -> "MonitorSession":
        """Build engine + session in one call, applying the config's
        monitor operating-point overrides (threshold / margin /
        capacity / monitor_n) at engine construction."""
        from repro.serving.collaborative import CollaborativeEngine
        config = config if config is not None else SessionConfig()
        if config.threshold is not None or config.trigger_margin is not None:
            mon = arch_cfg.monitor
            kw = {**mon.__dict__}
            if config.threshold is not None:
                kw["threshold"] = config.threshold
            if config.trigger_margin is not None:
                kw["trigger_margin"] = config.trigger_margin
            arch_cfg = arch_cfg.replace(monitor=mon.__class__(**kw))
        eng = CollaborativeEngine(params, arch_cfg, batch=batch,
                                  max_len=max_len, capacity=config.capacity,
                                  monitor_n=config.monitor_n)
        return cls(eng, config, streams=streams)

    # -- lifecycle -----------------------------------------------------------
    @property
    def state(self) -> str:
        return self._state

    @property
    def engine(self):
        return self._engine

    def __enter__(self) -> "MonitorSession":
        self._ensure_open()
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _ensure_open(self) -> None:
        if self._state == "open":
            return
        if self._state == "closed":
            raise RuntimeError("session is closed")
        if self.config.mesh is not None:
            # transparently shard at open (BEFORE any worker is built:
            # the worker must adopt the sharded cache + re-jitted
            # catch-up).  Idempotent when the engine already carries the
            # same mesh; loud on a mismatch.
            from repro.serving.mesh import ensure_sharded
            ensure_sharded(self._engine, self.config.mesh)
        if self.config.trace:
            # install the tracer BEFORE any worker is built so the
            # dispatcher / socket worker capture it at construction
            from repro.observability import Tracer
            self._engine._tracer = Tracer(self.config.trace_capacity)
        else:
            # don't inherit a previous session's tracer on a reused engine
            self._engine._tracer = None
        if self.config.needs_worker:
            spec = self.config.transport
            self._engine._start_async(
                transport=spec.kind,
                max_staleness=self.config.effective_staleness,
                latency_s=spec.latency_s, address=spec.address,
                wire_coalesce=spec.coalesce, worker=self._worker)
        self._state = "open"

    def close(self) -> None:
        """Drain + close.  Idempotent."""
        if self._state == "open" and self.config.needs_worker:
            self._engine._finish_async()
        self._state = "closed"

    # -- membership (the slot pool) ------------------------------------------
    @property
    def streams(self) -> Tuple[Hashable, ...]:
        """Attached stream ids, in slot order (the row order of every
        result)."""
        return tuple(s for s in self._slots if s is not None)

    @property
    def n_attached(self) -> int:
        return sum(s is not None for s in self._slots)

    def slot_of(self, stream_id: Hashable) -> int:
        for slot, sid in enumerate(self._slots):
            if sid == stream_id:
                return slot
        raise KeyError(f"stream {stream_id!r} is not attached")

    def attach(self, stream_id: Hashable) -> int:
        """Admit ``stream_id`` into a free slot (bit-cold state; its
        position starts at 0 regardless of the session's age).  Returns
        the slot index.  Raises when the pool is full, the id is already
        attached, or the session is scan-mode/closed."""
        if self.config.mode == "scan":
            raise RuntimeError("scan sessions have fixed membership")
        if self._state == "closed":
            raise RuntimeError("session is closed")
        if any(sid == stream_id for sid in self._slots if sid is not None):
            raise ValueError(f"stream {stream_id!r} is already attached")
        for slot, sid in enumerate(self._slots):
            if sid is None:
                break
        else:
            raise RuntimeError(
                f"slot pool full ({self._engine.batch} slots): detach a "
                "stream first or build a larger engine")
        self._engine._attach_slot(slot)
        if self._policy is not None:
            # fresh tenant -> cold controller: no threshold or evidence
            # leakage from the slot's previous stream
            self._policy.reset_stream(slot)
        self._slots[slot] = stream_id
        return slot

    def detach(self, stream_id: Hashable) -> None:
        """Retire ``stream_id``: its slot stops decoding, triggering, and
        accruing comms charges, and becomes reusable by ``attach``.  In
        async mode the pipeline drains first (no reply may land on a
        re-leased slot)."""
        if self.config.mode == "scan":
            raise RuntimeError("scan sessions have fixed membership")
        if self._state == "closed":
            raise RuntimeError("session is closed")
        slot = self.slot_of(stream_id)
        self._engine._detach_slot(slot)
        self._slots[slot] = None

    # -- serving -------------------------------------------------------------
    def _attached_slot_idx(self) -> np.ndarray:
        return np.asarray([i for i, s in enumerate(self._slots)
                           if s is not None], np.int64)

    def _full_pool(self) -> bool:
        return all(s is not None for s in self._slots)

    def _expand(self, tokens) -> Any:
        """Caller tokens (dict by stream id, or an array over the
        attached streams in slot order) -> full-batch array."""
        ids = self.streams
        if isinstance(tokens, dict):
            missing = set(ids) - set(tokens)
            extra = set(tokens) - set(ids)
            if missing or extra:
                raise ValueError(
                    f"token dict mismatch: missing {sorted(missing, key=str)}, "
                    f"unknown {sorted(extra, key=str)}")
            tokens = np.stack([np.asarray(tokens[sid]) for sid in ids])
        if self._full_pool():
            return tokens  # pass-through: the fixed-batch fast path
        arr = np.asarray(tokens)
        if arr.shape[0] != len(ids):
            raise ValueError(
                f"tokens first axis {arr.shape[0]} != {len(ids)} attached "
                "streams")
        full = np.zeros((self._engine.batch,) + arr.shape[1:], arr.dtype)
        full[self._attached_slot_idx()] = arr
        return full

    def _narrow(self, r: Dict[str, np.ndarray]) -> Dict[str, Any]:
        ids = self.streams
        if self._full_pool():
            out = dict(r)
        else:
            sl = self._attached_slot_idx()
            out = {k: v[sl] for k, v in r.items()}
        out["streams"] = ids
        return out

    def step(self, tokens) -> Dict[str, Any]:
        """One monitoring step over the attached streams.  ``tokens``: a
        dict ``{stream_id: token}`` or an array ``(n_attached,[K])`` in
        slot order.  Returns u/fhat/triggered rows in slot order plus
        the ``streams`` id tuple."""
        if self.config.mode == "scan":
            raise RuntimeError(
                "scan sessions are offline: use run(token_stream)")
        self._ensure_open()
        full = self._expand(tokens)
        eng = self._engine
        if self._policy is not None:
            # thresholds are data, not structure: writing the vector
            # never retraces a jitted path (recompile-guard-tested)
            eng._thr_eff = np.asarray(self._policy.step_thresholds(),
                                      np.float32)
        if self.config.needs_worker:
            r = eng._step_async(full)
        else:
            r = eng._step(full)
        if self._policy is not None:
            self._policy.update(r["u"], r["fhat"], r["triggered"],
                                eng.active.copy(), eng.comms)
        return self._narrow(r)

    def stream(self, token_iter: Iterable) -> Iterator[Dict[str, Any]]:
        """Drive the session from an iterable of per-step tokens,
        yielding one result dict per step.  Membership may change
        between steps (each yielded dict carries its own ``streams``)."""
        for tokens in token_iter:
            yield self.step(tokens)

    def run(self, token_stream) -> Dict[str, Any]:
        """Serve a full fixed stream ``(n_attached, S[,K])`` and return
        stacked traces + the comms report.  Worker-backed sessions
        (async / sync-over-transport) drain their pipeline tail and
        CLOSE when the stream ends — the report covers the whole
        session."""
        if self.config.mode == "scan":
            self._ensure_open()
            if not self._full_pool():
                raise RuntimeError("scan mode requires the full slot pool")
            if self._policy is not None:
                # offline trace: the policy's CURRENT per-stream
                # thresholds apply statically (no per-step feedback —
                # scan is one compiled pass)
                self._engine._thr_eff = np.asarray(
                    self._policy.step_thresholds(), np.float32)
            return self._engine._run_scan(token_stream)
        self._ensure_open()
        S = token_stream.shape[1]
        us, fhats, trigs = [], [], []
        try:
            for t in range(S):
                r = self.step(token_stream[:, t])
                us.append(r["u"]); fhats.append(r["fhat"])
                trigs.append(r["triggered"])
        finally:
            if self.config.needs_worker:
                self.close()
        return {"u": np.stack(us, 1), "fhat": np.stack(fhats, 1),
                "triggered": np.stack(trigs, 1), "streams": self.streams,
                "comms": self.report()}

    def report(self) -> Dict[str, Any]:
        """The engine's communication/overlap report (see CommsMeter)."""
        return self._engine.comms.report()

    # -- observability --------------------------------------------------------
    @property
    def tracer(self):
        """The session's span tracer (``SessionConfig(trace=True)``), or
        ``None`` when tracing is off."""
        return self._engine._tracer

    def export_trace(self, path: str) -> int:
        """Write the session's spans as Chrome trace-event / Perfetto
        JSON; returns the span count.  Requires ``trace=True``."""
        tr = self._engine._tracer
        if tr is None:
            raise RuntimeError(
                "tracing is off: open the session with "
                "SessionConfig(trace=True)")
        return tr.export(path)

    def metrics(self) -> Dict[str, Any]:
        """One flat metrics snapshot for the whole session: the engine's
        registry (wire RTT breakdown histograms as
        ``rtt_*_s_{n,mean,max,p50,p99}``), the flattened ``CommsMeter``
        report under ``comms/...`` keys, and — when tracing — the
        tracer's ring stats under ``trace/...``."""
        from repro.observability import flatten
        snap = self._engine.metrics.snapshot()
        snap.update(flatten(self._engine.comms.report(), "comms"))
        tr = self._engine._tracer
        if tr is not None:
            snap.update(flatten(tr.stats(), "trace"))
        return snap

    def arm_recompile_guard(self, *, track_global: bool = True,
                            warm_only: bool = False):
        """Arm a ``analysis.recompile.RecompileGuard`` over every jitted
        path of this session's engine and return it.  Call AFTER warmup
        (each shape signature legitimately compiles once — a ragged pool
        adds a vector-t catch-up variant); from then on, any retrace
        across churn makes ``guard.assert_stable()`` raise.  The guard
        the ROADMAP autoscaling work keys its batch buckets on.

        ``warm_only`` watches only paths the episode already compiled —
        use when the workload may leave optional paths (e.g. the
        triggered catch-up) cold through warmup."""
        from repro.analysis.recompile import RecompileGuard
        return RecompileGuard(self._engine.jitted_paths(),
                              track_global=track_global,
                              warm_only=warm_only).arm()

"""Metrics trackers for the serving stack (levanter-style composite).

The correction server used to print its lease/byte counters once, on
SIGTERM, to stderr — useless for a supervisor that needs to know *now*
which server is loaded and which is dead.  This module turns that dump
into a pluggable, composable surface:

  * ``Tracker`` — the tiny interface: ``log(metrics)`` for periodic
    snapshots, ``log_summary(metrics)`` for end-of-life totals.
  * ``JsonFileTracker`` — atomically rewrites one JSON file per call
    (tmp + ``os.replace``), so a reader never sees a torn write.  This
    file IS the fleet heartbeat channel: the supervisor scrapes it for
    ``leased_rows`` (routing load) and ``ts`` (liveness deadline).
  * ``CompositeTracker`` — fan-out to N trackers, so one server can
    heartbeat to a file AND log to stderr AND accumulate in-memory.
  * ``Histogram`` — fixed log-spaced buckets for replay latency /
    coalesce width / RTT, cheap enough to observe() on the reactor tick.

``read_stats(path)`` is the scrape side: tolerant of a missing or
half-born file (returns ``None`` rather than raising), because a
heartbeat reader must never crash on a writer mid-spawn.
"""
from __future__ import annotations

import json
import math
import os
import sys
import tempfile
import time
from collections import deque
from typing import Any, Dict, List, Optional, Sequence


def _jsonable(obj: Any) -> Any:
    """Best-effort conversion for numpy scalars/arrays inside metrics."""
    if hasattr(obj, "item") and not isinstance(obj, (str, bytes)):
        try:
            return obj.item()
        except (TypeError, ValueError):
            pass
    if hasattr(obj, "tolist"):
        return obj.tolist()
    return str(obj)


class Tracker:
    """Interface: periodic ``log`` snapshots plus a final ``log_summary``."""

    def log(self, metrics: Dict[str, Any], *, step: Optional[int] = None
            ) -> None:
        raise NotImplementedError

    def log_summary(self, metrics: Dict[str, Any]) -> None:
        # By default a summary is just a final log.
        self.log(metrics)

    def finish(self) -> None:
        pass


class NoopTracker(Tracker):
    def log(self, metrics: Dict[str, Any], *, step: Optional[int] = None
            ) -> None:
        pass


class LogTracker(Tracker):
    """Writes one ``key=value`` line per call to a stream (stderr)."""

    def __init__(self, stream=None, prefix: str = "tracker"):
        self._stream = stream if stream is not None else sys.stderr
        self._prefix = prefix

    def log(self, metrics: Dict[str, Any], *, step: Optional[int] = None
            ) -> None:
        parts = [f"{k}={metrics[k]}" for k in sorted(metrics)]
        head = self._prefix if step is None else f"{self._prefix}[{step}]"
        print(f"{head} " + " ".join(parts), file=self._stream, flush=True)


class InMemoryTracker(Tracker):
    """Keeps recent snapshots; ``latest``/``summary`` for tests and the
    supervisor's in-process (thread-backend) scrape path.

    ``max_records`` bounds the ring (oldest snapshots evicted): a
    long-running server heartbeats every ``stats_interval_s``, so an
    unbounded list was a slow leak.  ``None`` keeps everything (short
    test runs that assert on the full record stream)."""

    def __init__(self, max_records: Optional[int] = 4096):
        self._records: "deque[Dict[str, Any]]" = deque(maxlen=max_records)
        self.max_records = max_records
        self.summary: Dict[str, Any] = {}

    def log(self, metrics: Dict[str, Any], *, step: Optional[int] = None
            ) -> None:
        rec = dict(metrics)
        if step is not None:
            rec["step"] = step
        self._records.append(rec)

    def log_summary(self, metrics: Dict[str, Any]) -> None:
        self.summary = dict(metrics)

    @property
    def records(self) -> List[Dict[str, Any]]:
        """The retained snapshots, oldest first (a list copy — the ring
        itself is private so eviction can't surprise an iterator)."""
        return list(self._records)

    @property
    def latest(self) -> Optional[Dict[str, Any]]:
        return self._records[-1] if self._records else None


class JsonFileTracker(Tracker):
    """Atomic whole-file JSON heartbeat: each ``log`` replaces the file.

    The write goes to a tempfile in the same directory and lands with
    ``os.replace`` so a concurrent ``read_stats`` sees either the old
    snapshot or the new one, never a prefix of the new one.
    """

    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(path) or "."
        os.makedirs(d, exist_ok=True)

    def log(self, metrics: Dict[str, Any], *, step: Optional[int] = None
            ) -> None:
        rec = dict(metrics)
        if step is not None:
            rec["step"] = step
        rec.setdefault("ts", time.time())
        d = os.path.dirname(self.path) or "."
        fd, tmp = tempfile.mkstemp(dir=d, prefix=".stats-", suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as fh:
                json.dump(rec, fh, default=_jsonable)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def finish(self) -> None:
        try:
            os.unlink(self.path)
        except OSError:
            pass


class CompositeTracker(Tracker):
    """Fan-out: every call goes to every child, in order."""

    def __init__(self, trackers: Sequence[Tracker] = ()):
        self.trackers = list(trackers)

    def add(self, tracker: Tracker) -> None:
        self.trackers.append(tracker)

    def log(self, metrics: Dict[str, Any], *, step: Optional[int] = None
            ) -> None:
        for t in self.trackers:
            t.log(metrics, step=step)

    def log_summary(self, metrics: Dict[str, Any]) -> None:
        for t in self.trackers:
            t.log_summary(metrics)

    def finish(self) -> None:
        for t in self.trackers:
            t.finish()


class Histogram:
    """Fixed log-spaced buckets over ``[lo, hi]``; O(log n) observe.

    Summaries expose count/mean/max plus approximate p50/p99 from the
    bucket midpoints — enough resolution for replay-latency and
    coalesce-width dashboards without keeping raw samples.

    Edge-case contract (unit-tested): a quantile of an EMPTY histogram
    is ``None`` (there is no defined percentile — 0.0 would read as "we
    measured and it was instant"), and with exactly ONE observation
    every quantile is that observation (a bucket midpoint could sit a
    factor away from the sample).  With >= 2 observations quantiles are
    bucket-geomean estimates clamped into ``[vmin, vmax]``.
    """

    def __init__(self, lo: float, hi: float, n_buckets: int = 24):
        assert 0 < lo < hi and n_buckets >= 2
        step = (math.log(hi) - math.log(lo)) / (n_buckets - 1)
        self.edges = [math.exp(math.log(lo) + i * step)
                      for i in range(n_buckets)]
        self.counts = [0] * (n_buckets + 1)
        self.total = 0.0
        self.n = 0
        self.vmax = 0.0
        self.vmin = math.inf

    def observe(self, x: float) -> None:
        self.n += 1
        self.total += x
        if x > self.vmax:
            self.vmax = x
        if x < self.vmin:
            self.vmin = x
        import bisect
        self.counts[bisect.bisect_left(self.edges, x)] += 1

    def _quantile(self, q: float) -> Optional[float]:
        if self.n == 0:
            return None
        if self.n == 1:
            return self.vmax  # the single observation, exactly
        target = q * self.n
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= target:
                if i == 0:
                    est = self.edges[0]
                elif i >= len(self.edges):
                    est = self.vmax
                else:
                    est = math.sqrt(self.edges[i - 1] * self.edges[i])
                return min(max(est, self.vmin), self.vmax)
        return self.vmax

    def summary(self) -> Dict[str, Optional[float]]:
        mean = self.total / self.n if self.n else 0.0
        return {"n": self.n, "mean": mean, "max": self.vmax,
                "p50": self._quantile(0.5), "p99": self._quantile(0.99)}


def read_stats(path: str) -> Optional[Dict[str, Any]]:
    """Scrape one ``JsonFileTracker`` heartbeat; ``None`` if unreadable.

    Missing file, torn content, or a decode error all mean "no fresh
    heartbeat" to the caller — the supervisor's deadline logic handles
    staleness, this function only has to never raise.
    """
    try:
        with open(path, "r") as fh:
            return json.load(fh)
    except (OSError, ValueError):
        return None

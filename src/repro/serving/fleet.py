"""Correction-server fleet: supervisor, least-loaded routing, failover.

One ``CorrectionServer`` reactor (serving/server.py) is both a machine
ceiling and a single point of failure.  ``FleetSupervisor`` runs N of
them and fronts them with a ROUTER — a tiny endpoint speaking only the
HELLO half of the wire protocol: a client HELLOs the router, the router
answers ``REDIRECT <address>`` naming the least-loaded LIVE server, and
the client re-HELLOs there (``SocketWorker`` does this automatically for
``fleet:<router>`` addresses; one extra round trip per session, zero
per-token overhead — requests never proxy through the router).

Lifecycle (the xinference ``WorkerActor`` launch/terminate/recover
shape, adapted to processes):

  * **launch** — each server is spawned via
    ``launch.server.spawn_subprocess`` (or run on a thread for
    in-process tests) with a ``JsonFileTracker`` heartbeat: an
    atomically-rewritten JSON stats file (serving/tracker.py) carrying
    ``leased_rows`` (the routing load signal), ``sessions_live``,
    ``draining``, counters and latency histograms.
  * **health** — a server is LIVE while its process is running and its
    heartbeat is fresher than ``heartbeat_timeout_s``.  A dead process
    or a stale heartbeat marks it dead; ``respawn=True`` launches a
    replacement (recover_sub_pool).
  * **drain** — ``drain(name)`` sends SIGUSR1: the server GOAWAYs its
    sessions, refuses new HELLOs, and exits once empty.  Clients finish
    in-flight work, then migrate through the router.  Zero streams drop.
  * **failover is a replay, not a state transfer** — the wire protocol
    makes each client the source of truth for its own token history, so
    the supervisor never copies caches between servers: the client
    re-HELLOs and replays (see ``SocketWorker`` in async_rpc.py and
    docs/fleet.md for the bitwise argument).

The supervisor is single-threaded and non-blocking: ``tick()`` services
router I/O, scrapes heartbeats, reaps/respawns — call it from your own
loop or use ``run_forever``.
"""
from __future__ import annotations

import os
import selectors
import signal
import socket
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.serving import wire
from repro.serving.tracker import Tracker, read_stats

# redirects handed out against a heartbeat that predates them still count
# as load for this long (the optimistic-pending window — prevents a
# thundering herd onto one server between two heartbeats)
PENDING_TTL_S = 2.0


def resolve_route(router_address: str, hello: wire.Hello, *,
                  timeout: float = 10.0) -> str:
    """Ask a fleet router where a session shaped like ``hello`` should
    go; returns the server address from the REDIRECT.  Raises
    ``HandshakeRefused`` when the router answers ERROR (no live server
    fits) and ``PeerGone`` when the router itself is unreachable."""
    deadline = time.monotonic() + timeout
    try:
        sock = wire.connect(router_address, timeout=timeout)
    except OSError as e:
        raise wire.PeerGone(f"router {router_address!r}: {e}") from e
    reader = wire.FrameReader()
    try:
        sock.settimeout(max(0.1, deadline - time.monotonic()))
        sock.sendall(wire.encode_hello(hello))
        while True:
            data = sock.recv(1 << 16)
            if not data:
                raise wire.PeerGone("router closed during resolve")
            for p in reader.feed(data):
                msg = wire.decode(p)
                if isinstance(msg, wire.Redirect):
                    return msg.address
                if isinstance(msg, wire.Error):
                    raise wire.HandshakeRefused(msg.message)
                raise wire.WireError(f"unexpected router reply: {msg}")
    finally:
        sock.close()


class ServerHandle:
    """One managed correction server: identity, health, load, control."""

    def __init__(self, name: str):
        self.name = name
        self.address: Optional[str] = None
        self.state = "starting"   # starting | live | draining | dead | stopped
        self.reaped = False       # supervisor already acted on death/retire
        self.stats: Dict[str, Any] = {}
        self.last_seen = 0.0      # wall-clock ts of the freshest heartbeat
        # (issue_ts, rows) of redirects not yet visible in a heartbeat
        self.pending: List[Tuple[float, int]] = []

    # -- backend contract ----------------------------------------------------
    def alive(self) -> bool:
        raise NotImplementedError

    def scrape(self) -> Optional[Dict[str, Any]]:
        raise NotImplementedError

    def drain(self) -> None:
        raise NotImplementedError

    def kill(self) -> None:
        raise NotImplementedError

    def close(self) -> None:
        raise NotImplementedError

    # -- shared logic --------------------------------------------------------
    def refresh(self, heartbeat_timeout_s: float) -> None:
        """Scrape + update state.  ``starting -> live`` on first
        heartbeat; ``live/draining -> dead`` on process death or a stale
        heartbeat; a draining server that exits cleanly is ``stopped``."""
        rec = self.scrape()
        now = time.time()
        if rec is not None:
            self.stats = rec
            self.last_seen = float(rec.get("ts", now))
            if self.address is None:
                self.address = rec.get("address")
            if bool(rec.get("draining")) and self.state == "live":
                self.state = "draining"
            elif self.state == "starting":
                self.state = "live"
            self.pending = [(ts, n) for ts, n in self.pending
                            if ts > self.last_seen]
        if self.state in ("dead", "stopped"):
            return
        if not self.alive():
            # a draining server exiting on its own is a clean retire
            self.state = "stopped" if self.state == "draining" else "dead"
            return
        if (self.state in ("live", "draining")
                and now - self.last_seen > heartbeat_timeout_s):
            self.state = "dead"

    def load(self) -> int:
        """Leased rows per the last heartbeat plus redirects issued since
        (optimistically counted for PENDING_TTL_S)."""
        now = time.time()
        self.pending = [(ts, n) for ts, n in self.pending
                        if now - ts < PENDING_TTL_S]
        return int(self.stats.get("leased_rows", 0)) \
            + sum(n for _, n in self.pending)

    def free_rows(self) -> int:
        slots = int(self.stats.get("slots", 0))
        return max(0, slots - self.load())


class SubprocessServer(ServerHandle):
    """A ``launch.server`` subprocess on a UDS, heartbeating via a
    ``JsonFileTracker`` stats file the supervisor scrapes."""

    def __init__(self, name: str, *, arch: str, slots: int, max_len: int,
                 root: str, ckpt_dir: Optional[str] = None,
                 stats_interval_s: float = 0.25,
                 extra_args: Tuple[str, ...] = ()):
        super().__init__(name)
        from repro.launch.server import spawn_subprocess
        self.uds = os.path.join(root, f"{name}.sock")
        self.ready_file = os.path.join(root, f"{name}.ready")
        self.stats_file = os.path.join(root, f"{name}.stats.json")
        self.address = self.uds
        self.proc = spawn_subprocess(
            arch, uds=self.uds, slots=slots, max_len=max_len,
            ready_file=self.ready_file, ckpt_dir=ckpt_dir, wait=False,
            extra_args=("--stats-file", self.stats_file,
                        "--stats-interval-s", str(stats_interval_s))
            + tuple(extra_args))

    def wait_ready(self, timeout_s: float) -> None:
        from repro.launch.server import wait_ready
        wait_ready(self.proc, self.ready_file, timeout_s)

    def alive(self) -> bool:
        return self.proc.poll() is None

    def scrape(self) -> Optional[Dict[str, Any]]:
        return read_stats(self.stats_file)

    def drain(self) -> None:
        if self.alive():
            self.proc.send_signal(signal.SIGUSR1)
        if self.state == "live":
            self.state = "draining"

    def kill(self) -> None:
        """SIGKILL — the fault-injection primitive: no GOAWAY, no BYE,
        no flush; clients see a raw EOF/reset mid-whatever."""
        try:
            self.proc.kill()
        except OSError:
            pass
        self.state = "dead"

    def close(self) -> None:
        if self.alive():
            self.proc.terminate()
            try:
                self.proc.wait(timeout=10)
            except Exception:
                self.proc.kill()
                self.proc.wait()
        for f in (self.uds, self.ready_file, self.stats_file):
            try:
                os.unlink(f)
            except OSError:
                pass


class ThreadServer(ServerHandle):
    """An in-process ``CorrectionServer`` on a daemon thread — the fast
    backend for the chaos tests (no jax re-import per server; a "kill"
    severs every socket without ceremony, which is exactly what a
    SIGKILL looks like from the client's side of the wire)."""

    def __init__(self, name: str, *, cfg, params, slots: int, max_len: int,
                 root: str, coalesce: bool = True, shm: bool = False):
        super().__init__(name)
        from repro.serving.server import CorrectionServer
        self.uds = os.path.join(root, f"{name}.sock")
        self.srv = CorrectionServer(cfg, params, slots=slots,
                                    max_len=max_len, uds=self.uds,
                                    coalesce=coalesce, shm=shm)
        self.address = self.srv.address
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self.srv.serve_forever, kwargs={"stop": self._stop},
            daemon=True, name=f"fleet-{name}")
        self._thread.start()

    def wait_ready(self, timeout_s: float) -> None:
        pass  # the listener was bound synchronously in __init__

    def alive(self) -> bool:
        return self._thread.is_alive()

    def scrape(self) -> Optional[Dict[str, Any]]:
        try:
            return self.srv.stats_snapshot()
        except Exception:
            return None  # racing a concurrent close: treat as no beat

    def drain(self) -> None:
        self.srv.request_drain()
        if self.state == "live":
            self.state = "draining"

    def kill(self) -> None:
        """Crash emulation: unlink the listener path (new connects fail
        fast), sever every client socket without BYE/GOAWAY, stop the
        reactor.  From the wire, indistinguishable from SIGKILL."""
        try:
            os.unlink(self.uds)
        except OSError:
            pass
        self._stop.set()
        for conn in list(self.srv._sessions):
            try:
                conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
        self.state = "dead"

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=10)
        self.srv.close()


class FleetSupervisor:
    """Spawn/monitor N correction servers; route HELLOs; reap the dead.

    backend          — ``"subprocess"`` (production shape: one
                       ``launch.server`` process per server, heartbeat
                       via stats files) or ``"thread"`` (in-process, for
                       tests; needs ``cfg`` + ``params``).
    router_uds/port  — where the routing endpoint listens (UDS default:
                       ``<root>/router.sock``).
    heartbeat_timeout_s — a live server whose heartbeat is staler than
                       this is declared dead (covers hung processes; a
                       SIGKILL is caught faster via process liveness).
    respawn          — replace dead servers with fresh ones (xinference's
                       ``recover_sub_pool``); drained servers are
                       retired, never replaced.
    address_wrapper  — optional hook mapping a server address before it
                       is advertised in a REDIRECT (the chaos harness
                       interposes its proxy here).
    """

    def __init__(self, arch: Optional[str] = None, *, n_servers: int = 2,
                 slots: int = 16, max_len: int = 128,
                 backend: str = "subprocess", root: Optional[str] = None,
                 router_uds: Optional[str] = None,
                 router_host: str = "127.0.0.1",
                 router_port: Optional[int] = None,
                 heartbeat_timeout_s: float = 5.0, respawn: bool = True,
                 tracker: Optional[Tracker] = None,
                 cfg=None, params=None, ckpt_dir: Optional[str] = None,
                 coalesce: bool = True, shm: bool = False,
                 stats_interval_s: float = 0.25,
                 spawn_timeout_s: Optional[float] = None,
                 address_wrapper: Optional[Callable[[str], str]] = None):
        if backend not in ("subprocess", "thread"):
            raise ValueError(f"unknown fleet backend {backend!r}")
        if backend == "subprocess" and arch is None:
            raise ValueError("subprocess backend needs arch=")
        if backend == "thread" and (cfg is None or params is None):
            raise ValueError("thread backend needs cfg= and params=")
        self.arch, self.cfg, self.params = arch, cfg, params
        self.backend = backend
        self.n_servers, self.slots, self.max_len = n_servers, slots, max_len
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.respawn = respawn
        self.tracker = tracker
        self.ckpt_dir, self.coalesce = ckpt_dir, coalesce
        self.shm = shm   # servers offer same-host shm arenas on HELLO
        self.stats_interval_s = stats_interval_s
        if spawn_timeout_s is None:
            spawn_timeout_s = float(
                os.environ.get("REPRO_SPAWN_DEADLINE_S", "240"))
        self.spawn_timeout_s = spawn_timeout_s
        self.address_wrapper = address_wrapper
        if root is None:
            import tempfile
            root = tempfile.mkdtemp(prefix="fleet-")
        os.makedirs(root, exist_ok=True)
        self.root = root
        self.servers: Dict[str, ServerHandle] = {}
        self._seq = 0
        self.stats = {"routed": 0, "refused": 0, "respawns": 0,
                      "reaped": 0, "retired": 0}

        # -- router listener --------------------------------------------------
        if router_port is not None:
            self._listener = socket.socket(socket.AF_INET,
                                           socket.SOCK_STREAM)
            self._listener.setsockopt(socket.SOL_SOCKET,
                                      socket.SO_REUSEADDR, 1)
            self._listener.bind((router_host, router_port))
            h, p = self._listener.getsockname()
            self.router_address = f"{h}:{p}"
            self.router_uds = None
        else:
            self.router_uds = router_uds or os.path.join(root, "router.sock")
            self._listener = socket.socket(socket.AF_UNIX,
                                           socket.SOCK_STREAM)
            self._listener.bind(self.router_uds)
            self.router_address = self.router_uds
        self._listener.listen(64)
        self._listener.setblocking(False)
        self._sel = selectors.DefaultSelector()
        self._sel.register(self._listener, selectors.EVENT_READ, "accept")
        self._conns: Dict[socket.socket, wire.FrameReader] = {}
        self._closed = False

    # -- launch --------------------------------------------------------------
    def _spawn(self) -> ServerHandle:
        name = f"srv-{self._seq}"
        self._seq += 1
        if self.backend == "subprocess":
            extra = () if self.coalesce else ("--no-coalesce",)
            if self.shm:
                extra += ("--transport", "shm")
            h: ServerHandle = SubprocessServer(
                name, arch=self.arch, slots=self.slots,
                max_len=self.max_len, root=self.root,
                ckpt_dir=self.ckpt_dir,
                stats_interval_s=self.stats_interval_s,
                extra_args=extra)
        else:
            h = ThreadServer(name, cfg=self.cfg, params=self.params,
                             slots=self.slots, max_len=self.max_len,
                             root=self.root, coalesce=self.coalesce,
                             shm=self.shm)
        self.servers[name] = h
        return h

    def start(self, wait: bool = True) -> "FleetSupervisor":
        """Launch all N servers (spawned first, THEN ready-waited, so the
        jax imports overlap instead of serializing)."""
        fresh = [self._spawn() for _ in range(self.n_servers)]
        if wait:
            for h in fresh:
                h.wait_ready(self.spawn_timeout_s)
        return self

    # -- routing -------------------------------------------------------------
    def live_servers(self) -> List[ServerHandle]:
        return [h for h in self.servers.values() if h.state == "live"]

    def pick(self, batch: int) -> Optional[ServerHandle]:
        """Least-loaded LIVE server with room for ``batch`` rows."""
        fits = [h for h in self.live_servers() if h.free_rows() >= batch]
        if not fits:
            return None
        return min(fits, key=lambda h: (h.load(), h.name))

    def _route(self, conn: socket.socket, hello: wire.Hello) -> None:
        h = self.pick(hello.batch)
        if h is None or h.address is None:
            self.stats["refused"] += 1
            free = {x.name: x.free_rows() for x in self.live_servers()}
            conn.sendall(wire.encode_error(
                f"no live server with {hello.batch} free rows "
                f"(live free: {free})"))
            return
        h.pending.append((time.time(), hello.batch))
        self.stats["routed"] += 1
        addr = h.address
        if self.address_wrapper is not None:
            addr = self.address_wrapper(addr)
        conn.sendall(wire.encode_redirect(addr))

    def _router_io(self, timeout: float) -> None:
        for key, _ in self._sel.select(timeout):
            if key.data == "accept":
                while True:
                    try:
                        conn, _a = self._listener.accept()
                    except (BlockingIOError, InterruptedError, OSError):
                        break
                    conn.setblocking(False)
                    self._conns[conn] = wire.FrameReader()
                    self._sel.register(conn, selectors.EVENT_READ, "conn")
                continue
            conn = key.fileobj
            reader = self._conns.get(conn)
            if reader is None:
                continue
            try:
                data = conn.recv(1 << 16)
            except (BlockingIOError, InterruptedError):
                continue
            except OSError:
                data = b""
            done = not data
            if data:
                try:
                    for p in reader.feed(data):
                        msg = wire.decode(p)
                        if isinstance(msg, wire.Hello):
                            self._route(conn, msg)
                        else:
                            conn.sendall(wire.encode_error(
                                "router speaks HELLO only"))
                        done = True
                        break
                except (wire.WireError, OSError):
                    done = True
            if done:
                self._drop_conn(conn)

    def _drop_conn(self, conn: socket.socket) -> None:
        try:
            self._sel.unregister(conn)
        except (KeyError, ValueError):
            pass
        self._conns.pop(conn, None)
        try:
            conn.close()
        except OSError:
            pass

    # -- health / lifecycle --------------------------------------------------
    def _reap(self) -> None:
        # the flag, not a state TRANSITION, gates the reaction: kill()
        # sets state="dead" directly, so a transition-based check would
        # never respawn an explicitly killed server
        for name, h in list(self.servers.items()):
            h.refresh(self.heartbeat_timeout_s)
            if h.state == "dead" and not h.reaped:
                h.reaped = True
                h.kill()  # ensure a stale-heartbeat zombie really dies
                self.stats["reaped"] += 1
                if self.respawn:
                    self.stats["respawns"] += 1
                    self._spawn()  # ready-waits lazily via heartbeat
            elif h.state == "stopped" and not h.reaped:
                h.reaped = True
                self.stats["retired"] += 1

    def tick(self, timeout: float = 0.05) -> None:
        """One supervisor beat: router I/O, heartbeat scrape, reaping."""
        self._router_io(timeout)
        self._reap()
        if self.tracker is not None:
            self.tracker.log(self.aggregate())

    def run_forever(self, stop: Optional[threading.Event] = None,
                    poll_s: float = 0.05) -> None:
        while stop is None or not stop.is_set():
            self.tick(poll_s)

    # -- control -------------------------------------------------------------
    def drain(self, name: str) -> None:
        self.servers[name].drain()

    def kill(self, name: str) -> None:
        self.servers[name].kill()

    def aggregate(self) -> Dict[str, Any]:
        """The fleet-wide scrape: per-server heartbeats + totals."""
        per = {n: dict(h.stats, state=h.state, address=h.address)
               for n, h in self.servers.items()}
        totals: Dict[str, float] = dict(self.stats)
        for h in self.servers.values():
            for k in ("requests", "replays", "coalesced", "sessions",
                      "bytes_rx", "bytes_tx", "leased_rows"):
                if k in h.stats and h.state in ("live", "draining"):
                    totals[k] = totals.get(k, 0) + h.stats[k]
        # latency percentiles aggregate as max over live servers (the
        # fleet-wide worst case — summing percentiles is meaningless);
        # None while no server has observed that histogram yet
        for k in ("replay_s_p50", "replay_s_p99",
                  "queue_wait_s_p50", "queue_wait_s_p99",
                  "turnaround_s_p99"):
            vals = [h.stats[k] for h in self.servers.values()
                    if h.state in ("live", "draining")
                    and h.stats.get(k) is not None]
            totals[k] = max(vals) if vals else None
        totals["n_live"] = len(self.live_servers())
        return {"ts": time.time(), "servers": per, "totals": totals}

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for conn in list(self._conns):
            self._drop_conn(conn)
        try:
            self._sel.unregister(self._listener)
        except (KeyError, ValueError):
            pass
        self._listener.close()
        self._sel.close()
        if self.router_uds is not None:
            try:
                os.unlink(self.router_uds)
            except OSError:
                pass
        for h in self.servers.values():
            h.close()
        if self.tracker is not None:
            self.tracker.log_summary(self.aggregate())
            self.tracker.finish()

    def __enter__(self) -> "FleetSupervisor":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

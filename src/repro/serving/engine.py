"""Serving engine: batched autoregressive decode over the uniform backbone
API, with greedy/temperature sampling.  Prefill is cache-building: prompt
tokens are scanned through ``decode_step`` (shape-static, jit-once).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import api as model_api


class ServeEngine:
    """Holds params + cache for one batched decode session."""

    def __init__(self, params, cfg: ArchConfig, batch: int, max_len: int,
                 rng: Optional[jax.Array] = None):
        self.params, self.cfg = params, cfg
        self.batch, self.max_len = batch, max_len
        self.cache = model_api.init_cache(cfg, batch, max_len)
        self.pos = 0
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        self._step = jax.jit(self._step_impl)
        self._prefill = jax.jit(self._prefill_impl)

    # -- jitted kernels ----------------------------------------------------
    def _step_impl(self, params, cache, tokens, pos):
        return model_api.decode_step(params, self.cfg, cache, tokens, pos)

    def _prefill_impl(self, params, cache, tokens, pos0):
        """tokens: (B, S0) (or (B,S0,K) audio); scans decode_step over S0."""
        time_axis = 1

        def body(carry, tok_t):
            cache, pos = carry
            logits, hidden, cache = model_api.decode_step(
                params, self.cfg, cache, tok_t, pos)
            return (cache, pos + 1), (logits, hidden)

        toks = jnp.moveaxis(tokens, time_axis, 0)
        (cache, pos), (logits, hidden) = jax.lax.scan(body, (cache, pos0), toks)
        return cache, pos, logits[-1], jnp.moveaxis(hidden, 0, 1)

    # -- public API ----------------------------------------------------------
    def prefill(self, tokens: jnp.ndarray) -> jnp.ndarray:
        """Feed the prompt; returns last-position logits."""
        self.cache, pos, logits, _ = self._prefill(
            self.params, self.cache, tokens, jnp.asarray(self.pos, jnp.int32))
        self.pos = int(pos)
        return logits

    def decode(self, tokens_t: jnp.ndarray
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """One step; returns (logits, hidden)."""
        logits, hidden, self.cache = self._step(
            self.params, self.cache, tokens_t, jnp.asarray(self.pos, jnp.int32))
        self.pos += 1
        return logits, hidden

    def sample(self, logits: jnp.ndarray, temperature: float = 0.0) -> jnp.ndarray:
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.rng, k = jax.random.split(self.rng)
        return jax.random.categorical(k, logits / temperature, axis=-1).astype(jnp.int32)

    def generate(self, prompt: jnp.ndarray, n_new: int,
                 temperature: float = 0.0) -> jnp.ndarray:
        """prompt: (B, S0[,K]) -> generated ids (B, n_new[,K])."""
        logits = self.prefill(prompt)
        outs = []
        tok = self.sample(logits, temperature)
        for _ in range(n_new):
            outs.append(tok)
            logits, _ = self.decode(tok)
            tok = self.sample(logits, temperature)
        return jnp.stack(outs, axis=1)

"""Serving engine: batched autoregressive decode over the uniform backbone
API, with greedy/temperature sampling.  Prefill is cache-building: prompt
tokens are scanned through ``decode_step`` (shape-static, jit-once).

Besides the uniform-position ``decode``, the engine exposes a PER-ELEMENT
decode (``decode_at`` / ``step_at_fn``): every batch element carries its own
cache position and an active mask, so independent streams at heterogeneous
depths advance in one SPMD call (inactive elements' cache rows are left
bit-untouched).  This is the primitive the collaborative serving protocol
uses for per-stream server catch-up.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import api as model_api


def cache_batch_axes(cfg: ArchConfig, batch: int, max_len: int):
    """Pytree of ints: the batch axis of every cache leaf (structural
    discovery — no family-specific layout knowledge).

    Cache layouts differ per family: transformers stack (layers, B, S,
    heads, d); hybrids stack doubly (super-blocks x inner layers) and mix
    KV pages with SSM state rows; xLSTM carries (layers, B, heads, d, d)
    matrix memories with no sequence axis at all.  Rather than teach this
    module every layout, the batch axis is found structurally: build the
    cache tree twice under ``jax.eval_shape`` (abstract — no allocation) at
    batch sizes ``batch`` and ``batch+1``, and for each leaf take the FIRST
    axis whose extent differs.  Probing with a delta of exactly 1 makes the
    discovery unambiguous even when a leaf's other axes happen to equal the
    batch size (e.g. batch == n_heads): those axes don't grow.

    A leaf with no differing axis (per-layer scalars broadcast over the
    batch) raises — such a leaf cannot be vmapped per-element and would
    silently break the masked-decode contract below.

    The result is consumed as the ``in_axes``/``out_axes`` tree for the
    per-element vmap in ``make_step_at`` and as the axis map for its
    masked cache merge.
    """
    a = jax.eval_shape(lambda: model_api.init_cache(cfg, batch, max_len))
    b = jax.eval_shape(lambda: model_api.init_cache(cfg, batch + 1, max_len))

    def find(x, y):
        for i, (p, q) in enumerate(zip(x.shape, y.shape)):
            if p != q:
                return i
        raise ValueError(f"no batch axis in cache leaf {x.shape}")

    return jax.tree.map(find, a, b)


def zero_cache_rows(cache, axes, rows: jnp.ndarray, *, shardings=None):
    """Zero the selected batch rows of every cache leaf.

    ``rows``: (B,) bool mask along each leaf's discovered batch axis
    (``cache_batch_axes``).  Used when a slot is re-leased to a new
    stream (correction-server session turnover, ``MonitorSession``
    attach): the new tenant must see bit-cold cache rows, exactly as if
    the cache had just been built, while co-resident rows stay
    bit-untouched.

    ``shardings``: a NamedSharding tree matching ``cache`` — SPEC-AWARE
    reset for mesh-sharded caches (``serving/mesh.py``).  The select is
    elementwise, so each device only ever rewrites its own rows; the
    explicit re-placement pins the result to the input shardings so a
    reset can never silently gather a super-batch cache onto one device
    (the eager-mode default when sharding propagation loses the
    committed placement).  Asserted in tests/test_mesh.py.
    """
    rows = jnp.asarray(rows, bool)

    def z(a, ax):
        shape = [1] * a.ndim
        shape[ax] = rows.shape[0]
        return jnp.where(jnp.reshape(rows, shape), jnp.zeros((), a.dtype), a)

    out = jax.tree.map(z, cache, axes)
    if shardings is not None:
        out = jax.tree.map(jax.device_put, out, shardings)
    return out


def make_step_at(cfg: ArchConfig, axes, *, with_logits: bool = True):
    """Pure per-element decode step with vector positions and active mask.

    Returns ``step_at(params, cache, tokens_t, pos, active)`` where
    tokens_t: (B,[K]), pos: (B,) int32 per-element positions, active: (B,)
    bool; ``axes`` is the ``cache_batch_axes`` tree.  This is the primitive
    the collaborative protocol builds on: independent streams at
    heterogeneous cache depths advance in ONE shape-static SPMD call.

    Masking contract (load-bearing — tests assert it bitwise):

    * every element is DECODED (dense, discarded compute — the standard
      SPMD masked-semantics trick; there is no data-dependent shape, so
      the function is jit/scan/fori_loop-safe and compiles once);
    * elements with ``active[i] == False`` have their cache rows returned
      **bit-unchanged** — not recomputed-and-equal but the original values,
      selected leaf-wise by ``jnp.where`` along each leaf's batch axis.
      A masked-out stream's attention reductions in later steps are
      therefore exactly those of a stream that never decoded;
    * ``hidden[i]`` for inactive elements is garbage (whatever the dense
      decode produced) — callers must gate on ``active`` before use, as
      the collaborative catch-up loop does;
    * ``pos`` is NOT validated here: callers clip to [0, max_len) (inactive
      lanes may carry clipped dummy positions, see
      ``collaborative.CollaborativeEngine._catchup_impl``).

    Mechanically each element is decoded at singleton batch via ``vmap``
    over the cache's discovered batch axes: the vmapped body re-inserts a
    size-1 batch axis so ``model_api.decode_step`` sees its native layout,
    then squeezes it back out.  ``with_logits=False`` skips the unembed
    projection (monitoring-only decode — the protocol consumes hidden
    scores, not next-token logits).
    """

    def step_at(params, cache, tokens_t, pos, active):
        def one(cache_elem, tok, p):
            # cache_elem: leaves with the batch axis REMOVED (vmap);
            # reinsert a singleton batch so decode_step sees its layout.
            cache1 = jax.tree.map(jnp.expand_dims, cache_elem, axes)
            logits, hidden, ncache = model_api.decode_step(
                params, cfg, cache1, tok[None], p, with_logits=with_logits)
            return (logits[0] if with_logits else None), hidden[0], \
                jax.tree.map(jnp.squeeze, ncache, axes)

        vm = jax.vmap(one, in_axes=(axes, 0, 0), out_axes=(0, 0, axes))
        logits, hidden, new_cache = vm(cache, tokens_t,
                                       jnp.asarray(pos, jnp.int32))

        def merge(new, old, ax):
            B = active.shape[0]
            shape = [1] * new.ndim
            shape[ax] = B
            return jnp.where(jnp.reshape(active, shape), new, old)

        cache = jax.tree.map(merge, new_cache, cache, axes)
        return logits, hidden, cache

    return step_at


class ServeEngine:
    """Holds params + cache for one batched decode session."""

    def __init__(self, params, cfg: ArchConfig, batch: int, max_len: int,
                 rng: Optional[jax.Array] = None):
        self.params, self.cfg = params, cfg
        self.batch, self.max_len = batch, max_len
        self.cache = model_api.init_cache(cfg, batch, max_len)
        self.pos = 0
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        self._step = jax.jit(self._step_impl)
        self._step_masked = jax.jit(self._step_masked_impl)
        self._prefill = jax.jit(self._prefill_impl)
        self._step_at = {}  # built lazily (per-element decode), per variant
        self._axes = None   # cache_batch_axes, built lazily
        # NamedSharding tree for the cache when the engine is mesh-sharded
        # (set by serving.mesh.shard_engine); row resets preserve it
        self._cache_shardings = None

    @property
    def axes(self):
        """Batch-axis tree of the cache leaves (``cache_batch_axes``)."""
        if self._axes is None:
            self._axes = cache_batch_axes(self.cfg, self.batch, self.max_len)
        return self._axes

    def jitted_paths(self):
        """Name -> jit wrapper for every jitted path this engine drives —
        the watch list for ``analysis.recompile.RecompileGuard`` (each
        must compile exactly once per shape signature)."""
        paths = {"step": self._step, "step_masked": self._step_masked,
                 "prefill": self._prefill}
        for variant, fn in self._step_at.items():
            paths[f"step_at[with_logits={variant}]"] = fn
        return paths

    # -- jitted kernels ----------------------------------------------------
    def _step_impl(self, params, cache, tokens, pos):
        return model_api.decode_step(params, self.cfg, cache, tokens, pos)

    def _step_masked_impl(self, params, cache, tokens, pos, mask):
        """Dense decode at one scalar position with a batch mask: every
        element is decoded (discarded compute), but elements with
        ``mask[i] == False`` get their cache rows back bit-unchanged.

        Unlike ``make_step_at`` (vmapped singleton decode, which rounds
        differently from the batched matmul), this is the SAME dense
        ``decode_step`` subgraph with a leafwise select epilogue — masked
        rows are bitwise identical to the plain batched ``decode``
        (asserted in tests).  It is the cohort primitive the
        ``MonitorSession`` slot pool uses: streams admitted at different
        times share one engine by decoding each same-position cohort in
        one dense masked call.
        """
        logits, hidden, new_cache = model_api.decode_step(
            params, self.cfg, cache, tokens, pos)

        def merge(new, old, ax):
            shape = [1] * new.ndim
            shape[ax] = mask.shape[0]
            return jnp.where(jnp.reshape(mask, shape), new, old)

        cache = jax.tree.map(merge, new_cache, cache, self.axes)
        return logits, hidden, cache

    def _prefill_impl(self, params, cache, tokens, pos0):
        """tokens: (B, S0) (or (B,S0,K) audio); scans decode_step over S0."""
        time_axis = 1

        def body(carry, tok_t):
            cache, pos = carry
            logits, hidden, cache = model_api.decode_step(
                params, self.cfg, cache, tok_t, pos)
            return (cache, pos + 1), (logits, hidden)

        toks = jnp.moveaxis(tokens, time_axis, 0)
        (cache, pos), (logits, hidden) = jax.lax.scan(body, (cache, pos0), toks)
        return cache, pos, logits[-1], jnp.moveaxis(hidden, 0, 1)

    # -- public API ----------------------------------------------------------
    def prefill(self, tokens: jnp.ndarray) -> jnp.ndarray:
        """Feed the prompt; returns last-position logits."""
        self.cache, pos, logits, _ = self._prefill(
            self.params, self.cache, tokens, jnp.asarray(self.pos, jnp.int32))
        self.pos = int(pos)
        return logits

    def decode(self, tokens_t: jnp.ndarray
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """One step; returns (logits, hidden)."""
        logits, hidden, self.cache = self._step(
            self.params, self.cache, tokens_t, jnp.asarray(self.pos, jnp.int32))
        self.pos += 1
        return logits, hidden

    def decode_masked(self, tokens_t: jnp.ndarray, pos: int,
                      mask: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """One dense decode at scalar ``pos`` where only ``mask`` rows
        commit their cache writes (masked-out rows bit-untouched; their
        logits/hidden are garbage — callers gate on ``mask``).  The
        engine's scalar ``self.pos`` is NOT advanced: cohort callers
        (``MonitorSession``) track per-slot positions themselves."""
        logits, hidden, self.cache = self._step_masked(
            self.params, self.cache, tokens_t, jnp.asarray(pos, jnp.int32),
            jnp.asarray(mask, bool))
        return logits, hidden

    def zero_rows(self, rows) -> None:
        """Reset the selected batch rows of the cache to bit-cold zeros
        (``rows``: (B,) bool).  Slot-pool hygiene: a re-leased slot must
        start exactly as a fresh engine would.  On a mesh-sharded engine
        the reset preserves the cache placement (spec-aware)."""
        self.cache = zero_cache_rows(self.cache, self.axes,
                                     jnp.asarray(rows, bool),
                                     shardings=self._cache_shardings)

    def get_step_at(self, with_logits: bool = True) -> Callable:
        """Pure per-element decode fn (params, cache, tokens, pos(B,),
        active(B,)) -> (logits, hidden, cache); see ``make_step_at``.
        Exposed so callers (collaborative catch-up) can embed it in their
        own jitted loops."""
        if with_logits not in self._step_at:
            self._step_at[with_logits] = jax.jit(make_step_at(
                self.cfg, cache_batch_axes(self.cfg, self.batch, self.max_len),
                with_logits=with_logits))
        return self._step_at[with_logits]

    @property
    def step_at_fn(self) -> Callable:
        return self.get_step_at(True)

    def decode_at(self, tokens_t: jnp.ndarray, pos: jnp.ndarray,
                  active: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Per-element decode step: element i writes/reads its cache at
        pos[i]; elements with active[i]=False are untouched.  The engine's
        scalar ``self.pos`` is NOT advanced — per-element positions are the
        caller's to track."""
        logits, hidden, self.cache = self.step_at_fn(
            self.params, self.cache, tokens_t, pos, active)
        return logits, hidden

    def sample(self, logits: jnp.ndarray, temperature: float = 0.0) -> jnp.ndarray:
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.rng, k = jax.random.split(self.rng)
        return jax.random.categorical(k, logits / temperature, axis=-1).astype(jnp.int32)

    def generate(self, prompt: jnp.ndarray, n_new: int,
                 temperature: float = 0.0) -> jnp.ndarray:
        """prompt: (B, S0[,K]) -> generated ids (B, n_new[,K])."""
        logits = self.prefill(prompt)
        outs = []
        tok = self.sample(logits, temperature)
        for _ in range(n_new):
            outs.append(tok)
            logits, _ = self.decode(tok)
            tok = self.sample(logits, temperature)
        return jnp.stack(outs, axis=1)

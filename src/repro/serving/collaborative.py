"""Collaborative monitor->trigger->correct serving (the paper's protocol,
deployed):

  device: tiny edge tower decodes every token, computes u_t (monitor head);
          alarm candidate when u_t > gamma - margin.
  server: large backbone; receives data ONLY on trigger, catches up its
          KV/SSM cache on the shipped token backlog, returns the corrector
          -s*sigma(v_t) so the device reports f_hat = u - s*sigma(v).

CommsMeter reproduces the paper's communication-reduction metric; at pod
scale the same trigger drives ``core.gating.compact_correction`` (static
capacity) inside jit — this module is the request-level Python orchestrator.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import decomposition as deco
from repro.core.gating import CommsMeter
from repro.nn.module import linear
from repro.serving.engine import ServeEngine


class CollaborativeEngine:
    def __init__(self, params: Dict, cfg: ArchConfig, batch: int, max_len: int):
        self.cfg, self.m = cfg, cfg.monitor
        self.params = params
        self.edge = ServeEngine(params["edge"], deco.edge_arch(cfg), batch, max_len)
        self.server = ServeEngine(params["server"], cfg, batch, max_len)
        self.server_pos = 0           # how far the server cache has caught up
        self.backlog: List[jnp.ndarray] = []  # tokens not yet shipped
        # payload: one token id (4B) + edge score (4B) per element
        self.comms = CommsMeter(bytes_per_request=8)
        self._u_head = jax.jit(self._u_head_impl)
        self._v_head = jax.jit(self._v_head_impl)

    def _u_head_impl(self, params, hidden_t):
        hd = params["u_head"]
        feats = jnp.tanh(linear(hd["w_feat"], hidden_t.astype(jnp.float32)))
        t = jax.nn.softplus(hd["raw_t"])
        return feats @ hd["a"] + t

    def _v_head_impl(self, params, hidden_t):
        return linear(params["v_head"], hidden_t.astype(jnp.float32))[..., 0]

    def step(self, tokens_t: jnp.ndarray) -> Dict[str, np.ndarray]:
        """One monitoring step over the batch.  Returns u, fhat, triggered."""
        m = self.m
        _, hidden = self.edge.decode(tokens_t)
        u = self._u_head(self.params, hidden)  # (B,)
        self.backlog.append(tokens_t)
        triggered = np.asarray(u > m.threshold - m.trigger_margin)
        fhat = np.asarray(u).copy()
        if triggered.any():
            # ship backlog -> server catches up -> corrector for this step
            backlog_len = len(self.backlog)
            v = self._server_catchup()
            corr = m.s * np.asarray(jax.nn.sigmoid(v))
            fhat = np.where(triggered, fhat - corr, fhat)
            self.comms.update(int(triggered.sum()) * backlog_len,
                              tokens_t.shape[0])
        else:
            self.comms.update(0, tokens_t.shape[0])
        return {"u": np.asarray(u), "fhat": fhat, "triggered": triggered}

    def _server_catchup(self) -> jnp.ndarray:
        v_hidden = None
        for tok in self.backlog:
            _, v_hidden = self.server.decode(tok)
        self.backlog = []
        self.server_pos = self.server.pos
        return self._v_head(self.params, v_hidden)

    def run(self, token_stream: np.ndarray) -> Dict[str, np.ndarray]:
        """token_stream: (B, S[,K]).  Returns stacked traces + comms report."""
        S = token_stream.shape[1]
        us, fhats, trigs = [], [], []
        for t in range(S):
            r = self.step(jnp.asarray(token_stream[:, t]))
            us.append(r["u"]); fhats.append(r["fhat"]); trigs.append(r["triggered"])
        return {"u": np.stack(us, 1), "fhat": np.stack(fhats, 1),
                "triggered": np.stack(trigs, 1), "comms": self.comms.report()}

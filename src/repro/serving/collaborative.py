"""Collaborative monitor->trigger->correct serving (the paper's protocol,
deployed, batched over independent streams):

  device: tiny edge tower decodes every token of every stream, computes
          u_t (monitor head, truncated-basis Eq. 8 — same truncation as
          ``core.decomposition.monitor_score``); alarm candidate when
          u_t > gamma - margin.
  server: large backbone; receives data ONLY on trigger, catches up its
          KV/SSM cache on the shipped token backlog, returns the corrector
          -s*sigma(v_t) so the device reports f_hat = u - s*sigma(v).

PUBLIC SURFACE.  The one public serving entrypoint is
``repro.serving.MonitorSession`` (``serving/api.py``): construct a
``CollaborativeEngine`` (parameters + caches + protocol state), then open
a session over it — ``engine.session(SessionConfig(...))`` — and drive
``session.step`` / ``session.run`` / ``session.stream``.  The session
also owns batch MEMBERSHIP: its slot pool admits and retires monitored
streams mid-flight (``attach``/``detach``), reusing this engine's
per-element masked decode and per-stream protocol state.  The legacy
``run`` / ``run_scan`` / ``run_async`` methods survive only as thin
deprecated shims over a session.

PER-ELEMENT PROTOCOL.  Each batch element (SLOT) is an independent
monitored stream with its own backlog, clock, and server catch-up
position:

  * ``edge_pos[i]`` — stream i's own time axis: how many tokens its edge
    tower has decoded.  Streams attached mid-session start at 0 while
    co-resident slots keep counting — same-position cohorts advance in
    one dense masked decode (``ServeEngine.decode_masked``, bitwise
    identical per-row to the plain batched decode).
  * ``server_pos[i]`` — how far the server cache has caught up on stream
    i.  A trigger on stream i ships ONLY stream i's backlog (tokens
    server_pos[i]..t_i) and advances ONLY server_pos[i]; stream j's
    backlog, cache rows, and communication accounting are bit-untouched
    (``ServeEngine.step_at_fn`` masked per-element decode).
  * the backlog itself is implicit: the engine keeps the token history
    (B, max_len) on device, so stream i's backlog is
    ``history[i, server_pos[i]:t_i+1]`` — no per-stream Python lists.
  * ``active[i]`` — slot-pool membership.  Detached slots are masked out
    of decode, trigger, and comms accounting; a reattached slot is
    bit-cold (caches, history, positions zeroed — ``_attach_slot``).
  * ``CommsMeter`` accounts token-level bytes per slot: a trigger on
    stream i charges len(backlog_i) tokens against slot i only, so the
    paper's Fig-4 "reduction x" is measured per stream.  Each token ships
    at most once => bytes_sent <= bytes_baseline invariantly; detached
    slots accrue nothing.

Three execution paths (selected by ``SessionConfig.mode``; all private
here, dispatched to by ``MonitorSession``):

  * ``_step`` (mode="sync") — the ONLINE protocol path: per-token, lazily
    consults the server (the server cache stays cold until a trigger).
    The fused Pallas ``kernels.monitor_combine`` op (via ``kernels.ops``)
    computes fhat/trigger-mask/safety counters in one pass in the decode
    hot loop.  Each trigger BLOCKS on the server catch-up.
  * ``_step_async`` (mode="async") — the PIPELINED online path: a trigger
    dispatches the same masked catch-up to a ``ServerWorker`` (in-process,
    worker-thread, mock-remote, or real-socket ``wire`` transport —
    ``serving/async_rpc.py``; the wire transport talks to the standalone
    correction-server process of ``serving/server.py``, which coalesces
    queued requests across clients) and the edge loop keeps decoding;
    corrections merge one step late (``fhat`` picks up the corrector at
    t+1..t+max_staleness) while the monitor-only u/trigger path stays
    exact and never waits on the server.  ``max_staleness=0`` is the
    strict synchronous fallback, bit-identical to ``_step``.  See
    docs/protocol.md for the timelines.
  * ``_run_scan`` (mode="scan") — the OFFLINE trace-evaluation fast path:
    one ``jax.lax.scan`` over time (edge + server decoded in lockstep
    inside jit), routing corrections through
    ``core.gating.compact_correction`` with static capacity (the MoE
    trick: only ``capacity`` rows hit the corrector head per step).
    Produces traces equivalent to the online path (exact when capacity >=
    batch) at compiled-loop throughput, plus the same per-stream
    communication accounting derived from the trigger trace.  It does not
    mutate the engine's protocol state, and membership is fixed (scan
    sessions reject attach/detach).

All three paths run unchanged on a MESH-SHARDED engine
(``serving/mesh.py``, ``SessionConfig(mesh="data:8")`` or
``CollaborativeEngine(..., mesh=...)``): params replicate, every
per-stream buffer shards over the mesh data axis, and because the
protocol is elementwise across the batch the sharded engine is per-row
BITWISE identical to the unsharded one, with the monitor path
HLO-asserted collective-free (docs/sharding.md).
"""
from __future__ import annotations

import warnings
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import decomposition as deco
from repro.core.gating import CommsMeter, compact_correction
from repro.kernels import ops
from repro.nn.module import linear
from repro.observability import MetricsRegistry
from repro.serving.engine import ServeEngine, zero_cache_rows

# payload: one token id (4B) + edge score (4B) per shipped token
TOKEN_BYTES = 8


class CollaborativeEngine:
    """Parameters, caches, and per-slot protocol state for one batch of
    monitored streams.  Public surface: construction and the
    ``session()`` factory (plus the deprecated ``run*`` shims); all
    serving goes through ``repro.serving.MonitorSession``."""

    def __init__(self, params: Dict, cfg: ArchConfig, batch: int, max_len: int,
                 *, capacity: Optional[int] = None,
                 monitor_n: Optional[int] = None, mesh=None):
        self.cfg, self.m = cfg, cfg.monitor
        self.params = params
        self.batch, self.max_len = batch, max_len
        self.edge = ServeEngine(params["edge"], deco.edge_arch(cfg), batch, max_len)
        self.server = ServeEngine(params["server"], cfg, batch, max_len)
        # static correction capacity for the compacted scan path; the full
        # batch by default (exact protocol semantics)
        self.capacity = batch if capacity is None else min(capacity, batch)
        # truncation n for the serving u head (paper Eq. 8); defaults to the
        # training-time n_features, overridable for truncation sweeps
        self.monitor_n = self.m.n_features if monitor_n is None else monitor_n
        # per-slot protocol state (the MonitorSession slot pool drives
        # active/edge_pos; a fixed full batch is the all-active special case)
        self.server_pos = np.zeros(batch, np.int64)
        self.edge_pos = np.zeros(batch, np.int64)
        self.active = np.ones(batch, bool)
        self.t = 0  # session step counter (staleness clock, NOT a position)
        tok_tail = (cfg.n_codebooks,) if cfg.family == "audio" else ()
        self._history = jnp.zeros((batch, max_len) + tok_tail, jnp.int32)
        self.comms = CommsMeter(bytes_per_request=TOKEN_BYTES, n_streams=batch)
        # per-stream effective trigger points (serving/policy.py): the
        # engine triggers stream i when u_i > _thr_eff[i].  Seeded at the
        # calibrated scalar the comparison always used — with no policy
        # attached every path is bitwise-identical to the scalar compare.
        # Thresholds are DATA, not structure: policies mutate this vector
        # between steps without retracing any jitted path.
        self._thr_eff = np.full(batch, np.float32(self.m.threshold -
                                                  self.m.trigger_margin),
                                np.float32)
        # unified metrics registry (repro/observability): always on — the
        # wire transport feeds its measured RTT breakdown here, and
        # MonitorSession.metrics() snapshots it.  The span tracer is OFF
        # by default (None): SessionConfig(trace=True) installs one for
        # the session's lifetime, and every instrumentation site below is
        # a single `is not None` check when disabled.
        self.metrics = MetricsRegistry()
        self._tracer = None
        self._dispatcher = None
        self._worker = None
        self._u_head = jax.jit(self._u_head_impl)
        self._v_head = jax.jit(self._v_head_impl)
        self._record_at = jax.jit(self._record_at_impl)
        self._catchup = jax.jit(self._catchup_impl)
        self._scan = jax.jit(self._scan_impl)
        # mesh-sharded serving (serving/mesh.py): params replicated,
        # per-stream state batch-sharded over the mesh data axis, hot
        # paths re-jitted with explicit shardings.  ``mesh``: a MeshSpec
        # or "data:8"-style string; per-row numerics are unchanged.
        self.mesh = None
        self.mesh_spec = None
        if mesh is not None:
            from repro.serving.mesh import shard_engine
            shard_engine(self, mesh)

    def jitted_paths(self) -> Dict[str, object]:
        """Name -> jit wrapper for every jitted path in the serving
        stack (this engine's heads/catch-up/scan plus both towers'
        decode kernels) — the watch list a
        ``analysis.recompile.RecompileGuard`` snapshots to assert each
        path compiles exactly once across a churn episode."""
        paths = {"u_head": self._u_head, "v_head": self._v_head,
                 "record_at": self._record_at, "catchup": self._catchup,
                 "scan": self._scan}
        for tower, se in (("edge", self.edge), ("server", self.server)):
            for name, fn in se.jitted_paths().items():
                paths[f"{tower}.{name}"] = fn
        return paths

    # -- session factory -----------------------------------------------------
    def session(self, config=None, *, streams=None, worker=None):
        """Open a ``MonitorSession`` over this engine — THE public serving
        entrypoint (see ``serving/api.py``).  ``config``: a
        ``SessionConfig`` (default: sync mode); ``streams``: initial
        stream ids to admit (default: ids ``0..batch-1``, the full pool)."""
        from repro.serving.api import MonitorSession
        return MonitorSession(self, config, streams=streams, worker=worker)

    # -- heads ---------------------------------------------------------------
    # Both heads end in a matvec over the feature axis.  They are written
    # as elementwise-mul + single-axis reduce rather than ``x @ w``: XLA's
    # CPU matvec lowering is M-dependent at ~1 ulp (a (2,64)@(64,1) dot
    # rounds differently from a (16,64)@(64,1) dot), so the dot form
    # would break per-row bitwise identity between a mesh-sharded engine
    # (each device holds B/N rows) and the unsharded one, and between the
    # scan path's capacity-compacted corrector buffer and the online
    # path.  The reduce form is row-local by construction (asserted
    # sharded-vs-unsharded in tests/test_mesh.py).
    def _u_head_impl(self, params, hidden_t):
        hd = params["u_head"]
        feats = jnp.tanh(linear(hd["w_feat"], hidden_t.astype(jnp.float32)))
        # Eq. 8 truncation: only the first n basis features reach the device
        # — must match core.decomposition.monitor_score (serving u ==
        # training u)
        mask = (jnp.arange(feats.shape[-1]) < self.monitor_n).astype(jnp.float32)
        t = jax.nn.softplus(hd["raw_t"])
        return jnp.sum(feats * (hd["a"] * mask), axis=-1) + t

    def _v_head_impl(self, params, hidden_t):
        hd = params["v_head"]
        h = hidden_t.astype(jnp.float32)
        return jnp.sum(h * hd["w"][:, 0], axis=-1) + hd["b"][0]

    # -- online (lazy, per-element) path -------------------------------------
    def _record_at_impl(self, history, tokens_t, pos, active):
        """Write tokens_t[i] into history[i, pos[i]] where active (inactive
        slots bit-untouched).  Integer writes: bit-identical to the old
        uniform dynamic_update_slice when pos is uniform.

        Expressed as a one-hot time select rather than a scatter: the
        update is elementwise over the batch, so a batch-sharded history
        (serving/mesh.py) lowers collective-free — XLA's scatter
        partitioner cannot see that ``[arange(B), idx]`` is row-local
        and would all-gather the indices (HLO-asserted in test_mesh)."""
        B, L = history.shape[0], history.shape[1]
        idx = jnp.clip(pos, 0, self.max_len - 1)
        onehot = jnp.arange(L, dtype=idx.dtype) == idx[:, None]      # (B, L)
        sel = (onehot & active[:, None]).reshape(
            (B, L) + (1,) * (history.ndim - 2))
        val = tokens_t.astype(history.dtype)[:, None]                # (B, 1[,K])
        return jnp.where(sel, val, history)

    def _catchup_impl(self, params, cache, history, server_pos, t, triggered, u):
        """Masked per-element server catch-up + fused correction.

        Each triggered stream i replays its own backlog
        history[i, server_pos[i]:t+1] into the server cache at its own
        positions; untriggered streams' cache rows stay bit-identical.
        Rounds run to the LONGEST triggered backlog; streams that finish
        early (or never started) are masked out per round.  ``t`` may be
        a scalar (uniform pool) or a (B,) vector of per-stream end
        positions (ragged slot pool / server-side coalescing) — the round
        mask ``pos <= t`` is elementwise either way.
        """
        B = triggered.shape[0]
        step_at = self.server.get_step_at(with_logits=False)
        n_rounds = jnp.max(jnp.where(triggered, t + 1 - server_pos, 0))

        def round_body(r, carry):
            cache, last_hidden = carry
            pos = (server_pos + r).astype(jnp.int32)
            active = triggered & (pos <= t)
            idx = jnp.clip(pos, 0, self.max_len - 1)
            idxe = idx.reshape((B,) + (1,) * (history.ndim - 1))
            tok = jnp.take_along_axis(history, idxe, axis=1)[:, 0]
            _, hidden, cache = step_at(params["server"], cache, tok, pos, active)
            last_hidden = jnp.where(active[:, None], hidden.astype(jnp.float32),
                                    last_hidden)
            return cache, last_hidden

        last_hidden = jnp.zeros((B, self.cfg.d_model), jnp.float32)
        cache, last_hidden = jax.lax.fori_loop(
            0, n_rounds, round_body, (cache, last_hidden))
        v = self._v_head(params, last_hidden)
        # fused combine (Pallas on TPU / oracle under "xla" impl): fhat,
        # trigger mask and safety counters in one pass over the batch
        if self.m.sigma == "sigmoid":
            fhat_all, mask, _ = ops.monitor_combine(
                u, v, u, s=self.m.s, threshold=self.m.threshold,
                margin=self.m.trigger_margin)
        else:
            corr = self.m.s * deco.sigma(v, self.m.sigma)
            fhat_all, mask = u - corr, triggered.astype(jnp.float32)
        fhat = jnp.where(triggered, fhat_all, u)
        return cache, v, fhat

    def _monitor_prologue(self, tokens_t):
        """The edge-only half of one step, shared by ``_step`` and
        ``_step_async`` so the two stay bit-identical by construction:
        record each active slot's token at ITS position, decode on the
        edge tower (one dense masked call per same-position cohort),
        score u, decide the trigger.  Touches no server state.  Inactive
        slots report u = 0 and never trigger."""
        pos, active = self.edge_pos, self.active
        if not active.any():
            raise ValueError("no attached streams (empty slot pool)")
        if (pos[active] >= self.max_len).any():
            raise ValueError(f"stream longer than max_len={self.max_len}")
        tr = self._tracer
        t0 = tr.clock() if tr is not None else 0.0
        tokens_t = jnp.asarray(tokens_t)
        act_j = jnp.asarray(active)
        self._history = self._record_at(
            self._history, tokens_t, jnp.asarray(pos, jnp.int32), act_j)
        # cohort decode: active slots sharing a position advance in one
        # dense masked decode — per-row bitwise identical to the plain
        # batched decode, so a uniform pool reproduces the fixed-batch
        # path bit-for-bit and churn survivors match a fixed-batch run
        u = None
        for p in sorted(set(pos[active].tolist())):
            mask = active & (pos == p)
            _, hidden = self.edge.decode_masked(tokens_t, int(p),
                                                jnp.asarray(mask))
            u_p = self._u_head(self.params, hidden)  # (B,) device array
            u = u_p if u is None else jnp.where(jnp.asarray(mask), u_p, u)
        if not active.all():
            u = jnp.where(act_j, u, 0.0)
        if tr is not None:
            tr.done("edge.decode", "edge", t0, step=self.t)
            t1 = tr.clock()
        # per-stream effective thresholds (policy-driven; seeded at the
        # calibrated scalar, so the no-policy compare is bit-identical)
        triggered = (np.asarray(u) > self._thr_eff) & active
        if tr is not None:
            # the sync point: host readback of the trigger mask
            tr.done("edge.trigger", "edge", t1, step=self.t,
                    n_triggered=int(triggered.sum()))
        return u, triggered

    def _check_not_detached(self) -> None:
        """After a ``wire`` session the engine's server-side state lived
        in the remote correction server and was DISCARDED when the
        session closed (the server frees and zeroes the lease at BYE).
        The local server cache is cold while ``server_pos`` records the
        remote progress, so continued serving on this engine would replay
        partial backlogs into an empty cache — refuse loudly instead."""
        if getattr(self, "_remote_detached", False):
            raise RuntimeError(
                "this engine's server state lived in a remote correction "
                "server (wire transport) and was discarded when the "
                "session closed; create a fresh engine to serve again")

    def _step(self, tokens_t: jnp.ndarray) -> Dict[str, np.ndarray]:
        """One synchronous monitoring step over the slot pool.  Returns
        full-batch u, fhat, triggered (inactive slots: 0/0/False)."""
        B = self.batch
        self._check_not_detached()
        active = self.active.copy()
        t_vec = self.edge_pos.copy()  # per-slot time BEFORE this step
        u, triggered = self._monitor_prologue(tokens_t)
        fhat = np.asarray(u).copy()
        if triggered.any():
            tr = self._tracer
            t0 = tr.clock() if tr is not None else 0.0
            uniform = active.all() and (t_vec == t_vec[0]).all()
            # uniform pools pass the scalar t (the original compiled
            # program); ragged pools pass per-slot end positions
            t_arg = (jnp.asarray(int(t_vec[0]), jnp.int32) if uniform
                     else jnp.asarray(t_vec, jnp.int32))
            # each triggered stream ships ITS backlog; others untouched
            cache, v, fhat_j = self._catchup(
                self.params, self.server.cache, self._history,
                jnp.asarray(self.server_pos, jnp.int32), t_arg,
                jnp.asarray(triggered), u)
            self.server.cache = cache
            fhat = np.asarray(fhat_j)
            if tr is not None:
                # the sync path BLOCKS on the server here
                tr.done("edge.catchup", "edge", t0, step=self.t,
                        n_triggered=int(triggered.sum()))
            shipped = np.where(triggered, t_vec + 1 - self.server_pos, 0)
            self.comms.update_per_stream(shipped, active.astype(np.int64))
            self.server_pos = np.where(triggered, t_vec + 1, self.server_pos)
            self.server.pos = int(self.server_pos.max())
        else:
            self.comms.update_per_stream(np.zeros(B, np.int64),
                                         active.astype(np.int64))
        self.edge_pos = t_vec + active
        self.t += 1
        return {"u": np.asarray(u), "fhat": fhat, "triggered": triggered}

    # -- async pipelined online path -----------------------------------------
    def _start_async(self, *, transport: str = "stream",
                     max_staleness: int = 1,
                     latency_s: Optional[float] = None,
                     address: Optional[str] = None,
                     wire_coalesce: bool = True,
                     worker=None) -> None:
        """Open an async serving session: hand the server cache to a
        ``ServerWorker`` and set up the dispatch/merge layer.

        transport: "inproc" | "stream" | "thread" | "mock_remote" | "wire"
        | "shm" (see async_rpc; "stream" overlaps via JAX async dispatch;
        "wire" talks to a standalone correction-server PROCESS over a
        socket — the real boundary, RTT/bytes measured not simulated;
        "shm" is the wire protocol with the data plane moved into a
        same-host shared-memory ring pair, falling back to plain wire
        when the server is remote or offers no arena).
        max_staleness: merge window — 0 is the strict synchronous
        fallback (bit-identical to ``_step``); k >= 1 lets a reply land
        1..k steps after its trigger, blocking the edge loop only at k.
        latency_s: simulated server round trip (stream/thread/mock_remote);
        None keeps the transport's own default.  Rejected for "wire"/"shm".
        address: "wire"/"shm" only — the server's UDS path or "host:port"
        (start one with ``python -m repro.launch.server``).  With these
        the server process owns the session's server cache; the engine's
        local server cache stays cold and only ``server_pos`` (carried by
        replies) comes home.
        wire_coalesce: "wire"/"shm" only — opt this session out of
        server-side request coalescing (per-request replays) when False.
        """
        from repro.serving import async_rpc
        if self._dispatcher is not None:
            raise RuntimeError("async session already open")
        self._check_not_detached()
        if worker is None:
            wire_opts = None
            if transport in ("wire", "shm") and address is not None:
                wire_opts = dict(address=address, batch=self.batch,
                                 max_len=self.max_len,
                                 tok_tail=tuple(self._history.shape[2:]),
                                 coalesce=wire_coalesce, comms=self.comms,
                                 metrics=self.metrics, tracer=self._tracer)
            worker = async_rpc.make_worker(transport, self._catchup,
                                           self.params, self.server.cache,
                                           latency_s=latency_s,
                                           wire_opts=wire_opts)
        self._worker = worker
        self._dispatcher = async_rpc.Dispatcher(
            worker, max_staleness=max_staleness, comms=self.comms,
            tracer=self._tracer)
        # what has been SHIPPED (dispatched) per stream; merges move
        # ``server_pos`` (what the protocol state reflects) up to this
        self._dispatch_pos = self.server_pos.copy()

    def _step_async(self, tokens_t: jnp.ndarray) -> Dict[str, np.ndarray]:
        """One pipelined monitoring step.  Identical monitor semantics to
        ``_step`` (u and the trigger decision never wait on the server);
        corrections from earlier triggers merge into THIS step's fhat.
        """
        if self._dispatcher is None:
            raise RuntimeError("no open async session (use MonitorSession)")
        m, B = self.m, self.batch
        active = self.active.copy()
        t_vec = self.edge_pos.copy()
        u, triggered = self._monitor_prologue(tokens_t)
        u_np = np.asarray(u)
        # dispatch first so the synchronous fallback (max_staleness=0)
        # merges this step's own reply below
        tr = self._tracer
        if triggered.any():
            t0 = tr.clock() if tr is not None else 0.0
            shipped = np.where(triggered, t_vec + 1 - self._dispatch_pos, 0)
            # one request per same-position cohort, so every request keeps
            # the scalar-t backlog/wire semantics (a uniform pool is the
            # single-request special case, bit-identical to before)
            # cork the socket workers around the cohort fan-out: N
            # same-tick requests leave in ONE transmit (the client half
            # of wire micro-batching; a no-op for local transports)
            worker = self._worker
            corked = hasattr(worker, "cork")
            if corked:
                worker.cork()
            try:
                for p in sorted(set(t_vec[triggered].tolist())):
                    mask_p = triggered & (t_vec == p)
                    self._dispatcher.dispatch(
                        t=int(p), triggered=mask_p,
                        server_pos=self._dispatch_pos, history=self._history,
                        u=u, step_t=self.t)
            finally:
                if corked:
                    worker.uncork()
            self.comms.update_per_stream(shipped, active.astype(np.int64))
            self._dispatch_pos = np.where(triggered, t_vec + 1,
                                          self._dispatch_pos)
            if tr is not None:
                tr.done("edge.dispatch", "edge", t0, step=self.t,
                        n_triggered=int(triggered.sum()))
        else:
            self.comms.update_per_stream(np.zeros(B, np.int64),
                                         active.astype(np.int64))
        fhat = u_np.copy()
        t_merge = tr.clock() if tr is not None else 0.0
        n_merged = 0
        for r in self._dispatcher.collect(self.t):
            # churn drains before rewriting membership, so a reply's mask
            # can only reference still-attached slots; the `live` gate is
            # defensive against both
            live = r.triggered & self.active
            if r.step_t == self.t:
                # same-step merge (sync fallback): the fused fhat computed
                # from this step's u — bit-identical to ``_step``
                fhat = np.where(live, r.fhat, fhat)
            else:
                # late merge: the stale corrector applied to TODAY's u.
                # corr >= 0, so fhat <= u — staleness can only keep a
                # warning raised, never suppress one (safety semantics)
                corr = np.asarray(m.s * deco.sigma(jnp.asarray(r.v), m.sigma))
                fhat = np.where(live, u_np - corr, fhat)
            self.server_pos = np.where(live, r.t + 1, self.server_pos)
            n_merged += 1
        if tr is not None and n_merged:
            tr.done("edge.merge", "edge", t_merge, step=self.t,
                    n_replies=n_merged)
        self.edge_pos = t_vec + active
        self.t += 1
        return {"u": u_np, "fhat": fhat, "triggered": triggered}

    def _drain_async(self) -> None:
        """Settle every in-flight request (their replies update protocol
        state only — there is no report step for them).  Required before
        any slot-pool membership change in async mode: a reply must never
        land on a slot that has been re-leased since its dispatch."""
        for r in self._dispatcher.drain():
            live = r.triggered & self.active
            self.server_pos = np.where(live, r.t + 1, self.server_pos)

    def _finish_async(self) -> None:
        """Drain outstanding replies (pipeline tail: they update protocol
        state but have no edge step left to report into), re-adopt the
        worker's server cache, and close the session."""
        if self._dispatcher is None:
            return
        self._drain_async()
        self.server.cache = self._worker.cache
        self.server.pos = int(self.server_pos.max())
        if getattr(self._worker, "kind", None) in ("wire", "shm"):
            # the worker's cache is the engine's untouched cold cache (the
            # real one lived — and died — in the server process): any
            # further serving on this engine would be silently wrong
            self._remote_detached = True
        self._worker.close()
        self._dispatcher = self._worker = None

    # -- slot pool (driven by MonitorSession.attach/detach) -------------------
    def _attach_slot(self, slot: int) -> None:
        """Admit a new stream into ``slot``: every per-slot state the
        previous tenant left behind is reset to bit-cold zeros (edge +
        server cache rows, token history, positions), exactly as if the
        slot belonged to a freshly-built engine.  In async mode the
        pipeline is drained first and, over the wire, an ATTACH frame
        tells the correction server to zero and re-lease its row."""
        rows = np.zeros(self.batch, bool)
        rows[slot] = True
        if self._dispatcher is not None:
            self._drain_async()
        self.edge.zero_rows(rows)
        if (self._dispatcher is not None
                and getattr(self._worker, "kind", None) in ("wire", "shm")):
            self._worker.attach_slot(slot)
        elif self._dispatcher is not None:
            # the worker owns the server cache for the session; after the
            # drain no compute is in flight, so the functional row reset
            # is race-free on every local transport (spec-aware: a
            # sharded cache keeps its placement through the reset)
            self._worker.cache = zero_cache_rows(
                self._worker.cache, self.server.axes, jnp.asarray(rows),
                shardings=self.server._cache_shardings)
        else:
            self.server.zero_rows(rows)
        self._history = self._history.at[slot].set(0)
        if getattr(self, "_history_sharding", None) is not None:
            # eager row scatter may lose the committed placement
            self._history = jax.device_put(self._history,
                                           self._history_sharding)
        self.server_pos[slot] = 0
        self.edge_pos[slot] = 0
        # a fresh tenant starts at the calibrated operating point; any
        # policy-raised threshold the previous tenant earned must not
        # leak (the session also cold-starts its controller state)
        self._thr_eff[slot] = np.float32(self.m.threshold -
                                         self.m.trigger_margin)
        if self._dispatcher is not None:
            self._dispatch_pos[slot] = 0
        self.active[slot] = True

    def _detach_slot(self, slot: int) -> None:
        """Retire the stream in ``slot``: masked out of decode, trigger,
        and comms accounting from the next step on.  Its state is left in
        place (attach zeroes on reuse); in async mode the pipeline is
        drained first so no in-flight reply can land on the freed slot."""
        if self._dispatcher is not None:
            self._drain_async()
            if getattr(self._worker, "kind", None) in ("wire", "shm"):
                self._worker.detach_slot(slot)
        self.active[slot] = False

    # -- offline scan fast path ----------------------------------------------
    def _scan_impl(self, params, tokens, thr_eff):
        """One lax.scan over time: edge + server decode in lockstep,
        corrections routed through compact_correction (static capacity).
        Scratch caches are built inside jit (zeros at the engine's max_len
        capacity, so attention reduction widths match the online path
        bit-for-bit) — no per-call host allocation.  ``thr_eff``: (B,)
        f32 per-stream effective trigger points (traced DATA, like the
        tokens — static-policy scans pass a different vector without
        retracing)."""
        ecfg = deco.edge_arch(self.cfg)
        cfg, m = self.cfg, self.m
        B = tokens.shape[0]
        from repro.models import api as model_api
        edge_cache = model_api.init_cache(ecfg, B, self.max_len)
        server_cache = model_api.init_cache(cfg, B, self.max_len)

        def body(carry, tok_t):
            edge_cache, server_cache, pos = carry
            _, eh, edge_cache = model_api.decode_step(
                params["edge"], ecfg, edge_cache, tok_t, pos,
                with_logits=False)
            u = self._u_head(params, eh)
            _, sh, server_cache = model_api.decode_step(
                params["server"], cfg, server_cache, tok_t, pos,
                with_logits=False)

            def corrector(buf):  # (capacity, d) gathered server hiddens
                v = self._v_head(params, buf)
                return m.s * deco.sigma(v, m.sigma)

            # per-stream trigger points: urgency u - (thr_eff - 0.0) is
            # bit-identical to the scalar u - (threshold - margin) when
            # thr_eff is the calibrated f32 (x - 0.0 is an identity in
            # round-to-nearest f32)
            fhat, served, _ = compact_correction(
                u, sh.astype(jnp.float32), corrector, thr_eff,
                0.0, self.capacity)
            trig = u > thr_eff
            return (edge_cache, server_cache, pos + 1), (u, fhat, trig, served)

        toks = jnp.moveaxis(tokens, 1, 0)
        carry = (edge_cache, server_cache, jnp.asarray(0, jnp.int32))
        _, (u, fhat, trig, served) = jax.lax.scan(body, carry, toks)
        # time-major -> batch-major
        return (jnp.moveaxis(u, 0, 1), jnp.moveaxis(fhat, 0, 1),
                jnp.moveaxis(trig, 0, 1), jnp.moveaxis(served, 0, 1))

    def _run_scan(self, token_stream: np.ndarray) -> Dict[str, np.ndarray]:
        """Offline trace evaluation: same protocol semantics as the sync
        online path (exact when capacity == batch; capacity-limited
        correction otherwise), compiled into a single scan.  Scratch
        caches — the engine's online protocol state (server laziness,
        comms meter) is not mutated.  Comms are derived per stream from
        the trigger trace: a trigger at time t ships the backlog since
        that stream's previous trigger, so total shipped = last-trigger
        index + 1."""
        tokens = jnp.asarray(token_stream)
        B, S = tokens.shape[0], tokens.shape[1]
        if S > self.max_len:
            raise ValueError(f"stream longer than max_len={self.max_len}")
        tr = self._tracer
        t0 = tr.clock() if tr is not None else 0.0
        if B == self.batch:
            thr_eff = jnp.asarray(self._thr_eff)
        else:  # narrower offline trace: calibrated point for every row
            thr_eff = jnp.full((B,), np.float32(self.m.threshold -
                                                self.m.trigger_margin),
                               jnp.float32)
        u, fhat, trig, served = self._scan(self.params, tokens, thr_eff)
        trig_np = np.asarray(trig)
        if tr is not None:
            tr.done("scan.run", "edge", t0, batch=int(B), steps=int(S))
        comms = CommsMeter(bytes_per_request=TOKEN_BYTES, n_streams=B)
        any_trig = trig_np.any(axis=1)
        last = np.where(any_trig, S - 1 - np.argmax(trig_np[:, ::-1], axis=1), -1)
        comms.update_per_stream(last + 1, np.full(B, S, np.int64),
                                events=trig_np.sum(axis=1))
        return {"u": np.asarray(u), "fhat": np.asarray(fhat),
                "triggered": trig_np, "served": np.asarray(served),
                "comms": comms.report()}

    # -- deprecated shims (the pre-session public surface) --------------------
    def _session_shim(self, mode, name, worker=None, **cfg_kw):
        from repro.serving.api import SessionConfig
        warnings.warn(
            f"CollaborativeEngine.{name}() is deprecated: open a "
            f"MonitorSession instead — engine.session(SessionConfig("
            f"mode={mode!r}, ...)).run(stream)  (see docs/api.md)",
            DeprecationWarning, stacklevel=3)
        return self.session(SessionConfig(mode=mode, **cfg_kw),
                            worker=worker)

    def run(self, token_stream: np.ndarray) -> Dict[str, np.ndarray]:
        """DEPRECATED: thin shim over ``MonitorSession`` (sync mode).
        Bit-identical to ``session(SessionConfig(mode="sync")).run(...)``
        — asserted in tests."""
        with self._session_shim("sync", "run") as s:
            return s.run(token_stream)

    def run_scan(self, token_stream: np.ndarray) -> Dict[str, np.ndarray]:
        """DEPRECATED: thin shim over ``MonitorSession`` (scan mode)."""
        with self._session_shim("scan", "run_scan") as s:
            return s.run(token_stream)

    def run_async(self, token_stream: np.ndarray, *,
                  transport: str = "stream", max_staleness: int = 1,
                  latency_s: Optional[float] = None,
                  address: Optional[str] = None, wire_coalesce: bool = True,
                  worker=None) -> Dict[str, np.ndarray]:
        """DEPRECATED: thin shim over ``MonitorSession`` (async mode)."""
        from repro.serving.api import TransportSpec
        spec = TransportSpec(kind=transport, address=address,
                             latency_s=latency_s, coalesce=wire_coalesce)
        with self._session_shim("async", "run_async", worker=worker,
                                transport=spec,
                                max_staleness=max_staleness) as s:
            return s.run(token_stream)

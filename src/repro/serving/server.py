"""Standalone correction server: the server half of the paper's
``f = u + v`` decomposition as its own PROCESS, behind a real socket.

``serving/async_rpc.py``'s in-process transports simulate the network
round trip; this module is the measured counterpart.  One
``CorrectionServer`` owns a **super-batch** of cache rows (``slots``) and
leases contiguous row ranges to edge-client *sessions*: each connected
``CollaborativeEngine`` (the ``wire`` transport / ``SocketWorker``) gets
``batch`` rows of the shared server KV/SSM cache plus a matching region
of the server-side token-history mirror.  All protocol state the paper
assigns to the server — the per-stream catch-up cache and the replayed
token history — therefore lives HERE, across a serialization boundary
from the edge; the client's local server cache stays cold for the whole
session.

CROSS-CLIENT REQUEST COALESCING (the throughput mechanism):

Queued catch-up requests — from many edge clients, and from the deep
pipeline of a single async client — are merged into ONE masked replay per
event-loop tick through the engine's existing jitted ``_catchup_impl``:

  * ``triggered``  = union of the requests' trigger masks (slot-indexed);
  * ``server_pos`` = per-slot MIN of the requests' catch-up bases;
  * ``t``          = per-slot max trigger step (a (slots,) vector — the
    masked replay already supports per-stream end positions, since its
    round mask is ``server_pos + r <= t`` elementwise);
  * ``u``          = per-slot dispatch-time score of the latest request.

Because the replay is per-element masked (``engine.make_step_at``), rows
belonging to different sessions never interact: client A's triggers
cannot perturb client B's cache rows bit-wise (asserted in tests).  The
merge is safe for the protocol because every reply's corrector satisfies
``s*sigma(v) >= 0``: a coalesced reply can only carry a *fresher* v (the
replay may have advanced a shared row past an older queued request's
trigger step), and a fresher or staler corrector applied to the current
``u`` still only lowers ``fhat`` — the monitor's upper-bound safety
story is untouched (see docs/transport.md for the full argument).

What coalescing buys: the async bench at batch 64 is compute-bound on
per-request dense replay rounds (each queued request costs a full masked
pass over the batch).  Merging k queued requests costs max-rounds once
instead of sum-of-rounds — the per-request dispatch floor drops by ~k.

Replies are FIFO per session (the Dispatcher's ordering contract): a
session either coalesces (all its queued requests merge, replies emitted
in arrival order) or opted out via HELLO (``coalesce=False`` — the
bench's per-request baseline), in which case its requests replay one by
one, still in arrival order.

The event loop is a single-threaded ``selectors`` reactor: drain every
readable socket (and every session doorbell, for shared-memory
sessions), then run at most one coalesced replay, then flush writes.
JAX compute happens on the loop thread — the server is itself a batched
inference engine, not a proxy.  Run it with
``python -m repro.launch.server`` (see that module for the CLI) or embed
it in a thread via ``serve_forever(stop=threading.Event())`` (tests).

Same-host shared-memory sessions (``shm=True`` + a v5 client asking for
it): the HELLO_ACK carries an arena offer and its fds via SCM_RIGHTS,
data frames then move through the arena's ring pair
(``serving/shm.py``) while every control frame stays on the socket.
The reactor registers each session's doorbell fd alongside the sockets
— ring traffic wakes the same ``select``, no busy-spinning — and the
server NEVER blocks on a full reply ring: residue buffers in the
session and flushes when the client's consume-side doorbell fires.
"""
from __future__ import annotations

import logging
import selectors
import socket
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import decomposition as deco
from repro.observability import MetricsRegistry, Tracer
from repro.serving import shm as shm_mod
from repro.serving import wire
from repro.serving.collaborative import CollaborativeEngine
from repro.serving.engine import cache_batch_axes, zero_cache_rows
from repro.serving.tracker import Histogram, Tracker

log = logging.getLogger("repro.serving.server")

# sendmsg gather limit per flush: comfortably under any IOV_MAX (Linux
# has 1024); a tick queueing more frames than this simply loops
_IOV_MAX = 64


@dataclass
class Session:
    """One connected edge client: a leased range of super-batch rows."""

    sid: int
    conn: socket.socket
    lo: int = -1            # first super-batch row (−1 until HELLO)
    batch: int = 0
    max_len: int = 0
    coalesce: bool = True
    client: str = "?"
    reader: wire.FrameReader = field(default_factory=wire.FrameReader)
    # per-frame output buffers, gathered into ONE sendmsg per flush
    out: List[bytes] = field(default_factory=list)
    # -- shared-memory transport (serving/shm.py) ---------------------------
    shm_arena: Optional["shm_mod.ServerArena"] = None  # offered at HELLO
    shm_live: bool = False     # client confirmed with SHM_OPEN(ok=True)
    shm_out: bytearray = field(default_factory=bytearray)  # reply-ring residue

    @property
    def hi(self) -> int:
        return self.lo + self.batch


class CorrectionServer:
    """Socket front-end + coalescing replay core over one super-batch."""

    def __init__(self, cfg: ArchConfig, params, *, slots: int = 16,
                 max_len: int = 128, uds: Optional[str] = None,
                 host: str = "127.0.0.1", port: int = 0,
                 coalesce: bool = True, mesh: Optional[str] = None,
                 tracker: Optional[Tracker] = None,
                 tracer: Optional[Tracer] = None,
                 stats_interval_s: float = 0.5,
                 shm: bool = False,
                 shm_ring_bytes: int = shm_mod.DEFAULT_RING_BYTES):
        self.cfg, self.m = cfg, cfg.monitor
        self.slots, self.max_len = int(slots), int(max_len)
        self.coalesce = bool(coalesce)   # server-wide kill switch
        # offer a shared-memory arena to v5 clients that ask for one
        # (same-host UDS connections only; TCP peers stay pure-wire)
        self.shm = bool(shm)
        self.shm_ring_bytes = int(shm_ring_bytes)
        # the replay core IS the engine's jitted masked catch-up: one
        # CollaborativeEngine at batch=slots supplies the compiled
        # _catchup_impl and the super-batch server cache.  (Its edge tower
        # and comms meter are unused here — the edge lives in the clients.)
        # ``mesh`` ("data:8"-style, serving/mesh.py) shards that
        # super-batch over a device mesh: the coalesced replay runs with
        # each device holding slots/N cache rows, and leases/resets stay
        # row-local (the per-stream protocol is elementwise).
        eng = CollaborativeEngine(params, cfg, batch=self.slots,
                                  max_len=self.max_len, mesh=mesh)
        self._eng = eng
        self.mesh_spec = eng.mesh_spec
        self._cache = eng.server.cache
        self._cache_shardings = eng.server._cache_shardings
        self._axes = cache_batch_axes(cfg, self.slots, self.max_len)
        tok_tail = (cfg.n_codebooks,) if cfg.family == "audio" else ()
        self.tok_tail: Tuple[int, ...] = tok_tail
        # server-side token-history mirror: requests carry only backlog
        # slices; the replay needs them at absolute positions
        self._history = np.zeros((self.slots, self.max_len) + tok_tail,
                                 np.int32)
        # per-reply fusion from the REQUEST's own trigger mask and u (the
        # server's threshold is irrelevant: the client already decided)
        s, sig = self.m.s, self.m.sigma
        self._fuse = jax.jit(lambda u, v, trig: jnp.where(
            trig, u - s * deco.sigma(v, sig), u))

        # -- sessions / slots ------------------------------------------------
        self._sessions: Dict[socket.socket, Session] = {}
        self._free: List[Tuple[int, int]] = [(0, self.slots)]  # [lo, hi)
        self._next_sid = 1
        self._pending: List[Tuple[Session, wire.WireRequest, float]] = []

        # -- observability (repro/observability) ------------------------------
        # One MetricsRegistry backs every counter and histogram below;
        # ``stats``/``hist`` remain the public read surface (tests, the
        # launch CLI's SIGTERM dump) but the heartbeat snapshot is now
        # just ``registry.snapshot()`` plus identity fields — same keys
        # the FleetSupervisor always scraped.
        self.metrics = MetricsRegistry()
        for name in ("requests", "replays", "coalesced", "sessions",
                     "bytes_rx", "bytes_tx", "attaches", "detaches",
                     "defrags", "refused_draining",
                     # tx_flushes counts sendmsg syscalls: frames queued
                     # in one tick gather into ONE flush (the
                     # micro-batching regression gauge)
                     "tx_flushes",
                     # ring-plane bytes, metered separately from the
                     # socket so shm payloads are never silently free
                     "shm_bytes_rx", "shm_bytes_tx", "shm_sessions"):
            self.metrics.counter(name)   # pre-create: zeros still report
        # replay compute time per coalesced group (seconds)
        self.metrics.histogram("replay_s", 1e-5, 60.0)
        # requests merged per replay (the coalescing win)
        self.metrics.histogram("coalesce_width", 1.0, 4096.0)
        # request arrival -> reply enqueued, server-side (seconds)
        self.metrics.histogram("turnaround_s", 1e-5, 60.0)
        # request arrival -> replay start: the v4 REPLY timing payload,
        # so clients can split queueing from compute in their RTT
        self.metrics.histogram("queue_wait_s", 1e-6, 60.0)

        # ``tracker`` turns the one-shot SIGTERM stats print into a live
        # surface: serve_forever logs a full snapshot every
        # ``stats_interval_s`` — with a JsonFileTracker that IS the fleet
        # heartbeat the supervisor scrapes for load + liveness.
        self.tracker = tracker
        self.stats_interval_s = float(stats_interval_s)
        self._last_stats_log = 0.0
        # optional server-LOCAL span tracer (launch/server.py
        # --trace-file): records server.queue / server.replay spans on
        # the server's own clock; None (the default) costs one flag check
        self.tracer = tracer

        # -- drain (fleet lifecycle) ------------------------------------------
        # request_drain() is signal-safe (launch/server.py maps SIGUSR1 to
        # it); the reactor applies it on its own thread at the next tick:
        # GOAWAY to every leased session, ERROR to new HELLOs.  Sessions
        # finish their in-flight requests, BYE, and re-HELLO elsewhere —
        # zero streams dropped (tests/test_fleet.py::test_drain_*).
        self.draining = False
        self._drain_req = threading.Event()

        # -- listener ---------------------------------------------------------
        self.uds = uds
        if uds is not None:
            self._listener = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            self._listener.bind(uds)
            self.address = uds
        else:
            self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            self._listener.bind((host, port))
            h, p = self._listener.getsockname()
            self.address = f"{h}:{p}"
        self._listener.listen(64)
        self._listener.setblocking(False)
        self._sel = selectors.DefaultSelector()
        self._sel.register(self._listener, selectors.EVENT_READ, "accept")
        self._closed = False

    # -- observability / fleet surface ---------------------------------------
    @property
    def stats(self) -> Dict[str, object]:
        """Counter snapshot (name -> value) — the pre-registry ``stats``
        dict, now a registry view.  Read-only: mutate via
        ``self.metrics.inc``."""
        return self.metrics.counters()

    @property
    def hist(self) -> Dict[str, Histogram]:
        """The registry's histograms, by name (``replay_s`` etc.)."""
        return self.metrics.hists

    def leased_rows(self) -> int:
        """Super-batch rows currently leased — the routing load signal."""
        return self.slots - sum(h - l for l, h in self._free)

    def sessions_live(self) -> int:
        return sum(1 for s in self._sessions.values() if s.lo >= 0)

    def stats_snapshot(self) -> Dict[str, object]:
        """One scrapeable heartbeat record: identity, load, health, and
        the counter/histogram state.  This dict is what JsonFileTracker
        writes and what ``FleetSupervisor`` reads."""
        snap: Dict[str, object] = {
            "ts": time.time(),
            "address": self.address,
            "slots": self.slots,
            "leased_rows": self.leased_rows(),
            "sessions_live": self.sessions_live(),
            "fragmentation": self.fragmentation(),
            "draining": self.draining,
        }
        snap.update(self.metrics.snapshot())
        return snap

    # -- drain (fleet lifecycle) ---------------------------------------------
    def request_drain(self) -> None:
        """Ask the reactor to start draining (safe from signal handlers
        and other threads; applied at the next ``serve_tick``)."""
        self._drain_req.set()

    def start_drain(self) -> None:
        """Stop taking work: GOAWAY every leased session, refuse new
        HELLOs.  In-flight requests still complete — the client decides
        when its pipeline is empty and moves."""
        if self.draining:
            return
        self.draining = True
        for sess in list(self._sessions.values()):
            if sess.lo >= 0:
                self._send(sess, wire.encode_goaway("draining"))

    # -- slot allocation -----------------------------------------------------
    def _alloc(self, n: int) -> int:
        for i, (lo, hi) in enumerate(self._free):
            if hi - lo >= n:
                self._free[i] = (lo + n, hi)
                if self._free[i][0] == self._free[i][1]:
                    del self._free[i]
                return lo
        return -1

    def _release(self, lo: int, n: int) -> None:
        self._free.append((lo, lo + n))
        self._free.sort()
        merged: List[Tuple[int, int]] = []
        for a, b in self._free:
            if merged and merged[-1][1] == a:
                merged[-1] = (merged[-1][0], b)
            else:
                merged.append((a, b))
        self._free = merged

    def _reset_rows(self, lo: int, hi: int) -> None:
        """Zero a leased range: a new session (or a re-leased slot — the
        ATTACH churn frame) must see cold cache rows even if a previous
        tenant used them.  Spec-aware: on a mesh-sharded super-batch the
        reset preserves the cache placement (each device rewrites only
        its own rows — no gather-to-host)."""
        rows = np.zeros(self.slots, bool)
        rows[lo:hi] = True
        self._cache = zero_cache_rows(self._cache, self._axes,
                                      jnp.asarray(rows),
                                      shardings=self._cache_shardings)
        self._history[lo:hi] = 0

    # -- lease defrag --------------------------------------------------------
    def fragmentation(self) -> float:
        """Lease-fragmentation gauge in [0, 1): the fraction of free
        super-batch rows NOT in the largest free extent.  0 when the
        free space is one contiguous block (or there is none); reported
        in the SIGTERM stats dump of ``launch/server.py``."""
        free = sum(h - l for l, h in self._free)
        if free == 0:
            return 0.0
        return 1.0 - max(h - l for l, h in self._free) / free

    def _defrag(self) -> None:
        """Compact live leases to the low end of the super-batch so the
        free rows form ONE contiguous tail (a long-lived multi-tenant
        server must not refuse a batch-N HELLO while N rows sit free in
        scattered holes).  Cache rows and the history mirror move WITH
        their sessions — a client's rows are bit-identical before and
        after, only their physical position changes, and clients address
        slots relative to ``sess.lo`` so nothing crosses the wire.
        Queued requests stay valid: the replay reads ``sess.lo`` at
        replay time, after the rows have moved."""
        live = sorted((s for s in self._sessions.values() if s.lo >= 0),
                      key=lambda s: s.lo)
        if not any(s.lo != lo for s, lo in
                   zip(live, np.cumsum([0] + [s.batch for s in live]))):
            return  # already compact
        order: List[int] = []
        for s in live:
            order.extend(range(s.lo, s.lo + s.batch))
        taken = set(order)
        perm = np.asarray(order + [r for r in range(self.slots)
                                   if r not in taken])
        permj = jnp.asarray(perm)
        self._cache = jax.tree.map(
            lambda a, ax: jnp.take(a, permj, axis=ax), self._cache,
            self._axes)
        if self._cache_shardings is not None:
            self._cache = jax.tree.map(jax.device_put, self._cache,
                                       self._cache_shardings)
        self._history = self._history[perm]
        lo = 0
        for s in live:
            s.lo = lo
            lo += s.batch
        self._free = [(lo, self.slots)] if lo < self.slots else []
        self.metrics.inc("defrags")

    # -- socket plumbing -----------------------------------------------------
    def _send(self, sess: Session, data: bytes, *,
              flush: bool = True) -> None:
        """Queue a frame; ``flush=False`` defers the syscall so a tick
        that produces many frames for one session (a coalesced replay's
        reply fan-out) gathers them into ONE ``sendmsg``."""
        sess.out.append(data)
        if flush:
            self._flush(sess)

    def _flush(self, sess: Session) -> None:
        while sess.out:
            try:
                n = sess.conn.sendmsg(sess.out[:_IOV_MAX])
            except (BlockingIOError, InterruptedError):
                break
            except OSError:
                self._drop(sess)
                return
            self.metrics.inc("bytes_tx", n)
            self.metrics.inc("tx_flushes")
            # retire fully-sent buffers; re-head a partially-sent one
            while n > 0:
                head = sess.out[0]
                if n >= len(head):
                    n -= len(head)
                    sess.out.pop(0)
                else:
                    sess.out[0] = head[n:]
                    n = 0
        events = selectors.EVENT_READ | (selectors.EVENT_WRITE if sess.out
                                         else 0)
        try:
            self._sel.modify(sess.conn, events, "conn")
        except KeyError:
            pass

    # -- shared-memory plumbing (serving/shm.py) -----------------------------
    def _send_reply(self, sess: Session, data: bytes) -> None:
        """Data-plane send: the reply ring when the session is shm-live,
        the (deferred, gathered) socket path otherwise."""
        if sess.shm_live:
            sess.shm_out.extend(data)
            self._shm_flush(sess)
        else:
            self._send(sess, data, flush=False)

    def _shm_flush(self, sess: Session) -> None:
        """Move reply residue into the ring — as much as fits.  The
        server never blocks here: leftovers stay buffered and this runs
        again when the client's consume rings our doorbell."""
        arena = sess.shm_arena
        if arena is None or not sess.shm_out:
            return
        wrote = 0
        while sess.shm_out:
            n = arena.peer.writer.write(sess.shm_out)
            if n == 0:
                break
            del sess.shm_out[:n]
            wrote += n
        if wrote:
            self.metrics.inc("shm_bytes_tx", wrote)
            arena.peer.db_peer.ring()

    def _shm_wake(self, sess: Session) -> None:
        """Session doorbell fired: the client produced requests and/or
        consumed replies.  Drain-then-check so a ring racing the select
        is never lost."""
        arena = sess.shm_arena
        if arena is None or sess.conn not in self._sessions:
            return
        arena.peer.db_own.drain()
        self._shm_flush(sess)           # the client may have freed space
        try:
            frames = arena.peer.recv_frames()
        except wire.WireError as e:
            try:
                self._send(sess, wire.encode_error(str(e)))
            finally:
                self._drop(sess)
            return
        for p in frames:
            if sess.conn not in self._sessions:
                return
            self.metrics.inc("shm_bytes_rx", len(p) + 4)
            try:
                self._handle(sess, wire.decode(p))
            except wire.WireError as e:
                try:
                    self._send(sess, wire.encode_error(str(e)))
                finally:
                    self._drop(sess)
                return

    def _offer_shm(self, sess: Session) -> bool:
        """Answer a shm-requesting HELLO with an arena offer: the ack
        frame plus the arena/doorbell fds in ONE sendmsg (SCM_RIGHTS),
        after which the arena file is unlinked — the crash-safe window
        closes before the client even replies.  Returns False (and
        leaks nothing) when anything fails; the caller then sends the
        plain ack and the session stays pure-wire."""
        if sess.out:
            self._flush(sess)
            if sess.out:
                return False  # can't append fds to a backlogged stream
        try:
            arena = shm_mod.ServerArena.create(self.shm_ring_bytes)
        except (OSError, shm_mod.ShmError) as e:
            log.warning("arena creation failed (%s); session %d stays on "
                        "wire", e, sess.sid)
            return False
        buf = wire.encode_hello_ack(wire.HelloAck(
            sess.sid, sess.lo, self.max_len, shm_path=arena.path,
            ring_bytes=arena.ring_bytes, db_kind=arena.db_kind))
        try:
            n = socket.send_fds(sess.conn, [buf], arena.fds())
        except OSError as e:
            arena.close()
            log.warning("SCM_RIGHTS send failed (%s); session %d stays on "
                        "wire", e, sess.sid)
            return False
        arena.sent()  # fds are kernel-referenced in flight: unlink now
        sess.shm_arena = arena
        self.metrics.inc("bytes_tx", n)
        self.metrics.inc("tx_flushes")
        if n < len(buf):  # partial ack frame: finish on the normal path
            sess.out.append(buf[n:])
            self._flush(sess)
        return True

    def _shm_teardown(self, sess: Session) -> None:
        arena = sess.shm_arena
        if arena is None:
            return
        try:
            self._sel.unregister(arena.peer.fileno())
        except (KeyError, ValueError):
            pass
        arena.close()
        sess.shm_arena = None
        sess.shm_live = False
        sess.shm_out.clear()

    def _drop(self, sess: Session) -> None:
        self._shm_teardown(sess)
        try:
            self._sel.unregister(sess.conn)
        except (KeyError, ValueError):
            pass
        try:
            sess.conn.close()
        except OSError:
            pass
        released = sess.lo >= 0
        if released:
            self._release(sess.lo, sess.batch)
            # _drop can re-enter for the same session (the BYE handler
            # flushes then drops, and the flush itself drops on a broken
            # pipe when the peer closed first): releasing twice would
            # duplicate free ranges and later double-lease rows to two
            # tenants — mark the lease gone
            sess.lo = -1
        self._sessions.pop(sess.conn, None)
        self._pending = [p for p in self._pending if p[0] is not sess]
        # BYE/disconnect defrag: keep the freed rows one contiguous tail.
        # Deferred while catch-up requests are queued — the compaction
        # permutes the whole super-batch cache on the reactor thread, and
        # co-resident clients' replays must not stall behind it (a
        # fragmented map is still compacted lazily at the next HELLO that
        # needs it, see ``_handle``)
        if released and len(self._free) > 1 and not self._pending:
            self._defrag()

    def _accept(self) -> None:
        while True:
            try:
                conn, _ = self._listener.accept()
            except (BlockingIOError, InterruptedError):
                return
            except OSError:
                return
            conn.setblocking(False)
            if conn.family == socket.AF_INET:
                conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            sess = Session(self._next_sid, conn)
            self._next_sid += 1
            self._sessions[conn] = sess
            self._sel.register(conn, selectors.EVENT_READ, "conn")

    def _read(self, sess: Session) -> None:
        while True:
            try:
                data = sess.conn.recv(1 << 16)
            except (BlockingIOError, InterruptedError):
                return
            except OSError:
                self._drop(sess)
                return
            if not data:
                self._drop(sess)
                return
            self.metrics.inc("bytes_rx", len(data))
            try:
                payloads = sess.reader.feed(data)
                for p in payloads:
                    if sess.conn not in self._sessions:
                        return  # dropped mid-batch (BYE/protocol error)
                    self._handle(sess, wire.decode(p))
            except wire.WireError as e:
                try:
                    self._send(sess, wire.encode_error(str(e)))
                finally:
                    self._drop(sess)
                return

    # -- protocol ------------------------------------------------------------
    def _handle(self, sess: Session, msg: wire.Message) -> None:
        if isinstance(msg, wire.Hello):
            if self.draining:
                # a REFUSAL, not a death: the client sees HandshakeRefused
                # and tries a sibling (the router stopped advertising us)
                self.metrics.inc("refused_draining")
                self._send(sess, wire.encode_error(
                    "draining: no new sessions"))
                return
            if sess.lo >= 0:
                self._send(sess, wire.encode_error("duplicate HELLO"))
                return
            if msg.max_len > self.max_len:
                self._send(sess, wire.encode_error(
                    f"client max_len {msg.max_len} > server {self.max_len}"))
                return
            if msg.tok_tail != self.tok_tail:
                self._send(sess, wire.encode_error(
                    f"token tail {msg.tok_tail} != server {self.tok_tail}"))
                return
            lo = self._alloc(msg.batch)
            if lo < 0 and len(self._free) > 1 \
                    and sum(h - l for l, h in self._free) >= msg.batch:
                # enough rows free in total, just fragmented: compact and
                # retry — a HELLO that fits is never refused for holes
                self._defrag()
                lo = self._alloc(msg.batch)
            if lo < 0:
                self._send(sess, wire.encode_error(
                    f"server full: {msg.batch} slots requested, "
                    f"{sum(h - l for l, h in self._free)} free of {self.slots}"))
                return
            sess.lo, sess.batch = lo, msg.batch
            sess.max_len = msg.max_len
            sess.coalesce = bool(msg.coalesce) and self.coalesce
            sess.client = msg.client
            self._reset_rows(lo, lo + msg.batch)
            self.metrics.inc("sessions")
            if (self.shm and msg.shm
                    and sess.conn.family == socket.AF_UNIX):
                if self._offer_shm(sess):
                    return
            self._send(sess, wire.encode_hello_ack(
                wire.HelloAck(sess.sid, lo, self.max_len)))
        elif isinstance(msg, wire.ShmOpen):
            # the client's verdict on our arena offer: ok moves data
            # frames to the rings (register the doorbell with the
            # reactor); a decline tears the arena down — the session
            # continues pure-wire either way
            if sess.shm_arena is None:
                self._send(sess, wire.encode_error("SHM_OPEN without offer"))
                self._drop(sess)
                return
            if msg.ok:
                sess.shm_live = True
                self.metrics.inc("shm_sessions")
                self._sel.register(sess.shm_arena.peer.fileno(),
                                   selectors.EVENT_READ, ("shm", sess))
            else:
                log.info("session %d declined shm offer; staying on wire",
                         sess.sid)
                self._shm_teardown(sess)
        elif isinstance(msg, wire.WireRequest):
            if sess.lo < 0:
                self._send(sess, wire.encode_error("request before HELLO"))
                return
            bad = self._validate_request(sess, msg)
            if bad is not None:
                # a geometry violation is a protocol breach: reject AND
                # drop, so a buggy client can never reach rows outside
                # its lease or crash the shared replay
                self._send(sess, wire.encode_error(bad))
                self._drop(sess)
                return
            self._pending.append((sess, msg, time.monotonic()))
        elif isinstance(msg, (wire.Attach, wire.Detach)):
            # slot-pool churn: one row of THIS session's lease turns over.
            # The client drains its pipeline before churning, so no
            # request of this session that references the row is queued;
            # other sessions cannot reference it at all (lease geometry).
            if sess.lo < 0:
                self._send(sess, wire.encode_error("churn before HELLO"))
                self._drop(sess)
                return
            if not 0 <= msg.slot < sess.batch:
                self._send(sess, wire.encode_error(
                    f"churn slot {msg.slot} outside lease batch "
                    f"({sess.batch},)"))
                self._drop(sess)
                return
            row = sess.lo + msg.slot
            self._reset_rows(row, row + 1)
            self.metrics.inc("attaches" if isinstance(msg, wire.Attach)
                             else "detaches")
        elif isinstance(msg, wire.Bye):
            self._flush(sess)
            self._drop(sess)
        elif isinstance(msg, wire.Error):
            self._drop(sess)
        # HelloAck / WireReply from a client are protocol violations;
        # drop silently rather than crash the loop
        else:
            self._drop(sess)

    def _validate_request(self, sess: Session,
                          req: wire.WireRequest) -> Optional[str]:
        """Geometry check against the session's lease — every index the
        replay will touch must be inside it.  Returns an error string, or
        None when the request is well-formed."""
        B = sess.batch
        if (req.triggered.shape != (B,) or req.server_pos.shape != (B,)
                or req.u.shape != (B,)):
            return (f"request vectors {req.triggered.shape}/"
                    f"{req.server_pos.shape}/{req.u.shape} != session "
                    f"batch ({B},)")
        if not 0 <= req.t < sess.max_len:
            return f"trigger step {req.t} outside [0, {sess.max_len})"
        if req.triggered.any():
            pos = req.server_pos[req.triggered]
            if (pos < 0).any() or (pos > req.t).any():
                return "server_pos outside [0, t] on a triggered stream"
        want = (int(req.backlog_lengths().sum()),) + self.tok_tail
        if req.tokens.shape != want:
            return f"token payload shape {req.tokens.shape} != {want}"
        return None

    # -- the replay core -----------------------------------------------------
    def _replay(self, group: List[Tuple[Session, wire.WireRequest, float]]
                ) -> None:
        """One masked catch-up over the union of the group's requests,
        then one reply per request (arrival order)."""
        S = self.slots
        trig = np.zeros(S, bool)
        pos = np.zeros(S, np.int32)
        tvec = np.zeros(S, np.int32)
        uvec = np.zeros(S, np.float32)
        for sess, req, _ in group:
            lengths = req.backlog_lengths()
            off = 0
            for i in np.flatnonzero(req.triggered):
                L = int(lengths[i])
                gi = sess.lo + int(i)
                p = int(req.server_pos[i])
                self._history[gi, p:req.t + 1] = req.tokens[off:off + L]
                off += L
                if trig[gi]:
                    pos[gi] = min(pos[gi], p)
                else:
                    pos[gi] = p
                trig[gi] = True
                if req.t >= tvec[gi]:
                    tvec[gi] = req.t
                    uvec[gi] = req.u[i]
        t0 = time.monotonic()
        cache, v, _ = self._eng._catchup(
            self._eng.params, self._cache, jnp.asarray(self._history),
            jnp.asarray(pos), jnp.asarray(tvec), jnp.asarray(trig),
            jnp.asarray(uvec))
        v = jax.block_until_ready(v)
        self._cache = cache
        dt = time.monotonic() - t0
        v_np = np.asarray(v)
        self.metrics.inc("replays")
        self.metrics.inc("requests", len(group))
        if len(group) > 1:
            self.metrics.inc("coalesced", len(group) - 1)
        hist = self.metrics.hists
        hist["replay_s"].observe(max(dt, 1e-9))
        hist["coalesce_width"].observe(len(group))
        if self.tracer is not None:
            self.tracer.add("server.replay", "server", t0, dt,
                            track="server", coalesced=len(group))
        now = time.monotonic()
        touched: Dict[int, Session] = {}
        for sess, req, arrived in group:
            # queue wait = arrival -> replay start: the duration-only v4
            # timing payload the client uses to split its measured RTT
            # into socket / queue / compute
            queue_s = max(t0 - arrived, 0.0)
            hist["queue_wait_s"].observe(max(queue_s, 1e-9))
            hist["turnaround_s"].observe(max(now - arrived, 1e-9))
            if self.tracer is not None:
                self.tracer.add("server.queue", "server", arrived, queue_s,
                                track="server", req_id=req.req_id)
            vi = v_np[sess.lo:sess.hi]
            fhat = np.asarray(self._fuse(jnp.asarray(req.u),
                                         jnp.asarray(vi),
                                         jnp.asarray(req.triggered)))
            self._send_reply(sess, wire.encode_reply(wire.WireReply(
                req.req_id, req.t, req.triggered, vi, fhat,
                server_time_s=dt / len(group), coalesced=len(group),
                queue_s=queue_s)))
            touched[sess.sid] = sess
        # ONE gathered flush per session for every reply this tick
        # queued (the micro-batching fix: k frames, one sendmsg)
        for sess in touched.values():
            if sess.conn in self._sessions and not sess.shm_live:
                self._flush(sess)

    def _process_pending(self) -> None:
        if not self._pending:
            return
        pending, self._pending = self._pending, []
        group = [p for p in pending if p[0].coalesce]
        if group:
            self._replay(group)
        for p in pending:
            if not p[0].coalesce:
                self._replay([p])

    # -- loop ----------------------------------------------------------------
    def serve_tick(self, timeout: float = 0.001) -> None:
        if self._drain_req.is_set() and not self.draining:
            self.start_drain()
        for key, mask in self._sel.select(timeout):
            if key.data == "accept":
                self._accept()
                continue
            if isinstance(key.data, tuple) and key.data[0] == "shm":
                # a session doorbell: ring traffic (requests in, and/or
                # reply-ring space freed) — no socket involved
                self._shm_wake(key.data[1])
                continue
            sess = self._sessions.get(key.fileobj)
            if sess is None:
                continue
            if mask & selectors.EVENT_READ:
                self._read(sess)
            if mask & selectors.EVENT_WRITE and sess.conn in self._sessions:
                self._flush(sess)
        self._process_pending()

    def serve_forever(self, *, poll_s: float = 0.001,
                      stop: Optional[threading.Event] = None,
                      idle_exit_s: Optional[float] = None) -> None:
        """Run until ``stop`` is set (or forever).  ``idle_exit_s``: exit
        once a session has existed and none remain for that long — test
        and bench hygiene for subprocess servers."""
        idle_since: Optional[float] = None
        while stop is None or not stop.is_set():
            self.serve_tick(poll_s)
            if self.tracker is not None:
                now = time.monotonic()
                if now - self._last_stats_log >= self.stats_interval_s:
                    self._last_stats_log = now
                    self.tracker.log(self.stats_snapshot())
            # a drained server with no sessions left has nothing to do:
            # exit so the supervisor can reap it without a kill
            if self.draining and not self._sessions:
                return
            if idle_exit_s is not None:
                if self._sessions or self.stats["sessions"] == 0:
                    idle_since = None
                elif idle_since is None:
                    idle_since = time.monotonic()
                elif time.monotonic() - idle_since > idle_exit_s:
                    return

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for sess in list(self._sessions.values()):
            self._drop(sess)
        try:
            self._sel.unregister(self._listener)
        except (KeyError, ValueError):
            pass
        self._listener.close()
        self._sel.close()
        if self.tracker is not None:
            try:
                self.tracker.finish()
            except OSError:
                pass
        if self.uds is not None:
            import os
            try:
                os.unlink(self.uds)
            except OSError:
                pass

"""Adaptive triggering: the threshold as an online, per-stream policy —
plus the three-rung cascade built by composing two ``MonitorSession``s.

The paper fixes the trigger threshold at one calibrated operating point
(Fig. 4).  The hierarchical-inference line (arXiv 2304.00891,
2304.11763) treats edge offload as an *online decision problem*: each
stream's margin distribution drifts, so the threshold should too.  This
module makes that a first-class serving concern:

  * ``TriggerPolicy``  — the controller interface.  A policy owns the
    per-stream effective trigger points ``tau[i]`` (the engine triggers
    stream i when ``u_i > tau[i]``); the session reads
    ``step_thresholds()`` before every step and feeds the step's
    ``u``/``fhat``/trigger outcome back through ``update``.  Thresholds
    are DATA, not structure: the engine's jitted paths never retrace on
    policy motion (guarded by ``MonitorSession.arm_recompile_guard``).
  * ``FixedPolicy``    — today's behavior, bitwise-identical to a
    policy-free session (the regression anchor: ``tau[i]`` is exactly
    the float32 the scalar comparison used to produce).
  * ``QuantilePolicy`` — per-stream running-quantile tracker: ``tau[i]``
    rides the ``1 - target_rate`` quantile of stream i's recent u
    window, holding each stream near a trigger-rate budget.
  * ``BudgetPolicy``   — AIMD controller that holds a false-negative
    proxy budget at minimum comms, consuming the per-stream
    ``CommsMeter`` windowed trigger-rate gauge as its comms feedback.
  * ``CascadeSession`` — edge -> regional corrector -> central
    corrector: two ``MonitorSession``s composed into a three-rung
    topology where the regional tier's RESIDUAL margin drives its own
    escalation policy to the central tier, each hop metered in a
    distinct comms bucket (``report()["tier1"]`` / ``["tier2"]``).

SAFETY ARGUMENT (why threshold motion cannot create false negatives).
The sign certificates (``analysis/signs.py``) prove ``corr >= 0`` and
``fhat <= u`` for the catch-up REGARDLESS of when corrections are
requested — the trigger threshold only selects *when* the server is
consulted, never the corrector's sign.  Because ``u`` is an upper bound
on the monitored score, an alarm candidate (``u`` above the alarm level)
that a raised threshold leaves unconsulted STANDS as a raw alarm — a
possible false positive, never a suppressed warning.  Controllers
therefore treat raising ``tau`` (fewer consults, more comms saved) as
the move that needs evidence, and keep two hard rules:

  * the calibrated operating point ``tau0 = threshold - margin`` is a
    FLOOR — policies only ever raise above it;
  * when recent-margin evidence is thin (cold stream, stale window) or a
    controller's risk budget is blown, ``tau`` may only move in the
    fhat-conservative direction: multiplicative decay back toward the
    floor.

Controller state is CLIENT-HELD (it lives in the policy object next to
the session, like the token history): fleet failover replays a session
onto a sibling server without touching it, while ``attach`` of a fresh
stream cold-starts the slot's controller (no threshold leakage across
tenants).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import numpy as np

__all__ = ["TriggerPolicy", "FixedPolicy", "QuantilePolicy", "BudgetPolicy",
           "CascadeSession"]


def _tau0_of(threshold: float, margin: float) -> np.float32:
    """The engine's scalar comparison was ``u > f32(threshold - margin)``
    (the Python-float difference weak-cast to f32 by jnp) — reproduce
    that exact float so a FixedPolicy vector compares bitwise."""
    return np.float32(threshold - margin)


class TriggerPolicy:
    """Base class / interface for per-stream threshold controllers.

    Lifecycle: the session ``bind``s the policy to the engine's
    calibrated operating point (threshold, margin, batch) at open, then
    per step::

        tau = policy.step_thresholds()     # (B,) f32, engine triggers u > tau
        ...engine steps...
        policy.update(u, fhat, triggered, active, meter)

    ``reset_stream(slot)`` cold-starts one slot's controller (called on
    ``attach``).  Subclasses override ``_reset_slot_state`` and
    ``_update``; the base class owns the tau buffer and the floor.
    """

    name = "policy"

    def bind(self, *, threshold: float, margin: float,
             batch: int) -> "TriggerPolicy":
        self._gamma = np.float32(threshold)     # the alarm level (paper gamma)
        self._tau0 = _tau0_of(threshold, margin)  # calibrated floor
        self._batch = int(batch)
        self._tau = np.full(batch, self._tau0, np.float32)
        self.reset()
        return self

    @property
    def is_bound(self) -> bool:
        return hasattr(self, "_tau")

    @property
    def tau0(self) -> float:
        return float(self._tau0)

    def reset(self) -> None:
        for slot in range(self._batch):
            self.reset_stream(slot)

    def reset_stream(self, slot: int) -> None:
        """Cold controller for ``slot``: threshold back at the calibrated
        floor, all per-stream evidence dropped."""
        self._tau[slot] = self._tau0
        self._reset_slot_state(slot)

    def step_thresholds(self) -> np.ndarray:
        """(B,) float32 effective trigger points for the NEXT step."""
        return self._tau

    def update(self, u, fhat, triggered, active, meter=None) -> None:
        """Feed one step's outcome back.  ``u``/``fhat``: (B,) scores;
        ``triggered``/``active``: (B,) bool; ``meter``: the engine's
        ``CommsMeter`` (windowed per-stream trigger-rate feedback)."""
        self._update(np.asarray(u, np.float32), np.asarray(fhat, np.float32),
                     np.asarray(triggered, bool), np.asarray(active, bool),
                     meter)
        # the floor is an invariant, not a convention subclasses must keep
        np.maximum(self._tau, self._tau0, out=self._tau)

    def state(self) -> Dict[str, Any]:
        """Introspection snapshot (tests, benches, docs)."""
        return {"name": self.name, "tau": self._tau.copy(),
                "tau0": float(self._tau0)}

    # -- subclass hooks ------------------------------------------------------
    def _reset_slot_state(self, slot: int) -> None:
        pass

    def _update(self, u, fhat, triggered, active, meter) -> None:
        pass


class FixedPolicy(TriggerPolicy):
    """The paper's fixed operating point as a (degenerate) policy: every
    stream's tau stays pinned at the calibrated floor.  Bitwise-identical
    to a policy-free session on all four session paths (the regression
    anchor, asserted in tests/test_policy.py)."""

    name = "fixed"


class QuantilePolicy(TriggerPolicy):
    """Per-stream running margin-quantile tracker.

    Holds each stream near a trigger-rate budget: ``tau[i]`` tracks the
    ``1 - target_rate`` quantile of stream i's last ``window`` u values,
    floored at the calibrated ``tau0``.  Cold streams (fewer than
    ``min_samples`` observations — thin evidence) sit AT the floor: the
    conservative direction.

    target_rate — per-stream trigger-rate budget (fraction of steps).
    window      — u observations retained per stream.
    min_samples — observations before tau may leave the floor.
    """

    name = "quantile"

    def __init__(self, target_rate: float = 0.1, *, window: int = 64,
                 min_samples: int = 16):
        if not 0.0 < target_rate <= 1.0:
            raise ValueError("target_rate must be in (0, 1]")
        if window < 1 or min_samples < 1:
            raise ValueError("window and min_samples must be >= 1")
        self.target_rate = float(target_rate)
        self.window = int(window)
        self.min_samples = int(min_samples)

    def bind(self, **kw) -> "QuantilePolicy":
        b = kw["batch"]
        self._uwin = np.zeros((b, self.window), np.float32)
        self._n = np.zeros(b, np.int64)
        return super().bind(**kw)

    def _reset_slot_state(self, slot: int) -> None:
        self._uwin[slot] = 0.0
        self._n[slot] = 0

    def _update(self, u, fhat, triggered, active, meter) -> None:
        q = 1.0 - self.target_rate
        for i in np.flatnonzero(active):
            self._uwin[i, self._n[i] % self.window] = u[i]
            self._n[i] += 1
            k = min(int(self._n[i]), self.window)
            if k >= self.min_samples:
                self._tau[i] = np.quantile(self._uwin[i, :k], q)

    def state(self) -> Dict[str, Any]:
        return {**super().state(), "n_observed": self._n.copy(),
                "target_rate": self.target_rate}


class BudgetPolicy(TriggerPolicy):
    """AIMD controller: hold a false-negative proxy budget at minimum
    comms, consuming the ``CommsMeter``'s windowed per-stream
    trigger-rate gauge as comms feedback.

    The FN proxy is the windowed rate of UNCORRECTED ALARM CANDIDATES:
    steps where ``u`` crossed the alarm level gamma but the raised tau
    skipped the consult.  (Sign-safety means such a skip can only leave a
    false positive standing, never suppress a warning — see the module
    docstring — but each one is a correction the calibrated policy would
    have bought, so it is the honest risk proxy to budget.)

    Update rule, per active stream i (AIMD, floor ``tau0``):

      1. CONSERVATIVE-ONLY under thin evidence or a blown budget — if
         fewer than ``min_evidence`` consult margins (``gamma - fhat``
         on recent consulted steps) are in the window (cold stream: the
         controller has never seen what corrections buy here), or the
         FN proxy exceeds ``fn_budget``: multiplicative decay
         ``tau <- tau0 + (tau - tau0) * decay``.
      2. ADDITIVE INCREASE — else, while the meter's recent trigger rate
         sits above ``target_rate`` (the comms budget ceiling): raise
         ``tau`` by ``step`` (default: a quarter of the stream's recent
         u spread above the floor, so the raise is scale-free).
      3. otherwise hold.

    (A raised tau converts would-be consults into skips, never alarms
    into silence: ``fhat = u`` on a skipped candidate keeps the alarm
    raised — see the module safety argument.  The skip-rate budget is
    therefore a COST budget on foregone corrections, and the controller
    needs no separate alarm-proximity brake.)

    target_rate  — comms budget: windowed per-stream trigger-rate
                   ceiling the controller works down toward.
    fn_budget    — windowed uncorrected-alarm-candidate budget.
    window       — evidence window (u values, skip indicators, margins).
    min_evidence — consult margins required before tau may rise.
    decay        — multiplicative return factor toward the floor.
    step         — additive raise; None = adaptive from the u window.
    """

    name = "budget"

    def __init__(self, target_rate: float = 0.1, *, fn_budget: float = 0.1,
                 window: int = 32, min_evidence: int = 4, decay: float = 0.5,
                 step: Optional[float] = None):
        if not 0.0 < target_rate <= 1.0:
            raise ValueError("target_rate must be in (0, 1]")
        if not 0.0 <= fn_budget <= 1.0:
            raise ValueError("fn_budget must be in [0, 1]")
        if not 0.0 <= decay < 1.0:
            raise ValueError("decay must be in [0, 1)")
        self.target_rate = float(target_rate)
        self.fn_budget = float(fn_budget)
        self.window = int(window)
        self.min_evidence = int(min_evidence)
        self.decay = float(decay)
        self.step = None if step is None else float(step)

    def bind(self, **kw) -> "BudgetPolicy":
        b, w = kw["batch"], self.window
        self._uwin = np.zeros((b, w), np.float32)
        self._skip = np.zeros((b, w), bool)   # uncorrected alarm candidates
        self._trig = np.zeros((b, w), bool)   # meterless rate fallback
        self._marg = np.full((b, w), np.inf, np.float32)  # consult margins
        self._n = np.zeros(b, np.int64)       # steps observed
        self._nm = np.zeros(b, np.int64)      # margins observed
        return super().bind(**kw)

    def _reset_slot_state(self, slot: int) -> None:
        self._uwin[slot] = 0.0
        self._skip[slot] = False
        self._trig[slot] = False
        self._marg[slot] = np.inf
        self._n[slot] = 0
        self._nm[slot] = 0

    def _update(self, u, fhat, triggered, active, meter) -> None:
        rates = None
        if meter is not None:
            rates = meter.recent_trigger_rate()
        for i in np.flatnonzero(active):
            w = int(self._n[i] % self.window)
            self._uwin[i, w] = u[i]
            self._skip[i, w] = bool(u[i] > self._gamma) and not triggered[i]
            self._trig[i, w] = bool(triggered[i])
            if triggered[i]:
                self._marg[i, self._nm[i] % self.window] = self._gamma - fhat[i]
                self._nm[i] += 1
            self._n[i] += 1
            k = min(int(self._n[i]), self.window)
            km = min(int(self._nm[i]), self.window)
            fn_proxy = float(self._skip[i, :self.window].sum()) / k if k else 0.0
            thin = km < self.min_evidence
            if thin or fn_proxy > self.fn_budget:
                # conservative-only motion under thin evidence / blown
                # skip budget
                self._tau[i] = self._tau0 + (self._tau[i] - self._tau0) * self.decay
            else:
                if rates is not None:
                    rate = float(rates[i])
                else:
                    # no meter: fall back to the policy's own window
                    rate = float(self._trig[i, :k].mean())
                if rate > self.target_rate:
                    if self.step is not None:
                        raise_by = self.step
                    else:
                        spread = float(self._uwin[i, :k].max()) - float(self._tau0)
                        raise_by = max(1e-4, 0.25 * max(spread, 0.0))
                    self._tau[i] = self._tau[i] + np.float32(raise_by)

    def state(self) -> Dict[str, Any]:
        k = np.minimum(np.maximum(self._n, 1), self.window)
        return {**super().state(), "n_observed": self._n.copy(),
                "n_margins": self._nm.copy(),
                "fn_proxy": self._skip.sum(axis=1) / k,
                "target_rate": self.target_rate,
                "fn_budget": self.fn_budget}


# ---------------------------------------------------------------------------
# Three-rung cascade: edge -> regional corrector -> central corrector
# ---------------------------------------------------------------------------

_FORCE = np.float32(-np.inf)     # u > -inf: consult unconditionally
_SUPPRESS = np.float32(np.inf)   # u > +inf: never consult


class CascadeSession:
    """Edge -> regional corrector -> central corrector: two
    ``MonitorSession``s composed into the paper's two-tier decomposition
    plus a third rung.

    Topology.  Both sessions share the SAME edge tower (same ``u``,
    asserted bitwise every step).  The tier-1 session runs the ordinary
    protocol against the REGIONAL corrector (its transport is hop 1).
    The regional tier's RESIDUAL margin — its corrected ``fhat1`` —
    drives an escalation policy: rows whose residual still crowds the
    escalation threshold are escalated to the CENTRAL corrector by
    forcing the tier-2 session's per-stream thresholds (``-inf`` =
    consult, ``+inf`` = stay local), reusing the same vector-threshold
    mechanism every policy uses.  The final report takes the TIGHTER of
    the two corrected scores on escalated rows (both are sign-safe upper
    bounds, so ``fhat <= u`` holds at every rung — asserted each step).

    Comms.  Each hop is metered in its own session's ``CommsMeter``;
    ``report()`` returns them as distinct ``tier1`` / ``tier2`` buckets.
    Escalation re-ships from the client-held history, so tier-2 bytes
    are real shipped-token charges, not estimates.

    Membership is FIXED for the cascade's lifetime (attach/detach of the
    composed sessions would desynchronize the tiers — refused loudly).

    tier1 / tier2 — two open-able ``MonitorSession``s over engines built
                    from the same params (any non-scan mode; tier2 must
                    not carry its own policy — the cascade drives it).
    escalation    — a ``TriggerPolicy`` evaluated on the tier-1 residual
                    ``fhat1`` (default ``FixedPolicy``), bound at
                    ``escalate_above``.
    escalate_above — the escalation threshold on ``fhat1``.
    """

    def __init__(self, tier1, tier2, *, escalate_above: float,
                 escalation: Optional[TriggerPolicy] = None):
        if tier1.config.mode == "scan" or tier2.config.mode == "scan":
            raise ValueError("cascade tiers must be online sessions "
                             "(sync/async), not scan")
        if tier2.config.policy is not None:
            raise ValueError(
                "tier2 carries SessionConfig.policy: the cascade drives the "
                "central tier's thresholds itself (escalation=...)")
        if tier1.engine.batch != tier2.engine.batch:
            raise ValueError(
                f"tier batch mismatch: {tier1.engine.batch} != "
                f"{tier2.engine.batch}")
        self.tier1, self.tier2 = tier1, tier2
        self.escalation = (escalation if escalation is not None
                           else FixedPolicy())
        self.escalation.bind(threshold=float(escalate_above), margin=0.0,
                             batch=tier1.engine.batch)
        self._n_escalated = 0

    # -- lifecycle -----------------------------------------------------------
    def __enter__(self) -> "CascadeSession":
        self.tier1.__enter__()
        self.tier2.__enter__()
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        try:
            self.tier1.close()
        finally:
            self.tier2.close()

    def attach(self, *a, **kw):
        raise RuntimeError("cascade membership is fixed: attach/detach "
                           "would desynchronize the tiers")

    detach = attach

    @property
    def streams(self):
        return self.tier1.streams

    # -- serving -------------------------------------------------------------
    def step(self, tokens) -> Dict[str, Any]:
        """One cascade step: tier-1 protocol step, escalation decision on
        the residual, forced tier-2 consult on escalated rows.  Returns
        the merged ``fhat`` plus both tiers' traces and the escalation
        mask.  ``fhat <= u`` is asserted at every rung."""
        r1 = self.tier1.step(tokens)
        u1, fhat1 = r1["u"], r1["fhat"]
        active = self.tier1.engine.active
        if not (fhat1 <= u1).all():
            raise AssertionError("tier1 violated fhat <= u")
        # escalation: the regional tier's residual margin vs its policy
        tau_esc = self.escalation.step_thresholds()
        esc = (fhat1 > tau_esc) & active
        # drive tier2 through the same per-stream vector-threshold
        # mechanism: escalated rows consult unconditionally, the rest
        # never do (thresholds are data — no retrace)
        self.tier2.engine._thr_eff = np.where(esc, _FORCE, _SUPPRESS)
        r2 = self.tier2.step(tokens)
        u2, fhat2 = r2["u"], r2["fhat"]
        if not np.array_equal(u2, u1):
            raise AssertionError(
                "cascade tiers disagree on u: both tiers must share the "
                "same edge tower (build both engines from the same params)")
        if not (fhat2 <= u2).all():
            raise AssertionError("tier2 violated fhat <= u")
        self.escalation.update(fhat1, fhat1, esc, active,
                               self.tier2.engine.comms)
        self._n_escalated += int(esc.sum())
        # both corrected scores are sign-safe upper bounds: take the
        # tighter one where the central tier was consulted
        fhat = np.where(esc, np.minimum(fhat1, fhat2), fhat1)
        if not (fhat <= u1).all():
            raise AssertionError("cascade violated fhat <= u")
        return {"u": u1, "fhat": fhat, "fhat_tier1": fhat1,
                "fhat_tier2": fhat2, "triggered": r1["triggered"],
                "escalated": esc, "streams": r1["streams"]}

    def run(self, token_stream) -> Dict[str, Any]:
        """Serve a full fixed stream through the cascade; returns stacked
        traces plus the per-tier comms report."""
        S = token_stream.shape[1]
        outs = []
        try:
            for t in range(S):
                outs.append(self.step(np.asarray(token_stream[:, t])))
        finally:
            self.close()
        stacked = {k: np.stack([o[k] for o in outs], 1)
                   for k in ("u", "fhat", "fhat_tier1", "fhat_tier2",
                             "triggered", "escalated")}
        stacked["streams"] = self.streams
        stacked["comms"] = self.report()
        return stacked

    def report(self) -> Dict[str, Any]:
        """Per-hop comms: ``tier1`` = edge->regional, ``tier2`` =
        regional->central (shipped from the client-held history)."""
        return {"tier1": self.tier1.report(), "tier2": self.tier2.report(),
                "escalated_steps": self._n_escalated}

from repro.serving import async_rpc, collaborative, engine, wire  # noqa: F401

# repro.serving.server is imported lazily (it builds jitted engines at
# construction; import it explicitly to run a correction server)

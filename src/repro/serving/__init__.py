from repro.serving import async_rpc, collaborative, engine  # noqa: F401

"""Serving: the public entrypoint is the session API (``serving/api.py``)
— build a ``CollaborativeEngine`` (params + caches + protocol state),
then serve through a ``MonitorSession``:

    from repro.serving import MonitorSession, SessionConfig, TransportSpec

``repro.serving.server`` (the standalone correction server) is imported
lazily: it builds jitted engines at construction; import it explicitly
to run one.  Mesh-sharded serving (``SessionConfig(mesh="data:8")``)
lives in ``repro.serving.mesh`` — see docs/sharding.md.  A fleet of
correction servers behind a routing supervisor
(``TransportSpec.parse("fleet:<router>")``) lives in
``repro.serving.fleet`` — see docs/fleet.md; like ``server`` it is
imported lazily (its subprocess backend pulls in the launcher).
Metrics trackers (the per-server heartbeat/stats surface) are in
``repro.serving.tracker``.  Adaptive triggering — per-stream online
threshold policies (``SessionConfig(policy=...)``) and the three-rung
``CascadeSession`` — lives in ``repro.serving.policy``; see
docs/policy.md.
"""
from repro.serving import async_rpc, collaborative, engine, mesh, tracker, wire  # noqa: F401,E501
from repro.serving.api import (MonitorSession, SessionConfig,  # noqa: F401
                               TransportSpec)
from repro.serving.collaborative import CollaborativeEngine  # noqa: F401
from repro.serving.policy import (BudgetPolicy, CascadeSession,  # noqa: F401
                                  FixedPolicy, QuantilePolicy,
                                  TriggerPolicy)
from repro.serving.tracker import (CompositeTracker, Histogram,  # noqa: F401
                                   InMemoryTracker, JsonFileTracker,
                                   LogTracker, NoopTracker, Tracker)

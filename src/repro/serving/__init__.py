from repro.serving import collaborative, engine  # noqa: F401

"""Wire codec for the collaborative protocol: a versioned binary framing
of ``CatchupRequest``/``CatchupReply`` plus the session-control messages
(HELLO / HELLO_ACK / BYE / ERROR) that the standalone correction server
(``serving/server.py``) and the ``wire`` transport
(``async_rpc.SocketWorker``) exchange across a real serialization
boundary.

Design constraints (the reason this module exists, rather than pickle):

* **No pickle.**  Frames are plain ``struct``-packed little-endian bytes
  with explicitly-coded numpy arrays (dtype code + shape + raw C-order
  buffer).  A hostile/buggy peer can produce a ``WireError``, never code
  execution, and the byte layout is stable across Python versions.
* **Length-prefixed frames.**  Every message travels as
  ``[u32 length][payload]`` so a stream socket can be re-framed
  incrementally (``FrameReader``) with no sentinels inside the payload.
* **Backlogs, not histories.**  The in-process ``CatchupRequest`` carries
  the full on-device token-history snapshot because jnp arrays make the
  snapshot free.  On the wire only the protocol-relevant bytes move: each
  triggered stream's backlog slice ``history[i, server_pos[i] : t+1]``,
  concatenated.  That makes bytes-on-the-wire proportional to the tokens
  the paper says must ship — the measured counterpart of the
  ``CommsMeter`` token-level model (``TOKEN_BYTES`` per token), so the
  Fig-4 reduction can be *measured* instead of asserted.
* **Byte accounting.**  Every encode returns a complete frame whose
  length is the exact number of bytes handed to the kernel; the transport
  feeds those counts (tx and rx) into ``CommsMeter.record_wire_tx/rx``.

Frame payload layout (all little-endian)::

    u16 magic (0xC0AB)  | u8 version (3) | u8 msg_type | body

Arrays are encoded as ``u8 dtype_code | u8 ndim | u32 dims... | raw``.
See ``docs/transport.md`` for the full wire-format table.

Version history: v2 added the slot-pool churn frames ATTACH/DETACH
(``MonitorSession.attach``/``detach`` over the wire: the server zeroes
and re-leases a single super-batch row without disturbing co-resident
clients).  v3 added the fleet-control frames REDIRECT (a router answers
a HELLO with the address of the least-loaded live server — the client
re-HELLOs there) and GOAWAY (a draining server asks its sessions to
finish in-flight work and move to a sibling; see ``serving/fleet.py``
and docs/fleet.md).  v4 appends an OPTIONAL server-timing payload to
REPLY (``queue_s``: request arrival -> replay start on the server —
durations only, so no clock sync between the processes is needed);
together with the existing ``server_time_s``/``coalesced`` fields the
client assembles the full RTT breakdown (serialize / socket / queue /
compute) for the observability layer (docs/observability.md).  v5 added
the same-host shared-memory transport negotiation: HELLO grows an
OPTIONAL trailing ``u8 shm`` request byte, HELLO_ACK an OPTIONAL
trailing shm offer (arena path + ring geometry + doorbell kind — the
arena/doorbell fds themselves ride the same UDS via SCM_RIGHTS), and
SHM_OPEN confirms (or declines) the mapping so the server knows whether
data frames move to the rings (``serving/shm.py``, docs/transport.md).
Data frames over the ring use this exact codec unchanged — the rings
carry the same length-prefixed byte stream a socket would.

Compatibility: the decoder accepts any version in
``[MIN_VERSION, VERSION]`` — a v3 REPLY simply has no timing payload
(``queue_s`` reports -1, "absent"), a v3/v4 HELLO simply requests no
shm, and every other frame body is unchanged since v3, so v3..v5 peers
interoperate in both directions (shm engages only when both ends speak
it AND share a host).
Versions below ``MIN_VERSION`` (or above ``VERSION``) are rejected
loudly on BOTH sides — a v1 peer gets an ERROR frame naming the
versions, never silent misinterpretation.
"""
from __future__ import annotations

import math
import socket
import struct
import time
from dataclasses import dataclass
from typing import List, Optional, Tuple, Union

import numpy as np

MAGIC = 0xC0AB
VERSION = 5      # v5: shm negotiation (HELLO/HELLO_ACK tails, SHM_OPEN)
MIN_VERSION = 3  # oldest peer version still decoded (frame-compatible)

MSG_HELLO = 1
MSG_HELLO_ACK = 2
MSG_REQUEST = 3
MSG_REPLY = 4
MSG_BYE = 5
MSG_ERROR = 6
MSG_ATTACH = 7
MSG_DETACH = 8
MSG_REDIRECT = 9
MSG_GOAWAY = 10
MSG_SHM_OPEN = 11

_HEADER = struct.Struct("<HBB")       # magic, version, msg_type
_LEN = struct.Struct("<I")            # frame length prefix
MAX_FRAME_BYTES = 64 * 1024 * 1024    # hard cap against garbage prefixes

# dtype registry: stable small codes, no pickle/np dtype-string parsing
_DTYPES: Tuple[np.dtype, ...] = tuple(np.dtype(d) for d in (
    np.bool_, np.int8, np.uint8, np.int16, np.int32, np.int64,
    np.float16, np.float32, np.float64))
_DTYPE_CODE = {d: i for i, d in enumerate(_DTYPES)}


class WireError(Exception):
    """Malformed frame / protocol violation / server-reported error."""


class HandshakeRefused(WireError):
    """The peer ANSWERED the handshake with an ERROR frame: a deliberate
    refusal (server full, draining, version mismatch).  Retrying the same
    address is pointless — a fleet client should try a sibling instead.
    ``message`` carries the server's reason verbatim."""

    def __init__(self, message: str):
        super().__init__(f"server: {message}")
        self.message = message


class PeerGone(WireError):
    """The connection died MID-handshake (EOF / reset before any ACK or
    ERROR arrived): the server crashed or was killed.  Distinct from
    ``HandshakeRefused`` so the router/supervisor can mark the server
    unhealthy rather than merely loaded."""


# -- primitives --------------------------------------------------------------

def _pack_array(a: np.ndarray) -> bytes:
    a = np.ascontiguousarray(a)
    if a.dtype not in _DTYPE_CODE:
        raise WireError(f"unsupported wire dtype {a.dtype}")
    head = struct.pack("<BB", _DTYPE_CODE[a.dtype], a.ndim)
    dims = struct.pack(f"<{a.ndim}I", *a.shape) if a.ndim else b""
    return head + dims + a.tobytes()


def _unpack_array(buf: bytes, off: int) -> Tuple[np.ndarray, int]:
    try:
        code, ndim = struct.unpack_from("<BB", buf, off)
        off += 2
        shape = struct.unpack_from(f"<{ndim}I", buf, off) if ndim else ()
        off += 4 * ndim
        dtype = _DTYPES[code]
        n = math.prod(shape)  # python ints: no fixed-width overflow
        nbytes = n * dtype.itemsize
        if nbytes > MAX_FRAME_BYTES or off + nbytes > len(buf):
            raise WireError("array extends past frame end")
        a = np.frombuffer(buf, dtype=dtype, count=n, offset=off).reshape(shape)
        off += nbytes
        return a.copy(), off  # copy: detach from the recv buffer
    except (struct.error, IndexError, ValueError) as e:
        raise WireError(f"malformed array: {e}") from e


def _pack_str(s: str) -> bytes:
    b = s.encode("utf-8")
    return struct.pack("<H", len(b)) + b


def _unpack_str(buf: bytes, off: int) -> Tuple[str, int]:
    try:
        (n,) = struct.unpack_from("<H", buf, off)
        off += 2
        return buf[off:off + n].decode("utf-8"), off + n
    except (struct.error, UnicodeDecodeError) as e:
        raise WireError(f"malformed string: {e}") from e


def frame(payload: bytes) -> bytes:
    """Length-prefix a payload: the exact bytes that hit the socket."""
    return _LEN.pack(len(payload)) + payload


def _header(msg_type: int) -> bytes:
    return _HEADER.pack(MAGIC, VERSION, msg_type)


# -- messages ----------------------------------------------------------------

@dataclass
class Hello:
    """Session open: the client declares its stream-batch geometry.

    ``coalesce=False`` opts this session out of the server's request
    coalescing (each request gets its own masked replay) — the bench's
    per-request baseline arm.

    ``shm=True`` (v5) asks the server for a same-host shared-memory ring
    pair (``serving/shm.py``); a pre-v5 (or wire-only) server simply
    ignores the trailing byte and the session stays pure-wire.
    """

    batch: int
    max_len: int
    tok_tail: Tuple[int, ...] = ()   # (K,) for audio codebooks, else ()
    coalesce: bool = True
    client: str = "edge"
    shm: bool = False


@dataclass
class HelloAck:
    session_id: int
    slot_lo: int        # first super-batch row assigned to this session
    server_max_len: int
    version: int = VERSION
    # v5 shm offer (present iff ring_bytes > 0): the arena/doorbell fds
    # ride the SAME sendmsg as this frame via SCM_RIGHTS; ``shm_path``
    # is informational (the server unlinks it right after sending — the
    # client maps the received fd, so a SIGKILL leaks no file).
    shm_path: str = ""
    ring_bytes: int = 0
    db_kind: int = 0    # 0 = eventfd (1 fd/doorbell), 1 = pipe (2 fds)


@dataclass
class WireRequest:
    """The on-the-wire form of a ``CatchupRequest``: per-stream protocol
    vectors plus ONLY the backlog tokens (concatenated over triggered
    streams, in stream order) — not the full history snapshot."""

    req_id: int
    t: int
    triggered: np.ndarray    # (B,) bool
    server_pos: np.ndarray   # (B,) int32
    u: np.ndarray            # (B,) float32 — dispatch-time monitor scores
    tokens: np.ndarray       # (n_tok, *tok_tail) int32 — concatenated backlogs

    def backlog_lengths(self) -> np.ndarray:
        """(B,) tokens each stream contributes to ``tokens``."""
        return np.where(self.triggered,
                        self.t + 1 - self.server_pos, 0).astype(np.int64)


@dataclass
class WireReply:
    req_id: int
    t: int
    triggered: np.ndarray    # (B,) bool — echo of the request's mask
    v: np.ndarray            # (B,) float32, valid where triggered
    fhat: np.ndarray         # (B,) float32 fused from the request's u
    server_time_s: float     # replay compute time on the server
    coalesced: int = 1       # requests merged into the replay that served this
    # v4 server-timing payload: request arrival -> replay start on the
    # server (a DURATION — no clock sync needed).  < 0 means "absent"
    # (a v3 peer's reply); the client then reports RTT only, with no
    # serialize/socket/queue/compute breakdown for that request.
    queue_s: float = -1.0


@dataclass
class Bye:
    pass


@dataclass
class Attach:
    """Slot-pool churn: a new stream moved into row ``slot`` of this
    session's lease — zero and re-lease that single super-batch row
    (cache + history mirror), leaving co-resident rows bit-untouched."""

    slot: int


@dataclass
class Detach:
    """Slot-pool churn: the stream in row ``slot`` departed."""

    slot: int


@dataclass
class Redirect:
    """Fleet routing: the peer is a router, not a server — re-HELLO at
    ``address`` (the least-loaded live correction server)."""

    address: str


@dataclass
class GoAway:
    """Fleet drain: the server will take no new work; finish in-flight
    requests, then re-HELLO elsewhere and replay (``docs/fleet.md``)."""

    reason: str = "draining"


@dataclass
class ShmOpen:
    """Client verdict on the server's shm offer: ``ok=True`` moves data
    frames (REQUEST/REPLY) to the rings; ``ok=False`` (mmap failed,
    geometry mismatch) tears the arena down and the session continues
    pure-wire.  Control frames stay on the socket either way."""

    ok: bool


@dataclass
class Error:
    message: str


Message = Union[Hello, HelloAck, WireRequest, WireReply, Bye, Attach,
                Detach, Redirect, GoAway, ShmOpen, Error]


# -- encode ------------------------------------------------------------------

def encode_hello(h: Hello) -> bytes:
    body = struct.pack("<IIBB", h.batch, h.max_len, len(h.tok_tail),
                       1 if h.coalesce else 0)
    body += struct.pack(f"<{len(h.tok_tail)}I", *h.tok_tail)
    body += _pack_str(h.client)
    if h.shm:
        # v5 shm request: appended after the client string so a decoder
        # detects it by presence (a v3/v4-shaped frame ends earlier)
        body += struct.pack("<B", 1)
    return frame(_header(MSG_HELLO) + body)


def encode_hello_ack(a: HelloAck) -> bytes:
    body = struct.pack("<IIIB", a.session_id, a.slot_lo, a.server_max_len,
                       a.version)
    if a.ring_bytes > 0:
        # v5 shm offer: presence-detected tail (the fds travel in the
        # same sendmsg as SCM_RIGHTS ancillary data)
        body += (_pack_str(a.shm_path)
                 + struct.pack("<IB", a.ring_bytes, a.db_kind))
    return frame(_header(MSG_HELLO_ACK) + body)


def encode_shm_open(ok: bool) -> bytes:
    return frame(_header(MSG_SHM_OPEN) + struct.pack("<B", 1 if ok else 0))


def encode_request(req_id: int, t: int, triggered: np.ndarray,
                   server_pos: np.ndarray, u: np.ndarray,
                   history: np.ndarray) -> bytes:
    """Slice the triggered backlogs out of the (host) history snapshot and
    frame them.  ``history``: (B, max_len, *tok_tail) int32."""
    triggered = np.asarray(triggered, bool)
    server_pos = np.asarray(server_pos, np.int32)
    rows = np.flatnonzero(triggered)
    if len(rows):
        backlog = np.concatenate(
            [history[i, server_pos[i]:t + 1] for i in rows], axis=0)
    else:
        backlog = np.zeros((0,) + history.shape[2:], history.dtype)
    body = (struct.pack("<QI", req_id, t)
            + _pack_array(triggered)
            + _pack_array(server_pos)
            + _pack_array(np.asarray(u, np.float32))
            + _pack_array(np.asarray(backlog, np.int32)))
    return frame(_header(MSG_REQUEST) + body)


def encode_request_arrays(r: WireRequest) -> bytes:
    """Frame a WireRequest whose backlog tokens are already concatenated
    (codec round-trip tests; server-side re-encode)."""
    body = (struct.pack("<QI", r.req_id, r.t)
            + _pack_array(np.asarray(r.triggered, bool))
            + _pack_array(np.asarray(r.server_pos, np.int32))
            + _pack_array(np.asarray(r.u, np.float32))
            + _pack_array(np.asarray(r.tokens, np.int32)))
    return frame(_header(MSG_REQUEST) + body)


def encode_reply(r: WireReply) -> bytes:
    body = (struct.pack("<QIdI", r.req_id, r.t, r.server_time_s, r.coalesced)
            + _pack_array(np.asarray(r.triggered, bool))
            + _pack_array(np.asarray(r.v, np.float32))
            + _pack_array(np.asarray(r.fhat, np.float32)))
    if r.queue_s >= 0:
        # v4 timing payload: appended after the arrays so a decoder
        # detects it by presence (a v3-shaped frame simply ends earlier)
        body += struct.pack("<d", r.queue_s)
    return frame(_header(MSG_REPLY) + body)


def encode_bye() -> bytes:
    return frame(_header(MSG_BYE))


def encode_attach(slot: int) -> bytes:
    return frame(_header(MSG_ATTACH) + struct.pack("<I", slot))


def encode_detach(slot: int) -> bytes:
    return frame(_header(MSG_DETACH) + struct.pack("<I", slot))


def encode_redirect(address: str) -> bytes:
    return frame(_header(MSG_REDIRECT) + _pack_str(address))


def encode_goaway(reason: str = "draining") -> bytes:
    return frame(_header(MSG_GOAWAY) + _pack_str(reason))


def encode_error(message: str) -> bytes:
    return frame(_header(MSG_ERROR) + _pack_str(message))


# -- decode ------------------------------------------------------------------

def decode(payload: bytes) -> Message:
    """One frame payload (length prefix already stripped) -> message."""
    if len(payload) < _HEADER.size:
        raise WireError(f"short frame ({len(payload)} bytes)")
    magic, version, msg_type = _HEADER.unpack_from(payload, 0)
    if magic != MAGIC:
        raise WireError(f"bad magic 0x{magic:04x}")
    if not (MIN_VERSION <= version <= VERSION):
        raise WireError(f"wire version {version} outside supported "
                        f"[{MIN_VERSION}, {VERSION}]")
    off = _HEADER.size
    try:
        if msg_type == MSG_HELLO:
            batch, max_len, n_tail, coal = struct.unpack_from(
                "<IIBB", payload, off)
            off += struct.calcsize("<IIBB")
            tail = struct.unpack_from(f"<{n_tail}I", payload, off)
            off += 4 * n_tail
            client, off = _unpack_str(payload, off)
            # v5 shm-request byte, detected by presence (older frames end
            # at the client string)
            shm = off < len(payload) and payload[off] != 0
            return Hello(batch, max_len, tuple(tail), bool(coal), client,
                         shm)
        if msg_type == MSG_HELLO_ACK:
            sid, lo, sml, ver = struct.unpack_from("<IIIB", payload, off)
            off += struct.calcsize("<IIIB")
            shm_path, ring_bytes, db_kind = "", 0, 0
            if off < len(payload):  # v5 shm offer, presence-detected
                shm_path, off = _unpack_str(payload, off)
                ring_bytes, db_kind = struct.unpack_from("<IB", payload, off)
            return HelloAck(sid, lo, sml, ver, shm_path, ring_bytes, db_kind)
        if msg_type == MSG_REQUEST:
            req_id, t = struct.unpack_from("<QI", payload, off)
            off += struct.calcsize("<QI")
            triggered, off = _unpack_array(payload, off)
            server_pos, off = _unpack_array(payload, off)
            u, off = _unpack_array(payload, off)
            tokens, off = _unpack_array(payload, off)
            return WireRequest(req_id, t, triggered.astype(bool),
                               server_pos.astype(np.int32),
                               u.astype(np.float32),
                               tokens.astype(np.int32))
        if msg_type == MSG_REPLY:
            req_id, t, srv_s, coal = struct.unpack_from("<QIdI", payload, off)
            off += struct.calcsize("<QIdI")
            triggered, off = _unpack_array(payload, off)
            v, off = _unpack_array(payload, off)
            fhat, off = _unpack_array(payload, off)
            # v4 timing payload is detected by presence: a v3 frame (or a
            # v4 sender with timing disabled) simply ends after fhat
            queue_s = -1.0
            if off + 8 <= len(payload):
                (queue_s,) = struct.unpack_from("<d", payload, off)
            return WireReply(req_id, t, triggered.astype(bool),
                             v.astype(np.float32), fhat.astype(np.float32),
                             srv_s, coal, queue_s)
        if msg_type == MSG_BYE:
            return Bye()
        if msg_type == MSG_ATTACH:
            (slot,) = struct.unpack_from("<I", payload, off)
            return Attach(slot)
        if msg_type == MSG_DETACH:
            (slot,) = struct.unpack_from("<I", payload, off)
            return Detach(slot)
        if msg_type == MSG_REDIRECT:
            address, off = _unpack_str(payload, off)
            return Redirect(address)
        if msg_type == MSG_GOAWAY:
            reason, off = _unpack_str(payload, off)
            return GoAway(reason)
        if msg_type == MSG_SHM_OPEN:
            (ok,) = struct.unpack_from("<B", payload, off)
            return ShmOpen(bool(ok))
        if msg_type == MSG_ERROR:
            message, off = _unpack_str(payload, off)
            return Error(message)
    # the decode boundary converts EVERY parse failure to WireError: a
    # hostile/buggy peer must never crash a reactor with anything else
    except (struct.error, ValueError, IndexError, OverflowError) as e:
        raise WireError(f"malformed frame body: {e}") from e
    raise WireError(f"unknown message type {msg_type}")


class FrameReader:
    """Incremental re-framing of a byte stream: feed arbitrary chunks,
    get back complete frame payloads.  Tolerates any fragmentation the
    kernel produces (frames split across reads, many frames per read)."""

    def __init__(self) -> None:
        self._buf = bytearray()

    def feed(self, data: bytes) -> List[bytes]:
        self._buf.extend(data)
        out: List[bytes] = []
        while True:
            if len(self._buf) < _LEN.size:
                return out
            (n,) = _LEN.unpack_from(self._buf, 0)
            if n > MAX_FRAME_BYTES:
                raise WireError(f"frame length {n} exceeds cap")
            if len(self._buf) < _LEN.size + n:
                return out
            out.append(bytes(self._buf[_LEN.size:_LEN.size + n]))
            del self._buf[:_LEN.size + n]


# -- shared-memory rings -----------------------------------------------------
#
# One SPSC byte ring = a 128-byte header (u64 head cursor at +0, u64
# tail cursor at +64 — separate cache lines) followed by ``size`` data
# bytes.  Cursors increase monotonically and never wrap (u64 at ring
# throughput outlives the hardware); the data index is ``cursor % size``.
# The producer writes only ``head``, the consumer only ``tail`` — with
# one writer per cursor an 8-byte aligned store is the only
# synchronization needed (CPython's GIL orders the surrounding memcpys;
# see docs/transport.md for the safety argument).
#
# The rings carry the SAME length-prefixed byte stream a socket would:
# ``RingWriter.write`` is ``send`` (writes what fits, two memcpys across
# the wrap), ``RingReader.read`` is ``recv`` — so partial frames across
# the wrap point, frames larger than the ring, and backpressure all
# reduce to the stream semantics ``FrameReader`` already handles.

RING_HDR = 128          # u64 head @ +0, u64 tail @ +64
_CURSOR = struct.Struct("<Q")


class _RingSide:
    """Shared geometry/cursor plumbing for one ring over any writable
    buffer (an ``mmap`` arena or a plain ``bytearray`` in tests)."""

    def __init__(self, buf, offset: int, size: int):
        if size <= 0:
            raise WireError(f"ring size must be positive, got {size}")
        self._buf = buf
        self._head_off = offset
        self._tail_off = offset + 64
        self._data_off = offset + RING_HDR
        self.size = size

    def _load(self, off: int) -> int:
        return _CURSOR.unpack_from(self._buf, off)[0]

    def _store(self, off: int, value: int) -> None:
        _CURSOR.pack_into(self._buf, off, value)


class RingWriter(_RingSide):
    """Producer side: ``write`` as much of ``data`` as fits (0 when the
    ring is full — the caller loops like ``sendall``, waiting on the
    consumer's doorbell for space)."""

    def free(self) -> int:
        return self.size - (self._load(self._head_off)
                            - self._load(self._tail_off))

    def write(self, data) -> int:
        head = self._load(self._head_off)
        n = min(len(data), self.size - (head - self._load(self._tail_off)))
        if n <= 0:
            return 0
        i = head % self.size
        first = min(n, self.size - i)
        base = self._data_off
        self._buf[base + i:base + i + first] = bytes(data[:first])
        if n > first:  # wrap: the remainder lands at the ring start
            self._buf[base:base + (n - first)] = bytes(data[first:n])
        self._store(self._head_off, head + n)  # publish AFTER the copy
        return n


class RingReader(_RingSide):
    """Consumer side: ``read`` drains whatever is available (advancing
    ``tail`` frees the space), ``frames`` feeds it straight through an
    internal ``FrameReader`` so callers get complete frame payloads."""

    def __init__(self, buf, offset: int, size: int):
        super().__init__(buf, offset, size)
        self.reader = FrameReader()

    def available(self) -> int:
        return self._load(self._head_off) - self._load(self._tail_off)

    def read(self, limit: Optional[int] = None) -> bytes:
        tail = self._load(self._tail_off)
        n = self._load(self._head_off) - tail
        if limit is not None:
            n = min(n, limit)
        if n <= 0:
            return b""
        i = tail % self.size
        first = min(n, self.size - i)
        base = self._data_off
        out = bytes(self._buf[base + i:base + i + first])
        if n > first:
            out += bytes(self._buf[base:base + (n - first)])
        self._store(self._tail_off, tail + n)  # free AFTER the copy
        return out

    def frames(self) -> List[bytes]:
        data = self.read()
        return self.reader.feed(data) if data else []


# -- addressing --------------------------------------------------------------

def parse_address(address: str) -> Tuple[int, Union[str, Tuple[str, int]]]:
    """"/path/to.sock" -> (AF_UNIX, path); "host:port" -> (AF_INET, (h, p)).

    ``shm:ADDR`` strips the prefix and parses ADDR — the shared-memory
    transport's CONTROL channel is an ordinary socket (the rings are
    negotiated over it; ``serving/shm.py``), so a shm address is just a
    socket address wearing a transport hint."""
    if address.startswith("shm:"):
        return parse_address(address[len("shm:"):])
    if ":" in address and not address.startswith("/"):
        host, _, port = address.rpartition(":")
        return socket.AF_INET, (host or "127.0.0.1", int(port))
    return socket.AF_UNIX, address


def connect(address: str, *, timeout: Optional[float] = 20.0,
            retry_interval: float = 0.05) -> socket.socket:
    """Connect to a correction server, retrying until ``timeout`` (the
    server process may still be importing jax when the client starts)."""
    family, target = parse_address(address)
    deadline = None if timeout is None else time.monotonic() + timeout
    while True:
        sock = socket.socket(family, socket.SOCK_STREAM)
        try:
            sock.connect(target)
            if family == socket.AF_INET:
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            return sock
        except OSError:
            sock.close()
            if deadline is not None and time.monotonic() > deadline:
                raise
            time.sleep(retry_interval)


def connect_hello(address: str, hello: Hello, *,
                  timeout: Optional[float] = 20.0,
                  retry_interval: float = 0.05,
                  ) -> Tuple[socket.socket, HelloAck, "FrameReader",
                             int, int]:
    """Connect AND complete the HELLO handshake, distinguishing the two
    failure modes ``connect()`` used to conflate:

    * connection refused / EOF / reset before the ACK -> the server is
      (still) dead: keep retrying until ``timeout``, then raise
      ``PeerGone`` (mark-unhealthy signal for a fleet client).
    * an ERROR frame in answer to the HELLO -> the server is alive and
      REFUSING (full / draining / version skew): raise
      ``HandshakeRefused`` immediately — retrying the same address
      cannot help, but a sibling server might.

    Returns ``(sock, ack, reader, tx_bytes, rx_bytes)``; ``reader`` is
    the ``FrameReader`` holding any bytes that arrived after the ACK,
    and the byte counts cover everything this function put on / took off
    the socket (for ``CommsMeter`` accounting by the caller).
    """
    payload = encode_hello(hello)
    deadline = None if timeout is None else time.monotonic() + timeout
    while True:
        remaining = (None if deadline is None
                     else max(0.05, deadline - time.monotonic()))
        try:
            sock = connect(address, timeout=remaining,
                           retry_interval=retry_interval)
        except OSError as e:
            raise PeerGone(f"connect to {address!r} failed: {e}") from e
        tx = len(payload)
        reader = FrameReader()
        try:
            sock.sendall(payload)
            rx = 0
            msg: Optional[Message] = None
            while msg is None:
                chunk = sock.recv(65536)
                if not chunk:
                    raise PeerGone("server closed during handshake")
                rx += len(chunk)
                frames = reader.feed(chunk)
                if frames:
                    msg = decode(frames[0])
            if isinstance(msg, Error):
                sock.close()
                raise HandshakeRefused(msg.message)
            if isinstance(msg, Redirect):
                # one hop only: a router handing out another router is a
                # config error, surfaced by the recursive call's types
                sock.close()
                return connect_hello(msg.address, hello, timeout=remaining,
                                     retry_interval=retry_interval)
            if not isinstance(msg, HelloAck):
                sock.close()
                raise WireError(f"unexpected handshake reply: {msg}")
            return sock, msg, reader, tx, rx
        except (PeerGone, OSError) as e:
            # transient: the server died under us — retry until deadline
            sock.close()
            if deadline is not None and time.monotonic() > deadline:
                if isinstance(e, PeerGone):
                    raise
                raise PeerGone(f"handshake with {address!r} failed: {e}"
                               ) from e
            time.sleep(retry_interval)
        except WireError:
            sock.close()
            raise

"""Partition-rule engine: regex over param-tree key paths -> PartitionSpec.

Rules give *candidate* specs aligned to the TRAILING dims of each leaf
(stacked-layer leading axes are padded with None automatically).  The first
candidate whose named axes divide the corresponding dims is chosen;
otherwise the leaf replicates.  This one mechanism covers all 10 assigned
families — e.g. MoE experts shard expert-parallel where E % model == 0
(deepseek, 256) and fall back to d_ff tensor-parallel where not (mixtral, 8).

The monitor tower ('edge', 'u_head', 'v_head' subtrees) is ALWAYS
replicated over 'model' — the paper's device-locality requirement: the edge
path must not require model-axis collectives (asserted in tests by parsing
the lowered HLO of monitor_step).
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import AbstractMesh, Mesh, NamedSharding, PartitionSpec as P

from repro.nn.module import map_with_path


def abstract_mesh(axis_sizes: Sequence[int],
                  axis_names: Sequence[str]) -> AbstractMesh:
    """Version-portable AbstractMesh constructor.

    jax <= 0.4.35 took ``AbstractMesh(shape, axis_names)``; jax 0.4.36+
    takes a single ``shape_tuple`` of (name, size) pairs.  All in-repo
    device-free partition-rule checks go through this helper.
    """
    try:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))
    except TypeError:  # older positional (shape, names) signature
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))

# Candidate trailing-dim specs per path regex (first match wins; within a
# match, first divisible candidate wins).  "model" is the tensor axis;
# batch axes are handled by activation/batch specs, not these rules.
_RULES: List[Tuple[str, List[Tuple[Optional[str], ...]]]] = [
    # --- paper monitor tower: strictly replicated ---------------------------
    (r"(^|/)(edge|u_head|v_head)(/|$)", [()]),
    # --- embeddings ----------------------------------------------------------
    (r"embed/table$", [("model", None), (None, "model", None)]),
    # --- MoE (E, d, ff) / (E, ff, d): expert-parallel first, else TP on ff ---
    (r"moe/w_(gate|up)$", [("model", None, None), (None, None, "model")]),
    (r"moe/w_down$", [("model", None, None), (None, "model", None)]),
    (r"moe/shared/w_(gate|up)$", [(None, "model")]),
    (r"moe/shared/w_down$", [("model", None)]),
    (r"moe/router/", [()]),
    # --- attention: column-parallel in, row-parallel out ---------------------
    (r"(wq|wk|wv|wq_a|wq_b|wkv_b)/w$", [(None, "model")]),
    (r"(wq|wk|wv)/b$", [("model",)]),
    (r"wkv_a/w$", [()]),  # MLA latent proj output is tiny (kv_lora+rope)
    (r"(wo|w_out)/w$", [("model", None)]),
    # --- dense MLP -----------------------------------------------------------
    (r"mlp/w_(gate|up)/w$", [(None, "model")]),
    (r"mlp/w_down/w$", [("model", None)]),
    # --- Mamba2 split streams -------------------------------------------------
    (r"(w_z|w_x)/w$", [(None, "model")]),
    (r"(w_B|w_C)/w$", [()]),
    (r"w_dt/w$", [(None, "model")]),
    (r"conv_x/w$", [(None, "model")]),
    (r"conv_x/b$", [("model",)]),
    (r"conv_[BC]/", [()]),
    (r"(A_log|D|dt_bias)$", [("model",)]),
    (r"mamba/norm_scale$", [("model",)]),
    (r"out_proj/w$", [("model", None)]),
    # --- xLSTM ----------------------------------------------------------------
    (r"(w_i|w_f|w_o|w_z)/w$", [(None, "model")]),
    (r"r_[zifo]$", [()]),
    # --- everything else (norms, gates, scalars): replicate --------------------
    (r".*", [()]),
]


def _choose(shape: Tuple[int, ...], candidates, mesh: Mesh) -> P:
    for cand in candidates:
        if len(cand) > len(shape):
            continue
        if not any(ax is not None for ax in cand):
            return P()  # canonical replication
        spec = (None,) * (len(shape) - len(cand)) + tuple(cand)
        ok = True
        for dim, ax in zip(shape, spec):
            if ax is not None and dim % mesh.shape[ax] != 0:
                ok = False
                break
        if ok:
            return P(*spec)
    return P()


def param_specs(tree: Any, mesh: Mesh) -> Any:
    """Param tree (of arrays or ShapeDtypeStructs) -> PartitionSpec tree."""

    def assign(path: str, leaf) -> P:
        if leaf is None or not hasattr(leaf, "shape"):
            return P()
        for pattern, candidates in _RULES:
            if re.search(pattern, path):
                return _choose(leaf.shape, candidates, mesh)
        return P()

    return map_with_path(assign, tree)


def param_shardings(tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), param_specs(tree, mesh),
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Batch / cache / activation specs
# ---------------------------------------------------------------------------


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_spec(mesh: Mesh, leaf_shape: Tuple[int, ...], batch_size: int) -> P:
    """Shard dim0 (batch) over pod+data where divisible; else replicate."""
    axes = data_axes(mesh)
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    if batch_size % total == 0 and axes:
        first = axes if len(axes) > 1 else axes[0]
        return P(first, *((None,) * (len(leaf_shape) - 1)))
    # fall back to data-only or replication (long_500k: batch 1)
    if "data" in mesh.shape and batch_size % mesh.shape["data"] == 0:
        return P("data", *((None,) * (len(leaf_shape) - 1)))
    return P()


def batch_shardings(batch_tree: Any, mesh: Mesh) -> Any:
    def assign(leaf):
        if not hasattr(leaf, "shape") or not leaf.shape:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, batch_spec(mesh, leaf.shape, leaf.shape[0]))
    return jax.tree.map(assign, batch_tree)


def cache_specs(cache_tree: Any, mesh: Mesh, batch: int, *,
                use_model: bool = True, mode: str = "heads") -> Any:
    """KV/SSM cache sharding.  Cache leaves are layer-stacked (sometimes
    doubly: super-blocks x inner layers): (..., B, C, n_kv, hd) /
    (..., B, H, P, N).  The batch axis (first axis whose size == ``batch``)
    shards over data; the model axis goes to one of:

    - mode="heads" (baseline): the last trailing dim (>= 2 past the batch
      axis, so the cache-time axis indexed by dynamic_update_slice stays
      unsharded) divisible by the model axis — kv-heads where divisible,
      else head_dim, else replicated (DESIGN.md §6).
    - mode="time" (flash-decode, §Perf hillclimb B): the cache TIME axis
      (batch axis + 1) shards over model; each model shard scores its slice
      of the context locally and the softmax/output reductions become small
      cross-shard collectives.  The dynamic_update_slice at ``pos`` lowers
      to a masked per-shard update.
    """
    model = mesh.shape.get("model", 1)
    # a mesh without a model axis (serving data-parallel meshes, e.g.
    # "data:8") must never emit a "model" spec entry — model % 1 == 0
    # would otherwise qualify every trailing dim
    use_model = use_model and "model" in mesh.shape
    daxes = data_axes(mesh)
    dtotal = 1
    for a in daxes:
        dtotal *= mesh.shape[a]

    def assign(leaf):
        if leaf is None or not hasattr(leaf, "shape") or leaf.ndim < 2:
            return P()
        spec: List = [None] * leaf.ndim
        try:
            baxis = next(i for i in range(leaf.ndim - 1)
                         if leaf.shape[i] == batch)
        except StopIteration:
            return P()
        if batch % dtotal == 0 and daxes:
            spec[baxis] = daxes if len(daxes) > 1 else daxes[0]
        elif "data" in mesh.shape and batch % mesh.shape["data"] == 0:
            spec[baxis] = "data"
        if use_model and mode == "time":
            taxis = baxis + 1
            if (taxis < leaf.ndim
                    and leaf.shape[taxis] % model == 0
                    and leaf.shape[taxis] >= model):
                spec[taxis] = "model"
            return P(*spec)
        if use_model:
            for ax in range(leaf.ndim - 1, baxis + 1, -1):
                if leaf.shape[ax] % model == 0 and leaf.shape[ax] >= model:
                    spec[ax] = "model"
                    break
        return P(*spec)

    return jax.tree.map(assign, cache_tree)


def cache_shardings(cache_tree: Any, mesh: Mesh, batch: int, *,
                    use_model: bool = True, mode: str = "heads") -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        cache_specs(cache_tree, mesh, batch,
                                    use_model=use_model, mode=mode),
                        is_leaf=lambda x: isinstance(x, P))


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def opt_specs(tree: Any, mesh: Mesh, *, zero1: bool = False) -> Any:
    """Optimizer-moment PartitionSpecs.  zero1=False: mirror the params
    (the recorded baseline).  zero1=True (§Perf A3): additionally shard each
    moment leaf over the data axes on its first free divisible dim —
    ZeRO-1-style state partitioning (the update step reshards once per step,
    amortised over the whole layer stack)."""
    specs = param_specs(tree, mesh)
    if not zero1:
        return specs
    daxes = data_axes(mesh)
    dtotal = 1
    for a in daxes:
        dtotal *= mesh.shape[a]
    dname = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
    if dname is None or dtotal == 1:
        return specs

    def widen(leaf, spec: P) -> P:
        if leaf is None or not hasattr(leaf, "shape") or leaf.ndim == 0:
            return spec
        s = list(spec) + [None] * (leaf.ndim - len(spec))
        for ax in range(leaf.ndim):
            if s[ax] is None and leaf.shape[ax] % dtotal == 0 \
                    and leaf.shape[ax] >= dtotal:
                s[ax] = dname
                return P(*s)
        return spec

    flat_p, treedef = jax.tree.flatten(tree)
    flat_s = treedef.flatten_up_to(specs)
    return jax.tree.unflatten(
        treedef, [widen(l, s) for l, s in zip(flat_p, flat_s)])


def opt_shardings(tree: Any, mesh: Mesh, *, zero1: bool = False) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        opt_specs(tree, mesh, zero1=zero1),
                        is_leaf=lambda x: isinstance(x, P))

"""Zamba2-style hybrid backbone (arXiv:2411.15242): a stack of Mamba2 blocks
with ONE shared attention+MLP transformer block applied every
``shared_attn_every`` Mamba2 blocks (parameters reused at every invocation —
the arch's signature trick; we omit the per-invocation LoRA deltas and note
this in DESIGN.md).

Layout: n_super = n_layers // k super-blocks of (k mamba layers + shared
block invocation), then (n_layers mod k) tail mamba layers.  Each shared
invocation keeps its own KV cache at decode time (params shared, state not).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.base import (block_decode, block_prefill, cdt, decode_capacity,
                               init_block, init_kv_cache, pdt, scan_layers,
                               scan_layers_decode, stack_init)
from repro.nn.embedding import embed, init_embedding, unembed
from repro.nn.module import Params
from repro.nn.norms import init_rmsnorm, rmsnorm
from repro.nn.ssm import (init_mamba2, init_ssm_cache, mamba2_decode,
                          mamba2_prefill)


def _layout(cfg: ArchConfig) -> Tuple[int, int, int]:
    k = cfg.shared_attn_every or cfg.n_layers
    n_super = cfg.n_layers // k
    tail = cfg.n_layers - n_super * k
    return n_super, k, tail


def _init_mamba_block(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 2)
    return {"ln": init_rmsnorm(cfg.d_model, pdt(cfg)),
            "mamba": init_mamba2(ks[0], cfg.d_model, expand=cfg.ssm_expand,
                                 state=cfg.ssm_state, conv_k=cfg.ssm_conv,
                                 dtype=pdt(cfg))}


def init_lm(key, cfg: ArchConfig) -> Params:
    n_super, k, tail = _layout(cfg)
    ks = jax.random.split(key, 6)
    p: Params = {
        "embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model, pdt(cfg)),
        "ln_f": init_rmsnorm(cfg.d_model, pdt(cfg)),
        "mamba_blocks": stack_init(
            lambda kk: stack_init(lambda k2: _init_mamba_block(k2, cfg), kk, k),
            ks[1], n_super),
        "shared": init_block(ks[2], cfg),  # ONE param set, reused n_super times
    }
    if not cfg.tie_embeddings:
        p["unembed"] = init_embedding(ks[3], cfg.vocab_size, cfg.d_model, pdt(cfg))
    if tail:
        p["tail"] = stack_init(lambda k2: _init_mamba_block(k2, cfg), ks[4], tail)
    return p


def _mamba_fwd(lp, h, cfg: ArchConfig, scan_fn=None):
    if scan_fn is None:
        if cfg.scan_unroll:
            import functools
            from repro.nn.ssm import ssd_chunked
            scan_fn = functools.partial(ssd_chunked, unroll=True)
        else:
            from repro.kernels import ops
            if ops.get_impl() != "xla":  # Pallas SSD kernel path
                scan_fn = ops.ssd_scan
    kw = {} if scan_fn is None else {"scan_fn": scan_fn}
    from repro.models.base import seq_shard, seq_unshard
    h = seq_shard(h, cfg)
    hn = seq_unshard(rmsnorm(lp["ln"], h, cfg.norm_eps), cfg)
    y = mamba2_prefill(lp["mamba"], hn, expand=cfg.ssm_expand,
                       state=cfg.ssm_state, conv_k=cfg.ssm_conv,
                       chunk=cfg.ssm_chunk, compute_dtype=cdt(cfg), **kw)
    return h + seq_shard(y, cfg)


def forward(params: Params, cfg: ArchConfig, batch: Dict, *,
            attn_fn=None, ssm_scan_fn=None) -> Dict[str, jnp.ndarray]:
    n_super, k, tail = _layout(cfg)
    h = embed(params["embed"], batch["tokens"], cdt(cfg))
    shared = params["shared"]

    def super_body(lp, h, aux):
        def inner(mlp_, h, aux):
            return _mamba_fwd(mlp_, h, cfg, ssm_scan_fn), aux
        h, aux = scan_layers(inner, h, lp, remat=False, init_aux=aux,
                             unroll=cfg.scan_unroll)
        h, a = block_prefill(shared, h, cfg, attn_fn=attn_fn)
        return h, aux + a

    aux0 = jnp.zeros((), jnp.float32)
    h, aux = scan_layers(super_body, h, params["mamba_blocks"],
                         remat=cfg.remat, init_aux=aux0,
                         unroll=cfg.scan_unroll)
    if tail:
        def body(lp, h, aux):
            return _mamba_fwd(lp, h, cfg, ssm_scan_fn), aux
        h, aux = scan_layers(body, h, params["tail"], remat=cfg.remat,
                             init_aux=aux, unroll=cfg.scan_unroll)

    h = rmsnorm(params["ln_f"], h, cfg.norm_eps)
    tab = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return {"hidden": h, "logits": unembed(tab, h, cdt(cfg)), "aux_loss": aux}


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, seq_len: int, image_tokens: int = 0):
    n_super, k, tail = _layout(cfg)
    cap = decode_capacity(cfg, seq_len)
    ssm = init_ssm_cache(batch, cfg.d_model, expand=cfg.ssm_expand,
                         state=cfg.ssm_state, conv_k=cfg.ssm_conv)

    def stack(n, tree):
        return jax.tree.map(lambda l: jnp.broadcast_to(l, (n,) + l.shape), tree)

    return {
        "ssm": stack(n_super, stack(k, ssm)),
        "ssm_tail": stack(tail, ssm) if tail else None,
        "attn": stack(n_super, init_kv_cache(cfg, batch, cap)),
    }


def decode_step(params: Params, cfg: ArchConfig, cache, tokens_t, pos, *,
                with_logits: bool = True):
    n_super, k, tail = _layout(cfg)
    h = embed(params["embed"], tokens_t, cdt(cfg))
    shared = params["shared"]
    cap = cache["attn"].k.shape[2]
    win = cap if cfg.long_context_window else 0

    def mamba_body(lp, h, c, _pos):
        y, nc = mamba2_decode(lp["mamba"], rmsnorm(lp["ln"], h, cfg.norm_eps), c,
                              expand=cfg.ssm_expand, state=cfg.ssm_state,
                              conv_k=cfg.ssm_conv, compute_dtype=cdt(cfg))
        return h + y, nc

    def super_body(h, xs):
        lp, sc, ac = xs
        h, new_sc = scan_layers_decode(mamba_body, h, lp, sc, pos,
                                       unroll=cfg.scan_unroll)
        h, new_ac = block_decode(shared, h, ac, pos, cfg, window=win)
        return h, (new_sc, new_ac)

    h, (new_ssm, new_attn) = jax.lax.scan(
        super_body, h, (params["mamba_blocks"], cache["ssm"], cache["attn"]),
        unroll=cfg.scan_unroll)
    new_tail = None
    if tail:
        h, new_tail = scan_layers_decode(mamba_body, h, params["tail"],
                                         cache["ssm_tail"], pos,
                                         unroll=cfg.scan_unroll)
    h = rmsnorm(params["ln_f"], h[:, None], cfg.norm_eps)[:, 0]
    tab = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed(tab, h, cdt(cfg)) if with_logits else None
    return logits, h, {"ssm": new_ssm, "ssm_tail": new_tail, "attn": new_attn}

"""Uniform backbone API — the rest of the framework (core/, launch/,
serving/, training/) only talks to these five functions:

    init_model(key, cfg)                         -> params
    forward(params, cfg, batch)                  -> {hidden, logits, aux_loss, ...}
    init_cache(cfg, batch, seq_len)              -> cache pytree
    decode_step(params, cfg, cache, tokens, pos) -> (logits, hidden_t, cache)
    input_specs(cfg, shape)                      -> ShapeDtypeStruct batch
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import hybrid, transformer, xlstm_model
from repro.models.base import cdt

_FAMILY = {
    "dense": transformer, "moe": transformer, "vlm": transformer,
    "audio": transformer, "hybrid": hybrid, "ssm": xlstm_model,
}


def _impl(cfg: ArchConfig):
    return _FAMILY[cfg.family]


def init_model(key, cfg: ArchConfig):
    return _impl(cfg).init_lm(key, cfg)


def forward(params, cfg: ArchConfig, batch: Dict, **kw):
    return _impl(cfg).forward(params, cfg, batch, **kw)


def init_cache(cfg: ArchConfig, batch: int, seq_len: int):
    return _impl(cfg).init_cache(cfg, batch, seq_len)


def decode_step(params, cfg: ArchConfig, cache, tokens_t, pos, *,
                with_logits: bool = True):
    """with_logits=False skips the unembed projection (monitoring-only
    decode: the collaborative protocol consumes hidden scores, not
    next-token logits — the tokens come from the monitored stream)."""
    return _impl(cfg).decode_step(params, cfg, cache, tokens_t, pos,
                                  with_logits=with_logits)


# ---------------------------------------------------------------------------
# Shape-only input stand-ins (dry-run; modality frontends are stubs per brief)
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def tok(shp):
        return jax.ShapeDtypeStruct(shp, i32)

    if shape.kind == "train" or shape.kind == "prefill":
        if cfg.family == "audio":
            batch = {"tokens": tok((B, S, cfg.n_codebooks))}
        else:
            batch = {"tokens": tok((B, S))}
        if cfg.family == "vlm":
            batch["image_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_image_tokens, cfg.d_model), cdt(cfg))
        if shape.kind == "train":
            if cfg.family == "audio":
                batch["labels"] = tok((B, S, cfg.n_codebooks))
            else:
                batch["labels"] = tok((B, S))
            # monitoring target for the collaborative head (paper technique)
            batch["monitor_target"] = jax.ShapeDtypeStruct((B, S), jnp.float32)
        return batch

    # decode: one new token + a cache filled to seq_len
    if cfg.family == "audio":
        tokens = tok((B, cfg.n_codebooks))
    else:
        tokens = tok((B,))
    cache = jax.eval_shape(lambda: init_cache(cfg, B, S))
    return {"tokens": tokens, "cache": cache,
            "pos": jax.ShapeDtypeStruct((), i32)}


def sample_batch(key, cfg: ArchConfig, shape: ShapeConfig) -> Dict:
    """Concrete random batch matching input_specs (smoke tests / examples)."""
    specs = input_specs(cfg, shape)
    out = {}
    for name, spec in specs.items():
        key = jax.random.fold_in(key, hash(name) % (2**31))
        if name == "cache":
            out[name] = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
        elif spec.dtype == jnp.int32 and name != "pos":
            out[name] = jax.random.randint(key, spec.shape, 0, cfg.vocab_size, jnp.int32)
        elif name == "pos":
            out[name] = jnp.asarray(shape.seq_len - 1, jnp.int32)
        else:
            out[name] = jax.random.normal(key, spec.shape, jnp.float32).astype(spec.dtype)
    return out

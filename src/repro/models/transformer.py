"""Generic decoder-only backbone covering the dense / moe / vlm / audio
families (granite, qwen*, deepseek-v3, mixtral, llama-3.2-vision, musicgen).

Structure per family:
  dense  : embed -> scan(L x block) -> norm -> unembed
  moe    : embed -> scan(first_dense x dense block) -> scan(rest x moe block)
           [-> MTP head if cfg.mtp_depth > 0 (DeepSeek-V3)]
  vlm    : embed -> scan(n_super x [per_super self blocks + 1 cross block])
           cross blocks attend to stub-provided image patch embeddings
  audio  : sum-of-codebook embed -> dense stack -> per-codebook heads
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.base import (block_decode, block_prefill, cdt, decode_window,
                               init_block, init_kv_cache, pdt, scan_layers,
                               scan_layers_decode, stack_init)
from repro.nn.attention import cross_attn, init_gqa
from repro.nn.embedding import (codebook_embed, codebook_unembed, embed,
                                init_codebook_embedding, init_embedding,
                                unembed)
from repro.nn.module import Params, init_linear, linear
from repro.nn.norms import init_rmsnorm, rmsnorm


def _layer_layout(cfg: ArchConfig) -> Dict[str, int]:
    if cfg.family == "vlm" and cfg.cross_attn_every:
        n_super = cfg.n_layers // cfg.cross_attn_every
        tail = cfg.n_layers - n_super * cfg.cross_attn_every
        return {"kind": "vlm", "n_super": n_super,
                "per_super": cfg.cross_attn_every, "tail": tail}
    if cfg.is_moe:
        return {"kind": "moe", "dense": cfg.first_dense_layers,
                "moe": cfg.n_layers - cfg.first_dense_layers}
    return {"kind": "dense", "dense": cfg.n_layers}


def init_lm(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 8)
    lay = _layer_layout(cfg)
    p: Params = {"ln_f": init_rmsnorm(cfg.d_model, pdt(cfg))}
    if cfg.family == "audio":
        p["embed"] = init_codebook_embedding(ks[0], cfg.n_codebooks,
                                             cfg.vocab_size, cfg.d_model, pdt(cfg))
    else:
        p["embed"] = init_embedding(ks[0], cfg.vocab_size, cfg.d_model, pdt(cfg))
        if not cfg.tie_embeddings:
            p["unembed"] = init_embedding(ks[1], cfg.vocab_size, cfg.d_model, pdt(cfg))

    if lay["kind"] == "vlm":
        p["blocks"] = stack_init(
            lambda k: stack_init(lambda k2: init_block(k2, cfg), k, lay["per_super"]),
            ks[2], lay["n_super"])
        p["cross"] = stack_init(
            lambda k: {
                "ln": init_rmsnorm(cfg.d_model, pdt(cfg)),
                "attn": init_gqa(k, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.resolved_head_dim, dtype=pdt(cfg)),
                "gate": jnp.zeros((1,), jnp.float32),  # tanh-gated (llama-3.2)
            }, ks[3], lay["n_super"])
        if lay["tail"]:
            p["tail"] = stack_init(lambda k: init_block(k, cfg), ks[4], lay["tail"])
    elif lay["kind"] == "moe":
        if lay["dense"]:
            p["blocks_dense"] = stack_init(
                lambda k: init_block(k, cfg.replace(d_ff=cfg.d_ff or cfg.moe_d_ff)),
                ks[2], lay["dense"])
        p["blocks_moe"] = stack_init(lambda k: init_block(k, cfg, moe=True),
                                     ks[3], lay["moe"])
        if cfg.mtp_depth:
            p["mtp"] = {
                "proj": init_linear(ks[5], 2 * cfg.d_model, cfg.d_model, dtype=pdt(cfg)),
                "ln_h": init_rmsnorm(cfg.d_model, pdt(cfg)),
                "ln_e": init_rmsnorm(cfg.d_model, pdt(cfg)),
                "block": init_block(ks[6], cfg, moe=True),
                "ln_f": init_rmsnorm(cfg.d_model, pdt(cfg)),
            }
    else:
        p["blocks"] = stack_init(lambda k: init_block(k, cfg), ks[2], lay["dense"])
    return p


def _embed_in(p: Params, cfg: ArchConfig, batch: Dict) -> jnp.ndarray:
    if cfg.family == "audio":
        return codebook_embed(p["embed"], batch["tokens"], cdt(cfg))
    return embed(p["embed"], batch["tokens"], cdt(cfg))


def _logits(p: Params, cfg: ArchConfig, h: jnp.ndarray) -> jnp.ndarray:
    if cfg.family == "audio":
        return codebook_unembed(p["embed"], h, cdt(cfg))
    tab = p["embed"] if cfg.tie_embeddings else p["unembed"]
    return unembed(tab, h, cdt(cfg))


def forward(params: Params, cfg: ArchConfig, batch: Dict, *,
            attn_fn=None) -> Dict[str, jnp.ndarray]:
    """Prefill/training forward. batch: tokens (B,S[,K]) [+ image_embeds]."""
    lay = _layer_layout(cfg)
    h = _embed_in(params, cfg, batch)
    aux0 = jnp.zeros((), jnp.float32)
    window = cfg.sliding_window

    if lay["kind"] == "vlm":
        img = batch["image_embeds"].astype(cdt(cfg))

        def super_body(lp, h, aux):
            def self_body(slp, h, aux):
                h, a = block_prefill(slp, h, cfg, window=window, attn_fn=attn_fn)
                return h, aux + a
            h, aux = scan_layers(self_body, h, lp["blocks"], remat=False,
                                 init_aux=aux, unroll=cfg.scan_unroll)
            cp = lp["cross"]
            c = cross_attn(cp["attn"], rmsnorm(cp["ln"], h, cfg.norm_eps), img,
                           n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                           head_dim=cfg.resolved_head_dim, compute_dtype=cdt(cfg))
            h = h + jnp.tanh(cp["gate"].astype(jnp.float32)) * c.astype(jnp.float32)
            return h.astype(cdt(cfg)), aux

        stacked = {"blocks": params["blocks"], "cross": params["cross"]}
        h, aux = scan_layers(super_body, h, stacked, remat=cfg.remat,
                             init_aux=aux0, unroll=cfg.scan_unroll)
        if lay.get("tail"):
            def body(lp, h, aux):
                h, a = block_prefill(lp, h, cfg, window=window, attn_fn=attn_fn)
                return h, aux + a
            h, aux = scan_layers(body, h, params["tail"], remat=cfg.remat,
                                 init_aux=aux, unroll=cfg.scan_unroll)
    elif lay["kind"] == "moe":
        def dense_body(lp, h, aux):
            h, a = block_prefill(lp, h, cfg.replace(d_ff=cfg.d_ff or cfg.moe_d_ff),
                                 window=window, attn_fn=attn_fn)
            return h, aux + a

        def moe_body(lp, h, aux):
            h, a = block_prefill(lp, h, cfg, moe=True, window=window, attn_fn=attn_fn)
            return h, aux + a

        aux = aux0
        if lay["dense"]:
            h, aux = scan_layers(dense_body, h, params["blocks_dense"],
                                 remat=cfg.remat, init_aux=aux,
                                 unroll=cfg.scan_unroll)
        h, aux = scan_layers(moe_body, h, params["blocks_moe"],
                             remat=cfg.remat, init_aux=aux,
                             unroll=cfg.scan_unroll)
    else:
        def body(lp, h, aux):
            h, a = block_prefill(lp, h, cfg, window=window, attn_fn=attn_fn)
            return h, aux + a
        h, aux = scan_layers(body, h, params["blocks"], remat=cfg.remat,
                             init_aux=aux0, unroll=cfg.scan_unroll)

    h = rmsnorm(params["ln_f"], h, cfg.norm_eps)
    out = {"hidden": h, "logits": _logits(params, cfg, h), "aux_loss": aux}

    if cfg.is_moe and cfg.mtp_depth and "mtp" in params:
        # DeepSeek-V3 MTP (depth 1): combine h_t with emb(tok_{t+1}) to
        # predict tok_{t+2}; trained alongside the main head.
        mp = params["mtp"]
        emb_next = jnp.roll(_embed_in(params, cfg, batch), -1, axis=1)
        z = jnp.concatenate([rmsnorm(mp["ln_h"], h, cfg.norm_eps),
                             rmsnorm(mp["ln_e"], emb_next, cfg.norm_eps)], axis=-1)
        z = linear(mp["proj"], z, compute_dtype=cdt(cfg))
        z, _ = block_prefill(mp["block"], z, cfg, moe=True, window=window,
                             attn_fn=attn_fn)
        out["mtp_logits"] = _logits(params, cfg, rmsnorm(mp["ln_f"], z, cfg.norm_eps))
    return out


# ---------------------------------------------------------------------------
# Decode path
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, seq_len: int,
               image_tokens: int = 0):
    lay = _layer_layout(cfg)
    from repro.models.base import decode_capacity
    cap = decode_capacity(cfg, seq_len)

    def stack_cache(n):
        return jax.tree.map(
            lambda l: jnp.broadcast_to(l, (n,) + l.shape), init_kv_cache(cfg, batch, cap))

    if lay["kind"] == "vlm":
        cache = {"self": stack_cache(lay["n_super"] * lay["per_super"] + lay.get("tail", 0)),
                 # cross-KV computed once at prefill; stub zeros at dry-run
                 "cross_k": jnp.zeros((lay["n_super"], batch, image_tokens or cfg.n_image_tokens,
                                       cfg.n_kv_heads, cfg.resolved_head_dim), cdt(cfg)),
                 "cross_v": jnp.zeros((lay["n_super"], batch, image_tokens or cfg.n_image_tokens,
                                       cfg.n_kv_heads, cfg.resolved_head_dim), cdt(cfg))}
        return cache
    if lay["kind"] == "moe":
        return {"dense": stack_cache(lay["dense"]) if lay["dense"] else None,
                "moe": stack_cache(lay["moe"])}
    return {"blocks": stack_cache(lay["dense"])}


def decode_step(params: Params, cfg: ArchConfig, cache, tokens_t: jnp.ndarray,
                pos, *, with_logits: bool = True
                ) -> Tuple[jnp.ndarray, jnp.ndarray, Dict]:
    """One decode step. tokens_t: (B,[K]) -> (logits, hidden_t, new_cache).
    with_logits=False returns logits=None (monitoring-only decode)."""
    lay = _layer_layout(cfg)
    # Window handling: the cache was sized by decode_capacity; if it is
    # smaller than the logical context we run it as a ring buffer (SWA).
    if cfg.family == "audio":
        h = codebook_embed(params["embed"], tokens_t[:, None], cdt(cfg))[:, 0]
    else:
        h = embed(params["embed"], tokens_t, cdt(cfg))

    new_cache = {}
    if lay["kind"] == "vlm":
        n_sup, per = lay["n_super"], lay["per_super"]
        cap = cache["self"].k.shape[2]
        win = cap if cfg.sliding_window or cfg.long_context_window else 0

        def self_body(lp, h, c, pos):
            return block_decode(lp, h, c, pos, cfg, window=win)

        # scan over super-blocks: reshape self caches to (n_super, per, ...)
        selfc = jax.tree.map(
            lambda l: l[: n_sup * per].reshape((n_sup, per) + l.shape[1:]),
            cache["self"])

        def super_body(h, xs):
            lp, c, ck, cv = xs
            h, nc = scan_layers_decode(self_body, h, lp["blocks"], c, pos,
                                       unroll=cfg.scan_unroll)
            cp = lp["cross"]
            hn = rmsnorm(cp["ln"], h[:, None], cfg.norm_eps)
            catt = cross_attn_decode(cp["attn"], hn[:, 0], ck, cv, cfg)
            h = (h.astype(jnp.float32)
                 + jnp.tanh(cp["gate"].astype(jnp.float32)) * catt.astype(jnp.float32)
                 ).astype(cdt(cfg))
            return h, nc

        stacked = {"blocks": params["blocks"], "cross": params["cross"]}
        h, new_self = jax.lax.scan(
            super_body, h, (stacked, selfc, cache["cross_k"], cache["cross_v"]),
            unroll=cfg.scan_unroll)
        new_self = jax.tree.map(
            lambda l: l.reshape((n_sup * per,) + l.shape[2:]), new_self)
        if lay.get("tail"):
            tailc = jax.tree.map(lambda l: l[n_sup * per:], cache["self"])
            def body(lp, h, c, pos):
                return block_decode(lp, h, c, pos, cfg, window=win)
            h, new_tail = scan_layers_decode(body, h, params["tail"], tailc, pos,
                                             unroll=cfg.scan_unroll)
            new_self = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0),
                                    new_self, new_tail)
        new_cache = {"self": new_self, "cross_k": cache["cross_k"],
                     "cross_v": cache["cross_v"]}
    elif lay["kind"] == "moe":
        capc = cache["moe"].ckv if cfg.use_mla else cache["moe"].k
        cap = capc.shape[2]
        win = cap if cfg.long_context_window else 0

        def dense_body(lp, h, c, pos):
            return block_decode(lp, h, c, pos,
                                cfg.replace(d_ff=cfg.d_ff or cfg.moe_d_ff),
                                window=win)

        def moe_body(lp, h, c, pos):
            return block_decode(lp, h, c, pos, cfg, moe=True, window=win)

        new_dense = None
        if lay["dense"]:
            h, new_dense = scan_layers_decode(dense_body, h, params["blocks_dense"],
                                              cache["dense"], pos,
                                              unroll=cfg.scan_unroll)
        h, new_moe = scan_layers_decode(moe_body, h, params["blocks_moe"],
                                        cache["moe"], pos,
                                        unroll=cfg.scan_unroll)
        new_cache = {"dense": new_dense, "moe": new_moe}
    else:
        cap = cache["blocks"].k.shape[2]
        win = cap if (cfg.sliding_window or cfg.long_context_window) else 0

        def body(lp, h, c, pos):
            return block_decode(lp, h, c, pos, cfg, window=win)

        h, new_blocks = scan_layers_decode(body, h, params["blocks"],
                                           cache["blocks"], pos,
                                           unroll=cfg.scan_unroll)
        new_cache = {"blocks": new_blocks}

    h = rmsnorm(params["ln_f"], h[:, None], cfg.norm_eps)[:, 0]
    return (_logits(params, cfg, h) if with_logits else None), h, new_cache


def cross_attn_decode(p: Params, x: jnp.ndarray, k_img: jnp.ndarray,
                      v_img: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """Single-token cross attention against precomputed image KV."""
    from repro.nn.attention import decode_attention
    B = x.shape[0]
    q = linear(p["wq"], x, compute_dtype=cdt(cfg)).reshape(
        B, cfg.n_heads, cfg.resolved_head_dim)
    T = k_img.shape[1]
    o = decode_attention(q, k_img, v_img, jnp.asarray(T - 1))
    return linear(p["wo"], o.reshape(B, -1), compute_dtype=cdt(cfg))

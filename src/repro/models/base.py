"""Shared backbone scaffolding: transformer blocks, stacked-layer scans.

Every backbone is a pair of pure functions over a param tree; layer stacks
are ``lax.scan`` over parameters stacked on a leading layer axis (keeps the
HLO size O(1 layer) — essential for the 40-pair dry-run) with optional
``jax.checkpoint`` remat.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.nn.attention import (KVCache, MLACache, cross_attn, gqa_decode,
                                gqa_prefill, init_gqa, init_mla, mla_decode,
                                mla_prefill)
from repro.nn.mlp import init_swiglu, swiglu
from repro.nn.moe import init_moe, moe_dispatch
from repro.nn.module import Params
from repro.nn.norms import init_rmsnorm, rmsnorm


def cdt(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def pdt(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


def stack_init(init_fn: Callable, key, n: int) -> Params:
    return jax.vmap(init_fn)(jax.random.split(key, n))


def scan_layers(body: Callable, h, stacked: Params, *, remat: bool = True,
                init_aux=None, unroll: bool = False):
    """body(layer_params, h, aux) -> (h, aux); scans over the layer axis.
    ``unroll=True`` (dry-run accounting mode) fully unrolls the loop so
    cost_analysis counts every layer (it counts a while body once)."""
    f = jax.checkpoint(body) if remat else body

    def step(carry, lp):
        h, aux = carry
        h, aux = f(lp, h, aux)
        return (h, aux), None

    (h, aux), _ = jax.lax.scan(step, (h, init_aux), stacked, unroll=unroll)
    return h, aux


def scan_layers_decode(body: Callable, h_t, stacked: Params, caches, pos,
                       unroll: bool = False):
    """body(layer_params, h_t, cache, pos) -> (h_t, new_cache)."""

    def step(h, xs):
        lp, cache = xs
        h, new_cache = body(lp, h, cache, pos)
        return h, new_cache

    return jax.lax.scan(step, h_t, (stacked, caches), unroll=unroll)


# ---------------------------------------------------------------------------
# Sequence parallelism (§Perf C1): the residual stream between the matmul
# regions is replicated over 'model' by default; constraining its SEQUENCE
# axis onto 'model' divides all norm/elementwise (and their backward/remat)
# HBM traffic by the model-axis size.  XLA inserts the all-gather before the
# attention/SSM mixers and turns the row-parallel all-reduce into
# reduce-scatter — equal collective volume.
# ---------------------------------------------------------------------------


def _sp_mesh(cfg: ArchConfig, h):
    if not cfg.seq_parallel or h.ndim != 3:
        return None
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
            from jax.interpreters import pxla
            mesh = pxla.thread_resources.env.physical_mesh
        if "model" not in mesh.axis_names or mesh.shape["model"] <= 1:
            return None
        if h.shape[1] % mesh.shape["model"] != 0:
            return None
        return mesh
    except Exception:
        return None


def _batch_axes(mesh):
    ax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not ax:
        return None
    return ax if len(ax) > 1 else ax[0]


def seq_shard(h: jnp.ndarray, cfg: ArchConfig):
    """Constrain (B, S, d) h to (batch_axes, 'model', None): seq axis onto
    'model', batch staying on the data axes.  A mixer OUTPUT constrained
    this way turns the megatron row-parallel all-reduce into a
    reduce-scatter."""
    mesh = _sp_mesh(cfg, h)
    if mesh is None:
        return h
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        h, P(_batch_axes(mesh), "model", None))


def seq_unshard(h: jnp.ndarray, cfg: ArchConfig):
    """All-gather of the seq axis (batch sharding preserved) before a mixer
    (attention / SSM scan) that needs the full sequence.  Without this the
    partitioner tries to run the mixer with a sharded seq axis (for the SSD
    chunk recurrence that degenerates badly)."""
    mesh = _sp_mesh(cfg, h)
    if mesh is None:
        return h
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        h, P(_batch_axes(mesh), None, None))


# ---------------------------------------------------------------------------
# Transformer block (attention + FFN); FFN is SwiGLU or MoE.
# ---------------------------------------------------------------------------


def init_attn(key, cfg: ArchConfig) -> Params:
    if cfg.use_mla:
        return init_mla(key, cfg.d_model, cfg.n_heads, q_lora=cfg.q_lora_rank,
                        kv_lora=cfg.kv_lora_rank, qk_nope=cfg.qk_nope_dim,
                        qk_rope=cfg.qk_rope_dim, v_dim=cfg.v_head_dim,
                        dtype=pdt(cfg))
    return init_gqa(key, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                    cfg.resolved_head_dim, qkv_bias=cfg.qkv_bias, dtype=pdt(cfg))


def init_block(key, cfg: ArchConfig, *, moe: bool = False) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {
        "ln_attn": init_rmsnorm(cfg.d_model, pdt(cfg)),
        "attn": init_attn(ks[0], cfg),
        "ln_mlp": init_rmsnorm(cfg.d_model, pdt(cfg)),
    }
    if moe:
        p["moe"] = init_moe(ks[1], cfg.d_model, cfg.moe_d_ff, cfg.n_experts,
                            n_shared=cfg.n_shared_experts, dtype=pdt(cfg))
    else:
        p["mlp"] = init_swiglu(ks[1], cfg.d_model, cfg.d_ff, dtype=pdt(cfg))
    return p


def _attn_prefill(p, h, cfg: ArchConfig, window: int, attn_fn=None):
    if attn_fn is None:
        if cfg.scan_unroll:
            import functools
            from repro.nn.attention import chunked_attention
            attn_fn = functools.partial(chunked_attention, unroll=True)
        else:
            from repro.kernels import ops
            if ops.get_impl() != "xla":  # Pallas flash kernel path
                attn_fn = ops.flash_attention
    kw = {} if attn_fn is None else {"attn_fn": attn_fn}
    from repro.nn.attention import kv_shard_ctx
    with kv_shard_ctx(cfg.prefill_kv_shard):
        if cfg.use_mla:
            return mla_prefill(p, h, n_heads=cfg.n_heads,
                               qk_nope=cfg.qk_nope_dim,
                               qk_rope=cfg.qk_rope_dim, v_dim=cfg.v_head_dim,
                               rope_theta=cfg.rope_theta, window=window,
                               compute_dtype=cdt(cfg), **kw)
        return gqa_prefill(p, h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                           head_dim=cfg.resolved_head_dim,
                           rope_theta=cfg.rope_theta,
                           window=window, compute_dtype=cdt(cfg), **kw)


def block_prefill(p: Params, h: jnp.ndarray, cfg: ArchConfig, *,
                  moe: bool = False, window: int = 0,
                  attn_fn=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (h, aux_loss)."""
    h = seq_shard(h, cfg)
    hn = seq_unshard(rmsnorm(p["ln_attn"], h, cfg.norm_eps), cfg)
    a = _attn_prefill(p["attn"], hn, cfg, window, attn_fn)
    h = h + seq_shard(a, cfg)
    hn = seq_unshard(rmsnorm(p["ln_mlp"], h, cfg.norm_eps), cfg)
    if moe:
        m, aux = moe_dispatch(p["moe"], hn, n_experts=cfg.n_experts,
                              top_k=cfg.top_k,
                              capacity_factor=cfg.capacity_factor,
                              compute_dtype=cdt(cfg), impl=cfg.moe_impl)
    else:
        m, aux = swiglu(p["mlp"], hn, compute_dtype=cdt(cfg)), jnp.zeros((), jnp.float32)
    return h + seq_shard(m, cfg), aux


def block_decode(p: Params, h: jnp.ndarray, cache, pos, cfg: ArchConfig, *,
                 moe: bool = False, window: int = 0):
    hn = rmsnorm(p["ln_attn"], h, cfg.norm_eps)
    if cfg.use_mla:
        a, new_cache = mla_decode(p["attn"], hn, cache, pos, n_heads=cfg.n_heads,
                                  qk_nope=cfg.qk_nope_dim, qk_rope=cfg.qk_rope_dim,
                                  v_dim=cfg.v_head_dim, kv_lora=cfg.kv_lora_rank,
                                  rope_theta=cfg.rope_theta, compute_dtype=cdt(cfg))
    else:
        a, new_cache = gqa_decode(p["attn"], hn, cache, pos, n_heads=cfg.n_heads,
                                  n_kv=cfg.n_kv_heads, head_dim=cfg.resolved_head_dim,
                                  rope_theta=cfg.rope_theta, window=window,
                                  compute_dtype=cdt(cfg))
    h = h + a
    hn = rmsnorm(p["ln_mlp"], h, cfg.norm_eps)
    if moe:
        m, _ = moe_dispatch(p["moe"], hn[:, None, :], n_experts=cfg.n_experts,
                            top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
                            compute_dtype=cdt(cfg), impl=cfg.moe_impl)
        m = m[:, 0]
    else:
        m = swiglu(p["mlp"], hn, compute_dtype=cdt(cfg))
    return h + m, new_cache


def init_kv_cache(cfg: ArchConfig, batch: int, capacity: int):
    if cfg.use_mla:
        return MLACache(
            ckv=jnp.zeros((batch, capacity, cfg.kv_lora_rank), cdt(cfg)),
            krope=jnp.zeros((batch, capacity, cfg.qk_rope_dim), cdt(cfg)))
    return KVCache(
        k=jnp.zeros((batch, capacity, cfg.n_kv_heads, cfg.resolved_head_dim), cdt(cfg)),
        v=jnp.zeros((batch, capacity, cfg.n_kv_heads, cfg.resolved_head_dim), cdt(cfg)))


LONG_CONTEXT_THRESHOLD = 65_536  # beyond this, full-attention archs switch
                                 # to their swa-variant ring cache (DESIGN.md §5)


def decode_capacity(cfg: ArchConfig, seq_len: int) -> int:
    """Cache capacity for a decode shape: the long_500k swa-variant caps the
    window for full-attention archs (DESIGN.md §5)."""
    if cfg.sliding_window:
        return min(seq_len, cfg.sliding_window)
    if cfg.long_context_window and seq_len > LONG_CONTEXT_THRESHOLD:
        return cfg.long_context_window
    return seq_len


def decode_window(cfg: ArchConfig, seq_len: int) -> int:
    cap = decode_capacity(cfg, seq_len)
    return cap if cap < seq_len else (cfg.sliding_window or 0)

"""xLSTM-350m backbone (arXiv:2405.04517): mLSTM blocks with one sLSTM block
every ``slstm_every`` layers (the paper's xLSTM[a:b] notation).  d_ff=0 in
the assigned config: blocks carry their own internal projections.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.base import cdt, pdt, scan_layers, scan_layers_decode, stack_init
from repro.nn.embedding import embed, init_embedding, unembed
from repro.nn.module import Params
from repro.nn.norms import init_rmsnorm, rmsnorm
from repro.nn.xlstm import (MLSTMState, SLSTMState, init_mlstm, init_mlstm_state,
                            init_slstm, init_slstm_state, mlstm_decode,
                            mlstm_parallel, slstm_scan, slstm_step)


def _layout(cfg: ArchConfig):
    k = cfg.slstm_every or cfg.n_layers + 1
    if cfg.slstm_every:
        n_super = cfg.n_layers // k
        per = k - 1  # per super-block: (k-1) mLSTM + 1 sLSTM
        tail = cfg.n_layers - n_super * k  # trailing mLSTM layers
    else:
        n_super, per, tail = 0, 0, cfg.n_layers
    return n_super, per, tail


def init_lm(key, cfg: ArchConfig) -> Params:
    n_super, per, tail = _layout(cfg)
    ks = jax.random.split(key, 6)

    def init_m(k2):
        return {"ln": init_rmsnorm(cfg.d_model, pdt(cfg)),
                "cell": init_mlstm(k2, cfg.d_model, cfg.n_heads, dtype=pdt(cfg))}

    def init_s(k2):
        return {"ln": init_rmsnorm(cfg.d_model, pdt(cfg)),
                "cell": init_slstm(k2, cfg.d_model, cfg.n_heads, dtype=pdt(cfg))}

    p: Params = {
        "embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model, pdt(cfg)),
        "ln_f": init_rmsnorm(cfg.d_model, pdt(cfg)),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = init_embedding(ks[1], cfg.vocab_size, cfg.d_model, pdt(cfg))
    if n_super:
        p["super"] = {
            "mlstm": stack_init(lambda kk: stack_init(init_m, kk, per), ks[2], n_super),
            "slstm": stack_init(init_s, ks[3], n_super),
        }
    if tail:
        p["tail"] = stack_init(init_m, ks[4], tail)
    return p


def forward(params: Params, cfg: ArchConfig, batch: Dict, *,
            attn_fn=None, ssm_scan_fn=None) -> Dict[str, jnp.ndarray]:
    n_super, per, tail = _layout(cfg)
    h = embed(params["embed"], batch["tokens"], cdt(cfg))
    aux0 = jnp.zeros((), jnp.float32)

    def m_body(lp, h, aux):
        y = mlstm_parallel(lp["cell"], rmsnorm(lp["ln"], h, cfg.norm_eps),
                           cfg.n_heads, compute_dtype=cdt(cfg))
        return h + y, aux

    aux = aux0
    if n_super:
        def super_body(lp, h, aux):
            h, aux = scan_layers(m_body, h, lp["mlstm"], remat=False, init_aux=aux,
                                 unroll=cfg.scan_unroll)
            y, _ = slstm_scan(lp["slstm"]["cell"],
                              rmsnorm(lp["slstm"]["ln"], h, cfg.norm_eps),
                              cfg.n_heads, compute_dtype=cdt(cfg))
            return h + y, aux
        h, aux = scan_layers(super_body, h, params["super"], remat=cfg.remat,
                             init_aux=aux, unroll=cfg.scan_unroll)
    if tail:
        h, aux = scan_layers(m_body, h, params["tail"], remat=cfg.remat,
                             init_aux=aux, unroll=cfg.scan_unroll)

    h = rmsnorm(params["ln_f"], h, cfg.norm_eps)
    tab = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return {"hidden": h, "logits": unembed(tab, h, cdt(cfg)), "aux_loss": aux}


def init_cache(cfg: ArchConfig, batch: int, seq_len: int, image_tokens: int = 0):
    n_super, per, tail = _layout(cfg)
    m = init_mlstm_state(batch, cfg.d_model, cfg.n_heads)
    s = init_slstm_state(batch, cfg.d_model)

    def stack(n, tree):
        return jax.tree.map(lambda l: jnp.broadcast_to(l, (n,) + l.shape), tree)

    return {
        "mlstm": stack(n_super, stack(per, m)) if n_super else None,
        "slstm": stack(n_super, s) if n_super else None,
        "tail": stack(tail, m) if tail else None,
    }


def decode_step(params: Params, cfg: ArchConfig, cache, tokens_t, pos, *,
                with_logits: bool = True):
    n_super, per, tail = _layout(cfg)
    h = embed(params["embed"], tokens_t, cdt(cfg))

    def m_body(lp, h, c, _pos):
        y, nc = mlstm_decode(lp["cell"], rmsnorm(lp["ln"], h[:, None], cfg.norm_eps)[:, 0],
                             c, cfg.n_heads, compute_dtype=cdt(cfg))
        return h + y, nc

    new_cache = {"mlstm": None, "slstm": None, "tail": None}
    if n_super:
        def super_body(h, xs):
            lp, mc, sc = xs
            h, new_mc = scan_layers_decode(m_body, h, lp["mlstm"], mc, pos,
                                           unroll=cfg.scan_unroll)
            hn = rmsnorm(lp["slstm"]["ln"], h[:, None], cfg.norm_eps)[:, 0]
            y, new_sc = slstm_step(lp["slstm"]["cell"], hn, sc, cfg.n_heads)
            y = y * lp["slstm"]["cell"]["norm_scale"].astype(jnp.float32)[None, :]
            return (h.astype(jnp.float32) + y).astype(cdt(cfg)), (new_mc, new_sc)

        h, (new_m, new_s) = jax.lax.scan(
            super_body, h, (params["super"], cache["mlstm"], cache["slstm"]),
            unroll=cfg.scan_unroll)
        new_cache["mlstm"], new_cache["slstm"] = new_m, new_s
    if tail:
        h, new_t = scan_layers_decode(m_body, h, params["tail"], cache["tail"], pos,
                                      unroll=cfg.scan_unroll)
        new_cache["tail"] = new_t

    h = rmsnorm(params["ln_f"], h[:, None], cfg.norm_eps)[:, 0]
    tab = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return (unembed(tab, h, cdt(cfg)) if with_logits else None), h, new_cache

"""Parsed per-op rule engine over compiled HLO text.

Replaces the old substring scan (``serving/mesh.py``): matching opcodes
instead of raw lines means a benign op whose *metadata* mentions
``all_gather_like`` (named scopes, fusion names, source paths) can no
longer trip the collective-free check, while a real ``all-reduce``
buried inside a fusion body still does — every instruction line of
every computation in the module is parsed, fused bodies included.

Rules (each with an explicit allowlist):

* ``collective-free`` — no cross-device communication opcodes.  The
  paper's device-locality guarantee: the monitor path must decide
  without the server, hence without the mesh.
* ``no-host-transfer`` — no infeed/outfeed/send/recv, and no
  ``custom-call`` whose target is not allowlisted (host callbacks like
  ``xla_python_cpu_callback`` hide behind custom-call; the allowlist
  names the benign compute targets, e.g. ``TopK``).
* ``no-dynamic-shapes`` — no bounded-dynamic dimensions (``f32[<=8]``):
  the serving jits are shape-static by design and a dynamic dim means a
  shape-polymorphic lowering snuck in.

``monitor_path_hlo(engine)`` compiles the monitor-path kernels of a
``CollaborativeEngine`` — masked edge decode, u head, history record,
and the server catch-up — sharded when a mesh is attached, UNSHARDED
otherwise, so the edge rules run on single-device engines too (the old
check only existed after ``shard_engine``).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp

# opcodes that imply cross-device communication (async -start/-done
# halves included: a started collective is still a collective)
COLLECTIVE_OPCODES = frozenset({
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
    "all-reduce-start", "all-reduce-done", "all-gather-start",
    "all-gather-done", "collective-permute-start",
    "collective-permute-done", "all-to-all-start", "all-to-all-done",
})

# opcodes that move data between host and device
HOST_TRANSFER_OPCODES = frozenset({
    "infeed", "outfeed", "send", "recv", "send-done", "recv-done",
})

# custom-call targets that are pure device compute, not host transfers.
# Anything NOT listed fails ``no-host-transfer`` — deny by default, so
# new callback flavours cannot slip through unreviewed.
DEFAULT_CUSTOM_CALL_ALLOW = frozenset({
    "TopK",                     # lax.top_k on CPU
    "Sharding",                 # SPMD sharding annotations
    "SPMDFullToShardShape", "SPMDShardToFullShape",  # shard_map markers
})


@dataclasses.dataclass(frozen=True)
class HloInstruction:
    """One parsed HLO instruction line."""

    name: str
    opcode: str
    shape: str
    line: str                      # stripped source line
    custom_call_target: Optional[str] = None
    metadata_op_name: Optional[str] = None

    def brief(self) -> str:
        return self.line if len(self.line) <= 160 else self.line[:157] + "..."


# `%name = shape opcode(...)`; shape is a (possibly tuple of)
# dtype[dims]{layout} — dims may be bounded-dynamic (`<=8`)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*"
    r"(?P<shape>\([^=]*?\)|[A-Za-z0-9_]+(?:\[[^\]]*\])?(?:\{[^}]*\})?)\s+"
    r"(?P<opcode>[a-z][a-z0-9\-]*)\(")
_TARGET_RE = re.compile(r'custom_call_target="([^"]*)"')
_METADATA_RE = re.compile(r'metadata=\{[^}]*op_name="([^"]*)"')


def parse_hlo(hlo_text: str) -> List[HloInstruction]:
    """Every instruction of every computation in an HLO module dump
    (entry, fusions, called computations, while bodies...)."""
    out = []
    for raw in hlo_text.splitlines():
        m = _INSTR_RE.match(raw)
        if not m:
            continue
        tgt = _TARGET_RE.search(raw)
        md = _METADATA_RE.search(raw)
        out.append(HloInstruction(
            name=m.group("name"), opcode=m.group("opcode"),
            shape=m.group("shape"), line=raw.strip(),
            custom_call_target=tgt.group(1) if tgt else None,
            metadata_op_name=md.group(1) if md else None))
    return out


# ---------------------------------------------------------------------------
# Rules
# ---------------------------------------------------------------------------


def collective_instructions(hlo_text: str,
                            allow: Iterable[str] = ()) -> List[HloInstruction]:
    """Instructions whose OPCODE is a collective (metadata and fusion
    names cannot trip this).  ``allow``: instruction names to exempt."""
    allowed = frozenset(allow)
    return [i for i in parse_hlo(hlo_text)
            if i.opcode in COLLECTIVE_OPCODES and i.name not in allowed]


def host_transfer_instructions(
        hlo_text: str,
        allow_custom_calls: Iterable[str] = DEFAULT_CUSTOM_CALL_ALLOW,
) -> List[HloInstruction]:
    """Host-transfer opcodes plus any custom-call whose target is not in
    the allowlist (host callbacks are custom-calls)."""
    allowed = frozenset(allow_custom_calls)
    hits = []
    for i in parse_hlo(hlo_text):
        if i.opcode in HOST_TRANSFER_OPCODES:
            hits.append(i)
        elif i.opcode == "custom-call" and \
                (i.custom_call_target or "") not in allowed:
            hits.append(i)
    return hits


_DYNAMIC_DIM_RE = re.compile(r"\[[^\]]*<=")


def dynamic_shape_instructions(hlo_text: str,
                               allow: Iterable[str] = ()) -> List[HloInstruction]:
    """Instructions with bounded-dynamic dimensions (``f32[<=8]``)."""
    allowed = frozenset(allow)
    return [i for i in parse_hlo(hlo_text)
            if _DYNAMIC_DIM_RE.search(i.shape) and i.name not in allowed]


def assert_collective_free(hlo_text: str, what: str = "edge step",
                           allow: Iterable[str] = ()) -> None:
    """The paper's device-locality guarantee, checked per-op on compiled
    HLO: the monitor path must not communicate across devices."""
    hits = collective_instructions(hlo_text, allow)
    if hits:
        raise AssertionError(
            f"{what} HLO contains cross-device collectives (the monitor "
            f"path must be collective-free):\n  "
            + "\n  ".join(h.brief() for h in hits))


def assert_no_host_transfer(
        hlo_text: str, what: str = "edge step",
        allow_custom_calls: Iterable[str] = DEFAULT_CUSTOM_CALL_ALLOW) -> None:
    hits = host_transfer_instructions(hlo_text, allow_custom_calls)
    if hits:
        raise AssertionError(
            f"{what} HLO contains host transfers (the monitor path must "
            f"stay on device):\n  " + "\n  ".join(h.brief() for h in hits))


def assert_static_shapes(hlo_text: str, what: str = "edge step",
                         allow: Iterable[str] = ()) -> None:
    hits = dynamic_shape_instructions(hlo_text, allow)
    if hits:
        raise AssertionError(
            f"{what} HLO contains bounded-dynamic shapes (serving jits "
            f"are shape-static):\n  " + "\n  ".join(h.brief() for h in hits))


# ---------------------------------------------------------------------------
# Monitor-path lowering
# ---------------------------------------------------------------------------


def _shapes(tree):
    return jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)


def monitor_path_hlo(engine, include_catchup: bool = True) -> Dict[str, str]:
    """Compiled HLO of the monitor-path kernels of a
    ``CollaborativeEngine`` — the jits ``_monitor_prologue`` drives every
    step (masked edge decode, u head, per-slot history record), plus the
    triggered server catch-up.  Works on sharded AND unsharded engines:
    the lowering uses whatever jit wrappers the engine currently holds,
    so a mesh-sharded engine compiles with its placements baked in."""
    B = engine.batch
    tok_tail = tuple(engine._history.shape[2:])
    tokens = jax.ShapeDtypeStruct((B,) + tok_tail, jnp.int32)
    pos0 = jax.ShapeDtypeStruct((), jnp.int32)
    posv = jax.ShapeDtypeStruct((B,), jnp.int32)
    mask = jax.ShapeDtypeStruct((B,), jnp.bool_)
    hidden = jax.ShapeDtypeStruct((B, engine.edge.cfg.d_model), jnp.float32)
    out = {
        "decode_masked": engine.edge._step_masked.lower(
            _shapes(engine.edge.params), _shapes(engine.edge.cache),
            tokens, pos0, mask).compile().as_text(),
        "u_head": engine._u_head.lower(
            _shapes(engine.params), hidden).compile().as_text(),
        "record_at": engine._record_at.lower(
            _shapes(engine._history), tokens, posv, mask
        ).compile().as_text(),
    }
    if include_catchup:
        out["catchup"] = engine._catchup.lower(
            _shapes(engine.params), _shapes(engine.server.cache),
            _shapes(engine._history), posv, pos0, mask,
            jax.ShapeDtypeStruct((B,), jnp.float32)).compile().as_text()
    return out


# rules the EDGE kernels must satisfy even unsharded; the catch-up is
# exempt from collective-free on a sharded engine (its round count is a
# legitimate cross-device max-reduction — see serving/mesh.py)
EDGE_KERNELS = ("decode_masked", "u_head", "record_at")


def check_monitor_path(engine, *, include_catchup: bool = True,
                       sharded: Optional[bool] = None
                       ) -> List[Tuple[str, str, List[HloInstruction]]]:
    """Run all HLO rules over the monitor path; returns
    ``(kernel, rule, hits)`` triples — empty hits mean the rule passed."""
    if sharded is None:
        sharded = getattr(engine, "mesh_spec", None) is not None
    results = []
    for name, txt in monitor_path_hlo(
            engine, include_catchup=include_catchup).items():
        if name in EDGE_KERNELS or not sharded:
            results.append((name, "collective-free",
                            collective_instructions(txt)))
        results.append((name, "no-host-transfer",
                        host_transfer_instructions(txt)))
        results.append((name, "no-dynamic-shapes",
                        dynamic_shape_instructions(txt)))
    return results

"""Compile-time invariant verification for the serving stack.

The paper's two load-bearing guarantees are *structural*, so they are
checked on compiled artifacts rather than sampled outputs:

* ``analysis.signs`` — abstract interpretation over closed jaxprs with a
  sign/interval domain.  Proves the corrector ``s*sigma(v)`` is
  elementwise nonnegative and hence ``fhat <= u`` (the edge monitor is a
  safe upper bound) for every registered arch and every ``sigma_kind``,
  or emits the offending primitive chain as a counterexample.
* ``analysis.hlo`` — a parsed per-op rule engine over compiled HLO text:
  ``collective-free``, ``no-host-transfer``, ``no-dynamic-shapes``, each
  with an explicit allowlist.  ``serving/mesh.py`` delegates its
  zero-collectives assertion here; the rules also run unsharded.
* ``analysis.recompile`` — a compile-cache tracker ``MonitorSession``
  can arm to assert each jitted path compiles exactly once across a
  churn episode (retrace blowups fail tests instead of costing 10x).
* ``analysis.rules`` — the rule registry + report used by
  ``tools/check_static.py`` (CI's ``static-analysis`` job), including a
  mutation self-test that seeds violations and asserts each rule fires.

See docs/analysis.md for the rule table and the sign-domain semantics.
"""
from repro.analysis.signs import (  # noqa: F401
    Interval, SignAnalysis, SignCertificate, analyze_jaxpr,
    verify_catchup, verify_forward,
)
from repro.analysis.hlo import (  # noqa: F401
    HloInstruction, assert_collective_free, collective_instructions,
    dynamic_shape_instructions, host_transfer_instructions,
    monitor_path_hlo, parse_hlo,
)
from repro.analysis.recompile import RecompileError, RecompileGuard  # noqa: F401

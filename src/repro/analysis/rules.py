"""Static-verification rule registry + report + mutation self-test.

``tools/check_static.py`` (CI's ``static-analysis`` job) drives these:

* ``sign-safety`` — ``analysis.signs`` certificates (``corr >= 0``,
  ``fhat <= u``) for every registry arch x sigma kind, on both the
  training forward and the serving catch-up.
* ``collective-free`` / ``no-host-transfer`` / ``no-dynamic-shapes`` —
  ``analysis.hlo`` rules over every arch's compiled monitor path
  (unsharded lowering; the mesh path re-checks at shard time).
* ``recompile-once`` — a real churn episode on the paper serving config
  with a ``RecompileGuard`` armed after warmup.

The mutation self-test seeds one violation per rule (corrector sign
flip, injected ``psum``, host callback, bounded-dynamic dim, forced
retrace) and asserts the rule FIRES — a rule that cannot catch its own
seeded violation is reported as broken.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import hlo as ahlo
from repro.analysis import signs
from repro.analysis.recompile import RecompileGuard


@dataclasses.dataclass
class RuleResult:
    rule: str
    target: str
    ok: bool
    detail: str = ""


def _engine_for(cfg, batch: int = 2, max_len: int = 8):
    """A CollaborativeEngine over fully abstract params (ShapeDtypeStruct
    leaves) — construction does no math, lowering needs only avals."""
    from repro.serving.collaborative import CollaborativeEngine
    params = signs.abstract_params(cfg)
    return CollaborativeEngine(params, cfg, batch=batch, max_len=max_len)


def run_sign_rules(arch_names: Optional[Sequence[str]] = None
                   ) -> List[RuleResult]:
    from repro.configs import registry
    names = list(arch_names) if arch_names else registry.names()
    out = []
    for name in names:
        cfg = registry.get_smoke(name)
        for cert in signs.verify_arch(cfg, arch=name):
            out.append(RuleResult(
                "sign-safety", f"{name}/{cert.target}[{cert.sigma}]",
                cert.ok, "" if cert.ok else cert.detail))
    return out


def run_hlo_rules(arch_names: Optional[Sequence[str]] = None
                  ) -> List[RuleResult]:
    from repro.configs import registry
    names = list(arch_names) if arch_names else registry.names()
    out = []
    for name in names:
        eng = _engine_for(registry.get_smoke(name))
        for kernel, rule, hits in ahlo.check_monitor_path(eng):
            out.append(RuleResult(
                rule, f"{name}/{kernel}", not hits,
                "" if not hits else "\n".join(h.brief() for h in hits[:8])))
    return out


def run_recompile_rule() -> List[RuleResult]:
    """Arm a guard over a REAL churn episode (attach/detach on the paper
    serving config, threshold forced low so every step triggers the
    catch-up) and assert exactly-once compilation per jitted path after
    warmup covers both the uniform (scalar-t) and ragged (vector-t)
    pools."""
    from repro.configs.paper_synthetic import SERVING
    from repro.core import decomposition as deco
    from repro.data import tokens as tok
    cfg = SERVING.replace(monitor=SERVING.monitor.__class__(
        **{**SERVING.monitor.__dict__, "threshold": -1e9,
           "trigger_margin": 0.0}))
    params = deco.init_collab_lm(jax.random.PRNGKey(0), cfg)
    stream = next(tok.lm_batches(0, cfg, 3, 16))["tokens"]
    from repro.serving.collaborative import CollaborativeEngine
    eng = CollaborativeEngine(params, cfg, batch=3, max_len=32)
    session = eng.session(streams=["a", "b", "c"])

    def step(t, sids):
        session.step({sid: stream[i % 3, t] for i, sid in enumerate(sids)})

    # warmup: uniform pool (scalar-t catch-up), then ragged pool
    # (vector-t catch-up) — both legitimate compile entries
    for t in range(2):
        step(t, ("a", "b", "c"))
    session.detach("b")
    step(2, ("a", "c"))
    session.attach("d")
    step(3, ("a", "c", "d"))

    guard = session.arm_recompile_guard()
    # the churn episode under guard: more steps, another detach/attach
    step(4, ("a", "c", "d"))
    session.detach("d")
    step(5, ("a", "c"))
    session.attach("e")
    for t in range(6, 10):
        step(t, ("a", "c", "e"))
    bad = guard.violations()
    return [RuleResult(
        "recompile-once", "paper-synthetic-serving/churn", not bad,
        "" if not bad else "; ".join(bad))]


# ---------------------------------------------------------------------------
# Mutation self-test: each rule must catch its seeded violation
# ---------------------------------------------------------------------------


def _mutate_sign() -> RuleResult:
    from repro.configs import registry
    cfg = registry.get_smoke("granite-8b")
    s = cfg.monitor.s
    cert = signs.verify_forward(cfg, arch="granite-8b", s=-abs(s))
    fired = not cert.ok
    return RuleResult("sign-safety", "mutation: corrector sign flipped",
                      fired, "" if fired else
                      "flipped-sign corrector was NOT refuted")


def _mutate_policy_sign() -> RuleResult:
    """A runaway threshold policy can at most drive every stream to the
    always-trigger extreme (thresholds only select WHEN the server is
    consulted) — so the certificate that must hold there is still the
    corrector's sign.  Verify the catch-up at that extreme operating
    point with the sign flipped: the rule must refute it, proving the
    sign certificates cover every operating point a policy can reach."""
    from repro.configs import registry
    cfg = registry.get_smoke("granite-8b")
    mon = cfg.monitor
    cfg = cfg.replace(monitor=mon.__class__(
        **{**mon.__dict__, "threshold": -1e9, "trigger_margin": 0.0}))
    cert = signs.verify_catchup(cfg, arch="granite-8b", s=-abs(mon.s))
    fired = not cert.ok
    return RuleResult("sign-safety",
                      "mutation: sign flipped at policy always-trigger",
                      fired, "" if fired else
                      "flipped-sign catch-up at the policy extreme was "
                      "NOT refuted")


def _mutate_collective() -> RuleResult:
    if jax.device_count() >= 2:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        mesh = Mesh(np.asarray(jax.devices()[:2]), ("d",))
        f = shard_map(lambda x: jax.lax.psum(x, "d"), mesh,
                      in_specs=P("d"), out_specs=P())
        txt = jax.jit(f).lower(
            jax.ShapeDtypeStruct((2, 4), jnp.float32)).compile().as_text()
        src = "injected psum (shard_map over 2 devices)"
    else:  # single-device fallback: a real all-reduce instruction line
        txt = ("ENTRY %e {\n  %x = f32[4]{0} parameter(0)\n"
               "  ROOT %ar = f32[4]{0} all-reduce(f32[4]{0} %x)\n}\n")
        src = "synthetic all-reduce (host has 1 device)"
    hits = ahlo.collective_instructions(txt)
    fired = bool(hits)
    return RuleResult("collective-free", f"mutation: {src}", fired,
                      "" if fired else "injected collective NOT flagged")


def _mutate_host_transfer() -> RuleResult:
    def f(x):
        return jax.pure_callback(
            lambda a: np.asarray(a) * 2.0,
            jax.ShapeDtypeStruct((4,), jnp.float32), x)
    txt = jax.jit(f).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)).compile().as_text()
    hits = ahlo.host_transfer_instructions(txt)
    fired = bool(hits)
    return RuleResult("no-host-transfer", "mutation: pure_callback on path",
                      fired, "" if fired else
                      "host callback custom-call NOT flagged")


def _mutate_dynamic_shape() -> RuleResult:
    txt = ("ENTRY %e {\n  %x = f32[<=8]{0} parameter(0)\n"
           "  ROOT %y = f32[<=8]{0} add(f32[<=8]{0} %x, f32[<=8]{0} %x)\n}\n")
    hits = ahlo.dynamic_shape_instructions(txt)
    fired = bool(hits)
    return RuleResult("no-dynamic-shapes", "mutation: bounded-dynamic dim",
                      fired, "" if fired else "dynamic dim NOT flagged")


def _mutate_retrace() -> RuleResult:
    f = jax.jit(lambda x: x * 2.0)
    f(jnp.zeros((2,)))  # warmup
    guard = RecompileGuard({"f": f}, track_global=False).arm()
    f(jnp.zeros((3,)))  # forced retrace: new shape signature
    fired = bool(guard.violations())
    return RuleResult("recompile-once", "mutation: forced retrace", fired,
                      "" if fired else "forced retrace NOT detected")


def mutation_selftest() -> List[RuleResult]:
    """Seed one violation per rule; ``ok`` means the rule FIRED."""
    return [_mutate_sign(), _mutate_policy_sign(), _mutate_collective(),
            _mutate_host_transfer(), _mutate_dynamic_shape(),
            _mutate_retrace()]


# ---------------------------------------------------------------------------
# Report
# ---------------------------------------------------------------------------


def format_report(results: List[RuleResult], *, verbose: bool = False) -> str:
    w_rule = max([len(r.rule) for r in results] + [4])
    w_tgt = max([len(r.target) for r in results] + [6])
    lines = [f"{'RULE':<{w_rule}}  {'TARGET':<{w_tgt}}  STATUS",
             "-" * (w_rule + w_tgt + 10)]
    for r in results:
        lines.append(f"{r.rule:<{w_rule}}  {r.target:<{w_tgt}}  "
                     f"{'pass' if r.ok else 'FAIL'}")
        if r.detail and (verbose or not r.ok):
            lines += ["    " + d for d in r.detail.splitlines()[:12]]
    n_fail = sum(not r.ok for r in results)
    lines.append(f"{len(results)} checks, {n_fail} failed")
    return "\n".join(lines)


def summarize(results: List[RuleResult]) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for r in results:
        key = r.rule + ("" if r.ok else ":failed")
        out[key] = out.get(key, 0) + 1
    return out

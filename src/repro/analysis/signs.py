"""Sign/interval abstract interpretation over closed jaxprs.

Proves the paper's safety inequality at COMPILE time: the corrector
``corr = s * sigma(v)`` is elementwise nonnegative (``sigma`` maps into
[0, 1] and ``s >= 0``), hence ``fhat = u - corr <= u`` — the edge
monitor's score is a safe upper bound on the corrected score, for every
registered arch and every ``sigma_kind``, on both the training forward
(``core.decomposition.collab_forward``) and the serving engine's fused
catch-up (``CollaborativeEngine._catchup_impl``).

Two cooperating provers over one producer graph:

* an **interval domain**: every array is abstracted by one scalar
  interval ``[lo, hi]`` covering all its elements.  Transfer functions
  are monotone per primitive (``logistic -> [0,1]``, ``tanh -> [-1,1]``,
  interval arithmetic for ``add``/``sub``/``mul``, elementcount-scaled
  sums for reductions, join for ``select_n``/``concatenate``, ...);
  unknown primitives fall back to ``[-inf, inf]`` (always sound, never
  unsound — precision is the only casualty).  Call-like primitives
  (``pjit``, ``custom_jvp_call``, ``remat``...) are INLINED so the graph
  crosses jit boundaries; ``scan``/``while`` bodies are evaluated once
  with top carries (a sound post-fixpoint, by monotonicity of every
  transfer function); ``cond`` joins its branches.
* a **structural upper-bound prover**: the interval domain is
  non-relational (it cannot see that ``u - corr`` and ``u`` share the
  same ``u``), so ``fhat <= u`` is proved by walking ``fhat``'s producer
  chain: ``sub(a, b)`` proves when ``a <= u`` and ``interval(b) >= 0``;
  ``select_n`` proves when every case proves; ``min`` when either
  operand proves; value-preserving ops (reshape/broadcast/exact
  convert/...) are looked through.  Because calls are inlined, the ``u``
  appearing inside the jnp.where pjit IS the same graph node as the
  outer ``u``.

A failed proof yields the offending primitive chain (the producer path
to the interval that went negative) as the certificate's counterexample.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

INF = math.inf

# ---------------------------------------------------------------------------
# Interval domain
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Interval:
    """One scalar interval abstracting every element of an array.
    NaN endpoints widen to +-inf (top) so the domain stays sound."""

    lo: float
    hi: float

    def __post_init__(self):
        lo, hi = float(self.lo), float(self.hi)
        if math.isnan(lo) or math.isnan(hi) or lo > hi:
            lo, hi = -INF, INF
        object.__setattr__(self, "lo", lo)
        object.__setattr__(self, "hi", hi)

    def __str__(self) -> str:
        return f"[{self.lo:.6g}, {self.hi:.6g}]"

    @property
    def nonneg(self) -> bool:
        return self.lo >= 0.0

    @property
    def nonpos(self) -> bool:
        return self.hi <= 0.0

    def join(self, other: "Interval") -> "Interval":
        return Interval(min(self.lo, other.lo), max(self.hi, other.hi))


TOP = Interval(-INF, INF)
UNIT = Interval(0.0, 1.0)


def _xmul(x: float, y: float) -> float:
    # extended-real product with the 0 * inf := 0 convention (standard in
    # interval arithmetic: finite products never exceed the cross bounds)
    if x == 0.0 or y == 0.0:
        return 0.0
    return x * y


def iadd(a: Interval, b: Interval) -> Interval:
    return Interval(a.lo + b.lo, a.hi + b.hi)


def isub(a: Interval, b: Interval) -> Interval:
    return Interval(a.lo - b.hi, a.hi - b.lo)


def imul(a: Interval, b: Interval) -> Interval:
    c = [_xmul(x, y) for x in (a.lo, a.hi) for y in (b.lo, b.hi)]
    return Interval(min(c), max(c))


def idiv(a: Interval, b: Interval) -> Interval:
    if b.lo > 0.0 or b.hi < 0.0:  # 0 excluded: monotone in 1/b
        return imul(a, Interval(1.0 / b.hi, 1.0 / b.lo))
    return TOP


def _monotone(fn: Callable[[float], float]) -> Callable[[Interval], Interval]:
    def rule(a: Interval) -> Interval:
        return Interval(fn(a.lo), fn(a.hi))
    return rule


def _sigmoid(x: float) -> float:
    if x >= 0:
        return 1.0 / (1.0 + math.exp(-min(x, 700.0)))
    e = math.exp(max(x, -700.0))
    return e / (1.0 + e)


def _exp(x: float) -> float:
    return math.exp(x) if x < 700.0 else INF


def _log(x: float) -> float:
    if x <= 0.0:
        return -INF
    return math.log(x) if math.isfinite(x) else INF


def _log1p(x: float) -> float:
    if x <= -1.0:
        return -INF
    return math.log1p(x) if math.isfinite(x) else INF


def _sqrt(x: float) -> float:
    return math.sqrt(x) if 0.0 <= x < INF else (INF if x == INF else 0.0)


# ---------------------------------------------------------------------------
# Producer graph
# ---------------------------------------------------------------------------


@dataclasses.dataclass(eq=False)
class Node:
    """One value in the (inlined) producer graph: its interval, the
    primitive that made it, and its operand nodes."""

    ival: Interval
    prim: str
    operands: Tuple["Node", ...] = ()
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    aval: str = ""

    def describe(self) -> str:
        return f"{self.prim} {self.aval}: {self.ival}"


# value-preserving ops: same elements, new layout — transparent to both
# the interval domain and the structural prover
_IDENTITY_PRIMS = frozenset({
    "copy", "reshape", "broadcast_in_dim", "squeeze", "transpose", "rev",
    "expand_dims", "stop_gradient", "slice", "dynamic_slice", "gather",
    "device_put", "sharding_constraint", "optimization_barrier",
})

# bounded-range float unaries.  (Boolean-valued primitives — compares,
# logical ops, is_finite — need no rule: every bool-dtype output is
# clamped to [0, 1] by the interpreter's dtype refinement.)
_RANGE_PRIMS = {
    "logistic": UNIT,
    "erf": Interval(-1.0, 1.0),
    "sin": Interval(-1.0, 1.0),
    "cos": Interval(-1.0, 1.0),
    "atan2": Interval(-math.pi, math.pi),
}

_MONOTONE_PRIMS = {
    "exp": _exp, "exp2": lambda x: _exp(x * math.log(2.0)),
    "log": _log, "log1p": _log1p, "sqrt": _sqrt,
    "cbrt": lambda x: math.copysign(abs(x) ** (1.0 / 3.0), x)
    if math.isfinite(x) else x,
}


def _elem_count(shape: Sequence[int], axes: Sequence[int]) -> int:
    n = 1
    for ax in axes:
        n *= shape[ax]
    return max(n, 1)


def _refine_range(ival: Interval, prim: str) -> Interval:
    rng = _RANGE_PRIMS.get(prim)
    if rng is None:
        return ival
    return Interval(max(ival.lo, rng.lo), min(ival.hi, rng.hi))


class SignAnalysis:
    """Abstract interpretation of one closed jaxpr.  ``in_intervals``
    (optional, per flat invar) refines inputs; default is top."""

    def __init__(self, closed_jaxpr, in_intervals: Optional[Sequence[Interval]] = None):
        self.closed_jaxpr = closed_jaxpr
        jaxpr = closed_jaxpr.jaxpr
        consts = [self._const_node(c) for c in closed_jaxpr.consts]
        if in_intervals is None:
            in_intervals = [TOP] * len(jaxpr.invars)
        self.in_nodes = [
            Node(self._refine_input(iv, v), "input", aval=str(v.aval))
            for iv, v in zip(in_intervals, jaxpr.invars)]
        self.out_nodes = self._eval(jaxpr, consts, self.in_nodes)

    # -- node builders ------------------------------------------------------

    @staticmethod
    def _refine_input(iv: Interval, var) -> Interval:
        dt = getattr(var.aval, "dtype", None)
        if dt is not None and dt == jnp.bool_:
            return Interval(max(iv.lo, 0.0), min(iv.hi, 1.0))
        return iv

    @staticmethod
    def _value_interval(val) -> Interval:
        try:
            arr = np.asarray(val)
            if arr.size == 0:
                return Interval(0.0, 0.0)
            if arr.dtype == np.bool_:
                arr = arr.astype(np.float64)
            return Interval(float(arr.min()), float(arr.max()))
        except (TypeError, ValueError, OverflowError):
            return TOP

    def _const_node(self, val) -> Node:
        return Node(self._value_interval(val), "const",
                    aval=f"{getattr(val, 'dtype', '?')}{getattr(val, 'shape', '')}")

    def _read(self, env: Dict, v) -> Node:
        if isinstance(v, jax.core.Literal):
            return Node(self._value_interval(v.val), "literal", aval=str(v.aval))
        return env[v]

    # -- interpreter --------------------------------------------------------

    def _eval(self, jaxpr, const_nodes: List[Node],
              arg_nodes: List[Node]) -> List[Node]:
        env: Dict[Any, Node] = {}
        for var, node in zip(jaxpr.constvars, const_nodes):
            env[var] = node
        for var, node in zip(jaxpr.invars, arg_nodes):
            env[var] = node
        for eqn in jaxpr.eqns:
            ins = [self._read(env, v) for v in eqn.invars]
            outs = self._eval_eqn(eqn, ins)
            for var, node in zip(eqn.outvars, outs):
                env[var] = node
        return [self._read(env, v) for v in jaxpr.outvars]

    def _sub_jaxpr(self, obj) -> Tuple[Any, List[Node]]:
        if hasattr(obj, "jaxpr"):  # ClosedJaxpr
            return obj.jaxpr, [self._const_node(c) for c in obj.consts]
        return obj, []

    def _eval_eqn(self, eqn, ins: List[Node]) -> List[Node]:
        prim = eqn.primitive.name
        mk_top = lambda: [  # noqa: E731
            Node(self._refine_input(TOP, v), prim, tuple(ins),
                 dict(eqn.params), str(v.aval)) for v in eqn.outvars]

        if prim == "scan":
            return self._eval_scan(eqn, ins)
        if prim == "while":
            return self._eval_while(eqn, ins)
        if prim == "cond":
            return self._eval_cond(eqn, ins)
        for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
            sub = eqn.params.get(key)
            if sub is not None:
                inner, consts = self._sub_jaxpr(sub)
                if len(inner.invars) <= len(ins):
                    # custom_jvp/vjp carry extra non-primal operands first;
                    # primal args are the trailing invars
                    return self._eval(inner, consts, ins[-len(inner.invars):]
                                      if inner.invars else [])
                return mk_top()

        rule = _RULES.get(prim)
        if rule is None:
            return mk_top()
        res = rule(eqn, [n.ival for n in ins])
        if isinstance(res, Interval):
            res = [res] * len(eqn.outvars)
        return [Node(self._refine_input(iv, v), prim, tuple(ins),
                     dict(eqn.params), str(v.aval))
                for iv, v in zip(res, eqn.outvars)]

    def _eval_scan(self, eqn, ins: List[Node]) -> List[Node]:
        inner, consts = self._sub_jaxpr(eqn.params["jaxpr"])
        nc, nk = eqn.params["num_consts"], eqn.params["num_carry"]
        carry_top = [Node(TOP, "loop_carry", aval=str(v.aval))
                     for v in inner.invars[nc:nc + nk]]
        # xs operands are time-stacked: per-element interval == stacked one
        body_out = self._eval(inner, consts,
                              ins[:nc] + carry_top + ins[nc + nk:])
        outs = []
        for i, node in enumerate(body_out):
            if i < nk:  # carry out: join with init (covers 0 iterations)
                iv = node.ival.join(ins[nc + i].ival)
            else:  # ys: every slice produced by the top-carry body
                iv = node.ival
            outs.append(Node(iv, "scan", tuple(ins), dict(eqn.params),
                             str(eqn.outvars[i].aval)))
        return outs

    def _eval_while(self, eqn, ins: List[Node]) -> List[Node]:
        inner, consts = self._sub_jaxpr(eqn.params["body_jaxpr"])
        cn, bn = eqn.params["cond_nconsts"], eqn.params["body_nconsts"]
        carry_in = ins[cn + bn:]
        carry_top = [Node(TOP, "loop_carry", aval=str(v.aval))
                     for v in inner.invars[bn:]]
        body_out = self._eval(inner, consts, ins[cn:cn + bn] + carry_top)
        return [Node(node.ival.join(init.ival), "while", tuple(ins),
                     dict(eqn.params), str(v.aval))
                for node, init, v in zip(body_out, carry_in, eqn.outvars)]

    def _eval_cond(self, eqn, ins: List[Node]) -> List[Node]:
        branch_outs = []
        for br in eqn.params["branches"]:
            inner, consts = self._sub_jaxpr(br)
            branch_outs.append(self._eval(inner, consts, ins[1:]))
        outs = []
        for i, v in enumerate(eqn.outvars):
            iv = branch_outs[0][i].ival
            for bo in branch_outs[1:]:
                iv = iv.join(bo[i].ival)
            outs.append(Node(iv, "cond", tuple(ins), dict(eqn.params),
                             str(v.aval)))
        return outs


# -- per-primitive transfer functions (eqn, [Interval]) -> Interval|list ----


def _scaled_sum(a: Interval, n: int) -> Interval:
    """Sum of n elements each in ``a``: exactly [n*lo, n*hi]."""
    return Interval(_xmul(float(n), a.lo), _xmul(float(n), a.hi))


def _rule_reduce_sum(eqn, ivals):
    n = _elem_count(eqn.invars[0].aval.shape, eqn.params.get("axes", ()))
    return _scaled_sum(ivals[0], n)


def _rule_dot(eqn, ivals):
    (lc, _), _ = eqn.params["dimension_numbers"]
    n = _elem_count(eqn.invars[0].aval.shape, lc)
    return _scaled_sum(imul(ivals[0], ivals[1]), n)


def _ipow(x: float, y: int) -> float:
    if not math.isfinite(x):
        return x if y % 2 == 1 or x > 0 else INF
    try:
        return float(x) ** y
    except OverflowError:
        return INF if (x > 0 or y % 2 == 0) else -INF


def _rule_integer_pow(eqn, ivals):
    y = int(eqn.params["y"])
    a = ivals[0]
    if y == 0:
        return Interval(1.0, 1.0)
    if y < 0:
        return idiv(Interval(1.0, 1.0), _rule_integer_pow(
            type("E", (), {"params": {"y": -y}})(), [a]))
    c = [_ipow(a.lo, y), _ipow(a.hi, y)]
    lo, hi = min(c), max(c)
    if y % 2 == 0:
        lo = 0.0 if a.lo <= 0.0 <= a.hi else max(lo, 0.0)
    return Interval(lo, hi)


def _rule_pad(eqn, ivals):
    return ivals[0].join(ivals[1])


def _rule_select_n(eqn, ivals):
    iv = ivals[1]
    for other in ivals[2:]:
        iv = iv.join(other)
    return iv


def _rule_clamp(eqn, ivals):
    lo_b, x, hi_b = ivals
    m = Interval(max(x.lo, lo_b.lo), max(x.hi, lo_b.hi))   # max(x, lo)
    return Interval(min(m.lo, hi_b.hi), min(m.hi, hi_b.hi))  # min(., hi)


def _rule_iota(eqn, ivals):
    shape = eqn.params.get("shape", (1,))
    dim = eqn.params.get("dimension", 0)
    n = shape[dim] if shape else 1
    return Interval(0.0, float(max(n - 1, 0)))


def _rule_scatter_add(eqn, ivals):
    operand, _idx, upd = ivals[0], ivals[1], ivals[2]
    if upd.nonneg:
        return Interval(operand.lo, INF)
    if upd.nonpos:
        return Interval(-INF, operand.hi)
    return TOP


def _rule_sort(eqn, ivals):
    return list(ivals)  # values permuted per operand


def _rule_top_k(eqn, ivals):
    k_dim = eqn.invars[0].aval.shape[-1] if eqn.invars[0].aval.shape else 1
    return [ivals[0], Interval(0.0, float(max(k_dim - 1, 0)))]


def _rule_cumsum(eqn, ivals):
    axis = eqn.params.get("axis", 0)
    n = eqn.invars[0].aval.shape[axis] if eqn.invars[0].aval.shape else 1
    a = ivals[0]
    return Interval(min(_xmul(n, a.lo), a.lo), max(_xmul(n, a.hi), a.hi))


def _simple(fn):
    return lambda eqn, ivals: fn(*ivals)


_RULES: Dict[str, Callable] = {
    "add": _simple(iadd), "sub": _simple(isub), "mul": _simple(imul),
    "div": _simple(idiv),
    "neg": _simple(lambda a: Interval(-a.hi, -a.lo)),
    "abs": _simple(lambda a: Interval(
        0.0 if a.lo <= 0.0 <= a.hi else min(abs(a.lo), abs(a.hi)),
        max(abs(a.lo), abs(a.hi)))),
    "sign": _simple(lambda a: Interval(-1.0, 1.0)),
    "square": _simple(lambda a: Interval(
        0.0 if a.lo <= 0.0 <= a.hi else min(_xmul(a.lo, a.lo),
                                            _xmul(a.hi, a.hi)),
        max(_xmul(a.lo, a.lo), _xmul(a.hi, a.hi)))),
    "integer_pow": _rule_integer_pow,
    "max": _simple(lambda a, b: Interval(max(a.lo, b.lo), max(a.hi, b.hi))),
    "min": _simple(lambda a, b: Interval(min(a.lo, b.lo), min(a.hi, b.hi))),
    "rem": lambda eqn, ivals: TOP,
    "pow": _simple(lambda a, b: Interval(0.0, INF) if a.lo >= 0.0 else TOP),
    "rsqrt": _simple(lambda a: Interval(0.0, INF) if a.lo >= 0.0 else TOP),
    "logistic": _simple(_monotone(_sigmoid)),
    "tanh": _simple(lambda a: Interval(max(math.tanh(min(a.lo, 20.0)), -1.0),
                                       min(math.tanh(min(a.hi, 20.0)), 1.0))
                    if math.isfinite(a.lo) or math.isfinite(a.hi)
                    else Interval(-1.0, 1.0)),
    "convert_element_type": _simple(lambda a: a),
    "reduce_precision": _simple(lambda a: a),
    "reduce_sum": _rule_reduce_sum,
    "reduce_max": _simple(lambda a: a),
    "reduce_min": _simple(lambda a: a),
    "reduce_prod": lambda eqn, ivals: (
        Interval(0.0, INF) if ivals[0].nonneg else TOP),
    "argmax": lambda eqn, ivals: Interval(0.0, INF),
    "argmin": lambda eqn, ivals: Interval(0.0, INF),
    "dot_general": _rule_dot,
    "concatenate": lambda eqn, ivals: _rule_select_n(
        eqn, [None] + list(ivals)),
    "pad": _rule_pad,
    "dynamic_update_slice": lambda eqn, ivals: ivals[0].join(ivals[1]),
    "select_n": _rule_select_n,
    "clamp": _rule_clamp,
    "iota": _rule_iota,
    "scatter": lambda eqn, ivals: ivals[0].join(ivals[2]),
    "scatter-add": _rule_scatter_add,
    "scatter_add": _rule_scatter_add,
    "sort": _rule_sort,
    "top_k": _rule_top_k,
    "cumsum": _rule_cumsum,
    "cummax": _simple(lambda a: a),
    "cummin": _simple(lambda a: a),
    "floor": _simple(lambda a: Interval(a.lo - 1.0, a.hi)),
    "ceil": _simple(lambda a: Interval(a.lo, a.hi + 1.0)),
    "round": _simple(lambda a: Interval(a.lo - 1.0, a.hi + 1.0)),
    "nextafter": _simple(lambda a, b: a.join(b)),
    "split": lambda eqn, ivals: [ivals[0]] * len(eqn.outvars),
}
for _p, _rng in _RANGE_PRIMS.items():
    _RULES.setdefault(_p, (lambda rng: (lambda eqn, ivals: rng))(_rng))
for _p, _fn in _MONOTONE_PRIMS.items():
    _RULES.setdefault(_p, _simple(_monotone(_fn)))
for _p in _IDENTITY_PRIMS:
    _RULES.setdefault(_p, _simple(lambda a, *rest: a))


def analyze_jaxpr(closed_jaxpr, in_intervals=None) -> SignAnalysis:
    """Run the abstract interpreter; returns the analysis (``.in_nodes``,
    ``.out_nodes`` hold the producer graph)."""
    return SignAnalysis(closed_jaxpr, in_intervals)


# ---------------------------------------------------------------------------
# Structural prover + counterexample chains
# ---------------------------------------------------------------------------

# exact value-preserving: safe to look through when proving <=
_LE_TRANSPARENT = _IDENTITY_PRIMS | {"scan_ys_identity"}

_F_WIDTH = {"bfloat16": 8, "float16": 11, "float32": 24, "float64": 53}


def _convert_exact(node: Node) -> bool:
    """True when a convert_element_type cannot round values upward:
    float -> same-or-wider float, or int -> wide-enough float."""
    new = str(node.params.get("new_dtype", ""))
    src = str(getattr(node.operands[0], "aval", ""))
    src_dt = src.split("[")[0] if "[" in src else src
    if new in _F_WIDTH and src_dt in _F_WIDTH:
        return _F_WIDTH[new] >= _F_WIDTH[src_dt]
    if new in ("float32", "float64") and src_dt in ("int8", "uint8", "bool",
                                                    "int16", "uint16"):
        return True
    return False


def prove_nonneg(node: Node) -> Tuple[bool, List[str]]:
    """Interval proof of ``node >= 0`` elementwise; on failure, the
    producer chain that introduced the negative range."""
    if node.ival.nonneg:
        return True, [f"proved: {node.describe()} (interval nonnegative)"]
    return False, _blame_chain(node)


def _blame_chain(node: Node, depth: int = 14) -> List[str]:
    chain = [node.describe()]
    cur = node
    while depth > 0 and cur.operands:
        nxt = None
        for op in cur.operands:  # follow the operand that can go negative
            if not op.ival.nonneg:
                nxt = op
                break
        if nxt is None:
            break
        chain.append(nxt.describe())
        cur = nxt
        depth -= 1
    return chain


def prove_le(f: Node, u: Node, depth: int = 64) -> Tuple[bool, List[str]]:
    """Structural proof of ``f <= u`` elementwise.  Returns (ok, chain):
    the proof steps on success, the refuting producer path on failure."""
    ok, chain = _prove_le(f, u, depth)
    return ok, chain


def _prove_le(f: Node, u: Node, depth: int) -> Tuple[bool, List[str]]:
    here = f.describe()
    if f is u:
        return True, [f"{here} == u (same producer)"]
    if depth <= 0:
        return False, [f"{here}: proof depth exhausted"]
    # numeric fallback: intervals alone can settle it
    if f.ival.hi <= u.ival.lo:
        return True, [f"{here} <= {u.ival} numerically"]
    # look through exact value-preserving u producers
    if u.prim in _LE_TRANSPARENT and u.operands:
        return _prove_le(f, u.operands[0], depth - 1)
    if u.prim == "convert_element_type" and u.operands and _convert_exact(u):
        return _prove_le(f, u.operands[0], depth - 1)
    if f.prim in _LE_TRANSPARENT and f.operands:
        return _prove_le(f.operands[0], u, depth - 1)
    if f.prim == "convert_element_type" and f.operands and _convert_exact(f):
        return _prove_le(f.operands[0], u, depth - 1)
    if f.prim == "sub" and len(f.operands) == 2:
        a, b = f.operands
        ok, sub_chain = _prove_le(a, u, depth - 1)
        if ok and b.ival.nonneg:
            return True, [f"{here} = a - b with b {b.ival} >= 0"] + sub_chain
        if ok:
            return False, [f"{here}: subtrahend may be negative"] + \
                _blame_chain(b)
        return False, [f"{here}: minuend does not prove"] + sub_chain
    if f.prim == "add" and len(f.operands) == 2:
        a, b = f.operands
        for x, y in ((a, b), (b, a)):
            if y.ival.nonpos:
                ok, sub_chain = _prove_le(x, u, depth - 1)
                if ok:
                    return True, [f"{here} = x + y with y {y.ival} <= 0"] + \
                        sub_chain
        return False, [f"{here}: no nonpositive addend"]
    if f.prim in ("min", "minimum") and f.operands:
        fails = []
        for op in f.operands:
            ok, sub_chain = _prove_le(op, u, depth - 1)
            if ok:
                return True, [f"{here} = min(...), one operand proves"] + \
                    sub_chain
            fails = sub_chain
        return False, [f"{here}: no min operand proves"] + fails
    if f.prim in ("max", "maximum") and f.operands:
        chains = [f"{here} = max(...), all operands must prove"]
        for op in f.operands:
            ok, sub_chain = _prove_le(op, u, depth - 1)
            if not ok:
                return False, [f"{here}: max operand fails"] + sub_chain
            chains += sub_chain
        return True, chains
    if f.prim == "select_n" and len(f.operands) >= 2:
        chains = [f"{here} = select_n, every case must prove"]
        for op in f.operands[1:]:
            ok, sub_chain = _prove_le(op, u, depth - 1)
            if not ok:
                return False, [f"{here}: select case fails"] + sub_chain
            chains += sub_chain
        return True, chains
    if f.prim == "clamp" and len(f.operands) == 3:
        ok, sub_chain = _prove_le(f.operands[2], u, depth - 1)
        if ok:
            return True, [f"{here} = clamp(..., hi), hi proves"] + sub_chain
    return False, [f"{here}: no structural rule applies "
                   f"(u is {u.describe()})"]


# ---------------------------------------------------------------------------
# Certificates for the serving stack
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SignCertificate:
    """Per-(target, arch, sigma) result of the safety proof."""

    target: str           # "collab_forward" | "catchup"
    arch: str
    sigma: str
    ok: bool
    corr_interval: Optional[Interval]
    detail: str           # proof summary or counterexample chain

    def __str__(self) -> str:
        verdict = "PROVED" if self.ok else "REFUTED"
        corr = f" corr={self.corr_interval}" if self.corr_interval else ""
        return (f"[{verdict}] {self.target} arch={self.arch} "
                f"sigma={self.sigma}{corr}")


def _with_sigma(cfg, sigma: Optional[str], s: Optional[float] = None):
    mon = cfg.monitor
    kw = dict(mon.__dict__)
    if sigma is not None:
        kw["sigma"] = sigma
    if s is not None:
        kw["s"] = s
    return cfg.replace(monitor=mon.__class__(**kw))


def abstract_params(cfg, seed: int = 0):
    """Parameter ShapeDtypeStructs without allocating: the init runs
    under eval_shape (cfg closed over — it is config, not data)."""
    key = jax.random.PRNGKey(seed)
    from repro.core import decomposition as deco
    return jax.eval_shape(lambda k: deco.init_collab_lm(k, cfg), key)


def verify_forward(cfg, arch: str = "?", sigma: Optional[str] = None,
                   s: Optional[float] = None, batch: int = 2,
                   length: int = 4) -> SignCertificate:
    """Prove ``corr >= 0`` and ``fhat <= u`` on the traced jaxpr of the
    training-time ``collab_forward`` (params fully abstract)."""
    from repro.core import decomposition as deco
    from repro.data import tokens as tok
    cfg = _with_sigma(cfg, sigma)
    sigma_kind = cfg.monitor.sigma
    params = abstract_params(cfg)
    b = next(tok.lm_batches(0, cfg, batch, length, with_monitor=False))
    b = {k: jnp.asarray(v) for k, v in b.items()}

    def fn(p, bb):
        out = deco.collab_forward(p, cfg, bb, s=s)
        return out["corr"], out["fhat"], out["u"]

    closed = jax.make_jaxpr(fn)(params, b)
    return _certify(closed, "collab_forward", arch, sigma_kind)


def verify_catchup(cfg, arch: str = "?", sigma: Optional[str] = None,
                   s: Optional[float] = None, batch: int = 2,
                   max_len: int = 8) -> SignCertificate:
    """Prove the same inequality on the SERVING engine's fused masked
    catch-up (``CollaborativeEngine._catchup_impl`` — the jit the online
    paths call on every trigger).  The engine is built over abstract
    params; tracing allocates nothing."""
    from repro.serving.collaborative import CollaborativeEngine
    cfg = _with_sigma(cfg, sigma, s)
    sigma_kind = cfg.monitor.sigma
    params = abstract_params(cfg)
    eng = CollaborativeEngine(params, cfg, batch=batch, max_len=max_len)
    B = batch
    hist = jax.ShapeDtypeStruct(eng._history.shape, eng._history.dtype)
    cache = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                         eng.server.cache)
    args = (params, cache, hist,
            jax.ShapeDtypeStruct((B,), jnp.int32),         # server_pos
            jax.ShapeDtypeStruct((), jnp.int32),           # t (scalar form)
            jax.ShapeDtypeStruct((B,), jnp.bool_),         # triggered
            jax.ShapeDtypeStruct((B,), jnp.float32))       # u

    def fn(p, c, h, sp, t, trig, u):
        _, _, fhat = eng._catchup_impl(p, c, h, sp, t, trig, u)
        return fhat, u

    closed = jax.make_jaxpr(fn)(*args)
    analysis = analyze_jaxpr(closed)
    fhat_node, u_node = analysis.out_nodes
    # the corrector inside the fusion: fhat = sub(u', corr) possibly
    # under select_n — surfaced via the structural proof itself
    ok, chain = prove_le(fhat_node, u_node)
    corr_iv = _find_corr_interval(fhat_node)
    detail = "\n".join(chain)
    return SignCertificate("catchup", arch, sigma_kind, ok, corr_iv, detail)


def _find_corr_interval(fhat_node: Node, depth: int = 24) -> Optional[Interval]:
    """Walk fhat's producers for the first ``sub`` and report the
    subtrahend's interval — the corrector term the proof hinged on."""
    stack, seen = [(fhat_node, depth)], set()
    while stack:
        node, d = stack.pop()
        if d <= 0 or id(node) in seen:
            continue
        seen.add(id(node))
        if node.prim == "sub" and len(node.operands) == 2:
            return node.operands[1].ival
        stack.extend((op, d - 1) for op in node.operands)
    return None


def _certify(closed, target: str, arch: str, sigma_kind: str) -> SignCertificate:
    analysis = analyze_jaxpr(closed)
    corr_node, fhat_node, u_node = analysis.out_nodes
    ok_corr, corr_chain = prove_nonneg(corr_node)
    ok_le, le_chain = prove_le(fhat_node, u_node)
    ok = ok_corr and ok_le
    lines: List[str] = []
    if not ok_corr:
        lines.append("corr >= 0 REFUTED; producer chain:")
        lines += ["  " + c for c in corr_chain]
    else:
        lines.append(f"corr >= 0: interval {corr_node.ival}")
    if not ok_le:
        lines.append("fhat <= u REFUTED; producer chain:")
        lines += ["  " + c for c in le_chain]
    else:
        lines.append("fhat <= u: " + le_chain[0])
    return SignCertificate(target, arch, sigma_kind, ok,
                           corr_node.ival, "\n".join(lines))


SIGMA_KINDS = ("sigmoid", "tanh01")


def verify_arch(cfg, arch: str = "?",
                sigma_kinds: Sequence[str] = SIGMA_KINDS,
                include_catchup: bool = True) -> List[SignCertificate]:
    """The full sign-safety sweep for one arch: training forward and
    serving catch-up, under every sigma kind."""
    certs = []
    for kind in sigma_kinds:
        certs.append(verify_forward(cfg, arch=arch, sigma=kind))
        if include_catchup:
            certs.append(verify_catchup(cfg, arch=arch, sigma=kind))
    return certs

"""Compile-cache tracker: assert serving jits compile exactly once.

Every jitted path in the serving stack is shape-static by design — after
warmup, a churn episode (attach/detach, ragged pools, batch buckets)
must hit the executable cache on every call.  A silent retrace is a 10x
perf cliff; this module turns it into a test failure.

Two independent signals, cross-checked:

* per-function: ``jax.jit`` wrappers expose ``_cache_size()`` — the
  number of compiled shape specializations.  Precise and attributable
  (the violation names the path that retraced).
* global: a ``jax.monitoring`` duration listener on XLA's
  ``backend_compile`` event counts EVERY compilation in the process —
  catching retraces in jits the guard was not told about.

``MonitorSession.arm_recompile_guard()`` arms a guard over the engine's
``jitted_paths()``; ``tools/check_static.py`` and
``tests/test_churn.py`` drive it through real churn episodes.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

import jax

# -- global compile counter (one process-wide listener, registered once) ----

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
_global_compiles = 0
_listener_registered = False


def _on_event(event: str, duration: float, **kw) -> None:
    global _global_compiles
    if event == _COMPILE_EVENT:
        _global_compiles += 1


def _ensure_listener() -> None:
    global _listener_registered
    if not _listener_registered:
        jax.monitoring.register_event_duration_secs_listener(_on_event)
        _listener_registered = True


def global_compile_count() -> int:
    """Process-wide backend compilations observed since the first guard
    was armed (0 before that)."""
    _ensure_listener()
    return _global_compiles


def _cache_size(fn) -> Optional[int]:
    try:
        return int(fn._cache_size())
    except AttributeError:
        return None


class RecompileError(AssertionError):
    """A watched jitted path compiled again after the guard was armed."""


class RecompileGuard:
    """Snapshot compile-cache sizes for a set of jitted paths; assert
    they never grow.  Usage::

        guard = RecompileGuard(engine.jitted_paths()).arm()
        ... churn episode ...
        guard.assert_stable()          # raises RecompileError on retrace

    or as a context manager (asserts on clean exit).  Arm AFTER warmup:
    the first call on each shape signature legitimately compiles.
    """

    def __init__(self, jits: Dict[str, Callable],
                 *, track_global: bool = True, warm_only: bool = False):
        self.jits = dict(jits)
        self.track_global = track_global
        # warm_only: watch only paths that have >=1 compiled signature at
        # arm time — an episode that never exercised a path should not
        # count that path's FIRST compile as a retrace
        self.warm_only = warm_only
        self._baseline: Optional[Dict[str, Optional[int]]] = None
        self._global0 = 0
        if track_global:
            _ensure_listener()

    @property
    def armed(self) -> bool:
        return self._baseline is not None

    def arm(self) -> "RecompileGuard":
        if self.warm_only:
            self.jits = {name: fn for name, fn in self.jits.items()
                         if (_cache_size(fn) or 0) > 0}
        self._baseline = {name: _cache_size(fn)
                          for name, fn in self.jits.items()}
        if self.track_global:
            self._global0 = global_compile_count()
        return self

    def violations(self) -> List[str]:
        """Watched paths whose executable cache grew since ``arm()``."""
        if self._baseline is None:
            raise RuntimeError("guard not armed (call arm() after warmup)")
        out = []
        for name, fn in self.jits.items():
            before, now = self._baseline[name], _cache_size(fn)
            if before is not None and now is not None and now > before:
                out.append(f"{name}: {before} -> {now} compiled "
                           f"specializations")
        return out

    def global_compiles(self) -> int:
        """Backend compilations ANYWHERE in the process since ``arm()``."""
        if self._baseline is None:
            raise RuntimeError("guard not armed (call arm() after warmup)")
        return global_compile_count() - self._global0 \
            if self.track_global else 0

    def assert_stable(self, *, allow_global: Optional[int] = None) -> None:
        """Raise ``RecompileError`` if any watched path retraced.  With
        ``allow_global`` set, also bound the process-wide compile count
        (0 = nothing at all may have compiled since arming)."""
        bad = self.violations()
        if bad:
            raise RecompileError(
                "jitted serving paths retraced after warmup (each path "
                "must compile exactly once):\n  " + "\n  ".join(bad))
        if allow_global is not None and self.track_global:
            n = self.global_compiles()
            if n > allow_global:
                raise RecompileError(
                    f"{n} backend compilations since the guard was armed "
                    f"(allowed {allow_global}) — an unwatched jit "
                    f"retraced")

    def __enter__(self) -> "RecompileGuard":
        return self.arm()

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.assert_stable()

"""Checkpointing without orbax: leaves are stored in an .npz keyed by their
``jax.tree_util`` key-path string (+ a JSON manifest with the step/meta).
Restore flattens the template with the same canonical order and rebuilds
with ``tree_unflatten`` — this round-trips dicts, lists and NamedTuples
(AdamState) alike, and the template supplies dtypes/structure.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(kp): np.asarray(jax.device_get(leaf))
            for kp, leaf in flat}


def save(path: str, step: int, params, opt_state=None,
         meta: Optional[Dict] = None) -> None:
    os.makedirs(path, exist_ok=True)
    np.savez(os.path.join(path, "params.npz"), **_flatten(params))
    if opt_state is not None:
        np.savez(os.path.join(path, "opt.npz"), **_flatten(opt_state))
    with open(os.path.join(path, "manifest.json"), "w") as fh:
        json.dump({"step": int(step), "meta": meta or {}}, fh)


def _restore_into(template, npz) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for kp, leaf in flat:
        key = jax.tree_util.keystr(kp)
        arr = npz[key]
        assert arr.shape == leaf.shape, (
            f"checkpoint/template shape mismatch at {key}: "
            f"{arr.shape} vs {leaf.shape}")
        leaves.append(jnp.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load(path: str, params_template, opt_template=None
         ) -> Tuple[int, Any, Optional[Any]]:
    with open(os.path.join(path, "manifest.json")) as fh:
        manifest = json.load(fh)
    params = _restore_into(params_template, np.load(os.path.join(path, "params.npz")))
    opt = None
    opt_file = os.path.join(path, "opt.npz")
    if opt_template is not None and os.path.exists(opt_file):
        opt = _restore_into(opt_template, np.load(opt_file))
    return manifest["step"], params, opt

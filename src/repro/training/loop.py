"""Training loops.

``make_train_step`` builds the jit-able collaborative LM step used both by
the CPU examples (tiny configs) and the multi-pod launcher (full configs,
via pjit in launch/train.py — same function, different shardings).

``train_paper`` runs the paper-scale experiments (small MLPs, Adam, exactly
the §4 recipe).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import decomposition as deco
from repro.core.losses import collab_lm_loss, paper_loss
from repro.training.optimizer import AdamW


def make_train_step(cfg: ArchConfig, opt: AdamW, *, monitor_weight: float = 1.0,
                    safety_weight: float = 10.0) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def loss_fn(params, batch):
        out = deco.collab_forward(params, cfg, batch)
        parts = collab_lm_loss(out, batch, monitor_weight=monitor_weight,
                               safety_weight=safety_weight)
        return parts["total"], parts

    def step(params, opt_state, batch):
        (_, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        params, opt_state, gnorm = opt.update(grads, opt_state, params)
        parts["grad_norm"] = gnorm
        return params, opt_state, parts

    return step


def train_collab_lm(key, cfg: ArchConfig, batches: Iterator[Dict], *,
                    steps: int, lr: float = 3e-4, log_every: int = 10,
                    monitor_weight: float = 1.0, safety_weight: float = 10.0,
                    log_fn: Callable = print) -> Tuple[Dict, list]:
    """End-to-end driver (CPU scale).  Returns (params, history)."""
    params = deco.init_collab_lm(key, cfg)
    opt = AdamW(lr=lr)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt, monitor_weight=monitor_weight,
                                   safety_weight=safety_weight))
    history = []
    t0 = time.time()
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
        params, opt_state, m = step(params, opt_state, batch)
        if i % log_every == 0 or i == steps - 1:
            rec = {k: float(v) for k, v in m.items()}
            rec["step"], rec["wall_s"] = i, time.time() - t0
            history.append(rec)
            log_fn(f"step {i:5d}  loss {rec['total']:.4f}  lm {rec['lm']:.4f}  "
                   f"monitor {rec['monitor']:.4f}  safety {rec['safety']:.5f}")
    return params, history


# ---------------------------------------------------------------------------
# Paper-scale training (§4)
# ---------------------------------------------------------------------------


def train_paper(key, cfg, x: np.ndarray, f: np.ndarray, *, u_mode: str,
                s: Optional[float] = None, monitor_n: Optional[int] = None,
                n_modes: int = 0, u_dims=None, steps: int = 2000,
                lr: float = 1e-2, batch: int = 256,
                safety_weight: float = 0.0,
                freeze_t: Optional[float] = None, seed: int = 0,
                log_fn: Optional[Callable] = None) -> Tuple[Dict, Dict]:
    """Trains f_hat = u - s*sigma(v) end-to-end with Adam (paper §4.1).

    ``freeze_t``: if given, t is pinned to this value (Prop-2 calibration
    mode) instead of being learned.
    """
    params = deco.init_paper_decomposition(key, cfg, u_mode=u_mode,
                                           n_modes=n_modes, u_dims=u_dims)
    if freeze_t is not None:
        params["raw_t"] = jnp.asarray(deco._inv_softplus(max(freeze_t, 1e-6)),
                                      jnp.float32)
    opt = AdamW(lr=lr, clip_norm=0.0)
    opt_state = opt.init(params)
    xj, fj = jnp.asarray(x), jnp.asarray(f)
    n = x.shape[0]
    s_val = cfg.s if s is None else s

    def loss_fn(p, xb, fb):
        out = deco.paper_forward(p, xb, cfg, u_mode=u_mode, s=s_val,
                                 monitor_n=monitor_n)
        return paper_loss(out, fb, safety_weight=safety_weight)

    @jax.jit
    def step(p, st, xb, fb):
        loss, grads = jax.value_and_grad(loss_fn)(p, xb, fb)
        if freeze_t is not None:
            grads = dict(grads)
            grads["raw_t"] = jnp.zeros_like(grads["raw_t"])
        p, st, _ = opt.update(grads, st, p)
        return p, st, loss

    rng = np.random.default_rng(seed)
    loss = None
    for i in range(steps):
        idx = rng.integers(0, n, size=batch)
        params, opt_state, loss = step(params, opt_state, xj[idx], fj[idx])
        if log_fn and i % 200 == 0:
            log_fn(f"  paper-train step {i} loss {float(loss):.6f}")
    out = deco.paper_forward(params, xj, cfg, u_mode=u_mode, s=s_val,
                             monitor_n=monitor_n)
    return params, {"final_loss": float(loss), "out": out}

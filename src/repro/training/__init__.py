from repro.training import checkpoint, loop, optimizer, schedule  # noqa: F401

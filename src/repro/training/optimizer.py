"""Native-JAX optimizers (no optax in this environment): Adam / AdamW / SGD
with global-norm clipping.  State trees mirror the param tree, so the same
PartitionSpecs shard optimizer state (ZeRO-style) for free.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    count: jnp.ndarray
    m: Any
    v: Any


@dataclass(frozen=True)
class AdamW:
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: float = 1.0

    def init(self, params) -> AdamState:
        zeros = lambda t: jax.tree.map(lambda l: jnp.zeros_like(l, jnp.float32), t)
        return AdamState(count=jnp.zeros((), jnp.int32), m=zeros(params),
                         v=zeros(params))

    def update(self, grads, state: AdamState, params
               ) -> Tuple[Any, AdamState, jnp.ndarray]:
        """-> (new_params, new_state, grad_norm)."""
        gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                             for g in jax.tree.leaves(gf)) + 1e-12)
        if self.clip_norm:
            scale = jnp.minimum(1.0, self.clip_norm / gnorm)
            gf = jax.tree.map(lambda g: g * scale, gf)
        count = state.count + 1
        lr = self.lr(count) if callable(self.lr) else self.lr
        bc1 = 1.0 - self.b1 ** count.astype(jnp.float32)
        bc2 = 1.0 - self.b2 ** count.astype(jnp.float32)

        def upd(p, g, m, v):
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * jnp.square(g)
            step = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            if self.weight_decay:
                step = step + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(gf)
        flat_m = jax.tree.leaves(state.m)
        flat_v = jax.tree.leaves(state.v)
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
        new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
        return new_p, AdamState(count=count, m=new_m, v=new_v), gnorm


@dataclass(frozen=True)
class SGD:
    lr: Callable | float = 1e-2
    momentum: float = 0.9

    def init(self, params):
        return AdamState(count=jnp.zeros((), jnp.int32),
                         m=jax.tree.map(lambda l: jnp.zeros_like(l, jnp.float32), params),
                         v=None)

    def update(self, grads, state, params):
        count = state.count + 1
        lr = self.lr(count) if callable(self.lr) else self.lr
        new_m = jax.tree.map(
            lambda m, g: self.momentum * m + g.astype(jnp.float32), state.m, grads)
        new_p = jax.tree.map(lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
                             params, new_m)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)) + 1e-12)
        return new_p, AdamState(count=count, m=new_m, v=None), gnorm

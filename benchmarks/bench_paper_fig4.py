"""Paper Fig 4/5 (financial monitoring, §4.2): predict one ticker from the
other 29; truncated-16 monitor (Fig 4) and independent FC(29,10,1) monitor
(Fig 5, appendix).  Reports: FN rate (claim: 0), on-device model
compression, and communication reduction under threshold triggering
(paper claims ~6x size, ~10x comms).
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_financial import FULL as FIN
from repro.core import decomposition as deco, safety
from repro.core.gating import CommsMeter, trigger_mask
from repro.data.synthetic import financial_series, financial_xy
from repro.nn.module import param_count
from repro.training.loop import train_paper

STEPS = 2500


def _mlp_params(dims):
    return sum(dims[i] * dims[i + 1] + dims[i + 1] for i in range(len(dims) - 1))


def run(csv: List[str]) -> None:
    panel = financial_series(0)
    x, f = financial_xy(panel)
    key = jax.random.PRNGKey(2)
    thr, margin = FIN.threshold, 0.05

    for mode, kw, udesc in (
            ("truncated", {}, "truncate-16"),
            ("independent", {"u_dims": (29, 10, 1)}, "FC(29,10,1)")):
        t0 = time.time()
        params, res = train_paper(key, FIN, x, f, u_mode=mode, steps=STEPS,
                                  lr=2e-3, safety_weight=20.0, **kw)
        wall = (time.time() - t0) * 1e6 / STEPS
        out = res["out"]
        fj = jnp.asarray(f)
        rep = safety.metrics_report(fj, out["u"], out["fhat"], eps=0.01,
                                    threshold=thr)
        # on-device size: monitor head (or u_net) vs full server net V
        v_size = param_count(params["v"])
        if mode == "truncated":
            u_size = FIN.monitor_n + 1 + _mlp_params(
                (FIN.in_dim,) + tuple(FIN.hidden[:-1]) + (FIN.monitor_n,))
        else:
            u_size = param_count(params["u_net"]) + 1
        # communication: server consulted only when u > thr - margin
        mask = np.asarray(trigger_mask(out["u"], thr, margin))
        meter = CommsMeter(bytes_per_request=29 * 4)
        meter.update(int(mask.sum()), mask.size)
        csv.append(
            f"paper_fig4/{udesc},{wall:.1f},"
            f"l2={float(rep['l2']):.5f};fn={float(rep['fn']):.5f};"
            f"fp={float(rep['fp']):.5f};corr_fp={float(rep['corrected_fp']):.5f};"
            f"compression={v_size / u_size:.1f}x;"
            f"comms_reduction={meter.reduction:.1f}x;"
            f"trigger_rate={meter.trigger_rate:.4f}")
        print(csv[-1], flush=True)


if __name__ == "__main__":
    rows: List[str] = []
    run(rows)

"""Paper Fig 2: loss / FN / FP / corrected-FP landscape over (n, s) on the
synthetic exponential-decay dataset (§4.1).  Validates:
  - loss drops with large n or large s            (Fig 2a / Prop 2)
  - FN ~ 0 except when s >= 2 t(n) is violated    (Fig 2b)
  - on-device FP grows with s                     (Fig 2c / Prop 3)
  - corrected FP ~ 0 everywhere                   (Fig 2d)
"""
from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

from repro.configs.paper_synthetic import FULL as SYN
from repro.core import safety, theory
from repro.data.synthetic import paper_synthetic, synthetic_residual
from repro.training.loop import train_paper

N_GRID = (2, 6, 12, 24)
S_GRID = (0.05, 0.2, 0.5, 1.5)
N_MODES = 48  # full 100-mode target truncated for CPU runtime; rho matches
EPS = 0.05
STEPS = 900


def run(csv: List[str]) -> None:
    x, f = paper_synthetic(0, 4096, rho=SYN.rho, n_modes=N_MODES)
    key = jax.random.PRNGKey(0)
    import jax.numpy as jnp
    fj = jnp.asarray(f)
    for n in N_GRID:
        t = theory.t_of_n_sampled(
            lambda z: synthetic_residual(z, n, rho=SYN.rho, n_modes=N_MODES), x)
        for s in S_GRID:
            t0 = time.time()
            _, res = train_paper(key, SYN, x, f, u_mode="cosine",
                                 n_modes=N_MODES, monitor_n=n, s=s,
                                 freeze_t=t, steps=STEPS, lr=5e-3)
            out = res["out"]
            rep = safety.metrics_report(fj, out["u"], out["fhat"], eps=EPS)
            wall = (time.time() - t0) * 1e6 / STEPS
            csv.append(
                f"paper_fig2/n={n}/s={s},{wall:.1f},"
                f"l2={float(rep['l2']):.4f};fn={float(rep['fn']):.4f};"
                f"fp={float(rep['fp']):.4f};corr_fp={float(rep['corrected_fp']):.4f};"
                f"t={t:.4f};s_rule={theory.s_rule(t):.4f}")
            print(csv[-1], flush=True)


if __name__ == "__main__":
    rows: List[str] = []
    run(rows)

"""Benchmark harness — one module per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV rows (and tees them to
results/bench.csv).  Paper figures: fig2 (landscape), fig3 (s-sweep),
fig4 (financial).  Framework: kernels, serving, roofline (reads the
dry-run records; compile happens in repro.launch.dryrun).
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # benchmarks pkg

from benchmarks import (bench_kernels, bench_paper_fig2, bench_paper_fig3,
                        bench_paper_fig4, bench_roofline, bench_serving)

SUITES = {
    "paper_fig2": bench_paper_fig2.run,
    "paper_fig3": bench_paper_fig3.run,
    "paper_fig4": bench_paper_fig4.run,
    "kernels": bench_kernels.run,
    "serving": bench_serving.run,
    "roofline": bench_roofline.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names (default: all)")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(SUITES)

    rows: List[str] = ["name,us_per_call,derived"]
    t0 = time.time()
    for name in names:
        print(f"### suite: {name}", flush=True)
        SUITES[name](rows)
    out = os.path.join(os.path.dirname(__file__), "..", "results", "bench.csv")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as fh:
        fh.write("\n".join(rows) + "\n")
    print(f"\nwrote {len(rows)-1} rows to {out} in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()

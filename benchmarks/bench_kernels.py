"""Kernel micro-bench: XLA fallback path wall-time (CPU; per-call us) plus
analytic VMEM working-set / HBM-traffic derivations for the Pallas kernels
(the TPU numbers in EXPERIMENTS.md §Perf are derived, not timed — CPU
interpret-mode timings of Pallas are meaningless and are not reported).
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.nn.attention import chunked_attention, decode_attention


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
        jax.tree.leaves(out)[0].block_until_ready()
    return (time.time() - t0) / iters * 1e6


def run(csv: List[str]) -> None:
    key = jax.random.PRNGKey(0)
    # prefill attention (XLA chunked path)
    B, S, Hq, Hkv, D = 1, 2048, 8, 2, 64
    q = jax.random.normal(key, (B, S, Hq, D), jnp.bfloat16)
    k = jax.random.normal(key, (B, S, Hkv, D), jnp.bfloat16)
    v = jax.random.normal(key, (B, S, Hkv, D), jnp.bfloat16)
    fn = jax.jit(lambda q, k, v: chunked_attention(q, k, v, q_block=256))
    us = _time(fn, q, k, v)
    flops = 4 * B * S * S * Hq * D / 2  # causal
    csv.append(f"kernels/prefill_attn_xla_2k,{us:.1f},"
               f"gflops_cpu={flops/us/1e3:.2f}")
    # flash kernel derived numbers (TPU target): VMEM tiles + HBM traffic
    bq = bk = 128
    vmem = (bq * D + 2 * bk * D + bq * D + 2 * bq) * 4
    hbm_flash = (S * Hq * D + 2 * S * Hkv * D + S * Hq * D) * 2
    hbm_xla = hbm_flash + 2 * B * Hq * S * S * 4 / 2  # + materialised scores
    csv.append(f"kernels/flash_attn_derived,0.0,"
               f"vmem_per_block_kb={vmem/1024:.0f};"
               f"hbm_bytes_flash={hbm_flash:.3g};hbm_bytes_xla={hbm_xla:.3g};"
               f"traffic_reduction={hbm_xla/hbm_flash:.1f}x")

    # decode attention over a 32k cache
    C = 32768
    qd = jax.random.normal(key, (4, Hq, D), jnp.bfloat16)
    kc = jax.random.normal(key, (4, C, Hkv, D), jnp.bfloat16)
    vc = jax.random.normal(key, (4, C, Hkv, D), jnp.bfloat16)
    fn = jax.jit(lambda q, kc, vc: decode_attention(q, kc, vc, C - 1))
    us = _time(fn, qd, kc, vc)
    cache_bytes = 2 * 4 * C * Hkv * D * 2
    csv.append(f"kernels/decode_attn_32k,{us:.1f},"
               f"cache_bytes={cache_bytes};"
               f"v5e_floor_us={cache_bytes/819e9*1e6:.1f}")
    for row in csv[-3:]:
        print(row, flush=True)


if __name__ == "__main__":
    rows: List[str] = []
    run(rows)

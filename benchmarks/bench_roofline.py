"""Roofline table bench: renders the per-(arch x shape) three-term roofline
from the dry-run records (results/dryrun_single_pod.jsonl).  Compilation
happens in launch/dryrun.py (512 placeholder devices, its own process);
this bench only derives and prints.  Skips gracefully if no records exist.
"""
from __future__ import annotations

import json
import os
from typing import List

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun_single_pod.jsonl")


def run(csv: List[str]) -> None:
    if not os.path.exists(RESULTS):
        csv.append("roofline/missing,0.0,run=python -m repro.launch.dryrun")
        print(csv[-1])
        return
    with open(RESULTS) as fh:
        recs = [json.loads(l) for l in fh if l.strip()]
    ok = [r for r in recs if r.get("status") == "ok"]
    for r in ok:
        dom = r["bottleneck"]
        step_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        csv.append(
            f"roofline/{r['arch']}/{r['shape']},{step_s*1e6:.0f},"
            f"bottleneck={dom};compute_ms={r['compute_s']*1e3:.1f};"
            f"memory_ms={r['memory_s']*1e3:.1f};"
            f"collective_ms={r['collective_s']*1e3:.1f};"
            f"useful={r['useful_flops_ratio']*100:.1f}%")
        print(csv[-1], flush=True)
    csv.append(f"roofline/summary,0.0,pairs_ok={len(ok)};pairs_total={len(recs)}")
    print(csv[-1])


if __name__ == "__main__":
    rows: List[str] = []
    run(rows)

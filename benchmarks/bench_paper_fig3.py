"""Paper Fig 3: approximation error of f_hat vs corrector scale s, with the
theoretical choice s ~ rho^n/(1-rho) marked — the error should be near its
minimum at the theoretical s (blue triangle in the paper's figure).
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_synthetic import FULL as SYN
from repro.core import safety, theory
from repro.data.synthetic import paper_synthetic, synthetic_residual
from repro.training.loop import train_paper

N_LIST = (6, 10, 14)
S_SWEEP = (0.01, 0.05, 0.1, 0.3, 0.8, 2.0)
N_MODES = 48
STEPS = 700


def run(csv: List[str]) -> None:
    x, f = paper_synthetic(1, 4096, rho=SYN.rho, n_modes=N_MODES)
    fj = jnp.asarray(f)
    key = jax.random.PRNGKey(1)
    for n in N_LIST:
        s_theory = theory.exp_decay_s(SYN.rho, n)
        t = theory.t_of_n_sampled(
            lambda z: synthetic_residual(z, n, rho=SYN.rho, n_modes=N_MODES), x)
        errs = {}
        for s in sorted(set(S_SWEEP + (round(s_theory, 4),))):
            t0 = time.time()
            _, res = train_paper(key, SYN, x, f, u_mode="cosine",
                                 n_modes=N_MODES, monitor_n=n, s=s,
                                 freeze_t=t, steps=STEPS, lr=5e-3)
            errs[s] = float(safety.approx_error(fj, res["out"]["fhat"], 2.0))
            wall = (time.time() - t0) * 1e6 / STEPS
            csv.append(f"paper_fig3/n={n}/s={s},{wall:.1f},l2={errs[s]:.4f};"
                       f"s_theory={s_theory:.4f}")
            print(csv[-1], flush=True)
        best = min(errs, key=errs.get)
        csv.append(f"paper_fig3/n={n}/summary,0.0,"
                   f"best_s={best};theory_s={s_theory:.4f};"
                   f"err_at_theory={errs[round(s_theory,4)]:.4f};"
                   f"err_best={errs[best]:.4f}")
        print(csv[-1], flush=True)


if __name__ == "__main__":
    rows: List[str] = []
    run(rows)

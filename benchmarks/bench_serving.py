"""Collaborative serving bench: the batched lax.scan fast path vs the
per-token Python loop (the seed's only mode), the edge-vs-server step
costs, and the per-stream comms reduction the trigger buys (paper Fig 4).

Two workloads:
  * paper_synthetic (batch 8) — the LM analogue of the paper's synthetic
    experiment at the paper's tiny scale; this is where the scan fast
    path's dispatch-free decode shows its full tokens/sec advantage.
  * granite-8b smoke — LM-scale sanity rows (compute-dominated on CPU).
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.paper_synthetic import SERVING as PAPER_SERVING
from repro.core import decomposition as deco
from repro.data import tokens as tok
from repro.serving.collaborative import CollaborativeEngine
from repro.serving.engine import ServeEngine


def _bench_pair(name: str, cfg, batch: int, steps: int,
                csv: List[str]) -> None:
    """Per-token loop vs scan path on one config; appends two csv rows."""
    params = deco.init_collab_lm(jax.random.PRNGKey(0), cfg)
    stream = next(tok.lm_batches(0, cfg, batch, steps))["tokens"]
    max_len = steps + 8

    eng = CollaborativeEngine(params, cfg, batch=batch, max_len=max_len)
    warm = 4  # covers trigger AND no-trigger branches (catchup jit included)
    for t in range(warm):
        eng.step(jnp.asarray(stream[:, t]))
    t0 = time.time()
    for t in range(warm, steps):
        eng.step(jnp.asarray(stream[:, t]))
    dt_loop = time.time() - t0
    tps_loop = batch * (steps - warm) / dt_loop
    rep = eng.comms.report()
    csv.append(f"serving/{name}_step,{dt_loop / (steps - warm) * 1e6:.1f},"
               f"tokens_per_sec={tps_loop:.0f};"
               f"trigger_rate={rep['trigger_rate']:.3f};"
               f"reduction={rep['reduction_x']:.2f}x")

    sc = CollaborativeEngine(params, cfg, batch=batch, max_len=max_len)
    sc.run_scan(stream)  # compile
    t0 = time.time()
    res = sc.run_scan(stream)
    dt_scan = time.time() - t0
    tps_scan = batch * steps / dt_scan
    per = res["comms"]["per_stream"]["reduction_x"]
    csv.append(f"serving/{name}_scan,{dt_scan / steps * 1e6:.1f},"
               f"tokens_per_sec={tps_scan:.0f};"
               f"speedup_vs_loop={tps_scan / tps_loop:.1f}x;"
               f"per_stream_reduction={np.round(per, 2).tolist()}")


def run(csv: List[str]) -> None:
    # paper-synthetic scale, batch 8: the scan fast path's headline number
    _bench_pair("paper_synthetic", PAPER_SERVING, batch=8, steps=64, csv=csv)

    # LM smoke scale
    cfg = registry.get_smoke("granite-8b")
    _bench_pair("collab", cfg, batch=4, steps=48, csv=csv)

    # server-only baseline (every token through the big tower)
    params = deco.init_collab_lm(jax.random.PRNGKey(0), cfg)
    stream = next(tok.lm_batches(0, cfg, 4, 48))["tokens"]
    se = ServeEngine(params["server"], cfg, batch=4, max_len=64)
    se.decode(jnp.asarray(stream[:, 0]))
    t0 = time.time()
    for t in range(1, 33):
        se.decode(jnp.asarray(stream[:, t]))
    us_srv = (time.time() - t0) / 32 * 1e6
    csv.append(f"serving/server_only_step,{us_srv:.1f},edge_vs_server_note="
               f"smoke-scale")
    for row in csv[-5:]:
        print(row, flush=True)


if __name__ == "__main__":
    rows: List[str] = []
    run(rows)

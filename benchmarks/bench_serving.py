"""Collaborative serving bench: tokens/s of the edge monitor path vs the
always-consult-server baseline, and the comms-reduction the trigger buys —
the paper's Fig 4 claim, measured on the LM-scale system (smoke config).
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core import decomposition as deco
from repro.data import tokens as tok
from repro.serving.collaborative import CollaborativeEngine
from repro.serving.engine import ServeEngine


def run(csv: List[str]) -> None:
    key = jax.random.PRNGKey(0)
    cfg = registry.get_smoke("granite-8b")
    params = deco.init_collab_lm(key, cfg)
    stream = next(tok.lm_batches(0, cfg, 4, 48))["tokens"]

    # edge-only monitor throughput
    eng = CollaborativeEngine(params, cfg, batch=4, max_len=64)
    eng.step(jnp.asarray(stream[:, 0]))  # warm up jits
    t0 = time.time()
    for t in range(1, 33):
        eng.step(jnp.asarray(stream[:, t]))
    us_tok = (time.time() - t0) / 32 * 1e6
    rep = eng.comms.report()
    csv.append(f"serving/collab_step,{us_tok:.1f},"
               f"trigger_rate={rep['trigger_rate']:.3f};"
               f"reduction={rep['reduction_x']:.2f}x")

    # server-only baseline (every token through the big tower)
    se = ServeEngine(params["server"], cfg, batch=4, max_len=64)
    se.decode(jnp.asarray(stream[:, 0]))
    t0 = time.time()
    for t in range(1, 33):
        se.decode(jnp.asarray(stream[:, t]))
    us_srv = (time.time() - t0) / 32 * 1e6
    csv.append(f"serving/server_only_step,{us_srv:.1f},edge_vs_server_note="
               f"smoke-scale")
    for row in csv[-2:]:
        print(row, flush=True)


if __name__ == "__main__":
    rows: List[str] = []
    run(rows)
